"""Quickstart: build LIMS on a GaussMix dataset, run exact range / kNN /
point queries, insert + delete, and compare against brute force.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.baselines import LinearScan
from repro.core import LIMSIndex, MetricSpace
from repro.core.metrics import dist_one_to_many
from repro.data.datasets import gauss_mix

def main() -> None:
    print("== LIMS quickstart ==")
    X = gauss_mix(50_000, 8, seed=0)
    sp = MetricSpace(X, "l2")

    ix = LIMSIndex(sp, n_clusters=50, m=3, n_rings=20)
    print(f"built LIMS over {sp.n:,} points in {ix.build_time_s:.2f}s "
          f"(index {ix.index_nbytes()/2**20:.1f} MiB, "
          f"K={ix.K}, m={ix.m}, N={ix.n_rings})")
    scan = LinearScan(sp)

    rng = np.random.default_rng(1)
    q = X[rng.integers(sp.n)] + rng.normal(0, 0.003, 8)

    # ---- range query -------------------------------------------------
    d = dist_one_to_many(q, X, "l2")
    r = float(np.quantile(d, 1e-4))     # 0.01% selectivity, paper default
    ids, ds, st = ix.range_query(q, r)
    truth = set(np.where(d <= r)[0].tolist())
    assert set(map(int, ids)) == truth, "range query must be EXACT"
    _, _, st_scan = scan.range_query(q, r)
    print(f"range(q, {r:.3f}): {len(ids)} results | LIMS pages={st.pages} "
          f"vs scan pages={st_scan.pages} "
          f"({st_scan.pages/max(st.pages,1):.0f}x fewer reads)")

    # ---- kNN query -----------------------------------------------------
    ids, ds, st = ix.knn_query(q, 10)
    assert abs(np.sort(ds)[-1] - np.sort(d)[9]) < 1e-9, "kNN must be EXACT"
    print(f"knn(q, 10): kth distance {np.sort(ds)[-1]:.4f} | "
          f"pages={st.pages} dist_comps={st.dist_comps}")

    # ---- point query ---------------------------------------------------
    ids, st = ix.point_query(X[123])
    assert 123 in set(map(int, ids))
    print(f"point(X[123]): found with {st.pages} page reads")

    # ---- updates --------------------------------------------------------
    gid = ix.insert(q)
    ids, _, _ = ix.range_query(q, 1e-6)
    assert gid in set(map(int, ids)), "inserted object must be findable"
    ix.delete(q)
    ids, _, _ = ix.range_query(q, 1e-6)
    assert gid not in set(map(int, ids)), "deleted object must disappear"
    print("insert/delete: exact through the per-cluster buffer + tombstones")
    print("OK")


if __name__ == "__main__":
    main()
