"""End-to-end training driver: a ~100M-param llama-style model trained for
a few hundred steps on CPU with the full production stack — sharded data
pipeline, AdamW, checkpointing, fault-tolerant loop (with an injected
failure to prove restart works).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs.base import ModelConfig, RunConfig, ShapeCell
from repro.data.pipeline import DataShard, _batch_for_step
from repro.models import zoo
from repro.models.params import count_params, init_params
from repro.runtime.fault import FaultConfig, run_training
from repro.train.step import build_train_step, init_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: 12L x 512d x 8H, 50k vocab
    cfg = ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab=50_257, head_dim=64,
        attn_impl="dense", remat="none", dtype="float32")
    run = RunConfig(optimizer="adamw", learning_rate=3e-4)
    cell = ShapeCell("train", args.seq, args.batch, "train")

    specs = zoo.model_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0), cfg.dtype)
    print(f"model: {count_params(specs)/1e6:.1f}M params")
    state = init_state(cfg, run, params)
    step = jax.jit(build_train_step(cfg, run, total_steps=args.steps))

    def batches(s: int):
        return _batch_for_step(s, DataShard(0, 1), cfg.vocab, args.batch,
                               args.seq)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        fc = FaultConfig(ckpt_dir=ckpt_dir, ckpt_every=50,
                         inject_failures_at=(args.steps // 2,))
        state, stats = run_training(step, state, batches, args.steps, fc)
    first = sum(stats.losses[:10]) / max(len(stats.losses[:10]), 1)
    last = sum(stats.losses[-10:]) / max(len(stats.losses[-10:]), 1)
    print(f"steps={stats.steps_run} restarts={stats.restarts} "
          f"(1 injected failure survived)")
    print(f"loss: first10={first:.3f} -> last10={last:.3f}")
    assert last < first, "model must learn"
    print("OK")


if __name__ == "__main__":
    main()
