"""Retrieval serving: the paper's index as the framework's retrieval layer.

An LM encodes queries into its embedding space; LIMS answers *exact* kNN
over a corpus of embeddings. Serving runs through the layered stack
(DESIGN.md §1): a ``BatchedLIMS`` snapshot executor first (the whole
query batch through the Pallas kernels `pdist` → `rankeval` →
`range_filter` in one launch sequence — compiled on TPU/GPU, interpreted
on CPU), then the full ``ServingEngine`` lifecycle: online inserts with
double-buffered snapshot refresh, auto-sharding across every visible
device — and finally the ``ServingFrontend`` (DESIGN.md §9), which
coalesces concurrent single-query submitters into kernel batches and
routes them across a replica set, bit-identically. The host index
answers the same queries as a cross-check; both are exact. This is the
deployment story in DESIGN.md §2: the index serves the models the
framework trains.

    PYTHONPATH=src python examples/retrieval_serving.py
    # exercise the cluster-sharded executor on fake host devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/retrieval_serving.py
"""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import LIMSIndex, MetricSpace, ServingEngine
from repro.core.batched import BatchedLIMS
from repro.core.metrics import dist_one_to_many
from repro.models import zoo
from repro.models.params import init_params
from repro.models.transformer import forward_seq


def main() -> None:
    # 1) a small encoder LM produces the embedding space
    cfg = ModelConfig(
        name="encoder-20m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=1024, vocab=8192, head_dim=64,
        attn_impl="dense", remat="none", dtype="float32")
    params = init_params(zoo.model_specs(cfg), jax.random.PRNGKey(0),
                         cfg.dtype)

    @jax.jit
    def encode(tokens):
        x, _, _ = forward_seq(params, tokens, cfg)
        # mean-pool, then matryoshka-style truncation to 32 dims: metric
        # indexes live in moderate intrinsic dimension (the paper evaluates
        # ≤65d); retrieval is exact in the indexed embedding space
        return x.mean(axis=1)[:, :32]

    rng = np.random.default_rng(0)
    # a realistic corpus clusters by topic: 100 anchor docs, 50 noisy
    # variants each (edit a few tokens) — similar docs ⇒ nearby embeddings
    anchors = rng.integers(0, cfg.vocab, (100, 32))
    corpus_tokens = np.repeat(anchors, 50, axis=0)
    for i in range(5_000):
        corpus_tokens[i, rng.integers(0, 32)] = rng.integers(0, cfg.vocab)
    corpus = np.asarray(encode(jnp.asarray(corpus_tokens)))
    print(f"corpus: {corpus.shape[0]:,} docs embedded to d={corpus.shape[1]}")

    # 2) LIMS indexes the embedding corpus (exact metric index)
    sp = MetricSpace(corpus.astype(np.float64), "l2")
    # K should track the corpus's natural cluster count (the paper's
    # OR+λMAE elbow finds this automatically; here the corpus has 100
    # topics, so clusters must be at least that fine to be tight)
    ix = LIMSIndex(sp, n_clusters=100, m=3, n_rings=20)
    print(f"LIMS built in {ix.build_time_s:.2f}s "
          f"({ix.index_nbytes()/2**20:.2f} MiB index)")

    # 3) serve batched queries: encode -> exact kNN (queries are noisy
    # variants of corpus docs, the retrieval workload)
    q_tokens = np.repeat(anchors[:16], 1, axis=0)
    for i in range(16):
        q_tokens[i, rng.integers(0, 32)] = rng.integers(0, cfg.vocab)
    # calibrate the kNN radius step Δr to the neighbor-distance scale
    # (Alg. 2 takes Δr as input; too-large steps overshoot the kth ball)
    probe = sp.data[rng.choice(sp.n, 64)]
    nn_scale = np.median([np.partition(
        dist_one_to_many(p, sp.data, "l2"), 6)[6] for p in probe])
    q_emb = np.asarray(encode(jnp.asarray(q_tokens)))
    t0 = time.perf_counter()          # time the serving loop, not encoding
    pages = 0
    for i, q in enumerate(q_emb.astype(np.float64)):
        ids, ds, st = ix.knn_query(q, 5, delta_r=float(nn_scale) / 2)
        pages += st.pages
        truth = np.argsort(dist_one_to_many(q, sp.data, "l2"))[:5]
        assert abs(np.sort(ds)[-1] -
                   dist_one_to_many(q, sp.data, "l2")[truth[-1]]) < 1e-9, \
            "retrieval must be exact"
    dt = time.perf_counter() - t0
    total_pages = -(-sp.n // ix.clusters[0].store.omega)
    print(f"16 queries: {dt*1e3:.1f} ms end-to-end, "
          f"avg pages/query={pages/16:.1f} "
          f"(corpus is {total_pages} pages — "
          f"{total_pages/(pages/16):.0f}x less I/O than a scan)")
    print("all 16 kNN results verified exact. OK")

    # 4) the batched serving path: one snapshot, the whole query batch
    # through the Pallas kernels in a single launch sequence
    bx = BatchedLIMS(ix)
    # warm-up with the serving batch shape (jit caches key on shapes)
    bx.knn_query_batch(q_emb.astype(np.float64), 5)
    t0 = time.perf_counter()
    ids_b, ds_b = bx.knn_query_batch(q_emb.astype(np.float64), 5)
    dt_b = time.perf_counter() - t0
    for i, q in enumerate(q_emb.astype(np.float64)):
        d_all = dist_one_to_many(q, sp.data, "l2")
        assert abs(np.sort(ds_b[i])[-1] - np.sort(d_all)[4]) < 1e-9, \
            "batched retrieval must be exact"
    print(f"batched engine: 16 queries in {dt_b*1e3:.1f} ms "
          f"({16/dt_b:.0f} q/s, {dt/dt_b:.1f}x vs per-query host serving); "
          f"all 16 verified exact. OK")

    # 5) the serving frontend: online updates + double-buffered snapshot
    # refresh, auto-sharded across every visible device (DESIGN.md §4-5)
    # build_backend pinned so the retrain demo below takes the device
    # path even on CPU-interpret (the default resolves by dispatch
    # policy: device wherever the kernels compile)
    se = ServingEngine(ix, refresh_every=8, build_backend="device")
    ex = se.executor
    print(f"ServingEngine: {type(ex).__name__} over "
          f"{getattr(ex, 'n_shards', 1)} of {jax.device_count()} device(s)")
    # new docs arrive while serving: 8 fresh variants of anchor 0
    fresh_tokens = np.repeat(anchors[:1], 8, axis=0)
    for i in range(8):
        fresh_tokens[i, rng.integers(0, 32)] = rng.integers(0, cfg.vocab)
    fresh = np.asarray(encode(jnp.asarray(fresh_tokens)), np.float64)
    gids = [se.insert(row) for row in fresh]        # 8th insert → refresh
    assert se.generation == 1, "refresh_every=8 must have fired"
    ids_f, ds_f = se.knn_query_batch(fresh, 1)
    assert [int(i) for i in ids_f[:, 0]] == gids, \
        "each fresh doc must be its own exact 1-NN after the swap"
    print(f"inserted {len(gids)} docs; snapshot generation "
          f"{se.generation} swapped in, all {len(gids)} retrievable. OK")

    # 6) device-side (re)builds: the whole §4 build pipeline — batched
    # clustering, FFT pivots, pdist-kernel distance columns, every rank/
    # position model in one least-squares launch — runs through
    # repro.build (DESIGN.md §6); results stay exact because all bounds
    # are recomputed exactly at materialization
    t0 = time.perf_counter()
    ix_dev = LIMSIndex(MetricSpace(sp.data, "l2"), n_clusters=100, m=3,
                       n_rings=20, backend="device")
    t_dev = time.perf_counter() - t0
    q0 = q_emb.astype(np.float64)[0]
    _, ds_d, _ = ix_dev.knn_query(q0, 5, delta_r=float(nn_scale) / 2)
    truth = np.sort(dist_one_to_many(q0, sp.data, "l2"))[:5]
    # (the serving engine above already folded fresh docs into `ix`, so
    # the freshly device-built index is checked against ground truth
    # over its own corpus)
    assert np.array_equal(np.sort(ds_d), truth), \
        "device-built index must be exact"
    print(f"device builder: full rebuild in {t_dev:.2f}s vs "
          f"{ix.build_time_s:.2f}s host build; exact 5-NN verified. OK")

    # online retrain of a dirty cluster through the device builder:
    # fold the freshest cluster's insert buffer into its ring structure
    dirty = max(range(ix.K), key=lambda c: len(ix.clusters[c].buf_ids))
    t0 = time.perf_counter()
    se.retrain_cluster(dirty)                       # device-routed
    t_retrain = time.perf_counter() - t0
    t0 = time.perf_counter()
    ix.retrain_cluster(dirty, backend="host")       # now-idempotent rerun
    t_host_retrain = time.perf_counter() - t0
    ids_f, _ = se.knn_query_batch(fresh, 1)
    assert [int(i) for i in ids_f[:, 0]] == gids, \
        "retrained cluster must still serve every folded-in doc"
    print(f"retrain_cluster({dirty}): {t_retrain*1e3:.0f} ms via the "
          f"device builder ({t_host_retrain*1e3:.0f} ms host rerun); "
          f"all inserts still retrievable. OK")

    # 7) the paged storage tier (DESIGN.md §7): spill the snapshot to
    # disk — rows laid out in learned-position page extents — then
    # cold-start a fresh replica from the spilled directory.  Only the
    # manifest + metadata load up front; row pages fault in on demand,
    # driven by the certified candidate intervals, so the learned
    # positions finally do the job the paper built them for: deciding
    # which disk pages a query touches.
    spill_dir = tempfile.mkdtemp(prefix="lims-spill-")
    t0 = time.perf_counter()
    manifest = ix.spill(spill_dir)
    t_spill = time.perf_counter() - t0
    t0 = time.perf_counter()
    cold = ServingEngine.from_spill(spill_dir)
    t_cold = time.perf_counter() - t0
    ids_cold, _ = cold.knn_query_batch(fresh, 1)
    assert [int(i) for i in ids_cold[:, 0]] == gids, \
        "cold-started replica must serve the spilled snapshot exactly"
    io = cold.executor.last_io
    st = cold.store.stats.snapshot()
    print(f"paged store: spilled {manifest.total_pages} pages "
          f"({cold.store.nbytes_file()/2**20:.1f} MiB) in {t_spill:.2f}s; "
          f"cold start in {t_cold:.2f}s")
    print(f"cold replica: batch of {len(fresh)} kNN queries touched "
          f"{io['pages']} pages ({st['pages_per_query']:.1f}/query, "
          f"{st['candidates_per_query']:.0f} candidates/query, cache hit "
          f"rate {st['hit_rate']:.0%}); results match the warm engine. OK")

    # 8) the serving frontend (DESIGN.md §9): real traffic is single
    # queries from many clients, not pre-assembled batches.  The
    # frontend coalesces concurrent submitters into kernel-shaped
    # batches under a latency SLO and routes each batch's sub-batches
    # across a replica set (one replica per device) by the batch's own
    # CandidatePlan — per-query results stay bit-identical to a direct
    # executor call, so batching and routing are pure performance.
    import threading
    with cold.frontend(max_batch=16, slo_ms=10.0, max_queue=64) as fe:
        got = [None] * len(fresh)
        threads = [threading.Thread(
            target=lambda j=j: got.__setitem__(j, fe.knn_query(fresh[j], 1)))
            for j in range(len(fresh))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert [int(ids[0]) for ids, _ in got] == gids, \
            "frontend results must equal the direct executor's"
        m = fe.metrics()
    repl = m["routing"]["replicas"]
    print(f"frontend: {m['submitted']} concurrent submitters → "
          f"{m['batches']} kernel batch(es) "
          f"(mean size {m['batch_size_mean']}, queue wait "
          f"p99 {m['queue_wait_ms_p99']:.1f} ms, shed rate "
          f"{m['shed_rate']:.0%}) over {len(repl)} replica(s); "
          f"all results exact. OK")

    # 9) observability (DESIGN.md §11): everything above was also being
    # measured.  Under REPRO_OBS=trace every span on the query path —
    # frontend coalescing, plan construction, kernel execution, page
    # fetches — lands in a Chrome trace_event ring, every served batch
    # yields a structured QueryProfile (the paper's per-query costs:
    # pages, candidates, pruning power, rounds, per-stage latency), and
    # the registry holds the long-run counters and latency histograms.
    from repro import obs
    obs.configure("trace")
    cold.knn_query_batch(fresh, 1)          # one traced batch
    prof = cold.executor.last_profile
    assert prof is not None and prof.missing() == [], \
        f"served batch must yield a complete QueryProfile: {prof}"
    trace_path = os.path.join(spill_dir, "serving.trace.json")
    n_events = obs.write_chrome_trace(trace_path)
    assert n_events > 0, "trace mode must record query-path spans"
    d = prof.as_dict()
    print(f"observability: {d['kind']} batch of {d['batch']} on "
          f"{d['backend']}/{d['storage']} → profile: "
          f"{d['pages_per_query']:.1f} pages/query, "
          f"{d['candidates_per_query']:.0f} candidates/query, "
          f"{d['clusters_per_query']:.1f}/{d['n_clusters']} clusters, "
          f"{d['rounds']} round(s), stages "
          f"{ {k: round(v, 2) for k, v in d['stages_ms'].items()} } ms; "
          f"{n_events} trace events -> {trace_path} "
          f"(load in Perfetto). OK")

    # 10) continuous health monitoring (DESIGN.md §12): inject placement
    # drift — pin every cluster's ownership to replica 0 while query
    # heat stays spread — then drive manual monitor ticks and watch the
    # closed loop repair it: the heat-skew detector fires a finding, the
    # MonitorDaemon rebalances ownership from live heat (within its
    # action cooldown), and the health report shows the recovery.
    # Results stay bit-identical throughout: ownership only biases
    # routing, never answers.
    from repro.obs.monitor import Monitor
    from repro.serving import MonitorDaemon, PlanRouter, ReplicaSet
    snap = cold.executor.snap
    replicas = ReplicaSet(snap, n_replicas=4)
    router = PlanRouter(replicas)
    mon = Monitor(interval=3600.0)          # ticked by hand below
    daemon = MonitorDaemon(mon, lambda: router, engine=cold,
                           cooldown_ticks=3)
    baseline_ids, _ = router.knn_query_batch(fresh, 3)
    replicas.set_ownership(np.zeros(snap.K, np.int64))   # the drift
    for _ in range(6):
        ids, _ = router.knn_query_batch(fresh, 3)
        assert np.array_equal(ids, baseline_ids), \
            "results must stay exact under drift and rebalance"
        mon.tick()
    findings = [f for f in mon.findings() if f.detector == "heat_skew"]
    rebalances = [e for e in daemon.events() if e["action"] == "rebalance"]
    assert findings and rebalances, \
        "injected drift must fire a finding and a rebalance"
    from repro.obs.report import render_health
    print(render_health(mon, daemon))
    print(f"monitoring: drift skew {findings[0].value:.1f}x fired at "
          f"tick {findings[0].tick}, daemon rebalanced at tick "
          f"{rebalances[0]['tick']}, post-rebalance skew "
          f"{mon.store.get('router.heat_skew').last():.2f}x; results "
          f"bit-identical throughout. OK")


if __name__ == "__main__":
    main()
