"""Frozen copies of the PR-4 query drivers — golden references.

These are the four hand-rolled drivers the plan/execute refactor
replaced (resident/store × range/kNN), copied verbatim from the
pre-refactor ``repro.core.executor`` and kept as executable golden
outputs: the unified CandidatePlan path must return results bit-identical
to every one of them, on every CI leg.  They run against a *new-style*
executor object, using only the stable hooks the refactor kept
(``_candidate_mask``, ``_sq_dists``, ``_refine_rows``, ``snap``) plus
the kernel wrappers and the IO-batch scheduler — so the masks and kernel
math they consume are the same ones the unified path consumes, and any
divergence is attributable to the driver logic itself.

Do not "improve" this file: it is a pin, not production code.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.metrics import dist_one_to_many
from repro.core.planner import _BALL_ABS, _R_REL
from repro.kernels import ops
from repro.storage import plan_batch

_FAR = np.float32(1e30)


def _pad_bucket(rows32: np.ndarray, min_rows: int = 128) -> np.ndarray:
    """Pre-refactor power-of-two bucketing for store-mode launches."""
    n = rows32.shape[0]
    bucket = max(min_rows, 1 << max(n - 1, 1).bit_length())
    if bucket <= n:
        return rows32
    pad = np.full((bucket - n, rows32.shape[1]), _FAR, np.float32)
    return np.concatenate([rows32, pad])


def _refine_topk(ex, Q, final: np.ndarray, k_eff: int):
    """The shared exact-refinement tail, as it was."""
    s = ex.snap
    B = Q.shape[0]
    ids_out = np.empty((B, k_eff), np.int64)
    d_out = np.empty((B, k_eff))
    for b in range(B):
        idx = np.nonzero(final[b])[0]
        d_true = dist_one_to_many(Q[b], ex._refine_rows(idx), "l2")
        sel = np.argsort(d_true, kind="stable")[:k_eff]
        ids_out[b] = s.gids_np[idx[sel]]
        d_out[b] = d_true[sel]
    return ids_out, d_out


# ------------------------------------------------------------- range drivers
def range_resident(ex, Q, r):
    """PR-4 ``QueryExecutor.range_query_batch`` on a resident snapshot."""
    s = ex.snap
    Q = np.atleast_2d(np.asarray(Q, np.float64))
    B = Q.shape[0]
    r_arr = np.broadcast_to(np.asarray(r, np.float64), (B,))
    qf = jnp.asarray(Q, jnp.float32)
    rf = jnp.asarray(r_arr, jnp.float32)
    cand = ex._candidate_mask(qf, rf)
    ball, _ = ops.range_filter(qf, s.rows.reshape(s.n_slots, s.d),
                               rf * (1.0 + _R_REL) + _BALL_ABS)
    hit = np.asarray(cand & ball.astype(bool))
    out = []
    for b in range(B):
        idx = np.nonzero(hit[b])[0]
        ids = s.gids_np[idx]
        d_true = dist_one_to_many(Q[b], ex._refine_rows(idx), "l2")
        keep = d_true <= r_arr[b]
        out.append((ids[keep], d_true[keep]))
    return out


def range_store(ex, Q, r):
    """PR-4 ``QueryExecutor._hits_store`` + refinement on a paged snapshot."""
    s = ex.snap
    store = s.store
    Q = np.atleast_2d(np.asarray(Q, np.float64))
    B = Q.shape[0]
    r_arr = np.broadcast_to(np.asarray(r, np.float64), (B,))
    qf = jnp.asarray(Q, jnp.float32)
    rf = jnp.asarray(r_arr, jnp.float32)
    cand = np.asarray(ex._candidate_mask(qf, rf))
    plan = plan_batch(cand, store.layout)
    store.fetch(plan)
    hit = np.zeros_like(cand)
    if len(plan.slots):
        rows64 = store.gather(plan.slots)
        ball, _ = ops.range_filter(
            qf, jnp.asarray(_pad_bucket(rows64.astype(np.float32))),
            rf * (1.0 + _R_REL) + _BALL_ABS)
        ball = np.asarray(ball, bool)[:, :len(plan.slots)]
        hit[:, plan.slots] = cand[:, plan.slots] & ball
    out = []
    for b in range(B):
        idx = np.nonzero(hit[b])[0]
        ids = s.gids_np[idx]
        d_true = dist_one_to_many(Q[b], ex._refine_rows(idx), "l2")
        keep = d_true <= r_arr[b]
        out.append((ids[keep], d_true[keep]))
    return out


# --------------------------------------------------------------- kNN drivers
def knn_resident(ex, Q, k: int, max_rounds: int = 64):
    """PR-4 host-driven growing-radius kNN over a resident snapshot
    (per-round host sync, f32 k-th-distance seeding)."""
    s = ex.snap
    Q = np.atleast_2d(np.asarray(Q, np.float64))
    B = Q.shape[0]
    k_eff = min(int(k), s.live)
    if k_eff <= 0:
        return (np.empty((B, 0), np.int64), np.empty((B, 0)))
    qf = jnp.asarray(Q, jnp.float32)
    d2 = ex._sq_dists(qf)
    kth0 = jnp.sqrt(jnp.maximum(
        -jax.lax.top_k(-d2, k_eff)[0][:, -1], 0.0))
    r = np.asarray(kth0, np.float64) * (1.0 + 1e-3) + _BALL_ABS
    done = np.zeros(B, bool)
    final = np.zeros((B, d2.shape[1]), bool)
    for _ in range(max_rounds):
        rf = jnp.asarray(r, jnp.float32)
        cand = ex._candidate_mask(qf, rf)
        ball = d2 <= ((rf * (1.0 + _R_REL) + _BALL_ABS) ** 2)[:, None]
        candb = cand & ball
        cnt = jnp.sum(candb, axis=1)
        dm = jnp.where(candb, d2, jnp.inf)
        kth = jnp.sqrt(jnp.maximum(
            -jax.lax.top_k(-dm, k_eff)[0][:, -1], 0.0))
        ok = np.asarray((cnt >= k_eff) &
                        (kth <= rf * (1.0 - _R_REL) - _BALL_ABS))
        newly = ok & ~done
        if newly.any():
            final[newly] = np.asarray(candb)[newly]
            done |= newly
        if done.all():
            break
        r = np.where(done, r, r * 2.0)
    else:
        final[~done] = s.valid_np[None]
    return _refine_topk(ex, Q, final, k_eff)


def knn_store(ex, Q, k: int, max_rounds: int = 64):
    """PR-4 ``QueryExecutor._knn_store``: host-driven growing-radius kNN
    whose IO is the candidate pages (pivot-distance seeding)."""
    s = ex.snap
    store = s.store
    Q = np.atleast_2d(np.asarray(Q, np.float64))
    B = Q.shape[0]
    k_eff = min(int(k), s.live)
    if k_eff <= 0:
        return (np.empty((B, 0), np.int64), np.empty((B, 0)))
    qf = jnp.asarray(Q, jnp.float32)
    K, n_max, m = s.rids.shape
    dq = np.asarray(jnp.sqrt(jnp.maximum(
        ops.pdist(qf, s.pivots.reshape(K * m, s.d)), 0.0)))
    live_k = s.valid_np.reshape(K, n_max).any(axis=1)
    dqm = np.where(np.repeat(live_k, m)[None], dq, np.inf)
    r = dqm.min(axis=1).astype(np.float64) * (1.0 + 1e-3) + _BALL_ABS
    done = np.zeros(B, bool)
    final = np.zeros((B, s.n_slots), bool)
    pos = np.full(s.n_slots, -1, np.int64)
    d2g = np.empty((B, 0), np.float32)
    pages_seen = [set() for _ in range(B)]
    seen = np.zeros((B, s.n_slots), bool)
    for _ in range(max_rounds):
        rf = jnp.asarray(r, jnp.float32)
        cand = np.array(ex._candidate_mask(qf, rf))
        cand[done] = False
        plan = plan_batch(cand, store.layout, per_query=False)
        store.fetch(plan)
        newly = cand & ~seen
        seen |= cand
        for b in np.nonzero(newly.any(axis=1))[0]:
            pages_seen[b].update(store.layout.slot_pages(
                np.nonzero(newly[b])[0]).tolist())
        new = plan.slots[pos[plan.slots] < 0]
        if len(new):
            rows64 = store.gather(new)
            d2_new = np.asarray(ops.pdist(
                qf, jnp.asarray(_pad_bucket(
                    rows64.astype(np.float32)))))[:, :len(new)]
            pos[new] = d2g.shape[1] + np.arange(len(new))
            d2g = np.concatenate([d2g, d2_new], axis=1)
        r32 = np.asarray(rf)
        thr = (r32 * np.float32(1.0 + _R_REL) +
               np.float32(_BALL_ABS)) ** 2
        cert = r32 * np.float32(1.0 - _R_REL) - np.float32(_BALL_ABS)
        for b in np.nonzero(~done)[0]:
            sl = np.nonzero(cand[b])[0]
            if len(sl) < k_eff:
                continue
            db = d2g[b, pos[sl]]
            inball = db <= thr[b]
            if int(inball.sum()) < k_eff:
                continue
            kth = np.sqrt(np.float32(max(
                np.partition(db[inball], k_eff - 1)[k_eff - 1], 0.0)))
            if kth <= cert[b]:
                final[b, sl[inball]] = True
                done[b] = True
        if done.all():
            break
        r = np.where(done, r, r * 2.0)
    else:
        final[~done] = s.valid_np[None]
        seen[~done] = s.valid_np[None]
    return _refine_topk(ex, Q, final, k_eff)
