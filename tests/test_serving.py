"""Serving-stack correctness: the snapshot → executor → serving layers.

Covers the acceptance property (``ShardedExecutor`` results bit-identical
to single-device ``BatchedLIMS`` and to the host ``LIMSIndex``), snapshot
pytree purity/padding, and update-then-snapshot consistency through
``ServingEngine`` (insert / delete / retrain_cluster → refresh → exact
results, including tombstoned-row exclusion and buffer rows).

With one visible device the sharded path degrades to the single-device
pipeline (asserted below); CI runs this file a second time under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the real
``shard_map`` path is exercised on every PR.
"""
import numpy as np
import pytest

import jax

from repro.core import LIMSIndex, MetricSpace
from repro.core.batched import BatchedLIMS
from repro.core.executor import QueryExecutor, ShardedExecutor
from repro.core.metrics import dist_one_to_many
from repro.core.serving import ServingEngine
from repro.core.snapshot import LIMSSnapshot
from repro.data.datasets import gauss_mix

N, D = 1800, 6


@pytest.fixture(scope="module")
def setup():
    X = gauss_mix(N, D, seed=7)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=6, m=3, n_rings=10)
    return X, ix


def _queries(X, n_q, seed=2, scale=0.004):
    rng = np.random.default_rng(seed)
    return X[rng.choice(len(X), n_q)] + rng.normal(0, scale, (n_q, D))


def _radii(X, Q, sel=0.02):
    return np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), sel))
                     for q in Q])


# ---------------------------------------------------------------- snapshot
def test_snapshot_is_a_pytree(setup):
    X, ix = setup
    snap = LIMSSnapshot.build(ix)
    leaves = jax.tree_util.tree_leaves(snap)
    assert len(leaves) == 15            # the device arrays, nothing else
    snap2 = jax.tree_util.tree_map(lambda a: a, snap)
    assert isinstance(snap2, LIMSSnapshot)
    assert snap2.K == snap.K and snap2.live == snap.live
    for a, b in zip(leaves, jax.tree_util.tree_leaves(snap2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_snapshot_pad_clusters_is_inert(setup):
    """Padded clusters must contribute nothing: identical query results
    through the same executor, and all-dead padding slots."""
    X, ix = setup
    snap = LIMSSnapshot.build(ix)
    padded = snap.pad_clusters(snap.K + 3)
    assert padded.K == snap.K + 3
    assert padded.live == snap.live
    assert not padded.valid_np[snap.K * snap.n_max:].any()
    assert (padded.gids_np[snap.K * snap.n_max:] == -1).all()
    Q = _queries(X, 5)
    rs = _radii(X, Q)
    a = QueryExecutor(snap).range_query_batch(Q, rs)
    b = QueryExecutor(padded).range_query_batch(Q, rs)
    for (ai, ad), (bi, bd) in zip(a, b):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)


# ------------------------------------------------------- sharded execution
def test_sharded_bit_identical_to_single_device_and_host(setup):
    """The acceptance criterion. On a 1-device run this asserts the
    documented fallback; under the 4-fake-device CI job it runs the real
    shard_map path (and the padding that 6 clusters on 4 devices needs).
    """
    X, ix = setup
    snap = LIMSSnapshot.build(ix)
    bx = BatchedLIMS(ix)
    sx = ShardedExecutor(snap)
    assert sx.n_shards == jax.device_count()
    if jax.device_count() > 1:
        assert sx.snap.K % sx.n_shards == 0     # cluster padding applied
    Q = _queries(X, 8, seed=3)
    rs = _radii(X, Q)
    rs[0] = 1e-12                               # provably empty query
    sharded = sx.range_query_batch(Q, rs)
    single = bx.range_query_batch(Q, rs)
    assert len(sharded[0][0]) == 0
    for (s_ids, s_ds), (b_ids, b_ds), q, r in zip(sharded, single, Q, rs):
        assert np.array_equal(s_ids, b_ids)
        assert np.array_equal(s_ds, b_ds)
        h_ids, h_ds, _ = ix.range_query(q, r)
        assert set(map(int, s_ids)) == set(map(int, h_ids))
        np.testing.assert_allclose(np.sort(s_ds), np.sort(h_ds), atol=0)

    ids_s, ds_s = sx.knn_query_batch(Q, 6)
    ids_b, ds_b = bx.knn_query_batch(Q, 6)
    assert np.array_equal(ids_s, ids_b) and np.array_equal(ds_s, ds_b)
    for b, q in enumerate(Q):
        h_ids, h_ds, _ = ix.knn_query(q, 6)
        np.testing.assert_allclose(np.sort(ds_s[b]), np.sort(h_ds), atol=0)
        assert set(map(int, ids_s[b])) == set(map(int, h_ids))


def test_sharded_runs_through_kernels(setup, monkeypatch):
    """The sharded path must execute the same Pallas kernel pipeline
    (pdist / rankeval / range_filter via the ops wrappers).

    On the multi-device path the ops wrappers run at shard_map trace
    time, and the jitted pipeline is shared across executors via
    ``_sharded_pipeline``'s cache — drop it so this executor retraces
    under the patched wrappers instead of reusing a compiled artifact."""
    from repro.core.executor import _sharded_pipeline
    from repro.kernels import ops
    _sharded_pipeline.cache_clear()
    X, ix = setup
    calls = {"pdist": 0, "rankeval": 0, "range_filter": 0}
    real = {name: getattr(ops, name) for name in calls}

    def wrap(name):
        def fn(*a, **k):
            calls[name] += 1
            return real[name](*a, **k)
        return fn

    for name in calls:
        monkeypatch.setattr(ops, name, wrap(name))
    sx = ShardedExecutor(LIMSSnapshot.build(ix))
    Q = _queries(X, 3, seed=11)
    sx.range_query_batch(Q, _radii(X, Q))
    assert calls["pdist"] >= 1
    assert calls["rankeval"] >= 1
    assert calls["range_filter"] >= 1


# ------------------------------------------------------------ serving engine
def test_update_then_snapshot_consistency():
    """Satellite requirement: insert/delete/retrain_cluster on the host
    index, rebuild via ServingEngine.refresh(), and batch results stay
    bit-identical to the host — tombstoned rows excluded, buffer rows
    included."""
    rng = np.random.default_rng(0)
    X = gauss_mix(1400, D, seed=5)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=5, m=3, n_rings=10)
    se = ServingEngine(ix, refresh_every=0)     # manual refresh only
    new_rows = X[rng.choice(1400, 20)] + rng.normal(0, 0.02, (20, D))
    gids = [se.insert(r) for r in new_rows]
    assert se.delete(X[3]) == 1                 # stored row → tombstone
    assert se.delete(new_rows[0]) == 1          # buffered row → tombstone
    se.retrain_cluster(0)                       # fold cluster 0's buffer in
    se.refresh()
    Q = np.concatenate([new_rows[:4], X[rng.choice(1400, 4)]]) \
        + rng.normal(0, 0.003, (8, D))
    rs = _radii(X, Q)
    for (ids, ds), q, r in zip(se.range_query_batch(Q, rs), Q, rs):
        h_ids, h_ds, _ = ix.range_query(q, r)
        assert set(map(int, ids)) == set(map(int, h_ids))
        np.testing.assert_allclose(np.sort(ds), np.sort(h_ds), atol=0)
    ids, ds = se.knn_query_batch(Q, 5)
    for b, q in enumerate(Q):
        h_ids, h_ds, _ = ix.knn_query(q, 5)
        np.testing.assert_allclose(np.sort(ds[b]), np.sort(h_ds), atol=0)
    # a surviving buffered insert is findable; the tombstoned ones aren't
    hit_ids, _ = se.range_query(new_rows[1], 1e-9)
    assert gids[1] in set(map(int, hit_ids))
    dead_ids, _ = se.range_query(new_rows[0], 1e-9)
    assert gids[0] not in set(map(int, dead_ids))


def test_auto_refresh_after_threshold():
    X = gauss_mix(900, D, seed=9)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    se = ServingEngine(ix, refresh_every=6)
    rng = np.random.default_rng(1)
    rows = X[rng.choice(900, 6)] + rng.normal(0, 0.02, (6, D))
    for r in rows[:5]:
        se.insert(r)
    assert se.generation == 0 and se.pending_mutations == 5
    gid = se.insert(rows[5])                    # 6th mutation → refresh
    assert se.generation == 1 and se.pending_mutations == 0
    ids, _ = se.range_query(rows[5], 1e-9)      # visible without refresh()
    assert gid in set(map(int, ids))


def test_swap_is_atomic_for_inflight_batches():
    """A batch that grabbed the active executor keeps its snapshot across
    a refresh; new batches see the new generation."""
    X = gauss_mix(900, D, seed=3)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    se = ServingEngine(ix, refresh_every=0)
    old_exec = se.executor
    old_snap = se.snapshot
    gid = se.insert(X[11] + 0.5)
    se.refresh()
    assert se.executor is not old_exec          # swapped
    assert se._standby is old_exec              # double-buffered pair
    # the old executor still serves its (consistent, pre-insert) snapshot
    ids_old, _ = old_exec.range_query(X[11] + 0.5, 1e-9)
    assert gid not in set(map(int, ids_old))
    assert old_exec.snap is old_snap
    ids_new, _ = se.range_query(X[11] + 0.5, 1e-9)
    assert gid in set(map(int, ids_new))


def test_async_refresh_lands():
    X = gauss_mix(700, D, seed=13)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    se = ServingEngine(ix, refresh_every=3, async_refresh=True)
    rng = np.random.default_rng(2)
    rows = X[rng.choice(700, 3)] + rng.normal(0, 0.02, (3, D))
    gids = [se.insert(r) for r in rows]
    se.wait_refresh()
    assert se.generation >= 1
    ids, _ = se.range_query(rows[-1], 1e-9)
    assert gids[-1] in set(map(int, ids))


# ------------------------------------------------------- incremental deletes
def test_delete_keeps_live_mask_incremental():
    """The live mask must mirror tombstones∩store without isin rescans,
    and extents must shrink to the surviving rows."""
    X = gauss_mix(600, D, seed=21)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=3, m=3, n_rings=8)
    before = {ci.cid: ci.live_mask.sum() for ci in ix.clusters}
    victims = [4, 99, 250]
    for v in victims:
        assert ix.delete(X[v]) == 1
    after = {ci.cid: ci.live_mask.sum() for ci in ix.clusters}
    assert sum(before.values()) - sum(after.values()) == len(victims)
    for ci in ix.clusters:
        dead_here = [g for g in victims if g in set(ci.store_ids.tolist())]
        for g in dead_here:
            assert not ci.live_mask[np.where(ci.store_ids == g)[0][0]]
        if ci.live_mask.any():
            pd = ci.pivot_d_stored[ci.live_mask]
            np.testing.assert_allclose(ci.mapping.dist_min, pd.min(axis=0))
            np.testing.assert_allclose(ci.mapping.dist_max, pd.max(axis=0))
    # deleted rows are gone from both engines
    bx = BatchedLIMS(ix)
    for v in victims:
        ids, _, _ = ix.range_query(X[v], 1e-9)
        assert v not in set(map(int, ids))
        b_ids, _ = bx.range_query(X[v], 1e-9)
        assert v not in set(map(int, b_ids))
