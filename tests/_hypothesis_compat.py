"""Optional-hypothesis shim: property tests skip cleanly when absent.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt). On
machines without it the suite must still *collect* and run the
example-based tests, so this module exports either the real
``given/settings/strategies`` or inert stand-ins whose ``given`` marks
the test as skipped before any strategy object is ever used.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Placeholder: builds inert objects for strategy expressions."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -r "
                       "requirements-dev.txt)")(fn)
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
