"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


# Lanes: "on" = pallas interpret (the default CPU validation lane),
# "off" = the compiled lane (jitted-XLA on CPU, pallas_call on TPU/GPU).
# Running the oracle comparisons under both pins the compiled hot path
# against the references directly, not just against the interpret lane.
LANES = ["on", "off"]


@pytest.fixture(params=LANES)
def lane(request, monkeypatch):
    monkeypatch.setenv("REPRO_INTERPRET", request.param)
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    return request.param


# ---------------------------------------------------------------- pdist
@pytest.mark.parametrize("metric", ["sql2", "l1", "linf"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nq,npts,d", [(64, 128, 8), (137, 301, 33),
                                       (1, 257, 128), (128, 128, 4)])
def test_pdist_matches_ref(lane, metric, dtype, nq, npts, d):
    q = _rand((nq, d), dtype, 1)
    p = _rand((npts, d), dtype, 2)
    out = ops.pdist(q, p, metric)
    expect = ref.pdist_ref(q, p, metric)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol * d)


@settings(max_examples=25, deadline=None)
@given(nq=st.integers(1, 200), npts=st.integers(1, 300),
       d=st.integers(1, 64),
       metric=st.sampled_from(["sql2", "l1", "linf"]))
def test_pdist_property(nq, npts, d, metric):
    q = _rand((nq, d), jnp.float32, nq)
    p = _rand((npts, d), jnp.float32, npts + 1)
    out = np.asarray(ops.pdist(q, p, metric))
    expect = np.asarray(ref.pdist_ref(q, p, metric))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-3)
    assert (out >= -1e-6).all()            # non-negativity


# -------------------------------------------------------------- rankeval
@pytest.mark.parametrize("g,b,c", [(8, 128, 5), (13, 200, 9), (1, 1, 21),
                                   (32, 512, 2)])
def test_rankeval_matches_ref(lane, g, b, c):
    coef = _rand((g, c), jnp.float32, 3) * 10
    x = jax.random.uniform(KEY, (g, b), minval=0.0, maxval=2.0)
    lo = jnp.zeros(g)
    hi = jnp.full(g, 2.0)
    n = jnp.full(g, 500.0)
    rk, rid = ops.rankeval(x, coef, lo, hi, n, n_rings=20)
    rk2, rid2 = ref.rankeval_ref(x, coef, lo, hi, n, n_rings=20)
    # rint on fp32 can differ by 1 ulp at .5 boundaries
    assert int(jnp.abs(rk - rk2).max()) <= 1
    assert int(jnp.abs(rid - rid2).max()) <= 1


def test_rankeval_block_overrides_match_defaults():
    """Explicit bg/bb tile overrides (the autotune hook) change only the
    launch grid, never the values."""
    g, b, c = 13, 200, 9
    coef = _rand((g, c), jnp.float32, 3) * 10
    x = jax.random.uniform(KEY, (g, b), minval=0.0, maxval=2.0)
    lo = jnp.zeros(g)
    hi = jnp.full(g, 2.0)
    n = jnp.full(g, 500.0)
    rk, rid = ops.rankeval(x, coef, lo, hi, n, n_rings=20)
    rk2, rid2 = ops.rankeval(x, coef, lo, hi, n, n_rings=20, bg=8, bb=64)
    assert np.array_equal(np.asarray(rk), np.asarray(rk2))
    assert np.array_equal(np.asarray(rid), np.asarray(rid2))


def test_rankeval_matches_host_model():
    """Kernel model inference == the host PolyRankModel used by LIMS."""
    from repro.core.rankmodel import PolyRankModel
    rng = np.random.default_rng(0)
    col = np.sort(rng.gamma(2.0, 1.0, size=1000))
    model = PolyRankModel.fit(col, degree=8)
    xs = rng.uniform(col[0], col[-1], size=128)
    want = np.array([model.predict_scalar(float(v)) for v in xs])
    coef = np.zeros((1, len(model.coef)), np.float32)
    coef[0, :] = model.coef
    rk, _ = ops.rankeval(xs[None, :].astype(np.float32), coef,
                         np.array([model.lo], np.float32),
                         np.array([model.hi], np.float32),
                         np.array([model.n], np.float32))
    got = np.asarray(rk)[0]
    assert np.abs(got - want).max() <= 1   # fp32 vs fp64 rounding


# ----------------------------------------------------------- range_filter
@pytest.mark.parametrize("nq,npts,d", [(64, 256, 16), (137, 301, 33)])
def test_range_filter_matches_ref(lane, nq, npts, d):
    q = _rand((nq, d), jnp.float32, 5)
    p = _rand((npts, d), jnp.float32, 6)
    r = jax.random.uniform(KEY, (nq,), minval=1.0, maxval=8.0)
    mask, cnt = ops.range_filter(q, p, r)
    d2 = np.asarray(ref.pdist_ref(q, p, "sql2"))
    r2 = np.asarray(r) ** 2
    inner = d2 <= r2[:, None] - 1e-3
    outer = d2 <= r2[:, None] + 1e-3
    m = np.asarray(mask).astype(bool)
    assert (inner <= m).all() and (m <= outer).all()
    # counts consistent with the mask over full tiles
    assert int(np.asarray(cnt).sum()) == int(m.sum())


# -------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hk,sq,sk,d,causal", [
    (2, 8, 2, 256, 256, 64, True),
    (1, 4, 4, 100, 100, 32, True),
    (2, 8, 4, 128, 384, 64, False),
    (1, 2, 1, 64, 300, 16, False),
])
def test_flash_attention_matches_ref(dtype, b, hq, hk, sq, sk, d, causal):
    q = _rand((b, hq, sq, d), dtype, 7)
    k = _rand((b, hk, sk, d), dtype, 8)
    v = _rand((b, hk, sk, d), dtype, 9)
    out = ops.flash_attention(q, k, v, causal=causal)
    expect = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_rows_sum_preserved():
    """softmax rows sum to 1 ⇒ attention of constant V returns constant."""
    b, hq, hk, s, d = 1, 4, 2, 128, 32
    q = _rand((b, hq, s, d), jnp.float32, 1)
    k = _rand((b, hk, s, d), jnp.float32, 2)
    v = jnp.ones((b, hk, s, d), jnp.float32) * 3.5
    out = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 3.5, rtol=1e-5)
