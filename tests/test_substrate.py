"""Substrate: optimizers, train step, data pipeline, checkpointing,
fault-tolerant loop, elastic resharding."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig, ShapeCell
from repro.configs.registry import ARCHS
from repro.data.pipeline import DataShard, TokenPipeline, _batch_for_step
from repro.models import zoo
from repro.models.params import init_params
from repro.train import optim
from repro.train.step import build_train_step, init_state

CFG = ARCHS["llama3-8b"].reduced()
CELL = ShapeCell("t", 64, 4, "train")


def _state_and_batch(run: RunConfig):
    params = init_params(zoo.model_specs(CFG), jax.random.PRNGKey(0),
                         CFG.dtype)
    state = init_state(CFG, run, params)
    batch = zoo.make_batch(CFG, CELL, 0)
    return state, batch


# ------------------------------------------------------------- optimizers
@pytest.mark.parametrize("name", ["adamw", "adafactor", "adamw8bit"])
def test_optimizer_reduces_loss(name):
    run = RunConfig(optimizer=name, learning_rate=5e-3)
    state, batch = _state_and_batch(run)
    step = jax.jit(build_train_step(CFG, run, total_steps=100))
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_microbatched_grads_match_full():
    """grad accumulation over microbatches == single-batch gradient."""
    import dataclasses
    cfg = dataclasses.replace(CFG, dtype="float32")
    params = init_params(zoo.model_specs(cfg), jax.random.PRNGKey(0),
                         "float32")
    batch = zoo.make_batch(cfg, CELL, 0)
    loss_fn = zoo.loss_fn(cfg)
    g_full = jax.grad(lambda p: loss_fn(p, batch)[0])(params)
    # gradient-only closure mirroring step.grads_of's accumulation
    mb = 4
    def split(x):
        return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
    mbs = jax.tree.map(split, batch)
    def body(acc, micro):
        g = jax.grad(lambda p: loss_fn(p, micro)[0])(params)
        return jax.tree.map(lambda a, b: a + b, acc, g), None
    g0 = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    g_acc, _ = jax.lax.scan(body, g0, mbs)
    g_acc = jax.tree.map(lambda x: x / mb, g_acc)
    for a, b in zip(jax.tree.leaves(g_acc), jax.tree.leaves(g_full)):
        a, b = np.asarray(a), np.asarray(b)
        scale = np.abs(b).max() + 1e-9
        assert np.abs(a - b).max() <= 5e-3 * scale   # f32 assoc. noise


def test_quantize_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 3,
                    jnp.float32)
    q, s = optim.quantize_blockwise(x)
    y = optim.dequantize_blockwise(q, s, x.shape)
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_int8_grad_compression_unbiased():
    from repro.train.compress import int8_compress_decompress
    g = {"w": jnp.full((512,), 0.3711, jnp.float32)}
    outs = []
    for i in range(64):
        outs.append(int8_compress_decompress(g, jax.random.PRNGKey(i))["w"])
    mean = jnp.mean(jnp.stack(outs))
    assert abs(float(mean) - 0.3711) < 2e-3   # stochastic rounding unbiased


# ---------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_sharded():
    b1 = _batch_for_step(7, DataShard(0, 1), 512, 8, 16)
    b2 = _batch_for_step(7, DataShard(0, 1), 512, 8, 16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shard-local generation: different shards differ, same shard stable
    s0 = _batch_for_step(7, DataShard(0, 2), 512, 8, 16)
    s1 = _batch_for_step(7, DataShard(1, 2), 512, 8, 16)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_pipeline_iterator_and_skip():
    pipe = TokenPipeline(CFG, ShapeCell("t", 16, 4, "train"),
                         start_step=3)
    b = next(pipe)
    expect = _batch_for_step(3, DataShard(0, 1), CFG.vocab, 4, 16)
    np.testing.assert_array_equal(b["tokens"], expect["tokens"])
    pipe.skip_to(10)
    b = next(pipe)
    expect = _batch_for_step(10, DataShard(0, 1), CFG.vocab, 4, 16)
    np.testing.assert_array_equal(b["tokens"], expect["tokens"])
    pipe.close()


# ------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip_and_gc():
    from repro.ckpt import checkpoint as ckpt
    run = RunConfig()
    state, _ = _state_and_batch(run)
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(state, d, s, keep=2)
        assert ckpt.latest_step(d) == 5
        kept = sorted(os.listdir(d))
        assert len([k for k in kept if k.startswith("step_")]) == 2
        restored, step = ckpt.restore(state, d)
        assert step == 5
        for a, b in zip(jax.tree.leaves(restored),
                        jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_mesh_elastic_restore():
    """Save on one mesh layout, restore onto a different one."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")
    from repro.ckpt import checkpoint as ckpt


def test_fault_tolerant_loop_restarts():
    from repro.runtime.fault import FaultConfig, run_training
    run = RunConfig(learning_rate=1e-3)
    state, batch = _state_and_batch(run)
    step = jax.jit(build_train_step(CFG, run, total_steps=100))
    with tempfile.TemporaryDirectory() as d:
        fc = FaultConfig(ckpt_dir=d, ckpt_every=4, max_restarts=3,
                         inject_failures_at=(6, 11))
        state2, stats = run_training(step, state, lambda s: batch, 16, fc)
        assert stats.restarts == 2
        assert int(jax.device_get(state2["step"])) == 16
        # loop survived and kept training through both failures
        assert stats.steps_run >= 16
