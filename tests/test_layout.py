"""Memory-layout acceptance (DESIGN.md §13): the PR-10 roofline push.

Three independent byte-movers changed and every one must be invisible
in results:

  * **query-blocked kernels** — the xla lane's ``qb`` sub-blocking and
    the pallas point-major grid reorder move *bytes*, never math: every
    (bq, bp, qb) tiling of ``pdist`` / ``range_filter`` /
    ``pdist_rankeval`` is bit-identical;
  * **compacted candidate gather** — the resident range path's dense
    union-gather (``REPRO_COMPACT``) returns exactly the padded-slot
    path's hits, and executor results match bit-for-bit both ways;
  * **certified reduced-precision filter plane** — with
    ``REPRO_ROWS_DTYPE=bf16|f16`` the ε-widened filters keep every true
    result (property-tested) and final query results stay bitwise
    identical to the f32 baseline across both kNN drivers and the
    sharded executor (the 4-fake-device CI leg runs the real
    ``shard_map`` path through this file).
"""
import functools

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import LIMSIndex, MetricSpace
from repro.core.executor import QueryExecutor, ShardedExecutor, _bucket_size
from repro.core.metrics import dist_one_to_many
from repro.core.planner import _BALL_ABS, _R_REL
from repro.core import planner as planner_mod
from repro.core.snapshot import LIMSSnapshot, lp_quant_eps
from repro.kernels import ops

N, D = 1200, 6


@functools.lru_cache(maxsize=1)
def _env():
    # a single Gaussian blob k-center-clusters unevenly, so the padded
    # slot array carries real slack over the live rows — the layout the
    # compacted gather exists for (the union candidate set sits well
    # under the n_max-padded slot count)
    rng = np.random.default_rng(23)
    X = rng.normal(size=(N, D))
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=8, m=3, n_rings=10)
    return X, ix


def _queries(X, n_q, seed=2, scale=0.004):
    rng = np.random.default_rng(seed)
    return X[rng.choice(len(X), n_q)] + rng.normal(0, scale, (n_q, D))


def _radii(X, Q, sel=0.02):
    return np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), sel))
                     for q in Q])


def _run_queries(ex, X):
    """One range + one kNN batch; returns comparable result tuples."""
    Q = _queries(X, 5, seed=7)
    rr = ex.range_query_batch(Q, _radii(X, Q))
    kk = ex.knn_query_batch(Q, 9)
    return rr, kk


def _assert_same(a, b):
    for (ai, ad), (bi, bd) in zip(a[0], b[0]):
        assert np.array_equal(ai, bi)
        assert np.array_equal(ad, bd)
    assert np.array_equal(a[1][0], b[1][0])
    assert np.array_equal(a[1][1], b[1][1])


# ------------------------------------------------- compacted gather
def test_compact_range_bitwise_identical(monkeypatch):
    """REPRO_COMPACT=on (the default) gathers the union candidate rows
    into a pow2 bucket and must return exactly the padded-slot path's
    results; ``last_compact`` records the gather it ran."""
    X, ix = _env()
    snap = LIMSSnapshot.build(ix)
    monkeypatch.setenv("REPRO_COMPACT", "off")
    ex_full = QueryExecutor(snap)
    base = _run_queries(ex_full, X)
    assert ex_full.last_compact is None
    monkeypatch.setenv("REPRO_COMPACT", "on")
    ex_c = QueryExecutor(snap)
    got = _run_queries(ex_c, X)
    _assert_same(got, base)
    lc = ex_c.last_compact
    assert lc is not None
    assert 0 < lc["slots"] <= lc["bucket"] <= lc["n_slots"]
    assert lc["bucket"] == _bucket_size(lc["slots"])
    assert lc["bucket"] & (lc["bucket"] - 1) == 0        # power of two


def test_compact_falls_back_when_union_large(monkeypatch):
    """A union past the payoff bound streams the full padded array —
    same results, ``last_compact`` None, plan reports no gather."""
    X, ix = _env()
    snap = LIMSSnapshot.build(ix)
    monkeypatch.setenv("REPRO_COMPACT", "on")
    ex = QueryExecutor(snap)
    base = _run_queries(ex, X)
    monkeypatch.setattr(planner_mod, "_COMPACT_MAX_FRAC", 0.0)
    got = _run_queries(ex, X)
    _assert_same(got, base)
    assert ex.last_compact is None
    Q = _queries(X, 3, seed=5)
    plan = ex.planner.plan_range(Q, _radii(X, Q))
    assert plan.compact_slots() is None
    assert plan.compact_slots() is None                  # cached decision


def test_compact_slots_plan_contract():
    """The plan's gather is the sorted union of its certified mask and
    is cached with the mask it derives from."""
    X, ix = _env()
    ex = QueryExecutor(LIMSSnapshot.build(ix))
    Q = _queries(X, 4, seed=9)
    plan = ex.planner.plan_range(Q, _radii(X, Q))
    slots = plan.compact_slots()
    assert slots is not None and slots.size
    assert np.array_equal(slots, np.nonzero(plan.mask.any(axis=0))[0])
    assert plan.compact_slots() is slots                 # cached


# ------------------------------------------- query-blocked tilings
@pytest.mark.parametrize("metric", ["sql2", "l1", "linf"])
def test_xla_query_blocked_pdist_tilings_bit_identical(metric):
    """Every (bq, bp, qb) tiling of the xla-lane kernels reorders byte
    movement only — outputs are bit-identical (tiles never change
    per-pair math)."""
    if jax.default_backend() != "cpu":
        pytest.skip("xla lane is the CPU compiled path")
    from repro.kernels.xla import pdist_xla, range_filter_xla
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    p = jnp.asarray(rng.normal(size=(384, 8)), jnp.float32)
    r = jnp.asarray(rng.uniform(1.0, 3.0, 32), jnp.float32)
    base = pdist_xla(q, p, metric, bq=32, bp=384, qb=0)
    mbase, cbase = range_filter_xla(q, p, r, bq=32, bp=384, qb=0)
    for bq in (16, 32):
        for bp in (128, 384):
            for qb in (0, 8, 16):
                d = pdist_xla(q, p, metric, bq=bq, bp=bp, qb=qb)
                assert np.array_equal(np.asarray(d), np.asarray(base)), \
                    (metric, bq, bp, qb)
                m, c = range_filter_xla(q, p, r, bq=bq, bp=bp, qb=qb)
                assert np.array_equal(np.asarray(m), np.asarray(mbase))
                # cnt is per-p-block by contract — totals must agree
                assert np.array_equal(np.asarray(c).sum(axis=1),
                                      np.asarray(cbase).sum(axis=1))


def test_xla_fused_bb_blocking_bit_identical():
    if jax.default_backend() != "cpu":
        pytest.skip("xla lane is the CPU compiled path")
    from repro.kernels.xla import pdist_rankeval_xla
    rng = np.random.default_rng(4)
    G, B, d, C = 16, 32, 8, 9
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    piv = jnp.asarray(rng.normal(size=(G, d)), jnp.float32)
    coef = jnp.asarray(rng.normal(size=(G, C)), jnp.float32)
    lo = jnp.zeros(G, jnp.float32)
    hi = jnp.full(G, 4.0, jnp.float32)
    n = jnp.full(G, 64.0, jnp.float32)
    rg = jnp.asarray(rng.uniform(0.5, 1.5, B), jnp.float32)
    base = pdist_rankeval_xla(q, piv, coef, lo, hi, n, rg,
                              n_rings=10, bg=G, bb=B)
    for bg in (8, 16):
        for bb in (8, 16, 32):
            out = pdist_rankeval_xla(q, piv, coef, lo, hi, n, rg,
                                     n_rings=10, bg=bg, bb=bb)
            for a, b in zip(out, base):
                assert np.array_equal(np.asarray(a), np.asarray(b)), (bg, bb)


def test_pallas_point_major_grid_matches_reference():
    """The point-major grid reorder in the pallas kernels (point tile
    resident across query tiles) leaves per-cell outputs untouched."""
    from repro.kernels.pdist import pdist_pallas
    from repro.kernels.range_filter import range_filter_pallas
    from repro.kernels.ref import pdist_ref
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    p = jnp.asarray(rng.normal(size=(256, 8)), jnp.float32)
    r = jnp.asarray(rng.uniform(1.0, 3.0, 16), jnp.float32)
    ref = np.asarray(pdist_ref(q, p))
    base = np.asarray(pdist_pallas(q, p, bq=16, bp=256, interpret=True))
    mbase, _ = range_filter_pallas(q, p, r, bq=16, bp=256, interpret=True)
    mbase = np.asarray(mbase, bool)
    np.testing.assert_allclose(base, ref, rtol=1e-5, atol=1e-5)
    for bq, bp in ((8, 128), (16, 128), (8, 64), (8, 256)):
        d = pdist_pallas(q, p, bq=bq, bp=bp, interpret=True)
        np.testing.assert_array_equal(np.asarray(d), base)
        m, _ = range_filter_pallas(q, p, r, bq=bq, bp=bp, interpret=True)
        assert np.array_equal(np.asarray(m, bool), mbase)


# -------------------------------------- reduced-precision filter plane
@pytest.mark.parametrize("dtype", ["bf16", "f16"])
@pytest.mark.parametrize("driver", ["rounds", "loop"])
def test_lp_plane_results_bitwise_identical(monkeypatch, dtype, driver):
    """The ε-certified lp filter plane changes first-pass byte traffic
    only: range and kNN results are bitwise identical to the f32
    baseline under both kNN drivers."""
    X, ix = _env()
    monkeypatch.delenv("REPRO_ROWS_DTYPE", raising=False)
    base_snap = LIMSSnapshot.build(ix)
    base = _run_queries(QueryExecutor(base_snap), X)
    monkeypatch.setenv("REPRO_ROWS_DTYPE", dtype)
    monkeypatch.setenv("REPRO_KNN_DRIVER", driver)
    snap = LIMSSnapshot.build(ix)
    assert snap.rows_lp is not None and snap.lp_eps > 0.0
    ex = QueryExecutor(snap)
    got = _run_queries(ex, X)
    _assert_same(got, base)
    assert ex.last_knn["driver"] == driver


def test_lp_plane_sharded_and_compact_identical(monkeypatch):
    """bf16 plane + compaction on the sharded executor (real shard_map
    on the 4-fake-device CI leg; single-device degradation otherwise)
    still returns the f32 baseline bit-for-bit — the sharded filter
    keeps the exact f32 plane, resident compaction composes with the
    lp plane."""
    X, ix = _env()
    monkeypatch.delenv("REPRO_ROWS_DTYPE", raising=False)
    base = _run_queries(QueryExecutor(LIMSSnapshot.build(ix)), X)
    monkeypatch.setenv("REPRO_ROWS_DTYPE", "bf16")
    monkeypatch.setenv("REPRO_COMPACT", "on")
    snap = LIMSSnapshot.build(ix)
    _assert_same(_run_queries(ShardedExecutor(snap), X), base)


def test_lp_plane_off_is_default_and_plane_absent(monkeypatch):
    monkeypatch.delenv("REPRO_ROWS_DTYPE", raising=False)
    X, ix = _env()
    snap = LIMSSnapshot.build(ix)
    assert snap.rows_lp is None and snap.lp_eps == 0.0
    rows, eps = snap.filter_rows()
    assert rows is snap.rows and eps == 0.0


def _lp_never_drops(seed: int) -> None:
    """Core ε-certification property: for rows quantized to bf16,
    d(q, x_lp) ≤ d(q, x) + eps, so the ε-widened ball keeps every true
    result of the exact ball (the device filter additionally carries
    the f32 guard bands on top of eps)."""
    rng = np.random.default_rng(seed)
    n, d = int(rng.integers(4, 200)), int(rng.integers(1, 12))
    scale = 10.0 ** rng.integers(-3, 4)
    rows = rng.normal(scale=scale, size=(n, d))
    rows32 = jnp.asarray(rows, jnp.float32)
    lp = rows32.astype(jnp.bfloat16)
    eps = lp_quant_eps(rows32, lp, "l2")
    q = rng.normal(scale=scale, size=d)
    d_true = dist_one_to_many(q, rows, "l2")
    d_lp = np.sqrt(((q - np.asarray(lp, np.float64)) ** 2).sum(axis=1))
    r = float(np.quantile(d_true, rng.uniform(0.05, 0.95)))
    true_ball = d_true <= r
    widened = d_lp <= r + eps
    assert not (true_ball & ~widened).any(), seed


def test_lp_eps_widened_filter_never_drops_sweep():
    for seed in range(200):
        _lp_never_drops(seed)


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=0, max_value=10_000_000))
def test_lp_eps_widened_filter_never_drops_property(seed):
    _lp_never_drops(seed)


def test_lp_eps_guard_band_end_to_end(monkeypatch):
    """Device-path version of the property: the executor's ε-widened
    ball filter mask is a superset of the exact in-ball set for every
    query in a batch."""
    X, ix = _env()
    monkeypatch.setenv("REPRO_ROWS_DTYPE", "bf16")
    snap = LIMSSnapshot.build(ix)
    ex = QueryExecutor(snap)
    Q = _queries(X, 6, seed=13)
    rs = _radii(X, Q, sel=0.05)
    ball = np.asarray(ex._ball_filter(
        jnp.asarray(Q, jnp.float32), jnp.asarray(rs, jnp.float32)))
    rows = snap.rows_np.reshape(-1, D)
    valid = snap.valid_np
    for b, q in enumerate(Q):
        d_true = np.sqrt(((q - rows) ** 2).sum(axis=1))
        inside = (d_true <= rs[b]) & valid
        assert not (inside & ~ball[b]).any()
