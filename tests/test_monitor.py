"""Continuous index-health monitoring (DESIGN.md §12): time series,
detectors, the sampler lifecycle, and the closed placement/retrain loop.

Covers the PR's acceptance properties: detectors are deterministic
hysteresis machines over hand-built series (drift present / absent /
flapping); the sampler thread starts/stops idempotently, joins within
the shutdown timeout, and never leaks across repeated rebuilds (the
prefetch-daemon contract); ``REPRO_MONITOR=off`` is a zero-thread,
zero-allocation path (tracemalloc-pinned like ``REPRO_OBS=off``); the
Prometheus exporter's ``_bucket`` family is format-pinned with monotone
cumulative counts; and the end-to-end closed loop — a paged serving run
with skewed query heat fires a heat-drift finding, the daemon
rebalances within its cooldown, replica load spread measurably
tightens, and query results stay bit-identical throughout.
"""
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import LIMSIndex, MetricSpace, ServingEngine
from repro.core.snapshot import LIMSSnapshot
from repro.obs import registry as _reg
from repro.obs import monitor as monmod
from repro.obs.health import (HealthFinding, HeatSkewDetector,
                              PruningRegressionDetector, RankDriftDetector,
                              SloBurnDetector, default_detectors)
from repro.obs.monitor import (Monitor, active_monitors, configure_monitor,
                               maybe_monitor, shutdown_monitors)
from repro.obs.registry import DEFAULT_BUCKET_BOUNDS, MetricsRegistry
from repro.obs.timeseries import Series, SeriesStore, sparkline
from repro.serving import MonitorDaemon, PlanRouter, ReplicaSet

N, D = 700, 6


@pytest.fixture(autouse=True)
def _restore_modes():
    """Tests flip the cached obs/monitor modes and may start sampler
    threads; restore both and join stray threads for the suite."""
    obs_before = obs.obs_mode()
    mon_before = monmod.monitor_mode()
    yield
    shutdown_monitors()
    obs.configure(obs_before)
    configure_monitor(mon_before)


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    from repro.data.datasets import gauss_mix
    X = gauss_mix(N, D, seed=11)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=6, m=2, n_rings=6)
    snap = LIMSSnapshot.build(ix)
    path = str(tmp_path_factory.mktemp("mon-store"))
    snap.spill(path)
    rng = np.random.default_rng(5)
    Q = X[rng.choice(N, 12, replace=False)] + 0.005
    return X, ix, snap, path, Q


def _monitor_threads() -> list:
    return [t for t in threading.enumerate() if t.name == "lims-monitor"]


# ------------------------------------------------------------- time series
def test_series_kinds_window_and_cap():
    s = Series("x", "level", cap=4)
    s.extend([1, 2, 3, 4, 5])
    assert s.values() == [2.0, 3.0, 4.0, 5.0]       # ring bounded at 4
    assert s.last() == 5.0 and len(s) == 4
    assert s.window(2) == [4.0, 5.0]
    assert s.window_mean(2) == 4.5 and s.window_sum(10) == 14.0
    assert s.stats()["max"] == 5.0
    with pytest.raises(ValueError):
        Series("y", "cumulative")
    assert sparkline([]) == ""
    assert sparkline([2.0, 2.0]) == "▁▁"            # flat line, min block
    spark = sparkline([0, 1, 2, 3], width=4)
    assert len(spark) == 4 and spark[0] == "▁" and spark[-1] == "█"


def test_seriesstore_sampling_semantics():
    """Counters -> per-tick deltas (reset self-heals), gauges -> levels,
    histograms -> p50/p99 levels plus a count-delta rate series."""
    reg = MetricsRegistry()
    store = SeriesStore(cap=16)
    c, g, h = reg.counter("t.c"), reg.gauge("t.g"), reg.histogram("t.h")
    c.inc(3); g.set(1.5); h.observe(2.0); h.observe(4.0)
    store.sample(reg)
    c.inc(2); g.set(2.5); h.observe(6.0)
    store.sample(reg)
    assert store.get("t.c").values() == [3.0, 2.0]  # deltas, not levels
    assert store.get("t.c").kind == "delta"
    assert store.get("t.g").values() == [1.5, 2.5]
    assert store.get("t.g").kind == "level"
    assert store.get("t.h.rate").values() == [2.0, 1.0]
    assert store.get("t.h.p50").kind == "level"
    assert store.get("t.h.p50").last() == h.snapshot()["p50"]
    assert store.ticks == 2
    # counter reset (fresh process / registry.reset): baseline restarts,
    # the delta never goes negative
    reg.reset()
    c.inc(4)
    store.sample(reg)
    assert store.get("t.c").last() == 4.0
    assert store.match("t.") and store.names() == sorted(store.names())


# --------------------------------------------------------------- detectors
def _feed(det, store, series_name, values, kind="level"):
    """Drive one detector over a hand-built series, one evaluate per
    point; returns the findings in order."""
    s = store.series(series_name, kind)
    out = []
    for i, v in enumerate(values, 1):
        s.append(v)
        out.extend(det.evaluate(store, i))
    return out


def test_detector_hysteresis_drift_present_absent_flapping():
    store = SeriesStore(cap=64)
    # absent: forever under trigger -> silence
    det = HeatSkewDetector(trigger=1.5, clear=1.15, persistence=2)
    assert _feed(det, store, "router.heat_skew", [1.0, 1.2, 1.4, 1.1]) == []
    assert not det.active

    # present: needs `persistence` consecutive over-trigger ticks, fires
    # once, then clears with an informational cleared-finding
    store2 = SeriesStore(cap=64)
    det2 = HeatSkewDetector(trigger=1.5, clear=1.15, persistence=2)
    fs = _feed(det2, store2, "router.heat_skew",
               [2.0, 2.0, 2.0, 2.0, 1.0])
    assert [f.cleared for f in fs] == [False, True]
    fired, cleared = fs
    assert fired.detector == "heat_skew" and fired.severity == "warn"
    assert fired.tick == 2 and fired.value == 2.0       # not tick 1
    assert cleared.severity == "info" and cleared.tick == 5
    assert not det2.active

    # flapping around the trigger never reaches `persistence`
    store3 = SeriesStore(cap=64)
    det3 = HeatSkewDetector(trigger=1.5, clear=1.15, persistence=2)
    assert _feed(det3, store3, "router.heat_skew",
                 [2.0, 1.0, 2.0, 1.0, 2.0, 1.0]) == []

    # inside the hysteresis band (clear, trigger) an active detector
    # neither clears nor re-fires — the flap-suppression contract
    store4 = SeriesStore(cap=64)
    det4 = HeatSkewDetector(trigger=1.5, clear=1.15, persistence=1,
                            refire=2)
    fs4 = _feed(det4, store4, "router.heat_skew",
                [2.0, 1.3, 1.3, 1.3, 1.3, 1.3])
    assert len(fs4) == 1 and det4.active

    # refire: a persisting over-trigger signal re-emits every `refire`
    # ticks, keeping long-lived conditions visible without flooding
    store5 = SeriesStore(cap=64)
    det5 = HeatSkewDetector(trigger=1.5, clear=1.15, persistence=1,
                            refire=3)
    fs5 = _feed(det5, store5, "router.heat_skew", [2.0] * 7)
    assert [f.tick for f in fs5] == [1, 4, 7]

    with pytest.raises(ValueError):                 # clear must be < trigger
        HeatSkewDetector(trigger=1.0, clear=1.0)


def test_rank_drift_detector_per_cluster_and_severity():
    store = SeriesStore(cap=16)
    det = RankDriftDetector(trigger=0.75, clear=0.5, persistence=2)
    store.series("executor.rank_err_ratio.c0").append(0.2)
    store.series("executor.rank_err_ratio.c3").append(0.9)
    assert det.evaluate(store, 1) == []             # arming (persistence 2)
    store.series("executor.rank_err_ratio.c3").append(1.2)
    (f,) = det.evaluate(store, 2)
    assert f.context["cluster"] == 3                # worst cluster named
    assert f.severity == "critical"                 # >= critical_at=1.0
    assert "1.20x the certified bound" in f.summary
    assert det.state()["active"]


def test_pruning_regression_detector_baseline_ratio():
    store = SeriesStore(cap=64)
    det = PruningRegressionDetector(trigger=2.0, clear=1.5, persistence=1,
                                    baseline_n=3, window=2)
    name = "profile.candidates_per_query.p50"
    vals = [100, 100, 100,          # baseline mean = 100
            120, 300, 300]          # window [120,300] mean 210 -> 2.1x
    fs = _feed(det, store, name, vals)
    assert len(fs) == 1 and fs[0].value == pytest.approx(2.1)
    assert fs[0].tick == 5          # first tick the window mean crosses
    assert fs[0].context["baseline"] == pytest.approx(100.0)


def test_slo_burn_detector_window_math():
    store = SeriesStore(cap=64)
    det = SloBurnDetector(trigger=2.0, clear=1.0, persistence=1, window=10,
                          objective=0.99)
    ok = store.series("frontend.slo_ok", "delta")
    miss = store.series("frontend.slo_miss", "delta")
    assert det.evaluate(store, 1) == []             # no traffic -> no signal
    ok.append(97.0); miss.append(3.0)               # 3% miss = 3x budget
    (f,) = det.evaluate(store, 2)
    assert f.value == pytest.approx(3.0) and f.severity == "warn"
    assert int(f.context["miss"]) == 3
    ok.append(0.0); miss.append(50.0)               # burn worsens, but the
    assert det.evaluate(store, 3) == []             # refire isn't due yet
    assert det.active


def test_slo_burn_critical_severity():
    store = SeriesStore(cap=64)
    det = SloBurnDetector(trigger=2.0, clear=1.0, persistence=1, window=10)
    store.series("frontend.slo_ok", "delta").append(50.0)
    store.series("frontend.slo_miss", "delta").append(50.0)
    (f,) = det.evaluate(store, 1)                   # 50% miss = 50x budget
    assert f.severity == "critical" and f.value == pytest.approx(50.0)
    with pytest.raises(ValueError):
        SloBurnDetector(objective=1.5)


# ----------------------------------------------------- monitor + lifecycle
def test_monitor_manual_tick_probes_findings_subscribers():
    reg = MetricsRegistry()
    det = HeatSkewDetector(trigger=1.5, clear=1.15, persistence=1)
    mon = Monitor(registry=reg, interval=3600.0, detectors=[det],
                  findings=4)
    seen = []
    mon.subscribe(seen.append)
    mon.add_probe(lambda: reg.gauge("router.heat_skew").set(4.0))
    mon.add_probe(lambda: 1 / 0)                    # must not kill the tick
    fired = mon.tick()
    assert len(fired) == 1 and seen == fired
    assert isinstance(fired[0], HealthFinding)
    assert mon.store.ticks == 1 and not mon.running
    snap = mon.snapshot()
    assert snap["ticks"] == 1 and len(snap["findings"]) == 1
    assert snap["detectors"][0]["name"] == "heat_skew"
    # findings ring is bounded at the requested cap even under refires
    for _ in range(40):
        mon.tick()
    assert len(mon.findings()) <= 4
    assert reg.get("monitor.probe_errors") is None  # fresh registry; the
    # failing probe is counted on the *global* registry, never raised


def test_monitor_start_stop_idempotent_and_atexit_join(setup):
    mon = Monitor(interval=0.01)
    assert not _monitor_threads()
    mon.start()
    mon.start()                                     # idempotent
    assert len(_monitor_threads()) == 1 and mon.running
    assert mon in active_monitors()
    assert mon.stop(timeout=5.0)                    # joined within timeout
    assert mon.stop()                               # idempotent
    assert not mon.running and mon not in active_monitors()
    assert not _monitor_threads()
    # shutdown_monitors (the atexit hook) joins whatever is left
    m2 = Monitor(interval=0.01).start()
    assert m2.running
    assert shutdown_monitors(timeout=5.0)
    assert not m2.running and not _monitor_threads()


def test_no_thread_leak_across_repeated_engine_rebuilds(setup):
    """Rebuilding the frontend (monitor=True) N times leaves exactly
    zero lims-monitor threads — the prefetch-daemon shutdown contract
    applied to the sampler."""
    X, ix, snap, path, Q = setup
    se = ServingEngine(ix, refresh_every=0)
    base = len(_monitor_threads())
    for _ in range(3):
        with se.frontend(max_batch=4, slo_ms=50.0, monitor=True) as fe:
            assert fe.monitor is not None and fe.monitor.running
            assert fe.daemon is not None
            fe.knn_query(Q[0], 3)
        assert fe.monitor is not None and not fe.monitor.running
    assert len(_monitor_threads()) == base == 0


def test_monitor_off_is_zero_thread_zero_alloc():
    """With REPRO_MONITOR=off the gate helpers return without starting a
    thread and without allocating (tracemalloc pinned to the monitor
    module) — default-on construction of serving stacks stays free."""
    import tracemalloc

    configure_monitor("off")
    assert monmod.monitor_enabled() is False
    for _ in range(50):                             # settle freelists
        maybe_monitor()
        monmod.monitor_enabled()
    tracemalloc.start()
    try:
        for _ in range(200):
            assert maybe_monitor() is None
            monmod.monitor_enabled()
        snap = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    mon_alloc = sum(st.size for st in snap.statistics("filename")
                    if st.traceback[0].filename == monmod.__file__)
    assert mon_alloc == 0
    assert not _monitor_threads()
    # and flipping it on makes maybe_monitor return a started sampler
    configure_monitor("on")
    m = maybe_monitor(interval=0.01)
    assert m is not None and m.running
    assert m.stop(5.0) and not _monitor_threads()
    with pytest.raises(ValueError):
        configure_monitor("sometimes")


# ---------------------------------------------------- prometheus histogram
def test_prometheus_bucket_lines_format_pinned():
    """Satellite: real `_bucket`/`le` lines with fixed log-spaced bounds.
    Observing 0..9 pins the exact cumulative counts; the family must be
    monotone and internally consistent (+Inf == _count)."""
    obs.configure("on")
    reg = obs.REGISTRY
    h = reg.histogram("monbkt.h")
    for v in range(10):
        h.observe(float(v))
    text = obs.prometheus_text()
    assert "# TYPE lims_monbkt_h_hist histogram" in text
    assert 'lims_monbkt_h_hist_bucket{le="1"} 2' in text       # 0.0, 1.0
    assert 'lims_monbkt_h_hist_bucket{le="10"} 10' in text
    assert 'lims_monbkt_h_hist_bucket{le="+Inf"} 10' in text
    assert "lims_monbkt_h_hist_count 10" in text
    assert "lims_monbkt_h_hist_sum 45" in text
    # cumulative monotonicity across the whole family
    counts = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
              if line.startswith("lims_monbkt_h_hist_bucket")]
    assert counts == sorted(counts) and counts[-1] == 10
    assert len(counts) == len(DEFAULT_BUCKET_BOUNDS) + 1        # + +Inf
    bounds, cum = h.buckets()
    assert list(bounds) == sorted(bounds) and cum[-1] == 10
    h.reset()
    assert h.buckets()[1][-1] == 0


def test_prometheus_monitor_series_lines():
    obs.configure("on")
    reg = MetricsRegistry()
    mon = Monitor(registry=reg, interval=3600.0, detectors=[])
    reg.gauge("router.heat_skew").set(2.5)
    mon.tick()
    text = obs.prometheus_text(monitor=mon)
    assert ('lims_monitor_series{series="router.heat_skew",stat="last"} 2.5'
            in text)
    assert "lims_monitor_ticks 1" in text


# -------------------------------------------------------------- the daemon
def _drift_stack(snap, n_replicas=4, cooldown=2, **daemon_kw):
    """Replica set with ownership pinned to replica 0 (the injected
    drift), a router, and a manually-ticked monitor + daemon.  Uses the
    process registry (obs must be "on") because the router publishes
    its heat-skew gauge there — exactly the production wiring."""
    replicas = ReplicaSet(snap, n_replicas=n_replicas)
    router = PlanRouter(replicas)
    mon = Monitor(interval=3600.0,
                  detectors=[HeatSkewDetector(trigger=1.5, clear=1.15,
                                              persistence=2),
                             RankDriftDetector(persistence=1)])
    daemon = MonitorDaemon(mon, lambda: router,
                           cooldown_ticks=cooldown, **daemon_kw)
    replicas.set_ownership(np.zeros(snap.K, np.int64))
    return replicas, router, mon, daemon


def test_daemon_rebalance_cooldown_and_audit(setup):
    X, ix, snap, path, Q = setup
    obs.configure("on")
    replicas, router, mon, daemon = _drift_stack(snap, cooldown=10)
    router.knn_query_batch(Q, 4)
    for _ in range(6):
        mon.tick()
    evs = daemon.events()
    rebal = [e for e in evs if e["action"] == "rebalance"]
    skips = [e for e in evs if e["action"] == "cooldown_skip"]
    assert len(rebal) == 1                          # cooldown holds
    assert rebal[0]["skew"] == pytest.approx(4.0)   # all heat on replica 0
    assert rebal[0]["detector"] == "heat_skew"
    assert sorted(set(rebal[0]["owner"])) == list(range(4))
    assert all(s["last_action_tick"] == rebal[0]["tick"] for s in skips)
    snap_d = daemon.snapshot()
    assert snap_d["cooldown_ticks"] == 10
    assert snap_d["last_action"]["heat_skew"] == rebal[0]["tick"]


def test_daemon_retrain_modes(setup):
    """rank_drift findings route through REPRO_MONITOR_RETRAIN: off
    ignores, recommend records on the engine, auto also retrains."""
    X, ix, snap, path, Q = setup
    obs.configure("on")
    # refresh_every=1 so an auto retrain publishes a fresh generation
    se = ServingEngine(ix, refresh_every=1)

    def drive(mode):
        replicas, router, mon, daemon = _drift_stack(
            snap, cooldown=1, engine=se, retrain=mode)
        # hand-inject a drifting cluster signal (worst cluster = 2)
        mon.registry.gauge("executor.rank_err_ratio.c2").set(0.9)
        mon.tick()
        return daemon.events()

    with pytest.raises(ValueError):
        _drift_stack(snap, engine=se, retrain="always")

    se.clear_retrain_recommendations()
    evs = drive("off")
    assert not [e for e in evs if e["action"].startswith("retrain")]
    assert se.retrain_recommendations() == []

    evs = drive("recommend")
    (ev,) = [e for e in evs if e["action"] == "retrain_recommend"]
    assert ev["cluster"] == 2
    (rec,) = se.retrain_recommendations()
    assert rec["cluster"] == 2 and "rank error" in rec["reason"]

    se.clear_retrain_recommendations()
    before = se.generation
    evs = drive("auto")
    (ev,) = [e for e in evs if e["action"] == "retrain_auto"]
    assert ev["cluster"] == 2
    assert se.generation > before                   # retrain published
    assert len(se.retrain_recommendations()) == 1


def test_executor_emits_observed_rank_error(setup):
    """The executor's per-batch observed-rank-error stat feeds the
    rank-drift detector: profiles carry the ratio, per-cluster gauges
    appear, and fresh models sit well inside the certified bound."""
    X, ix, snap, path, Q = setup
    obs.configure("on")
    obs.clear_profiles()
    from repro.core.executor import QueryExecutor
    ex = QueryExecutor(snap)
    ex.knn_query_batch(Q, 5)
    p = obs.last_profile()
    assert p is not None and p.rank_err_ratio is not None
    assert 0.0 <= p.rank_err_ratio <= 1.0           # inside bound E
    gauges = [m for m in obs.REGISTRY.metrics()
              if m.name.startswith("executor.rank_err_ratio.c")]
    assert gauges and all(g.value <= 1.0 for g in gauges)
    assert obs.REGISTRY.histogram("profile.rank_err_ratio").count >= 1


# ----------------------------------------------------- the loop, end to end
def test_closed_loop_paged_drift_to_rebalance_bit_identical(setup):
    """Acceptance: paged serving with skewed heat -> heat-drift finding
    -> daemon rebalance within cooldown -> replica load spread tightens
    (router.replica_spread series) -> results bit-identical throughout."""
    X, ix, snap, path, Q = setup
    obs.configure("on")
    obs.REGISTRY.reset()            # deterministic reservoirs for p50s
    paged = LIMSSnapshot.load(path, store=True, cache_pages=8)
    replicas = ReplicaSet(paged, n_replicas=4)
    router = PlanRouter(replicas)
    mon = Monitor(interval=3600.0,
                  detectors=[HeatSkewDetector(trigger=1.5, clear=1.15,
                                              persistence=2)])
    daemon = MonitorDaemon(mon, lambda: router, cooldown_ticks=2)

    from repro.core.executor import QueryExecutor
    ids_ref, ds_ref = QueryExecutor(snap).knn_query_batch(Q, 5)

    def spread(owner):
        counts = np.bincount(owner, minlength=4)
        return counts.max() / max(counts.mean(), 1e-12)

    # baseline traffic, balanced ownership: no finding should fire
    router.knn_query_batch(Q, 5)
    mon.tick()
    assert daemon.events() == []

    # inject placement drift: replica 0 "owns" every cluster while the
    # page-cache heat stays spread across clusters
    replicas.set_ownership(np.zeros(paged.K, np.int64))
    assert spread(replicas.owner) == pytest.approx(4.0)
    found = []
    for _ in range(4):
        ids, ds = router.knn_query_batch(Q, 5)
        assert np.array_equal(ids, ids_ref)         # exactness under drift
        assert np.array_equal(ds, ds_ref)
        found.extend(mon.tick())

    drift = [f for f in found if f.detector == "heat_skew" and not f.cleared]
    assert drift, "skewed heat must produce a heat-drift HealthFinding"
    assert drift[0].value == pytest.approx(4.0)     # all heat on replica 0
    rebal = [e for e in daemon.events() if e["action"] == "rebalance"]
    assert rebal, "daemon must rebalance on the finding"
    # acted on the very tick it fired — well within the cooldown window
    assert rebal[0]["tick"] == drift[0].tick
    # post-rebalance ownership spread measurably tightens: no replica
    # owns everything any more and the heat-greedy split is real
    assert spread(replicas.owner) < 4.0
    assert len(set(replicas.owner.tolist())) >= 2
    # and the next routed batches spread across replicas again: the
    # router.replica_spread series (sub-batches per batch) recovers
    for _ in range(6):
        ids, ds = router.knn_query_batch(Q, 5)
        assert np.array_equal(ids, ids_ref)         # still bit-identical
        assert np.array_equal(ds, ds_ref)
        mon.tick()
    s = mon.store.get("router.replica_spread.p50")
    assert s.last() is not None
    # the series dipped while batches collapsed onto replica 0, then
    # recovered once the daemon's rebalance took effect
    assert min(s.values()) < s.last()
    assert s.last() > 1.0
    # the skew signal itself dropped from the pinned-ownership 4.0x
    # back under the detector's trigger
    assert mon.store.get("router.heat_skew").last() < 1.5


def test_frontend_slo_accounting_and_monitor_integration(setup):
    """Frontend records per-request completion latency against the SLO
    target; shed requests count as misses; metrics() exposes
    attainment; an explicit Monitor instance is adopted and stopped by
    close()."""
    X, ix, snap, path, Q = setup
    obs.configure("on")
    se = ServingEngine(ix, refresh_every=0)
    mon = Monitor(interval=3600.0)
    with se.frontend(max_batch=4, slo_ms=100.0, slo_target_ms=60_000.0,
                     monitor=mon) as fe:
        assert fe.monitor is mon and fe.daemon is not None
        for j in range(6):
            fe.knn_query(Q[j], 3)
        m = fe.metrics()
        assert m["slo_ok"] == 6 and m["slo_miss"] == 0
        assert m["slo_attained"] == 1.0
        assert m["slo_target_ms"] == 60_000.0
        assert m["latency_ms_p50"] > 0.0
        mon.tick()
    assert not mon.running                          # close() stopped it
    assert mon.store.get("frontend.request_latency_s.p50") is not None

    # a hopeless target turns every completion into a miss
    with se.frontend(max_batch=4, slo_ms=100.0,
                     slo_target_ms=1e-9) as fe2:
        fe2.knn_query(Q[0], 3)
        m2 = fe2.metrics()
        assert m2["slo_miss"] == 1 and m2["slo_attained"] == 0.0
