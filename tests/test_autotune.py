"""Compiled hot path: lane dispatch, tile autotuner, fused plan kernel.

Pins the PR's correctness claims:

* the compiled XLA lane (``REPRO_INTERPRET=off`` on CPU) computes the
  same results as the interpret validation lane for every kernel stage;
* the fused ``pdist_rankeval`` launch is *bit-identical* to the staged
  pdist→rankeval pair, at the ops level and through
  ``planner.plan_arrays``, in both lanes;
* tile policy always yields aligned tiles that divide the padded
  operands (property-tested), whatever the tuning table says;
* a corrupted tuning-cache entry is rejected on load, served as a miss,
  and replaced by a valid entry under ``REPRO_AUTOTUNE=force``
  (round-trip through the JSON file);
* the env-knob registry rejects unknown knobs and invalid values with
  actionable errors.
"""
import functools
import json

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import env
from repro.kernels import autotune, ops

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

_TINY = {"q": 16, "p": 64, "d": 8}       # fast enough to tune in-test


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Point the tuner at a private cache file and drop the in-memory
    table around the test, so nothing leaks to ~/.cache or across
    tests."""
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    autotune._reset()
    yield path
    autotune._reset()


def _operands(seed=0, nq=37, npts=201, d=9):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((nq, d)).astype(np.float32)
    p = rng.standard_normal((npts, d)).astype(np.float32)
    r = np.abs(rng.standard_normal(nq)).astype(np.float32) + 0.5
    return q, p, r


def _rank_operands(seed=1, g=13, b=200, c=9):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, 2.0, (g, b)).astype(np.float32)
    coef = (rng.standard_normal((g, c)) * 10).astype(np.float32)
    lo = np.zeros(g, np.float32)
    hi = np.full(g, 2.0, np.float32)
    n = np.full(g, 500.0, np.float32)
    return x, coef, lo, hi, n


# ------------------------------------------------------------ env registry
def test_env_unknown_knob_raises():
    with pytest.raises(KeyError):
        env.get("REPRO_NO_SUCH_KNOB")


def test_env_invalid_value_lists_valid_ones(monkeypatch):
    monkeypatch.setenv("REPRO_STORAGE", "bogus")
    with pytest.raises(ValueError, match="paged"):
        env.get("REPRO_STORAGE")
    monkeypatch.setenv("REPRO_AUTOTUNE", "sometimes")
    with pytest.raises(ValueError, match="force"):
        env.get("REPRO_AUTOTUNE")


def test_env_empty_and_unset_mean_default(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert env.get("REPRO_AUTOTUNE") == "on"
    monkeypatch.setenv("REPRO_AUTOTUNE", "")
    assert env.get("REPRO_AUTOTUNE") == "on"
    monkeypatch.setenv("REPRO_KNN_DRIVER", "ROUNDS")   # case-insensitive
    assert env.get("REPRO_KNN_DRIVER") == "rounds"


def test_env_describe_covers_all_knobs():
    text = env.describe()
    for name in ("REPRO_INTERPRET", "REPRO_AUTOTUNE", "REPRO_TUNE_CACHE",
                 "REPRO_KNN_DRIVER", "REPRO_STORAGE"):
        assert name in text


# ------------------------------------------------------- tile properties
@settings(max_examples=30, deadline=None)
@given(nq=st.integers(1, 300), npts=st.integers(1, 3000),
       bq=st.sampled_from([None, 8, 32, 128, 256]),
       bp=st.sampled_from([None, 128, 512, 4096]),
       metric=st.sampled_from(["sql2", "l1", "linf"]))
def test_local_blocks_divide_padded_operands(nq, npts, bq, bp, metric):
    """Whatever the policy (heuristics or tuning table) picks, the tiles
    are sublane-aligned and divide the padded operand exactly — the
    invariant every lane's launch grid depends on."""
    tbq, tbp = ops.local_blocks(nq, npts, bq=bq, bp=bp, metric=metric)
    assert tbq > 0 and tbp > 0
    assert tbq % 8 == 0 and tbp % 8 == 0
    padded_q = -(-nq // tbq) * tbq
    padded_p = -(-npts // tbp) * tbp
    assert padded_q % tbq == 0 and padded_p % tbp == 0
    # tiles never exceed the padded operand (no degenerate over-tiling)
    assert tbq <= max(-(-nq // 8) * 8, tbq)


def test_tiles_for_returns_validated_tiles(tune_cache, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "force")
    t = autotune.tiles_for("pdist", "sql2", _TINY)
    assert set(t) == {"bq", "bp", "qb"}
    assert all(isinstance(v, int) and v > 0 and v % 8 == 0
               for v in t.values())
    # the entry landed in the JSON file too
    data = json.loads(tune_cache.read_text())
    assert data["version"] == autotune.SCHEMA_VERSION
    [(key, ent)] = list(data["entries"].items())
    assert key.startswith("xla-cpu/pdist/sql2/") or "/pdist/" in key
    assert ent["tiles"] == t


def test_autotune_off_is_a_miss(tune_cache, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    assert autotune.tiles_for("pdist", "sql2", _TINY) is None


def test_autotune_on_never_tunes_implicitly(tune_cache, monkeypatch):
    """Default mode is lookup-only: a miss stays a miss (no surprise
    multi-second tuning runs inside a serving path)."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    assert autotune.tiles_for("pdist", "sql2", _TINY) is None
    assert not tune_cache.exists()


def test_corrupted_cache_rejected_then_retuned(tune_cache, monkeypatch):
    """Corrupted entries must not crash loading or leak into launches:
    they are dropped on load (miss), and ``force`` replaces them with a
    freshly tuned valid entry — full round-trip through the file."""
    backend = "xla-cpu"
    bd = {k: autotune.bucket(v) for k, v in _TINY.items()}
    key = autotune._key(backend, "pdist", "sql2", bd)
    v = autotune.SCHEMA_VERSION
    corrupt = {
        key: {"tiles": {"bq": 12, "bp": 64, "qb": 8}, "us": 1.0, "v": v},
        key + "x": {"tiles": {"bq": 8}, "us": 1.0, "v": v},       # names
        autotune._key(backend, "rankeval", None, {"g": 8, "b": 8, "c": 8}):
            {"tiles": {"bg": 8, "bb": "all"}, "us": 1.0, "v": v},  # type
        autotune._key(backend, "range_filter", "sql2", bd):
            {"tiles": {"bq": 8, "bp": 8, "qb": 8}, "us": 1.0,
             "v": 1},   # stale schema version (pre-qb)
    }
    tune_cache.write_text(json.dumps(
        {"version": autotune.SCHEMA_VERSION, "entries": corrupt}))
    autotune._reset()
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    assert autotune.tiles_for("pdist", "sql2", _TINY) is None
    assert autotune.tiles_for("range_filter", "sql2", _TINY) is None
    monkeypatch.setenv("REPRO_AUTOTUNE", "force")
    t = autotune.tiles_for("pdist", "sql2", _TINY)
    assert t["bq"] % 8 == 0 and t["bp"] % 8 == 0
    # the rewritten file now carries the valid entry under the same key
    data = json.loads(tune_cache.read_text())
    assert data["entries"][key]["tiles"] == t
    assert autotune._valid_entry(backend, "pdist", data["entries"][key])
    # and a fresh process (cache drop) sees it as a plain hit
    autotune._reset()
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    assert autotune.tiles_for("pdist", "sql2", _TINY) == t


def test_truncated_cache_file_is_a_miss(tune_cache, monkeypatch):
    tune_cache.write_text('{"version": 1, "entr')      # torn write
    autotune._reset()
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    assert autotune.tiles_for("pdist", "sql2", _TINY) is None


def test_bucket_is_pow2_floor8():
    assert [autotune.bucket(v) for v in (1, 8, 9, 64, 65, 4096)] == \
        [8, 8, 16, 64, 128, 4096]
    # bucketing is why nearby shapes share one entry
    a = autotune._key("xla-cpu", "pdist", "sql2",
                      {k: autotune.bucket(v)
                       for k, v in {"q": 60, "p": 4000, "d": 8}.items()})
    b = autotune._key("xla-cpu", "pdist", "sql2",
                      {k: autotune.bucket(v)
                       for k, v in {"q": 64, "p": 4096, "d": 8}.items()})
    assert a == b


# ----------------------------------------------------- lane equivalence
def _lane(monkeypatch, value):
    monkeypatch.setenv("REPRO_INTERPRET", value)
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")


@pytest.mark.parametrize("metric", ["sql2", "l1", "linf"])
def test_xla_lane_matches_interpret_pdist(monkeypatch, metric):
    q, p, _ = _operands()
    _lane(monkeypatch, "on")
    a = np.asarray(ops.pdist(q, p, metric))
    _lane(monkeypatch, "off")
    b = np.asarray(ops.pdist(q, p, metric))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_xla_lane_matches_interpret_rankeval(monkeypatch):
    x, coef, lo, hi, n = _rank_operands()
    _lane(monkeypatch, "on")
    rk_a, rid_a = ops.rankeval(x, coef, lo, hi, n)
    _lane(monkeypatch, "off")
    rk_b, rid_b = ops.rankeval(x, coef, lo, hi, n)
    assert np.array_equal(np.asarray(rk_a), np.asarray(rk_b))
    assert np.array_equal(np.asarray(rid_a), np.asarray(rid_b))


def test_xla_lane_matches_interpret_range_filter(monkeypatch):
    q, p, r = _operands(seed=3)
    _lane(monkeypatch, "on")
    m_a, c_a = ops.range_filter(q, p, r)
    _lane(monkeypatch, "off")
    m_b, c_b = ops.range_filter(q, p, r)
    assert np.array_equal(np.asarray(m_a), np.asarray(m_b))
    # counts are per point-tile, and the lanes tile differently —
    # compare the per-query totals
    assert np.array_equal(np.asarray(c_a).sum(-1), np.asarray(c_b).sum(-1))


# -------------------------------------------------- fused vs staged
def _fused_inputs(seed=5, B=21, G=13, d=9, c=9):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, d)).astype(np.float32)
    piv = rng.standard_normal((G, d)).astype(np.float32)
    coef = (rng.standard_normal((G, c)) * 10).astype(np.float32)
    lo = np.zeros(G, np.float32)
    hi = np.full(G, 8.0, np.float32)
    n = np.full(G, 500.0, np.float32)
    rg = np.abs(rng.standard_normal(B)).astype(np.float32)
    return q, piv, coef, lo, hi, n, rg


@pytest.mark.parametrize("lane", ["on", "off"])
def test_fused_bitwise_matches_staged_ops(monkeypatch, lane):
    """The fused launch is bit-identical to the staged pair *within a
    lane* — same jnp ops in the same order on the same blocks — so
    enabling fusion can never change a plan."""
    _lane(monkeypatch, lane)
    q, piv, coef, lo, hi, n, rg = _fused_inputs()
    B = q.shape[0]
    dq_f, lo_f, hi_f = ops.pdist_rankeval(q, piv, coef, lo, hi, n, rg)
    dq_s = jnp.sqrt(jnp.maximum(ops.pdist(q, piv), 0.0))
    xb = jnp.concatenate([(dq_s - rg[:, None]).T,
                          (dq_s + rg[:, None]).T], axis=1)
    rank, _ = ops.rankeval(xb, coef, lo, hi, n)
    assert np.array_equal(np.asarray(dq_f), np.asarray(dq_s))
    assert np.array_equal(np.asarray(lo_f), np.asarray(rank)[:, :B])
    assert np.array_equal(np.asarray(hi_f), np.asarray(rank)[:, B:])


@functools.lru_cache(maxsize=1)
def _snapshot_env():
    from repro.core import LIMSIndex, MetricSpace
    from repro.core.snapshot import LIMSSnapshot
    from repro.data.datasets import gauss_mix
    X = gauss_mix(900, 6, seed=7)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    return X, LIMSSnapshot.build(ix)


@pytest.mark.parametrize("lane", ["on", "off"])
def test_plan_arrays_fused_bit_identity(monkeypatch, lane):
    """plan_arrays(fused=True) == plan_arrays(fused=False) bitwise —
    candidate mask and TriPrune routing — in both lanes.  This is the
    pin that lets dispatch turn fusion on by default on compiled
    lanes."""
    from repro.core.planner import plan_arrays
    _lane(monkeypatch, lane)
    X, snap = _snapshot_env()
    rng = np.random.default_rng(8)
    qf = jnp.asarray(X[rng.choice(len(X), 6)]
                     + rng.normal(0, 0.004, (6, X.shape[1])), jnp.float32)
    rf = jnp.asarray(rng.uniform(0.05, 0.5, 6), jnp.float32)
    cand_s, alive_s = plan_arrays(qf, rf, snap, snap.n_rings, fused=False)
    cand_f, alive_f = plan_arrays(qf, rf, snap, snap.n_rings, fused=True)
    assert np.array_equal(np.asarray(cand_s), np.asarray(cand_f))
    assert np.array_equal(np.asarray(alive_s), np.asarray(alive_f))


def test_tuned_tiles_change_grid_not_values(tune_cache, monkeypatch):
    """End-to-end: tune a bucket, then run the kernel with the table on
    vs off in the compiled lane — identical results, only the launch
    shape differs."""
    monkeypatch.setenv("REPRO_INTERPRET", "off")
    q, p, r = _operands(seed=9, nq=16, npts=64, d=8)
    monkeypatch.setenv("REPRO_AUTOTUNE", "force")
    assert autotune.tiles_for("pdist", "sql2", _TINY) is not None
    a = np.asarray(ops.pdist(q, p))
    m_a, c_a = ops.range_filter(q, p, r)
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    b = np.asarray(ops.pdist(q, p))
    m_b, c_b = ops.range_filter(q, p, r)
    assert np.array_equal(a, b)
    assert np.array_equal(np.asarray(m_a), np.asarray(m_b))
    assert np.array_equal(np.asarray(c_a).sum(-1), np.asarray(c_b).sum(-1))
