"""Paged storage tier correctness (DESIGN.md §7).

Covers the acceptance properties: snapshot spill/load round trip is
bit-identical (structures AND range/kNN results, including after a
retrain's incremental manifest swap), the store-backed executor returns
results bit-identical to the in-memory path on both CI legs (the
``ShardedExecutor`` degrades or shards exactly as usual — only the row
payloads move to disk), the IO-batch scheduler dedupes and coalesces
page fetches, the LRU cache stays exact under eviction pressure, and
``ServingEngine`` serves cold-start from a spilled directory and writes
retrained clusters back as new page extents.
"""
import os

import numpy as np
import pytest

import jax

from repro.core import LIMSIndex, MetricSpace, ServingEngine
from repro.core.executor import QueryExecutor, ShardedExecutor
from repro.core.metrics import dist_one_to_many
from repro.core.snapshot import LIMSSnapshot
from repro.data.datasets import gauss_mix
from repro.storage import (Manifest, PageLayout, PagedStore, page_runs,
                           plan_batch, rows_per_page)

N, D = 1600, 6


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    X = gauss_mix(N, D, seed=7)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=6, m=3, n_rings=10)
    snap = LIMSSnapshot.build(ix)
    path = str(tmp_path_factory.mktemp("store"))
    snap.spill(path)
    return X, ix, snap, path


def _queries(X, n_q, seed=2, scale=0.004):
    rng = np.random.default_rng(seed)
    return X[rng.choice(len(X), n_q)] + rng.normal(0, scale, (n_q, D))


def _radii(X, Q, sel=0.02):
    return np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), sel))
                     for q in Q])


def _assert_snapshots_equal(a: LIMSSnapshot, b: LIMSSnapshot):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))
    assert (a.K, a.m, a.n_rings, a.n_max, a.live) == \
        (b.K, b.m, b.n_rings, b.n_max, b.live)
    assert np.array_equal(a.gids_np, b.gids_np)
    assert np.array_equal(a.rows_np, b.rows_np)
    assert np.array_equal(a.valid_np, b.valid_np)


# ----------------------------------------------------------- layout/plan
def test_layout_math_and_alignment():
    rpp = rows_per_page(4096, 8)            # 64 f64 records of d=8
    assert rpp == 64
    assert rows_per_page(65536, 8) == 1024  # > 128 rows → 128-aligned
    assert rows_per_page(65536, 7) % 128 == 0
    lay = PageLayout(page_bytes=512, rows_per_page=8, d=8, n_max=20,
                     extents=(0, 3, 10))
    assert lay.pages_per_cluster == 3       # ceil(20/8)
    # slot 0 of cluster 1 starts at its extent; slot 19 is in its 3rd page
    pages, offs = lay.slot_locations(np.array([20, 39, 45]))
    assert pages.tolist() == [3, 5, 10] and offs.tolist() == [0, 3, 5]


def test_scheduler_dedupes_and_coalesces():
    lay = PageLayout(page_bytes=512, rows_per_page=8, d=8, n_max=16,
                     extents=(0, 2))
    cand = np.zeros((2, 32), bool)
    cand[0, [0, 1, 9]] = True          # cluster 0, pages 0 and 1
    cand[1, [1, 16, 31]] = True        # shares page 0; cluster 1 pages 2+3
    plan = plan_batch(cand, lay)
    assert plan.pages.tolist() == [0, 1, 2, 3]      # deduped across queries
    assert plan.runs == ((0, 4),)                   # coalesced to one run
    assert plan.pages_per_query.tolist() == [2, 3]
    assert plan.cand_per_query.tolist() == [3, 3]
    assert page_runs(np.array([0, 1, 5, 7, 8])) == ((0, 2), (5, 6), (7, 9))


# ------------------------------------------------------------- round trip
def test_spill_load_resident_roundtrip(setup):
    X, ix, snap, path = setup
    loaded = LIMSSnapshot.load(path)
    assert loaded.store is None
    _assert_snapshots_equal(snap, loaded)


def test_spill_is_atomic_no_temp_litter(setup):
    _, _, _, path = setup
    assert Manifest.exists(path)
    assert not [f for f in os.listdir(path) if ".tmp" in f]


def test_store_backed_results_bit_identical(setup):
    """The acceptance criterion: range and kNN through the paged store
    equal the in-memory executor bit for bit.  Runs the sharded wrapper
    so the 4-fake-device CI legs exercise the sharded candidate mask
    over a store-backed snapshot."""
    X, ix, snap, path = setup
    mem = QueryExecutor(snap)
    st = ShardedExecutor(LIMSSnapshot.load(path, store=True))
    assert st.snap.store is not None
    Q = _queries(X, 8, seed=3)
    rs = _radii(X, Q)
    rs[0] = 1e-12                               # provably empty query
    a = mem.range_query_batch(Q, rs)
    b = st.range_query_batch(Q, rs)
    assert len(b[0][0]) == 0
    for (ai, ad), (bi, bd) in zip(a, b):
        assert np.array_equal(ai, bi)
        assert np.array_equal(ad, bd)
    ids_a, ds_a = mem.knn_query_batch(Q, 6)
    ids_b, ds_b = st.knn_query_batch(Q, 6)
    assert np.array_equal(ids_a, ids_b) and np.array_equal(ds_a, ds_b)
    # k > live clamps identically (the store driver must terminate too)
    ids_a, ds_a = mem.knn_query_batch(Q[:2], N + 99)
    ids_b, ds_b = st.knn_query_batch(Q[:2], N + 99)
    assert ids_b.shape == (2, N)
    assert np.array_equal(ids_a, ids_b) and np.array_equal(ds_a, ds_b)


def test_store_reports_page_and_candidate_counts(setup):
    X, ix, snap, path = setup
    ex = QueryExecutor(LIMSSnapshot.load(path, store=True))
    Q = _queries(X, 5, seed=9)
    ex.range_query_batch(Q, _radii(X, Q))
    stats = ex.snap.store.stats.snapshot()
    assert stats["queries"] == 5
    assert stats["pages_per_query"] > 0
    assert stats["candidates_per_query"] > 0
    assert stats["requests"] == stats["hits"] + stats["misses"]
    # a single batch on a cold cache is all misses: the gather behind a
    # planned fetch must not re-count resident pages as hits
    assert stats["hits"] == 0 and stats["misses"] == stats["requests"]
    io = ex.last_io
    assert io["pages"] <= ex.snap.store.manifest.total_pages
    assert len(io["pages_per_query"]) == 5
    # candidate pages are a fraction of the corpus: the learned positions
    # narrow IO (the paper's point) — batch union strictly under a scan
    assert io["pages"] < ex.snap.store.manifest.total_pages


def test_lru_eviction_stays_exact(setup):
    """A 4-page cache thrashes constantly; results must not change and
    the counters must stay consistent."""
    X, ix, snap, path = setup
    tiny = QueryExecutor(LIMSSnapshot.load(path, store=True, cache_pages=4))
    mem = QueryExecutor(snap)
    Q = _queries(X, 6, seed=11)
    rs = _radii(X, Q)
    a = mem.range_query_batch(Q, rs)
    b = tiny.range_query_batch(Q, rs)
    for (ai, ad), (bi, bd) in zip(a, b):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)
    st = tiny.snap.store
    assert len(st.cache) <= 4
    assert st.stats.evictions > 0
    assert st.stats.requests == st.stats.hits + st.stats.misses


# ----------------------------------------------------- serving + writeback
def test_serving_paged_writeback_and_extent_reuse(tmp_path):
    """A refresh after updates publishes a new generation atomically;
    clusters whose row bytes are unchanged keep their extents, dirty
    ones append new pages (append-only file — the reader's cache and any
    concurrent reader's mmap stay valid)."""
    X = gauss_mix(1200, D, seed=5)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=5, m=3, n_rings=10)
    path = str(tmp_path / "store")
    se = ServingEngine(ix, refresh_every=0, storage="paged",
                       storage_path=path)
    man0 = Manifest.load(path)
    assert se.executor.snap.store is not None
    # a delete only flips validity (metadata): row bytes unchanged
    # everywhere → every extent reused.  Target the smallest cluster so
    # the later retrain can't shrink the global n_max (full rewrite).
    victim = int(np.argmin([ci.n for ci in ix.clusters]))
    dead = int(ix.clusters[victim].store_ids[0])
    assert se.delete(X[dead]) == 1
    se.refresh()
    man1 = Manifest.load(path)
    assert man1.generation == man0.generation + 1
    assert man1.extents == man0.extents
    assert man1.total_pages == man0.total_pages
    # retrain the dirtied cluster: it drops the tombstone, so its rows
    # change — exactly its extent is rewritten (appended)
    se.retrain_cluster(victim)          # refresh_every=0 gates auto-refresh
    se.refresh()                        # → trigger manually
    man2 = Manifest.load(path)
    assert man2.generation > man1.generation
    assert man2.n_max == man1.n_max     # smallest cluster can't set n_max
    changed = [k for k in range(man2.K)
               if man2.extents[k] != man1.extents[k]]
    assert changed == [victim]
    assert man2.total_pages > man1.total_pages
    # post-writeback results still match the host exactly
    Q = _queries(X, 6, seed=13)
    rs = _radii(X, Q)
    for (ids, ds), q, r in zip(se.range_query_batch(Q, rs), Q, rs):
        h_ids, h_ds, _ = ix.range_query(q, r)
        assert set(map(int, ids)) == set(map(int, h_ids))
        np.testing.assert_allclose(np.sort(ds), np.sort(h_ds), atol=0)
    # and a fresh resident load of the swapped store round-trips the
    # current snapshot bit-for-bit (post-retrain manifest swap)
    _assert_snapshots_equal(LIMSSnapshot.build(ix), LIMSSnapshot.load(path))


def test_serving_paged_update_consistency():
    """Insert/delete/retrain through a paged engine: store-backed batch
    results stay bit-identical to the host after the refresh folds the
    updates in (buffer rows included, tombstones excluded)."""
    rng = np.random.default_rng(0)
    X = gauss_mix(1100, D, seed=9)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    se = ServingEngine(ix, refresh_every=0, storage="paged")
    new_rows = X[rng.choice(1100, 12)] + rng.normal(0, 0.02, (12, D))
    gids = [se.insert(r) for r in new_rows]
    assert se.delete(X[3]) == 1
    assert se.delete(new_rows[0]) == 1
    se.retrain_cluster(0)
    se.refresh()
    Q = np.concatenate([new_rows[:3], X[rng.choice(1100, 3)]]) \
        + rng.normal(0, 0.003, (6, D))
    rs = _radii(X, Q)
    for (ids, ds), q, r in zip(se.range_query_batch(Q, rs), Q, rs):
        h_ids, h_ds, _ = ix.range_query(q, r)
        assert set(map(int, ids)) == set(map(int, h_ids))
        np.testing.assert_allclose(np.sort(ds), np.sort(h_ds), atol=0)
    ids, ds = se.knn_query_batch(Q, 5)
    for b, q in enumerate(Q):
        h_ids, h_ds, _ = ix.knn_query(q, 5)
        np.testing.assert_allclose(np.sort(ds[b]), np.sort(h_ds), atol=0)
    hit_ids, _ = se.range_query(new_rows[1], 1e-9)
    assert gids[1] in set(map(int, hit_ids))
    dead_ids, _ = se.range_query(new_rows[0], 1e-9)
    assert gids[0] not in set(map(int, dead_ids))


def test_inflight_executor_survives_writeback(tmp_path):
    """An executor serving generation g must keep returning generation-g
    results after refreshes publish later generations into the same
    store: its ``StoreView`` froze g's extents, and append-only page ids
    keep them byte-valid — the engine's contract that an in-flight batch
    finishes on its consistent snapshot extends to the storage tier."""
    X = gauss_mix(1000, D, seed=3)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    se = ServingEngine(ix, refresh_every=0, storage="paged",
                       storage_path=str(tmp_path / "s"))
    old_ex = se.executor
    Q = _queries(X, 5, seed=23)
    rs = _radii(X, Q)
    before_r = old_ex.range_query_batch(Q, rs)
    before_k = old_ex.knn_query_batch(Q, 5)
    rng = np.random.default_rng(1)
    for row in X[rng.choice(1000, 8)] + rng.normal(0, 0.02, (8, D)):
        se.insert(row)
    for c in range(ix.K):            # rewrite every cluster's extent
        se.retrain_cluster(c)
    se.refresh()
    assert se.executor is not old_ex
    after_r = old_ex.range_query_batch(Q, rs)
    for (ai, ad), (bi, bd) in zip(before_r, after_r):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)
    after_k = old_ex.knn_query_batch(Q, 5)
    assert np.array_equal(before_k[0], after_k[0])
    assert np.array_equal(before_k[1], after_k[1])


def test_cold_start_from_spill(setup):
    """A replica cold-starts from the spilled directory: serves exact
    results immediately, is read-only until an index is attached, and
    keeps its warm page cache across the first refresh."""
    X, ix, snap, path = setup
    cold = ServingEngine.from_spill(path)
    warm = QueryExecutor(snap)
    Q = _queries(X, 5, seed=17)
    rs = _radii(X, Q)
    a = warm.range_query_batch(Q, rs)
    b = cold.range_query_batch(Q, rs)
    for (ai, ad), (bi, bd) in zip(a, b):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)
    ids_a, ds_a = warm.knn_query_batch(Q, 4)
    ids_b, ds_b = cold.knn_query_batch(Q, 4)
    assert np.array_equal(ids_a, ids_b) and np.array_equal(ds_a, ds_b)
    assert cold.store.stats.misses > 0          # pages faulted in on demand
    with pytest.raises(RuntimeError, match="read-only"):
        cold.insert(X[0])
    with pytest.raises(RuntimeError, match="read-only"):
        cold.refresh()
    cold.attach_index(ix)
    store_before = cold.store
    cold.refresh()
    assert cold.store is store_before           # warm reader carried over
    b2 = cold.range_query_batch(Q, rs)
    for (ai, ad), (bi, bd) in zip(a, b2):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)


def test_geometry_mismatch_rejected(setup):
    """Mixing record formats in one store file must be refused."""
    X, ix, snap, path = setup
    with pytest.raises(ValueError, match="geometry"):
        snap.spill(path, page_bytes=64)         # different rows_per_page


# -------------------------------------------------------------- compaction
def test_compact_reclaims_garbage_extents(tmp_path):
    """Repeated retrain writebacks append new extents and orphan the old
    ones; ``compact()`` rewrites the live extents into a fresh pages
    file (atomic manifest swap) and the garbage is reclaimed — while an
    executor bound to the pre-compaction generation keeps serving
    bit-identically through its ``StoreView`` (old file unlinked, bytes
    pinned by its mmap)."""
    X = gauss_mix(1000, D, seed=21)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    path = str(tmp_path / "store")
    se = ServingEngine(ix, refresh_every=0, storage="paged",
                       storage_path=path)
    Q = _queries(X, 5, seed=31)
    rs = _radii(X, Q)
    old_ex = se.executor
    before_r = old_ex.range_query_batch(Q, rs)
    before_k = old_ex.knn_query_batch(Q, 5)
    rng = np.random.default_rng(7)
    for _ in range(2):                  # two dirty writeback generations
        for row in X[rng.choice(1000, 6)] + rng.normal(0, 0.02, (6, D)):
            se.insert(row)
        se.retrain_cluster(0)
        se.refresh()
    man_dirty = Manifest.load(path)
    live_pages = man_dirty.K * man_dirty.layout().pages_per_cluster
    assert man_dirty.total_pages > live_pages       # garbage accumulated
    size_dirty = se.store.nbytes_file()
    man_c = se.compact()
    assert man_c.generation == man_dirty.generation + 1
    assert man_c.total_pages == live_pages          # dense again
    assert man_c.pages_file != man_dirty.pages_file
    assert se.store.nbytes_file() < size_dirty      # bytes reclaimed
    assert not os.path.exists(os.path.join(path, man_dirty.pages_file))
    # compaction moved rows, not results: current, pre-compaction and
    # freshly loaded readers all still serve exactly
    for (ids, ds), q, r in zip(se.range_query_batch(Q, rs), Q, rs):
        h_ids, h_ds, _ = ix.range_query(q, r)
        assert set(map(int, ids)) == set(map(int, h_ids))
    after_r = old_ex.range_query_batch(Q, rs)
    for (ai, ad), (bi, bd) in zip(before_r, after_r):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)
    after_k = old_ex.knn_query_batch(Q, 5)
    assert np.array_equal(before_k[0], after_k[0])
    assert np.array_equal(before_k[1], after_k[1])
    _assert_snapshots_equal(LIMSSnapshot.build(ix), LIMSSnapshot.load(path))
    # and the next dirty writeback appends into the compacted file
    se.insert(X[0] + 0.01)
    se.refresh()
    man_next = Manifest.load(path)
    assert man_next.pages_file == man_c.pages_file
    assert man_next.total_pages > man_c.total_pages


def test_compact_through_stale_reader_is_safe(tmp_path):
    """Regression: compact() must copy through the *latest published*
    manifest's file size, not the calling reader's possibly older mmap
    — a writeback since the reader's last refresh() appends extents
    past that mmap, and a stale-sized read would silently truncate the
    compacted file."""
    X = gauss_mix(700, D, seed=13)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=3, m=2, n_rings=6)
    path = str(tmp_path / "s")
    LIMSSnapshot.build(ix).spill(path)
    stale = PagedStore(path)            # mmap sized to generation 0
    # a writeback this reader never refresh()ed into: dirty every
    # cluster so new extents land beyond the stale reader's mmap
    for c in range(ix.K):
        ix.retrain_cluster(c)
    ix.insert(X[0] + 0.01)
    snap1 = LIMSSnapshot.build(ix)
    snap1.spill(path)
    assert Manifest.load(path).total_pages > stale.manifest.total_pages
    man_c = stale.compact()             # must read the NEW extents fully
    assert man_c.generation == Manifest.load(path).generation
    _assert_snapshots_equal(snap1, LIMSSnapshot.load(path))


def test_repeated_compaction_converges(tmp_path):
    """compact() after compact() is stable: no garbage → same size."""
    X = gauss_mix(600, D, seed=2)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=3, m=2, n_rings=6)
    path = str(tmp_path / "s")
    LIMSSnapshot.build(ix).spill(path)
    store = PagedStore(path)
    m1 = store.compact()
    size1 = store.nbytes_file()
    m2 = store.compact()
    assert m2.generation == m1.generation + 1
    assert store.nbytes_file() == size1
    assert m2.extents == m1.extents


def test_compaction_releases_retired_mmaps(tmp_path):
    """An unlinked pages file stays mapped only while a live StoreView
    pins it; once the last view dies, the next compaction/refresh drops
    the mmap (releasing the unlinked file's disk blocks).  Without this
    a long-lived reader would pin every retired generation forever."""
    X = gauss_mix(600, D, seed=8)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=3, m=2, n_rings=6)
    path = str(tmp_path / "s")
    snap = LIMSSnapshot.build(ix)
    snap.spill(path)
    store = PagedStore(path)
    v0 = store.view()                   # pins generation 0's file
    f0 = v0.file
    store.compact()
    assert f0 in store._maps            # v0 alive → old mmap retained
    rows_pinned = v0.gather(np.arange(4))
    assert rows_pinned.shape == (4, D)  # still readable post-unlink
    del v0, rows_pinned
    store.compact()                     # next adoption prunes it
    assert f0 not in store._maps
    assert len(store._maps) == 1        # only the current file mapped


# ---------------------------------------------------------- async prefetch
def test_prefetch_async_bit_identical_and_overlaps(setup):
    """``REPRO_PREFETCH=async`` is an IO-scheduling change only: kNN
    results stay bit-identical to the synchronous paged path, and the
    prefetcher demonstrably overlaps rounds — the speculative fetch for
    at least one round completes before that round's demand fetch
    arrives (the acceptance criterion's overlap proof)."""
    X, ix, snap, path = setup
    sync_ex = QueryExecutor(LIMSSnapshot.load(path, store=True),
                            prefetch="off")     # pinned past REPRO_PREFETCH
    pre_ex = QueryExecutor(LIMSSnapshot.load(path, store=True),
                           prefetch="async")
    assert sync_ex.prefetcher is None
    pf = pre_ex.prefetcher
    assert pf is not None
    # de-flake the overlap assertion: on a starved runner the daemon
    # worker might not get scheduled between submit and the next
    # round's demand, so let each demand wait for its pending ticket —
    # production keeps the racy best-effort behavior, this pins that
    # the machinery (submit → background fetch → demand hit) works
    orig_note = pf.note_demand

    def patient_note(pages, ticket=None):
        if ticket is not None:
            assert ticket.wait(timeout=60)
        orig_note(pages, ticket)

    pf.note_demand = patient_note
    # querying AT pivot rows collapses the seed radii to the guard band:
    # round-0 masks are tiny and each doubling adds slots (and pages)
    # incrementally — the regime prefetch exists for.  (Random-query
    # batches over a corpus this small saturate the batch-deduped page
    # union in round 0, leaving later rounds no IO to overlap.)
    Q = np.asarray(snap.pivots, np.float64).reshape(-1, D)[:8]
    ids_a, ds_a = sync_ex.knn_query_batch(Q, 8)
    ids_b, ds_b = pre_ex.knn_query_batch(Q, 8)
    assert np.array_equal(ids_a, ids_b) and np.array_equal(ds_a, ds_b)
    assert pre_ex.last_knn["rounds"] >= 2       # tiny seed → multi-round
    pf.drain()          # settle in-flight tickets before reading stats
    stats = pf.snapshot()
    assert stats["pages_submitted"] > 0
    assert stats["pages_fetched"] == stats["pages_submitted"]
    assert stats["overlapped_rounds"] >= 1
    assert 0.0 <= stats["hit_rate"] <= 1.0
    # range results are single-round (nothing to prefetch) but must be
    # unaffected by the prefetcher's presence
    rs = _radii(X, Q)
    a = sync_ex.range_query_batch(Q, rs)
    b = pre_ex.range_query_batch(Q, rs)
    for (ai, ad), (bi, bd) in zip(a, b):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)


def test_prefetch_engine_wiring(tmp_path):
    """ServingEngine(prefetch="async") threads the mode through refresh
    generations; results stay exact."""
    X = gauss_mix(900, D, seed=17)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    se = ServingEngine(ix, refresh_every=0, storage="paged",
                       storage_path=str(tmp_path / "s"), prefetch="async")
    assert se.executor.prefetcher is not None
    Q = _queries(X, 4, seed=3)
    ids, ds = se.knn_query_batch(Q, 5)
    for b, q in enumerate(Q):
        h_ids, h_ds, _ = ix.knn_query(q, 5)
        np.testing.assert_allclose(np.sort(ds[b]), np.sort(h_ds), atol=0)
    se.refresh()
    assert se.executor.prefetcher is not None   # survives the swap


# -------------------------------------------------------- schedule pinning
def test_pinned_pages_survive_cache_squeeze():
    """Unit pin/evict semantics: capacity eviction takes the coldest
    *unpinned* page; an all-pinned cache overflows instead of breaking
    a hold; releasing the pins shrinks back under capacity."""
    from repro.storage import LRUPageCache
    c = LRUPageCache(capacity_pages=2)
    blk = np.zeros((1, 1))
    c.put("a", blk), c.put("b", blk)
    c.pin(["a"])
    assert c.put("c", blk) == 1                 # "b" (coldest unpinned)
    assert c.peek("a") is not None and c.peek("b") is None
    c.pin(["c"])
    # "a"/"c" pinned → the only evictable page is "d" itself
    assert c.put("d", blk) == 1
    assert c.peek("a") is not None and c.peek("c") is not None
    c.pin(["d", "e"])                           # pin non-resident pages
    c.put("d", blk)
    assert len(c) == 3 and c.pinned == 4        # all pinned: overflowed
    assert c.put("e", blk) == 0                 # nothing evictable
    assert len(c) == 4
    assert c.unpin(["a", "c", "d", "e"]) == 2   # shrink back to capacity
    assert len(c) == 2 and c.pinned == 0


def test_unpin_restores_lru_order():
    """A pinned page earns recency like any other; after unpin it is
    evicted exactly when plain LRU would evict it — no residual
    privilege, no penalty."""
    from repro.storage import LRUPageCache
    c = LRUPageCache(capacity_pages=3)
    blk = np.zeros((1, 1))
    for k in ("a", "b", "c"):
        c.put(k, blk)
    c.pin(["a"])
    c.touch("a")                                # "a" now hottest
    c.unpin(["a"])
    c.put("d", blk)                             # plain LRU: "b" goes
    assert c.peek("b") is None
    assert all(c.peek(k) is not None for k in ("a", "c", "d"))


def test_plan_pins_released_after_batch(setup, monkeypatch):
    """A batch pins its planned pages for its whole execution (fetch →
    gather → exact refinement) and releases them all afterwards — on
    success AND when the executor errors mid-batch."""
    X, ix, snap, path = setup
    ex = QueryExecutor(LIMSSnapshot.load(path, store=True, cache_pages=4))
    store = ex.snap.store
    Q = _queries(X, 5, seed=23)
    rs = _radii(X, Q)
    mem = QueryExecutor(snap)
    a = mem.range_query_batch(Q, rs)
    b = ex.range_query_batch(Q, rs)
    for (ai, ad), (bi, bd) in zip(a, b):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)
    assert ex.last_io["pinned_pages"] > 0
    assert store.cache.pinned == 0              # fully released
    assert len(store.cache) <= 4                # overflow cleared too
    ids_m, _ = mem.knn_query_batch(Q, 6)
    ids_p, _ = ex.knn_query_batch(Q, 6)
    assert np.array_equal(ids_m, ids_p)
    assert ex.last_io["pinned_pages"] > 0
    assert store.cache.pinned == 0
    # executor error mid-refinement: the finally still drains the plan
    def boom(idx):
        raise RuntimeError("refinement died")
    monkeypatch.setattr(ex, "_refine_rows", boom)
    with pytest.raises(RuntimeError, match="refinement died"):
        ex.range_query_batch(Q, rs)
    assert store.cache.pinned == 0
    with pytest.raises(RuntimeError, match="refinement died"):
        ex.knn_query_batch(Q, 6)
    assert store.cache.pinned == 0


def test_pin_mode_off_is_blind_lru(setup, monkeypatch):
    """``REPRO_CACHE_PIN=off`` (the bench's baseline) takes no holds at
    all — and results are unchanged either way."""
    X, ix, snap, path = setup
    monkeypatch.setenv("REPRO_CACHE_PIN", "off")
    ex = QueryExecutor(LIMSSnapshot.load(path, store=True, cache_pages=4))
    Q = _queries(X, 4, seed=29)
    ids_p, ds_p = ex.knn_query_batch(Q, 5)
    assert ex.last_io["pinned_pages"] == 0
    assert ex.snap.store.cache.pinned == 0
    ids_m, ds_m = QueryExecutor(snap).knn_query_batch(Q, 5)
    assert np.array_equal(ids_p, ids_m) and np.array_equal(ds_p, ds_m)


# -------------------------------------------------------- prefetch shutdown
def test_prefetch_shutdown_drops_and_counts(setup):
    """Satellite requirement: the prefetch daemon stops deliberately —
    queued/in-flight plans are dropped (not drained), the drop is
    visible in the prefetcher's stats, and a post-shutdown submit
    degrades to an immediate counted drop instead of leaking work."""
    import repro.storage.prefetch as pfm
    from repro.storage import PagePrefetcher, shutdown_prefetch
    X, ix, snap, path = setup
    store = PagedStore(path)
    pf = PagePrefetcher(store)
    try:
        t = pf.submit(np.arange(3, dtype=np.int64))
        assert t.wait(5.0)
        assert pf.pages_fetched == 3
        assert shutdown_prefetch(timeout=5.0)   # joined within timeout
        t2 = pf.submit(np.arange(4, dtype=np.int64))
        assert t2.done()                        # completes at once...
        snap_d = pf.snapshot()
        assert snap_d["dropped_plans"] == 1     # ...but dropped, counted
        assert snap_d["pages_dropped"] == 4
        assert pf.pages_fetched == 3            # nothing fetched for it
        pf.drain()                              # no-op, must not hang
        assert shutdown_prefetch()              # idempotent
    finally:
        pfm._restart_for_tests()                # rest of the suite
    t3 = pf.submit(np.arange(2, dtype=np.int64))
    assert t3.wait(5.0)
    assert pf.pages_fetched == 5


# ----------------------------------------------------------------- real IO
def test_drop_os_cache_best_effort(setup):
    """``--real-io`` support: dropping the OS page cache is advisory and
    must never change results (it only makes the next cold read honest)."""
    X, ix, snap, path = setup
    ex = QueryExecutor(LIMSSnapshot.load(path, store=True))
    Q = _queries(X, 4, seed=41)
    rs = _radii(X, Q)
    a = ex.range_query_batch(Q, rs)
    supported = ex.snap.store.drop_os_cache()
    assert supported == hasattr(os, "posix_fadvise")
    ex.snap.store.cache.clear()
    b = ex.range_query_batch(Q, rs)
    for (ai, ad), (bi, bd) in zip(a, b):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)
