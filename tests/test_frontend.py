"""Serving-stack layers above the engine (DESIGN.md §9).

Covers the refactor's acceptance properties: queries submitted
concurrently through the dynamic-batching frontend return bit-identical
results to direct ``QueryExecutor`` calls (resident AND paged — the CI
legs run this file on 1 and 4 fake devices); the batcher demonstrably
coalesces ≥2 submitters into one kernel batch; admission control sheds
with ``FrontendOverload`` when the bounded queue is full; the router
builds exactly one CandidatePlan per batch and dispatches sub-batches
to replicas whose results reassemble bit-identically; replica placement
shares the snapshot's aux state; ownership rebalance follows the heat
signal; and the ``repro.core.serving`` shim keeps old imports working.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import LIMSIndex, MetricSpace
from repro.core.executor import QueryExecutor
from repro.core.metrics import dist_one_to_many
from repro.core.snapshot import LIMSSnapshot
from repro.serving import (FrontendOverload, PlanRouter, ReplicaSet,
                           ServingEngine, ServingFrontend)

N, D = 1200, 5


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    from repro.data.datasets import gauss_mix
    X = gauss_mix(N, D, seed=13)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=5, m=3, n_rings=8)
    snap = LIMSSnapshot.build(ix)
    path = str(tmp_path_factory.mktemp("frontend-store"))
    snap.spill(path)
    return X, ix, snap, path


def _queries(X, n_q, seed=2, scale=0.004):
    rng = np.random.default_rng(seed)
    return X[rng.choice(len(X), n_q)] + rng.normal(0, scale, (n_q, D))


def _radii(X, Q, sel=0.02):
    return np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), sel))
                     for q in Q])


def _pending(f: ServingFrontend) -> int:
    with f._cv:
        return len(f._pending)


def _wait_pending(f: ServingFrontend, n: int, timeout: float = 10.0):
    deadline = time.monotonic() + timeout
    while _pending(f) < n:
        assert time.monotonic() < deadline, \
            f"only {_pending(f)}/{n} requests queued"
        time.sleep(0.005)


# -------------------------------------------------------------------- shim
def test_core_serving_shim_still_works():
    """The refactor keeps every old import path alive."""
    from repro.core.serving import ServingEngine as shim_engine
    from repro.core import ServingEngine as core_engine
    assert shim_engine is ServingEngine
    assert core_engine is ServingEngine


# ---------------------------------------------------------------- replicas
def test_replica_set_shares_aux_state(setup):
    """Placement is a pytree map: device leaves move, aux data (ids,
    validity, store view) is shared by reference across replicas."""
    X, ix, snap, path = setup
    rs = ReplicaSet(snap, n_replicas=3)
    assert len(rs) == 3
    for rep in rs.members:
        s = rep.ex.snap
        assert s.gids_np is snap.gids_np
        assert s.valid_np is snap.valid_np
        assert s.store is snap.store
    own = rs.ownership()
    assert own.shape == (3, snap.K)
    assert (own.sum(axis=0) == 1).all()      # every cluster owned once
    # every replica answers bit-identically on its own
    Q = _queries(X, 4, seed=3)
    ref_ids, ref_ds = QueryExecutor(snap).knn_query_batch(Q, 5)
    for rep in rs.members:
        ids, ds = rep.ex.knn_query_batch(Q, 5)
        assert np.array_equal(ids, ref_ids)
        assert np.array_equal(ds, ref_ds)


def test_rebalance_follows_heat(setup):
    """Greedy makespan: the hottest cluster lands alone on one replica
    when it outweighs the rest combined; total heat stays balanced."""
    snap = setup[2]
    rs = ReplicaSet(snap, n_replicas=2)
    heat = np.ones(snap.K)
    heat[3] = 100.0
    owner = rs.rebalance(heat)
    hot = owner[3]
    assert (owner == hot).sum() == 1         # hot cluster isolated
    assert set(owner.tolist()) == {0, 1}
    assert np.array_equal(rs.owner, owner)
    stats = rs.load_stats()
    assert sum(s["owned_clusters"] for s in stats) == snap.K
    with pytest.raises(ValueError):
        rs.rebalance(np.ones(snap.K + 1))


# ------------------------------------------------------------------ router
def test_router_bit_identical_and_one_plan(setup):
    """Sub-batched execution across replicas reassembles to exactly the
    direct executor's results, from exactly one plan construction per
    batch (subsetting never re-plans)."""
    X, ix, snap, path = setup
    direct = QueryExecutor(snap)
    router = PlanRouter(ReplicaSet(snap, n_replicas=3))
    Q = _queries(X, 12, seed=5)
    rs = _radii(X, Q)
    rs[0] = 1e-12                            # unrouted → round-robin
    before = router.routing_ex.planner.built
    got = router.range_query_batch(Q, rs)
    assert router.routing_ex.planner.built == before + 1
    for (gi, gd), (ri, rd) in zip(got, direct.range_query_batch(Q, rs)):
        assert np.array_equal(gi, ri)
        assert np.array_equal(gd, rd)
    assert len(got[0][0]) == 0
    for k in (1, 7, N + 50):                 # incl. k > live clamp
        ids_r, ds_r = router.knn_query_batch(Q, k)
        ids_d, ds_d = direct.knn_query_batch(Q, k)
        assert np.array_equal(ids_r, ids_d)
        assert np.array_equal(ds_r, ds_d)
    # replica planners never built a plan; dispatch covered every query
    assert all(m.ex.planner.built == 0
               for m in router.replicas.members[1:])
    assert sum(m.queries for m in router.replicas.members) == 4 * len(Q)
    assert router.routed_heat.sum() > 0
    assert router.load_stats()["routed_heat"][0] >= 0


def test_router_paged_bit_identical(setup):
    """Routing composes with the paged tier: replicas share one
    StoreView/cache, results stay bit-identical, pins drain."""
    X, ix, snap, path = setup
    direct = QueryExecutor(snap)
    paged = LIMSSnapshot.load(path, store=True, cache_pages=8)
    router = PlanRouter(ReplicaSet(paged, n_replicas=2))
    Q = _queries(X, 8, seed=7)
    ids_r, ds_r = router.knn_query_batch(Q, 6)
    ids_d, ds_d = direct.knn_query_batch(Q, 6)
    assert np.array_equal(ids_r, ids_d)
    assert np.array_equal(ds_r, ds_d)
    assert paged.store.cache.pinned == 0
    rs = _radii(X, Q)
    for (gi, gd), (ri, rd) in zip(router.range_query_batch(Q, rs),
                                  direct.range_query_batch(Q, rs)):
        assert np.array_equal(gi, ri)
        assert np.array_equal(gd, rd)
    assert paged.store.cache.pinned == 0
    heat = router.replicas.cluster_heat()
    assert heat is not None and heat.shape == (paged.K,)
    assert heat.sum() > 0                    # cache counters fed back
    router.rebalance()                       # folds heat into ownership


def test_router_replica_error_reaches_caller(setup):
    """An executor failure inside a routed sub-batch re-raises on the
    calling thread, never silently drops queries."""
    X, ix, snap, path = setup
    router = PlanRouter(ReplicaSet(snap, n_replicas=1))
    def boom(Q, plan):
        raise RuntimeError("replica died")
    router.replicas.members[0].ex.execute_knn = boom
    with pytest.raises(RuntimeError, match="replica died"):
        router.knn_query_batch(_queries(X, 3, seed=9), 4)


# ---------------------------------------------------------------- frontend
def test_frontend_coalesces_concurrent_submitters(setup):
    """Acceptance criterion: single-query submitters are coalesced into
    one kernel batch (≥2 demonstrably), with results bit-identical to a
    direct batch call."""
    X, ix, snap, path = setup
    Q = _queries(X, 6, seed=11)
    ref_ids, ref_ds = QueryExecutor(snap).knn_query_batch(Q, 5)
    with ServingFrontend(QueryExecutor(snap), max_batch=8,
                         slo_ms=50.0) as f:
        f.pause()
        results = [None] * len(Q)

        def submit(j):
            results[j] = f.knn_query(Q[j], 5)

        threads = [threading.Thread(target=submit, args=(j,))
                   for j in range(len(Q))]
        for t in threads:
            t.start()
        _wait_pending(f, len(Q))
        f.resume()
        for t in threads:
            t.join()
        for j, (ids, ds) in enumerate(results):
            assert np.array_equal(ids, ref_ids[j])
            assert np.array_equal(ds, ref_ds[j])
        m = f.metrics()
    assert m["submitted"] == len(Q)
    assert m["batches"] == 1                 # all six in one dispatch
    assert m["batch_size_max"] == len(Q)
    assert m["coalesced_batches"] >= 1
    assert m["shed"] == 0
    assert m["queue_wait_ms_p99"] >= m["queue_wait_ms_p50"] >= 0.0
    # the whole batch was routed (replica count is device-dependent)
    assert sum(r["queries"] for r in m["routing"]["replicas"]) == len(Q)


def test_frontend_batches_by_key(setup):
    """Range queries coalesce regardless of radius; kNN batches never
    mix k (k shapes the plan and the outputs)."""
    X, ix, snap, path = setup
    Q = _queries(X, 4, seed=15)
    rs = _radii(X, Q)
    direct = QueryExecutor(snap)
    ref_range = direct.range_query_batch(Q, rs)
    ref3 = direct.knn_query_batch(Q[:2], 3)
    ref9 = direct.knn_query_batch(Q[2:], 9)
    with ServingFrontend(QueryExecutor(snap), max_batch=8,
                         slo_ms=50.0) as f:
        f.pause()
        out = {}

        def submit(tag, fn, *a):
            out[tag] = fn(*a)

        threads = [threading.Thread(target=submit,
                                    args=(("r", j), f.range_query,
                                          Q[j], rs[j]))
                   for j in range(4)]
        threads += [threading.Thread(target=submit,
                                     args=(("k3", j), f.knn_query, Q[j], 3))
                    for j in range(2)]
        threads += [threading.Thread(target=submit,
                                     args=(("k9", j), f.knn_query, Q[j], 9))
                    for j in range(2, 4)]
        for t in threads:
            t.start()
        _wait_pending(f, 8)
        f.resume()
        for t in threads:
            t.join()
        m = f.metrics()
    for j in range(4):
        ids, ds = out[("r", j)]
        assert np.array_equal(ids, ref_range[j][0])
        assert np.array_equal(ds, ref_range[j][1])
    for j in range(2):
        assert np.array_equal(out[("k3", j)][0], ref3[0][j])
        assert np.array_equal(out[("k9", j + 2)][0], ref9[0][j])
    assert m["batches"] == 3                 # range, k=3, k=9 — never mixed
    assert m["coalesced_batches"] == 3
    assert m["batch_size_mean"] > 2.0


def test_frontend_sheds_on_overload(setup):
    """Admission control: a submit that finds the bounded queue full
    fails immediately with FrontendOverload; queued requests still
    complete exactly."""
    X, ix, snap, path = setup
    Q = _queries(X, 3, seed=17)
    ref_ids, _ = QueryExecutor(snap).knn_query_batch(Q[:2], 4)
    with ServingFrontend(QueryExecutor(snap), max_batch=4, slo_ms=20.0,
                         max_queue=2) as f:
        f.pause()
        results = {}
        threads = [threading.Thread(
            target=lambda j=j: results.update({j: f.knn_query(Q[j], 4)}))
            for j in range(2)]
        for t in threads:
            t.start()
        _wait_pending(f, 2)
        with pytest.raises(FrontendOverload):
            f.knn_query(Q[2], 4)             # queue full → shed, no queueing
        f.resume()
        for t in threads:
            t.join()
        m = f.metrics()
    assert m["shed"] == 1 and m["submitted"] == 2
    assert m["shed_rate"] == pytest.approx(1 / 3, abs=1e-4)
    for j in range(2):
        assert np.array_equal(results[j][0], ref_ids[j])


def test_frontend_tracks_engine_generation(setup):
    """The frontend rebuilds its replica set when the engine publishes a
    new snapshot generation — batches never mix generations, and queries
    after a refresh see the refreshed index."""
    X, ix0, snap, path = setup
    from repro.data.datasets import gauss_mix
    Xe = gauss_mix(800, D, seed=21)
    ixe = LIMSIndex(MetricSpace(Xe, "l2"), n_clusters=4, m=3, n_rings=8)
    se = ServingEngine(ixe, refresh_every=0)
    with se.frontend(max_batch=4, slo_ms=5.0) as f:
        q = Xe[5]
        ids0, _ = f.knn_query(q, 3)
        r0 = f._router_obj
        assert f._gen == se.generation
        p_new = Xe[5] + 1e-7                 # near-duplicate insert
        gid = se.insert(p_new)
        se.refresh()
        assert se.generation == f._gen + 1
        ids1, _ = f.knn_query(q, 3)
        assert f._gen == se.generation
        assert f._router_obj is not r0       # replica set rebuilt
        assert gid in ids1                   # new generation is served
        ref_ids, _ = se.executor.knn_query_batch(q[None], 3)
        assert np.array_equal(ids1, ref_ids[0])
    assert ids0 is not None


def test_frontend_paged_backend(setup):
    """Frontend → router → replicas over the paged tier: bit-identical
    to the resident direct path, pins fully drained after every batch."""
    X, ix, snap, path = setup
    Q = _queries(X, 5, seed=19)
    ref_ids, ref_ds = QueryExecutor(snap).knn_query_batch(Q, 6)
    paged = LIMSSnapshot.load(path, store=True, cache_pages=8)
    with ServingFrontend(QueryExecutor(paged), max_batch=8,
                         slo_ms=50.0) as f:
        f.pause()
        results = [None] * len(Q)
        threads = [threading.Thread(
            target=lambda j=j: results.__setitem__(j, f.knn_query(Q[j], 6)))
            for j in range(len(Q))]
        for t in threads:
            t.start()
        _wait_pending(f, len(Q))
        f.resume()
        for t in threads:
            t.join()
        m = f.metrics()
    for j, (ids, ds) in enumerate(results):
        assert np.array_equal(ids, ref_ids[j])
        assert np.array_equal(ds, ref_ds[j])
    assert m["coalesced_batches"] >= 1
    assert paged.store.cache.pinned == 0


def test_frontend_lifecycle(setup):
    """close() drains and rejects later submits; errors inside a batch
    reach every submitter of that batch."""
    X, ix, snap, path = setup
    f = ServingFrontend(QueryExecutor(snap), max_batch=4, slo_ms=5.0)
    ids, ds = f.knn_query(X[0], 2)
    assert len(ids) == 2
    f.close()
    with pytest.raises(RuntimeError, match="closed"):
        f.knn_query(X[0], 2)
    with pytest.raises(ValueError):
        ServingFrontend(QueryExecutor(snap), max_batch=0)
