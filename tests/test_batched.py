"""Batch engine correctness: ``BatchedLIMS.range_query_batch`` /
``knn_query_batch`` must return exactly the host ``LIMSIndex`` results —
including heterogeneous radii, k=1, k > n, empty-result queries and
snapshots taken after inserts/deletes — and must execute through the
Pallas kernels (pdist / rankeval / range_filter), not ad-hoc broadcasts.
"""
import numpy as np
import pytest

from repro.core import LIMSIndex, MetricSpace
from repro.core.batched import BatchedLIMS
from repro.core.metrics import dist_one_to_many
from repro.data.datasets import gauss_mix

N, D = 2500, 6


@pytest.fixture(scope="module")
def setup():
    X = gauss_mix(N, D, seed=4)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=10, m=3, n_rings=12)
    return X, ix, BatchedLIMS(ix)


def _queries(X, n_q, seed=2, scale=0.004):
    rng = np.random.default_rng(seed)
    return X[rng.choice(len(X), n_q)] + rng.normal(0, scale, (n_q, D))


def test_range_batch_matches_host_heterogeneous_radii(setup):
    X, ix, bx = setup
    rng = np.random.default_rng(3)
    Q = _queries(X, 12)
    # heterogeneous per-query radii, including r≈0 (empty result set)
    rs = np.array([float(np.quantile(dist_one_to_many(q, X, "l2"),
                                     rng.uniform(5e-4, 5e-2))) for q in Q])
    rs[0] = 1e-12                       # provably empty
    results = bx.range_query_batch(Q, rs)
    assert len(results) == len(Q)
    assert len(results[0][0]) == 0      # empty-result query stays empty
    for (ids, ds), q, r in zip(results, Q, rs):
        h_ids, h_ds, _ = ix.range_query(q, r)
        assert set(map(int, ids)) == set(map(int, h_ids))
        np.testing.assert_allclose(np.sort(ds), np.sort(h_ds), atol=0)
        # returned distances are true f64 distances
        d_all = dist_one_to_many(q, X, "l2")
        for i, dd in zip(ids, ds):
            assert dd == d_all[int(i)]


def test_range_batch_scalar_radius_and_wrapper(setup):
    X, ix, bx = setup
    Q = _queries(X, 4, seed=9)
    r = float(np.quantile(dist_one_to_many(Q[0], X, "l2"), 0.01))
    batch = bx.range_query_batch(Q, r)
    for (ids, ds), q in zip(batch, Q):
        w_ids, w_ds = bx.range_query(q, r)
        assert set(map(int, ids)) == set(map(int, w_ids))


@pytest.mark.parametrize("k", [1, 7])
def test_knn_batch_matches_host(setup, k):
    X, ix, bx = setup
    Q = _queries(X, 8, seed=5)
    ids, ds = bx.knn_query_batch(Q, k)
    assert ids.shape == (len(Q), k) and ds.shape == (len(Q), k)
    for b, q in enumerate(Q):
        h_ids, h_ds, _ = ix.knn_query(q, k)
        np.testing.assert_allclose(np.sort(ds[b]), np.sort(h_ds), atol=0)
        assert set(map(int, ids[b])) == set(map(int, h_ids))


def test_knn_k_exceeds_live_count(setup):
    """k > n must clamp and terminate in both engines (regression for the
    infinite growing-radius loop)."""
    X, ix, bx = setup
    q = X[17] + 0.01
    ids, ds = bx.knn_query_batch(q[None], N + 500)
    assert ids.shape == (1, N)
    h_ids, h_ds, _ = ix.knn_query(q, N + 500)        # must terminate
    assert len(h_ids) == N
    np.testing.assert_allclose(np.sort(ds[0]), np.sort(h_ds), atol=0)


def test_post_insert_delete_snapshot():
    """A snapshot taken after §5.3 updates sees buffered inserts and skips
    tombstones, matching the host exactly."""
    rng = np.random.default_rng(0)
    X = gauss_mix(1500, D, seed=1)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=6, m=3, n_rings=10)
    new_rows = X[rng.choice(1500, 25)] + rng.normal(0, 0.02, (25, D))
    gids = [ix.insert(r) for r in new_rows]
    ix.delete(X[3])
    ix.delete(new_rows[0])
    bx = BatchedLIMS(ix)
    Q = np.concatenate([new_rows[:4], X[rng.choice(1500, 4)]]) \
        + rng.normal(0, 0.003, (8, D))
    rs = np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), 0.02))
                   for q in Q])
    for (ids, ds), q, r in zip(bx.range_query_batch(Q, rs), Q, rs):
        h_ids, h_ds, _ = ix.range_query(q, r)
        assert set(map(int, ids)) == set(map(int, h_ids))
    ids, ds = bx.knn_query_batch(Q, 5)
    for b, q in enumerate(Q):
        h_ids, h_ds, _ = ix.knn_query(q, 5)
        np.testing.assert_allclose(np.sort(ds[b]), np.sort(h_ds), atol=0)
    # a buffered insert is findable through the batch engine
    hit_ids, _ = bx.range_query(new_rows[1], 1e-9)
    assert gids[1] in set(map(int, hit_ids))


def test_batch_engine_runs_through_pallas_kernels(setup, monkeypatch):
    """The acceptance property: the batch paths execute pdist_pallas /
    rankeval_pallas / range_filter_pallas (via the ops wrappers), not
    host broadcasts."""
    from repro.kernels import ops
    X, ix, bx = setup
    calls = {"pdist": 0, "rankeval": 0, "range_filter": 0}
    real = {name: getattr(ops, name) for name in calls}

    def wrap(name):
        def fn(*a, **k):
            calls[name] += 1
            return real[name](*a, **k)
        return fn

    for name in calls:
        monkeypatch.setattr(ops, name, wrap(name))
    Q = _queries(X, 4, seed=11)
    r = float(np.quantile(dist_one_to_many(Q[0], X, "l2"), 0.01))
    bx.range_query_batch(Q, r)
    assert calls["pdist"] >= 1          # query→pivot distances
    assert calls["rankeval"] >= 1       # all rank models, one launch
    assert calls["range_filter"] >= 1   # fused refinement
    before = dict(calls)
    bx.knn_query_batch(Q, 3)
    assert calls["pdist"] > before["pdist"]
    assert calls["rankeval"] > before["rankeval"]
