"""Observability layer (DESIGN.md §11): registry, tracing, profiles,
exporters.

Covers the obs acceptance properties: the registry is thread-safe and
its histograms are bounded reservoirs whose percentiles match numpy
bit-for-bit below the cap; ``REPRO_OBS=off`` makes every recording
helper a no-op that allocates nothing (tracemalloc-pinned); every
served batch — resident, paged, sharded — yields a *complete*
``QueryProfile``; the exporters emit well-formed Prometheus text and a
Perfetto-loadable Chrome trace; the frontend's metric memory stays
bounded under a 10k-request soak (the unbounded-list regression this
PR removed); and the buffer-pool + prefetch counters sum to total page
reads (``misses + prefetch_reads == page_reads``).
"""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import LIMSIndex, MetricSpace
from repro.core.executor import QueryExecutor, ShardedExecutor
from repro.core.metrics import dist_one_to_many
from repro.core.snapshot import LIMSSnapshot
from repro.obs import registry as _reg
from repro.obs.registry import Counter, Histogram, MetricsRegistry
from repro.obs.trace import _NULL, span

N, D = 900, 5


@pytest.fixture(autouse=True)
def _restore_mode():
    """Tests flip the cached obs mode; put it back for the rest of the
    suite (metric *values* are process-global and harmless to leave)."""
    before = obs.obs_mode()
    yield
    obs.configure(before)


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    from repro.data.datasets import gauss_mix
    X = gauss_mix(N, D, seed=7)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=5, m=3, n_rings=8)
    snap = LIMSSnapshot.build(ix)
    path = str(tmp_path_factory.mktemp("obs-store"))
    snap.spill(path)
    rng = np.random.default_rng(3)
    Q = X[rng.choice(N, 8)] + rng.normal(0, 0.004, (8, D))
    rs = np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), 0.02))
                   for q in Q])
    return X, ix, snap, path, Q, rs


# ---------------------------------------------------------------- registry
def test_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry()
    c = reg.counter("a.b")
    assert reg.counter("a.b") is c
    with pytest.raises(TypeError):
        reg.gauge("a.b")
    reg.histogram("a.h").observe(2.0)
    g = reg.gauge("a.g")
    g.set(3.5)
    snap = reg.snapshot()
    assert snap["a.b"] == 0 and snap["a.g"] == 3.5
    assert snap["a.h"]["count"] == 1
    reg.reset()
    assert reg.snapshot()["a.h"]["count"] == 0
    assert reg.counter("a.b") is c          # reset keeps registrations


def test_registry_thread_safety():
    """Concurrent increments and observations lose nothing: counts and
    sums are exact (each metric's lock), and get-or-create under racing
    threads yields one object per name."""
    reg = MetricsRegistry()
    n_threads, per = 8, 2000

    def worker(i: int) -> None:
        for j in range(per):
            reg.counter("t.count").inc()
            reg.histogram("t.hist", cap=64).observe(float(j))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("t.count").value == n_threads * per
    h = reg.histogram("t.hist")
    assert h.count == n_threads * per
    assert h.sum == pytest.approx(n_threads * sum(range(per)))
    assert len(h) == 64                     # reservoir stayed bounded
    assert h.min == 0.0 and h.max == float(per - 1)


def test_histogram_percentiles_match_numpy():
    """Below the cap the reservoir holds everything, so percentiles are
    exact — bit-identical to numpy's default linear interpolation."""
    rng = np.random.default_rng(11)
    xs = rng.lognormal(0.0, 1.5, 500)
    h = Histogram("pct.test", cap=1024)
    for x in xs:
        h.observe(float(x))
    for p in (0.0, 25.0, 50.0, 90.0, 99.0, 100.0):
        assert h.percentile(p) == float(np.percentile(xs, p))
    assert h.mean == pytest.approx(float(np.mean(xs)))


def test_histogram_reservoir_bounded_stats_exact():
    """Past the cap, memory stays O(cap) while count/sum/min/max remain
    exact and percentiles stay plausible (uniform reservoir sample)."""
    h = Histogram("res.test", cap=128)
    n = 10_000
    for i in range(n):
        h.observe(float(i))
    assert len(h) == 128 and h.count == n
    assert h.min == 0.0 and h.max == float(n - 1)
    assert h.sum == pytest.approx(n * (n - 1) / 2)
    p50 = h.percentile(50)
    assert 0.2 * n < p50 < 0.8 * n          # sampled median is sane


def test_mode_gating_and_configure():
    obs.configure("off")
    assert not _reg.enabled() and not _reg.tracing()
    assert span("x") is _NULL               # shared no-op singleton
    obs.configure("trace")
    assert _reg.enabled() and _reg.tracing()
    assert span("x") is not _NULL
    with pytest.raises(ValueError):
        obs.configure("loud")


def test_off_mode_records_and_allocates_nothing():
    """The disabled path is one string compare: no metric mutation and
    zero allocations attributable to the obs modules (the contract that
    makes default-on instrumentation of hot paths acceptable)."""
    import time
    import tracemalloc

    import repro.obs.registry as regmod
    import repro.obs.trace as trmod
    from repro.storage.prefetch import drain_queue

    def quiesce():
        # background work from earlier tests runs obs calls off the main
        # thread (the prefetch worker pins pages -> set_gauge; transient
        # engine-refresh threads count refreshes), and a frame allocated
        # there is charged to registry.py: wait for transient threads to
        # exit, then drain the shared prefetch worker's queue
        deadline = time.monotonic() + 30.0
        persistent = {"MainThread", "lims-page-prefetch"}
        while time.monotonic() < deadline:
            if all(t.name in persistent for t in threading.enumerate()):
                break
            time.sleep(0.05)
        assert drain_queue(timeout=30.0)

    quiesce()
    obs.configure("on")
    obs.count("offtest.c")                  # materialize the metrics
    obs.observe("offtest.h", 1.0)
    before = obs.REGISTRY.counter("offtest.c").value
    obs.configure("off")
    for attempt in range(5):
        for _ in range(50):                 # settle frame freelists etc.
            obs.count("offtest.c")
            obs.observe("offtest.h", 2.0)
            obs.set_gauge("offtest.g", 3.0)
            with span("offtest.span"):
                pass
        tracemalloc.start()
        try:
            for _ in range(200):
                obs.count("offtest.c")
                obs.observe("offtest.h", 2.0)
                obs.set_gauge("offtest.g", 3.0)
                with span("offtest.span"):
                    pass
            snap = tracemalloc.take_snapshot()
        finally:
            tracemalloc.stop()
        obs_alloc = sum(
            st.size for st in snap.statistics("filename")
            if st.traceback[0].filename in (regmod.__file__, trmod.__file__))
        if obs_alloc == 0:
            break
        quiesce()                           # a straggler landed mid-window
    assert obs_alloc == 0
    assert obs.REGISTRY.counter("offtest.c").value == before
    assert obs.REGISTRY.histogram("offtest.h").count == 1


def test_trace_ring_is_bounded(monkeypatch):
    monkeypatch.setenv("REPRO_OBS_TRACE_CAP", "50")
    obs.clear_trace()                       # recreate the ring at cap 50
    obs.configure("trace")
    for i in range(200):
        with span("ring.test"):
            pass
    assert obs.trace_len() == 50
    obs.clear_trace()
    monkeypatch.delenv("REPRO_OBS_TRACE_CAP")


# ---------------------------------------------------------------- profiles
def _assert_complete(p, *, kind, backend, storage):
    assert p is not None, "no QueryProfile was recorded"
    assert p.missing() == [], f"incomplete profile: {p.missing()}"
    assert p.kind == kind and p.backend == backend and p.storage == storage
    assert p.batch > 0 and p.rounds >= 1 and p.n_clusters > 0
    assert p.total_s > 0
    assert all(v >= 0 for v in p.stages.values())
    if storage == "resident":
        assert p.pages == 0 and p.pages_per_query == 0
    else:
        assert p.pages > 0 and p.pages_per_query > 0


def test_profile_resident_complete(setup):
    X, ix, snap, path, Q, rs = setup
    obs.configure("on")
    ex = QueryExecutor(snap)
    ex.knn_query_batch(Q, 5)
    _assert_complete(ex.last_profile, kind="knn", backend="resident",
                     storage="resident")
    assert ex.last_profile.k == 5
    assert ex.last_profile.candidates_per_query >= 5
    ex.range_query_batch(Q, rs)
    _assert_complete(ex.last_profile, kind="range", backend="resident",
                     storage="resident")
    assert ex.last_profile.k is None
    assert obs.last_profile() is ex.last_profile


def test_profile_paged_complete(setup):
    X, ix, snap, path, Q, rs = setup
    obs.configure("on")
    paged = LIMSSnapshot.load(path, store=True, cache_pages=8)
    ex = QueryExecutor(paged)
    ex.knn_query_batch(Q, 5)
    _assert_complete(ex.last_profile, kind="knn", backend="paged",
                     storage="paged")
    ex.range_query_batch(Q, rs)
    _assert_complete(ex.last_profile, kind="range", backend="paged",
                     storage="paged")


def test_profile_sharded_complete(setup):
    import jax
    X, ix, snap, path, Q, rs = setup
    obs.configure("on")
    sx = ShardedExecutor(snap)
    sx.knn_query_batch(Q, 5)
    _assert_complete(sx.last_profile, kind="knn", backend="resident",
                     storage="resident")
    assert sx.last_profile.n_shards == jax.device_count()


def test_profile_off_mode_records_nothing(setup):
    X, ix, snap, path, Q, rs = setup
    obs.configure("on")
    ex = QueryExecutor(snap)
    ex.knn_query_batch(Q, 3)
    obs.clear_profiles()
    obs.configure("off")
    ex.knn_query_batch(Q, 3)
    assert obs.last_profile() is None


def test_profile_ring_bounded(setup):
    from repro.obs.profile import profile_cap
    X, ix, snap, path, Q, rs = setup
    obs.configure("on")
    obs.clear_profiles()
    ex = QueryExecutor(snap)
    for _ in range(3):
        ex.knn_query_batch(Q[:2], 3)
    assert 0 < len(obs.profiles()) <= profile_cap()
    assert obs.profiles(1) == [obs.last_profile()]


# ---------------------------------------------------------------- exporters
def test_prometheus_text_format():
    obs.configure("on")
    reg = obs.REGISTRY
    reg.counter("exp.count").inc(7)
    reg.gauge("exp.gauge").set(2.5)
    h = reg.histogram("exp.hist")
    for x in range(10):
        h.observe(float(x))
    text = obs.prometheus_text()
    lines = text.splitlines()
    assert text.endswith("\n")
    assert "# TYPE lims_exp_count counter" in lines
    assert "lims_exp_count 7" in lines
    assert "# TYPE lims_exp_gauge gauge" in lines
    assert "lims_exp_gauge 2.5" in lines
    assert "# TYPE lims_exp_hist summary" in lines
    assert 'lims_exp_hist{quantile="0.5"} 4.5' in lines
    assert "lims_exp_hist_count 10" in lines
    assert "lims_exp_hist_sum 45" in lines
    # every non-comment line is `name[{labels}] value` with a legal name
    for ln in lines:
        if ln.startswith("#"):
            continue
        name = ln.split("{")[0].split(" ")[0]
        assert name.startswith("lims_")
        assert all(c.isalnum() or c == "_" for c in name)


def test_chrome_trace_structure_and_file(tmp_path):
    obs.configure("trace")
    obs.clear_trace()
    with span("trace.outer", {"B": 4}):
        with span("trace.inner"):
            pass
    doc = obs.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert meta and meta[0]["name"] == "thread_name"
    assert {e["name"] for e in xs} == {"trace.outer", "trace.inner"}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["cat"] == "lims"
    outer = next(e for e in xs if e["name"] == "trace.outer")
    assert outer["args"] == {"B": 4}
    # the file a Perfetto load would open: valid JSON, same events
    path = str(tmp_path / "trace.json")
    n = obs.write_chrome_trace(path)
    assert n == 2
    with open(path) as f:
        assert json.load(f)["traceEvents"]
    obs.clear_trace()


def test_json_snapshot_round_trips(setup):
    X, ix, snap, path, Q, rs = setup
    obs.configure("on")
    QueryExecutor(snap).knn_query_batch(Q, 3)
    doc = obs.json_snapshot(n_profiles=4)
    assert doc["mode"] == "on"
    assert doc["profiles"] and doc["profiles"][-1]["kind"] == "knn"
    assert "profile.batches" in doc["metrics"]
    json.dumps(doc)                         # fully JSON-serializable


def test_report_demo_smoke(tmp_path):
    """The packaged reporter end-to-end: demo workload, all three
    exports, complete profile asserted inside."""
    from repro.obs import report
    out_json = str(tmp_path / "obs.json")
    out_prom = str(tmp_path / "obs.prom")
    out_trace = str(tmp_path / "obs.trace.json")
    rc = report.main(["--demo", "--json", out_json, "--prom", out_prom,
                      "--trace", out_trace])
    assert rc == 0
    with open(out_json) as f:
        doc = json.load(f)
    assert doc["profiles"]
    with open(out_prom) as f:
        assert "lims_" in f.read()
    with open(out_trace) as f:
        assert json.load(f)["traceEvents"]


# ----------------------------------------------------- frontend boundedness
def test_frontend_soak_memory_bounded(setup):
    """10k requests' worth of metric accounting holds O(reservoir)
    state — the unbounded `_waits`/`_batch_sizes` lists this PR removed
    would hold 10k floats here."""
    from repro.serving import ServingFrontend
    X, ix, snap, path, Q, rs = setup
    obs.configure("on")
    fe = ServingFrontend(QueryExecutor(snap), max_batch=8, slo_ms=1.0)
    try:
        fe.knn_query(Q[0], 3)               # one real served request
        # …then the soak drives the per-batch accounting path directly
        # (serving 10k real queries through interpret-mode kernels is
        # minutes of test time for the same metric-path coverage)
        for i in range(9_999):
            fe._obs_record(1, [1e-4])
        m = fe.metrics()
        assert m["batches"] == 10_000
        cap = fe._wait_hist.cap
        assert len(fe._wait_hist) <= cap
        assert len(fe._size_hist) <= cap
        assert m["queue_wait_ms_p50"] >= 0
        # the registry mirrors are bounded the same way
        assert len(obs.REGISTRY.histogram("frontend.queue_wait_s")) <= \
            obs.REGISTRY.histogram("frontend.queue_wait_s").cap
    finally:
        fe.close()


# ------------------------------------------------------- storage invariant
def test_prefetch_reads_sum_to_page_reads(setup):
    """Speculative (record=False) reads are no longer invisible: the
    buffer-pool misses plus the explicit prefetch_reads counter equal
    every page actually read into the cache."""
    X, ix, snap, path, Q, rs = setup
    obs.configure("on")
    paged = LIMSSnapshot.load(path, store=True, cache_pages=64)
    st = paged.store
    st.cache.clear()
    st.stats.reset()
    total = st.manifest.total_pages
    demand = np.arange(0, min(4, total), dtype=np.int64)
    spec = np.arange(0, min(8, total), dtype=np.int64)
    st.fetch_pages(demand)                  # demand path: misses
    st.fetch_pages(spec, record=False)      # speculative: prefetch_reads
    st.fetch_pages(demand)                  # warm: hits, no reads
    s = st.stats.snapshot()
    assert s["misses"] == len(demand)
    assert s["prefetch_reads"] == len(spec) - len(demand)
    assert s["page_reads"] == s["misses"] + s["prefetch_reads"]
    # and the set actually resident is exactly what was read
    assert s["page_reads"] == len(set(spec) | set(demand))
