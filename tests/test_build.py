"""Device-builder correctness (repro.build; DESIGN.md §6).

The acceptance property: an index built through the device pipeline
(``LIMSIndex(backend="device")``) materializes host structures bitwise
equal to the numpy build (same clustering, pivots, ring boundaries)
and answers range/kNN queries bit-identically — through the host path,
through ``QueryExecutor`` over an emitted snapshot, and through the
sharded executor (the 4-fake-device CI leg runs the real ``shard_map``
path over a device-built snapshot).

The hypothesis property test sweeps metrics and seeds; the device
retrain test covers the ``ServingEngine`` routing.
"""
import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st

from repro.build import batched_chebfit, build_snapshot, device_build
from repro.core import (LIMSIndex, MetricSpace, LIMSSnapshot, QueryExecutor,
                        ShardedExecutor, ServingEngine)
from repro.core.metrics import dist_one_to_many
from repro.data.datasets import gauss_mix

N, D = 1500, 6


@pytest.fixture(scope="module")
def pair():
    X = gauss_mix(N, D, seed=11)
    host = LIMSIndex(MetricSpace(X, "l2"), n_clusters=6, m=3, n_rings=10)
    dev = LIMSIndex(MetricSpace(X, "l2"), n_clusters=6, m=3, n_rings=10,
                    backend="device")
    return X, host, dev


def _queries(X, n_q, seed=2, scale=0.004):
    rng = np.random.default_rng(seed)
    return X[rng.choice(len(X), n_q)] + rng.normal(0, scale,
                                                   (n_q, X.shape[1]))


def _radii(X, Q, metric="l2", sel=0.02):
    return np.array([float(np.quantile(dist_one_to_many(q, X, metric), sel))
                     for q in Q])


# ----------------------------------------------------------- structure
def test_device_build_matches_host_structures(pair):
    """Clustering, pivots, ring boundaries and storage order must come
    out bitwise equal: the sweeps pick the same centers/pivots and the
    materialization recomputes the same exact f64 columns."""
    X, host, dev = pair
    assert dev.K == host.K
    assert np.array_equal(host.clustering.center_idx,
                          dev.clustering.center_idx)
    assert [len(m) for m in host.clustering.members] == \
           [len(m) for m in dev.clustering.members]
    assert np.array_equal(host.clustering.assign, dev.clustering.assign)
    for h, d in zip(host.clusters, dev.clusters):
        assert np.array_equal(h.pivot_idx, d.pivot_idx)
        assert np.array_equal(h.mapping.d_sorted, d.mapping.d_sorted)
        assert np.array_equal(h.mapping.rids, d.mapping.rids)
        assert np.array_equal(h.mapping.lims_sorted, d.mapping.lims_sorted)
        assert np.array_equal(h.mapping.dist_min, d.mapping.dist_min)
        assert np.array_equal(h.mapping.dist_max, d.mapping.dist_max)
        assert np.array_equal(h.store_ids, d.store_ids)
        # device-fit models are drop-in PolyRankModels over the same span
        for hm, dm in zip(h.rank_models, d.rank_models):
            assert dm.n == hm.n
    assert host.default_delta_r == dev.default_delta_r


def test_device_build_query_bit_identity(pair):
    """Acceptance criterion: range and kNN results bit-identical between
    the host-built and device-built index, on the host path and through
    ``QueryExecutor`` over the emitted snapshots."""
    X, host, dev = pair
    Q = _queries(X, 8)
    rs = _radii(X, Q)
    for q, r in zip(Q, rs):
        hi_, hd_, _ = host.range_query(q, r)
        di_, dd_, _ = dev.range_query(q, r)
        assert np.array_equal(hi_, di_) and np.array_equal(hd_, dd_)
        hk_i, hk_d, _ = host.knn_query(q, 6)
        dk_i, dk_d, _ = dev.knn_query(q, 6)
        assert np.array_equal(hk_i, dk_i) and np.array_equal(hk_d, dk_d)
        # and against brute force (exactness, not just agreement)
        d_all = dist_one_to_many(q, X, "l2")
        assert set(map(int, di_)) == set(np.where(d_all <= r)[0].tolist())
    eh = QueryExecutor(LIMSSnapshot.build(host))
    ed = QueryExecutor(LIMSSnapshot.build(dev))
    for (ai, ad), (bi, bd) in zip(eh.range_query_batch(Q, rs),
                                  ed.range_query_batch(Q, rs)):
        assert np.array_equal(ai, bi) and np.array_equal(ad, bd)
    ka, da = eh.knn_query_batch(Q, 6)
    kb, db = ed.knn_query_batch(Q, 6)
    assert np.array_equal(ka, kb) and np.array_equal(da, db)


def test_device_snapshot_serves_sharded(pair):
    """A device-built snapshot must serve through ``ShardedExecutor``
    (the real shard_map path under the 4-fake-device CI leg) with
    results bit-identical to the host index."""
    X, host, dev = pair
    snap = LIMSSnapshot.build(dev)
    sx = ShardedExecutor(snap)
    assert sx.n_shards == jax.device_count()
    Q = _queries(X, 6, seed=5)
    rs = _radii(X, Q)
    for (ids, ds), q, r in zip(sx.range_query_batch(Q, rs), Q, rs):
        h_ids, h_ds, _ = host.range_query(q, r)
        assert set(map(int, ids)) == set(map(int, h_ids))
        np.testing.assert_allclose(np.sort(ds), np.sort(h_ds), atol=0)
    ids, ds = sx.knn_query_batch(Q, 5)
    for b, q in enumerate(Q):
        _, h_ds, _ = host.knn_query(q, 5)
        np.testing.assert_allclose(np.sort(ds[b]), np.sort(h_ds), atol=0)


# ------------------------------------------------------------- property
@settings(max_examples=8, deadline=None, derandomize=True)
@given(metric=st.sampled_from(["l2", "l1", "linf"]),
       n=st.sampled_from([400, 700]),
       k_clusters=st.sampled_from([4, 6]),
       seed=st.integers(0, 200),
       sel=st.floats(0.005, 0.1))
def test_build_equivalence_property(metric, n, k_clusters, seed, sel):
    """Satellite: across metrics and seeds the device builder and the
    host numpy build agree on cluster assignment sizes, ring boundaries
    and query results (range + kNN bit-identity through QueryExecutor
    for the L2 device serving path)."""
    X = gauss_mix(n, 5, seed=seed)
    host = LIMSIndex(MetricSpace(X, metric), n_clusters=k_clusters, m=3,
                     n_rings=8, seed=seed)
    dev = LIMSIndex(MetricSpace(X, metric), n_clusters=k_clusters, m=3,
                    n_rings=8, seed=seed, backend="device")
    assert [len(mm) for mm in host.clustering.members] == \
           [len(mm) for mm in dev.clustering.members]
    for h, d in zip(host.clusters, dev.clusters):
        assert np.array_equal(h.mapping.rids, d.mapping.rids)
        assert np.array_equal(h.mapping.dist_min, d.mapping.dist_min)
        assert np.array_equal(h.mapping.dist_max, d.mapping.dist_max)
    Q = _queries(X, 4, seed=seed + 1)
    rs = _radii(X, Q, metric, sel)
    for q, r in zip(Q, rs):
        hi_, hd_, _ = host.range_query(q, r)
        di_, dd_, _ = dev.range_query(q, r)
        assert np.array_equal(hi_, di_) and np.array_equal(hd_, dd_)
    if metric == "l2":
        a = QueryExecutor(LIMSSnapshot.build(host)).range_query_batch(Q, rs)
        b = QueryExecutor(LIMSSnapshot.build(dev)).range_query_batch(Q, rs)
        for (ai, ad), (bi, bd) in zip(a, b):
            assert np.array_equal(ai, bi) and np.array_equal(ad, bd)
        ka, da = QueryExecutor(LIMSSnapshot.build(host)).knn_query_batch(Q, 4)
        kb, db = QueryExecutor(LIMSSnapshot.build(dev)).knn_query_batch(Q, 4)
        assert np.array_equal(ka, kb) and np.array_equal(da, db)


# ------------------------------------------------------ serving retrain
def test_serving_engine_routes_retrain_through_device_builder():
    """ServingEngine routes retrains through the device builder (by
    default wherever the kernels compile; pinned here for the
    CPU-interpret CI); retrain + refresh must fold buffers/tombstones
    exactly, matching the host index it mirrors."""
    rng = np.random.default_rng(0)
    X = gauss_mix(900, D, seed=5)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    se = ServingEngine(ix, refresh_every=0,        # manual refresh only
                       build_backend="device")
    assert se._build_backend == "device"
    # the default is the measured-crossover router: host for small
    # clusters, device past RETRAIN_AUTO_ROWS on compiled vector lanes
    se_auto = ServingEngine(ix, refresh_every=0)
    assert se_auto._build_backend == "auto"
    se_auto.retrain_cluster(0)
    # these clusters sit far below the RETRAIN_AUTO_ROWS crossover (and
    # interpret lanes route host regardless), so auto must pick host
    assert ix.last_retrain_backend == "host"
    new_rows = X[rng.choice(900, 12)] + rng.normal(0, 0.02, (12, D))
    gids = [se.insert(r) for r in new_rows]
    assert se.delete(X[7]) == 1
    for c in range(ix.K):
        se.retrain_cluster(c)                      # device-routed
    se.refresh()
    for ci in ix.clusters:                         # buffers all folded in
        assert len(ci.buf_ids) == 0
    all_rows = np.concatenate([X, new_rows])
    Q = _queries(X, 5, seed=3)
    rs = _radii(all_rows, Q)
    for (ids, ds), q, r in zip(se.range_query_batch(Q, rs), Q, rs):
        d_all = dist_one_to_many(q, all_rows, "l2")
        truth = set(np.where(d_all <= r)[0].tolist()) - {7}
        assert set(map(int, ids)) == truth
    hit, _ = se.range_query(new_rows[2], 1e-9)
    assert gids[2] in set(map(int, hit))


def test_build_snapshot_emits_serving_snapshot():
    X = gauss_mix(600, D, seed=9)
    snap, index = build_snapshot(MetricSpace(X, "l2"), n_clusters=4, m=2,
                                 n_rings=8)
    assert isinstance(snap, LIMSSnapshot)
    assert snap.live == index.live_count() == 600
    q = X[17] + 1e-7
    ids, ds = QueryExecutor(snap).range_query(q, 1e-5)
    assert 17 in set(map(int, ids))


def test_device_kmeans_backend_is_exact():
    """kMeans clustering on device: different partition than the host's
    f64 Lloyd loop is allowed — exactness of the materialized index is
    not (every bound is recomputed exactly)."""
    X = gauss_mix(800, 4, seed=3)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=5, m=2, n_rings=8,
                   backend="device", clusterer="kmeans")
    rng = np.random.default_rng(1)
    for qi in rng.choice(800, 4):
        q = X[qi] + rng.normal(0, 0.004, 4)
        d = dist_one_to_many(q, X, "l2")
        r = float(np.quantile(d, 0.02))
        ids, _, _ = ix.range_query(q, r)
        assert set(map(int, ids)) == set(np.where(d <= r)[0].tolist())


# ------------------------------------------------------------ components
def test_batched_chebfit_degenerate_groups():
    """The one-launch fit must survive constant, single-element and
    empty columns (device mirror of the hardened host fit)."""
    n_max = 64
    cols = np.zeros((4, n_max), np.float32)
    rng = np.random.default_rng(0)
    cols[0] = np.sort(rng.gamma(2.0, 1.0, n_max))     # healthy
    cols[1] = 3.25                                     # constant column
    cols[2, 0] = 1.5                                   # single element
    counts = np.array([n_max, n_max, 1, 0])
    coef, lo, hi, n, dg, err = batched_chebfit(
        cols, counts, np.full(4, 8), 8)
    coef = np.asarray(coef)
    assert np.all(np.isfinite(coef))
    # healthy fit predicts ranks decently
    t = np.clip((cols[0] - float(lo[0])) / (float(hi[0]) - float(lo[0]))
                * 2 - 1, -1, 1)
    pred = np.polynomial.chebyshev.chebval(t, coef[0])
    assert np.abs(pred - np.arange(n_max)).max() < n_max / 4
    # degenerate groups: constant model over a non-empty span
    assert not coef[1].any() and float(hi[1]) > float(lo[1])
    assert not coef[2].any() and float(hi[2]) > float(lo[2])
    assert not coef[3].any()
    assert float(err[3]) == 0.0
    # error estimates are bounded by n
    assert np.all(np.asarray(err) <= np.asarray(n) + 1e-6)


def test_device_build_rejects_generic_metrics():
    from repro.data.datasets import signature
    sig = signature(3, 40, seed=1)
    with pytest.raises(ValueError):
        device_build(MetricSpace(sig, "edit"), 3, m=2)
