"""Plan/execute query path (DESIGN.md §8).

Covers the acceptance properties of the CandidatePlan refactor: exactly
one plan construction per query batch shared by both execution backends;
the plan (radii, certified masks, cluster routing) is bit-identical
resident vs paged, single-device vs sharded (the 4-fake-device CI legs
run the real ``shard_map`` path), and unchanged across a store writeback
manifest swap; the unified path's range and kNN results are pinned
bit-identical against the pre-refactor drivers' golden outputs
(``tests/_golden_drivers.py``); and the compiled kNN loop's host-sync
counter is O(1) per batch regardless of workload.
"""
import functools
import tempfile

import numpy as np
import pytest

import _golden_drivers as golden
from _hypothesis_compat import given, settings, st

from repro.core import LIMSIndex, MetricSpace, ServingEngine
from repro.core.executor import QueryExecutor, ShardedExecutor
from repro.core.metrics import dist_one_to_many
from repro.core.snapshot import LIMSSnapshot

N, D = 1500, 6


@functools.lru_cache(maxsize=1)
def _env():
    """Shared corpus/snapshot/store + one executor per backend×sharding
    combination (module-level cache rather than a fixture so the
    hypothesis property test below stays fixture-free)."""
    from repro.data.datasets import gauss_mix
    X = gauss_mix(N, D, seed=11)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=6, m=3, n_rings=10)
    snap = LIMSSnapshot.build(ix)
    path = tempfile.mkdtemp(prefix="lims-plans-")
    snap.spill(path)
    executors = {
        "resident": QueryExecutor(snap),
        "paged": QueryExecutor(LIMSSnapshot.load(path, store=True)),
        "sharded": ShardedExecutor(snap),
        "sharded_paged": ShardedExecutor(LIMSSnapshot.load(path, store=True)),
    }
    return X, ix, snap, path, executors


@pytest.fixture(scope="module")
def setup():
    X, ix, snap, path, _ = _env()
    return X, ix, snap, path


@pytest.fixture(scope="module")
def executors():
    return _env()[4]


def _queries(X, n_q, seed=2, scale=0.004):
    rng = np.random.default_rng(seed)
    return X[rng.choice(len(X), n_q)] + rng.normal(0, scale, (n_q, D))


def _radii(X, Q, sel=0.02):
    return np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), sel))
                     for q in Q])


def _assert_plans_equal(a, b, P_ref: int, K_ref: int):
    """Plan equality modulo shard padding (padded slots must be inert)."""
    assert (a.kind, a.k, a.max_rounds, a.growth) == \
        (b.kind, b.k, b.max_rounds, b.growth)
    assert np.array_equal(a.radii, b.radii)
    assert np.array_equal(a.radius_at(3), b.radius_at(3))
    am, bm = a.mask[:, :P_ref], b.mask[:, :P_ref]
    assert np.array_equal(am, bm)
    assert not a.mask[:, P_ref:].any() and not b.mask[:, P_ref:].any()
    ar, br = a.routing[:, :K_ref], b.routing[:, :K_ref]
    assert np.array_equal(ar, br)
    assert not a.routing[:, K_ref:].any() and not b.routing[:, K_ref:].any()


# ----------------------------------------------------- plan construction
def test_one_plan_construction_per_batch(executors, setup):
    """Acceptance criterion: exactly one CandidatePlan per query batch,
    whichever backend executes it."""
    X = setup[0]
    Q = _queries(X, 5, seed=3)
    rs = _radii(X, Q)
    for name in ("resident", "paged"):
        ex = executors[name]
        before = ex.planner.built
        ex.range_query_batch(Q, rs)
        assert ex.planner.built == before + 1, name
        ex.knn_query_batch(Q, 5)
        assert ex.planner.built == before + 2, name


# ------------------------------------------------------- plan identity
@settings(max_examples=6, deadline=None, derandomize=True)
@given(qseed=st.integers(0, 1000), sel=st.sampled_from([0.005, 0.02, 0.06]),
       k=st.sampled_from([1, 4, 9]))
def test_plan_identical_across_backends_and_shards(qseed, sel, k):
    """The hypothesis property: a batch's CandidatePlan — radii, mask,
    routing, schedule — is identical resident vs paged and single-device
    vs sharded (shard padding contributes only inert slots).  The plan
    is metadata-only, so moving rows to disk or across devices cannot
    change it."""
    X, ix, snap, path, executors = _env()
    Q = _queries(X, 4, seed=qseed)
    rs = _radii(X, Q, sel=sel)
    ref = executors["resident"]
    P_ref, K_ref = snap.n_slots, snap.K
    plans_r = {n: e.planner.plan_range(Q, rs)
               for n, e in executors.items()}
    plans_k = {n: e.planner.plan_knn(Q, k, 64)
               for n, e in executors.items()}
    for n in executors:
        _assert_plans_equal(plans_r["resident"], plans_r[n], P_ref, K_ref)
        _assert_plans_equal(plans_k["resident"], plans_k[n], P_ref, K_ref)
    assert ref.planner.built >= 2


def test_plan_unchanged_across_writeback_swap(tmp_path):
    """A store writeback (retrain → new extents, atomic manifest swap)
    must not change the plans of an executor bound to the previous
    generation: its snapshot metadata and StoreView are frozen."""
    from repro.data.datasets import gauss_mix
    X = gauss_mix(900, D, seed=4)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=4, m=3, n_rings=8)
    path = str(tmp_path / "store")
    se = ServingEngine(ix, refresh_every=0, storage="paged",
                       storage_path=path)
    old_ex = se.executor
    Q = _queries(X, 4, seed=9)
    rs = _radii(X, Q)
    pr0 = old_ex.planner.plan_range(Q, rs)
    pk0 = old_ex.planner.plan_knn(Q, 5, 64)
    m0, r0, km0 = pr0.mask.copy(), pr0.routing.copy(), pk0.mask.copy()
    for c in range(ix.K):
        se.retrain_cluster(c)
    se.refresh()                       # new generation, appended extents
    assert se.executor is not old_ex
    pr1 = old_ex.planner.plan_range(Q, rs)
    pk1 = old_ex.planner.plan_knn(Q, 5, 64)
    assert np.array_equal(pr0.radii, pr1.radii)
    assert np.array_equal(pk0.radii, pk1.radii)
    assert np.array_equal(m0, pr1.mask)
    assert np.array_equal(r0, pr1.routing)
    assert np.array_equal(km0, pk1.mask)


# ---------------------------------------------------------- golden pins
def test_unified_path_matches_golden_drivers(executors, setup):
    """The refactor's bit-identity pin: range and kNN through the
    unified plan/execute path equal the four pre-refactor drivers'
    outputs exactly, resident AND paged (both CI device legs run this —
    the executors fixture shards where devices allow)."""
    X = setup[0]
    Q = _queries(X, 6, seed=5)
    rs = _radii(X, Q)
    rs[0] = 1e-12                       # provably empty query
    mem, pag = executors["resident"], executors["paged"]
    new_r = mem.range_query_batch(Q, rs)
    assert len(new_r[0][0]) == 0
    for ref in (golden.range_resident(mem, Q, rs),
                golden.range_store(pag, Q, rs),
                pag.range_query_batch(Q, rs)):
        for (ai, ad), (bi, bd) in zip(new_r, ref):
            assert np.array_equal(ai, bi)
            assert np.array_equal(ad, bd)
    for k in (6, N + 99):               # incl. k > live clamp
        ids_n, ds_n = mem.knn_query_batch(Q, k)
        for ref in (golden.knn_resident(mem, Q, k),
                    golden.knn_store(pag, Q, k),
                    pag.knn_query_batch(Q, k),
                    executors["sharded"].knn_query_batch(Q, k),
                    executors["sharded_paged"].knn_query_batch(Q, k)):
            assert np.array_equal(ids_n, ref[0])
            assert np.array_equal(ds_n, ref[1])


# --------------------------------------------------- host-sync counter
def test_knn_host_syncs_constant_in_compiled_path(executors, setup,
                                                  monkeypatch):
    """Acceptance criterion: the device-resident kNN *loop* costs O(1)
    host syncs per batch — one for the plan's seed radii, one for the
    loop's certified masks — independent of workload (k, batch size,
    rounds).  The sharded executor must hold the same bound: its loop
    keeps every per-round reduction a collective.  Pinned to the
    compiled driver: ``REPRO_KNN_DRIVER=auto`` picks the host-driven
    vectorized-round driver on single-device XLA-CPU interpret (per
    round, eager dispatch beats the jitted loop's slow lowerings —
    see the driver test below), which syncs per round by design."""
    monkeypatch.setenv("REPRO_KNN_DRIVER", "loop")
    X = setup[0]
    for name in ("resident", "sharded"):
        ex = executors[name]
        syncs = []
        for k, nq in ((3, 4), (11, 8), (64, 2)):
            ex.knn_query_batch(_queries(X, nq, seed=k), k)
            assert ex.last_knn["backend"] == "resident"
            assert ex.last_knn["driver"] == "loop"
            assert ex.last_knn["rounds"] >= 1
            syncs.append(ex.last_knn["host_syncs"])
        assert len(set(syncs)) == 1, (name, syncs)
        assert syncs[0] <= 3, (name, syncs)
    # the paged backend is host-driven by design; it reports its rounds
    pag = executors["paged"]
    pag.knn_query_batch(_queries(X, 4, seed=1), 6)
    assert pag.last_knn["backend"] == "paged"
    assert pag.last_knn["rounds"] >= 1


def test_knn_rounds_driver_matches_loop_driver(executors, setup,
                                               monkeypatch):
    """The interpret-mode vectorized-round driver (the PR-5 q/s
    regression fix) executes the same certified schedule as the
    compiled ``lax.while_loop`` — results bit-identical, driver
    surfaced in ``last_knn``."""
    X = setup[0]
    ex = executors["resident"]
    Q = _queries(X, 5, seed=17)
    for k in (4, 23):
        monkeypatch.setenv("REPRO_KNN_DRIVER", "loop")
        ids_l, ds_l = ex.knn_query_batch(Q, k)
        assert ex.last_knn["driver"] == "loop"
        monkeypatch.setenv("REPRO_KNN_DRIVER", "rounds")
        ids_r, ds_r = ex.knn_query_batch(Q, k)
        assert ex.last_knn["driver"] == "rounds"
        assert ex.last_knn["host_syncs"] >= ex.last_knn["rounds"]
        assert np.array_equal(ids_l, ids_r)
        assert np.array_equal(ds_l, ds_r)
