"""LIMS correctness: hypothesis property tests for exactness (range/kNN/
point vs brute force) across metrics and parameters, plus component
invariants (rings, LIMS-value order, rank models, search correction,
updates, K-selection)."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (LIMSIndex, MetricSpace, PolyRankModel, build_mapping,
                        exponential_search, lims_value)
from repro.core.metrics import dist_one_to_many
from repro.core.rankmodel import binary_search
from repro.data.datasets import gauss_mix, signature, skewed


def brute_range(sp, q, r):
    d = dist_one_to_many(q, sp.data, sp.metric)
    return set(np.where(d <= r)[0].tolist()), d


# ------------------------------------------------------------- exactness
@settings(max_examples=12, deadline=None)
@given(n=st.integers(300, 1500),
       d=st.integers(2, 12),
       metric=st.sampled_from(["l2", "l1", "linf"]),
       k_clusters=st.integers(2, 24),
       m=st.integers(1, 4),
       n_rings=st.integers(2, 30),
       sel=st.floats(0.001, 0.2),
       seed=st.integers(0, 10_000))
def test_range_query_exact(n, d, metric, k_clusters, m, n_rings, sel, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)) ** 3          # heavy-tailed, clustered-ish
    sp = MetricSpace(X, metric)
    ix = LIMSIndex(sp, n_clusters=k_clusters, m=m, n_rings=n_rings,
                   seed=seed)
    q = X[rng.integers(n)] + rng.normal(0, 0.1, d)
    truth, dists = brute_range(sp, q, r := float(np.quantile(dists_q :=
                               dist_one_to_many(q, X, metric), sel)))
    ids, ds, st_ = ix.range_query(q, r)
    assert set(int(i) for i in ids) == truth
    # returned distances are the true distances
    for i, dd in zip(ids, ds):
        assert abs(dd - dists_q[int(i)]) < 1e-9


@settings(max_examples=10, deadline=None)
@given(n=st.integers(300, 1200),
       d=st.integers(2, 8),
       k=st.integers(1, 25),
       seed=st.integers(0, 10_000))
def test_knn_query_exact(n, d, k, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=8, m=3, n_rings=10, seed=seed)
    q = X[rng.integers(n)] + rng.normal(0, 0.05, d)
    d_all = dist_one_to_many(q, X, "l2")
    kth = np.sort(d_all)[k - 1]
    ids, ds, _ = ix.knn_query(q, k)
    assert len(ids) == k
    assert abs(np.sort(ds)[-1] - kth) < 1e-9


def test_point_query_and_edit_metric():
    sig = signature(5, 80, seed=3)
    sp = MetricSpace(sig, "edit")
    ix = LIMSIndex(sp, n_clusters=5, m=2, n_rings=8)
    # point query finds the exact string
    ids, _ = ix.point_query(sig[17])
    assert 17 in set(int(i) for i in ids)
    # range query exact under edit distance
    q = sig[42]
    d = dist_one_to_many(q, sig, "edit")
    r = 10.0
    truth = set(np.where(d <= r)[0].tolist())
    ids, ds, _ = ix.range_query(q, r)
    assert set(int(i) for i in ids) == truth


def test_insert_delete_retrain_exact():
    rng = np.random.default_rng(0)
    X = gauss_mix(2000, 6, seed=1)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=10, m=3, n_rings=10)
    new_rows = X[rng.choice(2000, 50)] + rng.normal(0, 0.02, (50, 6))
    gids = [ix.insert(r) for r in new_rows]
    all_rows = np.concatenate([X, new_rows])
    q = X[3] + 0.01
    d = dist_one_to_many(q, all_rows, "l2")
    r = float(np.quantile(d, 0.02))
    truth = set(np.where(d <= r)[0].tolist())
    ids, _, _ = ix.range_query(q, r)
    assert set(int(i) for i in ids) == truth
    # delete two objects; they must disappear
    ix.delete(X[3])
    ids, _, _ = ix.range_query(q, r)
    assert 3 not in set(int(i) for i in ids)
    truth.discard(3)
    assert set(int(i) for i in ids) == truth
    # retrain a cluster (folds buffer, drops tombstones) — still exact
    for c in range(ix.K):
        ix.retrain_cluster(c)
    ids, _, _ = ix.range_query(q, r)
    assert set(int(i) for i in ids) == truth


def test_repeated_retrain_keeps_inserted_rows():
    """Regression: a row folded in by one retrain used to be silently
    dropped by the next retrain (its gid >= space.n mapped to nothing)."""
    X = gauss_mix(1200, 5, seed=7)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=5, m=2, n_rings=8)
    p = X[10] + 0.3
    gid = ix.insert(p)
    cents = np.stack([ci.pivot_rows[0] for ci in ix.clusters])
    c = int(np.argmin(dist_one_to_many(p, cents, "l2")))
    ix.retrain_cluster(c)           # folds the buffer into the store
    ix.retrain_cluster(c)           # must keep the folded row
    ids, ds, _ = ix.range_query(p, 1e-9)
    assert gid in set(int(i) for i in ids)
    # and kNN clamps k to the live count instead of spinning forever
    ids, _, _ = ix.knn_query(X[0], 10_000)
    assert len(ids) == 1201


# ------------------------------------------------------------ components
@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 400), m=st.integers(1, 4),
       n_rings=st.integers(1, 25), seed=st.integers(0, 9999))
def test_mapping_invariants(n, m, n_rings, seed):
    rng = np.random.default_rng(seed)
    pd = np.abs(rng.normal(size=(n, m))) * rng.uniform(0.5, 5, size=m)
    mp = build_mapping(pd, n_rings)
    # ring ids in range; equal-count rings (±1 page granularity)
    assert mp.rids.min() >= 0 and mp.rids.max() < n_rings
    # lims values sorted ascending in storage order
    assert (np.diff(mp.lims_sorted) >= 0).all()
    # lexicographic consistency: lims order == tuple order (Def. 8)
    vals = lims_value(mp.rids, n_rings)
    tuples = [tuple(row) for row in mp.rids]
    order_v = np.argsort(vals, kind="stable")
    order_t = sorted(range(n), key=lambda i: (tuples[i], i))
    assert list(order_v) == order_t
    # equal distances ⇒ equal ring id (ties share ranks)
    col = pd[:, 0]
    for v in np.unique(col)[:5]:
        sel = np.where(col == v)[0]
        assert len(set(mp.rids[sel, 0].tolist())) == 1


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 2000), degree=st.integers(1, 20),
       guess_off=st.integers(-500, 500), seed=st.integers(0, 9999))
def test_expsearch_matches_searchsorted(n, degree, guess_off, seed):
    rng = np.random.default_rng(seed)
    arr = np.sort(rng.normal(size=n) ** 3)
    xs = np.concatenate([rng.choice(arr, 3), rng.normal(size=3),
                         [arr[0] - 1, arr[-1] + 1]])
    lst = arr.tolist()
    for x in xs:
        for side in ("left", "right"):
            want = int(np.searchsorted(arr, x, side=side))
            guess = int(np.clip(want + guess_off, 0, n - 1))
            got = exponential_search(lst, float(x), guess, side=side)
            assert got == want
            assert binary_search(lst, float(x), side=side) == want


def test_rank_model_degenerate_columns():
    """Regression: constant / single-element / near-constant distance
    columns must yield explicit constant-or-linear fallbacks with finite
    coefficients, never an ill-conditioned high-degree fit."""
    # single element → constant model over a non-empty span
    m1 = PolyRankModel.fit(np.array([2.5]), degree=20)
    assert m1.n == 1 and m1.hi > m1.lo
    assert np.array_equal(m1.coef, np.zeros(1))
    assert m1.predict_scalar(2.5) == 0
    # constant column → constant model, rank 0 everywhere
    mc = PolyRankModel.fit(np.full(50, 1.25), degree=20)
    assert np.array_equal(mc.coef, np.zeros(1))
    assert mc.predict_scalar(1.25) == 0
    # two distinct values among many ties → at most a linear model
    x = np.sort(np.array([0.5] * 40 + [1.5] * 24))
    m2 = PolyRankModel.fit(x, degree=20)
    assert len(m2.coef) <= 2 and np.all(np.isfinite(m2.coef))
    assert m2.predict_scalar(0.5) == 0
    assert m2.predict_scalar(1.5) == 40
    # near-constant: one outlier among ties keeps the degree tiny and
    # the prediction finite and in range
    x = np.sort(np.concatenate([np.full(200, 3.0), [3.0 + 1e-12]]))
    m3 = PolyRankModel.fit(x, degree=20)
    assert np.all(np.isfinite(m3.coef)) and len(m3.coef) <= 2
    assert 0 <= m3.predict_scalar(3.0) <= 200
    # an empty column still round-trips
    m0 = PolyRankModel.fit(np.empty(0), degree=20)
    assert m0.n == 0 and m0.predict_scalar(1.0) == 0


def test_rank_model_error_bounded():
    rng = np.random.default_rng(0)
    col = np.sort(rng.gamma(2.0, 1.0, size=5000))
    model = PolyRankModel.fit(col, degree=8)
    xs = rng.uniform(col[0], col[-1], 200)
    errs = [abs(model.predict_scalar(float(x)) -
                int(np.searchsorted(col, x))) for x in xs]
    # learned guess lands near the truth: exponential search is O(log err)
    assert np.median(errs) < 200
    # fast scalar path == vectorized predict
    for x in xs[:20]:
        assert model.predict_scalar(float(x)) == int(model.predict(x))


def test_kselect_runs_and_is_sane():
    from repro.core.kselect import select_k
    X = gauss_mix(4000, 4, n_components=8, seed=0)
    sp = MetricSpace(X, "l2")
    res = select_k(sp, [2, 4, 8, 16, 32], m=2)
    assert res.best_k in (2, 4, 8, 16, 32)
    assert (res.overhead >= 0).all()


def test_pages_beat_scan_at_low_selectivity():
    """The index's raison d'être: far fewer pages than a full scan."""
    X = gauss_mix(40_000, 8, seed=2)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=50, m=3, n_rings=20)
    from repro.baselines import LinearScan
    scan = LinearScan(sp)
    rng = np.random.default_rng(1)
    tot_l = tot_s = 0
    for qi in rng.choice(40_000, 5):
        q = X[qi] + rng.normal(0, 0.003, 8)
        d = dist_one_to_many(q, X, "l2")
        r = float(np.quantile(d, 1e-4))
        _, _, st_l = ix.range_query(q, r)
        _, _, st_s = scan.range_query(q, r)
        tot_l += st_l.pages
        tot_s += st_s.pages
    assert tot_l < tot_s / 5


def test_batched_lims_matches_host():
    """The vectorized ring-box mask engine (TPU path) returns exactly the
    host index's results — the IntervalGen ≡ rid-box-mask equivalence."""
    from repro.core.batched import BatchedLIMS
    X = gauss_mix(8000, 8, seed=4)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=16, m=3, n_rings=20)
    bx = BatchedLIMS(ix)
    rng = np.random.default_rng(2)
    for qi in rng.choice(8000, 5):
        q = X[qi] + rng.normal(0, 0.004, 8)
        d = dist_one_to_many(q, X, "l2")
        r = float(np.quantile(d, 1e-3))
        truth = set(np.where(d <= r)[0].tolist())
        ids, _ = bx.range_query(q, r)
        assert set(int(i) for i in ids) == truth
        h_ids, _, _ = ix.range_query(q, r)
        assert set(int(i) for i in ids) == set(int(i) for i in h_ids)
        gid, dists = bx.knn_query(q, 9)
        assert abs(dists[-1] - np.sort(d)[8]) < 1e-4
