"""Model zoo correctness: per-arch smoke tests (shapes, finiteness) and
prefill+decode == full-forward consistency (the serving contract), plus
attention / SSD algorithm equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCell
from repro.configs.registry import ARCHS
from repro.models import zoo
from repro.models.layers import chunked_attention, dense_attention
from repro.models.mamba2 import ssd_chunked
from repro.models.params import count_params, init_params

KEY = jax.random.PRNGKey(0)
TRAIN = ShapeCell("t", 64, 2, "train")
PREFILL = ShapeCell("p", 64, 2, "prefill")


@pytest.fixture(scope="module")
def built():
    """fp32 reduced models: the consistency tests verify cache *mechanics*
    exactly; bf16 numerics are covered by the smoke/loss tests."""
    import dataclasses
    out = {}
    for name, cfg in ARCHS.items():
        r = dataclasses.replace(cfg.reduced(), dtype="float32")
        specs = zoo.model_specs(r)
        params = init_params(specs, KEY, r.dtype)
        if r.moe is not None:
            # make routing decisive: at init router logits are ~0.02-scale,
            # so bf16 noise between the full-seq and decode paths flips
            # top-k choices (a test artifact, not a cache bug). Scaling the
            # router separates the logits well past bf16 noise.
            params = jax.tree_util.tree_map_with_path(
                lambda path, x: x * 50.0
                if any(getattr(k, "key", None) == "router" for k in path)
                else x, params)
        out[name] = (r, params, specs)
    return out


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_finite(built, name):
    r, params, specs = built[name]
    batch = zoo.make_batch(r, TRAIN, 1)
    loss, metrics = jax.jit(zoo.loss_fn(r))(params, batch)
    assert jnp.isfinite(loss)
    assert count_params(specs) > 0
    # gradients flow and are finite
    g = jax.grad(lambda p: zoo.loss_fn(r)(p, batch)[0])(params)
    flat = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in flat)
    assert any(float(jnp.abs(x).max()) > 0 for x in flat)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_matches_forward(built, name):
    """Teacher-forced full forward at position t must equal prefill(≤t-1)
    + decode(t) — exactness of every cache type (KV, ring, conv, SSD)."""
    r, params, _ = built[name]
    b, s = 2, 48
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, r.vocab, (b, s)), jnp.int32)
    batch = {"tokens": tokens,
             "labels": jnp.asarray(rng.integers(0, r.vocab, (b, s)),
                                   jnp.int32)}
    pb = {"tokens": tokens[:, :-1]}
    if r.family == "vlm":
        pe = jnp.asarray(rng.normal(0, 0.02,
                                    (b, r.n_prefix_embeds, r.d_model)),
                         jnp.dtype(r.dtype))
        batch["prefix_embeds"] = pe
        pb["prefix_embeds"] = pe
    if r.family == "encdec":
        se = jnp.asarray(rng.normal(0, 0.02, (b, 32, r.d_model)),
                         jnp.dtype(r.dtype))
        batch["src_embeds"] = se
        pb["src_embeds"] = se

    # full teacher-forced logits
    if r.family == "encdec":
        from repro.models.encdec import decode_train, encode
        mem = encode(params, batch["src_embeds"], r)
        full = decode_train(params, mem, tokens, r)
    else:
        from repro.models.transformer import _unembed, forward_seq
        x, _, _ = forward_seq(params, tokens, r,
                              batch.get("prefix_embeds"))
        if r.family == "vlm":
            x = x[:, r.n_prefix_embeds:]
        full = _unembed(params, x, r)

    logits_p, cache = jax.jit(zoo.prefill_fn(r, s + 8))(params, pb)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(full[:, -2], np.float32), rtol=2e-3, atol=2e-3)

    logits_d, cache = jax.jit(zoo.decode_fn(r))(params, tokens[:, -1], cache)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full[:, -1], np.float32), rtol=5e-3, atol=5e-3)


def test_chunked_attention_matches_dense():
    rng = np.random.default_rng(0)
    b, s, hq, hk, hd = 2, 256, 8, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hk, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hk, hd)), jnp.float32)
    for window in (None, 64):
        d = dense_attention(q, k, v, causal=True, window=window)
        c = chunked_attention(q, k, v, causal=True, window=window,
                              chunk_q=64, chunk_k=64)
        np.testing.assert_allclose(np.asarray(c), np.asarray(d),
                                   rtol=2e-4, atol=2e-4)


def test_ssd_chunked_matches_recurrence():
    """Chunked SSD == step-by-step linear recurrence."""
    rng = np.random.default_rng(1)
    b, s, h, p, n, g = 2, 64, 4, 8, 16, 1
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a_neg = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)

    y_chunk, final = ssd_chunked(x, dt, a_neg, bm, cm, chunk=16)

    # naive recurrence
    state = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    xn, dtn = np.asarray(x, np.float64), np.asarray(dt, np.float64)
    bn = np.repeat(np.asarray(bm, np.float64), h // g, axis=2)
    cn = np.repeat(np.asarray(cm, np.float64), h // g, axis=2)
    an = np.asarray(a_neg, np.float64)
    for t in range(s):
        decay = np.exp(dtn[:, t] * an)[:, :, None, None]
        xt = xn[:, t] * dtn[:, t][..., None]
        state = state * decay + xt[..., None] * bn[:, t][:, :, None, :]
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, cn[:, t])
    np.testing.assert_allclose(np.asarray(y_chunk), ys, rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), state, rtol=2e-3,
                               atol=2e-3)


def test_swa_ring_buffer_decode(built):
    """Sliding-window decode with ring cache == dense SWA attention,
    past the wraparound point (s=60 > window=32)."""
    cfg, params, _ = built["mixtral-8x7b"]
    rng = np.random.default_rng(5)
    b, s = 2, 60
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    from repro.models.transformer import _unembed, forward_seq
    x, _, _ = forward_seq(params, tokens, cfg)
    full = _unembed(params, x, cfg)
    logits_p, cache = jax.jit(zoo.prefill_fn(cfg, s + 8))(
        params, {"tokens": tokens[:, :-1]})
    logits_d, _ = jax.jit(zoo.decode_fn(cfg))(params, tokens[:, -1], cache)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=5e-3, atol=5e-3)


def test_moe_capacity_drops():
    """With capacity 1 and >1 token per expert, overflow tokens are
    dropped (contribute nothing) and kept tokens are exact."""
    import dataclasses
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_ffn, moe_specs
    cfg = dataclasses.replace(
        ARCHS["mixtral-8x7b"].reduced(), dtype="float32",
        moe=MoEConfig(n_experts=2, top_k=1, d_expert=16,
                      capacity_factor=0.01))
    params = init_params(moe_specs(cfg), KEY, "float32")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 4, cfg.d_model)), jnp.float32)
    y, aux = moe_ffn(params, x, cfg)
    # capacity = min(t,16)=4? no: cap = max(min(4,16), round(4*1/2*.01)) = 4
    # force tiny capacity by many tokens:
    x2 = jnp.asarray(rng.normal(size=(1, 64, cfg.d_model)), jnp.float32)
    y2, _ = moe_ffn(params, x2, cfg)
    # cap = max(16, round(64*0.5*0.01)) = 16 per expert; 64 tokens top-1 on
    # 2 experts ⇒ ≥ 32 assignments on the busier expert ⇒ drops happen:
    dropped_rows = int((np.abs(np.asarray(y2[0])).sum(-1) == 0).sum())
    assert dropped_rows >= 64 - 2 * 16
    assert np.isfinite(np.asarray(y2)).all()
