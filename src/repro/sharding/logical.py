"""Logical-axis sharding rules (MaxText-style).

Every parameter and activation is annotated with *logical* axis names;
a rule table maps logical names to mesh axes per run configuration. This
is what makes the same model definition run as pure-DP on 8 chips and
DP×TP×EP(+FSDP) on 512 without touching model code.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis vocabulary
#   batch      — global batch                 → ("pod", "data")
#   seq        — sequence (activations)       → None (or "model" under SP)
#   embed      — d_model                      → None (or "data" under FSDP)
#   heads      — attention q heads            → "model"
#   kv_heads   — attention kv heads           → "model" if divisible
#   qkv        — per-head feature             → None
#   mlp        — FFN hidden                   → "model"
#   vocab      — vocabulary                   → "model"
#   experts    — MoE experts                  → "model"
#   layers     — stacked scan dim             → None
#   kv_seq     — KV-cache sequence            → "model" (flash-decode shards it)
#   ssm_state  — SSD state dim                → None
#   ssm_inner  — SSD inner (expand*d)         → "model"
#   clusters   — LIMS snapshot cluster axis   → "data" (cluster-granular
#                serving shards; pivot tables stay valid under partition)


def default_rules(fsdp: bool = False, seq_shard: bool = False,
                  kv_heads_shardable: bool = True) -> dict:
    return {
        "batch": ("pod", "data"),
        "seq": "model" if seq_shard else None,
        "embed": "data" if fsdp else None,
        "embed_noshard": None,
        "heads": "model",
        "kv_heads": "model" if kv_heads_shardable else None,
        "qkv": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        # TP-within-expert: when the expert COUNT doesn't divide the model
        # axis (mixtral: 8 experts on 16-way), the per-expert FFN dim
        # shards instead; spec_for's double-use guard keeps the two rules
        # mutually exclusive per tensor.
        "expert_mlp": "model",
        "layers": None,
        "kv_seq": "model",
        "ssm_state": None,
        "ssm_inner": "model",
        "conv": None,
        "clusters": "data",
    }


def serving_mesh(n_shards: Optional[int] = None) -> Mesh:
    """1-D ``("data",)`` mesh for cluster-sharded index serving.

    Uses every host-visible device by default (1 CPU in plain tests; N
    fake host devices under ``--xla_force_host_platform_device_count=N``;
    real chips on TPU/GPU pods). A FUNCTION, not a constant — importing
    must never touch jax device state (cf. ``repro.launch.mesh``).
    """
    devs = jax.devices()
    n = len(devs) if n_shards is None else max(1, min(n_shards, len(devs)))
    return Mesh(np.asarray(devs[:n]), ("data",))


def spec_for(axes: tuple, rules: dict, mesh: Mesh,
             shape: Optional[tuple] = None) -> P:
    """Logical axes → PartitionSpec, dropping mesh axes that don't exist
    (e.g. "pod" on the single-pod mesh), avoiding double-use, and — when
    ``shape`` is given — dropping assignments whose dim isn't divisible by
    the mesh extent (56 heads or a 50280 vocab can't shard 16 ways; the
    guard degrades them to replicated instead of erroring)."""
    used: set = set()
    parts = []
    for i, ax in enumerate(axes):
        r = rules.get(ax, None) if ax is not None else None
        if r is None:
            parts.append(None)
            continue
        names = (r,) if isinstance(r, str) else tuple(r)
        names = tuple(n for n in names
                      if n in mesh.axis_names and n not in used)
        if shape is not None and names:
            extent = 1
            for n in names:
                extent *= mesh.shape[n]
            if extent == 0 or shape[i] % extent != 0:
                names = ()
        used.update(names)
        parts.append(names if len(names) > 1 else (names[0] if names else None))
    return P(*parts)


def guarded_sharding(shape: tuple, axes: tuple, rules: dict,
                     mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(axes, rules, mesh, shape))


def sharding_for(axes: tuple, rules: dict, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(axes, rules, mesh))


def tree_shardings(axes_tree, rules: dict, mesh: Mesh):
    """Map a pytree of logical-axes tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: sharding_for(axes, rules, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))


def constrain(x: jax.Array, axes: tuple, rules: Optional[dict],
              mesh: Optional[Mesh]):
    """Activation sharding constraint by logical axes (no-op w/o mesh)."""
    if rules is None or mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes, rules, mesh)))


def shardable(dim: int, mesh: Mesh, axis: str = "model") -> bool:
    if mesh is None or axis not in mesh.axis_names:
        return False
    return dim % mesh.shape[axis] == 0


# --------------------------------------------------------------------------
# Active-mesh context: model code (e.g. the MoE dispatch) adds activation
# sharding constraints only when the launcher declares the mesh axes it is
# lowering under; smoke tests / host runs see a no-op.
_ACTIVE_AXES: tuple = ()


def set_active_mesh_axes(names) -> None:
    global _ACTIVE_AXES
    _ACTIVE_AXES = tuple(names or ())


def maybe_constrain(x: jax.Array, spec_elems: tuple) -> jax.Array:
    """with_sharding_constraint filtered to the declared mesh axes;
    no-op when no mesh is active."""
    if not _ACTIVE_AXES:
        return x
    clean = []
    for e in spec_elems:
        if e is None:
            clean.append(None)
            continue
        names = (e,) if isinstance(e, str) else tuple(e)
        names = tuple(n for n in names if n in _ACTIVE_AXES)
        clean.append(names if len(names) > 1 else
                     (names[0] if names else None))
    return jax.lax.with_sharding_constraint(x, P(*clean))
