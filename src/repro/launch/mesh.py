"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (tests see 1 CPU device; only dryrun.py sets the
512-host-device XLA flag before its first jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16×16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") across
    pods — the "pod" axis carries only data parallelism (+ gradient
    all-reduce over DCN), never tensor parallelism.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1):
    """Whatever devices exist locally (smoke tests / CPU examples)."""
    devs = jax.devices()
    mp = model_parallel
    while mp > 1 and len(devs) % mp:
        mp //= 2
    data = len(devs) // mp
    return jax.make_mesh(
        (data, mp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
