import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh (16×16 single-pod, 2×16×16 multi-pod) with
ShapeDtypeStruct inputs — nothing is allocated — and record
memory_analysis / cost_analysis / parsed collective bytes for the
roofline tables in EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""
import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs.base import SHAPES, ModelConfig, RunConfig, ShapeCell  # noqa: E402
from ..configs.registry import ARCHS, cells, get_arch  # noqa: E402
from ..models import zoo  # noqa: E402
from ..models.params import abstract_params, count_params  # noqa: E402
from ..roofline import analysis  # noqa: E402
from ..roofline import hw  # noqa: E402
from ..sharding.logical import default_rules, guarded_sharding  # noqa: E402
from ..train.step import abstract_state, build_train_step  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

# Per-arch execution overrides: big configs need FSDP; the 1T MoE needs a
# factored optimizer to fit 16 GB/chip; microbatching divides activation
# memory for train cells (documented in EXPERIMENTS.md).
RUN_OVERRIDES = {
    # ≥20B-param configs: full remat (selective's ~6× residual multiplier
    # exceeds 16 GiB at d_model ≥ 6144)
    "kimi-k2-1t-a32b": RunConfig(fsdp=True, optimizer="adafactor",
                                 microbatches=16, remat_override="full"),
    # ZeRO-1 (§Perf): optimizer+grad shards over data, weights TP-resident
    # — no per-microbatch FSDP weight re-gather
    "llava-next-34b": RunConfig(zero1=True, microbatches=8,
                                remat_override="full"),
    "internlm2-20b": RunConfig(fsdp=True, microbatches=8,
                               remat_override="full"),
    "mixtral-8x7b": RunConfig(zero1=True, microbatches=8,
                              remat_override="full"),
}
# zero1 default: optimizer+grad shards over data — llama3-class trains go
# from 22.6 GB/chip (doesn't fit) to 14.7 GB (fits) at zero collective cost
DEFAULT_RUN = RunConfig(microbatches=8, zero1=True)


def run_config_for(arch: str) -> RunConfig:
    return RUN_OVERRIDES.get(arch, DEFAULT_RUN)


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh, rules) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    out = {}
    for name, (shape, dtype, axes) in zoo.batch_desc(cfg, cell).items():
        out[name] = jax.ShapeDtypeStruct(
            shape, jnp.dtype(dtype),
            sharding=guarded_sharding(shape, axes, rules, mesh))
    return out


def cache_specs(cfg: ModelConfig, cell: ShapeCell, mesh, rules) -> dict:
    out = {}
    for name, (shape, axes, dtype) in zoo.cache_desc(cfg, cell).items():
        out[name] = jax.ShapeDtypeStruct(
            tuple(shape), jnp.dtype(dtype),
            sharding=guarded_sharding(tuple(shape), axes, rules, mesh))
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg: ModelConfig | None = None,
               run: RunConfig | None = None):
    """Lower + compile one cell. Returns (compiled, specs, mesh, n_chips)."""
    import dataclasses
    cfg = cfg or get_arch(arch)
    run = run or run_config_for(arch)
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = default_rules(fsdp=run.fsdp,
                          seq_shard=run.seq_shard_activations)
    if cfg.moe is not None and cfg.moe_dispatch_groups == 1:
        dp_total = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        cfg = dataclasses.replace(cfg, moe_dispatch_groups=dp_total)
    if run.remat_override and cfg.remat != run.remat_override:
        cfg = dataclasses.replace(cfg, remat=run.remat_override)
    specs = zoo.model_specs(cfg)

    from ..sharding.logical import set_active_mesh_axes
    cache = None
    set_active_mesh_axes(mesh.axis_names)
    with mesh:
        if cell.kind == "train":
            state = abstract_state(cfg, run, specs, mesh, rules)
            batch = input_specs(cfg, cell, mesh, rules)
            dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            grad_sh = None
            if run.zero1:
                from ..models.params import ParamSpec
                from ..sharding.logical import guarded_sharding
                r2 = dict(rules)
                if r2.get("embed") is None:
                    r2["embed"] = "data"
                grad_sh = jax.tree.map(
                    lambda s: guarded_sharding(s.shape, s.axes, r2, mesh),
                    specs, is_leaf=lambda x: isinstance(x, ParamSpec))
            step_fn = build_train_step(cfg, run, dp_axes=dp,
                                       grad_shardings=grad_sh)
            # donate the TrainState: params/opt buffers update in place
            lowered = jax.jit(step_fn, donate_argnums=(0,)).lower(state,
                                                                  batch)
        elif cell.kind == "prefill":
            params = abstract_params(specs, cfg.dtype, mesh, rules)
            batch = input_specs(cfg, cell, mesh, rules)
            fn = zoo.prefill_fn(cfg, cell.seq_len)
            lowered = jax.jit(fn).lower(params, batch)
        else:  # decode
            params = abstract_params(specs, cfg.dtype, mesh, rules)
            token = input_specs(cfg, cell, mesh, rules)["token"]
            cache = cache_specs(cfg, cell, mesh, rules)
            fn = zoo.decode_fn(cfg)
            # donate the cache: the KV update must alias, not copy
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(params, token,
                                                             cache)
        compiled = lowered.compile()
    set_active_mesh_axes(())
    return compiled, specs, mesh, n_chips, cfg, cell, run, rules, cache


def analyze_cell(arch: str, shape_name: str, multi_pod: bool,
                 cfg: ModelConfig | None = None,
                 run: RunConfig | None = None) -> dict:
    from ..roofline.hlo_cost import analyze_hlo
    t0 = time.time()
    compiled, specs, mesh, n_chips, cfg, cell, run, rules, cache = \
        lower_cell(arch, shape_name, multi_pod, cfg, run)
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo, pod_size=256)
    model_flops = analysis.model_flops_for_cell(cfg, specs, cell, n_chips)
    roof = analysis.Roofline(
        flops=cost.flops,
        hbm_bytes=cost.bytes,
        coll_bytes=cost.coll_bytes,
        cross_pod_bytes=cost.cross_pod_bytes,
        model_flops=model_flops,
        coll_detail={"bytes": cost.coll_by_kind,
                     "count": cost.coll_count,
                     "xla_flops_once": float(ca.get("flops", 0.0)),
                     "xla_bytes_once": float(ca.get("bytes accessed", 0.0))},
    )
    n_total, n_active = analysis.active_params(cfg, specs)
    mem_model = analysis.estimate_memory(cfg, run, specs, cell, mesh,
                                         rules, cache_abstract=cache)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": cell.kind,
        "params_total": n_total,
        "params_active": n_active,
        "compile_s": round(t_compile, 1),
        "run": {"fsdp": run.fsdp, "microbatches": run.microbatches,
                "optimizer": run.optimizer, "remat": cfg.remat,
                "seq_shard": run.seq_shard_activations},
        # raw XLA:CPU memory_analysis (recorded verbatim; its buffer
        # assignment lacks TPU scheduling — see EXPERIMENTS.md §Dry-run)
        "mem_xla": {
            "args_gb": ma.argument_size_in_bytes / 2**30,
            "temp_gb": ma.temp_size_in_bytes / 2**30,
            "out_gb": ma.output_size_in_bytes / 2**30,
            "alias_gb": ma.alias_size_in_bytes / 2**30,
        },
        # analytical per-device HBM model → the fits verdict
        "mem": {
            **{k: v / 2**30 for k, v in mem_model.items()},
            "live_gb": mem_model["total"] / 2**30,
            "fits_16gb": bool(mem_model["total"] <= hw.HBM_BYTES),
        },
        "roofline": roof.to_dict(),
    }
    return rec


def fmt_row(rec: dict) -> str:
    r = rec["roofline"]
    return (f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
            f"live={rec['mem']['live_gb']:8.2f}GB "
            f"fits={'Y' if rec['mem']['fits_16gb'] else 'N'} "
            f"tc={r['t_compute_s']*1e3:9.2f}ms "
            f"tm={r['t_memory_s']*1e3:9.2f}ms "
            f"tx={r['t_collective_s']*1e3:9.2f}ms "
            f"dom={r['bottleneck']:10s} "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"compile={rec['compile_s']}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    todo = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        for arch, shape, skip in cells(include_skipped=True):
            for mp in meshes:
                todo.append((arch, shape, mp, skip))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            todo.append((args.arch, args.shape, mp, False))

    os.makedirs(args.out, exist_ok=True)
    ok = fail = 0
    for arch, shape, mp, skip in todo:
        tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if skip:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "skipped": "full-attention arch: 500k dense-causal "
                              "context is out of contract (DESIGN.md)"}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"{arch:24s} {shape:12s} SKIP (full attention)")
            continue
        try:
            rec = analyze_cell(arch, shape, mp)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(fmt_row(rec))
            mx = rec["mem_xla"]
            rf = rec["roofline"]
            print(f"   memory_analysis/dev: args={mx['args_gb']:.2f}GB "
                  f"temp={mx['temp_gb']:.2f}GB out={mx['out_gb']:.2f}GB "
                  f"alias={mx['alias_gb']:.2f}GB | cost_analysis(hlo): "
                  f"flops={rf['flops_per_dev']:.3g} "
                  f"bytes={rf['hbm_bytes_per_dev']:.3g} "
                  f"coll={rf['coll_bytes_per_dev']:.3g}")
            ok += 1
        except Exception as e:  # noqa: BLE001
            fail += 1
            print(f"{arch:24s} {shape:12s} FAIL: {type(e).__name__}: {e}")
            with open(path + ".err", "w") as f:
                f.write(traceback.format_exc())
            if args.fail_fast:
                raise
    print(f"\ndry-run: {ok} ok, {fail} failed")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
