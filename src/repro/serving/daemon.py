"""The closed loop: health findings → serving actions (DESIGN.md §12).

:class:`MonitorDaemon` is the subscriber that turns the monitor's
:class:`~repro.obs.health.HealthFinding`s into operations on the
serving stack — the "placement daemon reacting to heat drift" ROADMAP
item 2 called for:

* ``heat_skew`` findings → :meth:`PlanRouter.rebalance` (fold the live
  heat signal back into replica ownership).  The detector's hysteresis
  already debounces the *signal*; the daemon adds an **action cooldown**
  (``cooldown_ticks`` monitor ticks between rebalances) so even a
  re-firing finding can never thrash placement.
* ``rank_drift`` findings → retrain handling per
  ``REPRO_MONITOR_RETRAIN``: ``off`` ignores them, ``recommend``
  records :meth:`ServingEngine.recommend_retrain` for the drifting
  cluster, ``auto`` additionally calls
  :meth:`ServingEngine.retrain_cluster` (same cooldown discipline,
  keyed per detector).

Every action (and every deliberate skip while cooling down) lands in a
bounded audit ring (:meth:`events`) with the triggering finding, so the
loop is inspectable after the fact — an autonomous actor nobody can
audit is a liability, not a feature.

The daemon owns no thread: it registers the router heat-skew probe on
the monitor (computing the ``router.heat_skew`` gauge each tick) and
reacts inside the monitor's tick, so manual-tick tests drive the whole
loop deterministically.
"""
from __future__ import annotations

import threading
from collections import deque

from .. import env
from ..obs import registry as _obs
from ..obs.health import HealthFinding
from ..obs.monitor import Monitor

__all__ = ["MonitorDaemon"]

_ACTIONABLE = ("warn", "critical")


def retrain_mode() -> str:
    """``REPRO_MONITOR_RETRAIN``: off | recommend | auto."""
    return env.get("REPRO_MONITOR_RETRAIN")


class MonitorDaemon:
    """Subscribe a serving stack to a monitor's findings and act.

    ``router_fn`` returns the live :class:`PlanRouter` (or None before
    the first routed batch) — a callable because the frontend rebuilds
    its router on generation change.  ``engine`` (optional) receives
    retrain recommendations.  ``retrain`` overrides the
    ``REPRO_MONITOR_RETRAIN`` knob when given.
    """

    def __init__(self, monitor: Monitor, router_fn, engine=None,
                 cooldown_ticks: int = 5, retrain: str | None = None,
                 max_events: int = 256):
        if retrain is not None and retrain not in ("off", "recommend",
                                                   "auto"):
            raise ValueError(
                f"retrain must be off|recommend|auto, got {retrain!r}")
        self.monitor = monitor
        self._router_fn = router_fn
        self._engine = engine
        self.cooldown_ticks = max(1, int(cooldown_ticks))
        self._retrain = retrain
        self._events: deque = deque(maxlen=max_events)
        self._lock = threading.Lock()
        # per-detector tick of the last *action* (cooldown keys)
        self._last_action: dict[str, int] = {}
        monitor.add_probe(self._probe)
        monitor.subscribe(self._on_finding)

    # -- per-tick probe --------------------------------------------------
    def _probe(self) -> None:
        """Publish the router's heat-skew gauge so the detector has a
        fresh signal every tick (cheap: one (R,K)@(K,) matvec)."""
        router = self._router_fn()
        if router is not None:
            router.heat_skew()

    # -- findings → actions ----------------------------------------------
    def _on_finding(self, f: HealthFinding) -> None:
        if f.cleared or f.severity not in _ACTIONABLE:
            return
        if f.detector == "heat_skew":
            self._act_rebalance(f)
        elif f.detector == "rank_drift":
            self._act_retrain(f)

    def _cooling(self, f: HealthFinding) -> bool:
        """True (and audited) when the detector acted too recently."""
        with self._lock:
            last = self._last_action.get(f.detector)
            if last is not None and f.tick - last < self.cooldown_ticks:
                self._events.append({
                    "action": "cooldown_skip", "detector": f.detector,
                    "tick": f.tick, "last_action_tick": last,
                    "finding": f.as_dict()})
                return True
            self._last_action[f.detector] = f.tick
        return False

    def _act_rebalance(self, f: HealthFinding) -> None:
        router = self._router_fn()
        if router is None or self._cooling(f):
            return
        owner = router.rebalance()
        _obs.count("daemon.rebalances")
        with self._lock:
            self._events.append({
                "action": "rebalance", "detector": f.detector,
                "tick": f.tick, "skew": f.value,
                "owner": owner.tolist(), "finding": f.as_dict()})

    def _act_retrain(self, f: HealthFinding) -> None:
        mode = self._retrain if self._retrain is not None else retrain_mode()
        if mode == "off" or self._engine is None:
            return
        if self._cooling(f):
            return
        cluster = f.context.get("cluster")
        if cluster is None:
            return
        self._engine.recommend_retrain(cluster, reason=f.summary)
        action = "retrain_recommend"
        if mode == "auto":
            self._engine.retrain_cluster(int(cluster))
            _obs.count("daemon.retrains")
            action = "retrain_auto"
        with self._lock:
            self._events.append({
                "action": action, "detector": f.detector, "tick": f.tick,
                "cluster": int(cluster), "finding": f.as_dict()})

    # -- inspection ------------------------------------------------------
    def events(self, n: int | None = None) -> list:
        """The audit ring, oldest first (all when ``n`` is None)."""
        with self._lock:
            out = list(self._events)
        return out if n is None else out[-n:]

    def snapshot(self) -> dict:
        with self._lock:
            return {"cooldown_ticks": self.cooldown_ticks,
                    "retrain_mode": self._retrain or retrain_mode(),
                    "last_action": dict(self._last_action),
                    "events": list(self._events)}
