"""Layer 4 of the serving stack: the request frontend.

Kernels want batches; users send single queries.  ``ServingFrontend``
bridges the two with *dynamic batching*: submitters enqueue one query
each and block; a batcher thread coalesces compatible requests — same
kind, same k — into one kernel-shaped batch, dispatching when the batch
fills (``max_batch``) or the oldest request's latency budget (``slo_ms``)
expires, whichever is first.  Per-query results are independent of
batchmates (the router's exactness argument, DESIGN.md §9), so a
coalesced query returns bit-identical results to a direct
``QueryExecutor`` call — pinned by tests under concurrent submitters.

Admission control is shed-on-overload: the queue is bounded
(``max_queue``) and a submit that finds it full fails *immediately*
with :class:`FrontendOverload` rather than queueing into a latency it
can't meet — the standard contract for an SLO-bound service (callers
retry against another frontend or back off).  Shed requests cost the
engine nothing: no plan, no kernel launch, no page IO.

Behind the batcher sits the plan-driven router over a replica set
(``router``/``replicas``); the frontend tracks its engine's snapshot
generation and rebuilds the replica set after a refresh lands, so
batches never mix generations (each batch runs on the replica set it
was dispatched to — the same atomic-grab contract the engine's own
query methods keep).

``pause()``/``resume()`` hold the batcher between dispatches —
deterministic coalescing and overload in tests and benchmarks, never
needed in production.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from ..obs import monitor as _mon
from ..obs import registry as _obs
from ..obs.monitor import Monitor
from ..obs.registry import Histogram
from ..obs.trace import instant, span
from .daemon import MonitorDaemon
from .replicas import ReplicaSet
from .router import PlanRouter


class FrontendOverload(RuntimeError):
    """Admission control shed this request: the queue was full."""


class _Request:
    __slots__ = ("kind", "q", "arg", "t_in", "t_run", "event", "result",
                 "error")

    def __init__(self, kind: str, q: np.ndarray, arg):
        self.kind = kind
        self.q = q
        self.arg = arg
        self.t_in = time.monotonic()
        self.t_run = None
        self.event = threading.Event()
        self.result = None
        self.error: BaseException | None = None

    @property
    def key(self):
        # range queries coalesce regardless of radius (radii are a (B,)
        # plan input); kNN batches share k (k shapes outputs and plan)
        return (self.kind, self.arg if self.kind == "knn" else None)


class ServingFrontend:
    """Dynamic-batching, admission-controlled frontend over an engine
    (or a bare executor — anything with ``.executor``/``.snap``)."""

    def __init__(self, target, *, n_replicas: int | None = None,
                 max_batch: int = 32, slo_ms: float = 2.0,
                 max_queue: int = 256, prefetch: str | None = None,
                 slo_target_ms: float | None = None,
                 monitor: "bool | Monitor | None" = None):
        if max_batch < 1 or max_queue < 1:
            raise ValueError("max_batch and max_queue must be >= 1")
        # engine-like targets expose .executor + .generation; a bare
        # executor serves one frozen generation
        self._engine = target if hasattr(target, "executor") else None
        self._executor = None if self._engine is not None else target
        self._n_replicas = n_replicas
        self._prefetch = prefetch
        self._max_batch = int(max_batch)
        self._slo = float(slo_ms) / 1e3
        self._max_queue = int(max_queue)
        self._cv = threading.Condition()
        self._pending: list[_Request] = []
        self._paused = False
        self._closed = False
        # metrics (all mutated under self._cv).  Distributions live in
        # bounded reservoirs — a long-running frontend holds O(cap)
        # metric memory, not one float per request ever served.  These
        # are *instance* histograms recording unconditionally:
        # ``metrics()`` is part of the frontend's API contract and must
        # work with REPRO_OBS=off; the process-wide registry mirrors
        # are the mode-gated part.
        self._submitted = 0
        self._shed = 0
        self._batches = 0
        self._coalesced = 0
        self._size_hist = Histogram("frontend.batch_size")
        self._wait_hist = Histogram("frontend.queue_wait_s")
        # end-to-end completion SLO: slo_ms bounds *coalescing wait*;
        # the completion target a request is judged against must also
        # absorb execution, so it defaults to 20x the batching budget.
        # A shed request burns budget too — it counts as a miss.
        self._slo_target = (float(slo_target_ms) if slo_target_ms is not None
                            else 20.0 * float(slo_ms)) / 1e3
        self._slo_ok = 0
        self._slo_miss = 0
        self._lat_hist = Histogram("frontend.request_latency_s")
        self._router_obj: PlanRouter | None = None
        self._gen: int | None = None
        self._batcher = threading.Thread(
            target=self._batch_loop, daemon=True, name="lims-frontend")
        self._batcher.start()
        # continuous health monitoring (DESIGN.md §12): None → the
        # REPRO_MONITOR knob; True / a Monitor instance force it on.
        # The daemon subscribes the router + engine to the findings and
        # the monitor thread samples until close().
        self.monitor: Monitor | None = None
        self.daemon: MonitorDaemon | None = None
        if monitor is None:
            monitor = _mon.monitor_enabled()
        if monitor:
            mon = monitor if isinstance(monitor, Monitor) else Monitor()
            self.monitor = mon
            self.daemon = MonitorDaemon(mon, lambda: self._router_obj,
                                        engine=self._engine)
            mon.start()

    # ------------------------------------------------------------- submit
    def range_query(self, q, r: float):
        """Submit one range query; blocks until its batch returns.
        Returns ``(ids, dists)`` exactly as ``QueryExecutor.range_query``.
        """
        return self._submit(_Request(
            "range", np.asarray(q, np.float64), float(r)))

    def knn_query(self, q, k: int):
        """Submit one kNN query; blocks until its batch returns."""
        return self._submit(_Request(
            "knn", np.asarray(q, np.float64), int(k)))

    def _submit(self, req: _Request):
        with self._cv:
            if self._closed:
                raise RuntimeError("frontend is closed")
            if len(self._pending) >= self._max_queue:
                self._shed += 1
                self._slo_miss += 1
                _obs.count("frontend.shed")
                _obs.count("frontend.slo_miss")
                instant("frontend.shed", {"pending": len(self._pending)})
                raise FrontendOverload(
                    f"queue full ({self._max_queue} pending)")
            self._submitted += 1
            _obs.count("frontend.submitted")
            self._pending.append(req)
            self._cv.notify_all()
        req.event.wait()
        self._record_latency(time.monotonic() - req.t_in)
        if req.error is not None:
            raise req.error
        return req.result

    def _record_latency(self, lat: float) -> None:
        """Judge one completed request against the completion SLO (the
        submitter's thread measures its own end-to-end latency: queue
        wait + execution + wakeup)."""
        ok = lat <= self._slo_target
        with self._cv:
            self._lat_hist.observe(lat)
            if ok:
                self._slo_ok += 1
            else:
                self._slo_miss += 1
        if _obs.enabled():
            reg = _obs.REGISTRY
            reg.histogram("frontend.request_latency_s").observe(lat)
            reg.counter(
                "frontend.slo_ok" if ok else "frontend.slo_miss").inc()

    # ------------------------------------------------------------ batcher
    def _batch_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            self._execute(batch)

    def _next_batch(self) -> list | None:
        """Block until a batch is due: the oldest request's key gathers
        batchmates until ``max_batch`` or its SLO deadline."""
        with self._cv:
            while not self._closed and (self._paused or not self._pending):
                self._cv.wait()
            if not self._pending:       # closed and drained
                return None
            first = self._pending[0]
            deadline = first.t_in + self._slo
            while not self._closed:
                n = sum(1 for r in self._pending if r.key == first.key)
                left = deadline - time.monotonic()
                if n >= self._max_batch or left <= 0:
                    break
                self._cv.wait(left)
            batch = [r for r in self._pending
                     if r.key == first.key][:self._max_batch]
            for r in batch:
                self._pending.remove(r)
            return batch

    def _execute(self, batch: list) -> None:
        t_run = time.monotonic()
        try:
            with span("frontend.execute",
                      {"B": len(batch), "kind": batch[0].kind}):
                router = self._router()
                Q = np.stack([r.q for r in batch])
                if batch[0].kind == "range":
                    rs = np.array([r.arg for r in batch], np.float64)
                    for r, res in zip(batch,
                                      router.range_query_batch(Q, rs)):
                        r.result = res
                else:
                    ids, ds = router.knn_query_batch(Q, batch[0].arg)
                    for j, r in enumerate(batch):
                        r.result = (ids[j], ds[j])
        except BaseException as e:
            for r in batch:
                r.error = e
        finally:
            waits = [t_run - r.t_in for r in batch]
            self._obs_record(len(batch), waits)
            for r in batch:
                r.t_run = t_run
                r.event.set()

    def _obs_record(self, size: int, waits: list) -> None:
        """Fold one dispatched batch into the frontend's bounded metrics
        and (mode permitting) the process-wide registry."""
        with self._cv:
            self._batches += 1
            if size >= 2:
                self._coalesced += 1
            self._size_hist.observe(size)
            for w in waits:
                self._wait_hist.observe(w)
        if _obs.enabled():
            reg = _obs.REGISTRY
            reg.counter("frontend.batches").inc()
            reg.counter("frontend.queries").inc(size)
            if size >= 2:
                reg.counter("frontend.coalesced_batches").inc()
            reg.histogram("frontend.batch_size").observe(size)
            wh = reg.histogram("frontend.queue_wait_s")
            for w in waits:
                wh.observe(w)

    def _router(self) -> PlanRouter:
        """The router for the current snapshot generation (batcher-thread
        only); a landed refresh rebuilds the replica set."""
        gen = self._engine.generation if self._engine is not None else 0
        if self._router_obj is None or gen != self._gen:
            ex = self._engine.executor if self._engine is not None \
                else self._executor
            with span("frontend.replica_rebuild", {"generation": gen}):
                self._router_obj = PlanRouter(ReplicaSet(
                    ex.snap, n_replicas=self._n_replicas,
                    prefetch=self._prefetch))
            _obs.count("frontend.replica_rebuilds")
            self._gen = gen
        return self._router_obj

    # ---------------------------------------------------------- lifecycle
    def pause(self) -> None:
        """Hold the batcher between dispatches (tests/benchmarks)."""
        with self._cv:
            self._paused = True

    def resume(self) -> None:
        with self._cv:
            self._paused = False
            self._cv.notify_all()

    def close(self, timeout: float = 30.0) -> None:
        """Stop accepting requests, drain what's queued, join the
        batcher."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._batcher.join(timeout)
        if self.monitor is not None:
            self.monitor.stop()

    def __enter__(self) -> "ServingFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ metrics
    def metrics(self) -> dict:
        """Frontend-side serving metrics: achieved batch sizes, queue
        wait percentiles, shed rate — plus per-replica load when the
        router has run."""
        with self._cv:
            submitted, shed = self._submitted, self._shed
            batches, coalesced = self._batches, self._coalesced
            slo_ok, slo_miss = self._slo_ok, self._slo_miss
        router = self._router_obj
        out = {
            "submitted": submitted,
            "shed": shed,
            "shed_rate": round(shed / max(submitted + shed, 1), 4),
            "slo_target_ms": round(self._slo_target * 1e3, 3),
            "slo_ok": slo_ok,
            "slo_miss": slo_miss,
            "slo_attained": round(slo_ok / max(slo_ok + slo_miss, 1), 4),
            "latency_ms_p50": round(
                self._lat_hist.percentile(50) * 1e3, 3),
            "latency_ms_p99": round(
                self._lat_hist.percentile(99) * 1e3, 3),
            "batches": batches,
            "batch_size_mean": round(self._size_hist.mean, 2)
            if batches else 0.0,
            "batch_size_max": int(self._size_hist.max) if batches else 0,
            "coalesced_batches": coalesced,
            "queue_wait_ms_p50": round(
                self._wait_hist.percentile(50) * 1e3, 3),
            "queue_wait_ms_p99": round(
                self._wait_hist.percentile(99) * 1e3, 3),
        }
        if router is not None:
            out["routing"] = router.load_stats()
        return out


__all__ = ["ServingFrontend", "FrontendOverload"]
