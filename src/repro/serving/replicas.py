"""Replica set: snapshot placement + per-replica load accounting.

A :class:`ReplicaSet` places one executor per serving replica, each
bound to the *same* snapshot generation with its device arrays
``jax.device_put`` onto that replica's device.  The snapshot is a
frozen pytree whose leaves are exactly the device arrays
(``_DEVICE_FIELDS``) and whose aux data (ids, validity, the
generation-bound ``StoreView``) is shared by reference — so placement
is one pytree map, replicas can never disagree about generation
content, and every paged replica gathers through the same page cache
(one buffer pool, one set of access counters, one pin ledger).

In logical-axis terms (``repro.sharding.logical``) this is the
*replicated* placement of the "clusters" axis: where ``ShardedExecutor``
maps clusters → mesh ``data`` axis (each device holds a shard and
collectives merge per-round reductions), a replica set gives every
device the whole cluster axis and partitions the *request* stream
instead — the router sends each query sub-batch to one replica, chosen
by TriPrune cluster ownership.  Both placements preserve exactness for
free (per-cluster state is self-contained; per-query results are
independent of batchmates); replication trades memory for routing
freedom and zero cross-device collectives on the hot path.

Cluster *ownership* is the routing preference, not a data partition:
every replica can execute any query bit-identically; ownership decides
which replica a query's TriPrune cluster set votes for.  The default is
round-robin (cluster k → replica k mod R); :meth:`ReplicaSet.rebalance`
reassigns ownership greedily from a cluster-heat signal — by default
the page cache's access counters folded per extent
(``PagedStore.cluster_heat``), closing the storage → placement feedback
loop (DESIGN.md §9).
"""
from __future__ import annotations

import threading

import numpy as np

import jax

from ..core.executor import QueryExecutor
from ..core.snapshot import LIMSSnapshot
from ..obs import registry as _obs


class Replica:
    """One serving replica: an executor on a device + load counters."""

    def __init__(self, rid: int, device, ex: QueryExecutor):
        self.rid = rid
        self.device = device
        self.ex = ex
        self._lock = threading.Lock()
        self.batches = 0
        self.queries = 0

    def record(self, n_queries: int) -> None:
        with self._lock:
            self.batches += 1
            self.queries += n_queries
        _obs.count(f"replica.{self.rid}.batches")
        _obs.count(f"replica.{self.rid}.queries", n_queries)

    def stats(self) -> dict:
        with self._lock:
            return {"rid": self.rid, "device": str(self.device),
                    "batches": self.batches, "queries": self.queries}


class ReplicaSet:
    """Executors over one snapshot generation, one per device.

    ``n_replicas=None`` → one replica per visible device (devices cycle
    when asked for more — useful for exercising the routing logic on a
    single-device host).  All replicas share the snapshot's aux state,
    including its ``StoreView`` when paged.
    """

    def __init__(self, snapshot: LIMSSnapshot, n_replicas: int | None = None,
                 devices: list | None = None,
                 prefetch: str | None = None):
        devices = list(devices) if devices is not None else jax.devices()
        n = int(n_replicas) if n_replicas is not None else len(devices)
        if n < 1:
            raise ValueError("a replica set needs at least one replica")
        self.snapshot = snapshot
        self.K = snapshot.K
        self.members: list[Replica] = []
        for i in range(n):
            dev = devices[i % len(devices)]
            snap_i = jax.device_put(snapshot, dev)
            self.members.append(
                Replica(i, dev, QueryExecutor(snap_i, prefetch=prefetch)))
        # ownership[k] = the replica cluster k's routing votes go to
        self._owner = np.arange(self.K, dtype=np.int64) % n
        self._own_lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.members)

    @property
    def owner(self) -> np.ndarray:
        """(K,) replica id owning each cluster (routing preference)."""
        return self._owner.copy()

    def ownership(self) -> np.ndarray:
        """(R, K) bool ownership matrix (the router's vote weights)."""
        with self._own_lock:
            return self._owner[None, :] == \
                np.arange(len(self.members))[:, None]

    def cluster_heat(self) -> np.ndarray | None:
        """(K,) access heat from the page cache, or None when resident
        (no page counters to fold — the router falls back to its own
        routed-cluster counts)."""
        store = self.snapshot.store
        return store.cluster_heat() if store is not None else None

    def rebalance(self, heat: np.ndarray) -> np.ndarray:
        """Reassign cluster ownership from a heat signal: hottest
        cluster first, each to the replica with the least heat assigned
        so far — the greedy makespan balance.  Returns the new (K,)
        owner array.  Queries in flight are unaffected (ownership only
        biases future routing; results never depend on it)."""
        heat = np.asarray(heat, np.float64)
        if heat.shape != (self.K,):
            raise ValueError(f"heat must be shape ({self.K},)")
        R = len(self.members)
        owner = np.empty(self.K, np.int64)
        load = np.zeros(R, np.float64)
        for k in np.argsort(-heat, kind="stable"):
            r = int(np.argmin(load))
            owner[k] = r
            load[r] += heat[k]
        with self._own_lock:
            self._owner = owner
        return owner.copy()

    def set_ownership(self, owner: np.ndarray) -> None:
        """Install an explicit (K,) ownership map.  Exactness never
        depends on ownership, so any assignment is legal — this is how
        demos and tests inject placement drift (stale ownership vs live
        heat) for the monitor daemon to detect and repair."""
        owner = np.asarray(owner, np.int64)
        if owner.shape != (self.K,):
            raise ValueError(f"owner must be shape ({self.K},)")
        R = len(self.members)
        if owner.size and (owner.min() < 0 or owner.max() >= R):
            raise ValueError(f"owner ids must be in [0, {R})")
        with self._own_lock:
            self._owner = owner.copy()

    def load_stats(self) -> list:
        with self._own_lock:
            counts = np.bincount(self._owner, minlength=len(self.members))
        out = []
        for rep, c in zip(self.members, counts):
            st = rep.stats()
            st["owned_clusters"] = int(c)
            out.append(st)
        return out


__all__ = ["Replica", "ReplicaSet"]
