"""Plan-driven routing: one CandidatePlan, dispatched by cluster
ownership.

The planner already computes, per batch, everything a router needs: the
TriPrune cluster routing (which clusters each query can possibly touch)
and the full radius schedule.  ``PlanRouter`` builds that plan exactly
*once* — on its routing executor, preserving the one-plan-per-batch
acceptance property — then splits the batch into per-replica sub-batches
and executes each through ``plan.subset`` on its replica.

Assignment: each query's routed clusters vote for the replicas that own
them; the query goes to the replica with the most votes, ties broken
toward the replica with the least load (already-assigned batchmates
included, so one batch spreads under ties); a query whose TriPrune set
is empty (it will match nothing, or its kNN schedule starts elsewhere)
falls to round-robin.

Exactness argument (DESIGN.md §9): a plan row — mask, routing, schedule
radius — is a function of that query and the snapshot metadata alone,
never of batchmates; every execution stage preserves that independence
(kernel math is per-pair, padding rows are inert, certification and
refinement are per-query).  So executing any sub-batch of a plan on any
replica of the same snapshot returns, per query, exactly what the full
batch on one executor returns — routing is a pure performance decision,
pinned by the bit-identity tests.

Routed-cluster counts accumulate in ``routed_heat``;
:meth:`PlanRouter.rebalance` folds the page cache's per-cluster access
counters (falling back to ``routed_heat`` when resident) back into
replica ownership — the cache → placement feedback loop.
"""
from __future__ import annotations

import threading

import numpy as np

from ..obs import registry as _obs
from ..obs.trace import span
from .replicas import ReplicaSet


class PlanRouter:
    """Dispatch query batches across a :class:`ReplicaSet` by plan."""

    def __init__(self, replicas: ReplicaSet):
        self.replicas = replicas
        # the routing executor: builds the batch's single plan (and owns
        # the pivot-distance seeding); replica 0 doubles as it, so a
        # one-replica set routes with zero overhead
        self.routing_ex = replicas.members[0].ex
        self.routed_heat = np.zeros(replicas.K, np.int64)
        self._lock = threading.Lock()
        self._rr = 0                    # round-robin cursor (empty routing)

    # ------------------------------------------------------------ queries
    def range_query_batch(self, Q, r):
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        B = Q.shape[0]
        r_arr = np.broadcast_to(np.asarray(r, np.float64), (B,))
        plan = self.routing_ex.planner.plan_range(Q, r_arr)
        parts = self._dispatch(Q, plan, "execute_range")
        out = [None] * B
        for idx, res in parts:
            for j, b in enumerate(idx):
                out[b] = res[j]
        return out

    def knn_query_batch(self, Q, k: int, max_rounds: int = 64):
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        B = Q.shape[0]
        k_eff = min(int(k), self.replicas.snapshot.live)
        if k_eff <= 0:
            return (np.empty((B, 0), np.int64), np.empty((B, 0)))
        plan = self.routing_ex.planner.plan_knn(Q, k_eff, max_rounds)
        parts = self._dispatch(Q, plan, "execute_knn")
        ids = np.empty((B, k_eff), np.int64)
        ds = np.empty((B, k_eff))
        for idx, (ids_p, ds_p) in parts:
            ids[idx] = ids_p
            ds[idx] = ds_p
        return ids, ds

    # ----------------------------------------------------------- dispatch
    def _assign(self, plan) -> np.ndarray:
        """(B,) replica id per query: ownership votes over the plan's
        TriPrune routing, least-loaded tie-break, round-robin for
        unrouted queries."""
        with span("router.assign", {"B": plan.B}):
            return self._assign_inner(plan)

    def _assign_inner(self, plan) -> np.ndarray:
        routing = plan.routing                       # (B, K) bool
        own = self.replicas.ownership()              # (R, K) bool
        votes = routing.astype(np.int64) @ own.T.astype(np.int64)  # (B, R)
        with self._lock:
            self.routed_heat += routing.sum(axis=0)
            load = np.array([m.queries for m in self.replicas.members],
                            np.float64)
            pick = np.empty(routing.shape[0], np.int64)
            for b in range(routing.shape[0]):
                v = votes[b]
                if v.max() == 0:
                    pick[b] = self._rr % len(self.replicas)
                    self._rr += 1
                else:
                    tied = np.nonzero(v == v.max())[0]
                    pick[b] = tied[int(np.argmin(load[tied]))]
                load[pick[b]] += 1.0    # spread batchmates under ties
        return pick

    def _dispatch(self, Q, plan, method: str) -> list:
        """[(query idx, sub-result)] per replica group; groups with >1
        replica run on threads (each replica's device works its own
        sub-batch concurrently)."""
        pick = self._assign(plan)
        groups = []
        for rep in self.replicas.members:
            idx = np.nonzero(pick == rep.rid)[0]
            if len(idx):
                groups.append((rep, idx))
        if _obs.enabled():
            reg = _obs.REGISTRY
            reg.counter("router.batches").inc()
            reg.counter("router.queries").inc(plan.B)
            reg.counter("router.subbatches").inc(len(groups))
            # how widely one batch spreads across the replica set (1 =
            # everything landed on a single replica)
            reg.histogram("router.replica_spread").observe(len(groups))
        results = [None] * len(groups)
        errors = [None] * len(groups)

        def run(g: int, rep, idx) -> None:
            try:
                with span("router.subbatch",
                          {"replica": rep.rid, "B": len(idx)}):
                    sub = plan.subset(idx, planner=rep.ex.planner,
                                      device=rep.device)
                    results[g] = getattr(rep.ex, method)(Q[idx], sub)
                rep.record(len(idx))
            except BaseException as e:  # re-raised on the caller thread
                errors[g] = e

        with span("router.dispatch",
                  {"B": plan.B, "groups": len(groups)}):
            if len(groups) == 1:
                run(0, *groups[0])
            else:
                threads = [threading.Thread(target=run, args=(g, rep, idx),
                                            name=f"lims-route-r{rep.rid}")
                           for g, (rep, idx) in enumerate(groups)]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
        for err in errors:
            if err is not None:
                raise err
        return [(idx, res) for (rep, idx), res in zip(groups, results)]

    # ---------------------------------------------------------- placement
    def _heat(self) -> np.ndarray:
        """The current per-cluster heat signal: page-cache access
        counters when paged, routed-cluster counts when resident.
        Always length ``replicas.K``: sharded snapshots pad K to a
        device multiple while the store reports real clusters only, so
        the tail pads with zero heat (padding clusters hold no data)."""
        heat = self.replicas.cluster_heat()
        if heat is None or not heat.any():
            heat = self.routed_heat
        heat = np.asarray(heat, np.float64).reshape(-1)
        K = self.replicas.K
        if len(heat) < K:
            heat = np.pad(heat, (0, K - len(heat)))
        return heat[:K]

    def heat_skew(self) -> float:
        """How badly ownership mismatches heat: max per-replica owned
        heat over the per-replica mean (1.0 = balanced, R = one replica
        owns everything hot).  Published as the ``router.heat_skew``
        gauge — the heat-skew detector's input; the monitor daemon
        calls this as its per-tick probe."""
        heat = self._heat()
        own = self.replicas.ownership()              # (R, K) bool
        per = own.astype(np.float64) @ heat          # (R,)
        total = per.sum()
        if total <= 0 or len(per) <= 1:
            skew = 1.0
        else:
            skew = float(per.max() / (total / len(per)))
        _obs.set_gauge("router.heat_skew", skew)
        return skew

    def rebalance(self) -> np.ndarray:
        """Fold the current heat signal into replica ownership: the page
        cache's per-cluster access counters when paged, the router's own
        routed-cluster counts when resident."""
        with span("router.rebalance"):
            moved = self.replicas.rebalance(self._heat())
        _obs.count("router.rebalances")
        return moved

    def load_stats(self) -> dict:
        return {"replicas": self.replicas.load_stats(),
                "routed_heat": self.routed_heat.tolist()}


__all__ = ["PlanRouter"]
