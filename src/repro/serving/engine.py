"""Layer 3 of the serving stack: snapshot lifecycle.

``ServingEngine`` is the *lifecycle* layer of the request path
(DESIGN.md §9): updates, refreshes, storage and compaction.  A
deployment talks to it directly for single-caller workloads, or puts a
:class:`~repro.serving.frontend.ServingFrontend` in front of it — the
frontend coalesces concurrent single queries into kernel-shaped batches
and routes them across a replica set, with this engine still owning the
snapshot generations underneath (:meth:`ServingEngine.frontend` wires
the stack up).  It owns

  * the host ``LIMSIndex`` (source of truth for §5.3 updates),
  * a double-buffered pair of snapshot executors: the *active* executor
    serves queries; ``refresh()`` builds a fresh ``LIMSSnapshot`` into the
    standby slot **off the hot path** and then swaps the two with a single
    attribute assignment — atomic under the GIL, so an in-flight batch
    that already grabbed the active executor keeps its consistent
    snapshot while new batches see the new one.  No query ever blocks on
    a rebuild and no query ever observes a half-built snapshot.

Updates (``insert`` / ``delete`` / ``retrain_cluster``) go straight to
the host index and bump a mutation counter; once the counter reaches
``refresh_every`` the engine triggers a rebuild — synchronously by
default (deterministic for tests), or on a background thread with
``async_refresh=True`` (updates serialize with the rebuild via a lock;
queries never take it).  Between refreshes queries serve the last
snapshot — stale but *consistent and exact with respect to that
snapshot*, the usual contract of a serving index (DESIGN.md §5).
"""
from __future__ import annotations

import shutil
import tempfile
import threading
import weakref
from collections import deque

from jax.sharding import Mesh

from ..obs import registry as _obs
from ..obs.trace import instant, span
from ..storage import (DEFAULT_CACHE_PAGES, DEFAULT_PAGE_BYTES, PagedStore,
                       storage_mode)
from ..core.executor import QueryExecutor, make_executor
from ..core.index import LIMSIndex
from ..core.snapshot import LIMSSnapshot


class ServingEngine:
    """Double-buffered snapshot serving over a mutable ``LIMSIndex``.

    Storage (DESIGN.md §7): with ``storage="paged"`` (or the process-wide
    ``REPRO_STORAGE=paged`` default) every snapshot generation spills to
    ``storage_path`` and serves store-backed — row payloads on disk
    behind an LRU page cache, query IO planned page-wise.  A refresh
    writes only the clusters whose rows changed as *new* page extents
    (a retrain's partial reconstruction touches one extent, not the
    corpus) and publishes with one atomic manifest swap; the long-lived
    ``PagedStore`` keeps its warm cache across generations because page
    ids are append-only.  :meth:`from_spill` is the cold-start path — a
    replica begins serving from a spilled directory without rebuilding
    anything.
    """

    def __init__(self, index: LIMSIndex | None, *, refresh_every: int = 64,
                 sharded: bool | None = None, mesh: Mesh | None = None,
                 async_refresh: bool = False,
                 build_backend: str | None = None,
                 storage: str | None = None,
                 storage_path: str | None = None,
                 page_bytes: int = DEFAULT_PAGE_BYTES,
                 cache_pages: int | None = DEFAULT_CACHE_PAGES,
                 prefetch: str | None = None,
                 _initial: QueryExecutor | None = None):
        self._index = index
        # paged executors overlap kNN rounds' page IO with refinement
        # when "async" (None defers to REPRO_PREFETCH; DESIGN.md §8)
        self._prefetch = prefetch
        self._refresh_every = int(refresh_every)
        # online retrains default to "auto": the index routes each
        # retrain host-vs-device on the cluster's member row count (the
        # measured crossover, core.index.RETRAIN_AUTO_ROWS) — small
        # clusters skip device dispatch overhead, big ones use the
        # accelerator, and the interpret lane / custom metrics always
        # rebuild on host.  Pass "device"/"host" to pin it.
        self._build_backend = "auto" if build_backend is None \
            else build_backend
        self._sharded = sharded
        self._mesh = mesh
        self._async = bool(async_refresh)
        if storage is None:
            storage = storage_mode() or None
        if storage not in (None, "paged"):
            raise ValueError(f"unknown storage mode {storage!r}")
        self._storage = storage
        self._page_bytes = int(page_bytes)
        self._cache_pages = cache_pages
        self._store: PagedStore | None = None
        self._storage_path = storage_path
        if storage == "paged" and storage_path is None:
            self._storage_path = tempfile.mkdtemp(prefix="lims-store-")
            weakref.finalize(self, shutil.rmtree, self._storage_path,
                             ignore_errors=True)
        # guards host-index mutation + snapshot builds (never queries)
        self._update_lock = threading.Lock()
        # guards background-refresh thread bookkeeping
        self._thread_lock = threading.Lock()
        self._refresh_thread: threading.Thread | None = None
        self._refresh_again = False
        self.generation = 0
        self.pending_mutations = 0
        # retrain recommendations surfaced by the monitor daemon
        # (bounded: a serving window, not a log)
        self._retrain_recs: deque = deque(maxlen=64)
        if _initial is not None:
            self._active: QueryExecutor = _initial
            view = getattr(_initial.snap, "store", None)
            # the engine holds the shared reader; snapshots hold
            # per-generation views of it
            self._store = view.base if view is not None else None
        else:
            self._active = self._build_executor()
        self._standby: QueryExecutor | None = None

    # ----------------------------------------------------------- cold start
    @classmethod
    def from_spill(cls, path: str, *, index: LIMSIndex | None = None,
                   sharded: bool | None = None, mesh: Mesh | None = None,
                   cache_pages: int | None = DEFAULT_CACHE_PAGES,
                   prefetch: str | None = None,
                   **kw) -> "ServingEngine":
        """Cold-start a serving replica from a spilled snapshot directory.

        Serving begins immediately — only the manifest and metadata load
        up front; row pages fault in on demand through the page cache
        (replica warm-up is query-driven).  Without ``index`` the engine
        is read-only: updates and refreshes raise until a host index is
        supplied via :meth:`attach_index` (e.g. rebuilt in the
        background).  With ``index``, refreshes write back to ``path``.
        """
        snap = LIMSSnapshot.load(path, store=True, cache_pages=cache_pages)
        ex = make_executor(snap, sharded=sharded, mesh=mesh,
                           prefetch=prefetch)
        # refresh writebacks must keep the on-disk page geometry
        kw.setdefault("page_bytes", snap.store.manifest.page_bytes)
        return cls(index, storage="paged", storage_path=path,
                   sharded=sharded, mesh=mesh, cache_pages=cache_pages,
                   prefetch=prefetch, _initial=ex, **kw)

    def attach_index(self, index: LIMSIndex) -> None:
        """Give a cold-started engine its mutable host index (updates and
        refreshes become available; the next refresh snapshots it)."""
        with self._update_lock:
            self._index = index

    def _require_index(self) -> LIMSIndex:
        if self._index is None:
            raise RuntimeError(
                "cold-started engine is read-only: no host index attached "
                "(use attach_index() once one is built)")
        return self._index

    # ------------------------------------------------------------ plumbing
    def _build_executor(self) -> QueryExecutor:
        snap = LIMSSnapshot.build(self._require_index())
        if self._storage == "paged":
            snap.spill(self._storage_path, page_bytes=self._page_bytes)
            if self._store is None:
                self._store = PagedStore(self._storage_path,
                                         cache_pages=self._cache_pages)
            else:
                # adopt the freshly published generation: rewritten
                # clusters reference appended extents, cached pages of
                # untouched clusters stay warm (append-only page ids).
                # with_store then freezes the new layout into this
                # snapshot's view — executors still serving the previous
                # generation keep gathering through THEIR view, so the
                # swap can never remap an in-flight batch's slots.
                self._store.refresh()
            snap = snap.with_store(self._store)
        return make_executor(snap, sharded=self._sharded, mesh=self._mesh,
                             prefetch=self._prefetch)

    @property
    def index(self) -> LIMSIndex | None:
        return self._index

    @property
    def store(self) -> PagedStore | None:
        """The paged-store reader (None when serving resident)."""
        return self._store

    @property
    def executor(self) -> QueryExecutor:
        """The active executor; grab it once per batch for a consistent
        view across the whole batch."""
        return self._active

    @property
    def snapshot(self) -> LIMSSnapshot:
        return self._active.snap

    # ------------------------------------------------------------- queries
    # Each query method reads ``self._active`` exactly once: the batch
    # runs against that snapshot even if a refresh swaps mid-flight.
    def range_query_batch(self, Q, r):
        return self._active.range_query_batch(Q, r)

    def range_query(self, q, r: float):
        return self._active.range_query(q, r)

    def knn_query_batch(self, Q, k: int, **kw):
        return self._active.knn_query_batch(Q, k, **kw)

    def knn_query(self, q, k: int):
        return self._active.knn_query(q, k)

    # ------------------------------------------------------------- updates
    # The mutation counter is only ever read or written under
    # _update_lock (refresh() subtracts under the same lock), so
    # concurrent updaters and a background rebuild can't lose counts.
    # The threshold check happens after the lock is released — refresh()
    # re-takes it — so two racing updaters can at worst both trigger a
    # refresh, which is harmless (the second sees zero pending).
    def insert(self, p) -> int:
        with self._update_lock:
            gid = self._require_index().insert(p)
            self.pending_mutations += 1
            pending = self.pending_mutations
        self._maybe_refresh(pending)
        return gid

    def delete(self, q) -> int:
        with self._update_lock:
            removed = self._require_index().delete(q)
            self.pending_mutations += removed
            pending = self.pending_mutations
        if removed:
            self._maybe_refresh(pending)
        return removed

    def retrain_cluster(self, c: int) -> None:
        with self._update_lock:
            self._require_index().retrain_cluster(
                c, backend=self._build_backend)
            # a retrain rewrites cluster structure the snapshot mirrors;
            # force the next refresh decision regardless of the
            # insert/delete count
            self.pending_mutations += self._refresh_every
            pending = self.pending_mutations
        self._maybe_refresh(pending)

    def recommend_retrain(self, c: int, reason: str = "") -> dict:
        """Record a retrain recommendation for cluster ``c`` (bounded
        ring, newest kept) — the monitor daemon surfaces rank-model
        drift findings here under ``REPRO_MONITOR_RETRAIN=recommend``;
        operators (or the daemon's ``auto`` mode) act on them.  Returns
        the recorded entry."""
        rec = {"cluster": int(c), "reason": str(reason),
               "generation": self.generation}
        with self._update_lock:
            self._retrain_recs.append(rec)
        _obs.count("engine.retrain_recommendations")
        return rec

    def retrain_recommendations(self) -> list:
        """Pending retrain recommendations, oldest first."""
        with self._update_lock:
            return list(self._retrain_recs)

    def clear_retrain_recommendations(self) -> None:
        with self._update_lock:
            self._retrain_recs.clear()

    def compact(self):
        """Reclaim the paged store's garbage extents: rewrite live
        extents into a fresh pages file and swap manifests atomically
        (``PagedStore.compact``).  Serialized with updates/refreshes via
        the update lock — queries never block, and executors serving the
        pre-compaction generation keep their file pinned through their
        ``StoreView``.  No-op (returns None) when serving resident."""
        if self._store is None:
            return None
        with self._update_lock:
            return self._store.compact()

    def _maybe_refresh(self, pending: int) -> None:
        if self._refresh_every and pending >= self._refresh_every:
            if self._async:
                self._spawn_refresh()
            else:
                self.refresh()

    # ------------------------------------------------------------- refresh
    def refresh(self) -> None:
        """Rebuild the standby snapshot and swap it in atomically."""
        with self._update_lock:
            seen = self.pending_mutations
            with span("engine.snapshot_build",
                      {"pending_mutations": seen}):
                new = self._build_executor()
            # the swap: one attribute store (GIL-atomic); the previous
            # executor moves to standby, kept alive for in-flight batches
            self._active, self._standby = new, self._active
            self.pending_mutations -= seen
            self.generation += 1
            _obs.count("engine.refreshes")
            instant("engine.snapshot_swap",
                    {"generation": self.generation})

    def _spawn_refresh(self) -> None:
        with self._thread_lock:
            if self._refresh_thread is not None:
                # a rebuild is running: ask it to go again before exiting
                # (its exit decision happens under this same lock, so the
                # request can never fall into a teardown window)
                self._refresh_again = True
                return
            t = threading.Thread(target=self._refresh_worker, daemon=True,
                                 name="lims-snapshot-refresh")
            self._refresh_thread = t
        t.start()

    def _refresh_worker(self) -> None:
        while True:
            self.refresh()
            with self._thread_lock:
                if not self._refresh_again:
                    self._refresh_thread = None
                    return
                self._refresh_again = False

    def wait_refresh(self) -> None:
        """Block until every requested background refresh has landed."""
        while True:
            with self._thread_lock:
                t = self._refresh_thread
            if t is None:
                return
            t.join()

    # ------------------------------------------------------------ frontend
    def frontend(self, **kw) -> "ServingFrontend":
        """A request frontend over this engine: dynamic batching +
        admission control in front, plan-driven replica routing behind
        (DESIGN.md §9).  Keyword arguments pass through to
        :class:`~repro.serving.frontend.ServingFrontend`."""
        from .frontend import ServingFrontend
        return ServingFrontend(self, **kw)


__all__ = ["ServingEngine"]
