"""The layered serving stack (DESIGN.md §9).

Request path, top to bottom::

    ServingFrontend   dynamic batching + admission control (frontend)
        PlanRouter    one CandidatePlan per batch, sub-batches to
                      replicas by TriPrune cluster ownership (router)
        ReplicaSet    executors over the snapshot pytree, one per
                      device, per-replica load stats (replicas)
    ServingEngine     snapshot lifecycle: updates, refresh, storage,
                      compaction (engine)

Every layer preserves the exactness contract: per-query results are
independent of batchmates and of which replica executes them, so a
query submitted through the frontend returns bit-identical results to
a direct ``QueryExecutor`` call.  ``repro.core.serving`` remains as a
compatibility shim for ``ServingEngine``.
"""
from .daemon import MonitorDaemon
from .engine import ServingEngine
from .frontend import FrontendOverload, ServingFrontend
from .replicas import Replica, ReplicaSet
from .router import PlanRouter

__all__ = ["ServingEngine", "ServingFrontend", "FrontendOverload",
           "MonitorDaemon", "Replica", "ReplicaSet", "PlanRouter"]
