"""Blocked pairwise-distance Pallas kernel (the paper's dominant cost).

TPU mapping of the distance computations LIMS performs everywhere
(clustering passes, pivot columns, refinement): a 2-D grid over
(query tiles × point tiles). For L2 the Gram trick turns the inner loop
into an MXU matmul with fp32 accumulation; L1/Linf run on the VPU with the
feature dimension resident in VMEM.

Tile sizing: (bq, d) + (bp, d) + (bq, bp) in VMEM. With the default
bq = bp = 128 and d ≤ 4096 this is ≤ 2×128×4096×4B + 64KB ≈ 4.3 MB —
comfortably inside a v5e's 16 MB VMEM, and every matmul dim is a multiple
of the 128-lane MXU width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret


def _pdist_l2_kernel(q_ref, p_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)           # (bq, 1)
    pn = jnp.sum(p * p, axis=-1, keepdims=True)           # (bp, 1)
    # MXU: (bq, d) @ (d, bp) with fp32 accumulation
    g = jax.lax.dot_general(q, p, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(qn + pn.T - 2.0 * g, 0.0)


def _pdist_l1_kernel(q_ref, p_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)                    # (bq, d)
    p = p_ref[...].astype(jnp.float32)                    # (bp, d)
    # VPU: broadcast diff over a (bq, bp, d) tile kept in registers/VMEM
    o_ref[...] = jnp.sum(jnp.abs(q[:, None, :] - p[None, :, :]), axis=-1)


def _pdist_linf_kernel(q_ref, p_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.max(jnp.abs(q[:, None, :] - p[None, :, :]), axis=-1)


_KERNELS = {"sql2": _pdist_l2_kernel, "l1": _pdist_l1_kernel,
            "linf": _pdist_linf_kernel}


@functools.partial(jax.jit,
                   static_argnames=("metric", "bq", "bp", "interpret"))
def pdist_pallas(q: jax.Array, p: jax.Array, metric: str = "sql2",
                 bq: int = 128, bp: int = 128,
                 interpret: bool | None = None) -> jax.Array:
    """Pairwise distances, rows of q (nq, d) × rows of p (np, d).

    ``metric='sql2'`` returns *squared* L2 (callers square radii instead of
    paying an elementwise sqrt over the nq×np tile). nq/np must be multiples
    of bq/bp — ``repro.kernels.ops`` handles padding. ``interpret=None``
    auto-selects by backend (compiled on TPU/GPU, interpreted on CPU).

    The grid is point-major (point tiles outer, query tiles inner — the
    last grid dimension iterates fastest): each candidate-point tile is
    fetched into VMEM once and reused across every query tile, instead
    of the whole point array being re-streamed per query tile.  The
    point plane dominates the operand bytes on the refinement path, so
    this is the bandwidth-friendly orientation; per-cell outputs are
    unchanged, so results are bit-identical to the query-major grid.
    """
    interpret = resolve_interpret(interpret)
    nq, d = q.shape
    npts, d2 = p.shape
    assert d == d2, (d, d2)
    assert nq % bq == 0 and npts % bp == 0, (nq, npts, bq, bp)
    # L1/Linf tiles materialize (bq, bp, d); keep them small enough for VMEM
    if metric in ("l1", "linf"):
        bq = min(bq, 32)
        assert nq % bq == 0
    return pl.pallas_call(
        _KERNELS[metric],
        grid=(npts // bp, nq // bq),
        in_specs=[
            pl.BlockSpec((bq, d), lambda j, i: (i, 0)),
            pl.BlockSpec((bp, d), lambda j, i: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bp), lambda j, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, npts), jnp.float32),
        interpret=interpret,
    )(q, p)
