"""Compiled-XLA lowerings of the kernel pipeline — the CPU "compiled lane".

``pallas_call`` cannot compile on the CPU backend (it raises "Only
interpret mode is supported"), but *compiled* on XLA-CPU does not need
pallas: the same tile-blocked math lowers through ``jax.jit`` straight
to XLA's native CPU codegen (Eigen GEMMs, vectorized loops) with none of
the per-grid-cell interpreter overhead.  These functions mirror the
pallas kernel bodies operation-for-operation — the Gram-trick sql2
distance, the shared :func:`rankeval.rank_math` Clenshaw recurrence, the
fused distance+threshold range filter — so within this lane the fused
and staged pipelines are bit-identical (pinned in tests), and across
lanes results agree to f32 tolerance (accumulation order in the dot may
differ).

Tile sizes here are real tuning parameters, not grid geometry: a
``(bq, bp, qb)`` / ``(bg, bb)`` tuple becomes ``lax.map`` chunk sizes —
cache blocking — which is exactly what the autotuner searches per shape
bucket.  A chunk size >= the operand dimension means "no chunking": one
fused XLA computation over the whole operand (for the sql2 Gram path
that is usually the winner; for the broadcast l1/linf path chunking is
mandatory to bound the (bq, bp, d) intermediate).

Query blocking: the query×points kernels take a third chunk size ``qb``
(query *sub*-block).  The loop nest is query super-tiles (``bq``) →
point blocks (``bp``) → query sub-blocks (``qb``): each point block is
loaded once per super-tile and stays cache-resident while the ``qb``-row
sub-blocks stream over it, instead of the whole point array being
re-streamed per query tile.  ``qb >= bq`` (or 0) disables sub-blocking.
Every output cell is produced by the same per-pair arithmetic regardless
of the (bq, bp, qb) choice, so results are bit-identical across tilings
(pinned in tests) — tiles move bytes, not math.

Operands arrive padded to tile multiples (``ops.py`` does the padding,
same as for the pallas lane), so every ``reshape(n // b, b, ...)`` here
is exact by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .rankeval import rank_math


def _gram_sq(q: jax.Array, p: jax.Array) -> jax.Array:
    """Squared-L2 distance block via the Gram trick, clamped at 0.

    Identical operation sequence to ``pdist._pdist_l2_kernel`` /
    ``range_filter``'s distance half: f32 row norms + one
    ``dot_general`` with f32 accumulation.
    """
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    pn = jnp.sum(p * p, axis=-1, keepdims=True)
    g = jax.lax.dot_general(q, p, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return jnp.maximum(qn + pn.T - 2.0 * g, 0.0)


def _pdist_block(qb: jax.Array, pb: jax.Array, metric: str) -> jax.Array:
    if metric == "sql2":
        return _gram_sq(qb, pb)
    diff = jnp.abs(qb[:, None, :] - pb[None, :, :])
    if metric == "l1":
        return jnp.sum(diff, axis=-1)
    if metric == "linf":
        return jnp.max(diff, axis=-1)
    raise ValueError(f"unknown metric {metric!r}")


def _map_pblocks(fn, p: jax.Array, bp: int):
    """Map ``fn`` over row-chunks of ``p`` and re-join on the *column*
    axis of fn's (nq, bp)-shaped output: (nP, nq, bp) → (nq, nP*bp)."""
    npts, d = p.shape
    out = jax.lax.map(fn, p.reshape(npts // bp, bp, d))
    return jnp.swapaxes(out, 0, 1).reshape(out.shape[1], npts)


def _map_qsub(fn, qs: jax.Array, qb: int):
    """Map ``fn`` over ``qb``-row sub-blocks of a query super-tile and
    re-join on the row axis: (nS, qb, cols) → (nS*qb, cols).  The point
    operand is closed over — loaded once, reused across sub-blocks."""
    gsz, d = qs.shape
    if qb <= 0 or qb >= gsz:
        return fn(qs)
    out = jax.lax.map(fn, qs.reshape(gsz // qb, qb, d))
    return out.reshape(gsz, out.shape[-1])


@functools.partial(jax.jit, static_argnames=("metric", "bq", "bp", "qb"))
def pdist_xla(q: jax.Array, p: jax.Array, metric: str = "sql2",
              bq: int = 128, bp: int = 128, qb: int = 0) -> jax.Array:
    """(nq, npts) f32 distance matrix; nq % bq == 0, npts % bp == 0,
    bq % qb == 0 when query sub-blocking is on (qb in (0, bq))."""
    q = q.astype(jnp.float32)
    p = p.astype(jnp.float32)
    nq, d = q.shape
    npts = p.shape[0]
    if 0 < qb < min(bq, nq):
        assert min(bq, nq) % qb == 0, (nq, bq, qb)

    def qblock(qs):
        def pblock(pb):
            return _map_qsub(lambda qsub: _pdist_block(qsub, pb, metric),
                             qs, qb)
        if bp >= npts:
            return pblock(p)
        return _map_pblocks(pblock, p, bp)

    if bq >= nq:
        return qblock(q)
    out = jax.lax.map(qblock, q.reshape(nq // bq, bq, d))
    return out.reshape(nq, npts)


@functools.partial(jax.jit, static_argnames=("n_rings", "bg", "bb"))
def rankeval_xla(x: jax.Array, coef: jax.Array, lo: jax.Array,
                 hi: jax.Array, n: jax.Array, n_rings: int = 20,
                 bg: int = 8, bb: int = 128):
    """Returns (rank, rid), both (G, B) int32 — same math as the pallas
    kernel via the shared ``rank_math``; (bg, bb) are chunk sizes."""
    g, b = x.shape
    n_coef = coef.shape[1]

    def gblock(args):
        xg, cg, log, hig, ng = args

        def bblock(xb):
            return rank_math(xb, cg, log, hig, ng, n_coef=n_coef,
                             n_rings=n_rings)

        if bb >= b:
            return bblock(xg)
        gsz = xg.shape[0]
        xbs = jnp.moveaxis(xg.reshape(gsz, b // bb, bb), 1, 0)
        rk, rid = jax.lax.map(bblock, xbs)          # (nB, gsz, bb) each
        return (jnp.moveaxis(rk, 0, 1).reshape(gsz, b),
                jnp.moveaxis(rid, 0, 1).reshape(gsz, b))

    args = (x, coef, lo, hi, n)
    if bg >= g:
        return gblock(args)
    chunked = tuple(a.reshape(g // bg, bg, *a.shape[1:]) for a in args)
    rk, rid = jax.lax.map(gblock, chunked)          # (nG, bg, b) each
    return rk.reshape(g, b), rid.reshape(g, b)


@functools.partial(jax.jit, static_argnames=("bq", "bp", "qb"))
def range_filter_xla(q: jax.Array, p: jax.Array, r: jax.Array,
                     bq: int = 128, bp: int = 128, qb: int = 0):
    """Fused sql2 distance + threshold: (mask (nq, npts) uint8,
    cnt (nq, npts//bp) int32) — same contract as the pallas kernel
    (``r`` is the per-query radius, squared here).  Same query-blocked
    nest as :func:`pdist_xla`: each point block is loaded once per query
    super-tile and reused across the ``qb``-row sub-blocks."""
    q = q.astype(jnp.float32)
    p = p.astype(jnp.float32)
    r2 = (r * r).astype(jnp.float32)
    nq, d = q.shape
    npts = p.shape[0]
    if 0 < qb < min(bq, nq):
        assert min(bq, nq) % qb == 0, (nq, bq, qb)

    def qblock(args):
        qs, r2s = args
        gsz = qs.shape[0]

        def pblock(pb):
            def sub(a):
                qsub, r2sub = a
                hit = _gram_sq(qsub, pb) <= r2sub[:, None]
                return (hit.astype(jnp.uint8),
                        jnp.sum(hit, axis=1,
                                keepdims=True).astype(jnp.int32))
            if qb <= 0 or qb >= gsz:
                return sub((qs, r2s))
            m, c = jax.lax.map(sub, (qs.reshape(gsz // qb, qb, d),
                                     r2s.reshape(gsz // qb, qb)))
            return m.reshape(gsz, pb.shape[0]), c.reshape(gsz, 1)

        if bp >= npts:
            return pblock(p)
        m, c = jax.lax.map(pblock, p.reshape(npts // bp, bp, d))
        return (jnp.swapaxes(m, 0, 1).reshape(gsz, npts),
                jnp.swapaxes(c, 0, 1).reshape(gsz, -1))

    if bq >= nq:
        return qblock((q, r2))
    m, c = jax.lax.map(qblock, (q.reshape(nq // bq, bq, d),
                                r2.reshape(nq // bq, bq)))
    return m.reshape(nq, npts), c.reshape(nq, -1)


@functools.partial(jax.jit, static_argnames=("n_rings", "bg", "bb"))
def pdist_rankeval_xla(q: jax.Array, piv: jax.Array, coef: jax.Array,
                       lo: jax.Array, hi: jax.Array, n: jax.Array,
                       rg: jax.Array, n_rings: int = 20, bg: int = 8,
                       bb: int = 128):
    """Fused plan stage: query→pivot distances + rank eval at the
    widened-radius boundaries, one compiled program, no (G, 2B) distance
    staging buffer.

    ``q`` (B, d) queries; ``piv`` (G, d) pivots; ``coef`` (G, C);
    ``lo``/``hi``/``n`` (G,); ``rg`` (B,) guard-widened radii.  Returns
    ``(dq (B, G) f32, rank_lo (G, B) i32, rank_hi (G, B) i32)`` where
    rank_lo/hi evaluate at dq∓rg — exactly the staged planner's
    ``rankeval(concat(dq-rg, dq+rg))`` split back into halves.  ``bb``
    chunks the query (B) axis: the pivot plane and model params are
    loaded once per query chunk and reused, bounding the live
    (bb, bg) distance/rank tiles — the same query-blocked nest as
    :func:`pdist_xla`.
    """
    q = q.astype(jnp.float32)
    B, d = q.shape
    g = piv.shape[0]
    n_coef = coef.shape[1]
    rg = rg.astype(jnp.float32)
    gargs = (piv.astype(jnp.float32), coef, lo, hi, n)

    def bchunk(qargs):
        qc, rgc = qargs                             # (bb, d), (bb,)

        def gblock(args):
            pg, cg, log, hig, ng = args
            dq = jnp.sqrt(_gram_sq(qc, pg))         # (bb, bg)
            xlo = dq.T - rgc[None, :]               # (bg, bb)
            xhi = dq.T + rgc[None, :]
            rk_lo, _ = rank_math(xlo, cg, log, hig, ng, n_coef=n_coef,
                                 n_rings=n_rings)
            rk_hi, _ = rank_math(xhi, cg, log, hig, ng, n_coef=n_coef,
                                 n_rings=n_rings)
            return dq, rk_lo, rk_hi

        if bg >= g:
            return gblock(gargs)
        chunked = tuple(a.reshape(g // bg, bg, *a.shape[1:])
                        for a in gargs)
        dq, rk_lo, rk_hi = jax.lax.map(gblock, chunked)
        bc = qc.shape[0]
        return (jnp.swapaxes(dq, 0, 1).reshape(bc, g),
                rk_lo.reshape(g, bc), rk_hi.reshape(g, bc))

    if bb >= B:
        return bchunk((q, rg))
    dq, rk_lo, rk_hi = jax.lax.map(
        bchunk, (q.reshape(B // bb, bb, d), rg.reshape(B // bb, bb)))
    return (dq.reshape(B, g),
            jnp.swapaxes(rk_lo, 0, 1).reshape(g, B),
            jnp.swapaxes(rk_hi, 0, 1).reshape(g, B))


__all__ = ["pdist_xla", "rankeval_xla", "range_filter_xla",
           "pdist_rankeval_xla"]
