"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function computes the same math as its kernel with no tiling — tests
sweep shapes/dtypes and assert allclose between kernel (interpret mode on
CPU, compiled on TPU) and these references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pdist_ref(q: jax.Array, p: jax.Array, metric: str = "sql2") -> jax.Array:
    q = q.astype(jnp.float32)
    p = p.astype(jnp.float32)
    if metric == "sql2":
        d = q[:, None, :] - p[None, :, :]
        return jnp.sum(d * d, axis=-1)
    if metric == "l1":
        return jnp.sum(jnp.abs(q[:, None, :] - p[None, :, :]), axis=-1)
    if metric == "linf":
        return jnp.max(jnp.abs(q[:, None, :] - p[None, :, :]), axis=-1)
    raise ValueError(metric)


def rankeval_ref(x: jax.Array, coef: jax.Array, lo: jax.Array,
                 hi: jax.Array, n: jax.Array, n_rings: int = 20):
    """(rank, rid) — vectorized Chebyshev eval + ring id, float32 math."""
    x = x.astype(jnp.float32)
    lo = lo.astype(jnp.float32)[:, None]
    hi = hi.astype(jnp.float32)[:, None]
    nn = n.astype(jnp.float32)[:, None]
    t = jnp.clip((x - lo) / jnp.maximum(hi - lo, 1e-30) * 2.0 - 1.0,
                 -1.0, 1.0)
    g, c = coef.shape
    # T_k recurrence accumulated explicitly
    acc = jnp.zeros_like(t)
    t_km1 = jnp.ones_like(t)
    t_k = t
    for k in range(c):
        term = coef[:, k].astype(jnp.float32)[:, None]
        if k == 0:
            acc = acc + term * t_km1
        elif k == 1:
            acc = acc + term * t_k
        else:
            t_kp1 = 2.0 * t * t_k - t_km1
            t_km1, t_k = t_k, t_kp1
            acc = acc + term * t_k
    rank = jnp.clip(jnp.rint(acc), 0.0, jnp.maximum(nn - 1.0, 0.0))
    width = jnp.maximum(jnp.ceil(nn / float(n_rings)), 1.0)
    rid = jnp.clip(jnp.floor(rank / width), 0.0, float(n_rings - 1))
    return rank.astype(jnp.int32), rid.astype(jnp.int32)


def range_filter_ref(q: jax.Array, p: jax.Array, r: jax.Array, bp: int = 128):
    d2 = pdist_ref(q, p, "sql2")
    hit = d2 <= (r * r).astype(jnp.float32)[:, None]
    nq, npts = hit.shape
    pad = (-npts) % bp
    hp = jnp.pad(hit, ((0, 0), (0, pad)))
    cnt = jnp.sum(hp.reshape(nq, -1, bp), axis=-1).astype(jnp.int32)
    return hit.astype(jnp.uint8), cnt


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """Dense softmax attention with GQA head mapping; fp32 math."""
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    group = hq // hk
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / (d ** 0.5)
    if causal:
        mask = np.tril(np.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(jnp.asarray(mask)[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vf).astype(q.dtype)
