"""Backend dispatch policy for the Pallas kernels.

One place decides whether a ``pallas_call`` compiles or interprets:

  * TPU / GPU backends → compiled (``interpret=False``);
  * CPU (and anything else without a Pallas lowering) → ``interpret=True``;
  * ``REPRO_PALLAS_INTERPRET=0|1`` overrides the auto-selection — useful
    for debugging a miscompile on device (force interpret) or exercising
    the compile path in CI emulators (force compile).

Kernels take ``interpret: bool | None = None`` and resolve ``None``
through :func:`resolve_interpret`; nothing else hard-codes the mode.
"""
from __future__ import annotations

import os

import jax

_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def default_interpret() -> bool:
    """Auto policy: compile on TPU/GPU, interpret elsewhere (CPU)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env not in ("", "auto"):
        return env not in ("0", "false", "False")
    return jax.default_backend() not in _COMPILED_BACKENDS


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` → backend auto-selection; a bool is respected as-is."""
    return default_interpret() if interpret is None else bool(interpret)


__all__ = ["default_interpret", "resolve_interpret"]
