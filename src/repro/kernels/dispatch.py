"""Backend dispatch policy for the kernel pipeline.

One place decides which of the three executable lanes a kernel call
takes (:func:`kernel_mode`):

  * ``"pallas"``    — compiled ``pallas_call`` on backends with a Pallas
    lowering (TPU / GPU);
  * ``"xla"``       — the compiled lane for backends where ``pallas_call``
    cannot compile (XLA-CPU raises "Only interpret mode is supported"):
    the same tile-blocked math lowered through ``jax.jit`` to native XLA
    codegen (``kernels/xla.py``), where tile sizes become ``lax.map``
    cache-blocking chunks;
  * ``"interpret"`` — ``pallas_call(interpret=True)``, the CPU default:
    validates kernel semantics exactly as written, at interpreter speed.

``REPRO_INTERPRET=auto|on|off`` selects: ``auto`` interprets on CPU and
compiles pallas on TPU/GPU; ``on`` forces interpret everywhere; ``off``
forces the compiled lane (pallas where it compiles, xla on CPU).  The
legacy ``REPRO_PALLAS_INTERPRET=1|0`` spelling maps to on/off when
``REPRO_INTERPRET`` is unset.

Pallas kernels still take ``interpret: bool | None = None`` and resolve
``None`` through :func:`resolve_interpret`; the xla-vs-pallas choice is
made above them, in ``ops.py``, via :func:`kernel_mode`.
"""
from __future__ import annotations

import jax

from .. import env

_PALLAS_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def kernel_mode() -> str:
    """The executable lane for this process: interpret | xla | pallas."""
    v = env.get("REPRO_INTERPRET")
    if v == "auto":
        legacy = env.get("REPRO_PALLAS_INTERPRET")
        if legacy in ("1", "true"):
            v = "on"
        elif legacy in ("0", "false"):
            v = "off"
    if v == "on":
        return "interpret"
    pallas_compiles = jax.default_backend() in _PALLAS_BACKENDS
    if v == "off":
        return "pallas" if pallas_compiles else "xla"
    return "pallas" if pallas_compiles else "interpret"


def backend_key() -> str:
    """Tuning-table backend key: ``xla-cpu`` | ``tpu`` | ``gpu``."""
    b = jax.default_backend()
    if b == "tpu":
        return "tpu"
    if b in ("gpu", "cuda", "rocm"):
        return "gpu"
    return "xla-cpu"


def fused_plan_enabled() -> bool:
    """Whether the planner should take the fused pdist→rankeval launch.

    Fusion is a compiled-lane optimization: it is on for the ``pallas``
    and ``xla`` modes and off under interpret, where the staged pipeline
    is the validated reference (the fused kernel itself is still
    test-exercised in interpret mode explicitly).
    """
    return kernel_mode() != "interpret"


def default_interpret() -> bool:
    """Auto policy: True iff this process's lane is pallas-interpret."""
    return kernel_mode() == "interpret"


def compact_enabled() -> bool:
    """Whether the resident executor should gather certified candidate
    rows into a dense bucket before the filter kernels (DESIGN.md §13)
    instead of streaming the full padded slot array."""
    return env.get("REPRO_COMPACT") == "on"


def rows_dtype() -> str | None:
    """Requested reduced-precision filter-plane dtype for snapshot rows:
    ``"bf16"`` | ``"f16"``, or None when the plane is disabled (the
    default — f32 everywhere, bitwise-identical to prior releases)."""
    v = env.get("REPRO_ROWS_DTYPE")
    return None if v in ("off", "f32") else v


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` → backend auto-selection; a bool is respected as-is."""
    return default_interpret() if interpret is None else bool(interpret)


__all__ = ["kernel_mode", "backend_key", "fused_plan_enabled",
           "default_interpret", "resolve_interpret", "compact_enabled",
           "rows_dtype"]
