"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), validated in
interpret mode against the pure-jnp oracle in ref.py; ops.py holds the
jitted public wrappers (padding + platform dispatch).
"""
from . import ops, ref
from .ops import (flash_attention, pdist, pdist_rankeval, range_filter,
                  rankeval)

__all__ = ["ops", "ref", "pdist", "rankeval", "range_filter",
           "pdist_rankeval", "flash_attention"]
