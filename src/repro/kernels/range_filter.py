"""Fused refinement kernel: distance + threshold, no HBM distance matrix.

LIMS's refinement step (Alg. 1 line 30) computes exact distances for the
candidate pages and filters by radius. Fusing the compare into the distance
tile means only a uint8 mask (and per-tile counts) ever leaves VMEM —
16/32× less HBM write traffic than materializing fp32 distances, which is
what makes the refinement memory-bound term small on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret


def _range_filter_kernel(q_ref, p_ref, r2_ref, mask_ref, cnt_ref):
    q = q_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    r2 = r2_ref[...].astype(jnp.float32)[:, None]          # (bq, 1) radius²
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    pn = jnp.sum(p * p, axis=-1, keepdims=True)
    g = jax.lax.dot_general(q, p, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    d2 = jnp.maximum(qn + pn.T - 2.0 * g, 0.0)
    hit = d2 <= r2
    mask_ref[...] = hit.astype(jnp.uint8)
    cnt_ref[...] = jnp.sum(hit, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bq", "bp", "interpret"))
def range_filter_pallas(q: jax.Array, p: jax.Array, r: jax.Array,
                        bq: int = 128, bp: int = 128,
                        interpret: bool | None = None):
    """(mask (nq, np) uint8, counts (nq, np/bp) int32) for L2 ball q≤r.

    ``r`` is one radius per query row (nq,) — batched heterogeneous range
    queries in one launch. Counts are per (query, point-tile): the host
    uses them to skip empty tiles when gathering results. ``interpret=None``
    auto-selects by backend (compiled on TPU/GPU, interpreted on CPU).

    Point-major grid (query tiles iterate fastest), same as
    ``pdist_pallas``: each candidate tile is fetched once and reused
    across the query tiles; per-cell outputs are unchanged.
    """
    interpret = resolve_interpret(interpret)
    nq, d = q.shape
    npts, _ = p.shape
    assert nq % bq == 0 and npts % bp == 0
    r2 = (r * r).astype(jnp.float32)
    return pl.pallas_call(
        _range_filter_kernel,
        grid=(npts // bp, nq // bq),
        in_specs=[
            pl.BlockSpec((bq, d), lambda j, i: (i, 0)),
            pl.BlockSpec((bp, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bq,), lambda j, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, bp), lambda j, i: (i, j)),
            pl.BlockSpec((bq, 1), lambda j, i: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, npts), jnp.uint8),
            jax.ShapeDtypeStruct((nq, npts // bp), jnp.int32),
        ],
        interpret=interpret,
    )(q, p, r2)
