"""Jitted public wrappers around the kernel pipeline.

Handle padding to block multiples, lane dispatch and result un-padding.
These are the entry points the rest of the framework calls; nothing else
touches ``pallas_call`` or the XLA lowerings.

Lane dispatch (``repro.kernels.dispatch.kernel_mode``): each call
resolves one of three lanes — compiled ``pallas_call`` on TPU/GPU,
compiled jitted-XLA (``kernels/xla.py``) on CPU under
``REPRO_INTERPRET=off``, and ``pallas_call(interpret=True)`` otherwise
(the CPU default).  The wrappers resolve the policy per call and pass
explicit statics down, so flipping the env var between calls takes
effect (the kernels' jit caches key on lane-distinct functions and the
resolved static tile values).

Tile selection: explicit ``bq``/``bp``/``bg``/``bb`` arguments are
always respected (callers pinning shard-local tiles, the autotuner's
own micro-runs).  ``None`` means policy: interpret mode keeps the
static heuristics below (small-operand shrink + large point tiles to
amortize per-grid-cell interpreter cost); the compiled lanes first
consult the autotuner's tuning table (``kernels/autotune.py``,
``REPRO_AUTOTUNE``) and fall back to the static compiled heuristics on
a miss.  Blocks still shrink to fit small operands — a batch of 3
queries pads to an 8-row tile, not a 128-row one — preserving the
8×128 f32 tile alignment the TPU lane wants (the xla lane only needs
the 8-row sublane granularity; its tiles are ``lax.map`` cache-blocking
chunks).

Shard-local sizing: under ``shard_map`` (the cluster-sharded executor)
each device traces these wrappers with *shard-local* shapes, so the
automatic policy — tuning-table buckets included — sizes blocks to the
per-device slice.  Callers that pin blocks explicitly should derive
them from local operand sizes via :func:`local_blocks` instead of
global corpus constants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import autotune
from .dispatch import fused_plan_enabled, kernel_mode
from .flash_attention import flash_attention_pallas
from .fused import pdist_rankeval_pallas
from .pdist import pdist_pallas
from .range_filter import range_filter_pallas
from .rankeval import rankeval_pallas
from .xla import (pdist_rankeval_xla, pdist_xla, range_filter_xla,
                  rankeval_xla)

from ..obs import registry as _obs

_LANE = 128     # TPU lane width: last-dim tiles stay multiples of this
_SUBLANE = 8    # f32 sublane width: leading-dim tiles align to this


def _count_launch(name: str, mode: str, probe) -> None:
    """Per-kernel dispatch counter (``kernels.<name>.launches`` plus a
    per-lane breakdown).  These wrappers run both eagerly and inside
    jit/shard_map traces; a traced call is bookkeeping at *trace* time,
    not a launch per execution, so tracer operands are skipped — the
    eager call sites (the planner's staged path, the paged backend's
    per-round refinement) are the ones that count."""
    if not _obs.enabled() or isinstance(probe, jax.core.Tracer):
        return
    _obs.count(f"kernels.{name}.launches")
    _obs.count(f"kernels.{name}.{mode}")


def _interpret() -> bool:
    return kernel_mode() == "interpret"


def _tile(n: int, block: int, mult: int = _SUBLANE) -> int:
    """Largest useful block: ``block`` capped at n rounded up to ``mult``."""
    return min(block, -(-max(n, 1) // mult) * mult)


def _lane_mult(interp: bool) -> int:
    """Lane-dim tile granularity: interpret mode can shrink below the
    128-lane TPU tile; the compiled path keeps full alignment."""
    return _SUBLANE if interp else _LANE


def _mode_lane(mode: str) -> int:
    """Lane-dim granularity per lane: only the pallas-compiled lane
    needs the 128-lane alignment; interpret and xla chunk at sublane."""
    return _LANE if mode == "pallas" else _SUBLANE


def _point_block(npts: int, bp: int, interp: bool) -> int:
    """Point-dim tile. Interpret mode executes the kernel body once per
    grid cell in Python, so its cost scales with the cell count, not the
    tile size — grow the tile to cover many points per cell. The compiled
    path keeps the VMEM-sized default."""
    if interp:
        bp = max(bp, 4096)
    return _tile(npts, bp, _lane_mult(interp))


def pad_to(x: jax.Array, mult: int, axis: int = 0,
           fill: float = 0.0) -> jax.Array:
    """Pad ``x`` along ``axis`` with ``fill`` to the next multiple of
    ``mult`` (identity when already aligned)."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _pad_rows(x: jax.Array, mult: int, fill: float = 0.0) -> jax.Array:
    return pad_to(x, mult, axis=0, fill=fill)


def _qp_tiles(nq: int, npts: int, d: int, metric: str, mode: str,
              bq: int | None, bp: int | None, qb: int | None,
              kernel: str, consult: bool = True) -> tuple[int, int, int]:
    """Resolve the (bq, bp, qb) triple for a query×points kernel under
    the current lane: explicit values win, then the tuning table
    (compiled lanes; ``consult=False`` skips it — the autotuner's own
    static-baseline resolution), then static heuristics.

    ``qb`` is the query *sub*-block of the xla lane's query-blocked
    nest (see ``kernels/xla.py``); 0 means no sub-blocking.  It is
    meaningful only on the xla lane — the pallas grid is point-major,
    which gives the same point-tile reuse structurally — and must
    divide ``bq`` (misaligned overrides degrade to 0, never to a bad
    reshape)."""
    interp = mode == "interpret"
    if consult and not interp and (bq is None or bp is None or qb is None):
        t = autotune.tiles_for(kernel, metric, {"q": nq, "p": npts, "d": d})
        if t:
            bq = t["bq"] if bq is None else bq
            bp = t["bp"] if bp is None else bp
            qb = t.get("qb") if qb is None else qb
    if kernel == "pdist" and metric in ("l1", "linf") and mode != "xla":
        # the pallas kernels cap bq at 32 for the broadcast metrics —
        # cap before padding so unaligned query counts pad to the capped
        # tile, not past it
        bq = min(128 if bq is None else bq, 32)
    bq = _tile(nq, 128 if bq is None else bq)
    if interp:
        bp = _point_block(npts, 128 if bp is None else bp, interp)
    else:
        bp = _tile(npts, 128 if bp is None else bp, _mode_lane(mode))
    if mode != "xla" or qb is None or qb >= min(bq, nq):
        qb = 0
    else:
        qb = _tile(min(bq, nq), qb)
        if qb <= 0 or min(bq, nq) % qb:
            qb = 0
    return bq, bp, qb


def static_tiles(kernel: str, metric: str | None,
                 dims: dict[str, int]) -> dict[str, int]:
    """Static-heuristic tiles for ``kernel`` at ``dims`` under the
    current lane — the autotuner's baseline candidate (never consults
    the tuning table, so tuning can't recurse into a lookup).  ``qb``
    is reported as ``bq`` ("no sub-blocking") so the dict validates as
    a table entry."""
    mode = kernel_mode()
    interp = mode == "interpret"
    if kernel in ("pdist", "range_filter"):
        bq, bp, qb = _qp_tiles(dims["q"], dims["p"], dims["d"],
                               metric or "sql2", mode, None, None, None,
                               kernel, consult=False)
        return {"bq": bq, "bp": bp, "qb": qb or bq}
    if kernel in ("rankeval", "pdist_rankeval"):
        g, b = dims["g"], dims["b"]
        bg = _tile(g, 64 if interp else 8)
        bb = _point_block(b, 128, interp) if interp \
            else _tile(b, 128, _mode_lane(mode))
        return {"bg": bg, "bb": bb}
    raise ValueError(f"unknown kernel {kernel!r}")


def local_blocks(nq: int, npts: int, bq: int | None = None,
                 bp: int | None = None, metric: str = "sql2",
                 d: int = 8) -> tuple[int, int]:
    """Resolve the (bq, bp) tile pair for (possibly shard-local) operand
    sizes under the current dispatch policy: query tiles align to the
    sublane width, point tiles grow to amortize interpret-mode grid cells
    (compiled lanes instead consult the autotune table) and cap at the
    local point count (lane-aligned per backend).

    This is exactly what ``pdist``/``range_filter`` resolve internally
    from the shapes they receive — callers inside ``shard_map`` get
    shard-local sizing for free.  The helper exists for code that needs
    the policy *outside* a kernel call: benchmarks reporting the tile a
    measurement ran with, and tile-alignment property tests.  ``d`` only
    affects the compiled lanes' tuning-table shape bucket.  (The xla
    lane's query sub-block ``qb`` is an internal chunking of ``bq`` and
    is not part of this pair.)"""
    tbq, tbp, _ = _qp_tiles(nq, npts, d, metric, kernel_mode(), bq, bp,
                            None, "pdist")
    return tbq, tbp


def pdist(q, p, metric: str = "sql2", bq: int | None = None,
          bp: int | None = None, qb: int | None = None):
    """Pairwise distances with automatic padding. metric: sql2 | l1 | linf.
    sql2 returns squared distances (use ``jnp.sqrt`` or square radii).
    ``qb`` is the xla lane's query sub-block (``None`` → policy; ignored
    on the pallas/interpret lanes, whose point-major grid already reuses
    point tiles)."""
    q = jnp.asarray(q)
    p = jnp.asarray(p)
    nq, npts = q.shape[0], p.shape[0]
    mode = kernel_mode()
    _count_launch("pdist", mode, q)
    bq, bp, qb = _qp_tiles(nq, npts, q.shape[1], metric, mode, bq, bp,
                           qb, "pdist")
    qp = _pad_rows(q, bq)
    pp = _pad_rows(p, bp)
    if mode == "xla":
        out = pdist_xla(qp, pp, metric=metric, bq=bq, bp=bp, qb=qb)
    else:
        out = pdist_pallas(qp, pp, metric=metric, bq=bq, bp=bp,
                           interpret=mode == "interpret")
    return out[:nq, :npts]


def rankeval(x, coef, lo, hi, n, n_rings: int = 20,
             bg: int | None = None, bb: int | None = None):
    """Batched rank-model eval (G groups × B values) + ring ids.

    ``bg``/``bb`` override the group/value tile sizes (``None`` → policy
    default, which adapts to the — possibly shard-local — operand and,
    on the compiled lanes, consults the tuning table)."""
    x = jnp.asarray(x, jnp.float32)
    coef = jnp.asarray(coef, jnp.float32)
    g, b = x.shape
    mode = kernel_mode()
    _count_launch("rankeval", mode, x)
    interp = mode == "interpret"
    if not interp and (bg is None or bb is None):
        t = autotune.tiles_for("rankeval", None,
                               {"g": g, "b": b, "c": coef.shape[1]})
        if t:
            bg = t["bg"] if bg is None else bg
            bb = t["bb"] if bb is None else bb
    bg = _tile(g, 64 if interp else 8) if bg is None else _tile(g, bg)
    # an explicit bb is respected (not grown) but keeps the backend's
    # lane granularity so an override can never break tile alignment
    if interp:
        bb = _point_block(b, 128, interp) if bb is None \
            else _tile(b, bb, _lane_mult(interp))
    else:
        bb = _tile(b, 128 if bb is None else bb, _mode_lane(mode))
    gp, bp_ = (-g) % bg, (-b) % bb
    xq = jnp.pad(x, ((0, gp), (0, bp_)))
    coefq = jnp.pad(coef, ((0, gp), (0, 0)))
    loq = jnp.pad(jnp.asarray(lo, jnp.float32), (0, gp))
    hiq = jnp.pad(jnp.asarray(hi, jnp.float32), (0, gp), constant_values=1.0)
    nq_ = jnp.pad(jnp.asarray(n, jnp.float32), (0, gp))
    if mode == "xla":
        rank, rid = rankeval_xla(xq, coefq, loq, hiq, nq_,
                                 n_rings=n_rings, bg=bg, bb=bb)
    else:
        rank, rid = rankeval_pallas(xq, coefq, loq, hiq, nq_,
                                    n_rings=n_rings, bg=bg, bb=bb,
                                    interpret=interp)
    return rank[:g, :b], rid[:g, :b]


def range_filter(q, p, r, bq: int | None = None, bp: int | None = None,
                 qb: int | None = None):
    """Fused L2-ball membership mask for batched range queries.
    ``qb`` as in :func:`pdist`."""
    q = jnp.asarray(q)
    p = jnp.asarray(p)
    r = jnp.asarray(r, jnp.float32)
    nq, npts = q.shape[0], p.shape[0]
    mode = kernel_mode()
    _count_launch("range_filter", mode, q)
    bq, bp, qb = _qp_tiles(nq, npts, q.shape[1], "sql2", mode, bq, bp,
                           qb, "range_filter")
    qp = _pad_rows(q, bq)
    pp = _pad_rows(p, bp, fill=np.inf)     # padding rows never match
    rp = _pad_rows(r, bq, fill=-1.0)
    if mode == "xla":
        mask, cnt = range_filter_xla(qp, pp, rp, bq=bq, bp=bp, qb=qb)
    else:
        mask, cnt = range_filter_pallas(qp, pp, rp, bq=bq, bp=bp,
                                        interpret=mode == "interpret")
    return mask[:nq, :npts], cnt[:nq]


def pdist_rankeval(q, piv, coef, lo, hi, n, rg, n_rings: int = 20,
                   bg: int | None = None, bb: int | None = None):
    """Fused plan stage: query→pivot L2 distances + rank eval at the
    widened-radius boundaries dq∓rg, one launch, no staged (G, 2B)
    distance buffer.

    ``q`` (B, d); ``piv`` (G, d); ``coef`` (G, C); ``lo``/``hi``/``n``
    (G,); ``rg`` (B,).  Returns ``(dq (B, G) f32, rank_lo (G, B) i32,
    rank_hi (G, B) i32)`` — bit-identical (within a lane) to the staged
    ``sqrt(max(pdist, 0))`` + ``rankeval(concat(dq-rg, dq+rg))``
    pipeline; the planner selects between them via
    ``dispatch.fused_plan_enabled``.
    """
    q = jnp.asarray(q, jnp.float32)
    piv = jnp.asarray(piv, jnp.float32)
    coef = jnp.asarray(coef, jnp.float32)
    B, d = q.shape
    G, C = coef.shape
    mode = kernel_mode()
    _count_launch("pdist_rankeval", mode, q)
    interp = mode == "interpret"
    if not interp and (bg is None or bb is None):
        t = autotune.tiles_for("pdist_rankeval", None,
                               {"g": G, "b": B, "d": d, "c": C})
        if t:
            bg = t["bg"] if bg is None else bg
            bb = t["bb"] if bb is None else bb
    bg = _tile(G, 64 if interp else 8) if bg is None else _tile(G, bg)
    bb = _tile(B, 128 if bb is None else bb, _mode_lane(mode))
    gp = (-G) % bg
    qp = _pad_rows(q, bb)
    rgp = _pad_rows(jnp.asarray(rg, jnp.float32), bb)
    pivp = _pad_rows(piv, bg)
    coefp = jnp.pad(coef, ((0, gp), (0, 0)))
    lop = jnp.pad(jnp.asarray(lo, jnp.float32), (0, gp))
    hip = jnp.pad(jnp.asarray(hi, jnp.float32), (0, gp),
                  constant_values=1.0)
    np_ = jnp.pad(jnp.asarray(n, jnp.float32), (0, gp))
    if mode == "xla":
        dq, rlo, rhi = pdist_rankeval_xla(qp, pivp, coefp, lop, hip, np_,
                                          rgp, n_rings=n_rings, bg=bg,
                                          bb=bb)
    else:
        dq, rlo, rhi = pdist_rankeval_pallas(qp, pivp, coefp, lop, hip,
                                             np_, rgp, n_rings=n_rings,
                                             bg=bg, bb=bb,
                                             interpret=interp)
    return dq[:B, :G], rlo[:G, :B], rhi[:G, :B]


def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    """Padded flash attention: (B,Hq,Sq,D) × (B,Hk,Sk,D) → (B,Hq,Sq,D)."""
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    _count_launch("flash_attention", kernel_mode(), q)
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    # flash attention has no jitted-XLA lane: it compiles only where
    # pallas_call does (TPU/GPU); everywhere else it stays in interpret
    # mode even under REPRO_INTERPRET=off
    out = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                 interpret=kernel_mode() != "pallas",
                                 kv_len=sk if pk else None)
    return out[:, :, :sq]


__all__ = ["pdist", "rankeval", "range_filter", "pdist_rankeval",
           "flash_attention", "pad_to", "local_blocks", "static_tiles",
           "fused_plan_enabled"]
