"""Jitted public wrappers around the Pallas kernels.

Handle padding to block multiples, platform dispatch and result
un-padding. These are the entry points the rest of the framework calls;
nothing else touches pallas_call.

Dispatch policy (``repro.kernels.dispatch``): ``pallas_call`` compiles on
TPU/GPU and runs in interpret mode on CPU, overridable via
``REPRO_PALLAS_INTERPRET=0|1``. The wrappers resolve the policy per call
and pass an explicit bool down, so flipping the env var between calls
takes effect (the kernels' jit caches key on the resolved static value).

Tiling glue: block sizes shrink to fit small operands — a batch of 3
queries pads to an 8-row tile, not a 128-row one — which keeps the
interpret-mode batch engine cheap at small batch sizes while preserving
the 8×128 f32 tile alignment the TPU path wants.

Shard-local sizing: under ``shard_map`` (the cluster-sharded executor)
each device traces these wrappers with *shard-local* shapes, so the
automatic `_tile`/`_point_block` policy already sizes blocks to the
per-device slice — a 64k-row corpus split 8 ways tiles like an 8k-row
one.  Callers that pin blocks explicitly (autotuners, benchmarks) should
derive them from the local operand sizes via :func:`local_blocks`
instead of global corpus constants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .dispatch import default_interpret
from .flash_attention import flash_attention_pallas
from .pdist import pdist_pallas
from .range_filter import range_filter_pallas
from .rankeval import rankeval_pallas

_LANE = 128     # TPU lane width: last-dim tiles stay multiples of this
_SUBLANE = 8    # f32 sublane width: leading-dim tiles align to this


def _interpret() -> bool:
    return default_interpret()


def _tile(n: int, block: int, mult: int = _SUBLANE) -> int:
    """Largest useful block: ``block`` capped at n rounded up to ``mult``."""
    return min(block, -(-max(n, 1) // mult) * mult)


def _lane_mult(interp: bool) -> int:
    """Lane-dim tile granularity: interpret mode can shrink below the
    128-lane TPU tile; the compiled path keeps full alignment."""
    return _SUBLANE if interp else _LANE


def _point_block(npts: int, bp: int, interp: bool) -> int:
    """Point-dim tile. Interpret mode executes the kernel body once per
    grid cell in Python, so its cost scales with the cell count, not the
    tile size — grow the tile to cover many points per cell. The compiled
    path keeps the VMEM-sized default."""
    if interp:
        bp = max(bp, 4096)
    return _tile(npts, bp, _lane_mult(interp))


def pad_to(x: jax.Array, mult: int, axis: int = 0,
           fill: float = 0.0) -> jax.Array:
    """Pad ``x`` along ``axis`` with ``fill`` to the next multiple of
    ``mult`` (identity when already aligned)."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _pad_rows(x: jax.Array, mult: int, fill: float = 0.0) -> jax.Array:
    return pad_to(x, mult, axis=0, fill=fill)


def local_blocks(nq: int, npts: int, bq: int = 128,
                 bp: int = 128) -> tuple[int, int]:
    """Resolve the (bq, bp) tile pair for (possibly shard-local) operand
    sizes under the current dispatch policy: query tiles align to the
    sublane width, point tiles grow to amortize interpret-mode grid cells
    and cap at the local point count (lane-aligned).

    This is exactly what ``pdist``/``range_filter`` resolve internally
    from the shapes they receive — callers inside ``shard_map`` get
    shard-local sizing for free.  The helper exists for code that needs
    the policy *outside* a kernel call: autotuners seeding a search, and
    benchmarks reporting the tile a measurement ran with."""
    interp = _interpret()
    return _tile(nq, bq), _point_block(npts, bp, interp)


def pdist(q, p, metric: str = "sql2", bq: int = 128, bp: int = 128):
    """Pairwise distances with automatic padding. metric: sql2 | l1 | linf.
    sql2 returns squared distances (use ``jnp.sqrt`` or square radii)."""
    q = jnp.asarray(q)
    p = jnp.asarray(p)
    nq, npts = q.shape[0], p.shape[0]
    interp = _interpret()
    bq = _tile(nq, bq)
    bp = _point_block(npts, bp, interp)
    qp = _pad_rows(q, bq)
    pp = _pad_rows(p, bp)
    out = pdist_pallas(qp, pp, metric=metric, bq=bq, bp=bp,
                       interpret=interp)
    return out[:nq, :npts]


def rankeval(x, coef, lo, hi, n, n_rings: int = 20,
             bg: int | None = None, bb: int | None = None):
    """Batched rank-model eval (G groups × B values) + ring ids.

    ``bg``/``bb`` override the group/value tile sizes (``None`` → policy
    default, which adapts to the — possibly shard-local — operand)."""
    x = jnp.asarray(x, jnp.float32)
    coef = jnp.asarray(coef, jnp.float32)
    g, b = x.shape
    interp = _interpret()
    bg = _tile(g, 64 if interp else 8) if bg is None else _tile(g, bg)
    # an explicit bb is respected (not grown) but keeps the backend's
    # lane granularity so an override can never break tile alignment
    bb = _point_block(b, 128, interp) if bb is None \
        else _tile(b, bb, _lane_mult(interp))
    gp, bp_ = (-g) % bg, (-b) % bb
    xq = jnp.pad(x, ((0, gp), (0, bp_)))
    coefq = jnp.pad(coef, ((0, gp), (0, 0)))
    loq = jnp.pad(jnp.asarray(lo, jnp.float32), (0, gp))
    hiq = jnp.pad(jnp.asarray(hi, jnp.float32), (0, gp), constant_values=1.0)
    nq_ = jnp.pad(jnp.asarray(n, jnp.float32), (0, gp))
    rank, rid = rankeval_pallas(xq, coefq, loq, hiq, nq_, n_rings=n_rings,
                                bg=bg, bb=bb, interpret=interp)
    return rank[:g, :b], rid[:g, :b]


def range_filter(q, p, r, bq: int = 128, bp: int = 128):
    """Fused L2-ball membership mask for batched range queries."""
    q = jnp.asarray(q)
    p = jnp.asarray(p)
    r = jnp.asarray(r, jnp.float32)
    nq, npts = q.shape[0], p.shape[0]
    interp = _interpret()
    bq = _tile(nq, bq)
    bp = _point_block(npts, bp, interp)
    qp = _pad_rows(q, bq)
    pp = _pad_rows(p, bp, fill=np.inf)     # padding rows never match
    rp = _pad_rows(r, bq, fill=-1.0)
    mask, cnt = range_filter_pallas(qp, pp, rp, bq=bq, bp=bp,
                                    interpret=interp)
    return mask[:nq, :npts], cnt[:nq]


def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    """Padded flash attention: (B,Hq,Sq,D) × (B,Hk,Sk,D) → (B,Hq,Sq,D)."""
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                 interpret=_interpret(),
                                 kv_len=sk if pk else None)
    return out[:, :, :sq]


__all__ = ["pdist", "rankeval", "range_filter", "flash_attention",
           "pad_to", "local_blocks"]
