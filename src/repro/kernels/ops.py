"""Jitted public wrappers around the Pallas kernels.

Handle padding to block multiples, platform dispatch (compiled on TPU,
``interpret=True`` elsewhere) and result un-padding. These are the entry
points the rest of the framework calls; nothing else touches pallas_call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .flash_attention import flash_attention_pallas
from .pdist import pdist_pallas
from .range_filter import range_filter_pallas
from .rankeval import rankeval_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jax.Array, mult: int, fill: float = 0.0) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0)


def pdist(q, p, metric: str = "sql2", bq: int = 128, bp: int = 128):
    """Pairwise distances with automatic padding. metric: sql2 | l1 | linf.
    sql2 returns squared distances (use ``jnp.sqrt`` or square radii)."""
    q = jnp.asarray(q)
    p = jnp.asarray(p)
    nq, npts = q.shape[0], p.shape[0]
    qp = _pad_rows(q, bq)
    pp = _pad_rows(p, bp)
    out = pdist_pallas(qp, pp, metric=metric, bq=bq, bp=bp,
                       interpret=_interpret())
    return out[:nq, :npts]


def rankeval(x, coef, lo, hi, n, n_rings: int = 20):
    """Batched rank-model eval (G groups × B values) + ring ids."""
    x = jnp.asarray(x, jnp.float32)
    coef = jnp.asarray(coef, jnp.float32)
    g, b = x.shape
    bg, bb = 8, 128
    gp, bp_ = (-g) % bg, (-b) % bb
    xq = jnp.pad(x, ((0, gp), (0, bp_)))
    coefq = jnp.pad(coef, ((0, gp), (0, 0)))
    loq = jnp.pad(jnp.asarray(lo, jnp.float32), (0, gp))
    hiq = jnp.pad(jnp.asarray(hi, jnp.float32), (0, gp), constant_values=1.0)
    nq_ = jnp.pad(jnp.asarray(n, jnp.float32), (0, gp))
    rank, rid = rankeval_pallas(xq, coefq, loq, hiq, nq_, n_rings=n_rings,
                                bg=bg, bb=bb, interpret=_interpret())
    return rank[:g, :b], rid[:g, :b]


def range_filter(q, p, r, bq: int = 128, bp: int = 128):
    """Fused L2-ball membership mask for batched range queries."""
    q = jnp.asarray(q)
    p = jnp.asarray(p)
    r = jnp.asarray(r, jnp.float32)
    nq, npts = q.shape[0], p.shape[0]
    qp = _pad_rows(q, bq)
    pp = _pad_rows(p, bp, fill=np.inf)     # padding rows never match
    rp = _pad_rows(r, bq, fill=-1.0)
    mask, cnt = range_filter_pallas(qp, pp, rp, bq=bq, bp=bp,
                                    interpret=_interpret())
    return mask[:nq, :npts], cnt[:nq]


def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    """Padded flash attention: (B,Hq,Sq,D) × (B,Hk,Sk,D) → (B,Hq,Sq,D)."""
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                 interpret=_interpret(),
                                 kv_len=sk if pk else None)
    return out[:, :, :sq]


__all__ = ["pdist", "rankeval", "range_filter", "flash_attention"]
