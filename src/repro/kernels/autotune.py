"""Per-backend kernel tile autotuner with a persistent tuning table.

The static heuristics in ``ops.local_blocks``/``ops._point_block`` pick
one tile size per kernel regardless of backend or operand shape.  This
module replaces them on the compiled lanes with a measured table:

* **Key**: ``backend/kernel/metric/dim=bucket…`` where ``backend`` is
  ``dispatch.backend_key()`` (xla-cpu | tpu | gpu) and every operand
  dimension is bucketed to the next power of two (floor 8) — one entry
  covers a whole shape bucket, and because ``ops`` pads operands to tile
  multiples anyway, tuning at the bucket shape measures the same
  computation the serving path runs.
* **Value**: ``{"tiles": {"bq": …, "bp": …, "qb": …}, "us": best_time,
  "static_us": static_time, "v": 2}``.
* **Search**: a small per-backend candidate grid (always containing the
  static-heuristic tile), each candidate timed via a compiled micro-run
  (warm-up call to compile, then best-of-N).  The winner is then
  *paired-timed* against the static heuristic and accepted only when it
  beats it by more than a noise margin — a near-tie would otherwise pin
  one noisy measurement into the cache forever, and a regression (a
  "tuned" tile slower than the heuristic at serving time) could ride
  along.  ``revalidate()`` re-measures cached entries whose recorded
  win may have evaporated (new kernel code, different machine load).
* **Persistence**: repo-shipped defaults (``tuning_defaults.json`` next
  to this file) overlaid by a user cache (``~/.cache/repro-tune.json``
  or ``$REPRO_TUNE_CACHE``), written atomically (temp + rename).
  Entries failing validation — wrong schema version, missing or
  non-integer tiles, alignment violations — are dropped on load and
  retuned under ``force``.

``REPRO_AUTOTUNE`` controls consultation (see ``repro.env``): ``off`` →
static heuristics only; ``on`` (default) → table lookups, heuristic on
miss, never tunes implicitly (steady-state serving pays zero tuning
cost); ``force`` → tune misses now and write the cache.  Interpret mode
never consults the table (``ops`` keeps today's interpret heuristics).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from .. import env
from . import dispatch

SCHEMA_VERSION = 2   # v2: query×points kernels gained the "qb" sub-block
_DEFAULTS_PATH = Path(__file__).parent / "tuning_defaults.json"

# tuned entries must beat the static heuristic by this fraction on a
# paired best-of-3 to be accepted (and to survive revalidation)
_NOISE_MARGIN = 0.03

_lock = threading.RLock()
_table: dict[str, dict] | None = None

# tile-name sets per kernel (also the validation contract); "qb" is the
# xla lane's query sub-block — "no sub-blocking" is stored as qb == bq
_TILE_NAMES = {
    "pdist": ("bq", "bp", "qb"),
    "range_filter": ("bq", "bp", "qb"),
    "rankeval": ("bg", "bb"),
    "pdist_rankeval": ("bg", "bb"),
}
# tile axes that address the 128-wide lane dimension on TPU/GPU
_LANE_TILES = {"pdist": ("bp",), "range_filter": ("bp",),
               "rankeval": ("bb",), "pdist_rankeval": ("bb",)}


def mode() -> str:
    return env.get("REPRO_AUTOTUNE")


def cache_path() -> Path:
    p = env.get("REPRO_TUNE_CACHE")
    if p:
        return Path(p)
    return Path(os.path.expanduser("~")) / ".cache" / "repro-tune.json"


def bucket(n: int) -> int:
    """Next power of two, floor 8 — the shape-bucketing of table keys."""
    return max(8, 1 << (int(max(n, 1)) - 1).bit_length())


def _key(backend: str, kernel: str, metric: str | None,
         bdims: dict[str, int]) -> str:
    dims = "/".join(f"{k}={v}" for k, v in sorted(bdims.items()))
    return f"{backend}/{kernel}/{metric or '-'}/{dims}"


def _valid_entry(backend: str, kernel: str, ent) -> bool:
    if not isinstance(ent, dict) or ent.get("v") != SCHEMA_VERSION:
        return False
    tiles = ent.get("tiles")
    names = _TILE_NAMES.get(kernel)
    if names is None or not isinstance(tiles, dict):
        return False
    if set(tiles) != set(names):
        return False
    for name, t in tiles.items():
        if not isinstance(t, int) or t <= 0 or t % 8 != 0:
            return False
        if backend in ("tpu", "gpu") and name in _LANE_TILES[kernel] \
                and t % 128 != 0:
            return False
    if not isinstance(ent.get("us"), (int, float)):
        return False
    return True


def _load_file(path: Path) -> dict[str, dict]:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    if not isinstance(data, dict):
        return {}
    entries = data.get("entries")
    return entries if isinstance(entries, dict) else {}


def _entries() -> dict[str, dict]:
    """Validated merged table (shipped defaults overlaid by user cache)."""
    global _table
    with _lock:
        if _table is None:
            merged: dict[str, dict] = {}
            for path in (_DEFAULTS_PATH, cache_path()):
                for key, ent in _load_file(path).items():
                    parts = key.split("/")
                    if len(parts) < 3:
                        continue
                    if _valid_entry(parts[0], parts[1], ent):
                        merged[key] = ent
            _table = merged
        return _table


def _reset() -> None:
    """Drop the in-memory table (tests re-point REPRO_TUNE_CACHE)."""
    global _table
    with _lock:
        _table = None


def tiles_for(kernel: str, metric: str | None,
              dims: dict[str, int]) -> dict[str, int] | None:
    """Tuned tiles for this call, or None → caller's static heuristics.

    Looks up the (backend, kernel, metric, shape-bucket) entry; under
    ``REPRO_AUTOTUNE=force`` a miss (or an entry invalidated on load)
    is tuned on the spot and cached.
    """
    m = mode()
    if m == "off":
        return None
    backend = dispatch.backend_key()
    bdims = {k: bucket(v) for k, v in dims.items()}
    ent = _entries().get(_key(backend, kernel, metric, bdims))
    if ent is not None:
        return dict(ent["tiles"])
    if m == "force":
        return dict(tune(kernel, metric, dims)["tiles"])
    return None


# ---------------------------------------------------------------- tuning

def _round8(t: int) -> int:
    return max(8, (int(t) + 7) // 8 * 8)


def _candidates(backend: str, kernel: str, metric: str | None,
                bd: dict[str, int]) -> list[dict[str, int]]:
    """Per-backend candidate tile grid; always includes the static
    heuristic so "tuned" can only tie or beat it on the measurements."""
    if kernel in ("pdist", "range_filter"):
        nq, npts, d = bd["q"], bd["p"], bd["d"]
        if backend == "xla-cpu":
            if metric in (None, "sql2"):
                bqs = {128, nq}
                bps = {128, 1024, 8192, npts}
                qbs = {16, 32, 0}        # 0 -> qb = bq (no sub-blocking)
            else:  # broadcast (bq, bp, d) intermediate — bound it
                bqs = {32, 128}
                bps = {128, 512, 2048}
                qbs = {8, 0}
        else:  # pallas lanes: bp rides the 128-lane axis; the grid is
            # point-major so qb sub-blocking adds nothing — pin qb = bq
            bqs = {128, 256}
            bps = {128, 256, 512, 1024}
            qbs = {0}
        cands = []
        for bq in bqs:
            for bp in bps:
                bqf = min(_round8(bq), nq)
                bpf = min(_round8(bp), npts)
                for qb in qbs:
                    # bucket dims are powers of two (floor 8), so a
                    # clamped qb always divides bq
                    qbf = bqf if qb == 0 else min(_round8(qb), bqf)
                    cands.append({"bq": bqf, "bp": bpf, "qb": qbf})
        if metric in ("l1", "linf"):
            cands = [c for c in cands
                     if c["qb"] * c["bp"] * d * 4 <= 512 * 2 ** 20]
    elif kernel in ("rankeval", "pdist_rankeval"):
        g, b = bd["g"], bd["b"]
        if backend == "xla-cpu":
            bgs = {8, 64, g}
            bbs = {128, 2048, b}
        else:
            bgs = {8, 16, 32}
            bbs = {128, 256, 512}
        cands = [{"bg": min(_round8(bg), g), "bb": min(_round8(bb), b)}
                 for bg in bgs for bb in bbs]
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    # lane-align + dedup, preserving a stable order
    if backend in ("tpu", "gpu"):
        for c in cands:
            for name in _LANE_TILES[kernel]:
                c[name] = max(128, (c[name] + 127) // 128 * 128)
    seen, out = set(), []
    for c in sorted(cands, key=lambda c: sorted(c.items())):
        key = tuple(sorted(c.items()))
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def _bench_thunk(kernel: str, metric: str | None, bd: dict[str, int],
                 tiles: dict[str, int]):
    """A zero-arg callable running one compiled kernel call at the
    bucket shape with explicit tiles (explicit tiles bypass the table,
    so tuning never recurses into a lookup)."""
    from . import ops  # deferred: ops imports this module
    rng = np.random.default_rng(0)
    if kernel in ("pdist", "range_filter"):
        q = rng.standard_normal((bd["q"], bd["d"])).astype(np.float32)
        p = rng.standard_normal((bd["p"], bd["d"])).astype(np.float32)
        if kernel == "pdist":
            return lambda: ops.pdist(q, p, metric or "sql2",
                                     bq=tiles["bq"], bp=tiles["bp"],
                                     qb=tiles.get("qb"))
        r = np.full((bd["q"],), 1.0, np.float32)
        return lambda: ops.range_filter(q, p, r, bq=tiles["bq"],
                                        bp=tiles["bp"],
                                        qb=tiles.get("qb"))
    if kernel == "rankeval":
        x = rng.standard_normal((bd["g"], bd["b"])).astype(np.float32)
        coef = rng.standard_normal((bd["g"], bd["c"])).astype(np.float32)
        lo = np.zeros((bd["g"],), np.float32)
        hi = np.ones((bd["g"],), np.float32)
        n = np.full((bd["g"],), 1000.0, np.float32)
        return lambda: ops.rankeval(x, coef, lo, hi, n, bg=tiles["bg"],
                                    bb=tiles["bb"])
    if kernel == "pdist_rankeval":
        q = rng.standard_normal((bd["b"], bd["d"])).astype(np.float32)
        piv = rng.standard_normal((bd["g"], bd["d"])).astype(np.float32)
        coef = rng.standard_normal((bd["g"], bd["c"])).astype(np.float32)
        lo = np.zeros((bd["g"],), np.float32)
        hi = np.ones((bd["g"],), np.float32)
        n = np.full((bd["g"],), 1000.0, np.float32)
        rg = np.full((bd["b"],), 0.5, np.float32)
        return lambda: ops.pdist_rankeval(q, piv, coef, lo, hi, n, rg,
                                          bg=tiles["bg"], bb=tiles["bb"])
    raise ValueError(f"unknown kernel {kernel!r}")


def _time_us(thunk, reps: int = 3) -> float:
    import jax
    jax.block_until_ready(thunk())        # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _paired_us(thunk_a, thunk_b, reps: int = 3) -> tuple[float, float]:
    """Interleaved best-of-``reps`` timing of two compiled thunks
    (A/B/A/B/…) so machine-load drift hits both measurements equally —
    the comparison the acceptance margin is applied to."""
    import jax
    jax.block_until_ready(thunk_a())      # compile + warm both first
    jax.block_until_ready(thunk_b())
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk_a())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(thunk_b())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a * 1e6, best_b * 1e6


def _static_tiles(kernel: str, metric: str | None,
                  bd: dict[str, int]) -> dict[str, int]:
    from . import ops  # deferred: ops imports this module
    return ops.static_tiles(kernel, metric, bd)


def tune(kernel: str, metric: str | None, dims: dict[str, int],
         verbose: bool = False) -> dict:
    """Search the candidate grid for this shape bucket, persist and
    return the winning entry.

    The grid winner is accepted only when a *paired* best-of-3 against
    the static heuristic shows it faster by more than ``_NOISE_MARGIN``
    — otherwise the static tiles are cached (with their measured time),
    so a noisy micro-run can never pin a regression into the table."""
    backend = dispatch.backend_key()
    bd = {k: bucket(v) for k, v in dims.items()}
    key = _key(backend, kernel, metric, bd)
    static = _static_tiles(kernel, metric, bd)
    best_tiles, best_us = None, float("inf")
    for tiles in _candidates(backend, kernel, metric, bd):
        us = _time_us(_bench_thunk(kernel, metric, bd, tiles))
        if verbose:
            print(f"  {key} {tiles} -> {us:.0f}us")
        if us < best_us:
            best_tiles, best_us = tiles, us
    static_us = best_us
    if best_tiles != static:
        best_us, static_us = _paired_us(
            _bench_thunk(kernel, metric, bd, best_tiles),
            _bench_thunk(kernel, metric, bd, static))
        if best_us >= static_us * (1.0 - _NOISE_MARGIN):
            best_tiles, best_us = dict(static), static_us
            if verbose:
                print(f"  {key} grid winner within noise of static "
                      f"-> keeping static {static}")
    ent = {"tiles": best_tiles, "us": round(best_us, 1),
           "static_us": round(static_us, 1), "v": SCHEMA_VERSION}
    with _lock:
        _entries()[key] = ent
        _write_user_cache(key, ent)
    return ent


def _parse_key(key: str):
    """(backend, kernel, metric, bucket-dims) from a table key, or None
    when malformed."""
    parts = key.split("/")
    if len(parts) < 4:
        return None
    backend, kernel, metric = parts[0], parts[1], parts[2]
    try:
        dims = {k: int(v) for k, v in (s.split("=") for s in parts[3:])}
    except ValueError:
        return None
    return backend, kernel, None if metric == "-" else metric, dims


def revalidate(verbose: bool = False) -> dict:
    """Re-measure every cached entry for the current backend.

    Entries whose tiles no longer beat the static heuristic by the
    noise margin (stale after kernel changes or a machine move) are
    re-tuned from scratch; still-winning entries get their timings
    refreshed.  Returns {key: entry} for every entry touched."""
    backend = dispatch.backend_key()
    out = {}
    for key, ent in sorted(_entries().items()):
        parsed = _parse_key(key)
        if parsed is None or parsed[0] != backend:
            continue
        _, kernel, metric, bd = parsed
        static = _static_tiles(kernel, metric, bd)
        if ent["tiles"] == static:
            continue                      # static entries can't go stale
        tuned_us, static_us = _paired_us(
            _bench_thunk(kernel, metric, bd, ent["tiles"]),
            _bench_thunk(kernel, metric, bd, static))
        if tuned_us >= static_us * (1.0 - _NOISE_MARGIN):
            if verbose:
                print(f"  {key}: stale ({tuned_us:.0f}us vs static "
                      f"{static_us:.0f}us) -> retuning")
            out[key] = tune(kernel, metric, bd, verbose=verbose)
        else:
            new = {"tiles": dict(ent["tiles"]), "us": round(tuned_us, 1),
                   "static_us": round(static_us, 1), "v": SCHEMA_VERSION}
            with _lock:
                _entries()[key] = new
                _write_user_cache(key, new)
            out[key] = new
    return out


def _write_user_cache(key: str, ent: dict) -> None:
    path = cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    entries = _load_file(path)
    entries[key] = ent
    payload = {"version": SCHEMA_VERSION, "entries": entries}
    fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                               prefix=path.name + ".")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# ------------------------------------------------------------------ warm

# the pipeline's standard shape buckets: (kernel, metric, dims) — the
# bench_kernels shapes plus the serving/roofline refinement bucket
# (batch 64 queries × ~100k padded slots × d=8, the shape the resident
# executor's range/knn filters actually launch at)
_WARM_FULL = (
    ("pdist", "sql2", {"q": 256, "p": 65536, "d": 32}),
    ("range_filter", "sql2", {"q": 256, "p": 65536, "d": 32}),
    ("pdist", "sql2", {"q": 64, "p": 92544, "d": 8}),
    ("range_filter", "sql2", {"q": 64, "p": 92544, "d": 8}),
    ("rankeval", None, {"g": 64, "b": 4096, "c": 16}),
    ("rankeval", None, {"g": 48, "b": 128, "c": 21}),
    ("pdist_rankeval", None, {"g": 64, "b": 256, "d": 32, "c": 16}),
    ("pdist_rankeval", None, {"g": 48, "b": 64, "d": 8, "c": 21}),
)
_WARM_QUICK = (
    ("pdist", "sql2", {"q": 128, "p": 4096, "d": 16}),
    ("range_filter", "sql2", {"q": 128, "p": 4096, "d": 16}),
    ("rankeval", None, {"g": 64, "b": 512, "c": 16}),
    ("pdist_rankeval", None, {"g": 64, "b": 128, "d": 16, "c": 16}),
)


def warm(shapes=None, quick: bool = False, verbose: bool = False) -> dict:
    """Tune (and cache) the standard pipeline shape buckets; returns
    {key: entry}.  Tunes unconditionally — the CLI entry point for CI
    and first-boot cache warming, regardless of REPRO_AUTOTUNE."""
    shapes = shapes if shapes is not None else (
        _WARM_QUICK if quick else _WARM_FULL)
    out = {}
    for kernel, metric, dims in shapes:
        ent = tune(kernel, metric, dims, verbose=verbose)
        bd = {k: bucket(v) for k, v in dims.items()}
        out[_key(dispatch.backend_key(), kernel, metric, bd)] = ent
    return out


def main(argv=None) -> int:  # pragma: no cover - thin CLI
    import argparse
    ap = argparse.ArgumentParser(
        description="Warm the kernel tile tuning cache.")
    ap.add_argument("--warm", action="store_true",
                    help="tune the standard pipeline shape buckets")
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke)")
    ap.add_argument("--revalidate", action="store_true",
                    help="re-measure cached entries; retune stale ones "
                         "whose win over the static heuristic is gone")
    args = ap.parse_args(argv)
    if not (args.warm or args.revalidate):
        ap.print_help()
        return 2
    res = warm(quick=args.quick, verbose=True) if args.warm else {}
    if args.revalidate:
        res.update(revalidate(verbose=True))
    print(f"tuned {len(res)} entries -> {cache_path()}")
    for key, ent in res.items():
        print(f"  {key}: {ent['tiles']} ({ent['us']:.0f}us, "
              f"static {ent.get('static_us', ent['us']):.0f}us)")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
