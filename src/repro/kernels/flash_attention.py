"""Blocked online-softmax (flash) attention Pallas kernel, GQA-aware.

The LM-side hot spot of the framework (training forward + prefill). Grid
is (batch, q_heads, q_blocks, kv_blocks) with the kv dimension innermost:
output / running-max / running-denominator blocks are revisited across the
kv sweep, so the accumulation state lives in VMEM without scratch buffers
(portable to ``interpret=True``). Causal tiles strictly above the diagonal
are skipped with ``pl.when`` — the classic ~2× FLOP saving.

GQA: q head h reads kv head h // (Hq // Hk) straight from the BlockSpec
index map — no KV replication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  bq: int, bk: int, scale: float, causal: bool,
                  kv_len: int | None = None):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, _NEG, m_ref.dtype)
        l_ref[...] = jnp.zeros(l_ref.shape, l_ref.dtype)
        o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype)

    # causal: skip tiles entirely above the diagonal
    live = (j * bk <= i * bq + bq - 1) if causal else (j >= 0)

    @pl.when(live)
    def _acc():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal or kv_len is not None:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            ok = (qpos >= kpos) if causal else (qpos >= 0)
            if kv_len is not None:
                ok = ok & (kpos < kv_len)
            s = jnp.where(ok, s, _NEG)
        m_prev = m_ref[0, 0]                               # (bq,)
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0, 0] = alpha * l_prev + jnp.sum(p, axis=-1)
        m_ref[0, 0] = m_new
        o_ref[0, 0] = o_ref[0, 0] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _norm():
        o_ref[0, 0] = o_ref[0, 0] / jnp.maximum(l_ref[0, 0], 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret", "kv_len"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           interpret: bool | None = None,
                           kv_len: int | None = None) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hk, Sk, D); Hq % Hk == 0.

    Sq % bq == 0 and Sk % bk == 0 (ops.py pads); ``kv_len`` masks padded
    keys beyond the true kv length. Returns (B, Hq, Sq, D) in q's dtype.
    ``interpret=None`` auto-selects by backend (compiled on TPU/GPU,
    interpreted on CPU).
    """
    interpret = resolve_interpret(interpret)
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    assert hq % hk == 0 and sq % bq == 0 and sk % bk == 0
    group = hq // hk
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                             causal=causal, kv_len=kv_len)
    o, _, _ = pl.pallas_call(
        kern,
        grid=(b, hq, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, h, i, j: (bi, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, h, i, j, g=group: (bi, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, h, i, j, g=group: (bi, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, h, i, j: (bi, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda bi, h, i, j: (bi, h, i)),
            pl.BlockSpec((1, 1, bq), lambda bi, h, i, j: (bi, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o.astype(q.dtype)
