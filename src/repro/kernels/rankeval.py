"""Fused rank-model inference kernel: Clenshaw polynomial eval + ring ID.

The TPU analogue of LIMS's per-query model calls: for G (cluster, pivot)
groups at once, evaluate each group's degree-g Chebyshev rank model on a
(G, B) tile of distances and fuse the ring-ID transform
rid = clip(rank // ceil(n/N), 0, N-1) — one VMEM pass, VPU only.

Layout: x (G, B) distances; coef (G, C) low→high Chebyshev coefficients
(zero-padded to a common C); lo/hi/n (G,) per-group normalization; a
single pass produces both clipped ranks and ring IDs.

:func:`rank_math` holds the arithmetic itself so the compiled-XLA lane
(``xla.py``) and the fused pdist→rankeval kernel (``fused.py``) execute
the exact same f32 operation sequence as this kernel — bit-identity
across call sites depends on sharing it, not on reimplementing the
recurrence (``ref.py`` intentionally uses a different one).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret


def rank_math(x, coef, lo, hi, n, *, n_coef: int, n_rings: int):
    """Clenshaw rank eval + ring id on a (g, b) tile; returns int32 pair.

    ``x`` (g, b) f32 distances; ``coef`` (g, C); ``lo``/``hi``/``n``
    (g,).  Pure jnp — callable from a pallas kernel body (on
    materialized refs) and from jitted XLA code alike.
    """
    x = x.astype(jnp.float32)
    lo = lo.astype(jnp.float32)[:, None]                # (g, 1)
    hi = hi.astype(jnp.float32)[:, None]
    n = n.astype(jnp.float32)[:, None]
    t = (x - lo) / jnp.maximum(hi - lo, 1e-30) * 2.0 - 1.0
    t = jnp.clip(t, -1.0, 1.0)
    # Clenshaw recurrence, coefficients high -> low (static unroll over C)
    b1 = jnp.zeros_like(t)
    b2 = jnp.zeros_like(t)
    t2 = 2.0 * t
    for k in range(n_coef - 1, 0, -1):
        c_k = coef[:, k].astype(jnp.float32)[:, None]
        b1, b2 = c_k + t2 * b1 - b2, b1
    c0 = coef[:, 0].astype(jnp.float32)[:, None]
    r = c0 + t * b1 - b2
    rank = jnp.clip(jnp.rint(r), 0.0, jnp.maximum(n - 1.0, 0.0))
    width = jnp.ceil(n / float(n_rings))
    rid = jnp.clip(jnp.floor(rank / jnp.maximum(width, 1.0)), 0.0,
                   float(n_rings - 1))
    return rank.astype(jnp.int32), rid.astype(jnp.int32)


def _rankeval_kernel(x_ref, coef_ref, lo_ref, hi_ref, n_ref, o_rank_ref,
                     o_rid_ref, *, n_coef: int, n_rings: int):
    rank, rid = rank_math(x_ref[...], coef_ref[...], lo_ref[...],
                          hi_ref[...], n_ref[...], n_coef=n_coef,
                          n_rings=n_rings)
    o_rank_ref[...] = rank
    o_rid_ref[...] = rid


@functools.partial(
    jax.jit, static_argnames=("n_rings", "bg", "bb", "interpret"))
def rankeval_pallas(x: jax.Array, coef: jax.Array, lo: jax.Array,
                    hi: jax.Array, n: jax.Array, n_rings: int = 20,
                    bg: int = 8, bb: int = 128,
                    interpret: bool | None = None):
    """Returns (rank, rid), both (G, B) int32. ``interpret=None``
    auto-selects by backend (compiled on TPU/GPU, interpreted on CPU)."""
    interpret = resolve_interpret(interpret)
    g, b = x.shape
    g2, n_coef = coef.shape
    assert g == g2 and g % bg == 0 and b % bb == 0, (x.shape, coef.shape, bg, bb)
    kern = functools.partial(_rankeval_kernel, n_coef=n_coef,
                             n_rings=n_rings)
    return pl.pallas_call(
        kern,
        grid=(g // bg, b // bb),
        in_specs=[
            pl.BlockSpec((bg, bb), lambda i, j: (i, j)),
            pl.BlockSpec((bg, n_coef), lambda i, j: (i, 0)),
            pl.BlockSpec((bg,), lambda i, j: (i,)),
            pl.BlockSpec((bg,), lambda i, j: (i,)),
            pl.BlockSpec((bg,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bg, bb), lambda i, j: (i, j)),
            pl.BlockSpec((bg, bb), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, b), jnp.int32),
            jax.ShapeDtypeStruct((g, b), jnp.int32),
        ],
        interpret=interpret,
    )(x, coef, lo, hi, n)
