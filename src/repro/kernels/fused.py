"""Fused pdist→rankeval Pallas kernel: the planner's hot stage in one launch.

The staged plan pipeline materializes a (B, G) pivot-distance block to
HBM, reshapes it into a (G, 2B) boundary matrix, and launches a second
kernel over it.  This kernel computes both in one grid cell: the Gram
sql2 distance tile, the sqrt, and the Clenshaw rank eval at the two
widened-radius boundaries dq∓rg — the distance tile lives only in VMEM.
Math is shared with the staged kernels (``xla._gram_sq`` mirrors
``pdist._pdist_l2_kernel``; ``rankeval.rank_math`` is literally the same
function), so fused-vs-staged bit-identity within a lane is structural,
not coincidental — and pinned by tests.

Grid: (B//bb, G//bg); each cell loads a (bb, d) query tile and a (bg, d)
pivot tile plus the (bg,)-shaped model params, and writes a (bb, bg)
distance tile and two (bg, bb) rank tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret
from .rankeval import rank_math


def _pdist_rankeval_kernel(q_ref, piv_ref, rg_ref, coef_ref, lo_ref,
                           hi_ref, n_ref, o_dq_ref, o_lo_ref, o_hi_ref,
                           *, n_coef: int, n_rings: int):
    qb = q_ref[...].astype(jnp.float32)                 # (bb, d)
    pv = piv_ref[...].astype(jnp.float32)               # (bg, d)
    qn = jnp.sum(qb * qb, axis=-1, keepdims=True)
    pn = jnp.sum(pv * pv, axis=-1, keepdims=True)
    g = jax.lax.dot_general(qb, pv, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    d2 = jnp.maximum(qn + pn.T - 2.0 * g, 0.0)
    dq = jnp.sqrt(d2)                                   # (bb, bg)
    rg = rg_ref[...].astype(jnp.float32)                # (bb,)
    xlo = dq.T - rg[None, :]                            # (bg, bb)
    xhi = dq.T + rg[None, :]
    coef = coef_ref[...]
    lo = lo_ref[...]
    hi = hi_ref[...]
    nn = n_ref[...]
    rk_lo, _ = rank_math(xlo, coef, lo, hi, nn, n_coef=n_coef,
                         n_rings=n_rings)
    rk_hi, _ = rank_math(xhi, coef, lo, hi, nn, n_coef=n_coef,
                         n_rings=n_rings)
    o_dq_ref[...] = dq
    o_lo_ref[...] = rk_lo
    o_hi_ref[...] = rk_hi


@functools.partial(
    jax.jit, static_argnames=("n_rings", "bg", "bb", "interpret"))
def pdist_rankeval_pallas(q: jax.Array, piv: jax.Array, coef: jax.Array,
                          lo: jax.Array, hi: jax.Array, n: jax.Array,
                          rg: jax.Array, n_rings: int = 20, bg: int = 8,
                          bb: int = 128, interpret: bool | None = None):
    """Returns (dq (B, G) f32, rank_lo (G, B) i32, rank_hi (G, B) i32).

    ``q`` (B, d) f32; ``piv`` (G, d); ``coef`` (G, C); ``lo``/``hi``/
    ``n`` (G,); ``rg`` (B,).  B % bb == 0 and G % bg == 0 (``ops.py``
    pads).  sql2/L2 only — the query path's metric.
    """
    interpret = resolve_interpret(interpret)
    B, d = q.shape
    G, n_coef = coef.shape
    assert piv.shape == (G, d) and B % bb == 0 and G % bg == 0, (
        q.shape, piv.shape, bg, bb)
    kern = functools.partial(_pdist_rankeval_kernel, n_coef=n_coef,
                             n_rings=n_rings)
    return pl.pallas_call(
        kern,
        grid=(B // bb, G // bg),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bg, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bg, n_coef), lambda i, j: (j, 0)),
            pl.BlockSpec((bg,), lambda i, j: (j,)),
            pl.BlockSpec((bg,), lambda i, j: (j,)),
            pl.BlockSpec((bg,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bg), lambda i, j: (i, j)),
            pl.BlockSpec((bg, bb), lambda i, j: (j, i)),
            pl.BlockSpec((bg, bb), lambda i, j: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, G), jnp.float32),
            jax.ShapeDtypeStruct((G, B), jnp.int32),
            jax.ShapeDtypeStruct((G, B), jnp.int32),
        ],
        interpret=interpret,
    )(q, piv, rg, coef, lo, hi, n)


__all__ = ["pdist_rankeval_pallas"]
