"""Brute-force page scan: the floor every index must beat."""
from __future__ import annotations

import time

import numpy as np

from ..core.index import QueryStats
from ..core.metrics import MetricSpace, dist_one_to_many
from ..core.paging import DEFAULT_PAGE_BYTES, PageStore


class LinearScan:
    name = "scan"

    def __init__(self, space: MetricSpace, page_bytes: int = DEFAULT_PAGE_BYTES,
                 **_):
        t0 = time.perf_counter()
        self.space = space
        self.store = PageStore(space.data, record_bytes=space.record_nbytes(),
                               page_bytes=page_bytes)
        self.build_time_s = time.perf_counter() - t0

    def _all_dists(self, q, st: QueryStats) -> np.ndarray:
        idx, rows = self.store.fetch_pages(range(self.store.n_pages), set())
        st.pages += self.store.n_pages
        st.dist_comps += len(rows)
        if self.space._custom is not None:
            return np.asarray([self.space._custom(q, r) for r in rows])
        return dist_one_to_many(q, rows, self.space.metric)

    def range_query(self, q, r):
        st = QueryStats()
        t0 = time.perf_counter()
        d = self._all_dists(q, st)
        ids = np.where(d <= r)[0]
        st.time_s = time.perf_counter() - t0
        return ids, d[ids], st

    def knn_query(self, q, k):
        st = QueryStats()
        t0 = time.perf_counter()
        d = self._all_dists(q, st)
        order = np.argsort(d, kind="stable")[:k]
        st.time_s = time.perf_counter() - t0
        return order, d[order], st

    def point_query(self, q):
        ids, d, st = self.range_query(q, 0.0)
        return ids, st

    def index_nbytes(self) -> int:
        return 0

    def reset_page_counters(self) -> None:
        self.store.reset_counters()
