"""Metric ball tree — the traditional-index stand-in for the M-tree.

Recursive 2-center splits; every node (internal or leaf) occupies one disk
page, as M-tree nodes do, so "page accesses" counts every node visited.
Triangle-inequality pruning: skip a subtree when d(q, c) - radius > r.
Works for any metric (only distances used)."""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field

import numpy as np

from ..core.index import QueryStats
from ..core.metrics import MetricSpace, dist_one_to_many
from ..core.paging import DEFAULT_PAGE_BYTES


@dataclass
class _Node:
    center_row: np.ndarray
    radius: float
    idx: np.ndarray | None = None        # leaf: member global ids
    children: list = field(default_factory=list)

    @property
    def is_leaf(self) -> bool:
        return self.idx is not None


class BallTree:
    name = "balltree"

    def __init__(self, space: MetricSpace, page_bytes: int = DEFAULT_PAGE_BYTES,
                 seed: int = 0, **_):
        t0 = time.perf_counter()
        self.space = space
        self.omega = max(1, page_bytes // max(1, space.record_nbytes()))
        self._rng = np.random.default_rng(seed)
        self.n_nodes = 0
        self.root = self._build(np.arange(space.n))
        self.build_time_s = time.perf_counter() - t0
        self.page_accesses = 0

    def _build(self, idx: np.ndarray) -> _Node:
        self.n_nodes += 1
        space = self.space
        c_local = int(self._rng.integers(len(idx)))
        d0 = space.dist(space.data[idx[c_local]], idx)
        center = space.data[idx[c_local]].copy()
        radius = float(d0.max()) if len(idx) else 0.0
        if len(idx) <= self.omega:
            return _Node(center, radius, idx=idx)
        # 2-center split: farthest point from c, then farthest from that
        a = int(np.argmax(d0))
        da = space.dist(space.data[idx[a]], idx)
        b = int(np.argmax(da))
        db = space.dist(space.data[idx[b]], idx)
        left = da <= db
        if left.sum() in (0, len(idx)):      # degenerate: median split
            half = max(1, len(idx) // 2)
            order = np.argsort(da, kind="stable")
            l_idx, r_idx = idx[order[:half]], idx[order[half:]]
        else:
            l_idx, r_idx = idx[left], idx[~left]
        node = _Node(center, radius)
        node.children = [self._build(l_idx), self._build(r_idx)]
        return node

    # ------------------------------------------------------------------
    def range_query(self, q, r):
        st = QueryStats()
        t0 = time.perf_counter()
        out_ids: list[int] = []
        out_d: list[float] = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            st.pages += 1                       # node read = page access
            dc = self._d1(q, node.center_row, st)
            if dc - node.radius > r:
                continue
            if node.is_leaf:
                d = self._drows(q, node.idx, st)
                st.candidates += len(node.idx)
                hit = d <= r
                out_ids.extend(int(i) for i in node.idx[hit])
                out_d.extend(float(x) for x in d[hit])
            else:
                stack.extend(node.children)
        st.time_s = time.perf_counter() - t0
        return np.asarray(out_ids, dtype=np.int64), np.asarray(out_d), st

    def knn_query(self, q, k):
        st = QueryStats()
        t0 = time.perf_counter()
        best: list[tuple[float, int]] = []      # max-heap via negation
        heap: list[tuple[float, int, _Node]] = []
        tie = 0

        def push(node):
            nonlocal tie
            dc = self._d1(q, node.center_row, st)
            heapq.heappush(heap, (max(0.0, dc - node.radius), tie, node))
            tie += 1

        push(self.root)
        while heap:
            lb, _, node = heapq.heappop(heap)
            if len(best) == k and lb > -best[0][0]:
                break
            st.pages += 1
            if node.is_leaf:
                d = self._drows(q, node.idx, st)
                st.candidates += len(node.idx)
                for dist, gid in zip(d, node.idx):
                    if len(best) < k:
                        heapq.heappush(best, (-float(dist), int(gid)))
                    elif dist < -best[0][0]:
                        heapq.heapreplace(best, (-float(dist), int(gid)))
            else:
                for ch in node.children:
                    push(ch)
        st.time_s = time.perf_counter() - t0
        pairs = sorted((-nd, gid) for nd, gid in best)
        return (np.asarray([g for _, g in pairs], dtype=np.int64),
                np.asarray([d for d, _ in pairs]), st)

    def point_query(self, q):
        ids, d, st = self.range_query(q, 0.0)
        return ids, st

    def _d1(self, q, row, st) -> float:
        st.dist_comps += 1
        if self.space._custom is not None:
            return float(self.space._custom(q, row))
        return float(dist_one_to_many(q, row[None, :], self.space.metric)[0])

    def _drows(self, q, idx, st) -> np.ndarray:
        st.dist_comps += len(idx)
        rows = self.space.data[idx]
        if self.space._custom is not None:
            return np.asarray([self.space._custom(q, row) for row in rows])
        return dist_one_to_many(q, rows, self.space.metric)

    def index_nbytes(self) -> int:
        # centers + radii per node ~ the M-tree routing-entry overhead
        rec = self.space.record_nbytes()
        return int(self.n_nodes * (rec + 8))

    def reset_page_counters(self) -> None:
        pass
