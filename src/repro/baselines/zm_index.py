"""ZM index (Wang et al., MDM'19): z-order curve + learned CDF.

Coordinates are quantized to ``bits`` per dimension and bit-interleaved
into a Morton code; data is sorted by code and a learned model predicts
rank from code. A range query maps the L_p ball to its bounding box, takes
the [z(box_lo), z(box_hi)] code interval and scans it — the naive ZM
behaviour the paper critiques (many irrelevant points between z_lo and
z_hi, worse with dimensionality). kNN is unsupported, as in the paper.
Vector metrics only (needs coordinates)."""
from __future__ import annotations

import time

import numpy as np

from ..core.index import QueryStats
from ..core.metrics import MetricSpace, dist_one_to_many
from ..core.paging import DEFAULT_PAGE_BYTES, PageStore
from ..core.rankmodel import PolyRankModel, SearchStats, exponential_search


def _interleave(codes: np.ndarray, bits: int) -> np.ndarray:
    """Bit-interleave (n, d) uint codes → (n,) uint64 Morton codes."""
    n, d = codes.shape
    out = np.zeros(n, dtype=np.uint64)
    for b in range(bits - 1, -1, -1):          # msb first
        for j in range(d):
            out = (out << np.uint64(1)) | ((codes[:, j] >> np.uint64(b)) & np.uint64(1))
    return out


class ZMIndex:
    name = "zm"

    def __init__(self, space: MetricSpace, degree: int = 20,
                 page_bytes: int = DEFAULT_PAGE_BYTES, bits: int | None = None,
                 **_):
        if not space.is_vector:
            raise ValueError("ZM index requires a vector space")
        t0 = time.perf_counter()
        self.space = space
        X = space.data.astype(np.float64)
        self.lo = X.min(axis=0)
        self.hi = X.max(axis=0)
        d = X.shape[1]
        self.bits = bits if bits is not None else max(2, min(10, 60 // d))
        self.d = d
        z = self._zcode(X)
        order = np.argsort(z, kind="stable")
        self.z_sorted = z[order].astype(np.float64)  # model works on floats
        self.store = PageStore(X[order], record_bytes=space.record_nbytes(),
                               page_bytes=page_bytes)
        self.store_ids = order.astype(np.int64)
        self.model = PolyRankModel.fit(self.z_sorted, degree)
        self._z_list = self.z_sorted.tolist()
        self.build_time_s = time.perf_counter() - t0

    def _zcode(self, X: np.ndarray) -> np.ndarray:
        span = np.maximum(self.hi - self.lo, 1e-12)
        q = np.clip((X - self.lo) / span, 0.0, 1.0)
        cells = (q * (2 ** self.bits - 1)).astype(np.uint64)
        return _interleave(cells, self.bits)

    def _locate(self, z: float, side: str, st: QueryStats) -> int:
        ss = SearchStats()
        guess = self.model.predict_scalar(z)
        st.model_calls += 1
        pos = exponential_search(self._z_list, z, guess, side=side, stats=ss)
        st.probes += ss.probes
        return pos

    def range_query(self, q, r, collect="filtered"):
        st = QueryStats()
        t0 = time.perf_counter()
        box_lo = self._zcode(np.maximum(q - r, self.lo)[None, :])[0]
        box_hi = self._zcode(np.minimum(q + r, self.hi)[None, :])[0]
        lb = self._locate(float(box_lo), "left", st)
        ub = self._locate(float(box_hi), "right", st) - 1
        out_ids: list[int] = []
        out_d: list[float] = []
        if ub >= lb:
            idx, rows = self.store.fetch_pages(
                self.store.page_range(lb, ub), set())
            st.pages += len(set(self.store.page_range(lb, ub)))
            d = dist_one_to_many(q, rows, self.space.metric)
            st.dist_comps += len(rows)
            st.candidates += len(rows)
            for i, dist in zip(idx, d):
                if dist <= r:
                    out_ids.append(int(self.store_ids[i]))
                    out_d.append(float(dist))
        st.time_s = time.perf_counter() - t0
        return np.asarray(out_ids, dtype=np.int64), np.asarray(out_d), st

    def point_query(self, q):
        ids, d, st = self.range_query(q, 0.0)
        return ids, st

    def index_nbytes(self) -> int:
        return int(self.z_sorted.nbytes + self.store_ids.nbytes +
                   self.model.nbytes())

    def reset_page_counters(self) -> None:
        self.store.reset_counters()
