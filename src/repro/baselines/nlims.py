"""N-LIMS (paper §6.7): identical LIMS structure and page layout, with the
rank prediction models replaced by B+-tree-style binary search. Same page
accesses by construction; the delta is pure CPU (probe count / locate
time), which is exactly what Fig. 14 measures."""
from __future__ import annotations

from ..core.index import LIMSIndex
from ..core.metrics import MetricSpace


class NLIMS(LIMSIndex):
    name = "nlims"

    def __init__(self, space: MetricSpace, **kw):
        kw.pop("learned", None)
        super().__init__(space, learned=False, **kw)
