"""ML-index (Davitkova et al., EDBT'20): iDistance + learned models.

Data is clustered; each object is keyed by ``key(p) = j * scale +
dist(p, c_j)`` (single reference point per cluster — the paper's critique
of ML is precisely that equi-distant points from one pivot collapse to the
same key, inflating false positives vs LIMS's multi-pivot rings). Keys are
sorted into one global page sequence; a learned model per cluster predicts
rank from key; exponential search corrects it. Range/kNN identical in
spirit to LIMS (growing radius for kNN)."""
from __future__ import annotations

import time

import numpy as np

from ..core.clustering import kcenter
from ..core.index import QueryStats
from ..core.metrics import MetricSpace, dist_one_to_many
from ..core.paging import DEFAULT_PAGE_BYTES, PageStore
from ..core.rankmodel import PolyRankModel, SearchStats, exponential_search


class MLIndex:
    name = "ml"

    def __init__(self, space: MetricSpace, n_clusters: int = 50,
                 degree: int = 20, page_bytes: int = DEFAULT_PAGE_BYTES,
                 seed: int = 0, **_):
        t0 = time.perf_counter()
        self.space = space
        self.K = min(n_clusters, space.n)
        cl = kcenter(space, self.K, seed=seed)
        self.K = cl.k
        self.center_idx = cl.center_idx
        self.center_rows = space.data[cl.center_idx].copy()
        # iDistance scale: strictly larger than any intra-cluster distance
        self.dist_min = np.zeros(self.K)
        self.dist_max = np.zeros(self.K)
        for c in range(self.K):
            mem = cl.members[c]
            if len(mem):
                d = cl.dist_to_center[mem]
                self.dist_min[c] = d.min()
                self.dist_max[c] = d.max()
        self.scale = float(self.dist_max.max()) * 1.5 + 1e-9
        keys = cl.assign * self.scale + cl.dist_to_center
        order = np.argsort(keys, kind="stable")
        self.keys_sorted = keys[order]
        self.store = PageStore(space.data[order],
                               record_bytes=space.record_nbytes(),
                               page_bytes=page_bytes)
        self.store_ids = order.astype(np.int64)
        # per-cluster rank models over the global sorted key array
        self.models: list[PolyRankModel] = []
        self.cluster_bounds = np.searchsorted(
            self.keys_sorted, np.arange(self.K + 1) * self.scale, side="left")
        self._segs: list = []
        for c in range(self.K):
            lo, hi = self.cluster_bounds[c], self.cluster_bounds[c + 1]
            m = PolyRankModel.fit(self.keys_sorted[lo:hi], degree)
            self.models.append(m)
            self._segs.append(self.keys_sorted[lo:hi].tolist())
        self.build_time_s = time.perf_counter() - t0

    # ------------------------------------------------------------------
    def _locate(self, c: int, key: float, side: str, st: QueryStats) -> int:
        lo = self.cluster_bounds[c]
        seg = self._segs[c]
        if len(seg) == 0:
            return int(lo)
        ss = SearchStats()
        guess = self.models[c].predict_scalar(key)
        st.model_calls += 1
        pos = exponential_search(seg, key, guess, side=side, stats=ss)
        st.probes += ss.probes
        return int(lo + pos)

    def range_query(self, q, r, visited: set | None = None, collect="filtered"):
        st = QueryStats()
        t0 = time.perf_counter()
        if visited is None:
            visited = set()
        dq = self._dist_rows(q, self.center_rows, st)
        out_ids: list[int] = []
        out_d: list[float] = []
        for c in range(self.K):
            r_lo = max(dq[c] - r, self.dist_min[c])
            r_hi = min(dq[c] + r, self.dist_max[c])
            if r_lo > r_hi:
                st.clusters_pruned += 1
                continue
            lb = self._locate(c, c * self.scale + r_lo, "left", st)
            ub = self._locate(c, c * self.scale + r_hi, "right", st) - 1
            if ub < lb:
                continue
            before = self.store.page_accesses
            idx, rows = self.store.fetch_pages(self.store.page_range(lb, ub),
                                               visited)
            st.pages += self.store.page_accesses - before
            if len(idx) == 0:
                continue
            d = self._dist_rows(q, rows, st)
            st.candidates += len(idx)
            for i, dist in zip(idx, d):
                if collect == "all" or dist <= r:
                    out_ids.append(int(self.store_ids[i]))
                    out_d.append(float(dist))
        st.time_s = time.perf_counter() - t0
        return (np.asarray(out_ids, dtype=np.int64),
                np.asarray(out_d), st)

    def knn_query(self, q, k, delta_r: float | None = None):
        st = QueryStats()
        t0 = time.perf_counter()
        dr = delta_r if delta_r is not None else \
            float(np.median(self.dist_max[self.dist_max > 0])) / 10 or 1.0
        visited: set = set()
        heap_d = np.full(k, np.inf)
        heap_id = np.full(k, -1, dtype=np.int64)
        r, flag = 0.0, False
        while not flag:
            r += dr
            if heap_d[-1] < r:
                flag = True
            ids, ds, st_i = self.range_query(q, r, visited=visited,
                                             collect="all")
            st += st_i
            if len(ids):
                cat_d = np.concatenate([heap_d, ds])
                cat_i = np.concatenate([heap_id, ids])
                sel = np.argsort(cat_d, kind="stable")[:k]
                heap_d, heap_id = cat_d[sel], cat_i[sel]
        st.time_s = time.perf_counter() - t0
        got = heap_id >= 0
        return heap_id[got], heap_d[got], st

    def point_query(self, q):
        ids, d, st = self.range_query(q, 0.0)
        return ids, st

    def _dist_rows(self, q, rows, st: QueryStats):
        st.dist_comps += len(rows)
        if self.space._custom is not None:
            return np.asarray([self.space._custom(q, row) for row in rows])
        return dist_one_to_many(q, rows, self.space.metric)

    def index_nbytes(self) -> int:
        b = self.keys_sorted.nbytes + self.store_ids.nbytes
        b += self.center_rows.nbytes + self.cluster_bounds.nbytes
        b += sum(m.nbytes() for m in self.models)
        return int(b)

    def reset_page_counters(self) -> None:
        self.store.reset_counters()
