"""Baselines the paper compares against (§6.1.2).

All share the LIMS page-accounting conventions (same PageStore, same
QueryStats) so the comparison measures index structure, not bookkeeping:

  * ``LinearScan``   — brute force over pages (sanity floor).
  * ``NLIMS``        — LIMS with B+-tree-style binary search instead of
                       learned models (the paper's ablation, §6.7); exposed
                       here as a thin wrapper over ``LIMSIndex(learned=False)``.
  * ``MLIndex``      — the ML-index (EDBT'20): iDistance keys + learned
                       models; single-pivot per cluster.
  * ``ZMIndex``      — z-order + learned CDF (MDM'19); vector spaces,
                       range/point only (no kNN, as in the paper).
  * ``BallTree``     — metric ball tree; stand-in for the M-tree
                       (same triangle-inequality node pruning, node = page).
"""
from .linear_scan import LinearScan
from .ml_index import MLIndex
from .nlims import NLIMS
from .zm_index import ZMIndex
from .balltree import BallTree

__all__ = ["LinearScan", "MLIndex", "NLIMS", "ZMIndex", "BallTree"]
