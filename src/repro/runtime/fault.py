"""Fault tolerance: restartable training loop with failure injection,
step watchdog (straggler mitigation), and checkpoint-resume.

The loop contract at fleet scale:
  * every step is deterministic given (state, step) — data is counter-based
    (repro.data.pipeline), so a restart from checkpoint replays identically;
  * a step exceeding ``watchdog_s`` is treated as a straggler: the step is
    abandoned and the loop resumes from the last good state (on real
    hardware this is where you'd also re-slice the mesh — see elastic.py);
  * any exception → restore latest checkpoint → continue, up to
    ``max_restarts``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax

from ..ckpt import checkpoint as ckpt


@dataclass
class FaultConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 5
    watchdog_s: Optional[float] = None
    # test hook: raise at these steps to exercise the restart path
    inject_failures_at: tuple = ()


@dataclass
class LoopStats:
    steps_run: int = 0
    restarts: int = 0
    stragglers: int = 0
    losses: list = field(default_factory=list)


def run_training(train_step: Callable, state, batches: Callable,
                 n_steps: int, fc: FaultConfig) -> tuple:
    """batches: step -> batch dict. Returns (state, LoopStats)."""
    stats = LoopStats()
    saver = ckpt.AsyncCheckpointer(fc.ckpt_dir, fc.keep)
    restored = ckpt.latest_step(fc.ckpt_dir)
    if restored is not None:
        state, _ = ckpt.restore(state, fc.ckpt_dir, restored)
        start = int(jax.device_get(state["step"]))
    else:
        start = int(jax.device_get(state["step"]))
        ckpt.save(state, fc.ckpt_dir, start, fc.keep)

    step = start
    injected = set(fc.inject_failures_at)
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            if step in injected:
                injected.discard(step)
                raise RuntimeError(f"injected failure at step {step}")
            batch = batches(step)
            state, metrics = train_step(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0
            if fc.watchdog_s is not None and dt > fc.watchdog_s:
                stats.stragglers += 1
            stats.losses.append(loss)
            stats.steps_run += 1
            step += 1
            if step % fc.ckpt_every == 0:
                saver.maybe_save(state, step)
        except Exception:  # noqa: BLE001 — restart-on-anything is the point
            stats.restarts += 1
            if stats.restarts > fc.max_restarts:
                raise
            saver.wait()
            last = ckpt.latest_step(fc.ckpt_dir)
            state, _ = ckpt.restore(state, fc.ckpt_dir, last)
            step = int(jax.device_get(state["step"]))
    saver.wait()
    ckpt.save(state, fc.ckpt_dir, step, fc.keep)
    return state, stats
