"""Elastic scaling: rebuild the mesh from the devices that are alive and
reshard state onto it.

The mechanism: ``plan_mesh`` picks the largest usable (data, model) grid
for the surviving device count (model-parallel degree is pinned by the
config's divisibility constraints; the data axis absorbs the loss);
``reshard_state`` is checkpoint-restore against the new mesh's shardings
(repro.ckpt restore is already mesh-agnostic). On a real fleet the
coordinator triggers this on hardware failure; tests drive it directly.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from ..sharding.logical import sharding_for


def plan_mesh(n_devices: int, model_parallel: int,
              axis_names=("data", "model")) -> tuple:
    """Largest (data, model) grid with the pinned model degree."""
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    data = n_devices // mp
    return (data, mp), axis_names


def make_mesh_from(devices: Sequence, model_parallel: int) -> Mesh:
    shape, names = plan_mesh(len(devices), model_parallel)
    import numpy as np
    arr = np.asarray(devices[:shape[0] * shape[1]]).reshape(shape)
    return Mesh(arr, names)


def reshard_state(state, specs_axes, mesh: Mesh, rules: dict):
    """device_put every leaf against the new mesh (host round-trip)."""
    def one(leaf, axes):
        import numpy as np
        host = np.asarray(jax.device_get(leaf))
        return jax.device_put(host, sharding_for(axes, rules, mesh))
    return jax.tree.map(one, state, specs_axes)
