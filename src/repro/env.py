"""Central registry for ``REPRO_*`` environment knobs.

Every runtime knob the package reads from the environment is declared
here with its set of valid values.  Consumers call :func:`get` (or the
thin helper functions that wrap it next to their subsystem, e.g.
``storage.storage_mode``) instead of ``os.environ.get`` so that a typo
like ``REPRO_STORAGE=pages`` fails loudly with the list of accepted
values rather than silently selecting a default via a scattered string
comparison.

Conventions:

* The empty string is always accepted and means "use the default" —
  benchmark harnesses explicitly blank knobs between configs
  (``env["REPRO_STORAGE"] = ""``) and that must stay valid.
* Values are matched case-insensitively after stripping whitespace.
* Free-form knobs (paths) declare ``values=None`` and are returned raw.
"""
from __future__ import annotations

import os
from dataclasses import dataclass


@dataclass(frozen=True)
class Knob:
    name: str
    values: tuple[str, ...] | None  # None -> free-form (e.g. a path)
    default: str
    help: str


_KNOBS = (
    Knob("REPRO_INTERPRET",
         ("", "auto", "on", "off"), "auto",
         "Kernel execution lane: auto (interpret on CPU, compiled pallas "
         "on TPU/GPU), on (force pallas interpret), off (force the "
         "compiled lane: pallas on TPU/GPU, jitted-XLA on CPU)."),
    Knob("REPRO_PALLAS_INTERPRET",
         ("", "auto", "0", "1", "false", "true"), "",
         "Legacy alias for REPRO_INTERPRET (1/true -> on, 0/false -> "
         "off). Ignored when REPRO_INTERPRET is set."),
    Knob("REPRO_AUTOTUNE",
         ("", "off", "on", "force"), "on",
         "Kernel tile autotuning: off (static heuristics), on (consult "
         "the tuning table, heuristics on miss), force (tune misses via "
         "timed micro-runs and write the cache)."),
    Knob("REPRO_TUNE_CACHE", None, "",
         "Path of the user tuning-cache JSON (default "
         "~/.cache/repro-tune.json)."),
    Knob("REPRO_STORAGE",
         ("", "paged"), "",
         "Snapshot storage tier: resident (default) or paged."),
    Knob("REPRO_PREFETCH",
         ("", "off", "async"), "",
         "Paged-store prefetch: sync IO (default/off) or async overlap."),
    Knob("REPRO_CACHE_PIN",
         ("", "on", "off", "0", "1", "no", "yes"), "on",
         "Schedule-aware page-cache pinning (off/0/no disables)."),
    Knob("REPRO_COMPACT",
         ("", "on", "off"), "on",
         "Compacted candidate gather on the resident range path: gather "
         "the certified candidate rows once into a dense power-of-two "
         "bucket and filter only those (on, default), or stream the "
         "full padded slot array through the kernels (off)."),
    Knob("REPRO_ROWS_DTYPE",
         ("", "off", "f32", "bf16", "f16"), "off",
         "Reduced-precision filter plane: keep an extra bf16/f16 copy "
         "of the snapshot row plane for first-pass distance filtering, "
         "with a certified rounding-error margin widening the filter "
         "radius so no true result can be cut (exact f32/f64 refinement "
         "keeps final results bitwise identical). off/f32 (default) "
         "disables the extra plane."),
    Knob("REPRO_KNN_DRIVER",
         ("", "auto", "loop", "rounds"), "auto",
         "kNN driver: loop (device lax.while_loop), rounds (host-stepped "
         "vectorized rounds), auto (rounds on single-shard XLA-CPU, "
         "loop elsewhere)."),
    Knob("REPRO_REAL_IO",
         ("", "0", "1"), "",
         "Benchmarks: drop the OS page cache before cold paged passes."),
    Knob("REPRO_OBS",
         ("", "off", "on", "trace"), "on",
         "Observability (repro.obs; DESIGN.md §11): off (zero-cost "
         "disabled path), on (metrics registry + span latency "
         "histograms + QueryProfiles), trace (additionally record "
         "Chrome trace_event spans for Perfetto)."),
    Knob("REPRO_OBS_RESERVOIR", None, "1024",
         "Histogram reservoir capacity (samples kept per histogram; "
         "percentiles are exact up to this many observations)."),
    Knob("REPRO_OBS_TRACE_CAP", None, "20000",
         "Trace ring capacity: most recent span events kept in "
         "REPRO_OBS=trace mode."),
    Knob("REPRO_OBS_PROFILES", None, "256",
         "QueryProfile ring capacity: most recent per-batch serving "
         "profiles kept."),
    Knob("REPRO_MONITOR",
         ("", "off", "on"), "off",
         "Continuous health monitoring (repro.obs.monitor; DESIGN.md "
         "§12): off (zero-thread, zero-allocation path), on (background "
         "sampler thread snapshotting registry metrics into time "
         "series, health detectors, and the closed-loop serving "
         "daemon)."),
    Knob("REPRO_MONITOR_INTERVAL", None, "0.5",
         "Monitor sampler tick interval in seconds (float)."),
    Knob("REPRO_MONITOR_SERIES_CAP", None, "512",
         "Time-series ring capacity: most recent samples kept per "
         "monitored series."),
    Knob("REPRO_MONITOR_FINDINGS", None, "256",
         "Health-finding ring capacity: most recent detector findings "
         "kept by a monitor."),
    Knob("REPRO_MONITOR_RETRAIN",
         ("", "off", "recommend", "auto"), "off",
         "Closed-loop reaction to rank-model drift findings: off "
         "(ignore), recommend (surface retrain recommendations on the "
         "ServingEngine), auto (additionally trigger "
         "retrain_cluster)."),
)

KNOBS: dict[str, Knob] = {k.name: k for k in _KNOBS}


def get(name: str) -> str:
    """Validated value of knob ``name`` ("" and unset -> its default).

    Raises ``KeyError`` for an undeclared knob (a programming error) and
    ``ValueError`` for a set-but-invalid value (a user error).
    """
    knob = KNOBS[name]
    raw = os.environ.get(name)
    if raw is None:
        return knob.default
    if knob.values is None:
        return raw
    val = raw.strip().lower()
    if val not in knob.values:
        valid = ", ".join(repr(v) for v in knob.values if v) or "''"
        raise ValueError(
            f"{name}={raw!r} is not a valid setting ({knob.help} "
            f"Valid values: {valid}, or empty/unset for the default.)")
    return knob.default if val == "" else val


def describe() -> str:
    """Human-readable table of all knobs (used by ``python -m repro.env``)."""
    lines = []
    for k in _KNOBS:
        vals = "path" if k.values is None else "|".join(v for v in k.values if v)
        cur = os.environ.get(k.name)
        cur_s = f"  [set: {cur!r}]" if cur is not None else ""
        lines.append(f"{k.name} ({vals}; default {k.default!r}){cur_s}\n    {k.help}")
    return "\n".join(lines)


if __name__ == "__main__":  # pragma: no cover
    print(describe())
