"""Checkpointing: atomic, mesh-elastic, async-capable.

Layout: <dir>/step_<n>/  with one .npy per tensor (flattened pytree path)
plus manifest.json (step, tree structure, dtypes). Writes go to a tmp dir
renamed into place — a killed writer never corrupts the latest checkpoint.

Restore is *resharding*: tensors are loaded on host and device_put against
the CURRENT mesh's NamedShardings, so a run checkpointed on mesh (4, 2)
restarts cleanly on (2, 4) or (8, 1) — the elastic-scaling contract.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_key_str(k) for k in path)
        out[key] = leaf
    return out, treedef


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


def save(state, directory: str, step: int, keep: int = 3) -> str:
    """Atomic synchronous save. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(state)
    manifest = {"step": int(step), "keys": sorted(flat),
                "time": time.time()}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        if str(arr.dtype) == "bfloat16":
            # .npy can't round-trip ml_dtypes; widen losslessly to f32
            arr = arr.astype(np.float32)
        np.save(os.path.join(tmp, key.replace("/", "__") + ".npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(template, directory: str, step: Optional[int] = None):
    """Load into the structure (and shardings) of ``template``.

    ``template`` may hold arrays OR ShapeDtypeStructs with shardings —
    each tensor is device_put against the template's sharding, which is
    what makes restore mesh-elastic.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    flat_t, treedef = _flatten(template)
    out = {}
    for key, leaf in flat_t.items():
        fn = os.path.join(path, key.replace("/", "__") + ".npy")
        arr = np.load(fn)
        import ml_dtypes  # noqa: F401  (registers bfloat16 casts)
        arr = arr.astype(leaf.dtype)
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None:
            out[key] = jax.device_put(arr, sharding)
        else:
            out[key] = jax.device_put(arr)
    leaves = [out[k] for k, _ in
              sorted(flat_t.items(), key=lambda kv: kv[0])]
    # rebuild in original order
    ordered = [out["/".join(_key_str(k) for k in p)]
               for p, _ in jax.tree_util.tree_flatten_with_path(template)[0]]
    return jax.tree_util.tree_unflatten(treedef, ordered), step


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; at most one in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def maybe_save(self, state, step: int) -> bool:
        if self._thread is not None and self._thread.is_alive():
            return False                   # previous save still running
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            save(host_state, self.directory, step, self.keep)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
