"""Span-based tracing for the query path.

A *span* is one timed region of the request path — ``frontend.execute``,
``router.dispatch``, ``executor.knn``, ``storage.fetch`` — entered as a
context manager::

    with span("executor.knn", args={"B": 64}):
        ...

What a span costs depends on ``REPRO_OBS``:

* ``off`` — :func:`span` returns a shared no-op singleton; entering and
  exiting it does nothing and allocates nothing.
* ``on`` — the span's wall duration lands in the registry histogram
  ``span.<name>`` (seconds), so every stage of the query path gets
  p50/p99 latency for free.
* ``trace`` — additionally, a Chrome ``trace_event`` "complete" record
  (name, thread, start, duration, args) is appended to a bounded ring
  buffer.  :func:`trace_events` renders the ring as the Trace Event
  Format dict Perfetto / ``chrome://tracing`` load directly; the
  exporter (``repro.obs.export``) writes it to a file.

The ring is ``REPRO_OBS_TRACE_CAP`` events (default 20000, oldest
dropped first), so tracing a long-running server is safe — you get the
most recent window, never unbounded growth.  Timestamps are
``perf_counter`` microseconds relative to a process epoch; thread ids
are compacted to small stable integers and named in the trace metadata
so Perfetto shows "lims-frontend" instead of a pointer-sized ident.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque

from . import registry as _reg
from .registry import _int_knob

_EPOCH = time.perf_counter()

_TRACE_LOCK = threading.Lock()
_EVENTS: deque | None = None        # created lazily at first trace append
_TIDS: dict[int, int] = {}          # thread ident → compact tid
_TID_NAMES: dict[int, str] = {}     # compact tid → thread name


def trace_cap() -> int:
    """Trace ring capacity (``REPRO_OBS_TRACE_CAP``)."""
    return _int_knob("REPRO_OBS_TRACE_CAP", 20000)


def _tid() -> int:
    t = threading.current_thread()
    ident = t.ident
    tid = _TIDS.get(ident)
    if tid is None:
        with _TRACE_LOCK:
            tid = _TIDS.get(ident)
            if tid is None:
                tid = len(_TIDS)
                _TIDS[ident] = tid
                _TID_NAMES[tid] = t.name
    return tid


def _append_event(name: str, t0: float, t1: float, args) -> None:
    global _EVENTS
    ev = (name, _tid(), (t0 - _EPOCH) * 1e6, (t1 - t0) * 1e6, args)
    with _TRACE_LOCK:
        if _EVENTS is None:
            _EVENTS = deque(maxlen=trace_cap())
        _EVENTS.append(ev)


class _Span:
    """Live span: duration → histogram, plus a trace event when tracing."""

    __slots__ = ("name", "args", "_t0")

    def __init__(self, name: str, args=None):
        self.name = name
        self.args = args
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        _reg.REGISTRY.histogram("span." + self.name).observe(t1 - self._t0)
        if _reg._MODE == "trace":
            _append_event(self.name, self._t0, t1, self.args)


class _NullSpan:
    """Shared no-op span for the disabled path (never allocates)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL = _NullSpan()


def span(name: str, args=None):
    """A context manager timing the enclosed region (see module doc).
    ``args`` (a small dict, or None) lands in the trace event's
    ``args`` field; hot callers pass None to avoid building it."""
    if _reg._MODE == "off":
        return _NULL
    return _Span(name, args)


def instant(name: str, args=None) -> None:
    """A zero-duration trace marker (mode 'trace' only) — e.g. a
    snapshot swap or a shed decision, things with a *moment* rather
    than a duration."""
    if _reg._MODE != "trace":
        return
    t = time.perf_counter()
    _append_event(name, t, t, args)


def trace_events() -> dict:
    """The trace ring as a Chrome Trace Event Format dict (Perfetto /
    chrome://tracing load it as-is).  Events are "X" (complete) phases;
    thread-name metadata rows label each tid."""
    pid = os.getpid()
    with _TRACE_LOCK:
        evs = list(_EVENTS) if _EVENTS is not None else []
        names = dict(_TID_NAMES)
    out = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": nm}} for tid, nm in sorted(names.items())]
    for name, tid, ts, dur, args in evs:
        ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
              "ts": round(ts, 3), "dur": round(dur, 3), "cat": "lims"}
        if args:
            ev["args"] = dict(args)
        out.append(ev)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def trace_len() -> int:
    with _TRACE_LOCK:
        return len(_EVENTS) if _EVENTS is not None else 0


def clear_trace() -> None:
    global _EVENTS
    with _TRACE_LOCK:
        _EVENTS = None


__all__ = ["span", "instant", "trace_events", "trace_len", "clear_trace",
           "trace_cap"]
