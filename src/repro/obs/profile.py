"""QueryProfile: the structured record of one served batch's cost.

The paper's headline metric is per-query cost — page accesses above
all, then candidates refined and distance computations — and the open
research directions (continuous rebalance, DIMS-style cost-based
distributed routing) need that cost measured *per served batch*, not
inferred from benchmarks.  Every executed batch therefore yields one
:class:`QueryProfile`:

* **IO** — unique pages the batch touched and pages/query (0 for the
  resident tier, real page-extent IO for the paged tier);
* **pruning power** — candidates certified per query and clusters the
  certified set touches per query (out of K), i.e. how hard TriPrune +
  the ring box actually pruned *this* batch — the signal the
  curse-of-dimensionality results say must be measured per query;
* **rounds / syncs** — growing-radius rounds and device→host syncs
  (the plan/execute acceptance metrics, now continuously recorded);
* **per-stage latency** — plan construction, backend execution, exact
  refinement, and the total.

Profiles land in a bounded ring (``REPRO_OBS_PROFILES`` records,
default 256 — a serving window, not a log) and feed the registry's
``profile.*`` histograms, so exporters see both the recent records and
the long-run distributions.  Recording is gated on ``REPRO_OBS`` like
every obs path; the executor builds the record only when enabled.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field

from . import registry as _reg
from .registry import _int_knob

# every field a complete profile must carry (the completeness test
# asserts these are present and non-None across resident / paged /
# sharded configs)
REQUIRED_FIELDS = (
    "kind", "batch", "backend", "storage", "n_shards", "rounds",
    "host_syncs", "pages", "pages_per_query", "candidates_per_query",
    "clusters_per_query", "n_clusters", "stages", "total_s",
)
REQUIRED_STAGES = ("plan", "execute", "refine")


@dataclass
class QueryProfile:
    """One served batch's cost record (see module doc)."""

    kind: str                    # "range" | "knn"
    batch: int                   # queries in the batch
    k: int | None                # kNN k (None for range)
    backend: str                 # "resident" | "paged"
    driver: str | None           # kNN driver (loop|rounds|paged); None range
    storage: str                 # "resident" | "paged"
    n_shards: int
    rounds: int                  # growing-radius rounds (1 for range)
    host_syncs: int              # device→host materializations
    pages: int                   # unique pages touched (0 resident)
    pages_per_query: float       # the paper's IO metric
    candidates_per_query: float  # certified candidate rows / query
    clusters_per_query: float    # clusters the certified set spans / query
    n_clusters: int              # K, for interpreting the pruning power
    stages: dict = field(default_factory=dict)   # stage → seconds
    total_s: float = 0.0
    # observed rank-model error as a fraction of the certified bound E
    # (host-sampled over this batch's certified in-ring candidates; None
    # when the batch had none — optional, NOT in REQUIRED_FIELDS)
    rank_err_ratio: float | None = None

    def as_dict(self) -> dict:
        return {
            "kind": self.kind, "batch": self.batch, "k": self.k,
            "backend": self.backend, "driver": self.driver,
            "storage": self.storage, "n_shards": self.n_shards,
            "rounds": self.rounds, "host_syncs": self.host_syncs,
            "pages": self.pages,
            "pages_per_query": round(self.pages_per_query, 3),
            "candidates_per_query": round(self.candidates_per_query, 2),
            "clusters_per_query": round(self.clusters_per_query, 2),
            "n_clusters": self.n_clusters,
            "rank_err_ratio": (round(self.rank_err_ratio, 4)
                               if self.rank_err_ratio is not None else None),
            "stages_ms": {k: round(v * 1e3, 3)
                          for k, v in self.stages.items()},
            "total_ms": round(self.total_s * 1e3, 3),
        }

    def missing(self) -> list:
        """Required fields that are absent/None (empty when complete)."""
        out = [f for f in REQUIRED_FIELDS if getattr(self, f, None) is None]
        out += [f"stages.{s}" for s in REQUIRED_STAGES
                if s not in self.stages]
        return out


_LOCK = threading.Lock()
_PROFILES: deque | None = None


def profile_cap() -> int:
    """Profile ring capacity (``REPRO_OBS_PROFILES``)."""
    return _int_knob("REPRO_OBS_PROFILES", 256)


def record_profile(p: QueryProfile) -> None:
    """Append one batch's profile to the ring and fold its scalars into
    the registry's ``profile.*`` metrics (no-op when obs is off — but
    the executor already skips *building* the record then)."""
    global _PROFILES
    if _reg._MODE == "off":
        return
    with _LOCK:
        if _PROFILES is None:
            _PROFILES = deque(maxlen=profile_cap())
        _PROFILES.append(p)
    r = _reg.REGISTRY
    r.counter("profile.batches").inc()
    r.counter("profile.queries").inc(p.batch)
    r.counter("profile.pages").inc(p.pages)
    r.histogram("profile.pages_per_query").observe(p.pages_per_query)
    r.histogram("profile.candidates_per_query").observe(
        p.candidates_per_query)
    r.histogram("profile.clusters_per_query").observe(p.clusters_per_query)
    r.histogram("profile.rounds").observe(p.rounds)
    r.histogram("profile.host_syncs").observe(p.host_syncs)
    r.histogram("profile.total_s").observe(p.total_s)
    if p.rank_err_ratio is not None:
        r.histogram("profile.rank_err_ratio").observe(p.rank_err_ratio)
    for stage, dt in p.stages.items():
        r.histogram(f"profile.stage.{stage}_s").observe(dt)


def profiles(n: int | None = None) -> list:
    """The most recent ``n`` profiles (all retained when None),
    oldest first."""
    with _LOCK:
        out = list(_PROFILES) if _PROFILES is not None else []
    return out if n is None else out[-n:]


def last_profile() -> QueryProfile | None:
    with _LOCK:
        if _PROFILES:
            return _PROFILES[-1]
    return None


def clear_profiles() -> None:
    global _PROFILES
    with _LOCK:
        _PROFILES = None


__all__ = ["QueryProfile", "REQUIRED_FIELDS", "REQUIRED_STAGES",
           "clear_profiles", "last_profile", "profile_cap", "profiles",
           "record_profile"]
