"""Exporters: registry + profiles + trace + monitor → JSON / Prometheus /
Chrome.

Three read-only renderings of the same state:

* :func:`json_snapshot` — everything (mode, metrics, recent
  QueryProfiles, trace depth, and — when a monitor is passed or active —
  its time series and findings) as one JSON-able dict; the programmatic
  surface and what ``repro.obs.report --json`` writes.
* :func:`prometheus_text` — the text exposition format: counters and
  gauges as-is; histograms twice — the original summary family with
  quantile labels plus ``_count``/``_sum``, and a parallel ``<name>_hist``
  **histogram** family with real cumulative ``_bucket``/``le`` lines from
  the exact fixed-bound counts, so burn-rate recording rules are
  computable by a stock Prometheus.  Monitor series additionally render
  as ``lims_monitor_series`` gauges.  Metric names are sanitized
  (dots → underscores) to the Prometheus grammar.
* :func:`chrome_trace` / :func:`write_chrome_trace` — the span ring as a
  Chrome Trace Event Format JSON object, loadable in Perfetto or
  chrome://tracing.

Exporters never mutate state and take the same locks the recorders do,
so they are safe to call from a live serving process.
"""
from __future__ import annotations

import json

from . import profile as _prof
from . import registry as _reg
from . import trace as _trace


def _active_monitor(monitor):
    """Resolve an explicit monitor, else any running one, else None."""
    if monitor is not None:
        return monitor
    from . import monitor as _mon  # local import: monitor imports registry
    act = _mon.active_monitors()
    return act[0] if act else None


def json_snapshot(n_profiles: int = 32, monitor=None) -> dict:
    """One dict with the whole observability state (JSON-serializable).

    ``monitor`` adds that monitor's series/findings under ``"monitor"``;
    when omitted, a running monitor (if any) is picked up automatically.
    """
    doc = {
        "mode": _reg.obs_mode(),
        "metrics": _reg.REGISTRY.snapshot(),
        "profiles": [p.as_dict() for p in _prof.profiles(n_profiles)],
        "trace_events": _trace.trace_len(),
    }
    mon = _active_monitor(monitor)
    if mon is not None:
        doc["monitor"] = mon.snapshot()
    return doc


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    s = "".join(out)
    if s and s[0].isdigit():
        s = "_" + s
    return "lims_" + s


def prometheus_text(monitor=None) -> str:
    """The registry (plus monitor series, when one is passed or running)
    in Prometheus text exposition format."""
    lines: list[str] = []
    for m in _reg.REGISTRY.metrics():
        pn = _prom_name(m.name)
        if m.kind == "counter":
            lines.append(f"# TYPE {pn} counter")
            if m.help:
                lines.append(f"# HELP {pn} {m.help}")
            lines.append(f"{pn} {m.value}")
        elif m.kind == "gauge":
            lines.append(f"# TYPE {pn} gauge")
            if m.help:
                lines.append(f"# HELP {pn} {m.help}")
            lines.append(f"{pn} {_fmt(m.value)}")
        else:  # histogram → summary + real bucket family
            lines.append(f"# TYPE {pn} summary")
            if m.help:
                lines.append(f"# HELP {pn} {m.help}")
            for q in (0.5, 0.9, 0.99):
                v = m.percentile(q * 100.0)
                lines.append(f'{pn}{{quantile="{_fmt(q)}"}} {_fmt(v)}')
            lines.append(f"{pn}_count {m.count}")
            lines.append(f"{pn}_sum {_fmt(m.sum)}")
            hn = pn + "_hist"
            bounds, cum = m.buckets()
            lines.append(f"# TYPE {hn} histogram")
            for b, c in zip(bounds, cum):
                lines.append(f'{hn}_bucket{{le="{_fmt(b)}"}} {c}')
            lines.append(f'{hn}_bucket{{le="+Inf"}} {cum[-1]}')
            lines.append(f"{hn}_count {cum[-1]}")
            lines.append(f"{hn}_sum {_fmt(m.sum)}")
    mon = _active_monitor(monitor)
    if mon is not None:
        lines.extend(_monitor_series_lines(mon))
    return "\n".join(lines) + "\n"


def _monitor_series_lines(mon) -> list[str]:
    """Series-derived gauges: last value and ring mean per series, plus
    tick and findings totals — the scrape surface for dashboarding the
    monitor without re-deriving series server-side."""
    lines = ["# TYPE lims_monitor_series gauge"]
    snap = mon.store.snapshot(spark_width=0)
    for name in sorted(snap):
        st = snap[name]
        if not st.get("n"):
            continue
        for stat in ("last", "mean"):
            lines.append(
                f'lims_monitor_series{{series="{name}",stat="{stat}"}} '
                f"{_fmt(st[stat])}")
    lines.append("# TYPE lims_monitor_ticks gauge")
    lines.append(f"lims_monitor_ticks {mon.store.ticks}")
    lines.append("# TYPE lims_monitor_findings_total gauge")
    lines.append(f"lims_monitor_findings_total {len(mon.findings())}")
    return lines


def _fmt(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def chrome_trace() -> dict:
    """The span ring as a Chrome Trace Event Format dict."""
    return _trace.trace_events()


def write_chrome_trace(path: str) -> int:
    """Write the Perfetto-loadable trace JSON to ``path``; returns the
    number of events written (excluding thread-name metadata)."""
    doc = chrome_trace()
    with open(path, "w") as f:
        json.dump(doc, f)
    return sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")


def write_json_snapshot(path: str, n_profiles: int = 32,
                        monitor=None) -> None:
    with open(path, "w") as f:
        json.dump(json_snapshot(n_profiles, monitor=monitor), f,
                  indent=2, sort_keys=True)


def write_prometheus(path: str, monitor=None) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(monitor=monitor))


__all__ = ["chrome_trace", "json_snapshot", "prometheus_text",
           "write_chrome_trace", "write_json_snapshot", "write_prometheus"]
