"""Shared demo fixtures for the report CLI (and its CI smokes).

``report --demo`` and ``report --health`` used to risk drifting apart
by each building their own inline state; both now build through
:func:`demo_state` — one tiny synthetic index + engine + query set —
and layer their workload on top:

* :func:`run_traffic_demo` — the PR-8 exporter smoke: range/kNN/frontend
  traffic under full tracing, asserting a complete ``QueryProfile``.
* :func:`run_health_demo` — the §12 closed loop, deterministically:
  a 4-replica router over the same snapshot, placement drift injected
  by pinning every cluster's ownership to replica 0, then
  manually-ticked monitoring — the heat-skew detector fires, the
  daemon rebalances within its cooldown, and the series show the
  spread recovering.  No threads, no sleeps: every tick is explicit.
"""
from __future__ import annotations

import threading
from types import SimpleNamespace

import numpy as np

from . import profile, registry


def demo_state(mode: str = "trace") -> SimpleNamespace:
    """One small index + serving engine + query batch (seeded rng)."""
    from ..core import LIMSIndex, MetricSpace, ServingEngine

    registry.configure(mode)
    rng = np.random.default_rng(0)
    data = rng.standard_normal((600, 8))
    ix = LIMSIndex(MetricSpace(data, "l2"), n_clusters=6, m=2, n_rings=6)
    se = ServingEngine(ix, refresh_every=0)
    Q = data[rng.choice(600, 16, replace=False)] + 0.01
    return SimpleNamespace(rng=rng, data=data, ix=ix, se=se, Q=Q)


def run_traffic_demo(st: SimpleNamespace | None = None) -> SimpleNamespace:
    """Serve a small synthetic workload with full tracing enabled."""
    st = st if st is not None else demo_state("trace")
    st.se.range_query_batch(st.Q, 0.7)
    st.se.knn_query_batch(st.Q, 5)
    with st.se.frontend(max_batch=8, slo_ms=5.0) as fe:
        threads = [threading.Thread(
            target=fe.knn_query, args=(st.Q[j], 3)) for j in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    p = profile.last_profile()
    assert p is not None and not p.missing(), \
        f"demo must yield a complete QueryProfile, missing={p and p.missing()}"
    return st


def run_health_demo(st: SimpleNamespace | None = None, ticks: int = 10):
    """Inject placement drift and drive the closed loop by hand.

    Returns ``(state, monitor, daemon)`` with at least one heat-skew
    finding recorded and (cooldown permitting) a rebalance event in the
    daemon's audit ring.
    """
    from ..serving import MonitorDaemon, PlanRouter, ReplicaSet
    from .monitor import Monitor

    st = st if st is not None else demo_state("trace")
    snap = st.se.executor.snap
    replicas = ReplicaSet(snap, n_replicas=4)
    router = PlanRouter(replicas)
    # interval is irrelevant — the demo ticks manually, nothing starts
    # the sampler thread, so the loop below is fully deterministic
    mon = Monitor(interval=3600.0)
    daemon = MonitorDaemon(mon, lambda: router, engine=st.se,
                           cooldown_ticks=3)
    # the injected drift: ownership says replica 0 owns *everything*
    # while real query heat is spread — exactly what serving a stale
    # placement under shifted traffic looks like
    replicas.set_ownership(np.zeros(snap.K, np.int64))
    for _ in range(int(ticks)):
        router.knn_query_batch(st.Q, 5)
        mon.tick()
    return st, mon, daemon
