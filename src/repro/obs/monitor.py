"""Continuous index-health monitor: the sampler loop (DESIGN.md §12).

A :class:`Monitor` owns one :class:`~repro.obs.timeseries.SeriesStore`,
a set of :class:`~repro.obs.health.Detector` instances, and a bounded
findings ring.  Each **tick** it (1) runs registered probe callables
(cheap gauges computed on demand, e.g. the router's heat-skew), (2)
samples the metrics registry into the series store, (3) evaluates every
detector, and (4) appends new :class:`HealthFinding`s to the ring and
fans them out to subscriber callbacks (the serving
:class:`~repro.serving.daemon.MonitorDaemon` is the canonical
subscriber).

Ticks can be driven two ways:

* **manually** — call :meth:`Monitor.tick` yourself; deterministic, the
  form every test and the ``report --health`` demo use;
* **periodically** — :meth:`Monitor.start` spawns one daemon thread
  ticking every ``interval`` seconds (``REPRO_MONITOR_INTERVAL``,
  default 0.5s).  :meth:`Monitor.stop` joins it with a timeout; all
  started monitors are also stopped by an atexit hook, mirroring the
  prefetch-worker lifecycle in ``repro.storage.prefetch``.

Mode: ``REPRO_MONITOR`` (off | on, default off) is read once at import
and cached in :data:`_MODE` — with ``off`` nothing here spawns a
thread and the gate helpers (:func:`monitor_enabled`,
:func:`maybe_monitor`) return without allocating (tracemalloc-pinned,
like ``REPRO_OBS=off``).
"""
from __future__ import annotations

import atexit
import threading
from collections import deque

from .. import env
from . import registry as _reg
from .health import Detector, HealthFinding, default_detectors
from .registry import MetricsRegistry, _int_knob
from .timeseries import SeriesStore

__all__ = ["Monitor", "monitor_enabled", "monitor_mode", "configure_monitor",
           "maybe_monitor", "active_monitors", "shutdown_monitors",
           "monitor_interval", "findings_cap"]


def _resolve_mode() -> str:
    return env.get("REPRO_MONITOR")


_MODE: str = _resolve_mode()


def monitor_mode() -> str:
    """The cached monitor mode: 'off' | 'on'."""
    return _MODE


def monitor_enabled() -> bool:
    return _MODE == "on"


def configure_monitor(mode: str | None = None) -> str:
    """Set the monitor mode ('off'|'on'), or re-read ``REPRO_MONITOR``
    when ``mode`` is None.  Returns the active mode.  Flipping the mode
    does not stop already-running monitors — owners do that."""
    global _MODE
    if mode is None:
        _MODE = _resolve_mode()
    else:
        mode = str(mode).strip().lower()
        if mode not in ("off", "on"):
            raise ValueError(f"monitor mode must be off|on, got {mode!r}")
        _MODE = mode
    return _MODE


def monitor_interval() -> float:
    """Sampler tick interval in seconds (``REPRO_MONITOR_INTERVAL``)."""
    raw = env.get("REPRO_MONITOR_INTERVAL")
    if raw is None or str(raw).strip() == "":
        return 0.5
    try:
        v = float(raw)
    except (TypeError, ValueError):
        raise ValueError(
            f"REPRO_MONITOR_INTERVAL={raw!r} is not a valid setting "
            "(expected a float, seconds)")
    if v <= 0:
        raise ValueError(f"REPRO_MONITOR_INTERVAL must be > 0, got {v}")
    return v


def findings_cap() -> int:
    """Findings ring capacity (``REPRO_MONITOR_FINDINGS``, >= 1)."""
    return _int_knob("REPRO_MONITOR_FINDINGS", 256)


# started monitors, tracked for the atexit join (mirrors the prefetch
# worker's shutdown contract: bounded join, never hangs interpreter exit)
_ACTIVE: set = set()
_ACTIVE_LOCK = threading.Lock()


class Monitor:
    """Sampler + detectors + findings ring over one metrics registry."""

    def __init__(self, registry: MetricsRegistry | None = None,
                 interval: float | None = None,
                 detectors: list[Detector] | None = None,
                 series_cap: int | None = None,
                 findings: int | None = None):
        self.registry = registry if registry is not None else _reg.REGISTRY
        self.interval = float(interval) if interval is not None \
            else monitor_interval()
        self.store = SeriesStore(series_cap)
        self.detectors = list(detectors) if detectors is not None \
            else default_detectors()
        self._findings: deque[HealthFinding] = deque(
            maxlen=findings or findings_cap())
        self._probes: list = []
        self._subscribers: list = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- wiring ----------------------------------------------------------
    def add_probe(self, fn) -> None:
        """Register a zero-arg callable run at the start of every tick
        (compute on-demand gauges before sampling).  Probe exceptions
        are counted (``monitor.probe_errors``), never propagated."""
        with self._lock:
            self._probes.append(fn)

    def subscribe(self, fn) -> None:
        """Register a callback invoked with each new HealthFinding."""
        with self._lock:
            self._subscribers.append(fn)

    # -- one tick --------------------------------------------------------
    def tick(self) -> list[HealthFinding]:
        """Probe, sample, detect; returns the findings fired this tick."""
        with self._lock:
            probes = list(self._probes)
            subs = list(self._subscribers)
        for p in probes:
            try:
                p()
            except Exception:
                _reg.count("monitor.probe_errors")
        self.store.sample(self.registry)
        tick = self.store.ticks
        fired: list[HealthFinding] = []
        for det in self.detectors:
            fired.extend(det.evaluate(self.store, tick))
        if fired:
            with self._lock:
                self._findings.extend(fired)
            _reg.count("monitor.findings", len(fired))
        _reg.count("monitor.ticks")
        for f in fired:
            for s in subs:
                try:
                    s(f)
                except Exception:
                    _reg.count("monitor.subscriber_errors")
        return fired

    # -- background loop -------------------------------------------------
    def start(self) -> "Monitor":
        """Spawn the sampler thread (idempotent while running)."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name="lims-monitor", daemon=True)
            self._thread.start()
        with _ACTIVE_LOCK:
            _ACTIVE.add(self)
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.tick()
            except Exception:
                # a failing probe/detector must never kill the sampler
                _reg.count("monitor.tick_errors")

    def stop(self, timeout: float = 2.0) -> bool:
        """Stop and join the sampler thread (idempotent).  Returns True
        when no sampler thread remains alive."""
        with self._lock:
            t, self._thread = self._thread, None
        self._stop.set()
        if t is not None and t.is_alive():
            t.join(timeout)
        with _ACTIVE_LOCK:
            _ACTIVE.discard(self)
        return t is None or not t.is_alive()

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    # -- inspection ------------------------------------------------------
    def findings(self, n: int | None = None) -> list[HealthFinding]:
        """Most recent findings, newest last (all when ``n`` is None)."""
        with self._lock:
            out = list(self._findings)
        return out if n is None else out[-n:]

    def snapshot(self, spark_width: int = 24) -> dict:
        """JSON-ready monitor state: series stats, findings, detectors."""
        return {
            "interval_s": self.interval,
            "running": self.running,
            "ticks": self.store.ticks,
            "series": self.store.snapshot(spark_width),
            "findings": [f.as_dict() for f in self.findings()],
            "detectors": [d.state() for d in self.detectors],
        }


def maybe_monitor(**kw) -> Monitor | None:
    """A fresh started Monitor when ``REPRO_MONITOR=on``, else None.

    This is the gate serving layers call at construction time — with
    the knob off it is one string compare and no allocation."""
    if _MODE != "on":
        return None
    return Monitor(**kw).start()


def active_monitors() -> list[Monitor]:
    """Monitors with a live sampler thread (stop() removes them)."""
    with _ACTIVE_LOCK:
        return list(_ACTIVE)


def shutdown_monitors(timeout: float = 2.0) -> bool:
    """Stop every started monitor; True when all joined within timeout.

    Registered atexit so stray monitors never block interpreter exit;
    also callable directly (tests, embedders)."""
    ok = True
    for m in active_monitors():
        ok = m.stop(timeout) and ok
    return ok


atexit.register(shutdown_monitors)
