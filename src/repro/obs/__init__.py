"""Unified observability for the serving stack (DESIGN.md §11).

One process-wide metrics registry (counters / gauges / bounded-reservoir
histograms), a span tracer over the query path, per-batch
``QueryProfile`` records, and exporters (JSON, Prometheus text, Chrome
trace_event).  Controlled by ``REPRO_OBS=off|on|trace``; the disabled
path costs one string compare and allocates nothing.

    from repro import obs
    with obs.span("my.stage"):
        ...
    obs.count("my.counter")
    print(obs.json_snapshot())
"""
from .registry import (REGISTRY, Counter, Gauge, Histogram,  # noqa: F401
                       MetricsRegistry, configure, count, enabled,
                       obs_mode, observe, set_gauge, tracing)
from .trace import (clear_trace, instant, span, trace_events,  # noqa: F401
                    trace_len)
from .profile import (QueryProfile, clear_profiles,  # noqa: F401
                      last_profile, profiles, record_profile)
from .export import (chrome_trace, json_snapshot,  # noqa: F401
                     prometheus_text, write_chrome_trace,
                     write_json_snapshot, write_prometheus)
from .timeseries import Series, SeriesStore, sparkline  # noqa: F401
from .health import (Detector, HealthFinding,  # noqa: F401
                     HeatSkewDetector, PruningRegressionDetector,
                     RankDriftDetector, SloBurnDetector,
                     default_detectors)
from .monitor import (Monitor, active_monitors,  # noqa: F401
                      configure_monitor, maybe_monitor, monitor_enabled,
                      monitor_mode, shutdown_monitors)

__all__ = [
    "REGISTRY", "Counter", "Detector", "Gauge", "HealthFinding",
    "HeatSkewDetector", "Histogram", "MetricsRegistry", "Monitor",
    "PruningRegressionDetector", "QueryProfile", "RankDriftDetector",
    "Series", "SeriesStore", "SloBurnDetector", "active_monitors",
    "chrome_trace", "clear_profiles", "clear_trace", "configure",
    "configure_monitor", "count", "default_detectors", "enabled",
    "instant", "json_snapshot", "last_profile", "maybe_monitor",
    "monitor_enabled", "monitor_mode", "obs_mode", "observe", "profiles",
    "prometheus_text", "record_profile", "set_gauge", "shutdown_monitors",
    "span", "sparkline", "trace_events", "trace_len", "tracing",
    "write_chrome_trace", "write_json_snapshot", "write_prometheus",
]
