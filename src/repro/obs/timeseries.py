"""Bounded time-series layer over the metrics registry (DESIGN.md §12).

The registry (``repro.obs.registry``) holds *instantaneous* state:
cumulative counters, last-writer-wins gauges, reservoir histograms.
This module adds the notion of **time**: a :class:`SeriesStore` samples
a registry on every monitor tick and appends one point per metric into
bounded ring-buffer :class:`Series`:

* counters   -> ``delta`` series (per-tick increments, so rates and
  windowed sums are trivial and counter resets self-heal),
* gauges     -> ``level`` series (the sampled value),
* histograms -> three derived ``level``/``delta`` series:
  ``<name>.p50`` and ``<name>.p99`` (reservoir percentiles at sample
  time) plus ``<name>.rate`` (observation-count delta per tick).

Everything is plain host Python under one lock — sampling touches no
device state and allocates O(#metrics) per tick.  Ring capacity comes
from ``REPRO_MONITOR_SERIES_CAP`` (default 512 points per series).

Health detectors (``repro.obs.health``) read these series; nothing in
this module starts threads — the sampler loop lives in
``repro.obs.monitor``.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Iterable

from . import registry as _reg
from .registry import Counter, Gauge, Histogram, MetricsRegistry, _int_knob

__all__ = ["Series", "SeriesStore", "series_cap", "sparkline"]

_SPARK_BLOCKS = "▁▂▃▄▅▆▇█"


def series_cap() -> int:
    """Ring capacity per series (``REPRO_MONITOR_SERIES_CAP``, >= 1)."""
    return _int_knob("REPRO_MONITOR_SERIES_CAP", 512)


class Series:
    """One bounded ring of float samples, appended once per tick.

    ``kind`` is ``"delta"`` (per-tick increments of a cumulative
    counter) or ``"level"`` (sampled instantaneous values).  The
    distinction matters to consumers: summing a delta series over a
    window gives the window total, while a level series is averaged.
    """

    __slots__ = ("name", "kind", "_vals")

    def __init__(self, name: str, kind: str = "level", cap: int | None = None):
        if kind not in ("delta", "level"):
            raise ValueError(f"series kind must be delta|level, got {kind!r}")
        self.name = name
        self.kind = kind
        self._vals: deque[float] = deque(maxlen=cap or series_cap())

    def append(self, v: float) -> None:
        self._vals.append(float(v))

    def extend(self, vs: Iterable[float]) -> None:
        for v in vs:
            self._vals.append(float(v))

    def __len__(self) -> int:
        return len(self._vals)

    def values(self) -> list[float]:
        return list(self._vals)

    def last(self) -> float | None:
        return self._vals[-1] if self._vals else None

    def window(self, n: int) -> list[float]:
        """The most recent ``n`` samples (fewer if the ring is shorter)."""
        if n <= 0:
            return []
        vs = self._vals
        return list(vs)[-n:] if len(vs) > n else list(vs)

    def window_mean(self, n: int) -> float | None:
        w = self.window(n)
        return sum(w) / len(w) if w else None

    def window_sum(self, n: int) -> float:
        return float(sum(self.window(n)))

    def stats(self) -> dict:
        vs = list(self._vals)
        if not vs:
            return {"kind": self.kind, "n": 0}
        return {
            "kind": self.kind,
            "n": len(vs),
            "last": vs[-1],
            "mean": sum(vs) / len(vs),
            "min": min(vs),
            "max": max(vs),
        }


def sparkline(values: list[float], width: int = 24) -> str:
    """Unicode block sparkline of the last ``width`` values."""
    vs = [v for v in values[-width:] if not math.isnan(v)]
    if not vs:
        return ""
    lo, hi = min(vs), max(vs)
    span = hi - lo
    if span <= 0:
        return _SPARK_BLOCKS[0] * len(vs)
    top = len(_SPARK_BLOCKS) - 1
    return "".join(
        _SPARK_BLOCKS[min(top, int((v - lo) / span * top + 0.5))] for v in vs)


class SeriesStore:
    """Named series rings plus the registry sampler that feeds them."""

    def __init__(self, cap: int | None = None):
        self._cap = cap or series_cap()
        self._series: dict[str, Series] = {}
        self._prev_counts: dict[str, int] = {}
        self._lock = threading.Lock()
        self.ticks = 0

    # -- access ----------------------------------------------------------
    def series(self, name: str, kind: str = "level") -> Series:
        """Get-or-create the series ``name`` (kind fixed at creation)."""
        with self._lock:
            s = self._series.get(name)
            if s is None:
                s = self._series[name] = Series(name, kind, self._cap)
            return s

    def get(self, name: str) -> Series | None:
        with self._lock:
            return self._series.get(name)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._series)

    def match(self, prefix: str) -> list[Series]:
        """All series whose name starts with ``prefix`` (sorted by name)."""
        with self._lock:
            return [s for n, s in sorted(self._series.items())
                    if n.startswith(prefix)]

    # -- sampling --------------------------------------------------------
    def sample(self, registry: MetricsRegistry | None = None) -> None:
        """Append one point per registry metric (one monitor tick)."""
        reg = registry if registry is not None else _reg.REGISTRY
        for m in reg.metrics():
            if isinstance(m, Counter):
                v = m.value
                prev = self._prev_counts.get(m.name, 0)
                # counter reset (registry.reset() / fresh process) shows
                # as v < prev: restart the delta baseline, don't go
                # negative
                self.series(m.name, "delta").append(v - prev if v >= prev else v)
                self._prev_counts[m.name] = v
            elif isinstance(m, Gauge):
                self.series(m.name, "level").append(m.value)
            elif isinstance(m, Histogram):
                snap = m.snapshot()
                self.series(m.name + ".p50", "level").append(snap["p50"])
                self.series(m.name + ".p99", "level").append(snap["p99"])
                cnt = snap["count"]
                key = m.name + ".rate"
                prev = self._prev_counts.get(key, 0)
                self.series(key, "delta").append(cnt - prev if cnt >= prev else cnt)
                self._prev_counts[key] = cnt
        self.ticks += 1

    def snapshot(self, spark_width: int = 24) -> dict:
        """JSON-ready summary of every series (stats + sparkline)."""
        with self._lock:
            items = sorted(self._series.items())
        out = {}
        for name, s in items:
            st = s.stats()
            st["spark"] = sparkline(s.values(), spark_width)
            out[name] = st
        return out
