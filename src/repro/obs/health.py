"""Health detectors: typed findings over monitor time series (DESIGN.md §12).

Each detector turns a scalar **signal** derived from the
:class:`~repro.obs.timeseries.SeriesStore` into typed
:class:`HealthFinding` events with hysteresis, so one noisy tick never
fires an action and a signal hovering at the threshold never flaps:

* the detector *arms* while the signal is >= ``trigger`` and fires only
  after ``persistence`` consecutive over-trigger ticks;
* once active it re-fires at most every ``refire`` ticks (the findings
  ring stays auditable without flooding);
* it *clears* only when the signal drops to <= ``clear`` (< trigger),
  emitting an informational cleared-finding.

Detectors are pure functions of the series store — no threads, no
registry access — so unit tests drive them deterministically over
hand-built series.  The sampler loop that feeds them lives in
``repro.obs.monitor``; the serving reactions live in
``repro.serving.daemon``.

The four shipped detectors watch the decay modes called out in the
paper's §6 dynamic workload and ROADMAP item 2:

* :class:`RankDriftDetector` — per-cluster observed rank-model error
  (``executor.rank_err_ratio.c<k>`` gauges, fed by the executor's
  per-batch observed-rank-error stat) as a fraction of the certified
  bound E.  Signal = max over clusters of the last sampled ratio.
* :class:`PruningRegressionDetector` — pruning power erosion: the
  ``profile.candidates_per_query.p50`` series against its own early
  baseline.  Signal = recent-window mean / baseline mean.
* :class:`HeatSkewDetector` — cache heat vs replica ownership: the
  ``router.heat_skew`` gauge (max per-replica owned heat / mean).
* :class:`SloBurnDetector` — frontend error-budget burn: window miss
  rate over ``frontend.slo_ok``/``frontend.slo_miss`` deltas divided
  by the budget (1 - objective).  Burn 1.0 = exactly on budget.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .timeseries import SeriesStore

__all__ = ["HealthFinding", "Detector", "RankDriftDetector",
           "PruningRegressionDetector", "HeatSkewDetector",
           "SloBurnDetector", "default_detectors"]

SEVERITIES = ("info", "warn", "critical")


@dataclass(frozen=True)
class HealthFinding:
    """One detector event: something crossed (or re-crossed) a threshold."""

    detector: str          # detector name, e.g. "heat_skew"
    severity: str          # "info" | "warn" | "critical"
    summary: str           # human-readable one-liner
    value: float           # the signal value at fire time
    threshold: float       # the trigger it was compared against
    tick: int              # store tick index when fired (deterministic)
    cleared: bool = False  # True for the informational clear event
    context: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "detector": self.detector, "severity": self.severity,
            "summary": self.summary, "value": self.value,
            "threshold": self.threshold, "tick": self.tick,
            "cleared": self.cleared, "context": dict(self.context),
        }


class Detector:
    """Hysteresis base: subclasses implement :meth:`signal`.

    State machine (evaluated once per tick):

    ``idle`` --signal >= trigger for `persistence` ticks--> ``active``
    (fires a finding); ``active`` --signal <= clear--> ``idle`` (fires
    a cleared info finding); while ``active``, re-fires every
    ``refire`` ticks.  A signal of ``None`` (no data yet) leaves the
    state untouched.
    """

    name = "detector"

    def __init__(self, trigger: float, clear: float | None = None,
                 persistence: int = 3, refire: int = 10,
                 critical_at: float | None = None):
        if clear is None:
            clear = trigger * 0.75
        if clear >= trigger:
            raise ValueError(
                f"{self.name}: clear ({clear}) must be < trigger ({trigger})")
        self.trigger = float(trigger)
        self.clear = float(clear)
        self.persistence = max(1, int(persistence))
        self.refire = max(1, int(refire))
        self.critical_at = critical_at
        self.active = False
        self._over = 0           # consecutive over-trigger ticks while idle
        self._fired_tick = -1    # tick of the last emitted active finding

    # -- subclass API ----------------------------------------------------
    def signal(self, store: SeriesStore) -> Optional[tuple[float, dict]]:
        """(value, context) of the watched signal, or None if no data."""
        raise NotImplementedError

    def describe(self, value: float, context: dict) -> str:
        return (f"{self.name} signal {value:.3g} over trigger "
                f"{self.trigger:.3g}")

    # -- hysteresis ------------------------------------------------------
    def evaluate(self, store: SeriesStore, tick: int) -> list[HealthFinding]:
        sig = self.signal(store)
        if sig is None:
            return []
        value, context = sig
        value = float(value)
        out: list[HealthFinding] = []
        if not self.active:
            if value >= self.trigger:
                self._over += 1
                if self._over >= self.persistence:
                    self.active = True
                    self._fired_tick = tick
                    out.append(self._finding(value, context, tick))
            else:
                self._over = 0
        else:
            if value <= self.clear:
                self.active = False
                self._over = 0
                out.append(HealthFinding(
                    detector=self.name, severity="info",
                    summary=f"{self.name} cleared "
                            f"(signal {value:.3g} <= {self.clear:.3g})",
                    value=value, threshold=self.clear, tick=tick,
                    cleared=True, context=dict(context)))
            elif (value >= self.trigger
                  and tick - self._fired_tick >= self.refire):
                # still firing over trigger — re-emit (bounded by refire)
                # so long-lived conditions stay visible; inside the
                # hysteresis band (clear, trigger) stay active silently
                self._fired_tick = tick
                out.append(self._finding(value, context, tick))
        return out

    def _finding(self, value: float, context: dict,
                 tick: int) -> HealthFinding:
        sev = "warn"
        if self.critical_at is not None and value >= self.critical_at:
            sev = "critical"
        return HealthFinding(
            detector=self.name, severity=sev,
            summary=self.describe(value, context), value=value,
            threshold=self.trigger, tick=tick, context=dict(context))

    def state(self) -> dict:
        return {"name": self.name, "active": self.active,
                "trigger": self.trigger, "clear": self.clear,
                "persistence": self.persistence}


class RankDriftDetector(Detector):
    """Observed per-cluster rank-model error approaching the certified
    bound E: ratio 1.0 means the model is mispredicting ranks by as
    much as its ring-widening budget assumes — exactness still holds
    (E certifies the widening), but pruning pays full price and any
    further drift after a retrain-free refresh erodes the margin."""

    name = "rank_drift"

    def __init__(self, trigger: float = 0.75, clear: float = 0.5,
                 persistence: int = 2, refire: int = 10,
                 critical_at: float | None = 1.0):
        super().__init__(trigger, clear, persistence, refire, critical_at)

    def signal(self, store: SeriesStore):
        worst, worst_name = None, None
        for s in store.match("executor.rank_err_ratio.c"):
            v = s.last()
            if v is not None and (worst is None or v > worst):
                worst, worst_name = v, s.name
        if worst is None:
            return None
        cluster = int(worst_name.rsplit(".c", 1)[1])
        return worst, {"cluster": cluster, "series": worst_name}

    def describe(self, value, context):
        return (f"cluster {context['cluster']} observed rank error at "
                f"{value:.2f}x the certified bound E "
                f"(trigger {self.trigger:.2f})")


class PruningRegressionDetector(Detector):
    """Pruning power erosion: median candidates/query trending up
    against this store's own early baseline (first ``baseline_n``
    samples of ``profile.candidates_per_query.p50``)."""

    name = "pruning_regression"

    def __init__(self, trigger: float = 2.0, clear: float = 1.5,
                 persistence: int = 3, refire: int = 10,
                 baseline_n: int = 5, window: int = 3,
                 series: str = "profile.candidates_per_query.p50"):
        super().__init__(trigger, clear, persistence, refire)
        self.baseline_n = max(1, int(baseline_n))
        self.window = max(1, int(window))
        self.series_name = series

    def signal(self, store: SeriesStore):
        s = store.get(self.series_name)
        if s is None or len(s) < self.baseline_n + 1:
            return None
        vs = s.values()
        baseline = sum(vs[:self.baseline_n]) / self.baseline_n
        if baseline <= 0:
            return None
        recent = vs[-self.window:]
        ratio = (sum(recent) / len(recent)) / baseline
        return ratio, {"baseline": baseline,
                       "recent": sum(recent) / len(recent)}

    def describe(self, value, context):
        return (f"candidates/query at {value:.2f}x its baseline "
                f"({context['recent']:.1f} vs {context['baseline']:.1f}; "
                f"trigger {self.trigger:.2f}x)")


class HeatSkewDetector(Detector):
    """Cache heat vs replica ownership drift: the ``router.heat_skew``
    gauge (max per-replica owned heat / mean) — 1.0 is perfectly
    balanced, R means one replica owns all the heat."""

    name = "heat_skew"

    def __init__(self, trigger: float = 1.5, clear: float = 1.15,
                 persistence: int = 2, refire: int = 5):
        super().__init__(trigger, clear, persistence, refire)

    def signal(self, store: SeriesStore):
        s = store.get("router.heat_skew")
        if s is None or not len(s):
            return None
        return s.last(), {}

    def describe(self, value, context):
        return (f"replica heat skew {value:.2f}x mean "
                f"(trigger {self.trigger:.2f}x) — ownership no longer "
                f"matches query heat")


class SloBurnDetector(Detector):
    """Frontend error-budget burn rate: window miss fraction over the
    ``frontend.slo_ok``/``frontend.slo_miss`` delta series divided by
    the budget (1 - objective).  Burn 1.0 spends the budget exactly;
    the default trigger 2.0 / critical 14.0 mirrors SRE fast-burn
    alerting."""

    name = "slo_burn"

    def __init__(self, trigger: float = 2.0, clear: float = 1.0,
                 persistence: int = 2, refire: int = 5,
                 objective: float = 0.99, window: int = 10):
        super().__init__(trigger, clear, persistence, refire,
                         critical_at=14.0)
        if not 0.0 < objective < 1.0:
            raise ValueError(f"objective must be in (0, 1), got {objective}")
        self.objective = float(objective)
        self.window = max(1, int(window))

    def signal(self, store: SeriesStore):
        ok = store.get("frontend.slo_ok")
        miss = store.get("frontend.slo_miss")
        n_ok = ok.window_sum(self.window) if ok is not None else 0.0
        n_miss = miss.window_sum(self.window) if miss is not None else 0.0
        total = n_ok + n_miss
        if total <= 0:
            return None
        burn = (n_miss / total) / (1.0 - self.objective)
        return burn, {"ok": n_ok, "miss": n_miss,
                      "objective": self.objective}

    def describe(self, value, context):
        return (f"SLO burn rate {value:.1f}x budget "
                f"({int(context['miss'])} misses / "
                f"{int(context['ok'] + context['miss'])} requests at "
                f"{context['objective']:.2%} objective)")


def default_detectors() -> list[Detector]:
    """Fresh instances of the four shipped detectors (stateful — one
    set per monitor)."""
    return [RankDriftDetector(), PruningRegressionDetector(),
            HeatSkewDetector(), SloBurnDetector()]
