"""``python -m repro.obs.report`` — export serving telemetry to files.

Renders the process-wide observability state (metrics registry, recent
``QueryProfile`` records, span trace) through the three exporters:

    python -m repro.obs.report --demo \\
        --json obs.json --prom obs.prom --trace obs.trace.json

``--demo`` builds a tiny index, serves range/kNN/frontend traffic under
``REPRO_OBS=trace``, and then exports — a one-command smoke check that
every exporter produces well-formed output (CI runs exactly this).
Without ``--demo`` the CLI exports whatever the current process already
recorded, which only makes sense when embedded (``repro.obs.report
.main([...])`` from a serving script).  With no output paths the JSON
snapshot prints to stdout.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import export, profile, registry


def _run_demo() -> None:
    """Serve a small synthetic workload with full tracing enabled."""
    import numpy as np

    from ..core import LIMSIndex, MetricSpace, ServingEngine

    registry.configure("trace")
    rng = np.random.default_rng(0)
    data = rng.standard_normal((600, 8))
    ix = LIMSIndex(MetricSpace(data, "l2"), n_clusters=6, m=2, n_rings=6)
    se = ServingEngine(ix, refresh_every=0)
    Q = data[rng.choice(600, 16, replace=False)] + 0.01
    se.range_query_batch(Q, 0.7)
    se.knn_query_batch(Q, 5)
    with se.frontend(max_batch=8, slo_ms=5.0) as fe:
        import threading
        threads = [threading.Thread(
            target=fe.knn_query, args=(Q[j], 3)) for j in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    p = profile.last_profile()
    assert p is not None and not p.missing(), \
        f"demo must yield a complete QueryProfile, missing={p and p.missing()}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Export LIMS serving telemetry "
                    "(JSON / Prometheus / Chrome trace).")
    ap.add_argument("--demo", action="store_true",
                    help="serve a small synthetic workload first "
                         "(trace mode) so there is telemetry to export")
    ap.add_argument("--json", metavar="PATH",
                    help="write the JSON snapshot here")
    ap.add_argument("--prom", metavar="PATH",
                    help="write Prometheus text format here")
    ap.add_argument("--trace", metavar="PATH",
                    help="write the Chrome trace_event file here "
                         "(load in Perfetto / chrome://tracing)")
    ap.add_argument("--profiles", type=int, default=32, metavar="N",
                    help="recent QueryProfiles to include in the JSON "
                         "snapshot (default 32)")
    args = ap.parse_args(argv)

    if args.demo:
        _run_demo()

    wrote = []
    if args.json:
        export.write_json_snapshot(args.json, n_profiles=args.profiles)
        wrote.append(f"json snapshot -> {args.json}")
    if args.prom:
        export.write_prometheus(args.prom)
        wrote.append(f"prometheus text -> {args.prom}")
    if args.trace:
        n = export.write_chrome_trace(args.trace)
        wrote.append(f"chrome trace ({n} events) -> {args.trace}")
    if wrote:
        for line in wrote:
            print(line)
    else:
        json.dump(export.json_snapshot(args.profiles), sys.stdout,
                  indent=2, sort_keys=True)
        print()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
