"""``python -m repro.obs.report`` — export serving telemetry to files.

Renders the process-wide observability state (metrics registry, recent
``QueryProfile`` records, span trace, monitor series/findings) through
the exporters:

    python -m repro.obs.report --demo \\
        --json obs.json --prom obs.prom --trace obs.trace.json

``--demo`` builds a tiny index and serves range/kNN/frontend traffic
under ``REPRO_OBS=trace`` (``repro.obs.demo``) — a one-command smoke
check that every exporter produces well-formed output (CI runs exactly
this).  ``--health`` renders the index-health report (findings, series
sparklines, SLO attainment, daemon audit); combined with ``--demo`` it
first drives the deterministic closed-loop drift demo so there are
findings to show (the monitor CI leg's smoke).  Without ``--demo`` the
CLI exports whatever the current process already recorded, which only
makes sense when embedded (``repro.obs.report.main([...])`` from a
serving script).  With no output paths and no ``--health``, the JSON
snapshot prints to stdout.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import export, registry
from .timeseries import sparkline


def render_health(monitor, daemon=None) -> str:
    """The health report as text: detector states, findings, daemon
    audit events, series sparklines, and SLO attainment."""
    snap = monitor.snapshot()
    lines = ["== LIMS index health =="]
    lines.append(
        f"monitor: ticks={snap['ticks']} series={len(snap['series'])} "
        f"findings={len(snap['findings'])} "
        f"sampler={'running' if snap['running'] else 'manual'}")

    lines.append("detectors:")
    for d in snap["detectors"]:
        state = "ACTIVE" if d["active"] else "idle"
        lines.append(f"  {d['name']:<22} {state:<6} "
                     f"trigger={d['trigger']:.3g} clear={d['clear']:.3g} "
                     f"persistence={d['persistence']}")

    lines.append("findings (newest last):")
    if not snap["findings"]:
        lines.append("  (none)")
    for f in snap["findings"][-12:]:
        lines.append(f"  [{f['severity']}] tick {f['tick']} "
                     f"{f['detector']}: {f['summary']}")

    if daemon is not None:
        ev = daemon.events()
        lines.append(f"daemon: cooldown={daemon.cooldown_ticks} ticks, "
                     f"{len(ev)} audit event(s)")
        for e in ev[-8:]:
            extra = ""
            if e["action"] == "rebalance":
                extra = f" (skew {e['skew']:.2f}x)"
            elif "cluster" in e:
                extra = f" (cluster {e['cluster']})"
            lines.append(f"  tick {e['tick']}: {e['action']}"
                         f"{extra} [{e['detector']}]")

    lines.append("series:")
    shown = 0
    for name in sorted(snap["series"]):
        st = snap["series"][name]
        if not st.get("n"):
            continue
        s = monitor.store.get(name)
        spark = sparkline(s.values()) if s is not None else ""
        lines.append(f"  {name:<36} {spark:<24} "
                     f"last={st['last']:.4g} mean={st['mean']:.4g}")
        shown += 1
    if not shown:
        lines.append("  (no samples yet)")

    ok = registry.REGISTRY.get("frontend.slo_ok")
    miss = registry.REGISTRY.get("frontend.slo_miss")
    n_ok = ok.value if ok is not None else 0
    n_miss = miss.value if miss is not None else 0
    if n_ok + n_miss:
        att = n_ok / (n_ok + n_miss)
        lines.append(f"slo: attained {att:.2%} "
                     f"({n_miss} miss / {n_ok + n_miss} requests)")
    else:
        lines.append("slo: no frontend requests recorded")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Export LIMS serving telemetry "
                    "(JSON / Prometheus / Chrome trace / health report).")
    ap.add_argument("--demo", action="store_true",
                    help="serve a small synthetic workload first "
                         "(trace mode) so there is telemetry to export; "
                         "with --health, also drive the closed-loop "
                         "drift demo")
    ap.add_argument("--health", action="store_true",
                    help="render the index-health report (findings, "
                         "series sparklines, SLO attainment) to stdout")
    ap.add_argument("--json", metavar="PATH",
                    help="write the JSON snapshot here")
    ap.add_argument("--prom", metavar="PATH",
                    help="write Prometheus text format here")
    ap.add_argument("--trace", metavar="PATH",
                    help="write the Chrome trace_event file here "
                         "(load in Perfetto / chrome://tracing)")
    ap.add_argument("--profiles", type=int, default=32, metavar="N",
                    help="recent QueryProfiles to include in the JSON "
                         "snapshot (default 32)")
    args = ap.parse_args(argv)

    monitor = daemon = None
    if args.demo:
        from . import demo as _demo
        st = _demo.run_traffic_demo()
        if args.health:
            _, monitor, daemon = _demo.run_health_demo(st)
    if monitor is None:
        from .monitor import active_monitors
        act = active_monitors()
        monitor = act[0] if act else None

    wrote = []
    if args.json:
        export.write_json_snapshot(args.json, n_profiles=args.profiles,
                                   monitor=monitor)
        wrote.append(f"json snapshot -> {args.json}")
    if args.prom:
        export.write_prometheus(args.prom, monitor=monitor)
        wrote.append(f"prometheus text -> {args.prom}")
    if args.trace:
        n = export.write_chrome_trace(args.trace)
        wrote.append(f"chrome trace ({n} events) -> {args.trace}")

    if args.health:
        if monitor is None:
            print("== LIMS index health ==\nno monitor active "
                  "(REPRO_MONITOR=off and none passed)")
        else:
            print(render_health(monitor, daemon))
    for line in wrote:
        print(line)
    if not wrote and not args.health:
        json.dump(export.json_snapshot(args.profiles), sys.stdout,
                  indent=2, sort_keys=True)
        print()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
