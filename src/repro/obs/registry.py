"""Process-wide metrics registry: counters, gauges, bounded-reservoir
histograms.

The paper's evaluation currency is per-query cost — pages read,
candidates pruned, distance computations — and until now the
reproduction surfaced it as ad-hoc dicts scattered across layers
(``frontend.metrics()``, ``CacheStats``, prefetch ledgers, ``last_knn``
counts).  This module is the one place those signals land: every layer
records through the module-level helpers (:func:`count`,
:func:`observe`, :func:`set_gauge`) into one :data:`REGISTRY`, and the
exporters (``repro.obs.export``) read the registry instead of chasing
per-object dicts.

Design constraints, in order:

* **Cheap when off.**  ``REPRO_OBS=off`` must cost a single global
  string compare per call and allocate *nothing* (pinned by a
  tracemalloc test) — the helpers return before touching the registry,
  and :func:`span` returns a shared no-op singleton.
* **Thread-safe, lock-light.**  Serving is many submitter threads over
  shared executors; every metric carries its own small lock, held for a
  few arithmetic ops — never across IO or kernel dispatch.  The
  registry dict itself is guarded only on get-or-create.
* **Bounded.**  Histograms keep a fixed-size reservoir (Vitter's
  algorithm R, deterministic per-name seed) plus exact count / sum /
  min / max, so a frontend that serves forever holds O(reservoir)
  memory while its mean and extremes stay exact; percentiles are exact
  until the reservoir overflows and statistically representative after.

Mode resolution: ``REPRO_OBS`` (off | on | trace, default on) is read
once at import and cached in :data:`_MODE`; tests and embedders flip it
with :func:`configure`.  ``on`` records metrics and span durations;
``trace`` additionally appends Chrome ``trace_event`` records
(``repro.obs.trace``).
"""
from __future__ import annotations

import threading
import zlib
from bisect import bisect_left
from random import Random

from .. import env


def _int_knob(name: str, fallback: int) -> int:
    raw = env.get(name)
    if raw is None or str(raw).strip() == "":
        return fallback
    try:
        v = int(raw)
    except (TypeError, ValueError):
        raise ValueError(
            f"{name}={raw!r} is not a valid setting (expected an integer)")
    if v < 1:
        raise ValueError(f"{name} must be >= 1, got {v}")
    return v


def _resolve_mode() -> str:
    return env.get("REPRO_OBS")


_MODE: str = _resolve_mode()


def obs_mode() -> str:
    """The cached observability mode: 'off' | 'on' | 'trace'."""
    return _MODE


def enabled() -> bool:
    return _MODE != "off"


def tracing() -> bool:
    return _MODE == "trace"


def configure(mode: str | None = None) -> str:
    """Set the observability mode ('off'|'on'|'trace'), or re-read
    ``REPRO_OBS`` when ``mode`` is None.  Returns the active mode.
    Existing metric values are kept — mode only gates *recording*."""
    global _MODE
    if mode is None:
        _MODE = _resolve_mode()
    else:
        mode = str(mode).strip().lower()
        if mode not in ("off", "on", "trace"):
            raise ValueError(f"obs mode must be off|on|trace, got {mode!r}")
        _MODE = mode
    return _MODE


def default_reservoir() -> int:
    """Histogram reservoir capacity (``REPRO_OBS_RESERVOIR``)."""
    return _int_knob("REPRO_OBS_RESERVOIR", 1024)


# ---------------------------------------------------------------------------
# metric types
# ---------------------------------------------------------------------------
class Counter:
    """Monotonic integer counter."""

    __slots__ = ("name", "help", "_v", "_lock")

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        return self._v

    def reset(self) -> None:
        with self._lock:
            self._v = 0

    def snapshot(self):
        return self._v


class Gauge:
    """Last-writer-wins scalar (queue depth, replica count, ...)."""

    __slots__ = ("name", "help", "_v", "_lock")

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    def add(self, dv: float) -> None:
        with self._lock:
            self._v += float(dv)

    @property
    def value(self) -> float:
        return self._v

    def reset(self) -> None:
        with self._lock:
            self._v = 0.0

    def snapshot(self):
        return self._v


# fixed log-spaced Prometheus bucket bounds: half-decade steps covering
# ~3.2e-7 .. 1e4 — wide enough for latencies in seconds, queue depths,
# candidate counts, and page tallies without per-metric tuning.  Exact
# counts below/above the range still land in the first / +Inf bucket.
DEFAULT_BUCKET_BOUNDS: tuple[float, ...] = tuple(
    10.0 ** (e / 2.0) for e in range(-13, 9))


class Histogram:
    """Bounded-reservoir distribution with exact count/sum/min/max.

    The reservoir holds the first ``cap`` observations verbatim
    (percentiles are then *exact*, matched against numpy in tests);
    past ``cap`` it switches to Vitter's algorithm R — each later
    observation replaces a uniformly random slot with probability
    ``cap/count`` — so memory stays O(cap) while the reservoir remains
    a uniform sample of everything observed.  The RNG is seeded from
    the metric name, so runs are reproducible.

    Alongside the reservoir each histogram keeps *exact* fixed-bound
    bucket counts (``bounds``, default :data:`DEFAULT_BUCKET_BOUNDS`,
    recorded at creation) so the Prometheus exporter can emit real
    cumulative ``_bucket``/``le`` lines — burn-rate recording rules
    need them, and unlike the reservoir they never subsample.
    """

    __slots__ = ("name", "help", "cap", "bounds", "_bcounts", "_res",
                 "_count", "_sum", "_min", "_max", "_rng", "_lock")

    kind = "histogram"

    def __init__(self, name: str, cap: int | None = None, help: str = "",
                 bounds: tuple[float, ...] | None = None):
        self.name = name
        self.help = help
        self.cap = int(cap) if cap is not None else default_reservoir()
        if self.cap < 1:
            raise ValueError("histogram reservoir cap must be >= 1")
        self.bounds = tuple(sorted(float(b) for b in (
            bounds if bounds is not None else DEFAULT_BUCKET_BOUNDS)))
        self._bcounts = [0] * (len(self.bounds) + 1)  # last = overflow/+Inf
        self._res: list[float] = []
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._rng = Random(zlib.crc32(name.encode()))
        self._lock = threading.Lock()

    def observe(self, x: float) -> None:
        x = float(x)
        with self._lock:
            self._count += 1
            self._sum += x
            if x < self._min:
                self._min = x
            if x > self._max:
                self._max = x
            # bisect_left puts x == bounds[i] into bucket i, matching
            # Prometheus' inclusive `le` semantics after cumsum
            self._bcounts[bisect_left(self.bounds, x)] += 1
            if len(self._res) < self.cap:
                self._res.append(x)
            else:
                j = self._rng.randrange(self._count)
                if j < self.cap:
                    self._res[j] = x

    def buckets(self) -> tuple[tuple[float, ...], list[int]]:
        """(bounds, cumulative counts) with a final +Inf entry equal to
        ``count`` — exactly the series a Prometheus ``_bucket`` family
        renders."""
        with self._lock:
            raw = list(self._bcounts)
        cum, total = [], 0
        for c in raw:
            total += c
            cum.append(total)
        return self.bounds, cum

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    @property
    def min(self) -> float:
        return self._min if self._count else 0.0

    @property
    def max(self) -> float:
        return self._max if self._count else 0.0

    def __len__(self) -> int:
        """Resident reservoir size (bounded by ``cap``)."""
        return len(self._res)

    def percentile(self, p: float) -> float:
        """The p-th percentile (0..100) of the reservoir, linearly
        interpolated exactly like ``numpy.percentile``'s default — so
        for <= cap observations the two agree bit-for-bit (pinned in
        tests)."""
        with self._lock:
            s = sorted(self._res)
        if not s:
            return 0.0
        if len(s) == 1:
            return s[0]
        pos = (len(s) - 1) * (float(p) / 100.0)
        lo = int(pos)
        frac = pos - lo
        if lo + 1 >= len(s):
            return s[-1]
        # numpy's exact lerp form (lo + t*(hi-lo)), for bit-identity
        return s[lo] + frac * (s[lo + 1] - s[lo])

    def reset(self) -> None:
        with self._lock:
            self._res.clear()
            self._count = 0
            self._sum = 0.0
            self._min = float("inf")
            self._max = float("-inf")
            self._bcounts = [0] * (len(self.bounds) + 1)

    def snapshot(self) -> dict:
        with self._lock:
            n, s = self._count, self._sum
        return {
            "count": n, "sum": s,
            "mean": s / n if n else 0.0,
            "min": self.min, "max": self.max,
            "p50": self.percentile(50), "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
class MetricsRegistry:
    """Name → metric map with get-or-create semantics.

    One instance (:data:`REGISTRY`) serves the whole process; layers
    never hold references to each other's metrics, only names.  A name
    maps to exactly one metric kind — asking for the same name as a
    different kind raises, catching wiring typos early.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name, **kw)
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"not {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, help=help)

    def histogram(self, name: str, cap: int | None = None,
                  help: str = "") -> Histogram:
        return self._get_or_create(name, Histogram, cap=cap, help=help)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    def __len__(self) -> int:
        return len(self._metrics)

    def metrics(self) -> list:
        """Stable-ordered list of live metric objects."""
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def snapshot(self) -> dict:
        """{name: value-or-dict} of everything registered."""
        return {m.name: m.snapshot() for m in self.metrics()}

    def reset(self) -> None:
        """Zero every metric (benchmarks isolating one workload); the
        metric objects themselves stay registered."""
        for m in self.metrics():
            m.reset()

    def clear(self) -> None:
        """Drop every metric (tests wanting a pristine registry)."""
        with self._lock:
            self._metrics.clear()


REGISTRY = MetricsRegistry()


# ---------------------------------------------------------------------------
# mode-gated helpers: the API the instrumented layers call
# ---------------------------------------------------------------------------
def count(name: str, n: int = 1) -> None:
    """Increment counter ``name`` (no-op, zero-alloc when off)."""
    if _MODE == "off":
        return
    REGISTRY.counter(name).inc(n)


def observe(name: str, x: float) -> None:
    """Record ``x`` into histogram ``name`` (no-op when off)."""
    if _MODE == "off":
        return
    REGISTRY.histogram(name).observe(x)


def set_gauge(name: str, v: float) -> None:
    """Set gauge ``name`` (no-op when off)."""
    if _MODE == "off":
        return
    REGISTRY.gauge(name).set(v)


__all__ = ["Counter", "DEFAULT_BUCKET_BOUNDS", "Gauge", "Histogram",
           "MetricsRegistry", "REGISTRY", "configure", "count",
           "default_reservoir", "enabled", "obs_mode", "observe",
           "set_gauge", "tracing"]
