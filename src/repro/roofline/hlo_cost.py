"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE,
which silently undercounts every scan-over-layers model by ~n_layers× (and
collectives inside FSDP scans by the same factor). This module re-derives
FLOPs / HBM bytes / collective bytes from the post-partitioning HLO text
with call-graph multipliers:

  * while ops: body and condition costs × trip count (parsed from the
    condition's loop-bound constant — exact for lax.scan/fori_loop);
  * fusion ``calls=``: internal ops contribute FLOPs only (one kernel ⇒
    operand/output bytes are counted once at the fusion call site);
  * dot FLOPs = 2 · |out| · Π(contracting dims); elementwise/transcendental
    ops ≈ 1 FLOP per output element (matmul-dominated workloads make this
    a <few-% correction);
  * collective bytes = max(in, out) per op, × multiplier, classified
    cross-pod by materializing the replica groups.

All quantities are per-device (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s+->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+"
    r"(\([^)]*\)|[\w\[\]{},]+)\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s+(\(?[\w\[\]{},]+\)?)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_RG_EXPLICIT = re.compile(r"replica_groups=\{\{([\d,}{\s]+)\}\}")
_RG_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "logistic", "sine", "cosine", "negate", "abs",
    "compare", "select", "and", "or", "xor", "not", "convert", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "clamp", "erf", "atan2", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "reduce",
}
NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
            "bitcast", "after-all", "partition-id", "replica-id", "iota",
            "custom-call", "rng-bit-generator",
            # control flow: carries are not HBM round-trips
            "while", "conditional", "call", "copy-start", "copy-done"}
# ops whose true traffic is O(slice/update), not O(operand buffer):
# handled specially in walk() — dynamic-slice/gather ≈ 2·|out|;
# dynamic-update-slice ≈ 2·|update| (in-place); scatter ≈ 2·|updates|.
SLICING = {"dynamic-slice", "gather", "dynamic-update-slice", "scatter"}


def _shape_info(txt: str):
    """(total_bytes, [dims of first shape], n_elems_total)."""
    total_b = 0
    total_n = 0
    first_dims = None
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        n = 1
        for v in d:
            n *= v
        total_b += n * _DTYPE_BYTES[dt]
        total_n += n
        if first_dims is None:
            first_dims = d
    return total_b, (first_dims or []), total_n


@dataclass
class _Op:
    name: str
    kind: str
    out_bytes: int
    out_elems: int
    out_dims: list
    line: str
    operands: list


@dataclass
class _Comp:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # name -> (bytes, dims)
    constants: dict = field(default_factory=dict)  # name -> int value


def _parse_computations(text: str) -> tuple:
    comps: dict = {}
    entry = None
    cur: _Comp | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        h = _HEADER_RE.match(line)
        if h and line.endswith("{"):
            cur = _Comp(h.group(2))
            comps[cur.name] = cur
            if h.group(1):
                entry = cur.name
            for pm in _PARAM_RE.finditer(h.group(3)):
                b, dims, _ = _shape_info(pm.group(2))
                cur.symbols[pm.group(1)] = (b, dims)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m is None:
            continue
        name, out_t, kind = m.group(1), m.group(2), m.group(3)
        ob, odims, oel = _shape_info(out_t)
        # operands: %refs inside the call parens, before attribute list
        paren = line[m.end():]
        depth = 1
        end = len(paren)
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(paren[:end])
        cur.symbols[name] = (ob, odims)
        if kind == "constant":
            cm = _CONST_RE.search(line)
            if cm:
                cur.constants[name] = int(cm.group(1))
        cur.ops.append(_Op(name, kind, ob, oel, odims, line, operands))
    return comps, entry


def _group_crosses_pod(line: str, pod_size: int) -> bool:
    m = _RG_EXPLICIT.search(line)
    if m:
        first = m.group(1).split("}")[0]
        ids = [int(x) for x in first.replace("{", "").split(",") if x.strip()]
        return len({i // pod_size for i in ids}) > 1
    m = _RG_IOTA.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        n = int(np.prod(dims))
        if n <= pod_size:
            return False
        arr = np.arange(n).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            arr = arr.transpose(perm)
        groups = arr.reshape(g, s)
        pods = groups // pod_size
        return bool((pods != pods[:, :1]).any())
    return False


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    cross_pod_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)


def analyze_hlo(text: str, pod_size: int = 256,
                structural_only: bool = True) -> HloCost:
    """``structural_only`` (default): count FLOPs from dot/reduce ops and
    bytes from dot/reduce/sort/slicing/collective ops only. The CPU
    backend's optimized HLO is littered with artifacts a TPU build would
    not have (bf16→f32 convert chains, physical transposes for CPU dot
    layouts, un-aliased full-buffer copies); matmul-structural ops are
    backend-neutral, and elementwise traffic fuses into them on TPU.
    ``structural_only=False`` counts everything (upper bound)."""
    comps, entry = _parse_computations(text)
    out = HloCost()
    if entry is None:
        return out

    trip_cache: dict = {}

    def trip_count(cond_name: str) -> int:
        """Loop bound from the condition's compare-against-constant (exact
        for lax.scan / fori_loop); falls back to max constant."""
        if cond_name in trip_cache:
            return trip_cache[cond_name]
        t = 0
        comp = comps.get(cond_name)
        if comp is not None:
            for op in comp.ops:
                if op.kind == "compare":
                    for o in op.operands:
                        if o in comp.constants:
                            t = max(t, comp.constants[o])
                    # inline constant form: compare(%x, s32[] constant(8))
                    for v in _CONST_RE.findall(op.line):
                        t = max(t, int(v))
            if t == 0:
                consts = [v for op in comp.ops
                          for v in map(int, _CONST_RE.findall(op.line))]
                if consts:
                    t = max(consts)
        t = max(t, 1)
        trip_cache[cond_name] = t
        return t

    def op_flops(comp: _Comp, op: _Op) -> float:
        if op.kind == "dot":
            lhs = comp.symbols.get(op.operands[0] if op.operands else "",
                                   (0, []))[1]
            mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
            contract = 1
            if mc and lhs:
                for i in mc.group(1).split(","):
                    if i and int(i) < len(lhs):
                        contract *= lhs[int(i)]
            return 2.0 * op.out_elems * max(contract, 1)
        if structural_only:
            if op.kind == "reduce":
                return float(op.out_elems)
            return 0.0
        if op.kind in ELEMENTWISE:
            return float(op.out_elems)
        return 0.0

    STRUCTURAL_BYTES = {"dot", "reduce", "sort", "convolution",
                        "reduce-window"}

    def walk(name: str, mult: float, flops_only: bool,
             depth: int = 0) -> None:
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        for op in comp.ops:
            out.flops += mult * op_flops(comp, op)
            refs_fusion = re.search(r"calls=%([\w\.\-]+)", op.line)
            if op.kind == "while":
                mb = re.search(r"body=%([\w\.\-]+)", op.line)
                mc = re.search(r"condition=%([\w\.\-]+)", op.line)
                t = trip_count(mc.group(1)) if mc else 1
                if mb:
                    walk(mb.group(1), mult * t, flops_only, depth + 1)
                if mc:
                    walk(mc.group(1), mult * t, True, depth + 1)
                continue
            if op.kind == "fusion" and refs_fusion:
                walk(refs_fusion.group(1), mult, True, depth + 1)
            if op.kind == "conditional":
                for bn in re.findall(r"%([\w\.\-]+)",
                                     op.line.split("branch_computations")[-1]
                                     )[:4]:
                    walk(bn, mult, flops_only, depth + 1)
            if op.kind.rstrip("-start").rstrip("-done") in COLLECTIVES or \
               op.kind in COLLECTIVES or \
               op.kind.replace("-start", "") in COLLECTIVES:
                if op.kind.endswith("-done"):
                    continue
                in_b = sum(comp.symbols.get(o, (0, []))[0]
                           for o in op.operands)
                b = mult * max(op.out_bytes, in_b)
                kind = op.kind.replace("-start", "")
                out.coll_bytes += b
                out.coll_by_kind[kind] = out.coll_by_kind.get(kind, 0) + b
                out.coll_count[kind] = out.coll_count.get(kind, 0) + mult
                if _group_crosses_pod(op.line, pod_size):
                    out.cross_pod_bytes += b
            if not flops_only and op.kind not in NO_BYTES:
                if structural_only and op.kind not in STRUCTURAL_BYTES \
                        and op.kind not in SLICING \
                        and not (op.kind == "fusion" and
                                 "dynamic-update-slice" in op.name):
                    continue
                if op.kind == "fusion" and "dynamic-update-slice" in op.name:
                    # in-place cache/accumulator update: with buffer
                    # aliasing (loop carries, donated args) the operand
                    # whose SHAPE matches the output is the same HBM
                    # buffer (a convert may change dtype bytes, so match
                    # shapes, not sizes); traffic = the small updates.
                    small = sum(
                        comp.symbols.get(o, (0, []))[0]
                        for o in op.operands
                        if comp.symbols.get(o, (0, []))[1] != op.out_dims)
                    matched = any(
                        comp.symbols.get(o, (0, []))[1] == op.out_dims
                        for o in op.operands)
                    if matched:
                        out.bytes += mult * 2 * small
                        continue
                if op.kind in SLICING:
                    if op.kind == "dynamic-update-slice" and \
                            len(op.operands) >= 2:
                        upd = comp.symbols.get(op.operands[1], (0, []))[0]
                        out.bytes += mult * 2 * upd
                    elif op.kind == "scatter" and len(op.operands) >= 3:
                        upd = comp.symbols.get(op.operands[2], (0, []))[0]
                        out.bytes += mult * 2 * upd
                    else:
                        out.bytes += mult * 2 * op.out_bytes
                else:
                    in_b = sum(comp.symbols.get(o, (0, []))[0]
                               for o in op.operands)
                    out.bytes += mult * (op.out_bytes + in_b)

    walk(entry, 1.0, False)
    return out
