"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch × shape × mesh), all in seconds-per-step, derived
from per-device quantities (SPMD: ``cost_analysis()`` and the partitioned
HLO are already per-device):

  compute    = HLO_FLOPs / PEAK_FLOPS_BF16
  memory     = HLO_bytes / HBM_BW
  collective = collective_bytes / ICI_BW   (DCN counted separately when a
                                            replica group crosses pods)

collective_bytes comes from parsing the compiled HLO text — it is NOT in
cost_analysis. Per op we take max(input, output) bytes: for all-gather the
output is what lands in HBM per device; for all-reduce/reduce-scatter the
ring moves ~input bytes per device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from . import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(?P<out>\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)"
    r"(?P<start>-start)?\(", )


def _shape_bytes(txt: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)
    cross_pod_bytes: int = 0

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str, pod_size: int = 256) -> CollectiveStats:
    """Sum per-device collective bytes from partitioned HLO text."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        kind = m.group("kind")
        out_bytes = _shape_bytes(m.group("out"))
        # operand types live inside the call parens
        paren = line[m.end():]
        depth = 1
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        in_bytes = _shape_bytes(paren[:end])
        b = max(out_bytes, in_bytes)
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + b
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
        # cross-pod detection: explicit replica_groups with ids from
        # different pods
        rg = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
        if rg:
            ids = [int(x) for x in rg.group(1).split(",") if x]
            if len({i // pod_size for i in ids}) > 1:
                st.cross_pod_bytes += b
    return st


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    cross_pod_bytes: float
    model_flops: float
    coll_detail: dict

    @property
    def t_compute(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        ici = (self.coll_bytes - self.cross_pod_bytes) / hw.ICI_BW
        dcn = self.cross_pod_bytes / hw.DCN_BW
        return ici + dcn

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """Perfect-overlap model: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs — remat/dispatch/causal waste."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        t = self.step_time_lower_bound
        return (self.model_flops / t) / hw.PEAK_FLOPS_BF16 if t else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "cross_pod_bytes": self.cross_pod_bytes,
            "model_flops_per_dev": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "coll_detail": self.coll_detail,
        }


# --------------------------------------------------------- model FLOPs
def active_params(cfg, specs) -> tuple:
    """(N_total, N_active): MoE expert tensors scaled by (top_k/E);
    the embedding gather table excluded from N_active (standard 6·N·D
    convention counts matmul-participating params; tied embeddings and
    lm_head do participate)."""
    import numpy as np
    from ..models.params import ParamSpec
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]
    total = 0
    active = 0
    for path, spec in flat:
        keys = [getattr(k, "key", "") for k in path]
        n = int(np.prod(spec.shape))
        total += n
        name = keys[-1] if keys else ""
        if name == "embed" and not cfg.tie_embeddings:
            continue
        if name in ("we1", "we2", "we3") and cfg.moe is not None:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    return total, int(active)


def model_flops_for_cell(cfg, specs, cell, n_chips: int) -> float:
    """Per-device MODEL_FLOPS for one step of the given shape cell."""
    _, n_active = active_params(cfg, specs)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens / n_chips
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens / n_chips
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch / n_chips


import jax  # noqa: E402  (used in active_params)


# ------------------------------------------------------- memory model
def estimate_memory(cfg, run, specs, cell, mesh, rules,
                    opt_state_abstract=None, cache_abstract=None) -> dict:
    """Analytical per-device HBM model (bytes), exact on the static terms
    (params / optimizer / cache via NamedSharding.shard_shape) and
    napkin-math on the dynamic ones (activation residuals per remat
    policy, logits, workspace).

    XLA:CPU's memory_analysis() lacks the TPU scheduler's buffer reuse
    (measured: microbatching leaves its temp estimate unchanged), so the
    fits-in-HBM verdict uses this model; the raw memory_analysis numbers
    are recorded alongside for transparency.
    """
    import numpy as np
    from ..models.params import ParamSpec
    from ..sharding.logical import guarded_sharding

    def shard_bytes(shape, axes, dtype_bytes):
        sh = guarded_sharding(tuple(shape), axes, rules, mesh)
        local = sh.shard_shape(tuple(shape))
        return int(np.prod(local)) * dtype_bytes if local else dtype_bytes

    import jax as _jax
    flat = _jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]
    dt_b = 2 if cfg.dtype == "bfloat16" else 4
    params_b = 0
    for _, s in flat:
        b = 2 if (s.dtype or cfg.dtype) == "bfloat16" else 4
        params_b += shard_bytes(s.shape, s.axes, b)

    out = {"params": params_b}
    if cell.kind == "train":
        # grads: fp32, params-sharded
        grads_b = 0
        for _, s in flat:
            grads_b += shard_bytes(s.shape, s.axes, 4)
        if run.zero1:
            # grads/opt shard additionally over data (approximation:
            # every embed-carrying tensor divides; the rest is small)
            dp_ext = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
            grads_b //= dp_ext
        out["grads"] = grads_b
        if run.optimizer == "adamw":
            out["opt"] = 2 * grads_b
        elif run.optimizer == "adafactor":
            out["opt"] = grads_b // 512      # row+col factors
        else:                                 # adamw8bit
            out["opt"] = grads_b // 2 + grads_b // 128
        bsh = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        b_loc = max(1, cell.global_batch // bsh) // max(run.microbatches, 1)
        b_loc = max(b_loc, 1)
        s_loc = cell.seq_len
        if rules.get("seq") == "model":
            s_loc //= mesh.shape.get("model", 1)
        hidden = b_loc * s_loc * cfg.d_model * dt_b
        n_res = cfg.n_layers + (cfg.n_dec_layers or 0)
        if cfg.remat == "full":
            resid = n_res * hidden
        elif cfg.remat == "selective":
            resid = n_res * hidden * 6       # dot outputs per block ≈ 6×
        else:
            resid = n_res * hidden * 12
        out["residuals"] = int(resid)
        vshard = mesh.shape.get("model", 1) \
            if cfg.vocab % mesh.shape.get("model", 1) == 0 else 1
        out["logits"] = int(b_loc * s_loc * cfg.vocab / vshard * 4)
        out["workspace"] = int(8 * hidden)
    else:
        bsh = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        b_loc = max(1, cell.global_batch // bsh)
        if cell.kind == "prefill":
            s_loc = cell.seq_len
            hidden = b_loc * s_loc * cfg.d_model * dt_b
            out["workspace"] = int(6 * hidden)
            # the produced cache lives in HBM
        cache_b = 0
        if cache_abstract is not None:
            for leaf in _jax.tree_util.tree_leaves(cache_abstract):
                sh = getattr(leaf, "sharding", None)
                if sh is not None:
                    local = sh.shard_shape(leaf.shape)
                    cache_b += int(np.prod(local)) * leaf.dtype.itemsize
                else:
                    cache_b += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        out["cache"] = cache_b
        vshard = mesh.shape.get("model", 1) \
            if cfg.vocab % mesh.shape.get("model", 1) == 0 else 1
        out["logits"] = int(b_loc * cfg.vocab / vshard * 4)
        out.setdefault("workspace", int(32 * b_loc * cfg.d_model * dt_b))
    out["total"] = int(sum(out.values()))
    return out
