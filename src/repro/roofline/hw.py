"""Hardware profiles for the roofline model.

TPU v5e constants (per chip) plus a measured profile for whatever this
process is actually running on: :func:`machine_profile` returns the
``{"name", "peak_flops", "mem_bw"}`` ceiling the pipeline roofline
report divides by.  On TPU the datasheet constants are used; on CPU the
peaks are *calibrated* — a jitted f32 GEMM for peak FLOP/s and a large
streaming elementwise op for memory bandwidth, best-of several runs,
cached per process — because there is no one datasheet number for "the
CI runner's CPU" and an unmeasured ceiling would make every utilization
figure fiction.
"""
from __future__ import annotations

import time

PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (≈ usable per direction)
DCN_BW = 25e9                 # bytes/s per host, inter-pod (approximate)
HBM_BYTES = 16 * 2**30        # 16 GiB HBM per chip
VMEM_BYTES = 16 * 2**20       # ~16 MiB more-or-less usable VMEM
MXU_DIM = 128

_cpu_profile: dict | None = None


def _best_of(fn, reps: int = 5) -> float:
    import jax
    jax.block_until_ready(fn())          # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _calibrate_cpu() -> dict:
    """Measured f32 GEMM peak + stream bandwidth for this host."""
    global _cpu_profile
    if _cpu_profile is not None:
        return _cpu_profile
    import jax
    import jax.numpy as jnp
    import numpy as np

    m = 1024
    a = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((m, m)).astype(np.float32))
    mm = jax.jit(lambda x: x @ x)
    t_mm = _best_of(lambda: mm(a))
    peak_flops = 2.0 * m ** 3 / t_mm

    n = 1 << 25                           # 128 MiB f32 — far beyond LLC
    v = jnp.ones((n,), jnp.float32)
    stream = jax.jit(lambda x: x * 1.0001 + 1.0)
    t_st = _best_of(lambda: stream(v))
    mem_bw = 2.0 * n * 4 / t_st           # one read + one write stream

    _cpu_profile = {"name": "xla-cpu (calibrated)",
                    "peak_flops": peak_flops, "mem_bw": mem_bw}
    return _cpu_profile


def machine_profile() -> dict:
    """The roofline ceiling for this process's default backend."""
    import jax
    backend = jax.default_backend()
    if backend == "tpu":
        return {"name": "tpu-v5e", "peak_flops": PEAK_FLOPS_BF16,
                "mem_bw": HBM_BW}
    if backend in ("gpu", "cuda", "rocm"):
        # no shipped datasheet constants for arbitrary GPUs; reuse the
        # calibration approach (the jitted kernels run on the device)
        return dict(_calibrate_cpu(), name=f"{backend} (calibrated)")
    return _calibrate_cpu()
