"""TPU v5e hardware constants for the roofline model (per chip)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (≈ usable per direction)
DCN_BW = 25e9                 # bytes/s per host, inter-pod (approximate)
HBM_BYTES = 16 * 2**30        # 16 GiB HBM per chip
VMEM_BYTES = 16 * 2**20       # ~16 MiB more-or-less usable VMEM
MXU_DIM = 128
