"""Render roofline reports.

Two modes:

* ``python -m repro.roofline.report --pipeline [--quick] [--json PATH]``
  — measure the real pdist→rankeval→range_filter query pipeline on this
  machine (compiled lane, calibrated ceiling; see ``pipeline.py``) and
  print the per-stage utilization table.
* ``python -m repro.roofline.report [DIR]`` — the original dry-run
  tables from ``results/dryrun`` JSON records.
"""
from __future__ import annotations

import json
import os
import sys


def load(dirpath: str) -> list[dict]:
    out = []
    for f in sorted(os.listdir(dirpath)):
        if f.endswith(".json"):
            with open(os.path.join(dirpath, f)) as fh:
                out.append(json.load(fh))
    return out


def fmt_ms(s: float) -> str:
    return f"{s*1e3:,.1f}"


def roofline_table(recs: list[dict], mesh: str) -> str:
    rows = ["| arch | shape | params | live GB | fits | t_comp ms | "
            "t_mem ms | t_coll ms | dominant | useful | MFU-bound |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                        f"| — | SKIP (full attn @500k) | — | — |")
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{r['params_total']/1e9:.1f}B | "
            f"{r['mem']['live_gb']:.1f} | "
            f"{'Y' if r['mem']['fits_16gb'] else 'N'} | "
            f"{fmt_ms(rf['t_compute_s'])} | {fmt_ms(rf['t_memory_s'])} | "
            f"{fmt_ms(rf['t_collective_s'])} | {rf['bottleneck']} | "
            f"{rf['useful_flops_ratio']:.2f} | {rf['mfu_bound']*100:.0f}% |")
    return "\n".join(rows)


def collective_summary(recs: list[dict]) -> str:
    rows = ["| arch | shape | mesh | all-reduce GB | all-gather GB | "
            "a2a GB | permute GB | cross-pod GB |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if "skipped" in r:
            continue
        cb = r["roofline"]["coll_detail"]["bytes"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{cb.get('all-reduce', 0)/1e9:.2f} | "
            f"{cb.get('all-gather', 0)/1e9:.2f} | "
            f"{cb.get('all-to-all', 0)/1e9:.2f} | "
            f"{cb.get('collective-permute', 0)/1e9:.2f} | "
            f"{r['roofline']['cross_pod_bytes']/1e9:.2f} |")
    return "\n".join(rows)


def main() -> None:
    if "--pipeline" in sys.argv:
        from .pipeline import pipeline_report, render
        rep = pipeline_report(quick="--quick" in sys.argv)
        print(render(rep))
        if "--json" in sys.argv:
            path = sys.argv[sys.argv.index("--json") + 1]
            with open(path, "w") as f:
                json.dump(rep, f, indent=1)
            print(f"# wrote {path}")
        return
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(d)
    print("## Roofline — single pod (16×16 = 256 chips)\n")
    print(roofline_table(recs, "16x16"))
    print("\n## Roofline — multi-pod (2×16×16 = 512 chips)\n")
    print(roofline_table(recs, "2x16x16"))
    print("\n## Collective breakdown (per device per step)\n")
    print(collective_summary([r for r in recs
                              if r.get("mesh") == "2x16x16"]))


if __name__ == "__main__":
    main()
