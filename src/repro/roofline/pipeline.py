"""Roofline analysis of the real query pipeline: pdist→rankeval→range_filter.

Turns the dormant HLO cost machinery into a measured claim about the
serving hot path.  For each kernel stage, at the shapes the resident
executor actually launches against a real snapshot:

1. jit the stage and lower it to *optimized* HLO
   (``jit(...).lower(args).compile().as_text()``);
2. run :func:`repro.roofline.hlo_cost.analyze_hlo` over the text with
   ``structural_only=False`` — rankeval is a dot-free VPU kernel, the
   structural filter would report it as ~0 FLOPs;
3. time the compiled stage (best-of, ``block_until_ready``);
4. divide by the calibrated machine ceiling
   (:func:`repro.roofline.hw.machine_profile`): arithmetic intensity
   I = FLOPs/bytes, attainable = min(peak_flops, I · mem_bw),
   utilization = achieved FLOP/s ÷ attainable, bottleneck =
   compute vs memory by which roof binds.

The report runs the *compiled* lane (``REPRO_INTERPRET=off`` is forced
for its duration — interpret timings would say nothing about hardware,
and on CPU the xla lane also yields analyzable HLO where a pallas
custom-call would be opaque).  Entry point:
``python -m repro.roofline.report --pipeline``; ``bench_kernels.py``
embeds the same dict in ``BENCH_kernels.json``.
"""
from __future__ import annotations

import os
import time

import numpy as np

from . import hw
from .hlo_cost import analyze_hlo


def _best_of(fn, reps: int) -> float:
    import jax
    jax.block_until_ready(fn())            # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _io_bytes(fn, args) -> float:
    """Algorithmic stage traffic: operand bytes in + result bytes out —
    the roofline denominator.  The per-op HLO byte sum double-counts
    every fused producer-consumer edge, so it is reported separately as
    an upper bound, not used for intensity."""
    import jax
    out = jax.eval_shape(fn, *args)
    leaves = jax.tree_util.tree_leaves(out)
    return (sum(float(np.prod(a.shape)) * a.dtype.itemsize for a in args)
            + sum(float(np.prod(l.shape)) * l.dtype.itemsize
                  for l in leaves))


def _stage_report(name: str, fn, args, machine: dict, reps: int) -> dict:
    import jax
    jfn = jax.jit(fn)
    txt = jfn.lower(*args).compile().as_text()
    cost = analyze_hlo(txt, structural_only=False)
    t = _best_of(lambda: jfn(*args), reps)
    flops = float(cost.flops)
    io = _io_bytes(fn, args)
    intensity = flops / io if io else float("inf")
    attainable = min(machine["peak_flops"], intensity * machine["mem_bw"])
    achieved = flops / t if t else 0.0
    util = achieved / attainable if attainable else 0.0
    if intensity * machine["mem_bw"] >= machine["peak_flops"]:
        bound = "compute"
    elif util > 1.0:
        # beating the DRAM roof: the working set is cache-resident, so
        # the memory ceiling doesn't apply at this shape
        bound = "cache"
    else:
        bound = "memory"
    return {
        "stage": name,
        "flops": flops,
        "io_bytes": io,
        "hlo_bytes": float(cost.bytes),
        "intensity_flops_per_byte": round(intensity, 3),
        "time_us": round(t * 1e6, 1),
        "achieved_gflops": round(achieved / 1e9, 2),
        "attainable_gflops": round(attainable / 1e9, 2),
        "roofline_utilization": round(util, 4),
        "bound": bound,
    }


def pipeline_report(n: int = 12_000, d: int = 8, batch: int = 64,
                    quick: bool = False, reps: int = 5) -> dict:
    """Per-stage roofline report over a real snapshot's query pipeline."""
    if quick:
        n, reps = min(n, 4_000), min(reps, 2)
    prev = os.environ.get("REPRO_INTERPRET")
    os.environ["REPRO_INTERPRET"] = "off"
    try:
        return _pipeline_report(n, d, batch, reps)
    finally:
        if prev is None:
            del os.environ["REPRO_INTERPRET"]
        else:
            os.environ["REPRO_INTERPRET"] = prev


def _pipeline_report(n: int, d: int, batch: int, reps: int) -> dict:
    import jax.numpy as jnp

    from ..core import LIMSIndex, MetricSpace
    from ..core.metrics import dist_one_to_many
    from ..core.planner import _R_ABS, _R_REL
    from ..core.snapshot import LIMSSnapshot
    from ..data.datasets import gauss_mix
    from ..kernels import ops
    from ..kernels.dispatch import kernel_mode

    X = gauss_mix(n, d, seed=0)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=16, m=3, n_rings=20)
    snap = LIMSSnapshot.build(ix)
    rng = np.random.default_rng(1)
    qf = jnp.asarray(X[rng.choice(n, batch)]
                     + rng.normal(0, 0.003, (batch, d)), jnp.float32)
    rf = jnp.asarray([float(np.quantile(dist_one_to_many(np.asarray(q), X,
                                                         "l2"), 1e-3))
                      for q in np.asarray(qf)], jnp.float32)
    r_g = rf * (1.0 + _R_REL) + _R_ABS

    G = snap.K * snap.m
    pivots = snap.pivots.reshape(G, d)
    rows = snap.rows.reshape(snap.n_slots, d)
    coef = snap.coef.reshape(G, -1)
    mlo = snap.model_lo.reshape(-1)
    mhi = snap.model_hi.reshape(-1)
    mn = snap.model_n.reshape(-1)

    machine = hw.machine_profile()

    # the boundary matrix the staged plan feeds rankeval (G, 2B)
    dq = jnp.sqrt(jnp.maximum(ops.pdist(qf, pivots), 0.0))
    xb = jnp.concatenate([(dq - r_g[:, None]).T,
                          (dq + r_g[:, None]).T], axis=1)

    stages = [
        # refinement-shaped pdist: the batch against every resident slot
        ("pdist", lambda q, p: ops.pdist(q, p), (qf, rows)),
        ("rankeval",
         lambda x, c, lo, hi, nn: ops.rankeval(x, c, lo, hi, nn,
                                               n_rings=snap.n_rings),
         (xb, coef, mlo, mhi, mn)),
        ("range_filter", lambda q, p, r: ops.range_filter(q, p, r),
         (qf, rows, rf)),
        # the fused plan stage (pdist+rankeval in one launch), for the
        # fusion-win line in the bench
        ("fused_plan",
         lambda q, pv, c, lo, hi, nn, rg: ops.pdist_rankeval(
             q, pv, c, lo, hi, nn, rg, n_rings=snap.n_rings),
         (qf, pivots, coef, mlo, mhi, mn, r_g)),
    ]
    out = {
        "machine": {k: (round(v, 3) if isinstance(v, float) else v)
                    for k, v in machine.items()},
        "kernel_mode": kernel_mode(),
        "shapes": {"n": n, "d": d, "batch": batch, "G": G,
                   "n_slots": snap.n_slots},
        "stages": [_stage_report(nm, fn, args, machine, reps)
                   for nm, fn, args in stages],
    }
    core = [s for s in out["stages"] if s["stage"] != "fused_plan"]
    tot_t = sum(s["time_us"] for s in core)
    out["pipeline"] = {
        "time_us": round(tot_t, 1),
        "flops": sum(s["flops"] for s in core),
        "io_bytes": sum(s["io_bytes"] for s in core),
        "utilization_weighted": round(
            sum(s["roofline_utilization"] * s["time_us"]
                for s in core) / tot_t, 4) if tot_t else 0.0,
    }
    return out


def render(report: dict) -> str:
    lines = [
        f"machine: {report['machine']['name']}  "
        f"peak {report['machine']['peak_flops'] / 1e9:.0f} GFLOP/s  "
        f"bw {report['machine']['mem_bw'] / 1e9:.1f} GB/s  "
        f"lane={report['kernel_mode']}",
        f"shapes: {report['shapes']}",
        "| stage | FLOPs | IO bytes | I (F/B) | t_us | achieved GF/s | "
        "attainable GF/s | util | bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for s in report["stages"]:
        lines.append(
            f"| {s['stage']} | {s['flops']:.3g} | {s['io_bytes']:.3g} | "
            f"{s['intensity_flops_per_byte']} | {s['time_us']} | "
            f"{s['achieved_gflops']} | {s['attainable_gflops']} | "
            f"{s['roofline_utilization'] * 100:.1f}% | {s['bound']} |")
    p = report["pipeline"]
    lines.append(
        f"pipeline (staged 3 kernels): {p['time_us']}us, "
        f"{p['flops']:.3g} FLOPs, {p['io_bytes']:.3g} IO bytes, "
        f"time-weighted utilization "
        f"{p['utilization_weighted'] * 100:.1f}%")
    return "\n".join(lines)


__all__ = ["pipeline_report", "render"]
