"""Pivot selection: farthest-first traversal (FFT) within a cluster.

Per the paper (§4.3) pivot #1 is the cluster centroid (its distances double
as the k-center assignment distances and feed the OR statistic), and the
remaining m-1 pivots are chosen by FFT inside the cluster — linear time and
space, maximizing spread so the ring intersections are tight.
"""
from __future__ import annotations

import numpy as np

from .metrics import MetricSpace


def fft_pivots(space: MetricSpace, member_idx: np.ndarray, centroid_idx: int,
               m: int, d_to_centroid: np.ndarray | None = None) -> np.ndarray:
    """Return (m,) global indices of pivots for one cluster.

    ``d_to_centroid`` (len == len(member_idx)) is reused from clustering if
    available to save one distance pass.
    """
    pivots = [int(centroid_idx)]
    if m == 1 or len(member_idx) == 0:
        return np.asarray(pivots[:m], dtype=np.int64)
    if d_to_centroid is None:
        d_near = space.dist(space.data[centroid_idx], member_idx)
    else:
        d_near = np.array(d_to_centroid, dtype=np.float64, copy=True)
    for _ in range(1, m):
        nxt_local = int(np.argmax(d_near))
        nxt = int(member_idx[nxt_local])
        if nxt in pivots:            # degenerate tiny cluster: reuse allowed
            break
        pivots.append(nxt)
        d_new = space.dist(space.data[nxt], member_idx)
        d_near = np.minimum(d_near, d_new)
    while len(pivots) < m:           # pad degenerate clusters
        pivots.append(pivots[-1])
    return np.asarray(pivots, dtype=np.int64)
