"""Data clustering for LIMS: k-center (Gonzalez farthest-first) + kMeans.

The paper uses the k-center algorithm (2-approximate optimal radius,
Hochbaum & Shmoys) and notes kMeans is a drop-in alternative. Both are
implemented over a ``MetricSpace`` so they work for any metric (kMeans only
for vector spaces, since it needs means).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .metrics import MetricSpace


@dataclass
class Clustering:
    center_idx: np.ndarray        # (K,) indices into the dataset
    assign: np.ndarray            # (n,) cluster id per object
    dist_to_center: np.ndarray    # (n,) distance to own centroid
    members: list                 # list of K index arrays

    @property
    def k(self) -> int:
        return len(self.center_idx)


def kcenter(space: MetricSpace, k: int, seed: int = 0) -> Clustering:
    """Gonzalez farthest-first traversal k-center clustering.

    O(nK) distance computations; each pass is one one-vs-many batched call.
    """
    n = space.n
    k = min(k, n)
    rng = np.random.default_rng(seed)
    first = int(rng.integers(n))
    centers = [first]
    d_near = space.dist_points(first)          # (n,) dist to nearest center
    assign = np.zeros(n, dtype=np.int64)
    for c in range(1, k):
        nxt = int(np.argmax(d_near))
        centers.append(nxt)
        d_new = space.dist_points(nxt)
        closer = d_new < d_near
        assign[closer] = c
        d_near = np.where(closer, d_new, d_near)
    center_idx = np.asarray(centers, dtype=np.int64)
    members = [np.where(assign == c)[0] for c in range(k)]
    return Clustering(center_idx, assign, d_near, members)


def kmeans(space: MetricSpace, k: int, iters: int = 15, seed: int = 0) -> Clustering:
    """Lloyd's kMeans (vector metrics only); centers snapped to the nearest
    data object at the end so the centroid is a real object (LIMS uses the
    centroid as pivot #1 and the k-center point-query pruning property)."""
    if not space.is_vector:
        raise ValueError("kmeans requires a vector metric")
    X = space.data.astype(np.float64)
    n = space.n
    k = min(k, n)
    rng = np.random.default_rng(seed)
    cent = X[rng.choice(n, size=k, replace=False)]
    for _ in range(iters):
        d = _cd(X, cent, space)
        assign = np.argmin(d, axis=1)
        for c in range(k):
            sel = assign == c
            if sel.any():
                cent[c] = X[sel].mean(axis=0)
    d = _cd(X, cent, space)
    assign = np.argmin(d, axis=1)
    # snap centers to nearest member
    center_idx = np.empty(k, dtype=np.int64)
    for c in range(k):
        sel = np.where(assign == c)[0]
        if len(sel) == 0:
            center_idx[c] = int(np.argmin(d[:, c]))
        else:
            center_idx[c] = sel[np.argmin(d[sel, c])]
    d_own = space.dist(space.data[center_idx[0]]) * 0  # placeholder fill below
    d_own = np.empty(n, dtype=np.float64)
    for c in range(k):
        sel = np.where(assign == c)[0]
        if len(sel):
            d_own[sel] = space.dist(space.data[center_idx[c]], sel)
    members = [np.where(assign == c)[0] for c in range(k)]
    return Clustering(center_idx, assign, d_own, members)


def _cd(X, cent, space: MetricSpace) -> np.ndarray:
    from .metrics import cdist
    import jax.numpy as jnp
    space.dist_count += X.shape[0] * cent.shape[0]
    metric = space.metric if space.metric != "cosine" else "l2"
    return np.asarray(cdist(jnp.asarray(X), jnp.asarray(cent), metric))
