"""Choosing the number of clusters K (paper §5.4).

Overhead(K) = OR(K) + λ·MAE(K):
  * OR (Eq. 14/15): mean pairwise overlap of cluster balls, measured along
    the centroid axis;
  * MAE (Eq. 16): mean absolute error of *linear* rank models over every
    (cluster, pivot) sorted-distance column — uneven intra-cluster
    distributions fit lines badly.
K* is the elbow of the overhead curve (max distance to the chord —
"kneedle" criterion), as in the paper's elbow method.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .clustering import kcenter
from .metrics import MetricSpace
from .pivots import fft_pivots


def overlap_rate(space: MetricSpace, center_idx: np.ndarray,
                 dist_min1: np.ndarray, dist_max1: np.ndarray) -> float:
    """Eq. (14)/(15): pairwise ball-overlap length over dist_max, averaged."""
    k = len(center_idx)
    if k < 2:
        return 0.0
    cc = np.empty((k, k), dtype=np.float64)
    for i in range(k):
        cc[i] = space.dist(space.data[center_idx[i]], center_idx)
    total = 0.0
    for i in range(k):
        if dist_max1[i] <= 0:
            continue
        hi = np.minimum(cc[i] + dist_max1, dist_max1[i])
        lo = np.maximum(cc[i] - dist_max1, dist_min1[i])
        r = hi - lo
        r[i] = 0.0
        total += float(np.sum(np.maximum(r, 0.0))) / dist_max1[i]
    return total / (k * (k - 1))


def linear_mae(d_sorted_cols: list[np.ndarray]) -> float:
    """Eq. (16): MAE of per-column least-squares lines, over all objects."""
    total_err, total_n = 0.0, 0
    for col in d_sorted_cols:
        n = len(col)
        if n == 0:
            continue
        ranks = np.searchsorted(col, col, side="left").astype(np.float64)
        if col[-1] > col[0]:
            A = np.stack([col, np.ones_like(col)], axis=1)
            sol, *_ = np.linalg.lstsq(A, ranks, rcond=None)
            pred = A @ sol
        else:
            pred = np.full(n, ranks.mean())
        total_err += float(np.abs(pred - ranks).sum())
        total_n += n
    return total_err / max(total_n, 1)


@dataclass
class KSelectResult:
    ks: np.ndarray
    overhead: np.ndarray
    or_curve: np.ndarray
    mae_curve: np.ndarray
    best_k: int


def select_k(space: MetricSpace, ks, m: int = 3, seed: int = 0,
             lam: float | None = None) -> KSelectResult:
    ks = np.asarray(sorted(ks))
    ors, maes = [], []
    for k in ks:
        cl = kcenter(space, int(k), seed=seed)
        dmin1 = np.empty(cl.k)
        dmax1 = np.empty(cl.k)
        cols = []
        for c in range(cl.k):
            mem = cl.members[c]
            d1 = cl.dist_to_center[mem]
            dmin1[c] = d1.min() if len(mem) else 0.0
            dmax1[c] = d1.max() if len(mem) else 0.0
            piv = fft_pivots(space, mem, int(cl.center_idx[c]), m, d1)
            for j in range(m):
                if j == 0:
                    cols.append(np.sort(d1))
                else:
                    cols.append(np.sort(space.dist(space.data[piv[j]], mem)))
        ors.append(overlap_rate(space, cl.center_idx, dmin1, dmax1))
        maes.append(linear_mae(cols))
    ors = np.asarray(ors)
    maes = np.asarray(maes)
    lam = lam if lam is not None else 1.0 / max(maes.max(), 1e-12)  # paper: 1/max(MAE)
    overhead = ors + lam * maes
    best_k = int(ks[_elbow(ks.astype(np.float64), overhead)])
    return KSelectResult(ks, overhead, ors, maes, best_k)


def _elbow(x: np.ndarray, y: np.ndarray) -> int:
    """Index of max perpendicular distance to the chord (kneedle)."""
    if len(x) < 3:
        return len(x) - 1
    x0, y0, x1, y1 = x[0], y[0], x[-1], y[-1]
    denom = np.hypot(x1 - x0, y1 - y0) + 1e-12
    d = np.abs((y1 - y0) * x - (x1 - x0) * y + x1 * y0 - y1 * x0) / denom
    return int(np.argmax(d))
