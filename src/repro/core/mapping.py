"""Pivot-based mapping: super rings, ring IDs and LIMS values (Defs. 5-8).

Per (cluster, pivot) the sorted distance list is cut into N equal-count
"super rings"; an object's LIMS value is the lexicographic concatenation of
its m ring IDs, realized as the integer  sum_j rid_j * N^(m-1-j)  — which
satisfies the paper's binary relation (Def. 8) exactly.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def ranks_with_ties_low(sorted_x: np.ndarray, x: np.ndarray) -> np.ndarray:
    """rank(x) = |{x' < x}| for each x, against the sorted column."""
    return np.searchsorted(sorted_x, x, side="left")


def ring_of_rank(rank, n: int, n_rings: int):
    """Equation (4): rid = floor(rank / ceil(n / N)), clipped to [0, N-1]."""
    width = -(-n // n_rings) if n > 0 else 1
    return np.clip(np.asarray(rank) // max(width, 1), 0, n_rings - 1)


@dataclass
class PivotMapping:
    """Everything derived from one cluster's (n_i, m) pivot-distance matrix."""
    d_sorted: np.ndarray       # (m, n_i) per-pivot sorted distances
    rids: np.ndarray           # (n_i, m) ring id per object (original order)
    lims: np.ndarray           # (n_i,) LIMS value per object (original order)
    order: np.ndarray          # argsort of lims (stable): storage order
    lims_sorted: np.ndarray    # lims[order]
    n_rings: int
    dist_min: np.ndarray       # (m,)
    dist_max: np.ndarray       # (m,)

    @property
    def n(self) -> int:
        return self.lims.shape[0]

    @property
    def m(self) -> int:
        return self.d_sorted.shape[0]


def build_mapping(pivot_d: np.ndarray, n_rings: int) -> PivotMapping:
    """``pivot_d``: (n_i, m) distances object→pivot, original cluster order."""
    pivot_d = np.asarray(pivot_d, dtype=np.float64)
    n, m = pivot_d.shape
    d_sorted = np.sort(pivot_d, axis=0).T.copy()          # (m, n)
    rids = np.empty((n, m), dtype=np.int64)
    for j in range(m):
        r = ranks_with_ties_low(d_sorted[j], pivot_d[:, j])
        rids[:, j] = ring_of_rank(r, n, n_rings)
    weights = n_rings ** np.arange(m - 1, -1, -1, dtype=np.int64)
    lims = rids @ weights
    order = np.argsort(lims, kind="stable")
    return PivotMapping(
        d_sorted=d_sorted,
        rids=rids,
        lims=lims,
        order=order,
        lims_sorted=lims[order],
        n_rings=n_rings,
        dist_min=d_sorted[:, 0].copy() if n else np.zeros(m),
        dist_max=d_sorted[:, -1].copy() if n else np.zeros(m),
    )


def lims_value(rids: np.ndarray, n_rings: int) -> np.ndarray:
    """Concatenate ring IDs (last axis) into integer LIMS values."""
    rids = np.asarray(rids)
    m = rids.shape[-1]
    weights = n_rings ** np.arange(m - 1, -1, -1, dtype=np.int64)
    return rids @ weights
