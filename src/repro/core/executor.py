"""Layer 2 of the serving stack: kernel-pipeline execution over a snapshot.

``QueryExecutor`` owns the device pipeline (``pdist`` → ``rankeval`` →
``range_filter``) over one immutable ``LIMSSnapshot`` plus the host-side
exact-search drivers (batched range, batch-wide growing-radius kNN).
``ShardedExecutor`` runs the same pipeline cluster-sharded across devices
with ``shard_map`` over a mesh from ``repro.sharding.logical``: each
device holds a contiguous shard of clusters, TriPrune routes every query
per shard (a device only evaluates its own clusters' ring boxes), and
per-shard results come back through ``jax.lax`` collectives / sharded
out-specs.  Cluster-granular sharding preserves exactness for free —
pivot tables, rank models and the certified error bound are all strictly
per-cluster state (DESIGN.md §4).

With one visible device ``ShardedExecutor`` degrades to the plain
single-device path, so CPU-interpret tests exercise the same class; a
second CI job forces 4 host devices (``--xla_force_host_platform_device_count``)
to run the real ``shard_map`` path.

Exactness contract: both executors return results bit-identical to the
host ``LIMSIndex`` — the device kernels only ever produce a certified
*superset* of candidates (error-widened ring box, inflated f32 guard
bands), and the final refinement recomputes true f64 distances on the
host (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from types import SimpleNamespace

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..kernels import ops
from ..sharding.logical import default_rules, serving_mesh, spec_for
from ..storage import plan_batch
from .metrics import dist_one_to_many
from .snapshot import _DEVICE_FIELDS, LIMSSnapshot

# f32 guard bands: rank math and distances run in f64 on the host; the
# device path inflates radii so rounding can never exclude a true result
# (the final f64 refinement removes the extras).
_R_REL = 1e-5       # relative radius inflation for the ring box
_R_ABS = 1e-4       # absolute radius inflation for the ring box
_BALL_ABS = 1e-3    # absolute inflation for the distance-ball prefilter
# padding rows for bucketed store-mode kernel launches: far outside any
# ball, large but finite so f32 arithmetic stays NaN-free
_FAR = np.float32(1e30)


def _pad_bucket(rows32: np.ndarray, min_rows: int = 128) -> np.ndarray:
    """Pad gathered rows to the next power-of-two bucket (≥ ``min_rows``).

    Store-mode launches run over candidate sets whose size varies per
    batch and per kNN round; without bucketing every distinct row count
    is a fresh jit compile on compiled backends.  Buckets cap the number
    of executable shapes at log₂(P); padding rows sit at distance ~1e30
    so they can never enter any ball, and callers slice kernel outputs
    back to the true count (per-pair math is unaffected by padding)."""
    n = rows32.shape[0]
    bucket = max(min_rows, 1 << max(n - 1, 1).bit_length())
    if bucket <= n:
        return rows32
    pad = np.full((bucket - n, rows32.shape[1]), _FAR, np.float32)
    return np.concatenate([rows32, pad])


def _candidate_mask_arrays(qf, rf, snap: LIMSSnapshot, n_rings: int):
    """(B, K·n_max) candidate mask — the pure device math, written against
    a (possibly shard-local) snapshot pytree so the single-device executor
    and every ``shard_map`` shard run literally the same code.

    One ``pdist`` launch gives query→pivot distances (TriPrune +
    AreaLocate inputs); one ``rankeval`` launch evaluates all K·m rank
    models on the lo/hi annulus boundaries of the whole batch, laid out
    (G, 2B); the predicted ring box is widened by the certified per-group
    rank-error bound so it is a guaranteed superset of the host's box.
    """
    B = qf.shape[0]
    K, n_max, m = snap.rids.shape
    d = snap.rows.shape[-1]
    N = n_rings
    r_g = rf * (1.0 + _R_REL) + _R_ABS                      # (B,)
    dq = jnp.sqrt(jnp.maximum(
        ops.pdist(qf, snap.pivots.reshape(K * m, d)), 0.0))
    dqr = dq.reshape(B, K, m)
    # TriPrune, per query per (local) cluster
    alive = jnp.all((dqr <= snap.dmax[None] + r_g[:, None, None]) &
                    (dqr >= snap.dmin[None] - r_g[:, None, None]),
                    axis=-1) & (snap.ns[None] > 0)          # (B, K)
    # one rankeval launch: G groups × (lo | hi) boundaries of all B
    x = jnp.concatenate([(dq - r_g[:, None]).T,
                         (dq + r_g[:, None]).T], axis=1)    # (G, 2B)
    rank, _ = ops.rankeval(
        x, snap.coef.reshape(K * m, -1), snap.model_lo.reshape(-1),
        snap.model_hi.reshape(-1), snap.model_n.reshape(-1), n_rings=N)
    err = snap.rank_err.reshape(-1)[:, None]                # (G, 1)
    lo_rank = jnp.maximum(rank[:, :B].astype(jnp.float32) - err, 0.0)
    hi_rank = rank[:, B:].astype(jnp.float32) + err
    w = snap.width[None, :, None].astype(jnp.float32)
    rid_lo = jnp.clip(jnp.floor(lo_rank.T.reshape(B, K, m) / w),
                      0, N - 1).astype(jnp.int32)
    rid_hi = jnp.clip(jnp.floor(hi_rank.T.reshape(B, K, m) / w),
                      0, N - 1).astype(jnp.int32)
    box = jnp.all((snap.rids[None] >= rid_lo[:, :, None, :]) &
                  (snap.rids[None] <= rid_hi[:, :, None, :]),
                  axis=-1)                                  # (B, K, n_max)
    cand = (box & alive[:, :, None] & snap.in_ring[None]) | \
        snap.always[None]
    cand = cand & snap.valid[None]
    return cand.reshape(B, K * n_max)


class QueryExecutor:
    """Single-device kernel pipeline + exact host drivers over a snapshot.

    A snapshot carrying a paged store (``snap.store``, DESIGN.md §7)
    flips the row-touching stages to *store mode*: the candidate mask is
    computed from resident metadata exactly as before, then the IO-batch
    scheduler converts it into deduplicated page runs, the store fetches
    them once per batch, and the Pallas ball prefilter plus the final
    f64 refinement run on the gathered rows — bit-identical results,
    page-granular IO (the paper's cost model, finally driven by the
    learned positions)."""

    def __init__(self, snapshot: LIMSSnapshot):
        self.snap = snapshot
        # IO summary of the most recent store-mode batch (None otherwise)
        self.last_io: dict | None = None

    @property
    def live(self) -> int:
        return self.snap.live

    # ------------------------------------------------------ device stages
    # (the three methods a sharding strategy overrides)
    def _candidate_mask(self, qf: jax.Array, rf: jax.Array) -> jax.Array:
        """(B, P) bool — error-widened ring box ∧ TriPrune ∧ validity."""
        return _candidate_mask_arrays(qf, rf, self.snap, self.snap.n_rings)

    def _hits(self, qf: jax.Array, rf: jax.Array):
        """(B, P) bool — candidates ∧ fused L2-ball prefilter."""
        s = self.snap
        if s.store is not None:
            return self._hits_store(qf, rf)
        cand = self._candidate_mask(qf, rf)
        ball, _ = ops.range_filter(qf, s.rows.reshape(s.n_slots, s.d),
                                   rf * (1.0 + _R_REL) + _BALL_ABS)
        return cand & ball.astype(bool)

    def _sq_dists(self, qf: jax.Array) -> jax.Array:
        """(B, P) f32 squared distances to every slot, inf where invalid."""
        s = self.snap
        if s.store is not None:
            raise RuntimeError(
                "store-backed executor never scans every slot; the kNN "
                "driver routes through _knn_store")
        d2 = ops.pdist(qf, s.rows.reshape(s.n_slots, s.d))
        return jnp.where(s.valid.reshape(-1)[None], d2, jnp.inf)

    # ----------------------------------------------------- storage tier
    def _hits_store(self, qf: jax.Array, rf: jax.Array) -> np.ndarray:
        """Store-mode ``_hits``: same candidate mask, ball prefilter on
        gathered pages.  Per-pair kernel math is independent of which
        other rows share a launch and the gathered f32 rows are the same
        downcast the resident snapshot holds, so the mask is identical
        to the in-memory path (DESIGN.md §7)."""
        s = self.snap
        store = s.store
        cand = np.asarray(self._candidate_mask(qf, rf))
        plan = plan_batch(cand, store.layout)
        store.fetch(plan)
        hits = np.zeros_like(cand)
        if len(plan.slots):
            rows64 = store.gather(plan.slots)
            ball, _ = ops.range_filter(
                qf, jnp.asarray(_pad_bucket(rows64.astype(np.float32))),
                rf * (1.0 + _R_REL) + _BALL_ABS)
            ball = np.asarray(ball, bool)[:, :len(plan.slots)]
            hits[:, plan.slots] = cand[:, plan.slots] & ball
        store.record_queries(plan.pages_per_query, plan.cand_per_query)
        self.last_io = plan.summary()
        return hits

    def _refine_rows(self, idx: np.ndarray) -> np.ndarray:
        """f64 rows for flat slot ids: resident matrix or page gather
        (cache-hot — the prefilter just fetched these pages)."""
        if self.snap.store is not None:
            return self.snap.store.gather(idx)
        return self.snap.rows_np[idx]

    # ------------------------------------------------------- range queries
    def range_query_batch(self, Q, r):
        """Exact batched L2 range query.

        ``Q``: (B, d) queries; ``r``: scalar or (B,) per-query radii.
        Returns a list of B ``(ids, dists)`` pairs (int64 / float64), the
        same results as ``LIMSIndex.range_query`` per query.
        """
        s = self.snap
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        B = Q.shape[0]
        r_arr = np.broadcast_to(np.asarray(r, np.float64), (B,))
        qf = jnp.asarray(Q, jnp.float32)
        rf = jnp.asarray(r_arr, jnp.float32)
        hit = np.asarray(self._hits(qf, rf))
        out = []
        for b in range(B):
            idx = np.nonzero(hit[b])[0]
            ids = s.gids_np[idx]
            d_true = dist_one_to_many(Q[b], self._refine_rows(idx), "l2")
            keep = d_true <= r_arr[b]
            out.append((ids[keep], d_true[keep]))
        return out

    def range_query(self, q, r: float):
        """Single-query convenience wrapper over the batch engine."""
        return self.range_query_batch(np.asarray(q)[None], float(r))[0]

    # --------------------------------------------------------- kNN queries
    def knn_query_batch(self, Q, k: int, max_rounds: int = 64):
        """Exact batched kNN: one growing-radius loop for the whole batch.

        Per-query done flags live on the host; every round runs the full
        batch through the kernels (queries already done keep their frozen
        radius — no per-query Python in the loop). ``k`` is clamped to the
        number of live objects. Returns ``(ids (B, k'), dists (B, k'))``
        with ``k' = min(k, live)``.
        """
        s = self.snap
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        B = Q.shape[0]
        k_eff = min(int(k), s.live)
        if k_eff <= 0:
            return (np.empty((B, 0), np.int64), np.empty((B, 0)))
        if s.store is not None:
            return self._knn_store(Q, k_eff, max_rounds)
        qf = jnp.asarray(Q, jnp.float32)
        d2 = self._sq_dists(qf)                             # (B, P)
        # seed radii at the f32 k-th distance: the loop usually certifies
        # the ball in one round and only grows on guard-band misses
        kth0 = jnp.sqrt(jnp.maximum(
            -jax.lax.top_k(-d2, k_eff)[0][:, -1], 0.0))
        r = np.asarray(kth0, np.float64) * (1.0 + 1e-3) + _BALL_ABS
        done = np.zeros(B, bool)
        final = np.zeros((B, d2.shape[1]), bool)
        for _ in range(max_rounds):
            rf = jnp.asarray(r, jnp.float32)
            cand = self._candidate_mask(qf, rf)
            ball = d2 <= ((rf * (1.0 + _R_REL) + _BALL_ABS) ** 2)[:, None]
            candb = cand & ball
            cnt = jnp.sum(candb, axis=1)
            dm = jnp.where(candb, d2, jnp.inf)
            kth = jnp.sqrt(jnp.maximum(
                -jax.lax.top_k(-dm, k_eff)[0][:, -1], 0.0))
            # certify: enough candidates AND the k-th ball fits inside the
            # queried radius with margin for the f32 guard band
            ok = np.asarray((cnt >= k_eff) &
                            (kth <= rf * (1.0 - _R_REL) - _BALL_ABS))
            newly = ok & ~done
            if newly.any():
                final[newly] = np.asarray(candb)[newly]
                done |= newly
            if done.all():
                break
            r = np.where(done, r, r * 2.0)
        else:
            final[~done] = s.valid_np[None]       # exact fallback: scan
        return self._refine_topk(Q, final, k_eff)

    def _refine_topk(self, Q, final: np.ndarray, k_eff: int):
        """Exact f64 refinement of the certified candidate sets: the
        shared tail of both kNN drivers.  ``final`` is a superset of the
        closed k-th ball per query, so the stable distance sort selects
        the same k results whichever driver produced it."""
        s = self.snap
        B = Q.shape[0]
        ids_out = np.empty((B, k_eff), np.int64)
        d_out = np.empty((B, k_eff))
        for b in range(B):
            idx = np.nonzero(final[b])[0]
            d_true = dist_one_to_many(Q[b], self._refine_rows(idx), "l2")
            sel = np.argsort(d_true, kind="stable")[:k_eff]
            ids_out[b] = s.gids_np[idx[sel]]
            d_out[b] = d_true[sel]
        return ids_out, d_out

    def _knn_store(self, Q: np.ndarray, k_eff: int, max_rounds: int):
        """Store-mode batched kNN: growing-radius rounds whose IO is the
        candidate pages, not a full scan.

        Each round runs the resident-metadata candidate mask for the
        whole batch, fetches only pages not yet gathered (the scheduler
        dedupes; earlier rounds' pages are cache hits — Alg. 2's
        never-re-read-a-page contract), computes f32 distances on the
        newly gathered rows with the same ``pdist`` kernel, and
        certifies per query with the in-memory driver's exact guard-band
        test.  The certified set is a superset of the closed k-th ball
        — ``_refine_topk`` therefore returns results bit-identical to
        the in-memory executor (DESIGN.md §7)."""
        s = self.snap
        store = s.store
        B = Q.shape[0]
        qf = jnp.asarray(Q, jnp.float32)
        K, n_max, m = s.rids.shape
        # seed radii at the nearest-pivot distance: pivots are data rows,
        # so the seed ball is non-empty and doubling reaches the k-th
        # ball in O(log) rounds.  Clusters with no live slots (deleted
        # out, or the inert padding a sharded snapshot carries) hold
        # zero/stale pivot rows — mask them so they can't collapse the
        # seed below any real point's distance
        dq = np.asarray(jnp.sqrt(jnp.maximum(
            ops.pdist(qf, s.pivots.reshape(K * m, s.d)), 0.0)))
        live_k = s.valid_np.reshape(K, n_max).any(axis=1)       # (K,)
        dqm = np.where(np.repeat(live_k, m)[None], dq, np.inf)
        r = dqm.min(axis=1).astype(np.float64) * (1.0 + 1e-3) + _BALL_ABS
        done = np.zeros(B, bool)
        final = np.zeros((B, s.n_slots), bool)
        pos = np.full(s.n_slots, -1, np.int64)    # slot → gathered column
        d2g = np.empty((B, 0), np.float32)        # sq dists, gathered slots
        pages_seen = [set() for _ in range(B)]    # per-query IO metric
        seen = np.zeros((B, s.n_slots), bool)     # per-query fetched cands
        for _ in range(max_rounds):
            rf = jnp.asarray(r, jnp.float32)
            cand = np.array(self._candidate_mask(qf, rf))
            cand[done] = False            # frozen queries stop driving IO
            # per_query=False: the pages_seen sets below are this
            # driver's cross-round page accounting
            plan = plan_batch(cand, store.layout, per_query=False)
            store.fetch(plan)
            # pages(∪ rounds) = ∪ pages(new slots per round): only map
            # slots not already charged to the query
            newly = cand & ~seen
            seen |= cand
            for b in np.nonzero(newly.any(axis=1))[0]:
                pages_seen[b].update(store.layout.slot_pages(
                    np.nonzero(newly[b])[0]).tolist())
            new = plan.slots[pos[plan.slots] < 0]
            if len(new):
                rows64 = store.gather(new)
                d2_new = np.asarray(ops.pdist(
                    qf, jnp.asarray(_pad_bucket(
                        rows64.astype(np.float32)))))[:, :len(new)]
                pos[new] = d2g.shape[1] + np.arange(len(new))
                d2g = np.concatenate([d2g, d2_new], axis=1)
            r32 = np.asarray(rf)
            thr = (r32 * np.float32(1.0 + _R_REL) +
                   np.float32(_BALL_ABS)) ** 2    # f32 guard-band ball
            cert = r32 * np.float32(1.0 - _R_REL) - np.float32(_BALL_ABS)
            for b in np.nonzero(~done)[0]:
                sl = np.nonzero(cand[b])[0]
                if len(sl) < k_eff:
                    continue
                db = d2g[b, pos[sl]]
                inball = db <= thr[b]
                if int(inball.sum()) < k_eff:
                    continue
                kth = np.sqrt(np.float32(max(
                    np.partition(db[inball], k_eff - 1)[k_eff - 1], 0.0)))
                # same certification as the in-memory driver: the k-th
                # ball fits strictly inside the queried radius minus the
                # f32 guard band
                if kth <= cert[b]:
                    final[b, sl[inball]] = True
                    done[b] = True
            if done.all():
                break
            r = np.where(done, r, r * 2.0)
        else:
            final[~done] = s.valid_np[None]       # exact fallback: scan
            seen[~done] = s.valid_np[None]
        ppq = [len(p) for p in pages_seen]
        # candidates = rows fetched for the query across every round
        # (the union of its candidate sets), matching the range path's
        # accounting — NOT the smaller certified final set
        cpq = seen.sum(axis=1)
        store.record_queries(ppq, cpq)
        self.last_io = {"pages": len(set().union(*pages_seen)),
                        "pages_per_query": ppq,
                        "candidates_per_query": [int(c) for c in cpq]}
        return self._refine_topk(Q, final, k_eff)

    def knn_query(self, q, k: int):
        """Single-query convenience wrapper over the batch engine."""
        ids, dists = self.knn_query_batch(np.asarray(q)[None], k)
        return ids[0], dists[0]


class ShardedExecutor(QueryExecutor):
    """Cluster-sharded executor: ``shard_map`` over a device mesh.

    The snapshot's K clusters are padded to a multiple of the mesh's
    ``data`` extent and split on the cluster axis; every device traces the
    *same* ``_candidate_mask_arrays`` body over its shard-local snapshot.
    Queries are replicated (in-spec ``P()``); per-shard hit masks come
    back sharded on the candidate axis (out-spec ``P(None, 'data')`` —
    the gather XLA inserts is an all-gather over the mesh), while the kNN
    distance pass gathers explicitly with ``jax.lax.all_gather`` so the
    seeding top-k sees the full corpus on every device.

    With one device (plain tier-1 CI) no mesh is built and the class
    behaves exactly like ``QueryExecutor``.
    """

    def __init__(self, snapshot: LIMSSnapshot, mesh: Mesh | None = None,
                 axis: str = "data"):
        if mesh is None:
            mesh = serving_mesh()
        self.mesh = mesh
        self.axis = axis
        self.n_shards = int(mesh.shape[axis]) if axis in mesh.axis_names \
            else 1
        if self.n_shards <= 1:
            super().__init__(snapshot)
            return
        K_pad = -(-snapshot.K // self.n_shards) * self.n_shards
        snapshot = snapshot.pad_clusters(K_pad)
        # cluster-major arrays shard on axis 0 (logical axis "clusters");
        # place each on its shard now so repeated calls never re-transfer
        rules = default_rules()
        leaves, treedef = jax.tree_util.tree_flatten(snapshot)
        specs = tuple(
            spec_for(("clusters",) + (None,) * (a.ndim - 1),
                     rules, mesh, a.shape) for a in leaves)
        snapshot = jax.tree_util.tree_unflatten(
            treedef, [jax.device_put(a, NamedSharding(mesh, sp))
                      for a, sp in zip(leaves, specs)])
        super().__init__(snapshot)
        self._dev_arrays = tuple(
            getattr(snapshot, f) for f in _DEVICE_FIELDS)
        self._cand_fn, self._hits_fn, self._sq_fn = _sharded_pipeline(
            mesh, axis, snapshot.n_rings, specs)

    # sharded device stages (same host drivers as the base class).  In
    # store mode only the candidate mask runs sharded — the ball
    # prefilter and refinement happen on host-gathered pages, so those
    # stages delegate to the base class (which routes them through the
    # store; the mask it requests still dispatches back here).
    def _candidate_mask(self, qf, rf):
        if self.n_shards <= 1:
            return super()._candidate_mask(qf, rf)
        return self._cand_fn(qf, rf, *self._dev_arrays)

    def _hits(self, qf, rf):
        if self.n_shards <= 1 or self.snap.store is not None:
            return super()._hits(qf, rf)
        return self._hits_fn(qf, rf, *self._dev_arrays)

    def _sq_dists(self, qf):
        if self.n_shards <= 1 or self.snap.store is not None:
            return super()._sq_dists(qf)
        return self._sq_fn(qf, *self._dev_arrays)


@functools.lru_cache(maxsize=32)
def _sharded_pipeline(mesh: Mesh, axis: str, n_rings: int, specs: tuple):
    """Build the (cand, hits, sq) jitted ``shard_map`` pipeline.

    Cached on (mesh, axis, n_rings, specs) — all hashable — so a
    ``ServingEngine`` refresh that swaps in a same-shaped snapshot reuses
    the previous generation's compiled pipeline instead of retracing on
    the first post-swap batch (``jax.jit`` then keys on array shapes as
    usual; only a snapshot whose padded shapes actually changed pays a
    retrace).  The bodies take the snapshot's device arrays positionally
    (flatten order = ``_DEVICE_FIELDS``) and rebuild an attribute view
    per shard: inside ``shard_map`` every leading extent is shard-local,
    and ``_candidate_mask_arrays`` derives all shapes from the arrays
    themselves.
    """
    rep = P()                        # queries/radii: replicated per shard

    def local(arrays) -> SimpleNamespace:
        return SimpleNamespace(**dict(zip(_DEVICE_FIELDS, arrays)))

    def cand_body(qf, rf, *arrays):
        # shard-local TriPrune routing: this device evaluates only its
        # own clusters' ring boxes for every query in the batch
        return _candidate_mask_arrays(qf, rf, local(arrays), n_rings)

    def hits_body(qf, rf, *arrays):
        snap = local(arrays)
        cand = _candidate_mask_arrays(qf, rf, snap, n_rings)
        # the ops wrappers trace with shard-local shapes here, so their
        # tile policy sizes blocks to the per-device slice automatically
        ball, _ = ops.range_filter(
            qf, snap.rows.reshape(-1, snap.rows.shape[-1]),
            rf * (1.0 + _R_REL) + _BALL_ABS)
        return cand & ball.astype(bool)

    def sq_body(qf, *arrays):
        snap = local(arrays)
        d2 = ops.pdist(qf, snap.rows.reshape(-1, snap.rows.shape[-1]))
        d2 = jnp.where(snap.valid.reshape(-1)[None], d2, jnp.inf)
        # explicit collective: every shard ends up holding the full
        # (B, P) distance matrix, in cluster-shard order, so the kNN
        # radius seeding (global top-k) needs no host-side stitching
        return jax.lax.all_gather(d2, axis, axis=1, tiled=True)

    out_sharded = P(None, axis)
    return (
        jax.jit(shard_map(cand_body, mesh=mesh,
                          in_specs=(rep, rep) + specs,
                          out_specs=out_sharded, check_rep=False)),
        jax.jit(shard_map(hits_body, mesh=mesh,
                          in_specs=(rep, rep) + specs,
                          out_specs=out_sharded, check_rep=False)),
        jax.jit(shard_map(sq_body, mesh=mesh, in_specs=(rep,) + specs,
                          out_specs=P(None, None), check_rep=False)),
    )


def make_executor(snapshot: LIMSSnapshot, *, sharded: bool | None = None,
                  mesh: Mesh | None = None) -> QueryExecutor:
    """Executor factory: ``sharded=None`` auto-shards when the process
    sees more than one device (or a mesh is given), else stays on the
    plain single-device pipeline."""
    if sharded is None:
        sharded = mesh is not None or jax.device_count() > 1
    if sharded:
        return ShardedExecutor(snapshot, mesh=mesh)
    return QueryExecutor(snapshot)


__all__ = ["QueryExecutor", "ShardedExecutor", "make_executor"]
