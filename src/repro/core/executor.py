"""Layer 2 of the serving stack: plan *execution* over a snapshot.

The query path is split plan/execute (DESIGN.md §8): ``repro.core.planner``
builds one :class:`~repro.core.planner.CandidatePlan` per query batch —
certified candidate masks, cluster routing and the growing-radius
schedule, derived purely from snapshot metadata — and this module
executes it through one of two backends:

  * ``_ResidentBackend`` — the in-memory kernel pipeline.  Range applies
    the fused L2-ball prefilter to the plan's device mask — by default
    (``REPRO_COMPACT=on``) over the plan's *compacted candidate gather*:
    the union certified candidate rows, gathered once into a
    power-of-two bucket, so filter bytes scale with TriPrune survivors
    instead of padded slots (DESIGN.md §13).  kNN runs the *entire*
    growing-radius schedule inside one compiled ``lax.while_loop`` with
    per-query done flags, so a batch costs O(1) host syncs no matter how
    many rounds it takes (the counter is recorded in ``last_knn`` and
    asserted in tests).  Both filters read the snapshot's *filter plane*
    (``REPRO_ROWS_DTYPE``: optionally bf16/f16 rows whose certified
    quantization margin widens ball tests and tightens certifications),
    and the exact host refinement keeps results bitwise identical either
    way.
  * ``_PagedBackend`` — the storage tier.  The plan's masks become
    IO-batched page runs; because round t+1's radius is known from the
    schedule before round t's refinement finishes, the backend can hand
    the next round's IOPlan to an async prefetcher
    (``REPRO_PREFETCH=async``) that overlaps page IO with kernel
    refinement.

``QueryExecutor`` owns the single-device pipeline; ``ShardedExecutor``
runs the same plan math cluster-sharded with ``shard_map`` over a mesh
from ``repro.sharding.logical``: each device holds a contiguous shard of
clusters, TriPrune routes every query per shard, and the kNN loop keeps
its per-round reductions on device — candidate counts via ``psum`` and
the k-th distance via a shard-local ``top_k`` merged with
``all_gather`` over (B, k)-sized blocks, never the full distance
matrix.  Cluster-granular sharding preserves exactness for free — pivot
tables, rank models and the certified error bound are all strictly
per-cluster state (DESIGN.md §4).

With one visible device ``ShardedExecutor`` degrades to the plain
single-device path, so CPU-interpret tests exercise the same class; a
second CI job forces 4 host devices (``--xla_force_host_platform_device_count``)
to run the real ``shard_map`` path.

Exactness contract: both executors return results bit-identical to the
host ``LIMSIndex`` — the plan's masks are a certified *superset* of
candidates (error-widened ring box, inflated f32 guard bands), kNN
rounds only certify once the k-th ball provably fits inside the queried
radius minus the guard band, and the final refinement recomputes true
f64 distances on the host (DESIGN.md §3, §8).
"""
from __future__ import annotations

import functools
import threading
import time
from types import SimpleNamespace

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .. import env
from ..kernels import ops
from ..kernels.dispatch import compact_enabled
from ..obs import registry as _obs
from ..obs.profile import QueryProfile, record_profile
from ..obs.trace import span
from ..sharding.logical import default_rules, serving_mesh, spec_for
from ..storage import (PagePrefetcher, cache_pin_mode, plan_batch,
                       prefetch_mode)
from .metrics import dist_one_to_many
from .planner import (_BALL_ABS, _R_REL, _SEED_REL, CandidatePlan, Planner,
                      plan_arrays)
from .snapshot import _DEVICE_FIELDS, LIMSSnapshot

# padding rows for bucketed store-mode kernel launches: far outside any
# ball, large but finite so f32 arithmetic stays NaN-free
_FAR = np.float32(1e30)


def _bucket_size(n: int, min_rows: int = 128) -> int:
    """Next power-of-two row bucket (≥ ``min_rows``) for ``n`` rows —
    the one bucketing policy both gather paths (paged IO and the
    resident compacted gather) launch kernels at, capping the number of
    executable shapes at log₂(P)."""
    return max(min_rows, 1 << max(n - 1, 1).bit_length())


def _pad_bucket(rows32: np.ndarray, min_rows: int = 128) -> np.ndarray:
    """Pad gathered rows to the next power-of-two bucket (≥ ``min_rows``).

    Store-mode launches run over candidate sets whose size varies per
    batch and per kNN round; without bucketing every distinct row count
    is a fresh jit compile on compiled backends.  Buckets cap the number
    of executable shapes at log₂(P); padding rows sit at distance ~1e30
    so they can never enter any ball, and callers slice kernel outputs
    back to the true count (per-pair math is unaffected by padding)."""
    n = rows32.shape[0]
    bucket = _bucket_size(n, min_rows)
    if bucket <= n:
        return rows32
    pad = np.full((bucket - n, rows32.shape[1]), _FAR, np.float32)
    return np.concatenate([rows32, pad])


# ---------------------------------------------------------------------------
# device-resident kNN rounds: the whole growing-radius schedule is one
# compiled loop — seed, rounds, certification and the exact-fallback all
# trace into a single executable, so a batch syncs to host exactly once
# ---------------------------------------------------------------------------
def _smallest_k(dm, k: int):
    """(B, k) smallest values per row, ascending — exact.

    Inside jit, XLA CPU lowers ``lax.top_k`` through a generic sort path
    roughly 40× slower than its eager dispatch (measured: 1.2s vs 31ms
    on a (64, 92k) f32 operand), which would dominate the compiled kNN
    loop.  For the small k the loop certifies with, k successive masked
    argmin sweeps are exact (ties consume one occurrence per sweep) and
    lower to plain fast reductions; large k (the k≈corpus clamp cases,
    where selection is a minor cost anyway) falls back to ``top_k``."""
    if k > 64:
        return -jax.lax.top_k(-dm, k)[0]
    rows = jnp.arange(dm.shape[0])

    def step(dm, _):
        i = jnp.argmin(dm, axis=1)
        v = dm[rows, i]
        return dm.at[rows, i].set(jnp.inf), v

    _, vs = jax.lax.scan(step, dm, None, length=k)
    return vs.T                                 # (B, k) ascending



def _knn_rounds(qf, d2, kth0, r0, eps, snap, n_rings, k_eff, max_rounds,
                count_sum, kth_select):
    """The entire certified growing-radius schedule as one
    ``lax.while_loop`` — the ONE copy of the loop both the single-device
    and the per-shard caller trace, parameterized only by the two global
    reductions (``count_sum``: (B, P_local) candidate mask → (B) global
    counts; ``kth_select``: (B, P_local) masked sq-distances → (B)
    global k-th smallest).  ``kth0`` is the f32 k-th distance (global —
    the sharded caller merges shard-local top-k first), ``r0`` the
    plan's (B,) f32 pivot-seeded schedule base.

    The start radius skips ahead on the schedule to the first round
    whose radius covers the k-th distance estimate (``r0·2^t ≥ kth``):
    executed radii stay on the deterministic schedule the plan
    advertises, but a well-seeded batch certifies in one round, exactly
    like the pre-refactor k-th-distance seeding.  Certification is the
    same guard-band test as ever — enough candidates AND the k-th ball
    strictly inside the round radius minus the f32 bands — so the
    certified set is a superset of the closed k-th ball at any radius
    the schedule visits, and exactness never depends on the seed.
    Anything the schedule never certifies falls back to the exact full
    scan of (locally) valid slots.  Returns (final mask, rounds used),
    both shard-local shapes under ``shard_map``.

    ``eps`` is the certified quantization margin of whatever row plane
    produced ``d2`` (``snap.filter_rows()``; 0.0 for the exact f32
    rows): per-pair filter distances satisfy |d_lp − d| ≤ eps, so the
    ball test widens by +eps (no true candidate can be cut) and the
    k-th-ball certification tightens by −eps (the true k-th distance is
    at most the filtered one plus eps).  At eps = 0.0 both adjustments
    are the f32 identity x ± 0.0 — bit-for-bit the pre-lp loop.
    """
    valid = snap.valid.reshape(-1)
    B = qf.shape[0]
    seed = kth0 * (1.0 + _SEED_REL) + _BALL_ABS
    t0 = jnp.ceil(jnp.log2(jnp.maximum(seed, 1e-30) / r0))
    r_start = r0 * jnp.exp2(jnp.maximum(t0, 0.0))

    def cond(st):
        done, r, rounds, final = st
        return jnp.logical_and(~jnp.all(done), rounds < max_rounds)

    def body(st):
        done, r, rounds, final = st
        cand = plan_arrays(qf, r, snap, n_rings)[0]
        ball = d2 <= ((r * (1.0 + _R_REL) + _BALL_ABS + eps) ** 2)[:, None]
        candb = cand & ball
        cnt = count_sum(candb)
        dm = jnp.where(candb, d2, jnp.inf)
        kth = jnp.sqrt(jnp.maximum(kth_select(dm), 0.0))
        ok = (cnt >= k_eff) & (kth <= r * (1.0 - _R_REL) - _BALL_ABS - eps)
        newly = ok & ~done
        final = jnp.where(newly[:, None], candb, final)
        done = done | newly
        r = jnp.where(done, r, r * 2.0)
        return done, r, rounds + 1, final

    st0 = (jnp.zeros(B, bool), r_start, jnp.int32(0),
           jnp.zeros((B, valid.shape[0]), bool))
    done, _, rounds, final = jax.lax.while_loop(cond, body, st0)
    final = jnp.where(done[:, None], final, valid[None])
    return final, rounds


@functools.partial(jax.jit,
                   static_argnames=("n_rings", "k_eff", "max_rounds"))
def _knn_loop_single(qf, d2, kth0, r0, eps, *arrays, n_rings, k_eff,
                     max_rounds):
    """Single-device compiled kNN rounds: (final mask, rounds used).

    ``d2``/``kth0`` (the full valid-masked filter-plane distance matrix
    and the f32 k-th distance) arrive precomputed from the *eager*
    kernel path — XLA CPU's eager TopK dispatch is ~40× its jitted
    lowering, and the seed is loop-invariant anyway, so only per-round
    work compiles.  ``eps`` is the plane's certified margin (see
    ``_knn_rounds``; 0.0 on the exact f32 plane)."""
    snap = SimpleNamespace(**dict(zip(_DEVICE_FIELDS, arrays)))
    return _knn_rounds(
        qf, d2, kth0, r0, eps, snap, n_rings, k_eff, max_rounds,
        count_sum=lambda candb: jnp.sum(candb, axis=1),
        kth_select=lambda dm: _smallest_k(dm, k_eff)[:, -1])


@jax.jit
def _knn_round_masks(d2, cand, rf, eps):
    """The fused wide part of one host-rounds round: certified ball
    mask, candidate count, and the masked distance matrix in a single
    launch.

    The eager spelling streams the (B, n_slots) matrix once per op —
    ball, candb, cnt, dm are four separate passes; fused, XLA reads
    ``d2``/``cand`` once and writes ``candb``/``dm`` once.  The k-th
    selection stays *outside*: XLA-CPU's jitted TopK lowering is an
    order of magnitude slower than its eager dispatch (the same cliff
    that routes REPRO_KNN_DRIVER=auto to this driver), so the round
    fuses everything except TopK.  Same jnp graph as the eager version
    (elementwise math, exact bool-sum reduction) so the outputs are
    bit-identical — a bytes-moved optimization, not a math change."""
    ball = d2 <= ((rf * (1.0 + _R_REL) + _BALL_ABS + eps) ** 2)[:, None]
    candb = cand & ball
    cnt = jnp.sum(candb, axis=1)
    dm = jnp.where(candb, d2, jnp.inf)
    return candb, cnt, dm


# ---------------------------------------------------------------------------
# execution backends (both consume the same CandidatePlan)
# ---------------------------------------------------------------------------
def _knn_driver(ex) -> str:
    """Which resident kNN driver executes the schedule, resolved per
    call so ``REPRO_KNN_DRIVER`` monkeypatching works on long-lived
    executors.  ``loop`` is the compiled ``lax.while_loop``; ``rounds``
    is the host-driven vectorized-round driver.  ``auto`` (default)
    picks ``rounds`` on single-device XLA-CPU — the while_loop/TopK
    cliff is a property of XLA's CPU lowerings (notably ``top_k``, ~40×
    its eager dispatch; the PR-5 ~433 → ~181 q/s regression), not of
    interpret mode, so the compiled xla lane takes the same exit — and
    ``loop`` everywhere else: real accelerators keep O(1) host syncs,
    and the sharded loop's per-round collectives have no eager
    equivalent."""
    mode = env.get("REPRO_KNN_DRIVER")
    if mode in ("loop", "rounds"):
        return mode
    if jax.default_backend() == "cpu" and getattr(ex, "n_shards", 1) <= 1:
        return "rounds"
    return "loop"


class _ResidentBackend:
    """In-memory execution: kernels over the snapshot's device rows."""

    name = "resident"

    def __init__(self, ex: "QueryExecutor"):
        self.ex = ex
        self.prefetcher = None          # nothing to prefetch in memory

    def release(self, plan: CandidatePlan) -> None:
        """No storage, nothing pinned."""

    def range_hits(self, plan: CandidatePlan) -> np.ndarray:
        ex = self.ex
        rf = jnp.asarray(plan.radii, jnp.float32)
        if compact_enabled() and getattr(ex, "n_shards", 1) <= 1:
            slots = plan.compact_slots()
            if slots is not None:
                return self._range_hits_compact(plan, rf, slots)
        ex.last_compact = None
        hits = plan.mask_dev & ex._ball_filter(plan.qf, rf)
        ex._count_sync()
        return np.asarray(hits)

    def _range_hits_compact(self, plan: CandidatePlan, rf,
                            slots: np.ndarray) -> np.ndarray:
        """Ball prefilter over the plan's compacted candidate gather
        (DESIGN.md §13): the union candidate rows are gathered from the
        filter plane once into a power-of-two bucket and only the dense
        array streams through ``range_filter`` — filter bytes scale
        with surviving candidates, not padded slots.

        Bit-identical to the full-array path: the gathered rows are the
        very device rows the full filter would stream, per-pair kernel
        math is independent of which rows share a launch, bucket
        padding sits at ~1e30 outside every ball, and slots outside the
        union are non-candidates for the whole batch in both paths
        (pinned by tests)."""
        ex = self.ex
        s = ex.snap
        cand = plan.mask
        hits = np.zeros_like(cand)
        bucket = 0
        if slots.size:
            frows, eps = s.filter_rows()
            sub = frows.reshape(s.n_slots, s.d)[jnp.asarray(slots)]
            bucket = _bucket_size(int(slots.size))
            if bucket > slots.size:
                sub = jnp.pad(sub, ((0, bucket - slots.size), (0, 0)),
                              constant_values=_FAR)
            ball, _ = ops.range_filter(
                plan.qf, sub, rf * (1.0 + _R_REL) + _BALL_ABS + eps)
            ball = np.asarray(ball, bool)[:, :slots.size]
            ex._count_sync()
            hits[:, slots] = cand[:, slots] & ball
        ex.last_compact = {"slots": int(slots.size), "bucket": int(bucket),
                           "n_slots": int(s.n_slots)}
        _obs.count("executor.compact_batches")
        if s.n_slots:
            _obs.observe("executor.compact_frac",
                         slots.size / float(s.n_slots))
        return hits

    def knn_candidates(self, plan: CandidatePlan):
        ex = self.ex
        if _knn_driver(ex) == "rounds":
            return self._knn_host_rounds(plan)
        ex.last_driver = "loop"
        r0 = jnp.asarray(plan.radii, jnp.float32)
        final, rounds = ex._knn_device_loop(
            plan.qf, r0, plan.k, plan.max_rounds)
        final, rounds = jax.device_get((final, rounds))
        ex._count_sync()
        return np.asarray(final, bool), int(rounds)

    def _knn_host_rounds(self, plan: CandidatePlan):
        """The same certified schedule as ``_knn_rounds``, driven from
        the host with eager per-round kernel dispatches: identical seed
        skip-ahead, identical guard-band certification, identical exact
        fallback — only the loop control moves to Python, trading O(1)
        host syncs for XLA-CPU's fast eager lowerings.  The certified
        set is a superset of the closed k-th ball at whatever schedule
        radius certifies, so refinement returns bit-identical results
        whichever driver ran (pinned by tests)."""
        ex = self.ex
        s = ex.snap
        qf = plan.qf
        k_eff = plan.k
        d2, eps = ex._filter_dists(qf)
        kth0 = jnp.sqrt(jnp.maximum(
            -jax.lax.top_k(-d2, k_eff)[0][:, -1], 0.0))
        r0 = jnp.asarray(plan.radii, jnp.float32)
        seed = kth0 * (1.0 + _SEED_REL) + _BALL_ABS
        t0 = jnp.ceil(jnp.log2(jnp.maximum(seed, 1e-30) / r0))
        r = np.asarray(r0 * jnp.exp2(jnp.maximum(t0, 0.0)))
        ex._count_sync()
        B = plan.B
        done = np.zeros(B, bool)
        final = np.zeros((B, s.n_slots), bool)
        rounds = 0
        for t in range(plan.max_rounds):
            rounds = t + 1
            rf = jnp.asarray(r, jnp.float32)
            cand = ex._candidate_mask(qf, rf)
            # same ±eps adjustments as _knn_rounds: widen the ball so
            # the lp plane can't cut a true candidate, tighten the
            # certification by the margin the filtered k-th may be off
            # (fused wide passes; TopK stays eager — see _knn_round_masks)
            candb, cnt, dm = _knn_round_masks(d2, cand, rf,
                                              jnp.float32(eps))
            kth = jnp.sqrt(jnp.maximum(
                -jax.lax.top_k(-dm, k_eff)[0][:, -1], 0.0))
            ok = np.asarray((cnt >= k_eff) &
                            (kth <= rf * (1.0 - _R_REL) - _BALL_ABS - eps))
            ex._count_sync()
            newly = ok & ~done
            if newly.any():
                final[newly] = np.asarray(candb)[newly]
                done |= newly
            if done.all():
                break
            r = np.where(done, r, r * 2.0)
        else:
            final[~done] = s.valid_np[None]
        ex.last_driver = "rounds"
        return final, rounds


class _PagedBackend:
    """Storage-tier execution: the plan's masks drive page IO.

    Round t's certified mask becomes a deduplicated, run-coalesced
    ``IOPlan``; rows are gathered through the snapshot's generation-bound
    ``StoreView`` and refined with the same kernels (power-of-two row
    bucketing keeps compile churn bounded).  With a prefetcher attached
    (``REPRO_PREFETCH=async``), round t+1's IOPlan — known from the
    schedule before round t's refinement starts — is fetched on a
    background thread while the kernels run, so the next round's fetch
    finds its pages already resident (DESIGN.md §8).
    """

    name = "paged"

    def __init__(self, ex: "QueryExecutor", prefetch: str | None = None):
        self.ex = ex
        mode = prefetch_mode() if prefetch is None else str(prefetch).lower()
        self.prefetcher = PagePrefetcher(ex.snap.store) \
            if mode == "async" else None

    # ----------------------------------------------------- schedule pins
    def _pin(self, plan: CandidatePlan, pages: np.ndarray) -> None:
        """Pin one round's planned pages for the plan's lifetime
        (``REPRO_CACHE_PIN=off`` reverts to blind LRU).  The ledger
        lives on the plan so ``release`` can drain it even when the
        executor errors mid-batch."""
        if len(pages) and cache_pin_mode():
            self.ex.snap.store.pin_pages(pages)
            plan._pins.append(pages)

    def release(self, plan: CandidatePlan) -> None:
        """Drop every page hold this plan's execution took (idempotent:
        the ledger drains)."""
        store = self.ex.snap.store
        pins, plan._pins = plan._pins, []
        for pages in pins:
            store.unpin_pages(pages)

    # ------------------------------------------------------------- range
    def range_hits(self, plan: CandidatePlan) -> np.ndarray:
        """Same candidate mask as the resident path, ball prefilter on
        gathered pages.  Per-pair kernel math is independent of which
        other rows share a launch and the gathered f32 rows are the same
        downcast the resident snapshot holds, so the mask is identical
        to the in-memory path (DESIGN.md §7)."""
        ex = self.ex
        store = ex.snap.store
        cand = plan.mask
        io = plan_batch(cand, store.layout)
        # schedule-aware eviction: the batch's planned pages stay pinned
        # until execute_*'s finally releases the plan — a squeezed cache
        # can't evict them between fetch, gather and exact refinement
        self._pin(plan, io.pages)
        store.fetch(io)
        rf = jnp.asarray(plan.radii, jnp.float32)
        hits = np.zeros_like(cand)
        if len(io.slots):
            rows64 = store.gather(io.slots)
            ball, _ = ops.range_filter(
                plan.qf, jnp.asarray(_pad_bucket(rows64.astype(np.float32))),
                rf * (1.0 + _R_REL) + _BALL_ABS)
            ball = np.asarray(ball, bool)[:, :len(io.slots)]
            ex._count_sync()
            hits[:, io.slots] = cand[:, io.slots] & ball
        store.record_queries(io.pages_per_query, io.cand_per_query)
        ex.last_io = io.summary()
        ex.last_io["pinned_pages"] = sum(len(p) for p in plan._pins)
        return hits

    # --------------------------------------------------------------- kNN
    def knn_candidates(self, plan: CandidatePlan):
        """Growing-radius rounds whose IO is the candidate pages.

        Each round evaluates the plan's schedule mask for the whole
        batch, fetches only pages not yet resident (the scheduler
        dedupes; earlier rounds' pages are cache hits — Alg. 2's
        never-re-read-a-page contract), computes f32 distances on the
        newly gathered rows with the same ``pdist`` kernel, and
        certifies per query with the resident loop's exact guard-band
        test.  The certified set is a superset of the closed k-th ball
        — ``_refine_topk`` therefore returns results bit-identical to
        the in-memory executor (DESIGN.md §7)."""
        ex = self.ex
        ex.last_driver = "paged"
        s = ex.snap
        store = s.store
        pf = self.prefetcher
        qf = plan.qf
        B, k_eff = plan.B, plan.k
        r = plan.radii.copy()
        done = np.zeros(B, bool)
        final = np.zeros((B, s.n_slots), bool)
        pos = np.full(s.n_slots, -1, np.int64)   # slot → gathered column
        d2g = np.empty((B, 0), np.float32)       # sq dists, gathered slots
        pages_seen = [set() for _ in range(B)]   # per-query IO metric
        seen = np.zeros((B, s.n_slots), bool)    # per-query fetched cands
        cand_next = plan.mask                    # round-0 schedule mask
        ticket = None
        rounds = 0
        for t in range(plan.max_rounds):
            rounds = t + 1
            cand = cand_next.copy()
            cand_next = None
            cand[done] = False        # frozen queries stop driving IO
            # per_query=False: the pages_seen sets below are this
            # driver's cross-round page accounting
            io = plan_batch(cand, store.layout, per_query=False)
            if pf is not None:
                pf.note_demand(io.pages, ticket)
                ticket = None
            # pin before the fetch: earlier rounds' pages a later round
            # re-demands (growing radii are supersets) stay resident
            # until execute_knn's finally releases the plan
            self._pin(plan, io.pages)
            store.fetch(io)
            # pages(∪ rounds) = ∪ pages(new slots per round): only map
            # slots not already charged to the query
            newly = cand & ~seen
            seen |= cand
            for b in np.nonzero(newly.any(axis=1))[0]:
                pages_seen[b].update(store.layout.slot_pages(
                    np.nonzero(newly[b])[0]).tolist())
            new = io.slots[pos[io.slots] < 0]
            if len(new):
                rows64 = store.gather(new)
                pos[new] = d2g.shape[1] + np.arange(len(new))
            # the schedule fixes round t+1's radius before round t's
            # refinement runs — evaluate its mask now and hand the page
            # IO of the genuinely new slots (``exclude``: everything
            # this or an earlier round gathered) to the background
            # prefetcher, overlapping the kernel work below
            if pf is not None and t + 1 < plan.max_rounds:
                spec_r = np.where(done, r, r * 2.0)
                cand_next = ex.planner.eval_mask(qf, spec_r)
                spec = cand_next.copy()
                spec[done] = False
                pio = plan_batch(spec, store.layout, per_query=False,
                                 exclude=pos >= 0)
                self._pin(plan, pio.pages)   # speculative pages too
                ticket = pf.submit(pio.pages)
            if len(new):
                d2_new = np.asarray(ops.pdist(
                    qf, jnp.asarray(_pad_bucket(
                        rows64.astype(np.float32)))))[:, :len(new)]
                ex._count_sync()
                d2g = np.concatenate([d2g, d2_new], axis=1)
            r32 = np.asarray(r, np.float32)
            thr = (r32 * np.float32(1.0 + _R_REL) +
                   np.float32(_BALL_ABS)) ** 2    # f32 guard-band ball
            cert = r32 * np.float32(1.0 - _R_REL) - np.float32(_BALL_ABS)
            for b in np.nonzero(~done)[0]:
                sl = np.nonzero(cand[b])[0]
                if len(sl) < k_eff:
                    continue
                db = d2g[b, pos[sl]]
                inball = db <= thr[b]
                if int(inball.sum()) < k_eff:
                    continue
                kth = np.sqrt(np.float32(max(
                    np.partition(db[inball], k_eff - 1)[k_eff - 1], 0.0)))
                # same certification as the resident loop: the k-th ball
                # fits strictly inside the round radius minus the f32
                # guard band
                if kth <= cert[b]:
                    final[b, sl[inball]] = True
                    done[b] = True
            if done.all():
                break
            r = np.where(done, r, r * 2.0)
            if cand_next is None and t + 1 < plan.max_rounds:
                cand_next = ex.planner.eval_mask(qf, r)
        else:
            final[~done] = s.valid_np[None]       # exact fallback: scan
            seen[~done] = s.valid_np[None]
        ppq = [len(p) for p in pages_seen]
        # candidates = rows fetched for the query across every round
        # (the union of its candidate sets), matching the range path's
        # accounting — NOT the smaller certified final set
        cpq = seen.sum(axis=1)
        store.record_queries(ppq, cpq)
        ex.last_io = {"pages": len(set().union(*pages_seen)),
                      "pages_per_query": ppq,
                      "candidates_per_query": [int(c) for c in cpq],
                      "pinned_pages": sum(len(p) for p in plan._pins)}
        if pf is not None:
            ex.last_io["prefetch"] = pf.snapshot()
        return final, rounds


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------
class QueryExecutor:
    """Single-device plan execution + exact host refinement.

    A snapshot carrying a paged store (``snap.store``, DESIGN.md §7)
    selects the paged backend: candidate masks are computed from
    resident metadata exactly as in memory, then executed as page-
    granular IO — bit-identical results, the paper's cost model driven
    by the learned positions."""

    def __init__(self, snapshot: LIMSSnapshot, prefetch: str | None = None):
        self.snap = snapshot
        self.planner = Planner(self)
        self.backend = _PagedBackend(self, prefetch) \
            if snapshot.store is not None else _ResidentBackend(self)
        # IO summary of the most recent store-mode batch (None otherwise)
        self.last_io: dict | None = None
        # {slots, bucket, n_slots} of the most recent resident range
        # batch that took the compacted-gather path (None when the full
        # padded array streamed; last-writer-wins like last_io)
        self.last_compact: dict | None = None
        # {backend, rounds, host_syncs, driver} of the most recent kNN
        # batch (last-writer-wins under concurrent batches, like last_io)
        self.last_knn: dict | None = None
        self.last_driver: str | None = None
        # QueryProfile of the most recent batch (None until one runs,
        # or with REPRO_OBS=off; last-writer-wins like last_io/last_knn)
        self.last_profile = None
        # per-thread sync counter: executors serve lock-free concurrent
        # query threads, and one batch's count must not absorb another's
        self._tls = threading.local()
        # host mirrors of the model/ring fields for the observed
        # rank-error health stat; materialized once on first profiled
        # batch (never on the off path), see _health_arrays
        self._health: SimpleNamespace | None = None

    @property
    def live(self) -> int:
        return self.snap.live

    @property
    def prefetcher(self):
        """The backend's async page prefetcher (None unless paged and
        ``REPRO_PREFETCH=async``)."""
        return self.backend.prefetcher

    def _count_sync(self) -> None:
        """One device→host materialization on the query path (the kNN
        acceptance bar counts these per batch; thread-local, so
        concurrent batches on a shared executor count independently)."""
        self._tls.syncs = getattr(self._tls, "syncs", 0) + 1

    # ------------------------------------------------------ device stages
    # (the three methods a sharding strategy overrides)
    def _plan_arrays(self, qf: jax.Array, rf: jax.Array):
        """((B, P) candidate mask, (B, K) routing) — the plan math."""
        return plan_arrays(qf, rf, self.snap, self.snap.n_rings)

    def _candidate_mask(self, qf: jax.Array, rf: jax.Array) -> jax.Array:
        """(B, P) bool — error-widened ring box ∧ TriPrune ∧ validity."""
        return self._plan_arrays(qf, rf)[0]

    def _ball_filter(self, qf: jax.Array, rf: jax.Array) -> jax.Array:
        """(B, P) bool — fused L2-ball prefilter over the snapshot's
        *filter plane*: the reduced-precision row copy when
        ``REPRO_ROWS_DTYPE`` enables one (radius widened by its
        certified eps so quantization can never cut a true result), the
        exact f32 rows with eps 0.0 — then bit-for-bit the pre-lp
        filter — otherwise."""
        s = self.snap
        frows, eps = s.filter_rows()
        ball, _ = ops.range_filter(qf, frows.reshape(s.n_slots, s.d),
                                   rf * (1.0 + _R_REL) + _BALL_ABS + eps)
        return ball.astype(bool)

    def _sq_dists(self, qf: jax.Array) -> jax.Array:
        """(B, P) f32 squared distances to every slot, inf where invalid."""
        s = self.snap
        if s.store is not None:
            raise RuntimeError(
                "store-backed executor never scans every slot; the kNN "
                "driver routes through the paged backend")
        d2 = ops.pdist(qf, s.rows.reshape(s.n_slots, s.d))
        return jnp.where(s.valid.reshape(-1)[None], d2, jnp.inf)

    def _filter_dists(self, qf: jax.Array) -> tuple[jax.Array, float]:
        """(B, P) f32 squared distances on the filter plane, plus the
        plane's certified quantization margin eps.

        With the lp plane off this is :meth:`_sq_dists` bit-for-bit
        (eps 0.0).  With it on, per-pair distances satisfy
        |d_lp − d| ≤ eps (metric-norm bound on the row rounding,
        computed at snapshot build), so callers widen ball tests by
        +eps and tighten certifications by −eps; the exact host
        refinement then keeps final results bitwise identical."""
        s = self.snap
        if s.store is not None:
            raise RuntimeError(
                "store-backed executor never scans every slot; the kNN "
                "driver routes through the paged backend")
        frows, eps = s.filter_rows()
        d2 = ops.pdist(qf, frows.reshape(s.n_slots, s.d))
        return jnp.where(s.valid.reshape(-1)[None], d2, jnp.inf), eps

    def _knn_device_loop(self, qf, r0, k_eff: int, max_rounds: int):
        """(final mask, rounds) — the kNN schedule as one executable.

        The loop-invariant pieces (filter-plane distance matrix, seed
        k-th distance) run on the eager kernel path first; only the
        rounds themselves compile.  No extra host syncs — eager results
        stay device-resident and feed the jitted loop directly."""
        d2, eps = self._filter_dists(qf)
        kth0 = jnp.sqrt(jnp.maximum(
            -jax.lax.top_k(-d2, k_eff)[0][:, -1], 0.0))
        return _knn_loop_single(
            qf, d2, kth0, r0, jnp.float32(eps),
            *(getattr(self.snap, f) for f in _DEVICE_FIELDS),
            n_rings=self.snap.n_rings, k_eff=k_eff, max_rounds=max_rounds)

    # -------------------------------------------------------- observability
    def _emit_profile(self, plan: CandidatePlan, final: np.ndarray,
                      rounds: int, stages: dict, t0: float) -> None:
        """Build and record one batch's :class:`QueryProfile`.

        Everything derives from state already on the host — the final
        candidate mask the backend returned, ``last_io``, the
        thread-local sync counter — so profiling adds *zero* device
        syncs (the planner's O(1)-syncs-per-batch contract is pinned by
        tests and must survive instrumentation).  Candidates here are
        the certified rows refinement actually scanned; clusters are
        how many of the K clusters those rows span (TriPrune's pruning
        power, per query)."""
        if not _obs.enabled():
            return
        s = self.snap
        B = plan.B
        K, n_max, _ = s.rids.shape
        cand = final.sum(axis=1)
        clusters = final.reshape(B, K, n_max).any(axis=-1).sum(axis=-1)
        if self.backend.name == "paged" and self.last_io is not None:
            pages = int(self.last_io["pages"])
            ppq = float(np.mean(self.last_io["pages_per_query"]))
        else:
            pages, ppq = 0, 0.0
        prof = QueryProfile(
            kind=plan.kind, batch=B, k=plan.k,
            backend=self.backend.name,
            driver=self.last_driver if plan.kind == "knn" else None,
            storage="paged" if s.store is not None else "resident",
            n_shards=int(getattr(self, "n_shards", 1)),
            rounds=int(rounds),
            host_syncs=int(getattr(self._tls, "syncs", 0)),
            pages=pages, pages_per_query=ppq,
            candidates_per_query=float(cand.mean()),
            clusters_per_query=float(clusters.mean()),
            n_clusters=int(K), stages=stages,
            total_s=time.perf_counter() - t0 + plan.plan_s,
            rank_err_ratio=self._observed_rank_err(final))
        self.last_profile = prof
        record_profile(prof)

    # how many certified candidates the rank-health stat replays per
    # batch (host f32 math over cache-hot rows — bounded, not per-row)
    _HEALTH_SAMPLE = 32

    def _health_arrays(self) -> SimpleNamespace:
        """Host mirrors of the model/ring fields, materialized once per
        executor so the per-batch health stat adds no device work."""
        h = self._health
        if h is None:
            s = self.snap
            h = SimpleNamespace(
                rids=np.asarray(s.rids),                     # (K, n_max, m)
                pivots=np.asarray(s.pivots, np.float32),     # (K, m, d)
                coef=np.asarray(s.coef, np.float32),         # (K, m, C)
                lo=np.asarray(s.model_lo, np.float32),       # (K, m)
                hi=np.asarray(s.model_hi, np.float32),
                n=np.asarray(s.model_n, np.float32),
                err=np.asarray(s.rank_err, np.float32),      # (K, m)
                in_ring=np.asarray(s.in_ring).reshape(-1),   # (K*n_max,)
            )
            self._health = h
        return h

    def _observed_rank_err(self, final: np.ndarray) -> float | None:
        """Observed rank-model error over this batch, as a fraction of
        the certified bound E (DESIGN.md §12).

        Samples up to ``_HEALTH_SAMPLE`` certified in-ring candidate
        slots from the final mask (deterministic stride — no RNG on the
        query path), recomputes their pivot distances from the rows
        refinement just gathered (cache-hot), replays the kernel's
        ``rank_math`` arithmetic in host f32 numpy, and compares the
        predicted ring id against the one the build stored.  Ratio 1.0
        means predictions are off by as much as the ring-widening
        budget E assumes; the rank-drift detector watches the
        per-cluster gauges this emits.  Returns the sample-mean ratio,
        or None when the batch certified no in-ring rows.  Buffer rows
        (``in_ring`` False) bypass the model and are skipped."""
        s = self.snap
        K, n_max, m = s.rids.shape
        h = self._health_arrays()
        slots = np.nonzero(final.any(axis=0) & h.in_ring)[0]
        if slots.size == 0:
            return None
        if slots.size > self._HEALTH_SAMPLE:
            step = slots.size // self._HEALTH_SAMPLE
            slots = slots[::step][:self._HEALTH_SAMPLE]
        rows = np.asarray(self._refine_rows(slots), np.float32)  # (S, d)
        kk = slots // n_max
        jj = slots % n_max
        x = np.sqrt(((rows[:, None, :] - h.pivots[kk]) ** 2).sum(-1))
        # replay rank_math (kernels/rankeval.py) in f32: normalize,
        # Clenshaw high→low, rank → ring id
        lo, hi, nn = h.lo[kk], h.hi[kk], h.n[kk]                 # (S, m)
        t = np.clip((x - lo) / np.maximum(hi - lo, np.float32(1e-30))
                    * 2.0 - 1.0, -1.0, 1.0).astype(np.float32)
        coef = h.coef[kk]                                        # (S, m, C)
        b1 = np.zeros_like(t)
        b2 = np.zeros_like(t)
        t2 = 2.0 * t
        for c in range(coef.shape[-1] - 1, 0, -1):
            b1, b2 = coef[..., c] + t2 * b1 - b2, b1
        r = coef[..., 0] + t * b1 - b2
        rank = np.clip(np.rint(r), 0.0, np.maximum(nn - 1.0, 0.0))
        width = np.ceil(nn / np.float32(s.n_rings))
        pred = np.clip(np.floor(rank / np.maximum(width, 1.0)), 0.0,
                       np.float32(s.n_rings - 1))
        act = h.rids[kk, jj]                                     # (S, m)
        ok = act >= 0
        if not ok.any():
            return None
        ratio = np.where(
            ok, np.abs(pred - act) * width / np.maximum(h.err[kk], 1.0),
            0.0)
        for k in np.unique(kk):
            _obs.set_gauge(f"executor.rank_err_ratio.c{int(k)}",
                           float(ratio[kk == k].max()))
        mean = float(ratio.sum() / ok.sum())
        _obs.observe("executor.rank_err_ratio", mean)
        return mean

    # ----------------------------------------------------- refinement data
    def _refine_rows(self, idx: np.ndarray) -> np.ndarray:
        """f64 rows for flat slot ids: resident matrix or page gather
        (cache-hot — the prefilter just fetched these pages)."""
        if self.snap.store is not None:
            return self.snap.store.gather(idx)
        return self.snap.rows_np[idx]

    # ------------------------------------------------------- range queries
    def range_query_batch(self, Q, r):
        """Exact batched L2 range query.

        ``Q``: (B, d) queries; ``r``: scalar or (B,) per-query radii.
        Returns a list of B ``(ids, dists)`` pairs (int64 / float64), the
        same results as ``LIMSIndex.range_query`` per query.
        """
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        B = Q.shape[0]
        r_arr = np.broadcast_to(np.asarray(r, np.float64), (B,))
        self._tls.syncs = 0
        plan = self.planner.plan_range(Q, r_arr)
        return self.execute_range(Q, plan)

    def execute_range(self, Q, plan: CandidatePlan):
        """Execute a prebuilt range plan — the router's entry point: a
        replica runs a ``plan.subset`` built by another executor's
        planner without constructing a second plan.  ``Q`` must be the
        (B, d) f64 queries the plan was built for (the plan carries only
        their f32 device copy; exact refinement needs f64)."""
        s = self.snap
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        if plan._planner is not self.planner:
            self._tls.syncs = 0
        t0 = time.perf_counter()
        stages = {"plan": plan.plan_s}
        try:
            with span("executor.range_execute",
                      {"B": plan.B, "backend": self.backend.name}):
                hit = self.backend.range_hits(plan)
            t1 = time.perf_counter()
            stages["execute"] = t1 - t0
            out = []
            with span("executor.refine", {"B": plan.B}):
                for b in range(Q.shape[0]):
                    idx = np.nonzero(hit[b])[0]
                    ids = s.gids_np[idx]
                    d_true = dist_one_to_many(Q[b], self._refine_rows(idx),
                                              "l2")
                    keep = d_true <= plan.radii[b]
                    out.append((ids[keep], d_true[keep]))
            stages["refine"] = time.perf_counter() - t1
            self._emit_profile(plan, hit, 1, stages, t0)
        finally:
            self.backend.release(plan)
        return out

    def range_query(self, q, r: float):
        """Single-query convenience wrapper over the batch engine."""
        return self.range_query_batch(np.asarray(q)[None], float(r))[0]

    # --------------------------------------------------------- kNN queries
    def knn_query_batch(self, Q, k: int, max_rounds: int = 64):
        """Exact batched kNN: one plan, one backend execution.

        ``k`` is clamped to the number of live objects. Returns
        ``(ids (B, k'), dists (B, k'))`` with ``k' = min(k, live)``.
        """
        s = self.snap
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        B = Q.shape[0]
        k_eff = min(int(k), s.live)
        if k_eff <= 0:
            return (np.empty((B, 0), np.int64), np.empty((B, 0)))
        self._tls.syncs = 0
        plan = self.planner.plan_knn(Q, k_eff, max_rounds)
        return self.execute_knn(Q, plan)

    def execute_knn(self, Q, plan: CandidatePlan):
        """Execute a prebuilt kNN plan (see :meth:`execute_range`).
        A plan built by a *different* executor's planner starts a fresh
        sync count here — the builder's syncs were charged to its own
        thread-local counter when the plan was constructed."""
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        if plan._planner is not self.planner:
            self._tls.syncs = 0
        t0 = time.perf_counter()
        stages = {"plan": plan.plan_s}
        try:
            with span("executor.knn_execute",
                      {"B": plan.B, "k": plan.k,
                       "backend": self.backend.name}):
                final, rounds = self.backend.knn_candidates(plan)
            t1 = time.perf_counter()
            stages["execute"] = t1 - t0
            self.last_knn = {"backend": self.backend.name, "k": plan.k,
                             "rounds": rounds,
                             "host_syncs": self._tls.syncs,
                             "driver": self.last_driver}
            with span("executor.refine", {"B": plan.B}):
                out = self._refine_topk(Q, final, plan.k)
            stages["refine"] = time.perf_counter() - t1
            self._emit_profile(plan, final, rounds, stages, t0)
            return out
        finally:
            self.backend.release(plan)

    def _refine_topk(self, Q, final: np.ndarray, k_eff: int):
        """Exact f64 refinement of the certified candidate sets: the
        shared tail of both kNN backends.  ``final`` is a superset of the
        closed k-th ball per query, so the stable distance sort selects
        the same k results whichever backend produced it."""
        s = self.snap
        B = Q.shape[0]
        ids_out = np.empty((B, k_eff), np.int64)
        d_out = np.empty((B, k_eff))
        for b in range(B):
            idx = np.nonzero(final[b])[0]
            d_true = dist_one_to_many(Q[b], self._refine_rows(idx), "l2")
            sel = np.argsort(d_true, kind="stable")[:k_eff]
            ids_out[b] = s.gids_np[idx[sel]]
            d_out[b] = d_true[sel]
        return ids_out, d_out

    def knn_query(self, q, k: int):
        """Single-query convenience wrapper over the batch engine."""
        ids, dists = self.knn_query_batch(np.asarray(q)[None], k)
        return ids[0], dists[0]


class ShardedExecutor(QueryExecutor):
    """Cluster-sharded executor: ``shard_map`` over a device mesh.

    The snapshot's K clusters are padded to a multiple of the mesh's
    ``data`` extent and split on the cluster axis; every device traces
    the *same* ``plan_arrays`` body over its shard-local snapshot.
    Queries are replicated (in-spec ``P()``); per-shard plan masks come
    back sharded on the candidate axis (out-spec ``P(None, 'data')``),
    and the compiled kNN loop runs *inside* ``shard_map`` — per-round
    candidate counts merge with ``psum`` and the k-th distance with a
    shard-local ``top_k`` + ``all_gather`` over (B, k) blocks, so
    neither seeding nor rounds ever gather the full distance matrix.

    With one device (plain tier-1 CI) no mesh is built and the class
    behaves exactly like ``QueryExecutor``.
    """

    def __init__(self, snapshot: LIMSSnapshot, mesh: Mesh | None = None,
                 axis: str = "data", prefetch: str | None = None):
        if mesh is None:
            mesh = serving_mesh()
        self.mesh = mesh
        self.axis = axis
        self.n_shards = int(mesh.shape[axis]) if axis in mesh.axis_names \
            else 1
        if self.n_shards <= 1:
            super().__init__(snapshot, prefetch=prefetch)
            return
        K_pad = -(-snapshot.K // self.n_shards) * self.n_shards
        snapshot = snapshot.pad_clusters(K_pad)
        # cluster-major arrays shard on axis 0 (logical axis "clusters");
        # place each on its shard now so repeated calls never re-transfer
        rules = default_rules()
        leaves, treedef = jax.tree_util.tree_flatten(snapshot)
        specs = tuple(
            spec_for(("clusters",) + (None,) * (a.ndim - 1),
                     rules, mesh, a.shape) for a in leaves)
        snapshot = jax.tree_util.tree_unflatten(
            treedef, [jax.device_put(a, NamedSharding(mesh, sp))
                      for a, sp in zip(leaves, specs)])
        super().__init__(snapshot, prefetch=prefetch)
        self._dev_arrays = tuple(
            getattr(snapshot, f) for f in _DEVICE_FIELDS)
        self._specs = specs
        self._plan_fn, self._ball_fn = _sharded_pipeline(
            mesh, axis, snapshot.n_rings, specs)

    # sharded device stages (same host drivers as the base class).  In
    # store mode only the plan math runs sharded — the ball prefilter
    # and refinement happen on host-gathered pages, so those stages
    # never dispatch here (the paged backend only asks for plan masks).
    def _plan_arrays(self, qf, rf):
        if self.n_shards <= 1:
            return super()._plan_arrays(qf, rf)
        return self._plan_fn(qf, rf, *self._dev_arrays)

    def _ball_filter(self, qf, rf):
        if self.n_shards <= 1 or self.snap.store is not None:
            return super()._ball_filter(qf, rf)
        return self._ball_fn(qf, rf, *self._dev_arrays)

    # NOTE: no _sq_dists override — the full (B, P) distance matrix is
    # only ever needed by the single-device loop's eager seeding; the
    # sharded kNN loop replaced PR-2's all_gather of it with in-loop
    # shard-local top-k merges (the base method's eager jnp still
    # assembles the matrix correctly from the sharded rows if some
    # residual caller asks).

    def _knn_device_loop(self, qf, r0, k_eff: int, max_rounds: int):
        if self.n_shards <= 1:
            return super()._knn_device_loop(qf, r0, k_eff, max_rounds)
        fn = _sharded_knn_loop(self.mesh, self.axis, self.snap.n_rings,
                               self._specs, k_eff, max_rounds)
        return fn(qf, r0, *self._dev_arrays)


def _local_view(arrays) -> SimpleNamespace:
    """Attribute view of the snapshot's device arrays (flatten order =
    ``_DEVICE_FIELDS``): inside ``shard_map`` every leading extent is
    shard-local, and ``plan_arrays`` derives all shapes from the arrays
    themselves."""
    return SimpleNamespace(**dict(zip(_DEVICE_FIELDS, arrays)))


@functools.lru_cache(maxsize=32)
def _sharded_pipeline(mesh: Mesh, axis: str, n_rings: int, specs: tuple):
    """Build the (plan, ball) jitted ``shard_map`` pipeline.

    Cached on (mesh, axis, n_rings, specs) — all hashable — so a
    ``ServingEngine`` refresh that swaps in a same-shaped snapshot reuses
    the previous generation's compiled pipeline instead of retracing on
    the first post-swap batch (``jax.jit`` then keys on array shapes as
    usual; only a snapshot whose padded shapes actually changed pays a
    retrace).
    """
    rep = P()                        # queries/radii: replicated per shard

    def plan_body(qf, rf, *arrays):
        # shard-local TriPrune routing: this device evaluates only its
        # own clusters' ring boxes for every query in the batch
        return plan_arrays(qf, rf, _local_view(arrays), n_rings)

    def ball_body(qf, rf, *arrays):
        snap = _local_view(arrays)
        # the ops wrappers trace with shard-local shapes here, so their
        # tile policy sizes blocks to the per-device slice automatically
        ball, _ = ops.range_filter(
            qf, snap.rows.reshape(-1, snap.rows.shape[-1]),
            rf * (1.0 + _R_REL) + _BALL_ABS)
        return ball.astype(bool)

    out_sharded = P(None, axis)
    return (
        jax.jit(shard_map(plan_body, mesh=mesh,
                          in_specs=(rep, rep) + specs,
                          out_specs=(out_sharded, out_sharded),
                          check_rep=False)),
        jax.jit(shard_map(ball_body, mesh=mesh,
                          in_specs=(rep, rep) + specs,
                          out_specs=out_sharded, check_rep=False)),
    )


@functools.lru_cache(maxsize=64)
def _sharded_knn_loop(mesh: Mesh, axis: str, n_rings: int, specs: tuple,
                      k_eff: int, max_rounds: int):
    """Compiled cluster-sharded kNN rounds: the whole growing-radius
    schedule inside one ``shard_map``.

    Every per-round reduction stays a collective: candidate counts via
    ``psum``, the k-th distance via shard-local ``top_k`` merged with an
    ``all_gather`` of (B, min(k, P_local)·n_shards) blocks — the full
    (B, P) distance matrix is never gathered, for seeding or rounds
    (PR-2's seeding all-gathered it).  ``done``/radii stay replicated
    because every shard computes identical global reductions, so the
    loop needs no host round-trips at all; the certified masks come
    back cluster-sharded and reassemble through the out-spec.
    """
    rep = P()

    def body(qf, r0, *arrays):
        snap = _local_view(arrays)
        valid_l = snap.valid.reshape(-1)
        n_local = valid_l.shape[0]
        kl = min(k_eff, n_local)     # shard-local top-k width
        d2 = ops.pdist(qf, snap.rows.reshape(n_local, -1))
        d2 = jnp.where(valid_l[None], d2, jnp.inf)

        def merged_kth(dm):
            """Global k-th smallest of (B, P_local) per-shard values:
            local top-k, gather the (B, kl) blocks, re-select.  Unlike
            the single-device loop, ``lax.top_k`` is the fast selection
            here — XLA lowers it well on the shard-local operands, and
            the ``_smallest_k`` sweeps measure ~4× slower in this
            position (both were benchmarked; keep whichever wins)."""
            loc = -jax.lax.top_k(-dm, kl)[0]                 # (B, kl)
            allk = jax.lax.all_gather(loc, axis, axis=1,
                                      tiled=True)            # (B, kl·S)
            return -jax.lax.top_k(-allk, k_eff)[0][:, -1]

        kth0 = jnp.sqrt(jnp.maximum(merged_kth(d2), 0.0))
        # the sharded loop always filters on the exact f32 rows — the
        # lp plane is aux state the shard_map pipeline never ships, and
        # cross-shard reductions must agree on one plane — so eps is 0
        return _knn_rounds(
            qf, d2, kth0, r0, jnp.float32(0.0), snap, n_rings, k_eff,
            max_rounds,
            count_sum=lambda candb: jax.lax.psum(
                jnp.sum(candb, axis=1), axis),
            kth_select=merged_kth)

    return jax.jit(shard_map(body, mesh=mesh, in_specs=(rep, rep) + specs,
                             out_specs=(P(None, axis), P()),
                             check_rep=False))


def make_executor(snapshot: LIMSSnapshot, *, sharded: bool | None = None,
                  mesh: Mesh | None = None,
                  prefetch: str | None = None) -> QueryExecutor:
    """Executor factory: ``sharded=None`` auto-shards when the process
    sees more than one device (or a mesh is given), else stays on the
    plain single-device pipeline.  ``prefetch`` pins the paged backend's
    prefetch mode ("async"/"off"; None → ``REPRO_PREFETCH``)."""
    if sharded is None:
        sharded = mesh is not None or jax.device_count() > 1
    if sharded:
        return ShardedExecutor(snapshot, mesh=mesh, prefetch=prefetch)
    return QueryExecutor(snapshot, prefetch=prefetch)


__all__ = ["QueryExecutor", "ShardedExecutor", "make_executor"]
