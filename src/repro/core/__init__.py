"""LIMS core: the paper's contribution (learned metric-space index)."""
from .batched import BatchedLIMS
from .clustering import Clustering, kcenter, kmeans
from .index import LIMSIndex, QueryStats
from .kselect import KSelectResult, select_k
from .mapping import PivotMapping, build_mapping, lims_value, ring_of_rank
from .metrics import MetricSpace, cdist, dist_one_to_many
from .paging import PageStore
from .pivots import fft_pivots
from .rankmodel import (PolyRankModel, SearchStats, binary_search,
                        exponential_search)

__all__ = [
    "BatchedLIMS", "Clustering", "kcenter", "kmeans", "LIMSIndex",
    "QueryStats",
    "KSelectResult", "select_k", "PivotMapping", "build_mapping",
    "lims_value", "ring_of_rank", "MetricSpace", "cdist",
    "dist_one_to_many", "PageStore", "fft_pivots", "PolyRankModel",
    "SearchStats", "binary_search", "exponential_search",
]
