"""LIMS core: the paper's contribution (learned metric-space index).

The device serving stack is layered (DESIGN.md §1): ``LIMSSnapshot``
(immutable pytree) → ``QueryExecutor`` / ``ShardedExecutor`` (kernel
pipeline, optionally cluster-sharded) → ``ServingEngine`` (mutable
frontend with double-buffered refresh).  ``BatchedLIMS`` is the stable
one-shot shim over the first two layers.
"""
from .batched import BatchedLIMS
from .clustering import Clustering, kcenter, kmeans
from .executor import QueryExecutor, ShardedExecutor, make_executor
from .index import LIMSIndex, QueryStats
from .kselect import KSelectResult, select_k
from .mapping import PivotMapping, build_mapping, lims_value, ring_of_rank
from .metrics import MetricSpace, cdist, dist_one_to_many
from .paging import PageStore
from .pivots import fft_pivots
from .rankmodel import (PolyRankModel, SearchStats, binary_search,
                        exponential_search)
from .snapshot import LIMSSnapshot, maybe_paged


def __getattr__(name: str):
    # lazy: ServingEngine moved to repro.serving (repro.core.serving is
    # a shim); importing it eagerly here would cycle through the serving
    # package while this module is still initializing
    if name == "ServingEngine":
        from ..serving.engine import ServingEngine
        return ServingEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "BatchedLIMS", "Clustering", "kcenter", "kmeans", "LIMSIndex",
    "QueryStats", "LIMSSnapshot", "maybe_paged", "QueryExecutor",
    "ShardedExecutor", "make_executor", "ServingEngine",
    "KSelectResult", "select_k", "PivotMapping", "build_mapping",
    "lims_value", "ring_of_rank", "MetricSpace", "cdist",
    "dist_one_to_many", "PageStore", "fft_pivots", "PolyRankModel",
    "SearchStats", "binary_search", "exponential_search",
]
