"""LIMS: the learned index for exact similarity search in metric spaces.

Faithful implementation of the paper's index (Fig. 1) and query algorithms
(Alg. 1: range, Alg. 2: kNN, §5.1 point queries, §5.3 updates):

  build:  k-center clustering → FFT pivots per cluster → per-(cluster,pivot)
          sorted distance columns + degree-20 polynomial rank models →
          equal-count rings → LIMS values → rows stored in pages in LIMS
          order → degree-1 position model per cluster.
  query:  TriPrune → AreaLocate (models + exponential search) → IntervalGen
          (ring-ID box → LIMS-value intervals) → PosLocate (position model +
          exponential search → pages) → exact-distance refinement.

All results are exact; learned models only ever *accelerate* locating
ranks, never decide membership.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field

import numpy as np

from .clustering import Clustering, kcenter, kmeans
from .mapping import PivotMapping, build_mapping, lims_value, ring_of_rank
from .metrics import MetricSpace
from .paging import DEFAULT_PAGE_BYTES, PageStore
from .pivots import fft_pivots
from .rankmodel import PolyRankModel, SearchStats, binary_search, exponential_search

# retrain ``backend="auto"`` crossover: below this many member rows the
# host numpy rebuild beats the device builder's per-launch dispatch
# overhead (BENCH_build.json pins host ahead at ~125 and ~500
# rows/cluster on CPU; the compiled-lane crossover is re-measured by
# ``benchmarks/bench_build.py`` and recorded next to this constant's
# routing decisions)
RETRAIN_AUTO_ROWS = 4096


@dataclass
class QueryStats:
    pages: int = 0
    dist_comps: int = 0
    probes: int = 0
    model_calls: int = 0
    candidates: int = 0
    intervals: int = 0
    clusters_pruned: int = 0
    time_s: float = 0.0

    def __iadd__(self, o: "QueryStats") -> "QueryStats":
        for f in ("pages", "dist_comps", "probes", "model_calls",
                  "candidates", "intervals", "clusters_pruned", "time_s"):
            setattr(self, f, getattr(self, f) + getattr(o, f))
        return self


@dataclass
class ClusterIndex:
    cid: int
    pivot_idx: np.ndarray          # (m,) global indices of pivot objects
    pivot_rows: np.ndarray         # (m, ...) pivot payloads
    mapping: PivotMapping
    rank_models: list              # m PolyRankModels: distance -> rank
    pos_model: PolyRankModel       # LIMS value -> storage rank
    store: PageStore               # rows in ascending-LIMS order
    store_ids: np.ndarray          # (n_i,) global object id per stored row
    pivot_d_stored: np.ndarray     # (n_i, m) pivot distances, storage order
    # (n_i,) False where the stored row is tombstoned — kept in sync by
    # delete()/retrain_cluster() so nothing ever rescans the tombstone set
    live_mask: np.ndarray = field(default_factory=lambda: np.ones(0, bool))
    # --- update state (§5.3) ---
    buf_d: np.ndarray = field(default_factory=lambda: np.empty(0))
    buf_rows: list = field(default_factory=list)
    buf_ids: list = field(default_factory=list)
    # lazy python-list views: probe loops index python floats (~5x faster
    # than numpy scalar indexing; the probe counter is the portable metric)
    _d_lists: list | None = None
    _lims_list: list | None = None

    def d_list(self, j: int) -> list:
        if self._d_lists is None:
            self._d_lists = [col.tolist() for col in self.mapping.d_sorted]
        return self._d_lists[j]

    def lims_list(self) -> list:
        if self._lims_list is None:
            self._lims_list = self.mapping.lims_sorted.tolist()
        return self._lims_list

    @property
    def n(self) -> int:
        return len(self.store_ids)

    def nbytes(self) -> int:
        b = self.mapping.d_sorted.nbytes + self.mapping.lims_sorted.nbytes
        b += self.pivot_d_stored.nbytes + self.store_ids.nbytes
        b += self.live_mask.nbytes
        b += sum(m.nbytes() for m in self.rank_models) + self.pos_model.nbytes()
        b += self.mapping.dist_min.nbytes + self.mapping.dist_max.nbytes
        b += self.buf_d.nbytes + 8 * len(self.buf_ids)
        return int(b)


class LIMSIndex:
    """Exact metric similarity index (paper: LIMS). ``learned=False`` gives
    the N-LIMS ablation: identical structure/pages, binary search instead of
    model + exponential search.  ``backend="device"`` builds through the
    batched device pipeline in ``repro.build`` (DESIGN.md §6) — same
    structures, same exact results, heavy stages on the accelerator."""

    def __init__(self, space: MetricSpace, n_clusters: int | None = None,
                 m: int = 3, n_rings: int = 20, degree: int = 8,
                 pos_degree: int = 8, page_bytes: int = DEFAULT_PAGE_BYTES,
                 seed: int = 0, clusterer: str = "kcenter",
                 learned: bool = True, max_intervals: int = 4096,
                 backend: str = "host"):
        t0 = time.perf_counter()
        self.space = space
        self.m = m
        self.n_rings = n_rings
        self.degree = degree
        self.pos_degree = pos_degree
        self.page_bytes = page_bytes
        self.learned = learned
        self.max_intervals = max_intervals
        self.backend = backend
        # backend the most recent retrain_cluster actually ran with
        # (records "auto"'s routing decision; None before any retrain)
        self.last_retrain_backend: str | None = None
        n = space.n

        if n_clusters is None:
            from .kselect import select_k
            grid = [k for k in (8, 16, 32, 64, 128) if k <= max(2, n // 4)] or [1]
            n_clusters = select_k(space, grid, m=m, seed=seed).best_k
        self.K = min(n_clusters, n)

        # ``backend="device"`` runs clustering, pivot selection and every
        # model fit on device (repro.build); the host structures below are
        # then materialized from its output with all exactness-bearing
        # quantities (columns, extents, ring boundaries) recomputed in f64
        # (DESIGN.md §6).
        prebuilt = None
        if backend == "device":
            from ..build.builder import device_build
            prebuilt = device_build(
                space, self.K, m=m, n_rings=n_rings, degree=degree,
                pos_degree=pos_degree, seed=seed, clusterer=clusterer,
                learned=learned)
            self.clustering: Clustering = prebuilt.clustering
            self.device_build_timings = dict(prebuilt.timings)
        elif backend != "host":
            raise ValueError(f"unknown build backend {backend!r}")
        elif clusterer == "kcenter":
            self.clustering = kcenter(space, self.K, seed=seed)
        elif clusterer == "kmeans":
            self.clustering = kmeans(space, self.K, seed=seed)
        else:
            raise ValueError(clusterer)
        self.K = self.clustering.k

        self.clusters: list[ClusterIndex] = []
        for c in range(self.K):
            self.clusters.append(self._build_cluster(c, prebuilt=prebuilt))
        self.tombstones: set[int] = set()
        # payloads of inserted objects (gid >= space.n): ``space.data``
        # only covers build-time rows, so retrains must look rows that a
        # previous retrain folded out of the buffer up here
        self.inserted_rows: dict[int, np.ndarray] = {}
        self._live = n
        self._next_id = n
        self.build_time_s = time.perf_counter() - t0
        # data-driven default kNN radius step: median ring width (§5.2)
        widths = [(ci.mapping.dist_max[j] - ci.mapping.dist_min[j]) / max(n_rings, 1)
                  for ci in self.clusters for j in range(self.m) if ci.n > 1]
        self.default_delta_r = 2.0 * float(np.median(widths)) if widths else 1.0

    # ------------------------------------------------------------------ build
    def _build_cluster(self, c: int, prebuilt=None) -> ClusterIndex:
        """Build one cluster's host structures.  ``prebuilt`` (a
        ``repro.build.DeviceBuildResult``) supplies device-chosen pivots
        and device-fit models; the pivot-distance columns, mapping and
        extents are recomputed here in exact f64 either way — that is
        what keeps the device build path exact (DESIGN.md §6)."""
        space, m = self.space, self.m
        mem = self.clustering.members[c]
        d1 = self.clustering.dist_to_center[mem]
        if prebuilt is None:
            centroid = int(self.clustering.center_idx[c])
            piv = fft_pivots(space, mem, centroid, m, d1)
        else:
            piv = prebuilt.pivot_gids[c]
        pivot_d = np.empty((len(mem), m), dtype=np.float64)
        pivot_d[:, 0] = d1
        for j in range(1, m):
            if piv[j] == piv[0]:
                pivot_d[:, j] = d1
            else:
                pivot_d[:, j] = space.dist(space.data[piv[j]], mem)
        mapping = build_mapping(pivot_d, self.n_rings)
        if prebuilt is None:
            deg = self.degree if self.learned else 1
            rank_models = [PolyRankModel.fit(mapping.d_sorted[j], deg)
                           for j in range(m)]
            pos_model = PolyRankModel.fit(
                mapping.lims_sorted.astype(np.float64), self.pos_degree)
        else:
            rank_models = prebuilt.rank_models[c]
            pos_model = prebuilt.pos_models[c]
        order = mapping.order
        rows = space.data[mem[order]]
        store = PageStore(rows, record_bytes=space.record_nbytes(),
                          page_bytes=self.page_bytes)
        return ClusterIndex(
            cid=c, pivot_idx=piv, pivot_rows=space.data[piv].copy(),
            mapping=mapping, rank_models=rank_models, pos_model=pos_model,
            store=store, store_ids=np.asarray(mem[order], dtype=np.int64),
            pivot_d_stored=pivot_d[order],
            live_mask=np.ones(len(mem), bool),
        )

    # ------------------------------------------------------------- rank locate
    def _locate(self, ci: ClusterIndex, arr: np.ndarray, x: float, side: str,
                model: PolyRankModel, st: QueryStats) -> int:
        ss = SearchStats()
        if self.learned:
            guess = model.predict_scalar(x)
            st.model_calls += 1
            pos = exponential_search(arr, x, guess, side=side, stats=ss)
        else:
            pos = binary_search(arr, x, side=side, stats=ss)
        st.probes += ss.probes
        return pos

    # ------------------------------------------------------------ range query
    def range_query(self, q: np.ndarray, r: float,
                    visited: dict | None = None,
                    collect: str = "filtered"):
        """Alg. 1. Returns (ids, dists, stats).

        ``visited``: {cid: set(page_id)} shared across calls (kNN reuse).
        ``collect``: 'filtered' → only results with d<=r; 'all' → every
        refined candidate (kNN needs candidates beyond r).
        """
        st = QueryStats()
        t0 = time.perf_counter()
        out_ids: list[int] = []
        out_d: list[float] = []
        if visited is None:
            visited = {}   # always dedupe page fetches within one query

        # --- TriPrune: one batched q→all-pivots distance evaluation -------
        piv_rows = np.concatenate([ci.pivot_rows for ci in self.clusters], axis=0)
        dq = self._dist_rows(q, piv_rows, st).reshape(self.K, self.m)
        for ci in self.clusters:
            dmin, dmax = ci.mapping.dist_min, ci.mapping.dist_max
            dqv = dq[ci.cid]
            alive = ci.n > 0 and bool(
                np.all(dqv <= dmax + r) and np.all(dqv >= dmin - r))
            if not alive:
                st.clusters_pruned += 1
            else:
                self._search_cluster(ci, q, dqv, r, st, visited, out_ids, out_d,
                                     collect)
            # insert buffer is outside the ring structure: always check
            self._search_buffer(ci, q, dqv[0], r, st, out_ids, out_d, collect)

        ids = np.asarray(out_ids, dtype=np.int64)
        ds = np.asarray(out_d, dtype=np.float64)
        if collect == "filtered":
            keep = ds <= r
            ids, ds = ids[keep], ds[keep]
        st.time_s = time.perf_counter() - t0
        return ids, ds, st

    def _search_cluster(self, ci: ClusterIndex, q, dqv, r, st: QueryStats,
                        visited, out_ids, out_d, collect) -> None:
        m, N = self.m, self.n_rings
        n = ci.n
        rid_min = np.empty(m, dtype=np.int64)
        rid_max = np.empty(m, dtype=np.int64)
        # --- AreaLocate ---------------------------------------------------
        for j in range(m):
            r_min = max(dqv[j] - r, ci.mapping.dist_min[j])
            r_max = min(dqv[j] + r, ci.mapping.dist_max[j])
            if r_min > r_max:
                return
            col = ci.d_list(j)
            lo = self._locate(ci, col, r_min, "left", ci.rank_models[j], st)
            hi = self._locate(ci, col, r_max, "right", ci.rank_models[j], st) - 1
            if hi < lo:
                return
            rid_min[j] = ring_of_rank(lo, n, N)
            rid_max[j] = ring_of_rank(hi, n, N)
        # --- IntervalGen: ring-ID box → LIMS-value intervals ---------------
        n_prefix = int(np.prod((rid_max - rid_min + 1)[:-1])) if m > 1 else 1
        intervals: list[tuple[int, int]] = []
        if n_prefix > self.max_intervals:
            # exact fallback: one covering interval (superset; refine fixes)
            intervals.append((int(lims_value(rid_min, N)),
                              int(lims_value(rid_max, N))))
        else:
            ranges = [range(int(rid_min[j]), int(rid_max[j]) + 1)
                      for j in range(m - 1)]
            lo_last, hi_last = int(rid_min[-1]), int(rid_max[-1])
            for prefix in itertools.product(*ranges):
                base = 0
                for j, p in enumerate(prefix):
                    base = base * N + p
                base *= N
                lo_v, hi_v = base + lo_last, base + hi_last
                # merge with previous interval when contiguous in LIMS space
                # (adjacent prefixes with ring-spanning last dim): exact, and
                # collapses O(prod |L_j|) locates into few.
                if intervals and lo_v <= intervals[-1][1] + 1:
                    intervals[-1] = (intervals[-1][0], hi_v)
                else:
                    intervals.append((lo_v, hi_v))
        st.intervals += len(intervals)
        # --- PosLocate + fetch + refine ------------------------------------
        vis = None
        if visited is not None:
            vis = visited.setdefault(ci.cid, set())
        lims_sorted = ci.lims_list()
        for lo_v, hi_v in intervals:
            lb = self._locate(ci, lims_sorted, lo_v, "left", ci.pos_model, st)
            ub = self._locate(ci, lims_sorted, hi_v, "right", ci.pos_model, st) - 1
            if ub < lb:
                continue
            pages = ci.store.page_range(lb, ub)
            before = ci.store.page_accesses
            idx, rows = ci.store.fetch_pages(pages, vis)
            st.pages += ci.store.page_accesses - before
            if len(idx) == 0:
                continue
            d = self._dist_rows(q, rows, st)
            st.candidates += len(idx)
            for row_i, dist in zip(idx, d):
                gid = int(ci.store_ids[row_i])
                if gid in self.tombstones:
                    continue
                if collect == "all" or dist <= r:
                    out_ids.append(gid)
                    out_d.append(float(dist))

    def _search_buffer(self, ci: ClusterIndex, q, d_q_centroid, r,
                       st: QueryStats, out_ids, out_d, collect) -> None:
        nb = len(ci.buf_ids)
        if nb == 0:
            return
        lo = np.searchsorted(ci.buf_d, d_q_centroid - r, side="left")
        hi = np.searchsorted(ci.buf_d, d_q_centroid + r, side="right")
        st.probes += max(1, int(np.ceil(np.log2(nb + 1)))) * 2
        if hi <= lo:
            return
        rows = np.stack([ci.buf_rows[i] for i in range(lo, hi)])
        st.pages += -(-len(rows) // ci.store.omega)
        d = self._dist_rows(q, rows, st)
        st.candidates += len(rows)
        for i, dist in zip(range(lo, hi), d):
            gid = ci.buf_ids[i]
            if gid in self.tombstones:
                continue
            if collect == "all" or dist <= r:
                out_ids.append(gid)
                out_d.append(float(dist))

    # ------------------------------------------------------------- point query
    def point_query(self, q: np.ndarray):
        """§5.1: k-center property prunes K-1 clusters; search nearest only."""
        st = QueryStats()
        t0 = time.perf_counter()
        piv_rows = np.concatenate([ci.pivot_rows for ci in self.clusters], axis=0)
        dq = self._dist_rows(q, piv_rows, st).reshape(self.K, self.m)
        order = np.argsort(dq[:, 0])
        out_ids: list[int] = []
        out_d: list[float] = []
        # identical objects can sit in a different cluster only if equidistant
        # centroids were tie-broken differently; scan clusters whose centroid
        # distance equals the minimum (exactness), typically just one.
        best = dq[order[0], 0]
        visited: dict = {}
        for c in order:
            if dq[c, 0] > best:
                break
            ci = self.clusters[c]
            if ci.n > 0 and np.all(dq[c] <= ci.mapping.dist_max) and \
               np.all(dq[c] >= ci.mapping.dist_min):
                self._search_cluster(ci, q, dq[c], 0.0, st, visited,
                                     out_ids, out_d, "filtered")
            self._search_buffer(ci, q, dq[c, 0], 0.0, st, out_ids, out_d,
                                "filtered")
        ids = np.asarray(out_ids, dtype=np.int64)
        ds = np.asarray(out_d, dtype=np.float64)
        keep = ds <= 0.0
        st.time_s = time.perf_counter() - t0
        return ids[keep], st

    # --------------------------------------------------------------- kNN query
    def live_count(self) -> int:
        """Objects that a query can return: stored + buffered − tombstoned.
        Maintained incrementally by insert/delete — O(1) on the query path."""
        return self._live

    def knn_query(self, q: np.ndarray, k: int, delta_r: float | None = None):
        """Alg. 2: growing-radius range queries, never re-reading pages.

        ``k`` is clamped to the number of live objects — asking for more
        neighbours than the index holds returns them all (previously the
        radius loop could never satisfy ``k`` and ran forever).
        """
        st = QueryStats()
        t0 = time.perf_counter()
        k = min(int(k), self.live_count())
        if k <= 0:
            return (np.empty(0, np.int64), np.empty(0), st)
        dr = float(delta_r) if delta_r is not None else self.default_delta_r
        visited: dict = {}
        heap_d = np.full(k, np.inf)
        heap_id = np.full(k, -1, dtype=np.int64)
        r, flag = 0.0, False
        while not flag:
            r += dr
            if heap_d[-1] < r:        # furthest candidate inside radius
                flag = True
            ids, ds, st_i = self.range_query(q, r, visited=visited,
                                             collect="all")
            st += st_i
            if len(ids):
                cat_d = np.concatenate([heap_d, ds])
                cat_i = np.concatenate([heap_id, ids])
                # dedupe by id, keep best distance
                uniq, ui = np.unique(cat_i, return_index=True)
                keep = ui[uniq >= 0] if (uniq >= 0).any() else ui
                cat_d, cat_i = cat_d[keep], cat_i[keep]
                pad = k - len(cat_d)
                if pad > 0:
                    cat_d = np.concatenate([cat_d, np.full(pad, np.inf)])
                    cat_i = np.concatenate([cat_i, np.full(pad, -1, np.int64)])
                sel = np.argsort(cat_d, kind="stable")[:k]
                heap_d, heap_id = cat_d[sel], cat_i[sel]
        st.time_s = time.perf_counter() - t0
        got = heap_id >= 0
        return heap_id[got], heap_d[got], st

    # ----------------------------------------------------------------- updates
    def insert(self, p: np.ndarray) -> int:
        """§5.3: append to the nearest cluster's sorted insert buffer."""
        st = QueryStats()
        cents = np.stack([ci.pivot_rows[0] for ci in self.clusters])
        d = self._dist_rows(p, cents, st)
        c = int(np.argmin(d))
        ci = self.clusters[c]
        pos = int(np.searchsorted(ci.buf_d, d[c]))
        row = np.array(p, copy=True)
        ci.buf_d = np.insert(ci.buf_d, pos, d[c])
        ci.buf_rows.insert(pos, row)
        ci.buf_ids.insert(pos, self._next_id)
        gid = self._next_id
        self.inserted_rows[gid] = row
        self._live += 1
        self._next_id += 1
        return gid

    def delete(self, q: np.ndarray) -> int:
        """Point query → tombstone; refresh the cluster's dist_min/max."""
        ids, _ = self.point_query(q)
        removed = 0
        for gid in ids:
            gid = int(gid)
            if gid in self.tombstones:
                continue
            self.tombstones.add(gid)
            removed += 1
            for ci in self.clusters:
                hit = np.where(ci.store_ids == gid)[0]
                if len(hit):
                    # incremental live mask: O(n) per delete, not
                    # O(n·|tombstones|) via an isin rebuild
                    ci.live_mask[hit] = False
                    if ci.live_mask.any():
                        pd = ci.pivot_d_stored[ci.live_mask]
                        ci.mapping.dist_min = pd.min(axis=0)
                        ci.mapping.dist_max = pd.max(axis=0)
                    break
        self._live -= removed
        return removed

    def retrain_cluster(self, c: int, backend: str | None = None) -> None:
        """Partial reconstruction (§5.3): rebuild one cluster's index,
        folding its insert buffer in and dropping tombstones.

        ``backend="device"`` routes pivot selection and model fitting
        through the device builder (``repro.build.retrain_device``); the
        pivot-distance matrix, mapping and extents are recomputed in
        exact f64 either way, so results stay exact (DESIGN.md §6).
        ``"auto"`` routes on the member row count: the host numpy
        rebuild wins below ``RETRAIN_AUTO_ROWS`` rows, where device
        dispatch overhead dominates (the crossover is measured in
        ``benchmarks/bench_build.py`` → ``BENCH_build.json``); custom /
        non-vector metrics and the interpret kernel lane always take
        the host path (the device builder can't serve them / only costs
        there).  The chosen backend lands in ``last_retrain_backend``.
        ``None`` uses the backend the index was built with.
        """
        backend = self.backend if backend is None else backend
        if backend not in ("host", "device", "auto"):
            raise ValueError(f"unknown build backend {backend!r}")
        ci = self.clusters[c]
        live = [int(g) for g in ci.store_ids if g not in self.tombstones]
        # build-time rows come from space.data; rows a previous retrain
        # folded in (gid >= space.n) come from the inserted-payload map —
        # without it they mapped to nothing and were silently dropped
        all_rows = [self.space.data[g] if g < self.space.n
                    else self.inserted_rows[g] for g in live]
        all_ids = list(live)
        for gid, row in zip(ci.buf_ids, ci.buf_rows):
            if gid not in self.tombstones:
                all_rows.append(row)
                all_ids.append(gid)
        if not all_rows:
            return
        if backend == "auto":
            from ..kernels.dispatch import default_interpret
            device_ok = (self.space._custom is None and self.space.is_vector
                         and not default_interpret())
            backend = "device" if device_ok and \
                len(all_rows) >= RETRAIN_AUTO_ROWS else "host"
        self.last_retrain_backend = backend
        sub = MetricSpace(np.stack(all_rows), self.space.metric,
                          self.space._custom)
        deg = self.degree if self.learned else 1
        if backend == "device":
            from ..build.builder import retrain_device
            piv_rows, pivot_d, ci.rank_models, ci.pos_model = retrain_device(
                sub, ci.pivot_rows[0], self.m, self.n_rings, deg,
                self.pos_degree)
            mapping = build_mapping(pivot_d, self.n_rings)
        else:
            # single-cluster LIMS over the member set, centroid = pivot 0
            mem = np.arange(sub.n)
            d1 = sub.dist(ci.pivot_rows[0], mem)
            piv_rows = [ci.pivot_rows[0]]
            pivot_d = np.empty((sub.n, self.m))
            pivot_d[:, 0] = d1
            d_near = d1.copy()
            for j in range(1, self.m):
                nxt = int(np.argmax(d_near))
                piv_rows.append(sub.data[nxt])
                dj = sub.dist(sub.data[nxt], mem)
                pivot_d[:, j] = dj
                d_near = np.minimum(d_near, dj)
            mapping = build_mapping(pivot_d, self.n_rings)
            ci.rank_models = [PolyRankModel.fit(mapping.d_sorted[j], deg)
                              for j in range(self.m)]
            ci.pos_model = PolyRankModel.fit(
                mapping.lims_sorted.astype(np.float64), self.pos_degree)
        order = mapping.order
        ci.mapping = mapping
        ci.pivot_rows = np.stack(piv_rows)
        ci.store = PageStore(sub.data[order], record_bytes=sub.record_nbytes(),
                             page_bytes=self.page_bytes)
        ci.store_ids = np.asarray([all_ids[i] for i in order], dtype=np.int64)
        ci.pivot_d_stored = pivot_d[order]
        ci.live_mask = np.ones(sub.n, bool)
        ci.buf_d = np.empty(0)
        ci.buf_rows, ci.buf_ids = [], []
        ci._d_lists = None
        ci._lims_list = None
        # tombstoned inserts can never resurface: free their payloads
        for g in set(self.inserted_rows) & self.tombstones:
            del self.inserted_rows[g]

    # ------------------------------------------------------------------ helpers
    def _dist_rows(self, q, rows, st: QueryStats) -> np.ndarray:
        st.dist_comps += len(rows)
        if self.space._custom is not None:
            return np.asarray([self.space._custom(q, row) for row in rows])
        from .metrics import dist_one_to_many
        return dist_one_to_many(q, rows, self.space.metric)

    def index_nbytes(self) -> int:
        return int(sum(ci.nbytes() for ci in self.clusters))

    def data_nbytes(self) -> int:
        return int(sum(ci.store.nbytes() for ci in self.clusters))

    def reset_page_counters(self) -> None:
        for ci in self.clusters:
            ci.store.reset_counters()

    def spill(self, path: str, page_bytes: int | None = None):
        """Spill this index's serving snapshot to a paged store directory
        (DESIGN.md §7): rows laid out in learned-position page extents
        plus the snapshot metadata, ready for store-backed execution or
        cold-start serving (``ServingEngine.from_spill``).  Defaults to
        the index's own page size so the on-disk geometry matches the
        host ``PageStore`` accounting.  Returns the store manifest."""
        from .snapshot import LIMSSnapshot
        pb = self.page_bytes if page_bytes is None else page_bytes
        return LIMSSnapshot.build(self).spill(path, page_bytes=pb)
