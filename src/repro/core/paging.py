"""Page store: the disk emulation layer.

The paper is a *disk-based* index evaluated on page accesses with 4 KB
pages. On TPU the same role is played by fixed-size HBM row tiles; the
accounting is identical, so one implementation serves both stories. Rows
are stored in index order (ascending LIMS value per cluster); a page holds
``omega`` records and the store counts unique page fetches per query.
"""
from __future__ import annotations

import numpy as np

DEFAULT_PAGE_BYTES = 4096


class PageStore:
    """Rows laid out sequentially in pages of ``omega`` records."""

    def __init__(self, rows: np.ndarray, record_bytes: int | None = None,
                 page_bytes: int = DEFAULT_PAGE_BYTES):
        self.rows = rows
        rb = record_bytes if record_bytes is not None else rows[0].nbytes
        self.omega = max(1, page_bytes // max(1, rb))
        self.n = rows.shape[0]
        self.n_pages = -(-self.n // self.omega)
        self.page_accesses = 0          # cumulative, across queries
        self.rows_fetched = 0

    def reset_counters(self) -> None:
        self.page_accesses = 0
        self.rows_fetched = 0

    def page_range(self, lo_row: int, hi_row: int) -> range:
        """Pages covering rows [lo_row, hi_row] inclusive."""
        if hi_row < lo_row:
            return range(0)
        return range(lo_row // self.omega, hi_row // self.omega + 1)

    def fetch_pages(self, page_ids, visited: set | None = None):
        """Return (row_indices, rows) for all unvisited pages; count I/O.

        ``visited`` is the caller-held per-query (or per-kNN-search) set —
        Algorithm 2 in the paper relies on skipping already-read pages
        across radius expansions.
        """
        new_pages = []
        for pid in page_ids:
            if pid < 0 or pid >= self.n_pages:
                continue
            if visited is not None:
                if pid in visited:
                    continue
                visited.add(pid)
            new_pages.append(pid)
        self.page_accesses += len(new_pages)
        if not new_pages:
            return np.empty(0, np.int64), self.rows[:0]
        idx = np.concatenate(
            [np.arange(p * self.omega, min((p + 1) * self.omega, self.n))
             for p in new_pages])
        self.rows_fetched += len(idx)
        return idx, self.rows[idx]

    def nbytes(self) -> int:
        return int(self.rows.nbytes)
