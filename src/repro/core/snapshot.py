"""Layer 1 of the serving stack: the immutable device snapshot.

``LIMSSnapshot`` is a pure pytree of padded, cluster-major arrays built
from a host ``LIMSIndex`` — no query logic lives here (that is layer 2,
``repro.core.executor``; the mutable serving frontend is layer 3,
``repro.core.serving``; see DESIGN.md §1 for the stack).

Everything a query needs is laid out per cluster, padded to a common
``n_max`` so the whole corpus is one rectangular block:

  rows    (K, n_max, d)  f32   ring-ordered store rows, then §5.3 insert-
                               buffer rows, then invalid padding slots
  rids    (K, n_max, m)  i32   ring id per (row, pivot); -1 on non-ring slots
  pivots  (K, m, d)      f32   pivot payloads
  dmin/dmax (K, m)       f32   per-pivot distance extents (TriPrune)
  width   (K,)           i32   ring width ceil(n/N)
  ns      (K,)           i32   stored-row count per cluster
  valid / in_ring / always (K, n_max) bool
                               live slots / ring-structured slots / slots
                               that bypass the ring box (insert buffers)
  coef    (K, m, C)      f32   Chebyshev rank-model tables (one row per
                               (cluster, pivot) group)
  model_lo/hi/n (K, m)   f32   per-group domain + train count
  rank_err (K, m)        f32   certified rank-error bound E (DESIGN.md §3)

The cluster-major (K-leading) layout is what makes cluster-granular
sharding free: a ``ShardedExecutor`` splits every device array on axis 0
and each shard is a self-contained snapshot of K/ndev clusters (pivot
tables stay valid under partition — pruning and rank models are purely
per-cluster, so exactness survives sharding; DESIGN.md §4).

Host-side refinement data (``gids_np``, ``rows_np`` in f64, ``valid_np``)
rides along as aux so the final exact-distance refinement never round-trips
through f32 device memory.

Two-plane row layout (DESIGN.md §13): next to the f32 ``rows`` plane the
snapshot can carry an optional reduced-precision copy ``rows_lp``
(bf16/f16, ``REPRO_ROWS_DTYPE``, default off) used *only* for first-pass
distance filtering.  Its certified companion ``lp_eps`` is the exact
quantization margin max_x ‖x_f32 − x_lp‖ (computed in f64 at build): by
the triangle inequality every low-precision distance satisfies
|d_lp(q, x) − d(q, x)| ≤ lp_eps, so a filter radius widened by lp_eps
admits every true result and a kNN certification radius tightened by
lp_eps never certifies early — the same certified-superset pattern as
the rank-error bound E below, with the exact f32/f64 refinement keeping
final results bit-identical.  With the plane off, ``lp_eps = 0.0`` and
every threshold expression reduces to today's bitwise-identical form.

Exactness with learned models on device: the host corrects model error
with exponential search; fixed-shape device code cannot branch per value,
so the snapshot instead *certifies* a per-(cluster, pivot) rank-error
bound E and widens the predicted ring box by it.  E is computed at build
by running the actual ``rankeval`` kernel over the group's own sorted
column (max observed error at the data points) plus a Chebyshev
derivative bound ``D = Σ k²|c_k|`` times the largest inter-point gap in
normalized t-space (the polynomial cannot wiggle more than that between
samples), plus slack for rint/f32.  The widened box is therefore a
guaranteed superset of the host's exact rid box, and the final f64
refinement removes every extra candidate — results are bit-identical to
``LIMSIndex``.  The full argument is DESIGN.md §3.
"""
from __future__ import annotations

import shutil
import tempfile
import weakref
from dataclasses import dataclass, fields, replace

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.dispatch import rows_dtype
from ..storage import (DEFAULT_CACHE_PAGES, DEFAULT_PAGE_BYTES, PagedStore,
                       StoreView, load_meta, spill_rows, storage_mode)
from .index import LIMSIndex

_E_SLACK = 2.0      # ranks: rint (±0.5 twice) + f32 eval slop

# device-array fields, in flatten order (pytree children)
_DEVICE_FIELDS = (
    "rows", "rids", "pivots", "dmin", "dmax", "width", "ns",
    "valid", "in_ring", "always",
    "coef", "model_lo", "model_hi", "model_n", "rank_err",
)
# static / host-side fields (pytree aux; the optional low-precision
# plane rides as aux, not a child — its presence must not change the
# pytree structure the sharded executor's cached shard_map builders key
# on, and the sharded/paged paths never read it)
_AUX_FIELDS = ("K", "m", "n_rings", "n_max", "live",
               "gids_np", "rows_np", "valid_np", "store",
               "rows_lp", "lp_eps")
# everything spilled to the store's metadata file (rows go to pages.bin)
_SPILL_FIELDS = tuple(f for f in _DEVICE_FIELDS if f != "rows")


@dataclass(frozen=True)
class LIMSSnapshot:
    """Immutable snapshot of one ``LIMSIndex`` (vector metrics, L2)."""

    # static metadata
    K: int
    m: int
    n_rings: int
    n_max: int
    live: int
    # device arrays (cluster-major; see module docstring for shapes)
    rows: jax.Array
    rids: jax.Array
    pivots: jax.Array
    dmin: jax.Array
    dmax: jax.Array
    width: jax.Array
    ns: jax.Array
    valid: jax.Array
    in_ring: jax.Array
    always: jax.Array
    coef: jax.Array
    model_lo: jax.Array
    model_hi: jax.Array
    model_n: jax.Array
    rank_err: jax.Array
    # host-side refinement data (f64 / int64, flat (K·n_max, …))
    gids_np: np.ndarray
    rows_np: np.ndarray
    valid_np: np.ndarray
    # paged storage tier (DESIGN.md §7): when set, row payloads live on
    # disk — ``rows``/``rows_np`` are empty placeholders and the executor
    # fetches candidate pages through this store view (the shared reader
    # bound to THIS snapshot's generation layout, so a later writeback
    # can never remap an in-flight batch's slots)
    store: StoreView | None = None
    # reduced-precision filter plane (DESIGN.md §13): bf16/f16 copy of
    # ``rows`` plus its certified quantization margin; None/0.0 when
    # disabled (``REPRO_ROWS_DTYPE``, the default)
    rows_lp: jax.Array | None = None
    lp_eps: float = 0.0

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        children = tuple(getattr(self, f) for f in _DEVICE_FIELDS)
        aux = tuple(getattr(self, f) for f in _AUX_FIELDS)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(**dict(zip(_AUX_FIELDS, aux)),
                   **dict(zip(_DEVICE_FIELDS, children)))

    @property
    def n_slots(self) -> int:
        """Total padded slot count P = K · n_max (the candidate axis)."""
        return self.K * self.n_max

    @property
    def d(self) -> int:
        return self.rows.shape[-1]

    def filter_rows(self) -> tuple[jax.Array, float]:
        """(row plane, certified margin) for first-pass distance
        filtering: the low-precision plane with its quantization margin
        when present, else the f32 plane with margin 0.0 — callers add
        the margin to filter radii unconditionally (+0.0 is an f32/f64
        identity, so the disabled path stays bitwise identical)."""
        if self.rows_lp is not None:
            return self.rows_lp, self.lp_eps
        return self.rows, 0.0

    # -------------------------------------------------------------- build
    @classmethod
    def build(cls, index: LIMSIndex) -> "LIMSSnapshot":
        assert index.space.metric == "l2", "device path: L2 (MXU kernel)"
        K, m = index.K, index.m
        d = index.space.data.shape[1]
        dead = index.tombstones

        n_slots = [ci.n + len(ci.buf_ids) for ci in index.clusters]
        n_max = max(max(n_slots), 1)
        rows = np.zeros((K, n_max, d), np.float32)
        rows64 = np.zeros((K, n_max, d), np.float64)
        rids = np.full((K, n_max, m), -1, np.int32)
        pivots = np.zeros((K, m, d), np.float32)
        dmin = np.zeros((K, m), np.float32)
        dmax = np.zeros((K, m), np.float32)
        width = np.ones((K,), np.int32)
        gids = np.full((K, n_max), -1, np.int64)
        valid = np.zeros((K, n_max), bool)
        in_ring = np.zeros((K, n_max), bool)
        for ci in index.clusters:
            k, n, nb = ci.cid, ci.n, len(ci.buf_ids)
            pivots[k] = ci.pivot_rows
            if n:
                rows[k, :n] = ci.store.rows
                rows64[k, :n] = ci.store.rows
                rids[k, :n] = ci.mapping.rids[ci.mapping.order]
                dmin[k] = ci.mapping.dist_min
                dmax[k] = ci.mapping.dist_max
                width[k] = max(1, -(-n // index.n_rings))
                gids[k, :n] = ci.store_ids
                in_ring[k, :n] = True
                valid[k, :n] = ci.live_mask
            if nb:
                buf = np.stack(ci.buf_rows)
                rows[k, n:n + nb] = buf
                rows64[k, n:n + nb] = buf
                gids[k, n:n + nb] = ci.buf_ids
                valid[k, n:n + nb] = [g not in dead for g in ci.buf_ids]
        coef, lo, hi, n_model, err = _certified_rank_table(index)
        rows_dev = jnp.asarray(rows)
        rows_lp, lp_eps = _lp_plane(rows_dev)
        return cls(
            K=K, m=m, n_rings=index.n_rings, n_max=n_max,
            live=int(valid.sum()),
            rows_lp=rows_lp, lp_eps=lp_eps,
            rows=rows_dev,
            rids=jnp.asarray(rids),
            pivots=jnp.asarray(pivots),
            dmin=jnp.asarray(dmin),
            dmax=jnp.asarray(dmax),
            width=jnp.asarray(width),
            ns=jnp.asarray(
                np.array([ci.n for ci in index.clusters], np.int32)),
            valid=jnp.asarray(valid),
            in_ring=jnp.asarray(in_ring),
            always=jnp.asarray(valid & ~in_ring),
            coef=jnp.asarray(coef.reshape(K, m, -1)),
            model_lo=jnp.asarray(lo.reshape(K, m)),
            model_hi=jnp.asarray(hi.reshape(K, m)),
            model_n=jnp.asarray(n_model.reshape(K, m)),
            rank_err=jnp.asarray(err.reshape(K, m), jnp.float32),
            gids_np=gids.reshape(-1),
            rows_np=rows64.reshape(K * n_max, d),
            valid_np=valid.reshape(-1),
        )

    # ------------------------------------------------------- shard padding
    def pad_clusters(self, K_new: int) -> "LIMSSnapshot":
        """Pad with inert clusters so K divides a shard count.

        Padding clusters have ``ns = 0`` (TriPrune never wakes them) and
        all-False validity masks, so they contribute no candidates; the
        host-side arrays get matching -1-id / dead slots so the flat
        candidate axis stays aligned with the device mask.  Pure — returns
        a new snapshot, ``self`` is untouched.
        """
        if K_new == self.K:
            return self
        assert K_new > self.K
        pk = K_new - self.K

        def dev(name, fill):
            a = getattr(self, name)
            widths = [(0, pk)] + [(0, 0)] * (a.ndim - 1)
            return jnp.pad(a, widths, constant_values=fill)

        nm = self.n_max
        lp = self.rows_lp
        if lp is not None:
            # zero padding quantizes exactly, so the margin is unchanged
            lp = jnp.pad(lp, [(0, pk), (0, 0), (0, 0)])
        return replace(
            self, K=K_new, rows_lp=lp,
            rows=dev("rows", 0.0), rids=dev("rids", -1),
            pivots=dev("pivots", 0.0),
            dmin=dev("dmin", 0.0), dmax=dev("dmax", 0.0),
            # width 1 / model_hi 1 keep the (masked-out) padded groups'
            # arithmetic finite — no /0 inside the kernels
            width=dev("width", 1), ns=dev("ns", 0),
            valid=dev("valid", False), in_ring=dev("in_ring", False),
            always=dev("always", False),
            coef=dev("coef", 0.0), model_lo=dev("model_lo", 0.0),
            model_hi=dev("model_hi", 1.0), model_n=dev("model_n", 0.0),
            rank_err=dev("rank_err", 0.0),
            gids_np=np.concatenate(
                [self.gids_np, np.full(pk * nm, -1, np.int64)]),
            rows_np=np.concatenate(
                [self.rows_np, np.zeros((pk * nm, self.d), np.float64)]),
            valid_np=np.concatenate(
                [self.valid_np, np.zeros(pk * nm, bool)]),
        )

    # ------------------------------------------------------ paged storage
    def spill(self, path: str, page_bytes: int = DEFAULT_PAGE_BYTES):
        """Spill to a paged store directory (DESIGN.md §7): rows land in
        cluster-major page extents (mapped-value order), every other
        array in the generation's metadata file, published by one atomic
        manifest swap.  Incremental over an existing store — clusters
        with unchanged row bytes keep their extents.  Returns the new
        manifest; ``self`` is untouched.
        """
        K, n_max, d = self.K, self.n_max, self.d
        assert self.rows_np.shape == (K * n_max, d), \
            "spill needs a resident snapshot (store-backed rows are on disk)"
        meta = {f: np.asarray(getattr(self, f)) for f in _SPILL_FIELDS}
        meta.update(
            gids_np=self.gids_np, valid_np=self.valid_np,
            scalars=np.asarray(
                [self.K, self.m, self.n_rings, self.n_max, self.live],
                np.int64))
        return spill_rows(path, self.rows_np.reshape(K, n_max, d),
                          page_bytes=page_bytes, meta_arrays=meta)

    def with_store(self, store: "PagedStore | StoreView") -> "LIMSSnapshot":
        """Store-backed view of this snapshot: row payloads dropped (the
        executor fetches them from ``store`` page-wise), all query
        metadata kept resident.  A raw ``PagedStore`` is bound through a
        ``StoreView`` freezing its *current* generation's layout — call
        this right after :meth:`spill` so snapshot and layout match.
        Pure — returns a new snapshot."""
        if isinstance(store, PagedStore):
            store = store.view()
        return replace(
            self, rows=jnp.zeros((self.K, 0, self.d), jnp.float32),
            rows_np=np.zeros((0, self.d), np.float64), store=store,
            rows_lp=None, lp_eps=0.0)

    @classmethod
    def load(cls, path: str, store: "bool | PagedStore | None" = None,
             cache_pages: int | None = DEFAULT_CACHE_PAGES):
        """Load a spilled snapshot.

        ``store=None/False``: resident — rows read back from the page
        file; bit-identical round trip with :meth:`spill`.
        ``store=True``: cold-start — metadata loads (fast), rows stay on
        disk behind a fresh ``PagedStore`` with ``cache_pages`` capacity.
        ``store=<PagedStore>``: serve through an existing reader (keeps
        its warm page cache; refreshed to the latest manifest).
        """
        meta, man = load_meta(path)
        K, m, n_rings, n_max, live = (int(v) for v in meta["scalars"])
        d = man.d
        kw = {f: jnp.asarray(meta[f]) for f in _SPILL_FIELDS}
        if isinstance(store, StoreView):
            store = store.base
        # the view's (layout, pages file) pair comes from the SAME
        # manifest read as the metadata above — a writeback (or
        # compaction) landing between the two reads would otherwise pair
        # generation-G arrays with G+1 extents
        if isinstance(store, PagedStore):
            ps = store.refresh().view(man.layout(), man.pages_file)
        elif store:
            ps = PagedStore(path, cache_pages=cache_pages).view(
                man.layout(), man.pages_file)
        else:
            ps = None
        if ps is not None:
            rows = jnp.zeros((K, 0, d), jnp.float32)
            rows_np = np.zeros((0, d), np.float64)
            rows_lp, lp_eps = None, 0.0
        else:
            reader = PagedStore(path, cache_pages=0)
            rows64 = np.stack([reader.read_cluster(k) for k in range(K)])
            rows = jnp.asarray(rows64.astype(np.float32))
            rows_np = rows64.reshape(K * n_max, d)
            rows_lp, lp_eps = _lp_plane(rows)
        return cls(K=K, m=m, n_rings=n_rings, n_max=n_max, live=live,
                   rows=rows, rows_np=rows_np,
                   rows_lp=rows_lp, lp_eps=lp_eps,
                   gids_np=np.asarray(meta["gids_np"], np.int64),
                   valid_np=np.asarray(meta["valid_np"], bool),
                   store=ps, **kw)


def maybe_paged(snap: "LIMSSnapshot", path: str | None = None,
                page_bytes: int = DEFAULT_PAGE_BYTES,
                cache_pages: int | None = DEFAULT_CACHE_PAGES
                ) -> "LIMSSnapshot":
    """Apply the process-wide ``REPRO_STORAGE`` policy to a fresh
    snapshot: under ``paged``, spill it (to ``path``, or a self-cleaning
    temp directory) and return the store-backed view, so the default
    serving surfaces exercise the storage tier suite-wide; otherwise
    return ``snap`` unchanged."""
    if storage_mode() != "paged" or snap.store is not None:
        return snap
    cleanup = path is None
    if path is None:
        path = tempfile.mkdtemp(prefix="lims-paged-")
    snap.spill(path, page_bytes=page_bytes)
    store = PagedStore(path, cache_pages=cache_pages)
    if cleanup:
        weakref.finalize(store, shutil.rmtree, path, ignore_errors=True)
    return snap.with_store(store)


jax.tree_util.register_pytree_node(
    LIMSSnapshot, LIMSSnapshot.tree_flatten, LIMSSnapshot.tree_unflatten)


_LP_DTYPES = {"bf16": jnp.bfloat16, "f16": jnp.float16}


def lp_quant_eps(rows, lp, metric: str = "l2") -> float:
    """Certified quantization margin of a low-precision row plane.

    ``max_x ‖x − x̃‖`` over rows, computed exactly in f64 — by the
    triangle inequality ``|d(q, x̃) − d(q, x)| ≤ ‖x − x̃‖`` for every
    query ``q`` under any norm-induced metric, so widening a filter
    radius by this margin makes the low-precision ball test a certified
    superset of the exact one (the ε analogue of the rank bound E:
    DESIGN.md §13 vs §3)."""
    delta = np.abs(np.asarray(rows).astype(np.float64)
                   - np.asarray(lp).astype(np.float64))
    if delta.size == 0:
        return 0.0
    delta = delta.reshape(-1, delta.shape[-1])
    if metric in ("l2", "sql2"):
        per = np.sqrt(np.sum(delta * delta, axis=-1))
    elif metric == "l1":
        per = np.sum(delta, axis=-1)
    elif metric == "linf":
        per = np.max(delta, axis=-1)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return float(per.max())


def _lp_plane(rows: jax.Array) -> tuple[jax.Array | None, float]:
    """(rows_lp, lp_eps) under the ``REPRO_ROWS_DTYPE`` policy — None /
    0.0 when the plane is off (the default)."""
    dt = rows_dtype()
    if dt is None or rows.size == 0:
        return None, 0.0
    lp = rows.astype(_LP_DTYPES[dt])
    return lp, lp_quant_eps(rows, lp, "l2")


def _certified_rank_table(index: LIMSIndex):
    """(G, C) Chebyshev table for one-launch ``rankeval`` + the certified
    per-group rank-error bound E (module docstring / DESIGN.md §3)."""
    m = index.m
    G = index.K * m
    models = [ci.rank_models[j] for ci in index.clusters for j in range(m)]
    C = max(len(mo.coef) for mo in models)
    coef = np.zeros((G, C), np.float32)
    lo = np.zeros(G, np.float32)
    hi = np.ones(G, np.float32)
    n_model = np.zeros(G, np.float32)
    for g, mo in enumerate(models):
        coef[g, :len(mo.coef)] = mo.coef
        lo[g], hi[g], n_model[g] = mo.lo, mo.hi, mo.n

    # certify E: kernel error at the data points + derivative bound for
    # the gaps between them
    n_col = max(int(ci.n) for ci in index.clusters)
    err = np.zeros(G)
    if n_col > 0:
        xcols = np.zeros((G, n_col), np.float32)
        for gi, (ci, j) in enumerate(
                (ci, j) for ci in index.clusters for j in range(m)):
            n = ci.n
            col = ci.mapping.d_sorted[j]
            xcols[gi, :n] = col
            if n:
                xcols[gi, n:] = col[-1]       # pad with hi (ignored)
        pred = np.asarray(ops.rankeval(
            xcols, coef, lo, hi, n_model, n_rings=index.n_rings)[0])
        for gi, mo in enumerate(models):
            n = mo.n
            if n == 0:
                continue
            err_pt = np.abs(pred[gi, :n] -
                            np.arange(n, dtype=np.float64)).max()
            deriv = float(np.sum(
                np.arange(len(mo.coef)) ** 2 * np.abs(mo.coef)))
            span = mo.hi - mo.lo
            col = index.clusters[gi // m].mapping.d_sorted[gi % m]
            gap = float(np.diff(col).max()) * 2.0 / span \
                if (n > 1 and span > 0) else 0.0
            # ranks live in [0, n-1] and predictions are clipped to the
            # same interval, so n always bounds the error — keeps a
            # degenerate fit from inflating E past "whole cluster"
            err[gi] = min(err_pt + deriv * gap + _E_SLACK, float(n))
    return coef, lo, hi, n_model, err


__all__ = ["LIMSSnapshot", "maybe_paged", "lp_quant_eps"]
