"""Rank prediction models + exponential search correction.

``RP``: a polynomial regression model (Def. 6) fit by least squares on
{(x, rank(x))} where rank(x) = |{x' < x}| (Def. 5). The paper's defaults
are degree 20 for the distance→rank models and degree 1 for the
LIMS-value→position models. Degree-20 monomial Vandermonde systems are
numerically hopeless, so we fit in the Chebyshev basis on x normalized to
[-1, 1] — the *model class* (degree-g polynomials) is identical to the
paper's; only the basis used by the solver differs.

Exactness never depends on model quality: every prediction is corrected by
exponential search over the underlying sorted array (O(log err) probes,
err = |predicted − true rank|). The number of probes is the honest "CPU
cost" of the learned index and is what the LIMS vs N-LIMS ablation
measures (N-LIMS = plain binary search from scratch, O(log n) probes).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class PolyRankModel:
    """rank(x) ≈ chebval((x-lo)/(hi-lo)*2-1, coef); clipped to [0, n]."""
    coef: np.ndarray
    lo: float
    hi: float
    n: int

    @staticmethod
    def fit(sorted_x: np.ndarray, degree: int = 20) -> "PolyRankModel":
        import warnings
        x = np.asarray(sorted_x, dtype=np.float64)
        n = len(x)
        if n == 0:
            return PolyRankModel(np.zeros(1), 0.0, 1.0, 0)
        lo, hi = float(x[0]), float(x[-1])
        # constant model for single-element and all-equal columns: a
        # high-degree fit on <2 distinct abscissae is ill-conditioned
        # noise, and rank(anything) is 0 here anyway
        if hi <= lo:
            return PolyRankModel(np.zeros(1), lo, lo + 1.0, n)
        # rank with ties-low semantics: first occurrence index
        ranks = np.searchsorted(x, x, side="left").astype(np.float64)
        # keep the system comfortably over-determined, and never ask for
        # more degrees of freedom than there are distinct values (ties
        # collapse rows: a near-constant column would otherwise feed an
        # ill-conditioned high-degree Vandermonde to lstsq)
        n_distinct = 1 + int(np.count_nonzero(np.diff(x) > 0))
        deg = int(min(degree, max(1, n // 8), max(1, n_distinct - 1), 64))
        t = (x - lo) / (hi - lo) * 2.0 - 1.0
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            # least-squares in Chebyshev basis (same polynomial model class)
            coef = np.polynomial.chebyshev.chebfit(t, ranks, deg)
        if not np.all(np.isfinite(coef)):
            # explicit linear fallback: the exact ramp rank ≈ (n'-1)(t+1)/2
            # through the column's endpoints — predictions stay finite and
            # exponential search corrects the rest
            r_hi = float(ranks[-1])
            coef = np.array([r_hi / 2.0, r_hi / 2.0])
        return PolyRankModel(coef, lo, hi, n)

    def predict(self, x) -> np.ndarray:
        t = (np.asarray(x, dtype=np.float64) - self.lo) / (self.hi - self.lo) * 2.0 - 1.0
        t = np.clip(t, -1.0, 1.0)
        r = np.polynomial.chebyshev.chebval(t, self.coef)
        return np.clip(np.rint(r), 0, max(self.n - 1, 0)).astype(np.int64)

    def predict_scalar(self, x: float) -> int:
        """Fast scalar Clenshaw evaluation (pure Python floats). Model
        inference on the query path is O(degree) multiplies — this is the
        O(1)-vs-O(log n) CPU story of the paper's ablation."""
        t = (x - self.lo) / (self.hi - self.lo) * 2.0 - 1.0
        t = -1.0 if t < -1.0 else (1.0 if t > 1.0 else t)
        c = getattr(self, "_coef_list", None)
        if c is None:
            # coefficients high→low, constant term last
            c = self._coef_list = [float(v) for v in self.coef[::-1]]
        b1 = 0.0
        b2 = 0.0
        t2 = 2.0 * t
        for ck in c[:-1]:                  # Clenshaw recurrence, high→low
            b1, b2 = ck + t2 * b1 - b2, b1
        r = c[-1] + t * b1 - b2
        n1 = self.n - 1 if self.n > 0 else 0
        r = int(r + 0.5) if r > 0 else 0
        return n1 if r > n1 else r

    def nbytes(self) -> int:
        return self.coef.nbytes + 8 * 3


@dataclass
class SearchStats:
    probes: int = 0
    corrections: int = 0

    def add(self, probes: int) -> None:
        self.probes += probes
        self.corrections += 1


def exponential_search(arr, x: float, guess: int,
                       side: str = "left",
                       stats: SearchStats | None = None) -> int:
    """Position of ``x`` in sorted ``arr`` starting from a model ``guess``.

    side='left'  → first index i with arr[i] >= x   (== rank(x), Def. 5)
    side='right' → first index i with arr[i] >  x

    Doubling bracket expansion from the guess, then binary search within
    the bracket: O(log err) total probes, counted in ``stats``. Hot path:
    pure-Python comparisons on a list-like ``arr`` (no numpy scalars).
    """
    n = len(arr)
    if n == 0:
        return 0
    g = 0 if guess < 0 else (n - 1 if guess > n - 1 else int(guess))
    probes = 1
    left = side == "left"
    v = arr[g]
    at_or_after = (v >= x) if left else (v > x)
    step = 1
    if at_or_after:
        hi = g
        lo = g - 1
        while lo >= 0:
            probes += 1
            v = arr[lo]
            if not ((v >= x) if left else (v > x)):
                break
            hi = lo
            step <<= 1
            lo = g - step
        if lo < -1:
            lo = -1
        lo_i, hi_i = lo + 1, hi
    else:
        lo = g
        hi = g + 1
        while hi < n:
            probes += 1
            v = arr[hi]
            if (v >= x) if left else (v > x):
                break
            lo = hi
            step <<= 1
            hi = g + step
        if hi > n:
            hi = n
        lo_i, hi_i = lo + 1, hi
    while lo_i < hi_i:
        mid = (lo_i + hi_i) >> 1
        probes += 1
        v = arr[mid]
        if (v >= x) if left else (v > x):
            hi_i = mid
        else:
            lo_i = mid + 1
    if stats is not None:
        stats.add(probes)
    return lo_i


def binary_search(arr, x: float, side: str = "left",
                  stats: SearchStats | None = None) -> int:
    """Plain binary search (the N-LIMS baseline path): O(log n) probes."""
    lo, hi = 0, len(arr)
    probes = 0
    left = side == "left"
    while lo < hi:
        mid = (lo + hi) >> 1
        probes += 1
        v = arr[mid]
        if (v >= x) if left else (v > x):
            hi = mid
        else:
            lo = mid + 1
    if stats is not None:
        stats.add(probes)
    return lo
