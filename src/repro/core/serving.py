"""Layer 3 of the serving stack: the mutable frontend.

``ServingEngine`` is what a deployment talks to.  It owns

  * the host ``LIMSIndex`` (source of truth for §5.3 updates),
  * a double-buffered pair of snapshot executors: the *active* executor
    serves queries; ``refresh()`` builds a fresh ``LIMSSnapshot`` into the
    standby slot **off the hot path** and then swaps the two with a single
    attribute assignment — atomic under the GIL, so an in-flight batch
    that already grabbed the active executor keeps its consistent
    snapshot while new batches see the new one.  No query ever blocks on
    a rebuild and no query ever observes a half-built snapshot.

Updates (``insert`` / ``delete`` / ``retrain_cluster``) go straight to
the host index and bump a mutation counter; once the counter reaches
``refresh_every`` the engine triggers a rebuild — synchronously by
default (deterministic for tests), or on a background thread with
``async_refresh=True`` (updates serialize with the rebuild via a lock;
queries never take it).  Between refreshes queries serve the last
snapshot — stale but *consistent and exact with respect to that
snapshot*, the usual contract of a serving index (DESIGN.md §5).
"""
from __future__ import annotations

import threading

from jax.sharding import Mesh

from .executor import QueryExecutor, make_executor
from .index import LIMSIndex
from .snapshot import LIMSSnapshot


class ServingEngine:
    """Double-buffered snapshot serving over a mutable ``LIMSIndex``."""

    def __init__(self, index: LIMSIndex, *, refresh_every: int = 64,
                 sharded: bool | None = None, mesh: Mesh | None = None,
                 async_refresh: bool = False,
                 build_backend: str | None = None):
        self._index = index
        self._refresh_every = int(refresh_every)
        # online retrains route through the device builder (repro.build;
        # DESIGN.md §6) whenever the kernels compile — on real
        # accelerators partial reconstruction stops being the refresh
        # bottleneck.  CPU runs interpret-mode kernels, where the device
        # path only costs (retrains hold the update lock), so the
        # default resolves by dispatch policy; pass "device"/"host" to
        # pin it.
        if build_backend is None:
            from ..kernels.dispatch import default_interpret
            build_backend = "host" if default_interpret() else "device"
        self._build_backend = build_backend
        self._sharded = sharded
        self._mesh = mesh
        self._async = bool(async_refresh)
        # guards host-index mutation + snapshot builds (never queries)
        self._update_lock = threading.Lock()
        # guards background-refresh thread bookkeeping
        self._thread_lock = threading.Lock()
        self._refresh_thread: threading.Thread | None = None
        self._refresh_again = False
        self.generation = 0
        self.pending_mutations = 0
        self._active: QueryExecutor = self._build_executor()
        self._standby: QueryExecutor | None = None

    # ------------------------------------------------------------ plumbing
    def _build_executor(self) -> QueryExecutor:
        snap = LIMSSnapshot.build(self._index)
        return make_executor(snap, sharded=self._sharded, mesh=self._mesh)

    @property
    def index(self) -> LIMSIndex:
        return self._index

    @property
    def executor(self) -> QueryExecutor:
        """The active executor; grab it once per batch for a consistent
        view across the whole batch."""
        return self._active

    @property
    def snapshot(self) -> LIMSSnapshot:
        return self._active.snap

    # ------------------------------------------------------------- queries
    # Each query method reads ``self._active`` exactly once: the batch
    # runs against that snapshot even if a refresh swaps mid-flight.
    def range_query_batch(self, Q, r):
        return self._active.range_query_batch(Q, r)

    def range_query(self, q, r: float):
        return self._active.range_query(q, r)

    def knn_query_batch(self, Q, k: int, **kw):
        return self._active.knn_query_batch(Q, k, **kw)

    def knn_query(self, q, k: int):
        return self._active.knn_query(q, k)

    # ------------------------------------------------------------- updates
    # The mutation counter is only ever read or written under
    # _update_lock (refresh() subtracts under the same lock), so
    # concurrent updaters and a background rebuild can't lose counts.
    # The threshold check happens after the lock is released — refresh()
    # re-takes it — so two racing updaters can at worst both trigger a
    # refresh, which is harmless (the second sees zero pending).
    def insert(self, p) -> int:
        with self._update_lock:
            gid = self._index.insert(p)
            self.pending_mutations += 1
            pending = self.pending_mutations
        self._maybe_refresh(pending)
        return gid

    def delete(self, q) -> int:
        with self._update_lock:
            removed = self._index.delete(q)
            self.pending_mutations += removed
            pending = self.pending_mutations
        if removed:
            self._maybe_refresh(pending)
        return removed

    def retrain_cluster(self, c: int) -> None:
        with self._update_lock:
            self._index.retrain_cluster(c, backend=self._build_backend)
            # a retrain rewrites cluster structure the snapshot mirrors;
            # force the next refresh decision regardless of the
            # insert/delete count
            self.pending_mutations += self._refresh_every
            pending = self.pending_mutations
        self._maybe_refresh(pending)

    def _maybe_refresh(self, pending: int) -> None:
        if self._refresh_every and pending >= self._refresh_every:
            if self._async:
                self._spawn_refresh()
            else:
                self.refresh()

    # ------------------------------------------------------------- refresh
    def refresh(self) -> None:
        """Rebuild the standby snapshot and swap it in atomically."""
        with self._update_lock:
            seen = self.pending_mutations
            new = self._build_executor()
            # the swap: one attribute store (GIL-atomic); the previous
            # executor moves to standby, kept alive for in-flight batches
            self._active, self._standby = new, self._active
            self.pending_mutations -= seen
            self.generation += 1

    def _spawn_refresh(self) -> None:
        with self._thread_lock:
            if self._refresh_thread is not None:
                # a rebuild is running: ask it to go again before exiting
                # (its exit decision happens under this same lock, so the
                # request can never fall into a teardown window)
                self._refresh_again = True
                return
            t = threading.Thread(target=self._refresh_worker, daemon=True,
                                 name="lims-snapshot-refresh")
            self._refresh_thread = t
        t.start()

    def _refresh_worker(self) -> None:
        while True:
            self.refresh()
            with self._thread_lock:
                if not self._refresh_again:
                    self._refresh_thread = None
                    return
                self._refresh_again = False

    def wait_refresh(self) -> None:
        """Block until every requested background refresh has landed."""
        while True:
            with self._thread_lock:
                t = self._refresh_thread
            if t is None:
                return
            t.join()


__all__ = ["ServingEngine"]
