"""Compatibility shim: ``ServingEngine`` lives in ``repro.serving``.

The serving stack grew past one module — frontend (dynamic batching +
admission control), router (plan-driven replica dispatch), replicas
(snapshot placement + load stats) and the lifecycle engine are the
layered ``repro.serving`` package now (DESIGN.md §9).  This module keeps
the historical import path ``repro.core.serving.ServingEngine``
bit-identical: same class object, no behavior shims.
"""
from ..serving.engine import ServingEngine

__all__ = ["ServingEngine"]
