"""Batched (TPU-native) LIMS query engine.

The paper's IntervalGen exists to produce *contiguous disk ranges*; the
union of its LIMS-value intervals is exactly the set of objects whose ring
vector lies inside the per-pivot rid box (DESIGN.md §3). On an accelerator
we skip the interval walk entirely: compute the rid box per (query,
cluster, pivot) with the same rank math as the host index, AND the
per-object ring IDs against the box (one vectorized mask), and refine with
the fused-distance kernel math. Exactness is inherited: the mask is the
same candidate set, refinement applies true distances.

Data layout: per-cluster arrays padded to a common n_max —
  rows (K, n_max, d) · rids (K, n_max, m) · d_sorted (K, m, n_max)
  pivots (K, m, d) · dist_min/max (K, m) · width (K,)
Padding uses +inf distances / -1 ids so padded slots never match.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .index import LIMSIndex


class BatchedLIMS:
    """Immutable device snapshot of a LIMSIndex (vector metrics, L2)."""

    def __init__(self, index: LIMSIndex):
        assert index.space.metric == "l2", "batched path: L2 (MXU kernel)"
        self.m = index.m
        self.n_rings = index.n_rings
        K = index.K
        n_max = max(ci.n for ci in index.clusters)
        d = index.space.data.shape[1]
        rows = np.zeros((K, n_max, d), np.float32)
        rids = np.full((K, n_max, self.m), -1, np.int32)
        dsort = np.full((K, self.m, n_max), np.inf, np.float32)
        pivots = np.zeros((K, self.m, d), np.float32)
        dmin = np.zeros((K, self.m), np.float32)
        dmax = np.zeros((K, self.m), np.float32)
        width = np.ones((K,), np.int32)
        gids = np.full((K, n_max), -1, np.int64)
        for ci in index.clusters:
            n = ci.n
            if n == 0:
                continue
            k = ci.cid
            rows[k, :n] = ci.store.rows
            rids[k, :n] = ci.mapping.rids[ci.mapping.order]
            dsort[k, :, :n] = ci.mapping.d_sorted
            pivots[k] = ci.pivot_rows
            dmin[k] = ci.mapping.dist_min
            dmax[k] = ci.mapping.dist_max
            width[k] = max(1, -(-n // self.n_rings))
            gids[k, :n] = ci.store_ids
        self.rows = jnp.asarray(rows)
        self.rids = jnp.asarray(rids)
        self.dsort = jnp.asarray(dsort)
        self.pivots = jnp.asarray(pivots)
        self.dmin = jnp.asarray(dmin)
        self.dmax = jnp.asarray(dmax)
        self.width = jnp.asarray(width)
        self.gids_np = gids
        self._ns = jnp.asarray(
            np.array([ci.n for ci in index.clusters], np.int32))
        # source-of-truth payloads for the exact (f64) final refinement
        self.data_np = np.asarray(index.space.data, np.float64)

    def _mask(self, q: jax.Array, r: jax.Array):
        """Candidate mask (K, n_max) for one query — fully vectorized."""
        K, mm, n_max = self.dsort.shape
        # f32 guard band: rank math ran in f64 at build time; inflate the
        # annulus so rounding can never exclude a true result (the final
        # f64 refinement removes the extras)
        r = r * (1 + 1e-5) + 1e-4
        dq = jnp.sqrt(jnp.maximum(jnp.sum(
            (self.pivots - q[None, None, :]) ** 2, -1), 0.0))   # (K, m)
        alive = jnp.all(dq <= self.dmax + r, -1) & \
            jnp.all(dq >= self.dmin - r, -1) & (self._ns > 0)   # (K,)
        r_lo = jnp.maximum(dq - r, self.dmin)
        r_hi = jnp.minimum(dq + r, self.dmax)
        # identical rank math to the host: rank = searchsorted-left;
        # hi rank = searchsorted-right - 1
        vs = jax.vmap(jax.vmap(
            lambda col, lo, hi: (jnp.searchsorted(col, lo, side="left"),
                                 jnp.searchsorted(col, hi, side="right") - 1)))
        rank_lo, rank_hi = vs(self.dsort, r_lo, r_hi)           # (K, m)
        w = self.width[:, None]
        rid_lo = jnp.clip(rank_lo // w, 0, self.n_rings - 1)
        rid_hi = jnp.clip(rank_hi // w, 0, self.n_rings - 1)
        nonempty = rank_hi >= rank_lo                           # (K, m)
        box = jnp.all(
            (self.rids >= rid_lo[:, None, :]) &
            (self.rids <= rid_hi[:, None, :]), -1)              # (K, n_max)
        ok = alive & jnp.all(nonempty, -1)
        return box & ok[:, None] & (self.rids[:, :, 0] >= 0)

    def range_query(self, q, r: float):
        """Exact L2 range query; returns (global ids, distances)."""
        qf = jnp.asarray(q, jnp.float32)
        mask = self._mask(qf, jnp.float32(r))
        d2 = jnp.sum((self.rows - qf[None, None, :]) ** 2, -1)
        # f32 guard band keeps borderline candidates; exact f64 refine below
        hit = np.asarray(mask & (d2 <= (jnp.float32(r) + 1e-3) ** 2))
        ids = self.gids_np[hit]
        from .metrics import dist_one_to_many
        d_true = dist_one_to_many(np.asarray(q, np.float64),
                                  self.data_np[ids], "l2")
        keep = d_true <= r
        return ids[keep], d_true[keep]

    def knn_query(self, q, k: int):
        """Exact kNN: growing radius over the mask + device top-k."""
        q = jnp.asarray(q, jnp.float32)
        d2 = jnp.sum((self.rows - q[None, None, :]) ** 2, -1)
        valid = self.rids[:, :, 0] >= 0
        # initial radius from the k-th distance in the query's cluster
        r = float(jnp.sqrt(jnp.maximum(jnp.min(
            jnp.where(valid, d2, jnp.inf)), 0.0))) + 1e-6
        while True:
            r *= 2.0
            mask = self._mask(q, jnp.float32(r)) & (d2 <= r * r)
            cnt = int(jnp.sum(mask))
            if cnt >= k or r > 1e9:
                d_masked = jnp.where(mask, d2, jnp.inf)
                flat = d_masked.reshape(-1)
                vals, idx = jax.lax.top_k(-flat, k)
                dists = np.sqrt(np.maximum(-np.asarray(vals), 0.0))
                if dists[-1] <= r:          # kth inside queried ball: done
                    gid = self.gids_np.reshape(-1)[np.asarray(idx)]
                    return gid, dists
