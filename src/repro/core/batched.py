"""Batched (accelerator-native) LIMS query engine — Pallas-kernel backed.

The paper's IntervalGen exists to produce *contiguous disk ranges*; the
union of its LIMS-value intervals is exactly the set of objects whose ring
vector lies inside the per-pivot rid box (DESIGN.md §3). On an accelerator
we skip the interval walk entirely and run the whole batch through three
fused kernels (``repro.kernels``):

  1. ``pdist``        — query→pivot distances for every query at once
                        (TriPrune + AreaLocate inputs, one MXU launch);
  2. ``rankeval``     — every (cluster, pivot) rank model evaluated on the
                        batch's annulus boundaries in ONE launch: x is laid
                        out (G, 2B) with G = K·m groups and the lo/hi
                        boundary values of all B queries as the columns;
  3. ``range_filter`` — fused exact-distance refinement over the padded
                        row store (only a uint8 mask leaves VMEM).

Exactness with learned models on device: the host corrects model error
with exponential search; fixed-shape device code cannot branch per value,
so the snapshot instead *certifies* a per-(cluster, pivot) rank-error
bound E and widens the predicted ring box by it.  E is computed at
snapshot build by running the actual ``rankeval`` kernel over the group's
own sorted column (max observed error at the data points) plus a Chebyshev
derivative bound ``D = Σ k²|c_k|`` times the largest inter-point gap in
normalized t-space (the polynomial cannot wiggle more than that between
samples), plus slack for rint/f32.  The widened box is therefore a
guaranteed superset of the host's exact rid box, and the final f64
refinement removes every extra candidate — results are bit-identical to
``LIMSIndex``.

Data layout: per-cluster arrays padded to a common n_max —
  rows (K, n_max, d) · rids (K, n_max, m) · pivots (K, m, d)
  dist_min/max (K, m) · width (K,) · gids (K, n_max)
Ring-ordered store rows come first in each cluster's slots; §5.3 insert-
buffer rows follow with ``in_ring=False`` (they bypass the ring box, as
the host always scans buffers); tombstoned and padding slots are invalid
(-1 ids) and never match.

Batch API: ``range_query_batch(Q, r)`` takes per-query radii and returns
one (ids, dists) pair per query; ``knn_query_batch(Q, k)`` grows radii
for the whole batch on device with per-query done flags (no per-query
Python in the search loop — host work is limited to the ragged output
assembly / f64 refinement after the loop converges).

The kernels auto-select compile-vs-interpret by backend (compiled on
TPU/GPU, interpreted on CPU) — see ``repro.kernels.dispatch``.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import ops
from .index import LIMSIndex
from .metrics import dist_one_to_many

# f32 guard bands: rank math and distances run in f64 on the host; the
# device path inflates radii so rounding can never exclude a true result
# (the final f64 refinement removes the extras).
_R_REL = 1e-5       # relative radius inflation for the ring box
_R_ABS = 1e-4       # absolute radius inflation for the ring box
_BALL_ABS = 1e-3    # absolute inflation for the distance-ball prefilter
_E_SLACK = 2.0      # ranks: rint (±0.5 twice) + f32 eval slop


class BatchedLIMS:
    """Immutable device snapshot of a LIMSIndex (vector metrics, L2)."""

    def __init__(self, index: LIMSIndex):
        assert index.space.metric == "l2", "batched path: L2 (MXU kernel)"
        self.m = index.m
        self.n_rings = index.n_rings
        self.K = K = index.K
        m = self.m
        d = index.space.data.shape[1]
        dead = index.tombstones

        n_slots = [ci.n + len(ci.buf_ids) for ci in index.clusters]
        n_max = max(max(n_slots), 1)
        rows = np.zeros((K, n_max, d), np.float32)
        rows64 = np.zeros((K, n_max, d), np.float64)
        rids = np.full((K, n_max, m), -1, np.int32)
        pivots = np.zeros((K, m, d), np.float32)
        dmin = np.zeros((K, m), np.float32)
        dmax = np.zeros((K, m), np.float32)
        width = np.ones((K,), np.int32)
        gids = np.full((K, n_max), -1, np.int64)
        valid = np.zeros((K, n_max), bool)
        in_ring = np.zeros((K, n_max), bool)
        for ci in index.clusters:
            k, n, nb = ci.cid, ci.n, len(ci.buf_ids)
            pivots[k] = ci.pivot_rows
            if n:
                rows[k, :n] = ci.store.rows
                rows64[k, :n] = ci.store.rows
                rids[k, :n] = ci.mapping.rids[ci.mapping.order]
                dmin[k] = ci.mapping.dist_min
                dmax[k] = ci.mapping.dist_max
                width[k] = max(1, -(-n // self.n_rings))
                gids[k, :n] = ci.store_ids
                in_ring[k, :n] = True
                valid[k, :n] = ~np.isin(
                    ci.store_ids, list(dead)) if dead else True
            if nb:
                buf = np.stack(ci.buf_rows)
                rows[k, n:n + nb] = buf
                rows64[k, n:n + nb] = buf
                gids[k, n:n + nb] = ci.buf_ids
                valid[k, n:n + nb] = [g not in dead for g in ci.buf_ids]
        self.n_max = n_max
        self.rows = jnp.asarray(rows.reshape(K * n_max, d))
        self.rows_np = rows64.reshape(K * n_max, d)
        self.rids = jnp.asarray(rids)
        self.pivots = jnp.asarray(pivots.reshape(K * m, d))
        self.dmin = jnp.asarray(dmin)
        self.dmax = jnp.asarray(dmax)
        self.width = jnp.asarray(width)
        self.gids_np = gids.reshape(-1)
        self.valid = jnp.asarray(valid)
        self.valid_np = valid.reshape(-1)
        self.in_ring = jnp.asarray(in_ring)
        self.always = jnp.asarray(valid & ~in_ring)
        self._ns = jnp.asarray(
            np.array([ci.n for ci in index.clusters], np.int32))
        self.live = int(valid.sum())
        self._build_rank_table(index)

    # ------------------------------------------------- rank-model snapshot
    def _build_rank_table(self, index: LIMSIndex) -> None:
        """(G, C) Chebyshev table for one-launch ``rankeval`` + the
        certified per-group rank-error bound E (see module docstring)."""
        K, m = self.K, self.m
        G = K * m
        models = [ci.rank_models[j] for ci in index.clusters
                  for j in range(m)]
        C = max(len(mo.coef) for mo in models)
        coef = np.zeros((G, C), np.float32)
        lo = np.zeros(G, np.float32)
        hi = np.ones(G, np.float32)
        n_model = np.zeros(G, np.float32)
        for g, mo in enumerate(models):
            coef[g, :len(mo.coef)] = mo.coef
            lo[g], hi[g], n_model[g] = mo.lo, mo.hi, mo.n
        self.coef = jnp.asarray(coef)
        self.model_lo = jnp.asarray(lo)
        self.model_hi = jnp.asarray(hi)
        self.model_n = jnp.asarray(n_model)

        # certify E: kernel error at the data points + derivative bound
        # for the gaps between them
        n_col = max(int(ci.n) for ci in index.clusters)
        err = np.zeros(G)
        if n_col > 0:
            xcols = np.zeros((G, n_col), np.float32)
            for gi, (ci, j) in enumerate(
                    (ci, j) for ci in index.clusters for j in range(m)):
                n = ci.n
                col = ci.mapping.d_sorted[j]
                xcols[gi, :n] = col
                if n:
                    xcols[gi, n:] = col[-1]       # pad with hi (ignored)
            pred = np.asarray(ops.rankeval(
                xcols, coef, lo, hi, n_model, n_rings=self.n_rings)[0])
            for gi, mo in enumerate(models):
                n = mo.n
                if n == 0:
                    continue
                err_pt = np.abs(pred[gi, :n] -
                                np.arange(n, dtype=np.float64)).max()
                deriv = float(np.sum(
                    np.arange(len(mo.coef)) ** 2 * np.abs(mo.coef)))
                span = mo.hi - mo.lo
                col = index.clusters[gi // m].mapping.d_sorted[gi % m]
                gap = float(np.diff(col).max()) * 2.0 / span \
                    if (n > 1 and span > 0) else 0.0
                # ranks live in [0, n-1] and predictions are clipped to
                # the same interval, so n always bounds the error — keeps
                # a degenerate fit from inflating E past "whole cluster"
                err[gi] = min(err_pt + deriv * gap + _E_SLACK, float(n))
        self.rank_err = jnp.asarray(err.reshape(K, m), jnp.float32)

    # ------------------------------------------------------ candidate mask
    def _candidate_mask(self, qf: jax.Array, rf: jax.Array) -> jax.Array:
        """(B, K·n_max) candidate mask for the batch — ring box from one
        ``rankeval`` launch (error-widened), plus buffer/always slots."""
        B = qf.shape[0]
        K, m, N = self.K, self.m, self.n_rings
        r_g = rf * (1.0 + _R_REL) + _R_ABS                      # (B,)
        dq = jnp.sqrt(jnp.maximum(ops.pdist(qf, self.pivots), 0.0))
        dqr = dq.reshape(B, K, m)
        alive = jnp.all((dqr <= self.dmax[None] + r_g[:, None, None]) &
                        (dqr >= self.dmin[None] - r_g[:, None, None]),
                        axis=-1) & (self._ns[None] > 0)         # (B, K)
        # one rankeval launch: G groups × (lo | hi) boundaries of all B
        x = jnp.concatenate([(dq - r_g[:, None]).T,
                             (dq + r_g[:, None]).T], axis=1)    # (G, 2B)
        rank, _ = ops.rankeval(x, self.coef, self.model_lo, self.model_hi,
                               self.model_n, n_rings=N)
        err = self.rank_err.reshape(-1)[:, None]                # (G, 1)
        lo_rank = jnp.maximum(rank[:, :B].astype(jnp.float32) - err, 0.0)
        hi_rank = rank[:, B:].astype(jnp.float32) + err
        w = self.width[None, :, None].astype(jnp.float32)
        rid_lo = jnp.clip(jnp.floor(lo_rank.T.reshape(B, K, m) / w),
                          0, N - 1).astype(jnp.int32)
        rid_hi = jnp.clip(jnp.floor(hi_rank.T.reshape(B, K, m) / w),
                          0, N - 1).astype(jnp.int32)
        box = jnp.all((self.rids[None] >= rid_lo[:, :, None, :]) &
                      (self.rids[None] <= rid_hi[:, :, None, :]),
                      axis=-1)                                  # (B, K, n_max)
        cand = (box & alive[:, :, None] & self.in_ring[None]) | \
            self.always[None]
        cand = cand & self.valid[None]
        return cand.reshape(B, K * self.n_max)

    # -------------------------------------------------------- range queries
    def range_query_batch(self, Q, r):
        """Exact batched L2 range query.

        ``Q``: (B, d) queries; ``r``: scalar or (B,) per-query radii.
        Returns a list of B ``(ids, dists)`` pairs (int64 / float64), the
        same results as ``LIMSIndex.range_query`` per query.
        """
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        B = Q.shape[0]
        r_arr = np.broadcast_to(np.asarray(r, np.float64), (B,))
        qf = jnp.asarray(Q, jnp.float32)
        rf = jnp.asarray(r_arr, jnp.float32)
        cand = self._candidate_mask(qf, rf)
        ball, _ = ops.range_filter(qf, self.rows,
                                   rf * (1.0 + _R_REL) + _BALL_ABS)
        hit = np.asarray(cand & ball.astype(bool))
        out = []
        for b in range(B):
            idx = np.nonzero(hit[b])[0]
            ids = self.gids_np[idx]
            d_true = dist_one_to_many(Q[b], self.rows_np[idx], "l2")
            keep = d_true <= r_arr[b]
            out.append((ids[keep], d_true[keep]))
        return out

    def range_query(self, q, r: float):
        """Single-query convenience wrapper over the batch engine."""
        return self.range_query_batch(np.asarray(q)[None], float(r))[0]

    # ---------------------------------------------------------- kNN queries
    def knn_query_batch(self, Q, k: int, max_rounds: int = 64):
        """Exact batched kNN: one growing-radius loop for the whole batch.

        Per-query done flags live on the host; every round runs the full
        batch through the kernels (queries already done keep their frozen
        radius — no per-query Python in the loop). ``k`` is clamped to the
        number of live objects. Returns ``(ids (B, k'), dists (B, k'))``
        with ``k' = min(k, live)``.
        """
        Q = np.atleast_2d(np.asarray(Q, np.float64))
        B = Q.shape[0]
        k_eff = min(int(k), self.live)
        if k_eff <= 0:
            return (np.empty((B, 0), np.int64), np.empty((B, 0)))
        qf = jnp.asarray(Q, jnp.float32)
        d2 = ops.pdist(qf, self.rows)                           # (B, P)
        d2 = jnp.where(self.valid_np[None], d2, jnp.inf)
        # seed radii at the f32 k-th distance: the loop usually certifies
        # the ball in one round and only grows on guard-band misses
        kth0 = jnp.sqrt(jnp.maximum(
            -jax.lax.top_k(-d2, k_eff)[0][:, -1], 0.0))
        r = np.asarray(kth0, np.float64) * (1.0 + 1e-3) + _BALL_ABS
        done = np.zeros(B, bool)
        final = np.zeros((B, d2.shape[1]), bool)
        for _ in range(max_rounds):
            rf = jnp.asarray(r, jnp.float32)
            cand = self._candidate_mask(qf, rf)
            ball = d2 <= ((rf * (1.0 + _R_REL) + _BALL_ABS) ** 2)[:, None]
            candb = cand & ball
            cnt = jnp.sum(candb, axis=1)
            dm = jnp.where(candb, d2, jnp.inf)
            kth = jnp.sqrt(jnp.maximum(
                -jax.lax.top_k(-dm, k_eff)[0][:, -1], 0.0))
            # certify: enough candidates AND the k-th ball fits inside the
            # queried radius with margin for the f32 guard band
            ok = np.asarray((cnt >= k_eff) &
                            (kth <= rf * (1.0 - _R_REL) - _BALL_ABS))
            newly = ok & ~done
            if newly.any():
                final[newly] = np.asarray(candb)[newly]
                done |= newly
            if done.all():
                break
            r = np.where(done, r, r * 2.0)
        else:
            final[~done] = self.valid_np[None]    # exact fallback: scan
        ids_out = np.empty((B, k_eff), np.int64)
        d_out = np.empty((B, k_eff))
        for b in range(B):
            idx = np.nonzero(final[b])[0]
            d_true = dist_one_to_many(Q[b], self.rows_np[idx], "l2")
            sel = np.argsort(d_true, kind="stable")[:k_eff]
            ids_out[b] = self.gids_np[idx[sel]]
            d_out[b] = d_true[sel]
        return ids_out, d_out

    def knn_query(self, q, k: int):
        """Single-query convenience wrapper over the batch engine."""
        ids, dists = self.knn_query_batch(np.asarray(q)[None], k)
        return ids[0], dists[0]
