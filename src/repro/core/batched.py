"""Batched (accelerator-native) LIMS query engine — compatibility shim.

The original ``BatchedLIMS`` fused snapshot construction, kernel
orchestration and the public query API into one class; those now live in
three layers (DESIGN.md §1):

  * ``repro.core.snapshot.LIMSSnapshot`` — the immutable device pytree
    (padded cluster-major arrays + the certified rank-error bounds that
    keep device results exact, DESIGN.md §3);
  * ``repro.core.executor.QueryExecutor`` / ``ShardedExecutor`` — the
    kernel pipeline (``pdist`` → ``rankeval`` → ``range_filter``) over a
    snapshot, single-device or cluster-sharded via ``shard_map``;
  * ``repro.core.serving.ServingEngine`` — the mutable frontend with
    double-buffered snapshot refresh.

``BatchedLIMS`` remains the stable one-shot API: build a snapshot from a
host index and query it.  It *is* a ``QueryExecutor`` (same methods, same
bit-exact results), so existing callers keep working unchanged; new code
that wants sharding or online updates should use the layers directly.
"""
from __future__ import annotations

from .executor import QueryExecutor
from .index import LIMSIndex
from .snapshot import LIMSSnapshot, maybe_paged


class BatchedLIMS(QueryExecutor):
    """Immutable device snapshot of a LIMSIndex (vector metrics, L2).

    Under ``REPRO_STORAGE=paged`` the snapshot spills to a self-cleaning
    paged store and serves store-backed (bit-identical results, page-
    granular IO) — the CI storage leg runs the whole suite this way."""

    def __init__(self, index: LIMSIndex):
        super().__init__(maybe_paged(LIMSSnapshot.build(index)))

    # legacy attribute surface (pre-split callers poked these directly)
    @property
    def K(self) -> int:
        return self.snap.K

    @property
    def m(self) -> int:
        return self.snap.m

    @property
    def n_rings(self) -> int:
        return self.snap.n_rings

    @property
    def n_max(self) -> int:
        return self.snap.n_max

    @property
    def gids_np(self):
        return self.snap.gids_np

    @property
    def rank_err(self):
        return self.snap.rank_err


__all__ = ["BatchedLIMS"]
