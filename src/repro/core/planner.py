"""Layer 2a of the serving stack: query planning (the *plan* half of the
plan/execute query path).

The paper's cost model is positional: rank models certify, per query, an
interval of learned positions, and the query cost is how many of those
positions (pages, on disk) get touched.  Everything about that decision
is a function of the snapshot's *metadata* — pivot distances, Chebyshev
rank tables, the certified per-group rank-error bound E — and never of
the row payloads.  This module makes that boundary explicit:

  * :class:`CandidatePlan` — one query batch's certified plan: per-query
    radii (plus the growing-radius schedule kNN rounds walk), the
    error-widened per-query candidate masks, and per-query cluster
    routing (TriPrune).  Built exactly once per batch.
  * :class:`Planner` — builds plans from a bound executor's device
    pipeline and evaluates schedule rounds on demand.

Both execution backends (the resident kernel pipeline and the paged
store, ``repro.core.executor``) consume the same plan object, so the
candidate math exists in one place and is provably identical however
the batch executes:

  * the plan never reads rows, so a resident snapshot and its spilled
    store-backed twin plan identically (and a store writeback/manifest
    swap cannot change an existing snapshot's plans);
  * masks and routing are evaluated through the executor's device hook,
    so the ``shard_map``-sharded pipeline produces the same bits as the
    single-device one (cluster padding only appends always-False slots);
  * the kNN radius schedule is deterministic doubling from a
    pivot-distance seed: round t's radius is ``radii · 2^t``, which is
    what lets the paged backend construct round t+1's IOPlan *before*
    round t's refinement finishes (``repro.storage.prefetch``) and the
    resident backend run the whole schedule inside one compiled
    ``lax.while_loop`` (DESIGN.md §8).

Guard-band constants live here because they are plan semantics: the
plan's masks must be a certified superset of the host's exact candidate
sets (DESIGN.md §3), and every consumer widens/narrows by the same
bands.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..obs import registry as _obs
from ..obs.trace import span

# f32 guard bands: rank math and distances run in f64 on the host; the
# device path inflates radii so rounding can never exclude a true result
# (the final f64 refinement removes the extras).
_R_REL = 1e-5       # relative radius inflation for the ring box
_R_ABS = 1e-4       # absolute radius inflation for the ring box
_BALL_ABS = 1e-3    # absolute inflation for the distance-ball prefilter
# seed-radius inflation: pivot/k-th distances are f32, the schedule base
# is f64 — the same margin both pre-refactor kNN drivers applied
_SEED_REL = 1e-3
# compacted-gather payoff bound: when the union candidate set exceeds
# this fraction of the slot array, gathering survivors moves more bytes
# than the full-array filter saves (the power-of-two bucket would cover
# most of the slots anyway) and the plan reports "don't compact"
_COMPACT_MAX_FRAC = 0.5


def plan_arrays(qf, rf, snap, n_rings: int, fused: bool | None = None):
    """The pure device plan math: (B, K·n_max) candidate mask + (B, K)
    cluster routing, written against a (possibly shard-local) snapshot
    pytree so the single-device executor and every ``shard_map`` shard
    run literally the same code.

    Staged path: one ``pdist`` launch gives query→pivot distances
    (TriPrune + AreaLocate inputs); one ``rankeval`` launch evaluates all
    K·m rank models on the lo/hi annulus boundaries of the whole batch,
    laid out (G, 2B).  On the compiled lanes (``fused=None`` defers to
    ``dispatch.fused_plan_enabled``) both collapse into the single
    ``ops.pdist_rankeval`` launch — bit-identical within a lane, pinned
    by tests.  Either way the predicted ring box is widened by the
    certified per-group rank-error bound so it is a guaranteed superset
    of the host's box.
    """
    B = qf.shape[0]
    K, n_max, m = snap.rids.shape
    d = snap.rows.shape[-1]
    N = n_rings
    r_g = rf * (1.0 + _R_REL) + _R_ABS                      # (B,)
    if fused is None:
        fused = ops.fused_plan_enabled()
    G = K * m
    if fused:
        dq, rank_lo, rank_hi = ops.pdist_rankeval(
            qf, snap.pivots.reshape(G, d), snap.coef.reshape(G, -1),
            snap.model_lo.reshape(-1), snap.model_hi.reshape(-1),
            snap.model_n.reshape(-1), r_g, n_rings=N)
    else:
        dq = jnp.sqrt(jnp.maximum(
            ops.pdist(qf, snap.pivots.reshape(G, d)), 0.0))
        # one rankeval launch: G groups × (lo | hi) boundaries of all B
        x = jnp.concatenate([(dq - r_g[:, None]).T,
                             (dq + r_g[:, None]).T], axis=1)  # (G, 2B)
        rank, _ = ops.rankeval(
            x, snap.coef.reshape(G, -1), snap.model_lo.reshape(-1),
            snap.model_hi.reshape(-1), snap.model_n.reshape(-1),
            n_rings=N)
        rank_lo, rank_hi = rank[:, :B], rank[:, B:]
    dqr = dq.reshape(B, K, m)
    # TriPrune, per query per (local) cluster
    alive = jnp.all((dqr <= snap.dmax[None] + r_g[:, None, None]) &
                    (dqr >= snap.dmin[None] - r_g[:, None, None]),
                    axis=-1) & (snap.ns[None] > 0)          # (B, K)
    err = snap.rank_err.reshape(-1)[:, None]                # (G, 1)
    lo_rank = jnp.maximum(rank_lo.astype(jnp.float32) - err, 0.0)
    hi_rank = rank_hi.astype(jnp.float32) + err
    w = snap.width[None, :, None].astype(jnp.float32)
    rid_lo = jnp.clip(jnp.floor(lo_rank.T.reshape(B, K, m) / w),
                      0, N - 1).astype(jnp.int32)
    rid_hi = jnp.clip(jnp.floor(hi_rank.T.reshape(B, K, m) / w),
                      0, N - 1).astype(jnp.int32)
    box = jnp.all((snap.rids[None] >= rid_lo[:, :, None, :]) &
                  (snap.rids[None] <= rid_hi[:, :, None, :]),
                  axis=-1)                                  # (B, K, n_max)
    cand = (box & alive[:, :, None] & snap.in_ring[None]) | \
        snap.always[None]
    cand = cand & snap.valid[None]
    return cand.reshape(B, K * n_max), alive


@dataclass(eq=False)
class CandidatePlan:
    """One query batch's certified plan, built once and consumed by
    whichever execution backend runs the batch.

    ``radii`` are the round-0 radii (a range query's actual radii; a kNN
    batch's pivot-distance seeds) and ``growth`` the deterministic
    per-round multiplier (1 for range — there is only round 0).  The
    candidate mask and cluster routing are evaluated lazily through the
    owning planner's device pipeline and cached, so a backend that never
    needs the host copy (the resident kNN loop keeps everything on
    device) never pays the transfer — while two backends sharing the
    plan still share one evaluation.
    """

    kind: str                    # "range" | "knn"
    B: int                       # batch size
    k: int | None                # kNN k (clamped to live); None for range
    max_rounds: int              # schedule length
    growth: float                # radius multiplier per round
    radii: np.ndarray            # (B,) f64 round-0 radii
    _planner: "Planner" = field(repr=False, default=None)
    _qf: jax.Array = field(repr=False, default=None)
    _dev: tuple | None = field(repr=False, default=None)
    _mask_np: np.ndarray | None = field(repr=False, default=None)
    _routing_np: np.ndarray | None = field(repr=False, default=None)
    # cached compacted-gather decision: None = not evaluated yet,
    # (slots,) = dense gather indices, (None,) = union too large to pay
    _compact: tuple | None = field(repr=False, default=None)
    # page arrays the paged backend pinned for this plan's execution;
    # drained by the executor's release (finally) — never shared across
    # plans, so a router subset starts with its own empty ledger
    _pins: list = field(repr=False, default_factory=list)
    # wall seconds the planner spent constructing this plan — travels
    # with the plan so whichever executor runs it can charge the plan
    # stage in its QueryProfile (a router subset inherits it: the
    # replica executes a slice of the same single construction)
    plan_s: float = 0.0

    @property
    def qf(self) -> jax.Array:
        """(B, d) f32 device queries (shared by every plan consumer)."""
        return self._qf

    def radius_at(self, t: int) -> np.ndarray:
        """(B,) f64 schedule radii for round ``t`` — known for every
        round the moment the plan exists (what prefetch relies on)."""
        return self.radii * (self.growth ** t)

    def _device(self) -> tuple:
        if self._dev is None:
            rf = jnp.asarray(self.radii, jnp.float32)
            self._dev = self._planner.ex._plan_arrays(self._qf, rf)
        return self._dev

    @property
    def mask_dev(self) -> jax.Array:
        """(B, P) bool device candidate mask at round 0."""
        return self._device()[0]

    @property
    def routing_dev(self) -> jax.Array:
        """(B, K) bool device TriPrune cluster routing at round 0."""
        return self._device()[1]

    @property
    def mask(self) -> np.ndarray:
        """Host copy of :attr:`mask_dev` (materialized once)."""
        if self._mask_np is None:
            self._mask_np = np.asarray(self.mask_dev)
            self._planner.ex._count_sync()
        return self._mask_np

    @property
    def routing(self) -> np.ndarray:
        """Host copy of :attr:`routing_dev` (materialized once)."""
        if self._routing_np is None:
            self._routing_np = np.asarray(self.routing_dev)
            self._planner.ex._count_sync()
        return self._routing_np

    def compact_slots(self) -> np.ndarray | None:
        """The plan's compacted row-index gather: sorted flat slot ids
        of the *union* certified candidate set at round-0 radii, or
        None when compaction cannot pay (union > ``_COMPACT_MAX_FRAC``
        of the slots — streaming the full padded array is cheaper than
        gather + dense filter would save).

        This is the memory-roofline half of the plan (DESIGN.md §13):
        the resident backend gathers exactly these rows once into a
        power-of-two bucket (the paged path's compile-churn bucketing)
        and runs the ball prefilter over the dense array, so filter
        bytes scale with TriPrune's surviving candidates instead of
        with the padded slot count.  Certification is untouched — the
        union is read off the already-certified mask, every
        non-listed slot is a non-candidate for every query in the
        batch, and per-pair kernel math is independent of which rows
        share a launch.  Cached with the host mask it derives from.
        """
        if self._compact is None:
            mask = self.mask
            slots = np.nonzero(mask.any(axis=0))[0]
            limit = int(mask.shape[1] * _COMPACT_MAX_FRAC)
            self._compact = (None,) if slots.size > limit else (slots,)
        return self._compact[0]

    def subset(self, idx: np.ndarray, planner: "Planner | None" = None,
               device=None) -> "CandidatePlan":
        """The plan restricted to queries ``idx`` — what the router
        dispatches to a replica (one plan construction per batch still
        holds: a subset is a view, not a rebuild, and does not bump the
        planner's ``built`` counter).

        Per-query plan rows are independent of batchmates (every mask /
        routing / schedule row is a function of that query alone), so
        slicing the batch axis preserves certification exactly.  Host
        copies already materialized slice for free; device arrays are
        NOT carried over — the receiving executor re-evaluates them
        through its own pipeline (same math, its own device), with
        ``device`` placing the sliced queries there first.  ``planner``
        rebinds the subset to the replica executor that will run it.
        """
        idx = np.asarray(idx, np.int64)
        qf = self._qf[jnp.asarray(idx)]
        if device is not None:
            qf = jax.device_put(qf, device)
        return CandidatePlan(
            kind=self.kind, B=len(idx), k=self.k,
            max_rounds=self.max_rounds, growth=self.growth,
            radii=self.radii[idx],
            _planner=planner if planner is not None else self._planner,
            _qf=qf,
            _mask_np=None if self._mask_np is None else self._mask_np[idx],
            _routing_np=None if self._routing_np is None
            else self._routing_np[idx],
            plan_s=self.plan_s)


class Planner:
    """Builds :class:`CandidatePlan`s for one executor.

    ``built`` counts plan constructions — the acceptance criterion is
    exactly one per query batch (tests assert it), with per-round
    schedule evaluations going through :meth:`eval_mask` instead of
    rebuilding anything.
    """

    def __init__(self, executor):
        self.ex = executor
        self.built = 0

    # ------------------------------------------------------------ plans
    def plan_range(self, Q64: np.ndarray, r64: np.ndarray) -> CandidatePlan:
        """Single-round plan at the queries' own radii."""
        self.built += 1
        t0 = time.perf_counter()
        with span("planner.plan_range", {"B": int(Q64.shape[0])}):
            plan = CandidatePlan(
                kind="range", B=Q64.shape[0], k=None, max_rounds=1,
                growth=1.0, radii=np.array(r64, np.float64),
                _planner=self, _qf=jnp.asarray(Q64, jnp.float32))
        plan.plan_s = time.perf_counter() - t0
        _obs.count("planner.plans_built")
        return plan

    def plan_knn(self, Q64: np.ndarray, k_eff: int,
                 max_rounds: int) -> CandidatePlan:
        """Growing-radius plan seeded at the nearest live pivot.

        Pivots are data rows, so the seed ball is non-empty and doubling
        reaches the k-th ball in O(log) rounds; the seed uses only
        resident metadata (pivot payloads + validity masks), so resident
        and store-backed snapshots plan identically.  Clusters with no
        live slots (deleted out, or the inert padding a sharded snapshot
        carries) hold zero/stale pivot rows — mask them so they can't
        collapse the seed below any real point's distance.
        """
        self.built += 1
        t0 = time.perf_counter()
        with span("planner.plan_knn",
                  {"B": int(Q64.shape[0]), "k": int(k_eff)}):
            s = self.ex.snap
            qf = jnp.asarray(Q64, jnp.float32)
            K, n_max, m = s.rids.shape
            dq = np.asarray(jnp.sqrt(jnp.maximum(
                ops.pdist(qf, s.pivots.reshape(K * m, s.d)), 0.0)))
            self.ex._count_sync()
            live_k = s.valid_np.reshape(K, n_max).any(axis=1)       # (K,)
            dqm = np.where(np.repeat(live_k, m)[None], dq, np.inf)
            r0 = dqm.min(axis=1).astype(np.float64) * (1.0 + _SEED_REL) \
                + _BALL_ABS
            plan = CandidatePlan(
                kind="knn", B=Q64.shape[0], k=int(k_eff),
                max_rounds=int(max_rounds), growth=2.0, radii=r0,
                _planner=self, _qf=qf)
        plan.plan_s = time.perf_counter() - t0
        _obs.count("planner.plans_built")
        return plan

    # -------------------------------------------------- round evaluation
    def eval_mask(self, qf: jax.Array, radii: np.ndarray) -> np.ndarray:
        """(B, P) host candidate mask at explicit per-query radii — the
        paged backend's per-round schedule evaluation (the resident
        backend evaluates the same math on device, inside its loop)."""
        cand, _ = self.ex._plan_arrays(qf, jnp.asarray(radii, jnp.float32))
        self.ex._count_sync()
        _obs.count("planner.round_evals")
        return np.asarray(cand)


__all__ = ["CandidatePlan", "Planner", "plan_arrays"]
