"""Distance metrics for generic metric spaces.

Two execution paths:
  * vector metrics (L2 / squared-L2 / L1 / Linf / cosine) — batched jnp,
    jitted, MXU-friendly formulations (the Pallas kernels in
    ``repro.kernels`` implement the same math with explicit VMEM tiling);
  * generic metrics (edit distance over fixed-length strings) — vectorized
    numpy dynamic programming, host-side. LIMS only ever needs
    one-against-many distances, which is what these provide.

Every function returns *true* metric distances (so the triangle inequality
holds); squared L2 is exposed separately for callers that want to avoid the
sqrt (the Gram-trick kernel) and take responsibility for re-squaring radii.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

VECTOR_METRICS = ("l2", "l1", "linf", "cosine")
GENERIC_METRICS = ("edit",)


# ---------------------------------------------------------------------------
# jnp batched one-vs-many / many-vs-many distances
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("metric",))
def cdist(x: jax.Array, y: jax.Array, metric: str = "l2") -> jax.Array:
    """Pairwise distances between rows of ``x`` (nq, d) and ``y`` (np, d)."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if metric == "l2":
        # Gram trick: MXU does the heavy lifting; clamp for fp error.
        xn = jnp.sum(x * x, axis=-1, keepdims=True)
        yn = jnp.sum(y * y, axis=-1, keepdims=True)
        sq = xn + yn.T - 2.0 * (x @ y.T)
        return jnp.sqrt(jnp.maximum(sq, 0.0))
    if metric == "l1":
        return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
    if metric == "linf":
        return jnp.max(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
    if metric == "cosine":
        # angular distance = 1 - cos; NOT a metric in general, kept for
        # retrieval use only (LIMS proper requires a true metric).
        xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
        yn = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-12)
        return 1.0 - xn @ yn.T
    raise ValueError(f"unknown vector metric: {metric}")


def dist_one_to_many(q: np.ndarray, pts: np.ndarray, metric: str) -> np.ndarray:
    """Host-side one-vs-many distance in float64 with the *direct* (diff)
    formulation: bit-exact zeros for identical objects (point queries) and
    bounds that are consistent between build and query time. The Gram-trick
    f32 path is reserved for the many-vs-many TPU kernels where its MXU
    mapping pays off."""
    if metric == "edit":
        return edit_distance_one_to_many(np.asarray(q), np.asarray(pts))
    q = np.asarray(q, dtype=np.float64)
    pts = np.asarray(pts, dtype=np.float64)
    if metric == "l2":
        diff = pts - q
        return np.sqrt(np.einsum("nd,nd->n", diff, diff))
    if metric == "l1":
        return np.abs(pts - q).sum(axis=1)
    if metric == "linf":
        return np.abs(pts - q).max(axis=1)
    if metric == "cosine":
        qn = q / max(np.linalg.norm(q), 1e-12)
        pn = pts / np.maximum(np.linalg.norm(pts, axis=1, keepdims=True), 1e-12)
        return 1.0 - pn @ qn
    raise ValueError(f"unknown metric: {metric}")


# ---------------------------------------------------------------------------
# Edit (Levenshtein) distance, vectorized across candidates.
# Strings are encoded as fixed-length int arrays (the paper's Signature
# dataset uses 65-letter strings).
# ---------------------------------------------------------------------------
def edit_distance_one_to_many(q: np.ndarray, pts: np.ndarray) -> np.ndarray:
    """Levenshtein distance from ``q`` (la,) to each row of ``pts`` (n, lb).

    Classic DP with the row dimension vectorized over all candidates; the
    inner scan over the candidate-string position is sequential because of
    the dp[j-1] dependency, so the loop nest is la * lb numpy steps on
    n-vectors.
    """
    q = np.asarray(q)
    pts = np.atleast_2d(np.asarray(pts))
    n, lb = pts.shape
    la = q.shape[0]
    # dp[j] = edit distance between q[:i] and pts[:, :j]
    dp = np.broadcast_to(np.arange(lb + 1, dtype=np.int32), (n, lb + 1)).copy()
    for i in range(1, la + 1):
        prev_diag = dp[:, 0].copy()          # dp[i-1][j-1]
        dp[:, 0] = i
        for j in range(1, lb + 1):
            cur = dp[:, j].copy()            # dp[i-1][j]
            sub = prev_diag + (pts[:, j - 1] != q[i - 1])
            dp[:, j] = np.minimum(np.minimum(cur + 1, dp[:, j - 1] + 1), sub)
            prev_diag = cur
    return dp[:, lb].astype(np.float64)


def edit_distance(a: np.ndarray, b: np.ndarray) -> float:
    return float(edit_distance_one_to_many(a, b[None, :])[0])


# ---------------------------------------------------------------------------
# MetricSpace: the object LIMS is built over.
# ---------------------------------------------------------------------------
class MetricSpace:
    """A dataset living in a metric space.

    ``data`` is an (n, d) float array for vector metrics, or an (n, L) int
    array of encoded strings for the edit metric. The API is purely
    one-vs-many / subset distance evaluation + a distance-computation
    counter (the paper's ``D`` cost term).
    """

    def __init__(self, data: np.ndarray, metric: str = "l2",
                 dist_fn: Callable | None = None):
        self.data = np.asarray(data)
        self.metric = metric
        self._custom = dist_fn
        self.n = self.data.shape[0]
        self.dist_count = 0
        if metric not in VECTOR_METRICS + GENERIC_METRICS and dist_fn is None:
            raise ValueError(f"metric {metric!r} needs an explicit dist_fn")

    @property
    def is_vector(self) -> bool:
        return self.metric in VECTOR_METRICS

    def reset_counter(self) -> None:
        self.dist_count = 0

    def dist(self, q: np.ndarray, idx: np.ndarray | None = None) -> np.ndarray:
        """Distances from query object ``q`` to data[idx] (or all)."""
        pts = self.data if idx is None else self.data[idx]
        self.dist_count += len(pts)
        if self._custom is not None:
            return np.asarray([self._custom(q, p) for p in pts])
        return dist_one_to_many(q, pts, self.metric)

    def dist_points(self, i: int, idx: np.ndarray | None = None) -> np.ndarray:
        return self.dist(self.data[i], idx)

    def pair(self, a: np.ndarray, b: np.ndarray) -> float:
        self.dist_count += 1
        if self._custom is not None:
            return float(self._custom(a, b))
        return float(dist_one_to_many(a, b[None, :], self.metric)[0])

    def record_nbytes(self) -> int:
        return int(self.data[0].nbytes)
