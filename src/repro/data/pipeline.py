"""Deterministic sharded synthetic-token pipeline with host prefetch.

Every (step, data-shard) pair maps to a counter-based RNG stream, so:
  * restarts resume mid-stream exactly (fault tolerance — the iterator
    state IS the step number, checkpointed for free);
  * each data-parallel host generates only its slice (no cross-host IO);
  * a straggler that skips a step stays consistent with the fleet.

The generator produces Zipf-distributed token documents packed into fixed
sequences — enough structure for a ~100M-param model to show a real
learning curve (EXAMPLES: train_lm.py).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig, ShapeCell


@dataclass
class DataShard:
    shard_id: int
    n_shards: int


def _batch_for_step(step: int, shard: DataShard, vocab: int, batch: int,
                    seq: int, seed: int = 1234) -> dict:
    """Counter-based deterministic batch (shard-local slice)."""
    local = batch // shard.n_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard.shard_id]))
    # Zipf-ish unigram with markov-ish bigram structure: token t+1 depends
    # on t via a cheap hash so the LM has something learnable
    base = rng.zipf(1.3, size=(local, seq + 1)).astype(np.int64)
    base = base % (vocab - 2) + 1
    mix = (base[:, :-1] * 2654435761 % (vocab - 2) + 1)
    keep = rng.random((local, seq)) < 0.5
    nxt = np.where(keep, mix, base[:, 1:])
    tokens = base[:, :-1]
    labels = nxt
    return {"tokens": tokens.astype(np.int32),
            "labels": labels.astype(np.int32)}


class TokenPipeline:
    """Iterator with background prefetch; resume via ``start_step``."""

    def __init__(self, cfg: ModelConfig, cell: ShapeCell,
                 shard: Optional[DataShard] = None, start_step: int = 0,
                 prefetch: int = 2, seed: int = 1234):
        self.cfg = cfg
        self.cell = cell
        self.shard = shard or DataShard(0, 1)
        self.step = start_step
        self.seed = seed
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            b = _batch_for_step(step, self.shard, self.cfg.vocab,
                                self.cell.global_batch, self.cell.seq_len,
                                self.seed)
            extra = _extra_inputs(self.cfg, self.cell, step, self.seed)
            b.update(extra)
            try:
                self._q.put((step, b), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            step, b = self._q.get()
            if step < self.step:     # stale after a skip
                continue
            self.step = step + 1
            return b

    def skip_to(self, step: int) -> None:
        """Straggler mitigation: jump the stream forward."""
        self.step = step

    def close(self):
        self._stop.set()


def _extra_inputs(cfg: ModelConfig, cell: ShapeCell, step: int,
                  seed: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 777]))
    out = {}
    if cfg.family == "vlm" and cfg.n_prefix_embeds:
        out["prefix_embeds"] = rng.normal(
            0, 0.02, (cell.global_batch, cfg.n_prefix_embeds,
                      cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        out["src_embeds"] = rng.normal(
            0, 0.02, (cell.global_batch, cell.seq_len,
                      cfg.d_model)).astype(np.float32)
    return out
