"""Dataset generators following the paper's §6.1.1 recipes.

No network access: Color-Histogram and Forest-Cover-Type are replaced by
distribution-matched synthetic stand-ins (marked `*_like`); GaussMix,
Skewed and Signature follow the paper's published generators verbatim
(scaled by the caller to the CPU budget).
"""
from __future__ import annotations

import numpy as np

ALPHABET = 26
SIG_LEN = 65


def gauss_mix(n: int, d: int, n_components: int = 150, std: float = 0.05,
              seed: int = 0) -> np.ndarray:
    """iDistance-style GaussMix: `n_components` normals, sigma=0.05,
    uniform-random means, values normalized to [0, 1]."""
    rng = np.random.default_rng(seed)
    means = rng.uniform(0.0, 1.0, size=(n_components, d))
    comp = rng.integers(0, n_components, size=n)
    x = means[comp] + rng.normal(0.0, std, size=(n, d))
    return np.clip(x, 0.0, 1.0).astype(np.float64)


def skewed(n: int, d: int, seed: int = 0) -> np.ndarray:
    """RSMI-style Skewed: uniform data with dim i raised to power i+1
    ((x1, x2^2, ..., xd^d)); L1 norm is the paper's metric for it."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, 1.0, size=(n, d))
    powers = np.arange(1, d + 1, dtype=np.float64)
    return np.power(x, powers[None, :])


def signature(n_anchors: int = 25, per_anchor: int = 4000,
              seed: int = 0) -> np.ndarray:
    """Signature dataset: 65-letter strings; each anchor spawns a cluster by
    mutating x ~ U[1,30] positions to random other letters. Returns (n, 65)
    int8-encoded strings for the edit metric."""
    rng = np.random.default_rng(seed)
    anchors = rng.integers(0, ALPHABET, size=(n_anchors, SIG_LEN))
    out = np.empty((n_anchors * per_anchor, SIG_LEN), dtype=np.int8)
    row = 0
    for a in anchors:
        for _ in range(per_anchor):
            s = a.copy()
            x = int(rng.integers(1, 31))
            pos = rng.choice(SIG_LEN, size=x, replace=False)
            # change to *other* random letters
            shift = rng.integers(1, ALPHABET, size=x)
            s[pos] = (s[pos] + shift) % ALPHABET
            out[row] = s
            row += 1
    return out


def color_histogram_like(n: int = 50_000, d: int = 32, seed: int = 0) -> np.ndarray:
    """Stand-in for the ImageNet color-histogram features: sparse-ish,
    positively skewed, correlated mixture in 32-d, rows on the simplex."""
    rng = np.random.default_rng(seed)
    k = 40
    centers = rng.dirichlet(np.full(d, 0.4), size=k)
    comp = rng.integers(0, k, size=n)
    noise = rng.gamma(0.8, 0.02, size=(n, d))
    x = centers[comp] * rng.uniform(0.5, 1.5, size=(n, 1)) + noise
    x /= x.sum(axis=1, keepdims=True)
    return x.astype(np.float64)


def forest_like(n: int = 60_000, seed: int = 0) -> np.ndarray:
    """Stand-in for 6 quantitative Forest-Cover-Type variables: correlated,
    mixed-scale cartographic measurements, normalized to [0, 1]."""
    rng = np.random.default_rng(seed)
    elev = rng.normal(0.55, 0.18, size=n)
    slope = np.abs(rng.normal(0.25, 0.12, size=n)) + 0.1 * elev
    aspect = rng.uniform(0, 1, size=n)
    h_dist = np.abs(rng.normal(0.3, 0.2, size=n)) + 0.2 * slope
    v_dist = h_dist * rng.uniform(0.2, 0.8, size=n)
    shade = 0.6 * aspect + 0.4 * rng.uniform(0, 1, size=n)
    x = np.stack([elev, aspect, slope, h_dist, v_dist, shade], axis=1)
    x -= x.min(axis=0)
    x /= np.maximum(x.max(axis=0), 1e-9)
    return x


def dataset_by_name(name: str, n: int, d: int = 8, seed: int = 0):
    """(data, metric) factory used by benchmarks."""
    if name == "gaussmix":
        return gauss_mix(n, d, seed=seed), "l2"
    if name == "skewed":
        return skewed(n, d, seed=seed), "l1"
    if name == "signature":
        per = max(1, n // 25)
        return signature(25, per, seed=seed), "edit"
    if name == "colorhist":
        return color_histogram_like(n, seed=seed), "l2"
    if name == "forest":
        return forest_like(n, seed=seed), "l2"
    raise ValueError(name)
