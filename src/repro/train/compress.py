"""Gradient compression: int8 with stochastic rounding.

On a real multi-pod fabric this wraps the cross-pod all-reduce (compress →
reduce → decompress), cutting inter-pod collective bytes 4×; under pjit
the all-reduce is XLA-inserted, so we apply the transform to the gradient
pytree at the same point in the step — the quantization error model (and
the roofline collective-bytes accounting in EXPERIMENTS.md) is identical.
Stochastic rounding keeps the compression unbiased: E[q] = g.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress_decompress(grads, key: jax.Array):
    """Quantize every gradient leaf to int8 (per-tensor scale, stochastic
    rounding) and dequantize — the numerical effect of a compressed
    all-reduce."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))

    def one(g, k):
        gf = g.astype(jnp.float32)
        scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-20
        x = gf / scale
        lo = jnp.floor(x)
        frac = x - lo
        up = jax.random.uniform(k, x.shape) < frac
        q = jnp.clip(lo + up.astype(jnp.float32), -127, 127)
        return (q * scale).astype(g.dtype)

    return jax.tree.unflatten(treedef, [one(g, k)
                                        for g, k in zip(leaves, keys)])
