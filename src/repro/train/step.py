"""The training step: loss → grad → clip → (compress) → optimizer update.

Built once per (ModelConfig, RunConfig); the returned function is pure and
jit-friendly, with TrainState a plain pytree so pjit shards it by the
embedded NamedShardings (params rules + mirrored optimizer state).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..models import zoo
from .compress import int8_compress_decompress
from .optim import OPTIMIZERS, lr_schedule


def init_state(cfg: ModelConfig, run: RunConfig, params):
    opt_init, _ = OPTIMIZERS[run.optimizer]
    return {
        "params": params,
        "opt": opt_init(params),
        "step": jnp.zeros((), jnp.int32),
        "rng": jax.random.PRNGKey(0),
    }


def abstract_state(cfg: ModelConfig, run: RunConfig, specs, mesh=None,
                   rules=None):
    """ShapeDtypeStruct TrainState with shardings — the dry-run input.

    Optimizer moments inherit the param's sharding (same shape); Adafactor
    row/col factors shard by the param's remaining logical axes. Nothing is
    allocated.
    """
    import numpy as np
    from ..models.params import ParamSpec, abstract_params
    from ..sharding.logical import guarded_sharding
    from .optim import Q_BLOCK

    def sds(shape, dtype, axes):
        if mesh is not None:
            return jax.ShapeDtypeStruct(
                shape, jnp.dtype(dtype),
                sharding=guarded_sharding(shape, axes, rules, mesh))
        return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))

    params = abstract_params(specs, cfg.dtype, mesh, rules)
    is_spec = lambda x: isinstance(x, ParamSpec)

    # ZeRO-1: optimizer state (and grad accumulators) shard over the data
    # axis through the embed dimension, while weights stay TP-only
    if run.zero1 and rules is not None:
        rules = dict(rules)
        if rules.get("embed") is None:
            rules["embed"] = "data"

    if run.optimizer == "adamw":
        mom = lambda s: sds(s.shape, "float32", s.axes)
        opt = {"mu": jax.tree.map(mom, specs, is_leaf=is_spec),
               "nu": jax.tree.map(mom, specs, is_leaf=is_spec),
               "count": sds((), "int32", ())}
    elif run.optimizer == "adafactor":
        def fac(s: ParamSpec):
            if len(s.shape) >= 2:
                return {"vr": sds(s.shape[:-1], "float32", s.axes[:-1]),
                        "vc": sds(s.shape[:-2] + s.shape[-1:], "float32",
                                  s.axes[:-2] + s.axes[-1:])}
            return {"v": sds(s.shape, "float32", s.axes)}
        opt = {"v": jax.tree.map(fac, specs, is_leaf=is_spec),
               "count": sds((), "int32", ())}
    elif run.optimizer == "adamw8bit":
        def q(s: ParamSpec):
            n = int(np.prod(s.shape)) if s.shape else 1
            blocks = -(-n // Q_BLOCK)
            return {"mu_q": sds((blocks, Q_BLOCK), "int8", (None, None)),
                    "mu_s": sds((blocks,), "float32", (None,)),
                    "nu_q": sds((blocks, Q_BLOCK), "int8", (None, None)),
                    "nu_s": sds((blocks,), "float32", (None,))}
        opt = {"q": jax.tree.map(q, specs, is_leaf=is_spec),
               "count": sds((), "int32", ())}
    else:
        raise ValueError(run.optimizer)

    return {
        "params": params,
        "opt": opt,
        "step": sds((), "int32", ()),
        "rng": sds((2,), "uint32", (None,)),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in leaves))


def build_train_step(cfg: ModelConfig, run: RunConfig,
                     total_steps: int = 10_000,
                     dp_axes: tuple | None = None,
                     grad_shardings=None) -> Callable:
    """``dp_axes``: mesh axis names carrying data parallelism — the
    microbatch reshape needs an explicit re-constraint or XLA drops the
    batch sharding at the reshape (measured: 8× replicated compute).
    ``grad_shardings``: optional pytree of NamedShardings for the fp32
    grad accumulators (ZeRO-1: accumulate on optimizer shards, which turns
    the per-µb grad all-reduce into a reduce-scatter)."""
    loss_fn = zoo.loss_fn(cfg)
    _, opt_update = OPTIMIZERS[run.optimizer]
    sched = lr_schedule(run.learning_rate, total=total_steps)

    def constrain_grads(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s)
            if s is not None else g, tree, grad_shardings)

    def grads_of(params, batch):
        if run.microbatches <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # gradient accumulation: activation memory ÷ microbatches; the
        # fp32 grad accumulator is params-shaped (and params-sharded).
        mb = run.microbatches

        def split(x):
            y = x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
            if dp_axes:
                from jax.sharding import PartitionSpec as P
                spec = P(None, dp_axes, *([None] * (y.ndim - 2)))
                y = jax.lax.with_sharding_constraint(y, spec)
            return y

        mbs = jax.tree.map(split, batch)

        def body(acc, micro):
            g_acc, l_acc, m_acc = acc
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, micro)
            g_acc = constrain_grads(jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g))
            m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
            return (g_acc, l_acc + loss, m_acc), None

        g0 = constrain_grads(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        metrics0 = jax.eval_shape(
            lambda p, b: loss_fn(p, b)[1], params,
            jax.tree.map(lambda x: x[0], mbs))
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                          metrics0)
        (g, loss, metrics), _ = jax.lax.scan(
            body, (g0, jnp.float32(0.0), m0), mbs)
        inv = 1.0 / mb
        return (loss * inv,
                jax.tree.map(lambda x: x * inv, metrics)), \
            jax.tree.map(lambda x: x * inv, g)

    def train_step(state, batch):
        params = state["params"]
        (loss, metrics), grads = grads_of(params, batch)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, run.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        rng, sub = jax.random.split(state["rng"])
        if run.grad_compression == "int8":
            grads = int8_compress_decompress(grads, sub)
        lr = sched(state["step"])
        kw = {}
        if run.optimizer in ("adamw", "adamw8bit"):
            kw["weight_decay"] = run.weight_decay
        updates, opt = opt_update(grads, state["opt"], params, lr, **kw)
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, updates)
        new_state = {"params": params, "opt": opt,
                     "step": state["step"] + 1, "rng": rng}
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr, **metrics}
        return new_state, out

    return train_step
