"""Optimizers: AdamW (fp32 state), Adafactor (factored second moment — the
only way a 1T-param config fits a 256-chip pod), and 8-bit Adam (int8
block-quantized moments, the optimizer-state-compression distributed
trick). All states are pytrees mirroring the params, so they inherit the
params' NamedShardings under pjit (ZeRO-style state sharding for free).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Q_BLOCK = 256


# ----------------------------------------------------------------- schedule
def lr_schedule(base_lr: float, warmup: int = 100,
                total: int = 10_000) -> Callable:
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, 0.1 + 0.9 * cos)
    return fn


# ------------------------------------------------------------------- AdamW
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1 - b1 ** cf
    bc2 = 1 - b2 ** cf

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        return -lr * step, mu, nu

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    updates = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return updates, {"mu": mu, "nu": nu, "count": count}


# --------------------------------------------------------------- Adafactor
def adafactor_init(params):
    def one(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(one, params), "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, lr, *, decay=0.8, eps=1e-30,
                     clip=1.0, weight_decay=0.0):
    count = state["count"] + 1
    beta = 1.0 - count.astype(jnp.float32) ** (-decay)

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if g.ndim >= 2:
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :] /
                jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                            eps))
            nv = {"vr": vr, "vc": vc}
        else:
            nvv = beta * v["v"] + (1 - beta) * g2
            denom = jnp.sqrt(nvv)
            nv = {"v": nvv}
        u = g / jnp.maximum(denom, eps)
        # update clipping by RMS
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip)
        u = u + weight_decay * p.astype(jnp.float32)
        return -lr * u, nv

    out = jax.tree.map(upd, grads, state["v"], params,
                       is_leaf=lambda x: isinstance(x, dict) and
                       ("vr" in x or "v" in x))
    # out mirrors params-with-tuples
    updates = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    return updates, {"v": v, "count": count}


# -------------------------------------------------------------- 8-bit Adam
def _q_shape(p):
    n = int(np.prod(p.shape)) if p.shape else 1
    blocks = -(-n // Q_BLOCK)
    return n, blocks


def quantize_blockwise(x: jax.Array):
    """fp32 → (int8 codes, fp32 per-block scales). Symmetric linear."""
    n = x.size
    blocks = -(-n // Q_BLOCK)
    flat = jnp.pad(x.reshape(-1), (0, blocks * Q_BLOCK - n)) \
        .reshape(blocks, Q_BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.rint(flat / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_blockwise(q: jax.Array, scale: jax.Array, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = int(np.prod(shape)) if shape else 1
    return flat[:n].reshape(shape)


def adam8bit_init(params):
    def one(p):
        n, blocks = _q_shape(p)
        return {"mu_q": jnp.zeros((blocks, Q_BLOCK), jnp.int8),
                "mu_s": jnp.zeros((blocks,), jnp.float32),
                "nu_q": jnp.zeros((blocks, Q_BLOCK), jnp.int8),
                "nu_s": jnp.zeros((blocks,), jnp.float32)}
    return {"q": jax.tree.map(one, params), "count": jnp.zeros((), jnp.int32)}


def adam8bit_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                    weight_decay=0.1):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1 - b1 ** cf
    bc2 = 1 - b2 ** cf

    def upd(g, q, p):
        g = g.astype(jnp.float32)
        mu = dequantize_blockwise(q["mu_q"], q["mu_s"], g.shape)
        nu = dequantize_blockwise(q["nu_q"], q["nu_s"], g.shape)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step = (mu / bc1) / (jnp.sqrt(jnp.maximum(nu, 0.0) / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        mu_q, mu_s = quantize_blockwise(mu)
        nu_q, nu_s = quantize_blockwise(nu)
        return (-lr * step, {"mu_q": mu_q, "mu_s": mu_s,
                             "nu_q": nu_q, "nu_s": nu_s})

    is_q = lambda x: isinstance(x, dict) and "mu_q" in x
    out = jax.tree.map(upd, grads, state["q"], params, is_leaf=is_q)
    updates = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    q = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    return updates, {"q": q, "count": count}


OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
    "adamw8bit": (adam8bit_init, adam8bit_update),
}
