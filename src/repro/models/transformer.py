"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

One definition, scan-over-layers (stacked params ⇒ small HLO at 512
devices), configurable remat, logical-axis annotations on every param.
Modes:
  * train:   tokens+labels → (loss, metrics)
  * prefill: tokens → (last-position logits, KV/SSM cache)
  * decode:  one token + cache → (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (apply_rope, attention_specs, chunked_attention,
                     decode_attention, dense_attention, mlp_specs, rmsnorm,
                     rope_tables, swiglu)
from .mamba2 import (mamba_decode, mamba_dims, mamba_forward, mamba_specs)
from .moe import moe_ffn, moe_specs
from .params import ParamSpec


# --------------------------------------------------------------- specs
def _stack(specs: dict, n: int) -> dict:
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                            s.scale, s.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def block_specs(cfg: ModelConfig) -> dict:
    """One transformer block (attention + FFN/MoE)."""
    d = cfg.d_model
    sp = {
        "attn_norm": ParamSpec((d,), ("embed_noshard",), init="ones",
                               dtype="float32"),
        "attn": attention_specs(d, cfg.n_q_heads, cfg.n_kv_heads, cfg.hd),
        "mlp_norm": ParamSpec((d,), ("embed_noshard",), init="ones",
                              dtype="float32"),
    }
    if cfg.moe is not None:
        sp["moe"] = moe_specs(cfg)
    else:
        sp["mlp"] = mlp_specs(d, cfg.d_ff)
    return sp


def mamba_block_specs(cfg: ModelConfig) -> dict:
    return {
        "norm": ParamSpec((cfg.d_model,), ("embed_noshard",), init="ones",
                          dtype="float32"),
        "mixer": mamba_specs(cfg),
    }


def model_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab
    sp: dict = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="normal"),
        "final_norm": ParamSpec((d,), ("embed_noshard",), init="ones",
                                dtype="float32"),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    if cfg.family in ("dense", "moe", "vlm"):
        sp["layers"] = _stack(block_specs(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        sp["layers"] = _stack(mamba_block_specs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        n_inv = cfg.n_layers // cfg.shared_every
        sp["layers"] = _stack(mamba_block_specs(cfg), cfg.n_layers)
        sp["shared"] = block_specs(cfg)          # ONE shared block
        sp["shared_proj"] = ParamSpec((n_inv, 2 * d, d),
                                      ("layers", "embed", "embed_noshard"))
    else:
        raise ValueError(cfg.family)
    return sp


# --------------------------------------------------------------- blocks
def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=None)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def attn_block(p: dict, x: jax.Array, cfg: ModelConfig, cos, sin,
               q0=0) -> jax.Array:
    """Full-sequence attention sub-block (pre-norm, residual outside)."""
    xn = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", xn, p["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xn, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xn, p["attn"]["wv"])
    rot = int(cfg.hd * cfg.partial_rotary)
    q = apply_rope(q, cos, sin, rot)
    k = apply_rope(k, cos, sin, rot)
    sq = x.shape[1]
    if cfg.attn_impl == "dense" or sq <= cfg.attn_chunk:
        o = dense_attention(q, k, v, q0=q0, causal=True,
                            window=cfg.sliding_window)
    else:
        ck = min(cfg.attn_chunk, sq)
        o = chunked_attention(q, k, v, q0=q0, causal=True,
                              window=cfg.sliding_window,
                              chunk_q=ck, chunk_k=ck)
    return jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"]), (k, v)


def ffn_block(p: dict, x: jax.Array, cfg: ModelConfig):
    xn = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_ffn(p["moe"], xn, cfg)
        return y, aux
    return swiglu(p["mlp"], xn), {"load_balance": jnp.float32(0.0),
                                  "router_z": jnp.float32(0.0)}


def transformer_layer(p, x, cfg: ModelConfig, cos, sin, q0=0):
    a, kv = attn_block(p, x, cfg, cos, sin, q0)
    x = x + a
    f, aux = ffn_block(p, x, cfg)
    return (x + f).astype(x.dtype), aux, kv


# --------------------------------------------- full-sequence forward pass
def _embed(params, tokens, cfg: ModelConfig,
           prefix_embeds: Optional[jax.Array]):
    x = params["embed"][tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def _unembed(params, x, cfg: ModelConfig):
    xn = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", xn, params["embed"],
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bsd,dv->bsv", xn, params["lm_head"],
                      preferred_element_type=jnp.float32)


def forward_seq(params, tokens, cfg: ModelConfig,
                prefix_embeds: Optional[jax.Array] = None,
                collect_cache: bool = False):
    """Full-sequence forward. Returns (hidden, aux, cache_kv or None)."""
    x = _embed(params, tokens, cfg, prefix_embeds)
    s = x.shape[1]
    positions = jnp.arange(s)
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = rope_tables(positions, rot, cfg.rope_theta)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, lp):
            h = carry
            out, aux, kv = transformer_layer(lp, h, cfg, cos, sin)
            ys = (aux, kv) if collect_cache else (aux, None)
            return out, ys
        body = _remat(body, cfg)
        if cfg.scan_layers:
            x, (auxs, kvs) = jax.lax.scan(body, x, params["layers"])
            aux = jax.tree.map(lambda a: jnp.sum(a), auxs)
        else:
            auxs, kvs_l = [], []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda t: t[i], params["layers"])
                x, (a, kv) = body(x, lp)
                auxs.append(a)
                kvs_l.append(kv)
            aux = jax.tree.map(lambda *a: jnp.sum(jnp.stack(a)), *auxs)
            kvs = (jax.tree.map(lambda *t: jnp.stack(t), *kvs_l)
                   if collect_cache else None)
        return x, aux, kvs

    if cfg.family == "ssm":
        def body(carry, lp):
            h = carry
            y, _ = mamba_forward(lp["mixer"],
                                 rmsnorm(h, lp["norm"], cfg.norm_eps), cfg)
            return (h + y).astype(h.dtype), None
        body = _remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, _zero_aux(), None

    if cfg.family == "hybrid":
        return _hybrid_forward_seq(params, x, cfg, cos, sin)

    raise ValueError(cfg.family)


def _zero_aux():
    return {"load_balance": jnp.float32(0.0), "router_z": jnp.float32(0.0)}


def _hybrid_forward_seq(params, x, cfg: ModelConfig, cos, sin):
    """Zamba2-style: scan over super-blocks of `shared_every` mamba layers
    followed by one invocation of the SHARED attention block (weights
    common, per-invocation concat down-projection)."""
    k = cfg.shared_every
    n_inv = cfg.n_layers // k
    x0 = x                                 # residual stream of embeddings
    stacked = params["layers"]
    grouped = jax.tree.map(
        lambda t: t.reshape((n_inv, k) + t.shape[1:]), stacked)
    shared = params["shared"]

    def super_block(carry, inp):
        h = carry
        mlayers, proj = inp

        def mamba_step(hc, lp):
            y, _ = mamba_forward(lp["mixer"],
                                 rmsnorm(hc, lp["norm"], cfg.norm_eps), cfg)
            return (hc + y).astype(hc.dtype), None
        h, _ = jax.lax.scan(mamba_step, h, mlayers)
        inp2 = jnp.concatenate([h, x0], axis=-1) @ proj
        a, _ = attn_block(shared, inp2, cfg, cos, sin)
        f, _ = ffn_block(shared, inp2 + a, cfg)
        h = (h + a + f).astype(h.dtype)
        return h, None

    super_block = _remat(super_block, cfg)
    x, _ = jax.lax.scan(super_block, x, (grouped, params["shared_proj"]))
    return x, _zero_aux(), None


# --------------------------------------------------------------- training
def lm_loss(params, batch: dict, cfg: ModelConfig):
    """batch: tokens (B,S) int32, labels (B,S) int32 (-1 = masked),
    optional prefix_embeds (B,P,D)."""
    prefix = batch.get("prefix_embeds")
    x, aux, _ = forward_seq(params, batch["tokens"], cfg, prefix)
    if prefix is not None:
        x = x[:, prefix.shape[1]:]
    logits = _unembed(params, x, cfg).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux["load_balance"] \
                    + cfg.moe.router_z_weight * aux["router_z"]
    return loss, {"nll": nll, **aux}


# ---------------------------------------------------------------- serving
def cache_spec(cfg: ModelConfig, batch: int, cache_len: int):
    """Logical description of the decode cache: {name: (shape, axes, dtype)}."""
    out = {}
    t = cache_len
    if cfg.sliding_window is not None:
        t = min(cache_len, cfg.sliding_window)
    if cfg.family in ("dense", "moe", "vlm"):
        kv = (cfg.n_layers, batch, t, cfg.n_kv_heads, cfg.hd)
        axes = ("layers", "batch", "kv_seq", "kv_heads", "qkv")
        out["k"] = (kv, axes, cfg.dtype)
        out["v"] = (kv, axes, cfg.dtype)
    elif cfg.family in ("ssm", "hybrid"):
        d_in, n_heads, conv_dim = mamba_dims(cfg)
        s = cfg.ssm
        out["conv"] = ((cfg.n_layers, batch, s.d_conv - 1, conv_dim),
                       ("layers", "batch", "conv", "ssm_inner"), cfg.dtype)
        out["ssm"] = ((cfg.n_layers, batch, n_heads, s.head_dim, s.d_state),
                      ("layers", "batch", "ssm_inner", "qkv", "ssm_state"),
                      "float32")
        if cfg.family == "hybrid":
            n_inv = cfg.n_layers // cfg.shared_every
            kv = (n_inv, batch, t, cfg.n_kv_heads, cfg.hd)
            axes = ("layers", "batch", "kv_seq", "kv_heads", "qkv")
            out["k"] = (kv, axes, cfg.dtype)
            out["v"] = (kv, axes, cfg.dtype)
    out["pos"] = ((), (), "int32")
    return out


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return {name: jnp.zeros(shape, jnp.dtype(dt)) if shape else
            jnp.zeros((), jnp.dtype(dt))
            for name, (shape, axes, dt) in
            cache_spec(cfg, batch, cache_len).items()}


def prefill(params, tokens, cfg: ModelConfig, cache_len: int,
            prefix_embeds: Optional[jax.Array] = None):
    """Run the prompt, return (last-token logits, populated cache)."""
    b, s = tokens.shape
    p_len = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    total = s + p_len
    cache = init_cache(cfg, b, cache_len)
    if cfg.family in ("dense", "moe", "vlm"):
        x, aux, kvs = forward_seq(params, tokens, cfg, prefix_embeds,
                                  collect_cache=True)
        k_new, v_new = kvs
        t = cache["k"].shape[2]
        if cfg.sliding_window is not None and total > t:
            # keep the last `t` positions, rotated so slot = pos % t
            k_tail = k_new[:, :, total - t:]
            v_tail = v_new[:, :, total - t:]
            shift = total % t
            k_tail = jnp.roll(k_tail, shift, axis=2)
            v_tail = jnp.roll(v_tail, shift, axis=2)
            cache["k"], cache["v"] = k_tail, v_tail
        else:
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new, 0, axis=2)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new, 0, axis=2)
        logits = _unembed(params, x[:, -1:], cfg)
    elif cfg.family == "ssm":
        x, logits, cache = _ssm_prefill(params, tokens, cfg, cache)
    elif cfg.family == "hybrid":
        x, logits, cache = _hybrid_prefill(params, tokens, cfg, cache)
    else:
        raise ValueError(cfg.family)
    cache["pos"] = jnp.asarray(total, jnp.int32)
    return logits, cache


def _ssm_prefill(params, tokens, cfg, cache):
    x = _embed(params, tokens, cfg, None)

    def body(carry, inp):
        h = carry
        lp, conv0, ssm0 = inp
        y, nc = mamba_forward(lp["mixer"],
                              rmsnorm(h, lp["norm"], cfg.norm_eps), cfg,
                              cache={"conv": conv0, "ssm": ssm0})
        return (h + y).astype(h.dtype), (nc["conv"], nc["ssm"])

    body = _remat(body, cfg)
    x, (convs, ssms) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"]))
    cache = dict(cache, conv=convs, ssm=ssms)
    logits = _unembed(params, x[:, -1:], cfg)
    return x, logits, cache


def _hybrid_prefill(params, tokens, cfg, cache):
    x = _embed(params, tokens, cfg, None)
    s = x.shape[1]
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = rope_tables(jnp.arange(s), rot, cfg.rope_theta)
    k = cfg.shared_every
    n_inv = cfg.n_layers // k
    x0 = x
    grouped = jax.tree.map(
        lambda t: t.reshape((n_inv, k) + t.shape[1:]), params["layers"])
    conv_g = cache["conv"].reshape((n_inv, k) + cache["conv"].shape[1:])
    ssm_g = cache["ssm"].reshape((n_inv, k) + cache["ssm"].shape[1:])
    shared = params["shared"]

    def super_block(carry, inp):
        h = carry
        mlayers, proj, conv0, ssm0 = inp

        def mamba_step(hc, lp_c):
            lp, c0, s0 = lp_c
            y, nc = mamba_forward(lp["mixer"],
                                  rmsnorm(hc, lp["norm"], cfg.norm_eps),
                                  cfg, cache={"conv": c0, "ssm": s0})
            return (hc + y).astype(hc.dtype), (nc["conv"], nc["ssm"])
        h, (convs, ssms) = jax.lax.scan(mamba_step, h,
                                        (mlayers, conv0, ssm0))
        inp2 = jnp.concatenate([h, x0], axis=-1) @ proj
        a, kv = attn_block(shared, inp2, cfg, cos, sin)
        f, _ = ffn_block(shared, inp2 + a, cfg)
        h = (h + a + f).astype(h.dtype)
        return h, (convs, ssms, kv)

    super_block = _remat(super_block, cfg)
    x, (convs, ssms, kvs) = jax.lax.scan(
        super_block, x, (grouped, params["shared_proj"], conv_g, ssm_g))
    cache = dict(cache)
    cache["conv"] = convs.reshape(cache["conv"].shape)
    cache["ssm"] = ssms.reshape(cache["ssm"].shape)
    k_new, v_new = kvs
    t = cache["k"].shape[2]
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new, 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new, 0, axis=2)
    logits = _unembed(params, x[:, -1:], cfg)
    return x, logits, cache


def decode_step(params, token, cache, cfg: ModelConfig):
    """token: (B,) int32 — the token at position cache['pos'].
    Returns (logits (B,1,V), new cache)."""
    pos = cache["pos"]
    x = params["embed"][token][:, None, :]                 # (B, 1, D)
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = rope_tables(pos[None], rot, cfg.rope_theta)

    if cfg.family in ("dense", "moe", "vlm"):
        # The KV cache rides in the scan CARRY and is updated in place
        # with a layer-indexed dynamic_update_slice: only the one-token
        # slot is written per layer. Passing per-layer caches through
        # xs/ys instead makes XLA re-stack a full layer cache every step
        # (~2× the entire cache in HBM traffic per token — measured).
        t = cache["k"].shape[2]
        slot = pos % t if cfg.sliding_window is not None else pos

        def body(carry, inp):
            h, kall, vall = carry
            lp, li = inp
            xn = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", xn, lp["attn"]["wq"])
            kn = jnp.einsum("bsd,dhk->bshk", xn, lp["attn"]["wk"])
            vn = jnp.einsum("bsd,dhk->bshk", xn, lp["attn"]["wv"])
            q = apply_rope(q, cos, sin, rot)
            kn = apply_rope(kn, cos, sin, rot)
            zero = jnp.zeros((), jnp.int32)
            kall = jax.lax.dynamic_update_slice(
                kall, kn[None].astype(kall.dtype),
                (li, zero, slot, zero, zero))
            vall = jax.lax.dynamic_update_slice(
                vall, vn[None].astype(vall.dtype),
                (li, zero, slot, zero, zero))
            kc = jax.lax.dynamic_index_in_dim(kall, li, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vall, li, 0, keepdims=False)
            o = decode_attention(q, kc, vc, pos,
                                 window=cfg.sliding_window)
            h = h + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            f, _ = ffn_block(lp, h, cfg)
            return ((h + f).astype(h.dtype), kall, vall), None

        li = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        (x, ks, vs), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]), (params["layers"], li))
        cache = dict(cache, k=ks, v=vs)
    elif cfg.family == "ssm":
        def body(carry, inp):
            h = carry
            lp, c0, s0 = inp
            y, nc = mamba_decode(lp["mixer"],
                                 rmsnorm(h, lp["norm"], cfg.norm_eps), cfg,
                                 {"conv": c0, "ssm": s0})
            return (h + y).astype(h.dtype), (nc["conv"], nc["ssm"])
        x, (convs, ssms) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]))
        cache = dict(cache, conv=convs, ssm=ssms)
    elif cfg.family == "hybrid":
        x, cache = _hybrid_decode(params, x, cache, cfg, cos, sin, pos)
    else:
        raise ValueError(cfg.family)

    logits = _unembed(params, x, cfg)
    cache["pos"] = pos + 1
    return logits, cache


def _hybrid_decode(params, x, cache, cfg, cos, sin, pos):
    k = cfg.shared_every
    n_inv = cfg.n_layers // k
    x0 = x
    grouped = jax.tree.map(
        lambda t: t.reshape((n_inv, k) + t.shape[1:]), params["layers"])
    conv_g = cache["conv"].reshape((n_inv, k) + cache["conv"].shape[1:])
    ssm_g = cache["ssm"].reshape((n_inv, k) + cache["ssm"].shape[1:])
    shared = params["shared"]
    rot = int(cfg.hd * cfg.partial_rotary)

    def super_block(carry, inp):
        h, kall, vall = carry
        mlayers, proj, c0, s0, ii = inp

        def mamba_step(hc, lp_c):
            lp, cc, ss = lp_c
            y, nc = mamba_decode(lp["mixer"],
                                 rmsnorm(hc, lp["norm"], cfg.norm_eps),
                                 cfg, {"conv": cc, "ssm": ss})
            return (hc + y).astype(hc.dtype), (nc["conv"], nc["ssm"])
        h, (convs, ssms) = jax.lax.scan(mamba_step, h, (mlayers, c0, s0))
        inp2 = jnp.concatenate([h, x0], axis=-1) @ proj
        xn = rmsnorm(inp2, shared["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", xn, shared["attn"]["wq"])
        kn = jnp.einsum("bsd,dhk->bshk", xn, shared["attn"]["wk"])
        vn = jnp.einsum("bsd,dhk->bshk", xn, shared["attn"]["wv"])
        q = apply_rope(q, cos, sin, rot)
        kn = apply_rope(kn, cos, sin, rot)
        zero = jnp.zeros((), jnp.int32)
        kall = jax.lax.dynamic_update_slice(
            kall, kn[None].astype(kall.dtype), (ii, zero, pos, zero, zero))
        vall = jax.lax.dynamic_update_slice(
            vall, vn[None].astype(vall.dtype), (ii, zero, pos, zero, zero))
        kc = jax.lax.dynamic_index_in_dim(kall, ii, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vall, ii, 0, keepdims=False)
        o = decode_attention(q, kc, vc, pos)
        a = jnp.einsum("bshk,hkd->bsd", o, shared["attn"]["wo"])
        f, _ = ffn_block(shared, inp2 + a, cfg)
        h = (h + a + f).astype(h.dtype)
        return (h, kall, vall), (convs, ssms)

    ii = jnp.arange(n_inv, dtype=jnp.int32)
    (x, ks, vs), (convs, ssms) = jax.lax.scan(
        super_block, (x, cache["k"], cache["v"]),
        (grouped, params["shared_proj"], conv_g, ssm_g, ii))
    cache = dict(cache)
    cache["conv"] = convs.reshape(cache["conv"].shape)
    cache["ssm"] = ssms.reshape(cache["ssm"].shape)
    cache["k"], cache["v"] = ks, vs
    return x, cache
