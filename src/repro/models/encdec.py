"""Encoder-decoder backbone (seamless-m4t style): bidirectional encoder
over stubbed modality-frontend frame embeddings + causal decoder with
cross-attention. The speech/text frontend is explicitly a stub per the
assignment — ``input_specs`` provides precomputed (B, S_src, D) frames.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (apply_rope, attention_specs, chunked_attention,
                     decode_attention, dense_attention, mlp_specs, rmsnorm,
                     rope_tables, swiglu)
from .params import ParamSpec
from .transformer import _remat, _stack


def enc_block_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "attn_norm": ParamSpec((d,), ("embed_noshard",), init="ones",
                               dtype="float32"),
        "attn": attention_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "mlp_norm": ParamSpec((d,), ("embed_noshard",), init="ones",
                              dtype="float32"),
        "mlp": mlp_specs(d, cfg.d_ff),
    }


def dec_block_specs(cfg: ModelConfig) -> dict:
    sp = enc_block_specs(cfg)
    d = cfg.d_model
    sp["cross_norm"] = ParamSpec((d,), ("embed_noshard",), init="ones",
                                 dtype="float32")
    sp["cross"] = attention_specs(d, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
    return sp


def encdec_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab
    n_dec = cfg.n_dec_layers or cfg.n_layers
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="normal"),
        "enc_final_norm": ParamSpec((d,), ("embed_noshard",), init="ones",
                                    dtype="float32"),
        "final_norm": ParamSpec((d,), ("embed_noshard",), init="ones",
                                dtype="float32"),
        "lm_head": ParamSpec((d, v), ("embed", "vocab")),
        "enc_layers": _stack(enc_block_specs(cfg), cfg.n_layers),
        "dec_layers": _stack(dec_block_specs(cfg), n_dec),
    }


def _attend(p, xq, xkv, cfg, cos_q, sin_q, cos_k, sin_k, causal,
            rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    rot = int(cfg.hd * cfg.partial_rotary)
    if rope:
        q = apply_rope(q, cos_q, sin_q, rot)
        k = apply_rope(k, cos_k, sin_k, rot)
    sq, t = xq.shape[1], xkv.shape[1]
    if cfg.attn_impl == "dense" or max(sq, t) <= cfg.attn_chunk:
        o = dense_attention(q, k, v, causal=causal)
    else:
        ck = min(cfg.attn_chunk, sq, t)
        sq_pad = (-sq) % ck
        t_pad = (-t) % ck
        assert sq_pad == 0 and t_pad == 0, (sq, t, ck)
        o = chunked_attention(q, k, v, causal=causal, chunk_q=ck,
                              chunk_k=ck)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (k, v)


def encode(params, src_embeds: jax.Array, cfg: ModelConfig) -> jax.Array:
    """src_embeds: (B, S_src, D) stub frontend output → encoder memory."""
    x = src_embeds.astype(jnp.dtype(cfg.dtype))
    s = x.shape[1]
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = rope_tables(jnp.arange(s), rot, cfg.rope_theta)

    def body(carry, lp):
        h = carry
        xn = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        a, _ = _attend(lp["attn"], xn, xn, cfg, cos, sin, cos, sin,
                       causal=False)
        h = h + a
        f = swiglu(lp["mlp"], rmsnorm(h, lp["mlp_norm"], cfg.norm_eps))
        return (h + f).astype(h.dtype), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def decode_train(params, memory: jax.Array, tokens: jax.Array,
                 cfg: ModelConfig) -> jax.Array:
    """Teacher-forced decoder over full target sequence → logits."""
    x = params["embed"][tokens]
    s = x.shape[1]
    sm = memory.shape[1]
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = rope_tables(jnp.arange(s), rot, cfg.rope_theta)
    cos_m, sin_m = rope_tables(jnp.arange(sm), rot, cfg.rope_theta)

    def body(carry, lp):
        h = carry
        xn = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        a, _ = _attend(lp["attn"], xn, xn, cfg, cos, sin, cos, sin,
                       causal=True)
        h = h + a
        xn = rmsnorm(h, lp["cross_norm"], cfg.norm_eps)
        c, _ = _attend(lp["cross"], xn, memory, cfg, cos, sin, cos_m,
                       sin_m, causal=False, rope=False)
        h = h + c
        f = swiglu(lp["mlp"], rmsnorm(h, lp["mlp_norm"], cfg.norm_eps))
        return (h + f).astype(h.dtype), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    xn = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", xn, params["lm_head"],
                      preferred_element_type=jnp.float32)


def encdec_loss(params, batch: dict, cfg: ModelConfig):
    memory = encode(params, batch["src_embeds"], cfg)
    logits = decode_train(params, memory, batch["tokens"], cfg) \
        .astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll, {"nll": nll}


# ------------------------------------------------------------------ serving
def encdec_cache_spec(cfg: ModelConfig, batch: int, cache_len: int,
                      mem_len: int):
    n_dec = cfg.n_dec_layers or cfg.n_layers
    kv = (n_dec, batch, cache_len, cfg.n_kv_heads, cfg.hd)
    mem_kv = (n_dec, batch, mem_len, cfg.n_kv_heads, cfg.hd)
    axes = ("layers", "batch", "kv_seq", "kv_heads", "qkv")
    return {
        "k": (kv, axes, cfg.dtype),
        "v": (kv, axes, cfg.dtype),
        "mem_k": (mem_kv, axes, cfg.dtype),
        "mem_v": (mem_kv, axes, cfg.dtype),
        "pos": ((), (), "int32"),
    }


def encdec_prefill(params, src_embeds, tokens, cfg: ModelConfig,
                   cache_len: int):
    """Encode source, prime decoder with `tokens`, build caches."""
    b = tokens.shape[0]
    memory = encode(params, src_embeds, cfg)
    spec = encdec_cache_spec(cfg, b, cache_len, memory.shape[1])
    cache = {k: jnp.zeros(s, jnp.dtype(dt)) for k, (s, a, dt) in spec.items()}
    # precompute cross-attention KV once per request
    def mk_mem(lp):
        k = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wv"])
        return k, v
    mem_k, mem_v = jax.vmap(mk_mem)(
        jax.tree.map(lambda t: t, params["dec_layers"]))
    cache["mem_k"], cache["mem_v"] = mem_k, mem_v

    # teacher-forced pass over the prime tokens to fill self-attn cache
    x = params["embed"][tokens]
    s = x.shape[1]
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = rope_tables(jnp.arange(s), rot, cfg.rope_theta)

    def body(carry, lp):
        h = carry
        xn = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        a, kv = _attend(lp["attn"], xn, xn, cfg, cos, sin, cos, sin,
                        causal=True)
        h = h + a
        xn = rmsnorm(h, lp["cross_norm"], cfg.norm_eps)
        c, _ = _attend(lp["cross"], xn, memory, cfg, cos, sin, None, None,
                       causal=False, rope=False)
        h = h + c
        f = swiglu(lp["mlp"], rmsnorm(h, lp["mlp_norm"], cfg.norm_eps))
        return (h + f).astype(h.dtype), kv

    x, (k_new, v_new) = jax.lax.scan(body, x, params["dec_layers"])
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new, 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new, 0, axis=2)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    xn = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", xn, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, cache


def encdec_decode_step(params, token, cache, cfg: ModelConfig):
    pos = cache["pos"]
    x = params["embed"][token][:, None, :]
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = rope_tables(pos[None], rot, cfg.rope_theta)
    n_dec = cfg.n_dec_layers or cfg.n_layers

    def body(carry, inp):
        h, kall, vall = carry
        lp, mk, mv, li = inp
        xn = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", xn, lp["attn"]["wq"])
        kn = jnp.einsum("bsd,dhk->bshk", xn, lp["attn"]["wk"])
        vn = jnp.einsum("bsd,dhk->bshk", xn, lp["attn"]["wv"])
        q = apply_rope(q, cos, sin, rot)
        kn = apply_rope(kn, cos, sin, rot)
        zero = jnp.zeros((), jnp.int32)
        kall = jax.lax.dynamic_update_slice(
            kall, kn[None].astype(kall.dtype), (li, zero, pos, zero, zero))
        vall = jax.lax.dynamic_update_slice(
            vall, vn[None].astype(vall.dtype), (li, zero, pos, zero, zero))
        kc = jax.lax.dynamic_index_in_dim(kall, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vall, li, 0, keepdims=False)
        o = decode_attention(q, kc, vc, pos)
        h = h + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        # cross attention against the precomputed memory KV (all valid)
        xn = rmsnorm(h, lp["cross_norm"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dhk->bshk", xn, lp["cross"]["wq"])
        oc = decode_attention(qc, mk, mv, jnp.asarray(mk.shape[1] - 1,
                                                      jnp.int32))
        h = h + jnp.einsum("bshk,hkd->bsd", oc, lp["cross"]["wo"])
        f = swiglu(lp["mlp"], rmsnorm(h, lp["mlp_norm"], cfg.norm_eps))
        return ((h + f).astype(h.dtype), kall, vall), None

    li = jnp.arange(n_dec, dtype=jnp.int32)
    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["dec_layers"], cache["mem_k"], cache["mem_v"], li))
    cache = dict(cache, k=ks, v=vs)
    cache["pos"] = pos + 1
    xn = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", xn, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, cache
