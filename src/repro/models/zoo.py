"""Unified model interface: specs / loss / prefill / decode per family,
plus abstract batch descriptions for the dry-run's ``input_specs``."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeCell
from . import encdec as ed
from . import transformer as tr

ENC_MEM_LEN = 4096     # encoder memory length for enc-dec decode shapes


def model_specs(cfg: ModelConfig) -> dict:
    if cfg.family == "encdec":
        return ed.encdec_specs(cfg)
    return tr.model_specs(cfg)


def loss_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return lambda params, batch: ed.encdec_loss(params, batch, cfg)
    return lambda params, batch: tr.lm_loss(params, batch, cfg)


def prefill_fn(cfg: ModelConfig, cache_len: int):
    if cfg.family == "encdec":
        return lambda params, batch: ed.encdec_prefill(
            params, batch["src_embeds"], batch["tokens"], cfg, cache_len)
    if cfg.family == "vlm":
        return lambda params, batch: tr.prefill(
            params, batch["tokens"], cfg, cache_len,
            prefix_embeds=batch["prefix_embeds"])
    return lambda params, batch: tr.prefill(params, batch["tokens"], cfg,
                                            cache_len)


def decode_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return lambda params, token, cache: ed.encdec_decode_step(
            params, token, cache, cfg)
    return lambda params, token, cache: tr.decode_step(params, token,
                                                       cache, cfg)


# ------------------------------------------------------------------ batches
def batch_desc(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """{name: (shape, dtype, logical_axes)} for the given shape cell."""
    b, s = cell.global_batch, cell.seq_len
    if cfg.family == "vlm" and cfg.n_prefix_embeds:
        # the image-patch prefix is part of the context budget: text
        # tokens + prefix == seq_len (the decode cache is seq_len long)
        s = max(s - cfg.n_prefix_embeds, 1)
    if cell.kind == "train":
        if cfg.family == "encdec":
            return {
                "src_embeds": ((b, s, cfg.d_model), cfg.dtype,
                               ("batch", "seq", "embed_noshard")),
                "tokens": ((b, s), "int32", ("batch", "seq")),
                "labels": ((b, s), "int32", ("batch", "seq")),
            }
        d = {
            "tokens": ((b, s), "int32", ("batch", "seq")),
            "labels": ((b, s), "int32", ("batch", "seq")),
        }
        if cfg.family == "vlm":
            d["prefix_embeds"] = ((b, cfg.n_prefix_embeds, cfg.d_model),
                                  cfg.dtype,
                                  ("batch", "seq", "embed_noshard"))
        return d
    if cell.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "src_embeds": ((b, s, cfg.d_model), cfg.dtype,
                               ("batch", "seq", "embed_noshard")),
                "tokens": ((b, 1), "int32", ("batch", "seq")),
            }
        d = {"tokens": ((b, s), "int32", ("batch", "seq"))}
        if cfg.family == "vlm":
            d["prefix_embeds"] = ((b, cfg.n_prefix_embeds, cfg.d_model),
                                  cfg.dtype,
                                  ("batch", "seq", "embed_noshard"))
        return d
    if cell.kind == "decode":
        return {"token": ((b,), "int32", ("batch",))}
    raise ValueError(cell.kind)


def cache_desc(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """{name: (shape, axes, dtype)} decode-cache description."""
    if cfg.family == "encdec":
        return ed.encdec_cache_spec(cfg, cell.global_batch, cell.seq_len,
                                    ENC_MEM_LEN)
    return tr.cache_spec(cfg, cell.global_batch, cell.seq_len)


def make_batch(cfg: ModelConfig, cell: ShapeCell, seed: int = 0) -> dict:
    """Materialize a random batch matching batch_desc (smoke tests)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, (shape, dtype, _) in batch_desc(cfg, cell).items():
        if dtype == "int32":
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab, size=shape), jnp.int32)
        else:
            out[name] = jnp.asarray(
                rng.normal(0, 0.02, size=shape), jnp.dtype(dtype))
    return out
