"""Shared transformer building blocks: RMSNorm, RoPE (partial-rotary),
GQA attention (dense / chunked-flash / decode, sliding-window aware),
SwiGLU MLP. Pure functions over param subtrees; fp32 softmax/norm math,
bf16 matmuls.

On TPU the chunked path is replaced by ``repro.kernels.flash_attention``
(same math, explicit VMEM tiling); the jnp implementations here are what
the CPU dry-run lowers, and the kernels are validated against the same
oracle (tests/test_kernels.py, tests/test_models_attn.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .params import ParamSpec

_NEG = -1e30


# ----------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def gated_rmsnorm(x: jax.Array, z: jax.Array, w: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    """Mamba2 output norm: rmsnorm(x * silu(z)) * w."""
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ RoPE
def rope_tables(positions: jax.Array, rot_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,) → cos/sin tables (..., rot_dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                           / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               rot_dim: int) -> jax.Array:
    """x: (..., hd); rotate the first rot_dim dims (partial rotary)."""
    rot, rest = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = rot[..., 0::2], rot[..., 1::2]
    # cos/sin: (..., S, rot/2) → insert the head axis so trailing dims
    # align against x's (..., S, H, rot/2)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(rot.shape)
    return jnp.concatenate([out, rest], axis=-1).astype(x.dtype)


# ------------------------------------------------------------- attention
def attention_specs(d_model: int, n_heads: int, n_kv: int, hd: int) -> dict:
    return {
        "wq": ParamSpec((d_model, n_heads, hd), ("embed", "heads", "qkv")),
        "wk": ParamSpec((d_model, n_kv, hd), ("embed", "kv_heads", "qkv")),
        "wv": ParamSpec((d_model, n_kv, hd), ("embed", "kv_heads", "qkv")),
        "wo": ParamSpec((n_heads, hd, d_model), ("heads", "qkv", "embed")),
    }


def _grouped_scores(q, k):
    """q: (B, Hk, G, Sq, hd), k: (B, Hk, T, hd) → (B, Hk, G, Sq, T)."""
    return jnp.einsum("bkgqh,bkth->bkgqt", q, k,
                      preferred_element_type=jnp.float32)


def _grouped_out(w, v):
    return jnp.einsum("bkgqt,bkth->bkgqh", w.astype(v.dtype), v)


def _causal_mask(sq: int, t: int, q0, window: Optional[int]):
    """(sq, t) boolean mask; q0 = absolute position of q row 0."""
    qpos = q0 + jnp.arange(sq)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = qpos >= kpos
    if window is not None:
        ok &= (qpos - kpos) < window
    return ok


def dense_attention(q, k, v, q0=0, causal=True,
                    window: Optional[int] = None) -> jax.Array:
    """q: (B, Sq, Hq, hd); k/v: (B, T, Hk, hd). Full-score fp32 softmax —
    the smoke-test / oracle path."""
    b, sq, hq, hd = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    qg = q.transpose(0, 2, 1, 3).reshape(b, hk, g, sq, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    s = _grouped_scores(qg * (hd ** -0.5), kt)
    if causal:
        m = _causal_mask(sq, t, q0, window)
        s = jnp.where(m[None, None, None], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    o = _grouped_out(w, vt)
    return o.reshape(b, hq, sq, hd).transpose(0, 2, 1, 3)


def chunked_attention(q, k, v, q0=0, causal=True,
                      window: Optional[int] = None,
                      chunk_q: int = 2048, chunk_k: int = 2048) -> jax.Array:
    """Two-level flash attention in pure jnp: scan over q chunks, inner scan
    over kv chunks with online softmax. O(chunk_q × chunk_k) live scores —
    this is what lets 32k×32k prefill lower without an S×S buffer.

    Causal waste note: fully-masked kv chunks are still *computed* (masked
    to -inf) because scan trip counts are static; the roofline MODEL_FLOPS
    ratio surfaces this ~2× attention-FLOP overhead, and the kernels'
    `pl.when` skip removes it on real TPU.
    """
    b, sq, hq, hd = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    sq_real, t_real = sq, t
    pad_q, pad_k = (-sq) % chunk_q, (-t) % chunk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        sq += pad_q
    if pad_k:
        # padded keys sit at positions ≥ t_real: the causal mask hides them
        # from real queries automatically; the kv_limit mask below covers
        # the non-causal case.
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        t += pad_k
    kv_limit = t_real if (pad_k and not causal) else None
    nq, nk = sq // chunk_q, t // chunk_k
    qg = (q.transpose(0, 2, 1, 3).reshape(b, hk, g, sq, hd) *
          (hd ** -0.5))
    kt = k.transpose(0, 2, 1, 3).reshape(b, hk, nk, chunk_k, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b, hk, nk, chunk_k, hd)
    qs = qg.reshape(b, hk, g, nq, chunk_q, hd).transpose(3, 0, 1, 2, 4, 5)

    def q_step(_, qi_pack):
        qc, iq = qi_pack                       # (b,hk,g,cq,hd), scalar

        def kv_step(carry, kj_pack):
            m_p, l_p, acc = carry
            kc, vc, jk = kj_pack
            s = jnp.einsum("bkgqh,bkth->bkgqt", qc, kc,
                           preferred_element_type=jnp.float32)
            if causal or kv_limit is not None:
                qpos = q0 + iq * chunk_q + jnp.arange(chunk_q)[:, None]
                kpos = jk * chunk_k + jnp.arange(chunk_k)[None, :]
                ok = (qpos >= kpos) if causal else (qpos >= -1)
                if window is not None:
                    ok &= (qpos - kpos) < window
                if kv_limit is not None:
                    ok &= kpos < kv_limit
                s = jnp.where(ok[None, None, None], s, _NEG)
            m_n = jnp.maximum(m_p, jnp.max(s, -1))
            p = jnp.exp(s - m_n[..., None])
            alpha = jnp.exp(m_p - m_n)
            l_n = alpha * l_p + jnp.sum(p, -1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bkth->bkgqh", p.astype(vc.dtype), vc)
            return (m_n, l_n, acc), None

        m0 = jnp.full((b, hk, g, chunk_q), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hk, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, hk, g, chunk_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kt.transpose(2, 0, 1, 3, 4), vt.transpose(2, 0, 1, 3, 4),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    # outs: (nq, b, hk, g, cq, hd) → (b, sq, hq, hd)
    o = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hk, g, sq, hd)
    o = o.reshape(b, hq, sq, hd).transpose(0, 2, 1, 3)
    return o[:, :sq_real]


def decode_attention(q, k_cache, v_cache, pos,
                     window: Optional[int] = None) -> jax.Array:
    """Single-step attention against a (possibly ring-buffered) cache.

    q: (B, 1, Hq, hd); caches: (B, T, Hk, hd); pos: scalar int32 — the
    absolute position of the new token. Entries with index > pos (or
    outside the sliding window) are masked.
    """
    b, _, hq, hd = q.shape
    t, hk = k_cache.shape[1], k_cache.shape[2]
    g = hq // hk
    qg = q.reshape(b, hk, g, hd) * (hd ** -0.5)
    # einsum directly against the (B, T, Hk, hd) cache layout — an explicit
    # transpose here would materialize a full cache copy every step
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32)
    idx = jnp.arange(t)
    if window is None:
        ok = idx <= pos
    else:
        # ring buffer (cache size t == min(window, seq)): slot i holds the
        # largest absolute position p ≤ pos with p % t == i; p ≥ 0 ⇒ valid
        # (p is automatically within the window because t ≤ window).
        wrapped = pos - ((pos - idx) % t)
        ok = wrapped >= 0
    s = jnp.where(ok[None, None, None], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", w.astype(v_cache.dtype), v_cache)
    return o.reshape(b, 1, hq, hd)


# -------------------------------------------------------------------- MLP
def mlp_specs(d_model: int, d_ff: int) -> dict:
    return {
        "w1": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w3": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w2": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]
