"""Parameter specification & materialization (no flax — params are plain
pytrees of arrays, described first as ``ParamSpec`` trees so the dry-run
can build ShapeDtypeStructs + shardings without allocating anything).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.logical import guarded_sharding, sharding_for


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                       # logical axis names, len == len(shape)
    init: str = "fan_in"              # fan_in | normal | zeros | ones
    scale: float = 1.0
    dtype: Optional[str] = None       # override model compute dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_params(specs, dtype: str, mesh=None, rules=None):
    """ParamSpec tree → ShapeDtypeStruct tree (with shardings if mesh)."""
    def one(s: ParamSpec):
        dt = jnp.dtype(s.dtype or dtype)
        if mesh is not None:
            return jax.ShapeDtypeStruct(
                s.shape, dt,
                sharding=guarded_sharding(s.shape, s.axes, rules, mesh))
        return jax.ShapeDtypeStruct(s.shape, dt)
    return jax.tree.map(one, specs, is_leaf=_is_spec)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def init_params(specs, key: jax.Array, dtype: str):
    """Materialize parameters (smoke tests / real training only)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        dt = jnp.dtype(s.dtype or dtype)
        if s.init == "zeros":
            v = jnp.zeros(s.shape, dt)
        elif s.init == "ones":
            v = jnp.ones(s.shape, dt)
        elif s.init == "normal":
            v = (jax.random.normal(k, s.shape, jnp.float32) *
                 (0.02 * s.scale)).astype(dt)
        else:  # fan_in
            fan = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
            std = s.scale / np.sqrt(max(fan, 1))
            v = (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dt)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def tree_bytes(specs, dtype: str) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    total = 0
    for s in leaves:
        dt = jnp.dtype(s.dtype or dtype)
        total += int(np.prod(s.shape)) * dt.itemsize
    return total
