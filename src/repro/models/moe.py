"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-friendly).

No (B,S,E,C) one-hot dispatch tensors — for kimi-k2 (384 experts) those
would be terabytes. Instead: flatten tokens, argsort assignments by expert,
compute each assignment's position within its expert segment, drop beyond
capacity, gather into (E, C, D) buffers, run the grouped SwiGLU batched
matmul (experts sharded over "model" ⇒ all-to-all-style collectives), and
scatter-add the combine. Router math in fp32 with load-balance + z losses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from .params import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    sp = {
        "router": ParamSpec((d, m.n_experts), ("embed_noshard", "experts"),
                            dtype="float32"),
        "we1": ParamSpec((m.n_experts, d, m.d_expert),
                         ("experts", "embed", "expert_mlp")),
        "we3": ParamSpec((m.n_experts, d, m.d_expert),
                         ("experts", "embed", "expert_mlp")),
        "we2": ParamSpec((m.n_experts, m.d_expert, d),
                         ("experts", "expert_mlp", "embed")),
    }
    if m.n_shared_experts:
        f = m.d_expert * m.n_shared_experts
        sp.update({
            "ws1": ParamSpec((d, f), ("embed", "mlp")),
            "ws3": ParamSpec((d, f), ("embed", "mlp")),
            "ws2": ParamSpec((f, d), ("mlp", "embed")),
        })
    return sp


def _dispatch_one_group(xf, logits, m: MoEConfig, cap: int):
    """Route one token group: (T_g, D) → gathered (E, cap, D) buffers.
    All ops are local to the group (vmapped over the leading group dim)."""
    t, d = xf.shape
    gate_vals, expert_idx = jax.lax.top_k(logits, m.top_k)        # (T, k)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    a = t * m.top_k
    flat_e = expert_idx.reshape(a)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), m.top_k)
    flat_g = gates.reshape(a)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sg = flat_e[order], flat_t[order], flat_g[order]
    seg_start = jnp.searchsorted(se, jnp.arange(m.n_experts,
                                                dtype=se.dtype))
    pos = jnp.arange(a, dtype=jnp.int32) - seg_start[se].astype(jnp.int32)
    keep = pos < cap
    slot = jnp.where(keep, se.astype(jnp.int32) * cap + pos, a)
    tok_of_slot = jnp.full((m.n_experts * cap + 1,), -1, jnp.int32) \
        .at[slot].set(jnp.where(keep, st_, -1))[:-1]
    gate_of_slot = jnp.zeros((m.n_experts * cap + 1,), jnp.float32) \
        .at[slot].set(jnp.where(keep, sg, 0.0))[:-1]
    occupied = tok_of_slot >= 0
    xin = jnp.where(occupied[:, None], xf[jnp.maximum(tok_of_slot, 0)], 0.0)
    return (xin.reshape(m.n_experts, cap, d), tok_of_slot, gate_of_slot,
            occupied)


def _combine_one_group(eo, tok_of_slot, gate_of_slot, occupied, t: int,
                       d: int):
    contrib = eo.reshape(-1, d).astype(jnp.float32) * gate_of_slot[:, None]
    return jnp.zeros((t + 1, d), jnp.float32).at[
        jnp.where(occupied, tok_of_slot, t)].add(contrib)[:-1]


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) → (y, aux) with aux = {load_balance, router_z}.

    Dispatch is *locality-grouped*: tokens are split into
    ``cfg.moe_dispatch_groups`` groups (≥ the data-parallel degree) and
    routed group-locally; only the (G, E, cap, D) expert buffers cross the
    mesh (an all-to-all-shaped reshard from G-sharded to E-sharded). With
    a single global dispatch XLA instead all-gathers the full (T, D)
    activations to every model shard — measured 16× more collective bytes
    on mixtral train_4k (EXPERIMENTS.md §Perf).
    """
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)

    # ---- aux losses (fp32, global) -----------------------------------
    gate_vals_all, expert_idx_all = jax.lax.top_k(logits, m.top_k)
    me = jnp.mean(probs, axis=0)
    one_hot_top = jax.nn.one_hot(expert_idx_all[:, 0], m.n_experts,
                                 dtype=jnp.float32)
    ce = jnp.mean(one_hot_top, axis=0)
    load_balance = m.n_experts * jnp.sum(me * ce)
    router_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- grouped dispatch --------------------------------------------
    groups = max(1, getattr(cfg, "moe_dispatch_groups", 1) or 1)
    while t % groups:
        groups //= 2
    tg = t // groups
    a_g = tg * m.top_k
    cap = int(max(min(tg, 16),
                  round(a_g / m.n_experts * m.capacity_factor)))
    from ..sharding.logical import maybe_constrain
    dp = ("pod", "data")
    xg = maybe_constrain(xf.reshape(groups, tg, d), (dp, None, None))
    lg = maybe_constrain(logits.reshape(groups, tg, m.n_experts),
                         (dp, None, None))
    xin, tok_of_slot, gate_of_slot, occupied = jax.vmap(
        lambda xx, ll: _dispatch_one_group(xx, ll, m, cap))(xg, lg)
    # xin: (G, E, cap, D) — resharding G(data)→E(model) here is the
    # all-to-all; the grouped matmul below then needs no weight movement.
    xin = maybe_constrain(xin, (dp, "model", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["we1"])) * \
        jnp.einsum("gecd,edf->gecf", xin, p["we3"])
    eo = jnp.einsum("gecf,efd->gecd", h, p["we2"])
    eo = maybe_constrain(eo, (dp, "model", None, None))
    yg = jax.vmap(lambda e, ts, gs, oc:
                  _combine_one_group(e, ts, gs, oc, tg, d))(
        eo, tok_of_slot, gate_of_slot, occupied)
    yf = maybe_constrain(yg, (dp, None, None)).reshape(t, d)

    if m.n_shared_experts:
        hs = jax.nn.silu(xf @ p["ws1"]) * (xf @ p["ws3"])
        yf = yf + (hs @ p["ws2"]).astype(jnp.float32)

    y = yf.astype(x.dtype).reshape(b, s, d)
    aux = {"load_balance": load_balance, "router_z": router_z}
    return y, aux
