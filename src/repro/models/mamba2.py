"""Mamba2 / SSD (state-space duality) blocks — chunked, MXU-friendly.

The SSD algorithm (Dao & Gu 2024) splits the sequence into chunks: the
intra-chunk term is a masked (decay-weighted) attention-like matmul, the
inter-chunk term is a short ``lax.scan`` over chunk states — both map onto
the MXU, which is the whole point of SSD on TPU. Decode is the O(1)
recurrent update on a (B, H, P, N) state cache.

Shapes: d_inner = expand·d_model; H = d_inner / head_dim heads; state N.
in_proj emits [z, x, B, C, dt]; depthwise causal conv over (x, B, C).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import gated_rmsnorm
from .params import ParamSpec


def mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, n_heads, conv_dim


def mamba_specs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in, n_heads, conv_dim = mamba_dims(cfg)
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + n_heads
    return {
        "in_proj": ParamSpec((d, proj_out), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((s.d_conv, conv_dim), ("conv", "ssm_inner")),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((n_heads,), ("ssm_inner",), init="zeros",
                           dtype="float32"),
        "dt_bias": ParamSpec((n_heads,), ("ssm_inner",), init="zeros",
                             dtype="float32"),
        "D": ParamSpec((n_heads,), ("ssm_inner",), init="ones",
                       dtype="float32"),
        "norm_w": ParamSpec((d_in,), ("ssm_inner",), init="ones",
                            dtype="float32"),
        "out_proj": ParamSpec((d_in, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    d_in, n_heads, _ = mamba_dims(cfg)
    gn = s.n_groups * s.d_state
    z, xc, bb, cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, xc, bb, cc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv along S. xbc: (B, S, C); w: (K, C).
    Returns (out, new_state) with state = last K-1 inputs."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)              # (B, S+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1], :] *
              w[i][None, None, :] for i in range(k))
    out = out + b[None, None, :].astype(out.dtype)
    new_state = xp[:, -(k - 1):, :]
    return jax.nn.silu(out).astype(xbc.dtype), new_state


def ssd_chunked(x, dt, a_neg, bmat, cmat, chunk: int, init_state=None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); a_neg: (H,) negative;
    bmat/cmat: (B, S, G, N) with G groups broadcast over H.
    Returns y (B, S, H, P), final_state (B, H, P, N).
    """
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    pad = (-s) % chunk
    if pad:
        # zero-dt padding steps are identities: decay=exp(0)=1, input
        # contribution dt·x = 0 — state passes through untouched.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk
    rep = h // g

    def to_chunks(t):
        return t.reshape((b, nc, chunk) + t.shape[2:])

    xc = to_chunks(x)
    dtc = to_chunks(dt)
    bc = to_chunks(bmat)
    cc = to_chunks(cmat)

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def chunk_step(state, inp):
        xk, dtk, bk, ck = inp            # (b,L,h,p) (b,L,h) (b,L,g,n) ...
        a = dtk * a_neg[None, None, :]                     # (b,L,h) ≤ 0
        cums = jnp.cumsum(a, axis=1)                       # (b,L,h)
        seg = cums[:, :, None, :] - cums[:, None, :, :]    # (b,i,j,h)
        li = jnp.tril(jnp.ones((chunk, chunk), bool))
        lmat = jnp.where(li[None, :, :, None], jnp.exp(seg), 0.0)
        bh = jnp.repeat(bk, rep, axis=2)                   # (b,L,h,n)
        ch = jnp.repeat(ck, rep, axis=2)
        gmat = jnp.einsum("bihn,bjhn->bijh", ch.astype(jnp.float32),
                          bh.astype(jnp.float32))
        xt = xk.astype(jnp.float32) * dtk[..., None]       # (b,L,h,p)
        y_intra = jnp.einsum("bijh,bjhp->bihp", gmat * lmat, xt)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bihn,bhpn->bihp",
                             ch.astype(jnp.float32) * jnp.exp(cums)[..., None],
                             state)
        # state update
        decay_end = jnp.exp(cums[:, -1:, :] - cums)        # (b,L,h)
        s_new = state * jnp.exp(cums[:, -1, :])[:, :, None, None] + \
            jnp.einsum("bjhn,bjhp->bhpn", bh.astype(jnp.float32) *
                       decay_end[..., None], xt)
        return s_new, (y_intra + y_inter)

    xcs = xc.transpose(1, 0, 2, 3, 4)
    dts = dtc.transpose(1, 0, 2, 3)
    bcs = bc.transpose(1, 0, 2, 3, 4)
    ccs = cc.transpose(1, 0, 2, 3, 4)
    final, ys = jax.lax.scan(chunk_step, init_state, (xcs, dts, bcs, ccs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    if pad:
        y = y[:, :s - pad]
    return y.astype(x.dtype), final


def mamba_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                  cache: dict | None = None):
    """Full-sequence forward. Returns (y, new_cache_state or None)."""
    s = cfg.ssm
    d_in, n_heads, conv_dim = mamba_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xc, bb, ccm, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xc, bb, ccm], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xc, bb, ccm = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state],
                            axis=-1)
    b_, sl, _ = xc.shape
    xh = xc.reshape(b_, sl, n_heads, s.head_dim)
    bmat = bb.reshape(b_, sl, s.n_groups, s.d_state)
    cmat = ccm.reshape(b_, sl, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) +
                          p["dt_bias"][None, None, :])
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32))
    init = None if cache is None else cache["ssm"]
    y, final = ssd_chunked(xh, dtv, a_neg, bmat, cmat, s.chunk, init)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b_, sl, d_in).astype(x.dtype)
    y = gated_rmsnorm(y, z, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": final}
    return out, new_cache


def mamba_decode(p: dict, x: jax.Array, cfg: ModelConfig, cache: dict):
    """Single-token recurrent step. x: (B, 1, D)."""
    s = cfg.ssm
    d_in, n_heads, conv_dim = mamba_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xc, bb, ccm, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xc, bb, ccm], axis=-1)[:, 0]    # (B, C)
    conv_state = cache["conv"]                             # (B, K-1, C)
    window = jnp.concatenate([conv_state.astype(xbc.dtype),
                              xbc[:, None, :]], axis=1)    # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]
    xc2, bb2, cc2 = jnp.split(
        conv_out, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    b_ = x.shape[0]
    xh = xc2.reshape(b_, n_heads, s.head_dim).astype(jnp.float32)
    bmat = bb2.reshape(b_, s.n_groups, s.d_state).astype(jnp.float32)
    cmat = cc2.reshape(b_, s.n_groups, s.d_state).astype(jnp.float32)
    rep = n_heads // s.n_groups
    bh = jnp.repeat(bmat, rep, axis=1)                     # (B, H, N)
    ch = jnp.repeat(cmat, rep, axis=1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * a_neg)[..., None, None]          # (B, H, 1, 1)
    state = cache["ssm"]                                   # (B, H, P, N)
    xt = xh * dtv[..., None]                               # (B, H, P)
    state = state * decay + xt[..., None] * bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, ch)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b_, 1, d_in).astype(x.dtype)
    y = gated_rmsnorm(y, z, p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], {"conv": new_conv.astype(cache["conv"].dtype),
                               "ssm": state}
