"""Paged storage tier: learned-position disk layout for LIMS snapshots.

The paper's rank models approximate where each record sits **on disk**;
this package is the disk.  A spilled snapshot directory holds an
append-only page file (cluster-major extents, rows in mapped-value
order — ``layout``), an atomic JSON manifest (``manifest``), and the
snapshot's non-row arrays; serving reads it through a ``PagedStore``
(mmap + LRU page cache with access counters — ``cache``/``store``)
driven by the IO-batch scheduler (``scheduler``), which turns the
executor's certified candidate plans into deduplicated sequential page
runs fetched once per query batch, and — under ``REPRO_PREFETCH=async``
— by the background prefetcher (``prefetch``), which overlaps upcoming
kNN rounds' page IO with kernel refinement.  ``PagedStore.compact()``
reclaims the garbage extents append-only writebacks leave behind.
DESIGN.md §7–§8 are the full story, including why store-backed results
stay bit-identical to the resident path.

``REPRO_STORAGE=paged`` flips the default serving surfaces
(``BatchedLIMS``, ``ServingEngine``) to spill-and-serve through this
tier — CI runs the whole suite that way on a dedicated leg.
"""
from __future__ import annotations

from .. import env

from .cache import (DEFAULT_CACHE_PAGES, CacheStats, LRUPageCache,
                    cache_pin_mode)
from .layout import DEFAULT_PAGE_BYTES, PageLayout, rows_per_page
from .manifest import Manifest, write_atomic
from .prefetch import (PagePrefetcher, PrefetchTicket, drain_queue,
                       prefetch_mode, shutdown_prefetch)
from .scheduler import IOPlan, page_runs, plan_batch
from .store import PagedStore, StoreView, load_meta, spill_rows


def storage_mode() -> str:
    """The process-wide storage default: '' (resident) or 'paged'
    (``REPRO_STORAGE``, validated by ``repro.env``)."""
    return env.get("REPRO_STORAGE")


__all__ = [
    "CacheStats", "DEFAULT_CACHE_PAGES", "DEFAULT_PAGE_BYTES", "IOPlan",
    "LRUPageCache", "Manifest", "PageLayout", "PagePrefetcher",
    "PagedStore", "PrefetchTicket", "StoreView", "cache_pin_mode",
    "drain_queue", "load_meta", "page_runs", "plan_batch", "prefetch_mode",
    "rows_per_page", "shutdown_prefetch", "spill_rows", "storage_mode",
    "write_atomic",
]
