"""Async page prefetcher: overlap upcoming rounds' page IO with kernel
refinement.

The kNN schedule is deterministic (``CandidatePlan``: round t's radius
is ``seed · 2^t``), so the paged backend knows round t+1's IOPlan before
round t's refinement has run.  This module turns that plan into a
background fetch: a single daemon worker drains a queue of page lists
and pulls them into the store's cache (under the store's own lock, so it
composes with concurrent query threads for free), while the main thread
runs the round's ``pdist`` refinement and certification.  When the next
round issues its synchronous fetch, the pages are already resident —
the fetch degrades to cache hits and the round's IO cost has been hidden
behind compute.

Speculation is bounded and safe: a prefetched page the batch never ends
up needing (its queries all certified in the meantime) cost one wasted
background read, never a wrong result — correctness is entirely the
store's (idempotent, locked) fetch path.  Prefetch IO bypasses the
store's buffer-pool counters (``record=False``) so the per-query IO
metrics keep meaning "what the queries demanded"; the prefetcher keeps
its own ledger instead, including the two numbers the benchmark
surfaces: the *hit rate* (fraction of prefetched pages a later round
actually demanded — speculation accuracy) and *overlapped rounds*
(rounds whose background IO completed before the demand fetch arrived —
proof the overlap actually happened).

``REPRO_PREFETCH=async`` enables the prefetcher on paged executors;
unset/anything else keeps today's fully synchronous behavior.

Shutdown: the worker is a daemon thread, but daemon teardown at
interpreter exit can kill it mid-``fetch_pages`` while library state is
being finalized — so ``shutdown_prefetch`` (registered with ``atexit``)
stops it deliberately: it sets the shutdown flag, enqueues a sentinel,
and joins with a timeout.  In-flight IOPlans are *dropped*, not drained
— speculative IO has no correctness obligation and exit shouldn't wait
on disk — and every dropped plan is counted on its prefetcher
(``dropped_plans`` / ``pages_dropped`` in ``snapshot()``), so a bench
or test that cares can see exactly what the close threw away.
"""
from __future__ import annotations

import atexit
import queue
import threading
from dataclasses import dataclass, field

import numpy as np

from .. import env
from ..obs import registry as _obs


def prefetch_mode() -> str:
    """Process-wide prefetch policy: ''/off (synchronous) or 'async'
    (``REPRO_PREFETCH``, validated by ``repro.env``)."""
    return env.get("REPRO_PREFETCH")


@dataclass
class PrefetchTicket:
    """One submitted round's prefetch: its pages + completion event."""

    pages: np.ndarray
    _event: threading.Event = field(default_factory=threading.Event)

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)


# one shared daemon worker drains every prefetcher's submissions: a
# process can hold many paged executors (one per snapshot generation,
# per engine, per test...) and a thread per executor would pile up —
# speculative IO is background work, one background thread is enough.
# The worker owns no state a crash could corrupt (each store's lock
# serializes the actual cache/mmap mutation), so process teardown needs
# no handshake.
_QUEUE: queue.SimpleQueue = queue.SimpleQueue()
_WORKER_LOCK = threading.Lock()
_WORKER: threading.Thread | None = None
_SHUTDOWN = threading.Event()
_SENTINEL = object()
_EMPTY_PAGES = np.empty(0, np.int64)    # shared drain-marker payload


def _drop(prefetcher, pages) -> None:
    """Account a plan the shutdown discarded (drain markers — empty
    page lists — are control flow, not dropped IO)."""
    if prefetcher is not None and len(pages):
        with prefetcher._lock:
            prefetcher.dropped_plans += 1
            prefetcher.pages_dropped += len(pages)


def _worker_loop() -> None:
    while True:
        item = _QUEUE.get()
        if item is _SENTINEL:
            return
        prefetcher, pages, ev = item
        try:
            if _SHUTDOWN.is_set():
                _drop(prefetcher, pages)
            elif len(pages):
                prefetcher.store.fetch_pages(pages, record=False)
                with prefetcher._lock:
                    prefetcher.pages_fetched += len(pages)
                _obs.count("prefetch.pages_fetched", len(pages))
        except Exception:
            # a failed speculative read is a missed optimization, not an
            # error: the demand fetch will read (and raise) for real if
            # the page genuinely matters
            pass
        finally:
            ev.set()


def _ensure_worker() -> None:
    global _WORKER
    if _SHUTDOWN.is_set():
        return                          # closing: no restarts
    with _WORKER_LOCK:
        if _WORKER is None or not _WORKER.is_alive():
            _WORKER = threading.Thread(
                target=_worker_loop, daemon=True, name="lims-page-prefetch")
            _WORKER.start()


def shutdown_prefetch(timeout: float = 2.0) -> bool:
    """Stop the shared worker deliberately (atexit hook; callable early
    by tests).  Queued plans behind the flag are dropped-and-counted by
    the worker on its way to the sentinel; the join timeout bounds exit
    latency if the worker is wedged mid-read.  Returns True when the
    worker is (or was already) fully stopped.  Irreversible for the
    process: later ``submit`` calls drop immediately."""
    global _WORKER
    _SHUTDOWN.set()
    with _WORKER_LOCK:
        w = _WORKER
        if w is None or not w.is_alive():
            _WORKER = None
            return True
        _QUEUE.put(_SENTINEL)
        w.join(timeout)
        stopped = not w.is_alive()
        if stopped:
            _WORKER = None
        return stopped


def drain_queue(timeout: float | None = None) -> bool:
    """Block until every plan queued so far (from any prefetcher) has
    been processed.  The shared worker touches stores — and therefore
    the obs gauges — from its own thread, so anything measuring
    allocation or metric quiescence must drain first.  Returns False on
    timeout; True when the queue was empty or became empty (including
    after shutdown, when nothing can be in flight)."""
    if _SHUTDOWN.is_set():
        return True
    with _WORKER_LOCK:
        if _WORKER is None or not _WORKER.is_alive():
            return True
    ev = threading.Event()
    _QUEUE.put((None, _EMPTY_PAGES, ev))
    return ev.wait(timeout)


def _restart_for_tests() -> None:
    """Undo a test-invoked shutdown so the rest of the suite keeps its
    prefetcher (production exits never restart — atexit is terminal)."""
    shutdown_prefetch()
    _SHUTDOWN.clear()


atexit.register(shutdown_prefetch)


class PagePrefetcher:
    """Background fetcher bound to one store (view), sharing the
    process-wide worker thread.  ``submit`` never blocks;
    ``note_demand`` is the accounting hook the paged backend calls right
    before each round's synchronous fetch.
    """

    def __init__(self, store):
        self.store = store
        self._lock = threading.Lock()
        self.submitted = 0           # tickets with at least one page
        self.pages_submitted = 0
        self.pages_fetched = 0
        self.demand_hits = 0         # prefetched pages a round demanded
        self.overlapped_rounds = 0   # rounds whose prefetch beat demand
        self.dropped_plans = 0       # plans the shutdown discarded
        self.pages_dropped = 0

    # ------------------------------------------------------------------ api
    def submit(self, pages: np.ndarray) -> PrefetchTicket:
        """Queue a background fetch; returns immediately.  After
        ``shutdown_prefetch`` the plan is dropped-and-counted instead
        (its ticket completes at once, with nothing fetched)."""
        pages = np.asarray(pages, np.int64)
        t = PrefetchTicket(pages)
        if len(pages) == 0:
            t._event.set()
            return t
        with self._lock:
            self.submitted += 1
            self.pages_submitted += len(pages)
        _obs.count("prefetch.rounds_submitted")
        _obs.count("prefetch.pages_submitted", len(pages))
        if _SHUTDOWN.is_set():
            _drop(self, pages)
            t._event.set()
            return t
        _ensure_worker()
        _QUEUE.put((self, pages, t._event))
        return t

    def note_demand(self, pages: np.ndarray,
                    ticket: PrefetchTicket | None = None) -> None:
        """Account a round's demand fetch against the prefetch submitted
        for it last round: ``pages`` is what the round is about to fetch
        synchronously; a ticket page the round demands is a hit
        (speculation accuracy — a page prefetched for queries that
        certified in the meantime is the wasted-IO miss case), and a
        ticket already complete at demand time is a fully overlapped
        round."""
        if ticket is None or not len(ticket.pages):
            return
        dem = {int(p) for p in pages}
        hits = sum(1 for p in ticket.pages if int(p) in dem)
        overlapped = ticket.done()
        with self._lock:
            self.demand_hits += hits
            if overlapped:
                self.overlapped_rounds += 1
        # speculation accuracy, process-wide: demand_hits /
        # pages_submitted is the fraction of speculative IO a later
        # round actually wanted
        _obs.count("prefetch.demand_hits", hits)
        if overlapped:
            _obs.count("prefetch.overlapped_rounds")

    def drain(self) -> None:
        """Block until every prefetch queued so far has completed (a
        shut-down worker has nothing left to wait for)."""
        if _SHUTDOWN.is_set():
            return
        ev = threading.Event()
        _ensure_worker()
        _QUEUE.put((self, np.empty(0, np.int64), ev))
        ev.wait()

    def reset(self) -> None:
        """Zero the counters (benchmarks isolating one workload)."""
        with self._lock:
            self.submitted = self.pages_submitted = 0
            self.pages_fetched = self.demand_hits = 0
            self.overlapped_rounds = 0
            self.dropped_plans = self.pages_dropped = 0

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "mode": "async",
                "submitted_rounds": self.submitted,
                "pages_submitted": self.pages_submitted,
                "pages_fetched": self.pages_fetched,
                "demand_hits": self.demand_hits,
                "hit_rate": round(
                    self.demand_hits / max(self.pages_submitted, 1), 4),
                "overlapped_rounds": self.overlapped_rounds,
                "dropped_plans": self.dropped_plans,
                "pages_dropped": self.pages_dropped,
            }


__all__ = ["PagePrefetcher", "PrefetchTicket", "drain_queue",
           "prefetch_mode", "shutdown_prefetch"]
