"""IO-batch scheduler: certified candidate sets → deduplicated page runs.

The executor's device pipeline certifies, per query, a candidate slot
set (the error-widened ring box ``[lo-E, hi+E]`` ∧ TriPrune ∧ validity).
Refinement needs those rows.  Fetching them per query would re-read
shared pages B times; this module plans the IO for the *whole batch*
instead:

  1. union the candidate slots over the batch (dedup across queries),
  2. map slots to pages through the learned-position layout,
  3. coalesce the deduped page list into contiguous runs, so the store
     reads each run with one sequential mmap slice.

Because the layout is cluster-major in mapped-value order, a query's
candidates inside one cluster cover few pages and adjacent queries share
them — exactly the access pattern the paper's learned positions exist to
produce.  The plan also carries the per-query unique-page and candidate
counts: the paper's IO cost metric, recorded into the store's cache
stats.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .layout import PageLayout


def page_runs(pages: np.ndarray) -> tuple:
    """Coalesce a sorted unique page-id array into [start, stop) runs."""
    if len(pages) == 0:
        return ()
    breaks = np.nonzero(np.diff(pages) > 1)[0]
    starts = np.concatenate([[0], breaks + 1])
    stops = np.concatenate([breaks, [len(pages) - 1]])
    return tuple((int(pages[a]), int(pages[b]) + 1)
                 for a, b in zip(starts, stops))


@dataclass(frozen=True)
class IOPlan:
    """One query batch's IO: what to read, and what each query touched."""

    slots: np.ndarray            # unique sorted candidate slot ids
    pages: np.ndarray            # unique sorted page ids covering them
    runs: tuple                  # coalesced [start, stop) page runs
    pages_per_query: np.ndarray  # (B,) unique pages per query
    cand_per_query: np.ndarray   # (B,) candidate slots per query

    @property
    def n_pages(self) -> int:
        return len(self.pages)

    def summary(self) -> dict:
        return {
            "pages": int(self.n_pages),
            "runs": len(self.runs),
            "candidates": int(len(self.slots)),
            "pages_per_query": [int(p) for p in self.pages_per_query],
            "candidates_per_query": [int(c) for c in self.cand_per_query],
        }


def plan_batch(cand: np.ndarray, layout: PageLayout,
               per_query: bool = True,
               exclude: np.ndarray | None = None) -> IOPlan:
    """Plan the page fetch for a (B, P) bool candidate mask.

    Every page is listed once no matter how many queries (or how many
    slots within a query) need it; runs are maximal contiguous spans so
    the store turns them into sequential reads.  ``per_query=False``
    skips the per-query unique-page accounting (a caller that tracks
    pages across rounds itself — the kNN driver — avoids paying the
    slot→page mapping twice per round).  ``exclude`` (a (P,) bool mask)
    drops slots whose rows the caller already holds — the speculative
    prefetch plan for round t+1 covers only IO round t hasn't done.
    """
    cand = np.asarray(cand, dtype=bool)
    if exclude is not None:
        cand = cand & ~np.asarray(exclude, dtype=bool)[None]
    B = cand.shape[0]
    slots = np.nonzero(cand.any(axis=0))[0].astype(np.int64)
    pages = np.unique(layout.slot_pages(slots)) if len(slots) \
        else np.empty(0, np.int64)
    ppq = np.zeros(B, np.int64)
    cpq = cand.sum(axis=1).astype(np.int64)
    if per_query and len(slots):
        # one vectorized pass: dedupe (query, page) pairs via a packed
        # key, then count pages per query — no per-query Python loop
        qi, si = np.nonzero(cand)
        pg = layout.slot_pages(si)
        span = int(pages[-1]) + 1
        uq = np.unique(qi.astype(np.int64) * span + pg)
        ppq = np.bincount(uq // span, minlength=B).astype(np.int64)
    return IOPlan(slots=slots, pages=pages, runs=page_runs(pages),
                  pages_per_query=ppq, cand_per_query=cpq)


__all__ = ["IOPlan", "plan_batch", "page_runs"]
