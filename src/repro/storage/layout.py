"""Learned-position page layout: where a snapshot slot lives on disk.

The paper's defining claim is that the learned models approximate the
position of each record **on disk**; this module fixes the disk geometry
those positions point into.  The layout is cluster-major, mirroring the
serving snapshot exactly: cluster ``k`` owns one contiguous *extent* of
fixed-size pages holding its ``n_max`` slot rows in mapped-value order
(ring order, then §5.3 insert-buffer rows, then padding slots) — so a
certified rank interval ``[lo-E, hi+E]`` translates to a contiguous run
of pages, which is the whole point of the paper's IntervalGen.

Pages are fixed-size (``page_bytes``, default 4 KB like the paper's
evaluation); the row capacity of a page is additionally truncated to a
multiple of 128 rows once it exceeds 128, so page boundaries line up
with the Pallas kernels' 128-lane tiles and a gathered page block feeds
the refinement kernels without re-alignment.

All math here is integer slot/page arithmetic over numpy arrays — no
file IO (that is ``repro.storage.store``) and no jax.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# the paper evaluates 4 KB pages; keep parity with repro.core.paging
DEFAULT_PAGE_BYTES = 4096
_TILE_ROWS = 128        # kernel tile alignment for large pages
_RECORD_BYTES = 8       # f64 component size; a record is d of these


def rows_per_page(page_bytes: int, d: int) -> int:
    """Row capacity of one page: floor-fit f64 records, 128-row aligned
    once a page holds at least a full kernel tile."""
    rpp = max(1, int(page_bytes) // (d * _RECORD_BYTES))
    if rpp > _TILE_ROWS:
        rpp -= rpp % _TILE_ROWS
    return rpp


@dataclass(frozen=True)
class PageLayout:
    """Slot ↔ page geometry for one store generation.

    ``extents[k]`` is the first page of cluster ``k``'s extent; every
    extent spans ``pages_per_cluster`` contiguous pages (all clusters
    share the snapshot's padded ``n_max``).  Flat slot ids are the
    executor's candidate axis: ``slot = k * n_max + i``.
    """

    page_bytes: int
    rows_per_page: int
    d: int
    n_max: int
    extents: tuple          # (K,) start page per cluster

    @property
    def K(self) -> int:
        return len(self.extents)

    @property
    def pages_per_cluster(self) -> int:
        return -(-self.n_max // self.rows_per_page)

    @property
    def page_stride_bytes(self) -> int:
        """Physical bytes per page in the store file (packed rows; at
        most ``page_bytes``)."""
        return self.rows_per_page * self.d * _RECORD_BYTES

    def _extents_arr(self) -> np.ndarray:
        return np.asarray(self.extents, dtype=np.int64)

    def slot_pages(self, slots: np.ndarray) -> np.ndarray:
        """Page id holding each flat slot (same shape as ``slots``)."""
        slots = np.asarray(slots, dtype=np.int64)
        k, i = slots // self.n_max, slots % self.n_max
        return self._extents_arr()[k] + i // self.rows_per_page

    def slot_locations(self, slots: np.ndarray):
        """(page id, row offset inside the page) per flat slot."""
        slots = np.asarray(slots, dtype=np.int64)
        k, i = slots // self.n_max, slots % self.n_max
        return (self._extents_arr()[k] + i // self.rows_per_page,
                i % self.rows_per_page)

    def cluster_file_rows(self, k: int) -> tuple[int, int]:
        """[start, stop) in file-row space covering cluster ``k``'s
        ``n_max`` slot rows (its extent's pages are contiguous)."""
        start = int(self.extents[k]) * self.rows_per_page
        return start, start + self.n_max


__all__ = ["DEFAULT_PAGE_BYTES", "PageLayout", "rows_per_page"]
