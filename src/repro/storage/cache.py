"""LRU page cache + the store's IO / serving-metric counters.

The cache emulates the bounded buffer pool of a disk-based index: pages
enter on miss, recency-ordered, evicting the coldest once over capacity.
Pages are keyed by ``(pages file, page id)``: within one file page ids
are append-only and their content immutable, so the cache is never
invalidated — not across manifest swaps (a refreshed generation
references *new* page ids for rewritten clusters) and not across
compactions (a compacted generation lives in a *new* file, so its
restarted page ids can never collide with a pinned view's old ones).

Schedule-aware eviction (DESIGN.md §9): a query batch's ``CandidatePlan``
knows every page its remaining rounds will touch, so the paged backend
*pins* them for the batch's duration — ``pin``/``unpin`` hold a
per-page count, and capacity eviction skips pinned pages (the coldest
*unpinned* page goes instead).  Blind LRU would evict a round's pages
between its fetch and its gather under a squeezed capacity, or drop
earlier rounds' pages a later round is guaranteed to re-demand; pinning
replaces that with the plan's own schedule.  Pinning never blocks an
insert — when every resident page is pinned the cache briefly overflows
capacity (bounded by one batch's working set) rather than corrupt a
planned fetch.  ``unpin`` restores plain LRU: the page keeps the
recency position its accesses earned and becomes evictable again.
``REPRO_CACHE_PIN=off`` disables plan pinning process-wide (the bench's
blind-LRU baseline).

``CacheStats`` carries two families of counters:

  * cache-level IO: requests / hits / misses (= actual page reads) /
    evictions / rows gathered — the buffer-pool story;
  * per-query serving metrics recorded by the executor: unique pages
    touched and candidate rows refined per query — the paper's headline
    cost model (page accesses per query), surfaced in
    ``BENCH_serving.json`` alongside q/s.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from .. import env

DEFAULT_CACHE_PAGES = 4096


def cache_pin_mode() -> bool:
    """Whether planned batches pin their scheduled pages (default on).
    ``REPRO_CACHE_PIN=off`` reverts to blind LRU — the bench baseline
    (validated by ``repro.env``)."""
    return env.get("REPRO_CACHE_PIN") not in ("off", "0", "no")


@dataclass
class CacheStats:
    # buffer-pool counters
    requests: int = 0
    hits: int = 0
    misses: int = 0             # demand page reads
    evictions: int = 0
    rows_gathered: int = 0
    # speculative page reads issued by the async prefetcher
    # (``fetch_pages(record=False)``): real file IO that is not a demand
    # miss.  Total page reads = misses + prefetch_reads — the invariant
    # that makes buffer-pool stats + prefetch stats sum to all IO
    # (asserted in tests); before this counter that IO was invisible.
    prefetch_reads: int = 0
    # per-query serving metrics (executor-recorded)
    batches: int = 0
    queries: int = 0
    pages_touched: int = 0      # Σ over queries of unique pages accessed
    candidates: int = 0         # Σ over queries of rows fetched for refine

    def record_queries(self, pages_per_query, cand_per_query) -> None:
        self.batches += 1
        self.queries += len(pages_per_query)
        self.pages_touched += int(np.sum(pages_per_query))
        self.candidates += int(np.sum(cand_per_query))

    def snapshot(self) -> dict:
        q = max(self.queries, 1)
        return {
            "requests": self.requests, "hits": self.hits,
            "misses": self.misses, "evictions": self.evictions,
            "rows_gathered": self.rows_gathered,
            "prefetch_reads": self.prefetch_reads,
            "page_reads": self.misses + self.prefetch_reads,
            "hit_rate": round(self.hits / max(self.requests, 1), 4),
            "batches": self.batches, "queries": self.queries,
            "pages_per_query": round(self.pages_touched / q, 2),
            "candidates_per_query": round(self.candidates / q, 2),
        }

    def reset(self) -> None:
        for f in ("requests", "hits", "misses", "evictions",
                  "rows_gathered", "prefetch_reads", "batches", "queries",
                  "pages_touched", "candidates"):
            setattr(self, f, 0)


@dataclass
class LRUPageCache:
    """(file, page id) → (rows_per_page, d) f64 block, recency-ordered.

    ``capacity_pages=None`` means unbounded (useful for warm replicas
    that are expected to fault the whole working set in once).
    ``access`` keeps a per-page hit counter — the store's "access
    counters", e.g. for spotting hot extents.
    """

    capacity_pages: int | None = DEFAULT_CACHE_PAGES
    _pages: OrderedDict = field(default_factory=OrderedDict)
    access: dict = field(default_factory=dict)
    _pins: dict = field(default_factory=dict)   # pid → pin count

    def __len__(self) -> int:
        return len(self._pages)

    def touch(self, pid: int) -> bool:
        """Mark ``pid`` accessed; True when resident (LRU bump)."""
        self.access[pid] = self.access.get(pid, 0) + 1
        if pid in self._pages:
            self._pages.move_to_end(pid)
            return True
        return False

    def peek(self, pid: int) -> np.ndarray | None:
        """Resident page block without recency/counter side effects."""
        return self._pages.get(pid)

    def put(self, pid: int, block: np.ndarray) -> int:
        """Insert a page; returns how many pages were evicted.

        Eviction is pin-aware: the coldest *unpinned* page goes first;
        when every resident page is pinned the cache overflows capacity
        rather than break a planned fetch (bounded by one batch's
        pinned working set)."""
        self._pages[pid] = block
        self._pages.move_to_end(pid)
        return self._shrink()

    def _shrink(self) -> int:
        """Evict coldest unpinned pages until back under capacity; an
        all-pinned cache stays overflowed (bounded by one batch's
        working set) until its pins release."""
        evicted = 0
        if self.capacity_pages is not None:
            while len(self._pages) > self.capacity_pages:
                victim = next(
                    (k for k in self._pages if k not in self._pins), None)
                if victim is None:          # all pinned → allow overflow
                    break
                del self._pages[victim]
                evicted += 1
        return evicted

    def pin(self, pids) -> None:
        """Hold the given pages against capacity eviction (refcounted).
        Pinning a non-resident page is allowed: the hold applies the
        moment the page is inserted."""
        for pid in pids:
            self._pins[pid] = self._pins.get(pid, 0) + 1

    def unpin(self, pids) -> int:
        """Release one hold per page; at zero the page rejoins plain LRU
        at whatever recency position its accesses earned.  Unknown pids
        are ignored (a pinned page may have been cleared meanwhile).
        Returns pages evicted clearing any pin-era overflow."""
        for pid in pids:
            c = self._pins.get(pid, 0) - 1
            if c > 0:
                self._pins[pid] = c
            else:
                self._pins.pop(pid, None)
        return self._shrink()

    @property
    def pinned(self) -> int:
        """Number of distinct pages currently held."""
        return len(self._pins)

    def clear(self) -> None:
        """Drop every resident page (access counters are kept — they
        describe the workload, not the residency; pins are dropped with
        the pages they guarded)."""
        self._pages.clear()
        self._pins.clear()

    def hottest(self, n: int = 10) -> list:
        """(page id, access count) for the n most-accessed pages."""
        return sorted(self.access.items(), key=lambda kv: -kv[1])[:n]


__all__ = ["LRUPageCache", "CacheStats", "DEFAULT_CACHE_PAGES",
           "cache_pin_mode"]
