"""The paged store: append-only mmap file of cluster extents.

Writer (``spill_rows``): lays each cluster's ``n_max`` slot rows (f64,
mapped-value order — the order the learned positions predict) into a
contiguous extent of fixed-size pages inside a single ``pages.bin``.
Incremental spills reuse the extents of clusters whose row bytes are
unchanged (sha1 in the manifest) and *append* extents for dirty ones;
the new generation is published with one atomic manifest swap
(``repro.storage.manifest``).  The file is never rewritten in place, so
live readers — and their page caches — stay valid across swaps.

Reader (``PagedStore``): a read-only ``np.memmap`` over the page file
plus an LRU page cache with access counters.  ``fetch`` takes an
``IOPlan`` (deduplicated, run-coalesced page list from the IO-batch
scheduler) and reads each missing run as one sequential slice;
``gather`` returns the f64 rows for a set of flat slot ids through the
cache, which is both the Pallas-refinement input (cast to f32 — the
same cast the resident snapshot applies) and the exact f64 refinement
input, so store-backed results are bit-identical to the in-memory path.
"""
from __future__ import annotations

import hashlib
import io
import os
import threading

import numpy as np

from .cache import DEFAULT_CACHE_PAGES, CacheStats, LRUPageCache
from .layout import DEFAULT_PAGE_BYTES, PageLayout, rows_per_page
from .manifest import FORMAT_VERSION, PAGES_NAME, Manifest, write_atomic
from .scheduler import IOPlan, page_runs


def _cluster_hashes(rows64: np.ndarray) -> list:
    return [hashlib.sha1(np.ascontiguousarray(rows64[k]).tobytes())
            .hexdigest() for k in range(rows64.shape[0])]


def spill_rows(root: str, rows64: np.ndarray,
               page_bytes: int = DEFAULT_PAGE_BYTES,
               meta_arrays: dict | None = None) -> Manifest:
    """Write (or incrementally refresh) the paged row store under ``root``.

    ``rows64``: (K, n_max, d) f64 cluster-major slot rows.  When a
    compatible manifest already exists, unchanged clusters keep their
    extents and only dirty clusters append new pages ("retrained
    clusters write back as new page extents"); otherwise every cluster
    gets a fresh extent (still append-only).  ``meta_arrays`` (optional)
    lands in a generation-stamped ``meta-<gen>.npz`` referenced by the
    manifest, published together by the atomic manifest swap.
    """
    K, n_max, d = rows64.shape
    rows64 = np.ascontiguousarray(rows64, dtype=np.float64)
    os.makedirs(root, exist_ok=True)
    prev = Manifest.load(root) if Manifest.exists(root) else None
    rpp = rows_per_page(page_bytes, d)
    reusable = (prev is not None and prev.n_max == n_max and prev.d == d
                and prev.rows_per_page == rpp and prev.K == K)
    if prev is not None and not reusable and (prev.d != d or
                                              prev.rows_per_page != rpp):
        raise ValueError(
            "store geometry changed (d or page size); spill to a fresh "
            "directory instead of mixing record formats in one file")
    hashes = _cluster_hashes(rows64)
    ppc = -(-n_max // rpp)
    next_page = prev.total_pages if prev is not None else 0
    extents, dirty = [], []
    for k in range(K):
        if reusable and prev.cluster_sha1[k] == hashes[k]:
            extents.append(prev.extents[k])
        else:
            extents.append(next_page)
            dirty.append(k)
            next_page += ppc

    pages_path = os.path.join(root, PAGES_NAME)
    stride_rows = ppc * rpp
    with open(pages_path, "r+b" if prev is not None else "wb") as f:
        for k in dirty:
            block = np.zeros((stride_rows, d), np.float64)
            block[:n_max] = rows64[k]
            f.seek(extents[k] * rpp * d * 8)
            f.write(block.tobytes())
        f.flush()
        os.fsync(f.fileno())

    gen = prev.generation + 1 if prev is not None else 0
    meta_file = ""
    if meta_arrays is not None:
        meta_file = f"meta-{gen}.npz"
        buf = io.BytesIO()
        np.savez(buf, **meta_arrays)
        write_atomic(os.path.join(root, meta_file), buf.getvalue())
    man = Manifest(version=FORMAT_VERSION, generation=gen,
                   page_bytes=page_bytes, rows_per_page=rpp, d=d,
                   n_max=n_max, K=K, total_pages=next_page,
                   extents=extents, cluster_sha1=hashes,
                   meta_file=meta_file or (prev.meta_file if prev else ""))
    man.save(root)
    # prune stale metas, but never one a live manifest can reference:
    # the one just published (possibly carried forward from an older
    # generation) or the previous manifest's (a reader that loaded it
    # moments ago must still find its meta)
    keep = {man.meta_file} | ({prev.meta_file} if prev else set())
    for name in os.listdir(root):
        if name.startswith("meta-") and name.endswith(".npz") \
                and name not in keep:
            g = int(name[5:-4])
            if g < gen - 1:
                os.unlink(os.path.join(root, name))
    return man


def load_meta(root: str) -> tuple[dict, Manifest]:
    """Read the manifest and its generation's metadata arrays."""
    man = Manifest.load(root)
    if not man.meta_file:
        raise FileNotFoundError(f"store at {root!r} has no metadata file")
    with np.load(os.path.join(root, man.meta_file)) as z:
        meta = {k: z[k] for k in z.files}
    return meta, man


class PagedStore:
    """mmap reader over a spilled store: page cache + IO accounting."""

    def __init__(self, root: str,
                 cache_pages: int | None = DEFAULT_CACHE_PAGES):
        self.root = root
        self.manifest = Manifest.load(root)
        self.cache = LRUPageCache(cache_pages)
        self.stats = CacheStats()
        # serializes cache/mmap mutation: executors share one reader
        # across concurrent lock-free query threads (the resident path's
        # immutability argument doesn't cover the page cache), so page
        # IO is the one place store-mode queries serialize.  Reentrant —
        # gather() fetches missing pages under its own lock.
        self._lock = threading.RLock()
        self._mm: np.memmap | None = None
        self._map()

    def _map(self) -> None:
        man = self.manifest
        self.layout: PageLayout = man.layout()
        n_rows = man.total_pages * man.rows_per_page
        self._mm = np.memmap(os.path.join(self.root, man.pages_file),
                             dtype="<f8", mode="r",
                             shape=(max(n_rows, 1), man.d))

    @property
    def generation(self) -> int:
        return self.manifest.generation

    def refresh(self) -> "PagedStore":
        """Adopt the latest published manifest (after a writer swap).

        Append-only page ids make this trivially safe: cached pages stay
        byte-valid, a rewritten cluster simply references new ids.
        """
        with self._lock:
            man = Manifest.load(self.root)
            if man.generation != self.manifest.generation:
                self.manifest = man
                self._map()
        return self

    # ------------------------------------------------------------------ io
    def fetch_pages(self, pages: np.ndarray) -> None:
        """Ensure ``pages`` are cached; missing ones read as runs."""
        with self._lock:
            st = self.stats
            missing = []
            for pid in np.asarray(pages, dtype=np.int64):
                pid = int(pid)
                st.requests += 1
                if self.cache.touch(pid):
                    st.hits += 1
                else:
                    missing.append(pid)
            rpp = self.layout.rows_per_page
            for a, b in page_runs(np.asarray(missing, np.int64)):
                block = np.array(self._mm[a * rpp:b * rpp],
                                 dtype=np.float64)
                for j, pid in enumerate(range(a, b)):
                    st.evictions += self.cache.put(
                        pid, block[j * rpp:(j + 1) * rpp])
                st.misses += b - a

    def fetch(self, plan: IOPlan) -> None:
        """Execute an IO-batch plan: each deduped page read at most once
        (and not at all when cache-resident)."""
        self.fetch_pages(plan.pages)

    def gather(self, slots: np.ndarray,
               layout: PageLayout | None = None) -> np.ndarray:
        """(len(slots), d) f64 rows for flat slot ids, through the cache.

        ``layout`` maps slots for a specific store generation (a
        ``StoreView`` passes its frozen one); default is the current
        manifest's.  Pages already resident are *not* re-counted as
        cache requests — the buffer-pool stats reflect the planned
        fetches, while gather is the data access behind them (only a
        page evicted between fetch and gather costs a genuine re-read).
        """
        lay = layout if layout is not None else self.layout
        slots = np.asarray(slots, dtype=np.int64)
        out = np.empty((len(slots), lay.d), np.float64)
        if len(slots) == 0:
            return out
        with self._lock:
            pages, offs = lay.slot_locations(slots)
            missing = [int(p) for p in np.unique(pages)
                       if self.cache.peek(p) is None]
            if missing:
                self.fetch_pages(np.asarray(missing, np.int64))
            order = np.argsort(pages, kind="stable")
            sp, so = pages[order], offs[order]
            bounds = np.concatenate(
                [[0], np.nonzero(np.diff(sp))[0] + 1, [len(sp)]])
            for a, b in zip(bounds[:-1], bounds[1:]):
                block = self.cache.peek(int(sp[a]))
                if block is None:           # evicted under tiny capacity
                    self.fetch_pages(sp[a:a + 1])
                    block = self.cache.peek(int(sp[a]))
                out[order[a:b]] = block[so[a:b]]
            self.stats.rows_gathered += len(slots)
        return out

    def view(self, layout: PageLayout | None = None) -> "StoreView":
        """Freeze a generation's layout into a view (what a snapshot
        binds to — see ``StoreView``); default is the current one."""
        return StoreView(self, layout)

    def record_queries(self, pages_per_query, cand_per_query) -> None:
        """Record per-query serving metrics under the store lock (the
        executor is shared across lock-free query threads; unsynchronized
        read-modify-writes would lose counts)."""
        with self._lock:
            self.stats.record_queries(pages_per_query, cand_per_query)

    def read_cluster(self, k: int) -> np.ndarray:
        """(n_max, d) f64 bulk read of one cluster extent (no cache —
        used by the resident loader, not the query path)."""
        a, b = self.layout.cluster_file_rows(k)
        return np.array(self._mm[a:b], dtype=np.float64)

    def nbytes_file(self) -> int:
        return os.path.getsize(os.path.join(self.root,
                                            self.manifest.pages_file))


class StoreView:
    """One snapshot's binding to a ``PagedStore``: the generation's
    layout frozen at bind time.

    The reader is shared and mutable (``refresh()`` adopts newer
    manifests so a serving engine reuses one warm cache across
    generations), but a snapshot's slot ids are only meaningful under
    the extents of *its* generation — so each snapshot gathers through
    a view that captured them.  Append-only page ids keep an old view's
    extents byte-valid in the file (and in the cache) after any number
    of later writebacks, which is exactly what lets an in-flight batch
    on a pre-swap executor finish correctly.
    """

    def __init__(self, store: PagedStore, layout: PageLayout | None = None):
        self.base = store
        # an explicit layout pins a specific generation (the snapshot
        # loader passes the one matching the metadata it just read, so a
        # concurrent writeback between the two reads can't mismatch them)
        self.layout = layout if layout is not None else store.layout

    def gather(self, slots: np.ndarray) -> np.ndarray:
        return self.base.gather(slots, layout=self.layout)

    def __getattr__(self, name):
        # everything generation-agnostic (fetch, stats, cache,
        # manifest, generation, nbytes_file, ...) delegates
        return getattr(self.base, name)


__all__ = ["PagedStore", "StoreView", "spill_rows", "load_meta"]
