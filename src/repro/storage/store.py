"""The paged store: append-only mmap file of cluster extents.

Writer (``spill_rows``): lays each cluster's ``n_max`` slot rows (f64,
mapped-value order — the order the learned positions predict) into a
contiguous extent of fixed-size pages inside the generation's pages
file.  Incremental spills reuse the extents of clusters whose row bytes
are unchanged (sha1 in the manifest) and *append* extents for dirty
ones; the new generation is published with one atomic manifest swap
(``repro.storage.manifest``).  A pages file is never rewritten in
place, so live readers — and their page caches — stay valid across
swaps.

Compaction (``PagedStore.compact``): append-only writebacks leave
garbage extents behind.  ``compact()`` rewrites the *live* extents into
a fresh pages file (named per generation) and publishes it with the same
atomic manifest swap; the old file is unlinked, but in-flight readers
keep serving through it because every generation-bound ``StoreView``
pins the (layout, pages file) pair it was created under, and an open
mmap keeps an unlinked file's bytes alive.  Page ids restart in the new
file, so the cache keys pages by (file, id) — ids are immutable *within*
a file, which preserves the never-invalidate property per generation.

Reader (``PagedStore``): read-only ``np.memmap``s over the pages files
plus an LRU page cache with access counters.  ``fetch`` takes an
``IOPlan`` (deduplicated, run-coalesced page list from the IO-batch
scheduler) and reads each missing run as one sequential slice;
``gather`` returns the f64 rows for a set of flat slot ids through the
cache, which is both the Pallas-refinement input (cast to f32 — the
same cast the resident snapshot applies) and the exact f64 refinement
input, so store-backed results are bit-identical to the in-memory path.
``record=False`` lets the async prefetcher pull pages in without
touching the demand-side buffer-pool counters (its IO is speculative;
the demand metrics keep meaning what queries asked for) — its reads
are charged to ``stats.prefetch_reads`` instead, so misses +
prefetch_reads always equals total page IO.
"""
from __future__ import annotations

import hashlib
import io
import os
import threading
import weakref

import numpy as np

from ..obs import registry as _obs
from .cache import DEFAULT_CACHE_PAGES, CacheStats, LRUPageCache
from .layout import DEFAULT_PAGE_BYTES, PageLayout, rows_per_page
from .manifest import FORMAT_VERSION, PAGES_NAME, Manifest, write_atomic
from .scheduler import IOPlan, page_runs


def _cluster_hashes(rows64: np.ndarray) -> list:
    return [hashlib.sha1(np.ascontiguousarray(rows64[k]).tobytes())
            .hexdigest() for k in range(rows64.shape[0])]


def spill_rows(root: str, rows64: np.ndarray,
               page_bytes: int = DEFAULT_PAGE_BYTES,
               meta_arrays: dict | None = None) -> Manifest:
    """Write (or incrementally refresh) the paged row store under ``root``.

    ``rows64``: (K, n_max, d) f64 cluster-major slot rows.  When a
    compatible manifest already exists, unchanged clusters keep their
    extents and only dirty clusters append new pages ("retrained
    clusters write back as new page extents") — into whatever pages file
    the current generation references (a compaction may have renamed
    it); otherwise every cluster gets a fresh extent (still
    append-only).  ``meta_arrays`` (optional) lands in a
    generation-stamped ``meta-<gen>.npz`` referenced by the manifest,
    published together by the atomic manifest swap.
    """
    K, n_max, d = rows64.shape
    rows64 = np.ascontiguousarray(rows64, dtype=np.float64)
    os.makedirs(root, exist_ok=True)
    prev = Manifest.load(root) if Manifest.exists(root) else None
    rpp = rows_per_page(page_bytes, d)
    reusable = (prev is not None and prev.n_max == n_max and prev.d == d
                and prev.rows_per_page == rpp and prev.K == K)
    if prev is not None and not reusable and (prev.d != d or
                                              prev.rows_per_page != rpp):
        raise ValueError(
            "store geometry changed (d or page size); spill to a fresh "
            "directory instead of mixing record formats in one file")
    hashes = _cluster_hashes(rows64)
    ppc = -(-n_max // rpp)
    next_page = prev.total_pages if prev is not None else 0
    extents, dirty = [], []
    for k in range(K):
        if reusable and prev.cluster_sha1[k] == hashes[k]:
            extents.append(prev.extents[k])
        else:
            extents.append(next_page)
            dirty.append(k)
            next_page += ppc

    pages_file = prev.pages_file if prev is not None else PAGES_NAME
    pages_path = os.path.join(root, pages_file)
    stride_rows = ppc * rpp
    with open(pages_path, "r+b" if prev is not None else "wb") as f:
        for k in dirty:
            block = np.zeros((stride_rows, d), np.float64)
            block[:n_max] = rows64[k]
            f.seek(extents[k] * rpp * d * 8)
            f.write(block.tobytes())
        f.flush()
        os.fsync(f.fileno())

    gen = prev.generation + 1 if prev is not None else 0
    meta_file = ""
    if meta_arrays is not None:
        meta_file = f"meta-{gen}.npz"
        buf = io.BytesIO()
        np.savez(buf, **meta_arrays)
        write_atomic(os.path.join(root, meta_file), buf.getvalue())
    man = Manifest(version=FORMAT_VERSION, generation=gen,
                   page_bytes=page_bytes, rows_per_page=rpp, d=d,
                   n_max=n_max, K=K, total_pages=next_page,
                   extents=extents, cluster_sha1=hashes,
                   pages_file=pages_file,
                   meta_file=meta_file or (prev.meta_file if prev else ""))
    man.save(root)
    # prune stale metas, but never one a live manifest can reference:
    # the one just published (possibly carried forward from an older
    # generation) or the previous manifest's (a reader that loaded it
    # moments ago must still find its meta)
    keep = {man.meta_file} | ({prev.meta_file} if prev else set())
    for name in os.listdir(root):
        if name.startswith("meta-") and name.endswith(".npz") \
                and name not in keep:
            g = int(name[5:-4])
            if g < gen - 1:
                os.unlink(os.path.join(root, name))
    return man


def load_meta(root: str) -> tuple[dict, Manifest]:
    """Read the manifest and its generation's metadata arrays."""
    man = Manifest.load(root)
    if not man.meta_file:
        raise FileNotFoundError(f"store at {root!r} has no metadata file")
    with np.load(os.path.join(root, man.meta_file)) as z:
        meta = {k: z[k] for k in z.files}
    return meta, man


class PagedStore:
    """mmap reader over a spilled store: page cache + IO accounting."""

    def __init__(self, root: str,
                 cache_pages: int | None = DEFAULT_CACHE_PAGES):
        self.root = root
        self.manifest = Manifest.load(root)
        self.cache = LRUPageCache(cache_pages)
        self.stats = CacheStats()
        # serializes cache/mmap mutation: executors share one reader
        # across concurrent lock-free query threads (the resident path's
        # immutability argument doesn't cover the page cache), so page
        # IO is the one place store-mode queries serialize.  Reentrant —
        # gather() fetches missing pages under its own lock; the async
        # prefetcher's background fetches take the same lock.
        self._lock = threading.RLock()
        # pages files by name: the current generation's plus any older
        # ones still pinned by generation-bound views (a compaction
        # retires a file from the manifest, but its mmap lives here
        # until the last view of it dies, so in-flight readers keep
        # their bytes even after the unlink — and the disk blocks ARE
        # freed once those views go away, see _prune_maps)
        self._maps: dict[str, np.memmap] = {}
        self._view_refs: dict[str, weakref.WeakSet] = {}
        self._map()

    def _map(self) -> None:
        """(Re)map the current manifest's pages file."""
        man = self.manifest
        self.layout: PageLayout = man.layout()
        n_rows = man.total_pages * man.rows_per_page
        self._maps[man.pages_file] = np.memmap(
            os.path.join(self.root, man.pages_file), dtype="<f8", mode="r",
            shape=(max(n_rows, 1), man.d))

    def _register_view(self, view: "StoreView") -> None:
        """Track which pages files live views pin (weakly — a dead view
        stops pinning automatically)."""
        with self._lock:
            self._view_refs.setdefault(view.file, weakref.WeakSet()) \
                .add(view)

    def _prune_maps(self) -> None:
        """Drop mmaps of non-current files no live view pins (called
        under the lock).  Closing the last mapping of an unlinked
        pages file is what actually returns its disk blocks — without
        this, compaction would only ever *rename* garbage."""
        cur = self.manifest.pages_file
        for name in list(self._maps):
            if name == cur:
                continue
            refs = self._view_refs.get(name)
            if refs is None or not len(refs):
                del self._maps[name]
                self._view_refs.pop(name, None)

    def _mmap_for(self, file: str) -> np.memmap:
        mm = self._maps.get(file)
        if mm is None:
            # a view bound before this reader existed (cross-process
            # race); best effort by size — raises if compaction already
            # unlinked the file
            path = os.path.join(self.root, file)
            n_rows = os.path.getsize(path) // (self.manifest.d * 8)
            mm = np.memmap(path, dtype="<f8", mode="r",
                           shape=(max(int(n_rows), 1), self.manifest.d))
            self._maps[file] = mm
        return mm

    @property
    def generation(self) -> int:
        return self.manifest.generation

    @property
    def pages_file(self) -> str:
        return self.manifest.pages_file

    def refresh(self) -> "PagedStore":
        """Adopt the latest published manifest (after a writer swap).

        Within one pages file page ids are append-only, so cached pages
        stay byte-valid and a rewritten cluster simply references new
        ids; a compaction switches the manifest to a fresh file, which
        maps alongside the old one (views pinned to the old file keep
        gathering through it).
        """
        with self._lock:
            man = Manifest.load(self.root)
            if man.generation != self.manifest.generation:
                self.manifest = man
                self._map()
            self._prune_maps()
        return self

    # ------------------------------------------------------------------ io
    def fetch_pages(self, pages: np.ndarray, file: str | None = None,
                    record: bool = True) -> None:
        """Ensure ``pages`` (of ``file``; default the current
        generation's) are cached; missing ones read as runs.
        ``record=False`` skips the demand-side buffer-pool counters —
        the async prefetcher's speculative IO — but the reads still
        land in ``prefetch_reads``, so misses + prefetch_reads is
        always the total page IO (no invisible reads)."""
        with self._lock:
            file = file if file is not None else self.manifest.pages_file
            st = self.stats
            missing = []
            hits = 0
            for pid in np.asarray(pages, dtype=np.int64):
                pid = int(pid)
                if record:
                    st.requests += 1
                if self.cache.touch((file, pid)):
                    hits += 1
                else:
                    missing.append(pid)
            if record:
                st.hits += hits
                _obs.count("storage.page_requests", len(pages))
                _obs.count("storage.cache_hits", hits)
            if not missing:         # fully cache-resident: no file IO,
                return              # and no mapping of a retired file
            rpp = self.layout.rows_per_page
            mm = self._mmap_for(file)
            evs = 0
            for a, b in page_runs(np.asarray(missing, np.int64)):
                block = np.array(mm[a * rpp:b * rpp], dtype=np.float64)
                for j, pid in enumerate(range(a, b)):
                    evs += self.cache.put(
                        (file, pid), block[j * rpp:(j + 1) * rpp])
                if record:
                    st.misses += b - a
                else:
                    st.prefetch_reads += b - a
            # evictions are real whoever triggered the insert — an
            # uncounted speculative insert could silently thrash the pool
            st.evictions += evs
            _obs.count("storage.page_reads" if record
                       else "storage.prefetch_reads", len(missing))
            if evs:
                _obs.count("storage.evictions", evs)

    def fetch(self, plan: IOPlan, file: str | None = None) -> None:
        """Execute an IO-batch plan: each deduped page read at most once
        (and not at all when cache-resident)."""
        self.fetch_pages(plan.pages, file=file)

    # ------------------------------------------------------ schedule pins
    def pin_pages(self, pages: np.ndarray, file: str | None = None) -> None:
        """Hold ``pages`` against capacity eviction for a planned batch
        (refcounted; pinning before the fetch is fine — the hold applies
        on insert).  Callers must pair with ``unpin_pages`` — the paged
        backend does so in a ``finally`` so an executor error can't leak
        a batch's pins."""
        with self._lock:
            file = file if file is not None else self.manifest.pages_file
            self.cache.pin([(file, int(p)) for p in np.asarray(pages)])
            pinned = self.cache.pinned
        _obs.count("storage.page_pins", len(pages))
        _obs.set_gauge("storage.pinned_pages", pinned)

    def unpin_pages(self, pages: np.ndarray,
                    file: str | None = None) -> None:
        """Release one batch's holds; pages rejoin plain LRU at the
        recency their accesses earned, and any pin-era overflow evicts
        immediately (counted with the regular eviction stats)."""
        with self._lock:
            file = file if file is not None else self.manifest.pages_file
            evs = self.cache.unpin(
                [(file, int(p)) for p in np.asarray(pages)])
            self.stats.evictions += evs
            pinned = self.cache.pinned
        if evs:
            _obs.count("storage.evictions", evs)
        _obs.set_gauge("storage.pinned_pages", pinned)

    def cluster_heat(self, layout: PageLayout | None = None,
                     file: str | None = None) -> np.ndarray:
        """(K,) page-cache access counts folded per cluster extent — the
        demand signal the router's replica placement consumes (hot
        clusters get replicated / reassigned first).  Counts accumulate
        across the store's lifetime; callers diff snapshots for a rate.
        """
        with self._lock:
            lay = layout if layout is not None else self.layout
            file = file if file is not None else self.manifest.pages_file
            ppc = lay.pages_per_cluster
            K = len(lay.extents)
            owner = {}                      # page id → cluster (this gen)
            for k in range(K):
                base = int(lay.extents[k])
                for p in range(base, base + ppc):
                    owner[p] = k
            heat = np.zeros(K, np.int64)
            for (f, pid), cnt in self.cache.access.items():
                k = owner.get(pid) if f == file else None
                if k is not None:
                    heat[k] += cnt
            return heat

    def gather(self, slots: np.ndarray, layout: PageLayout | None = None,
               file: str | None = None) -> np.ndarray:
        """(len(slots), d) f64 rows for flat slot ids, through the cache.

        ``layout``/``file`` map slots for a specific store generation (a
        ``StoreView`` passes its frozen pair); default is the current
        manifest's.  Pages already resident are *not* re-counted as
        cache requests — the buffer-pool stats reflect the planned
        fetches, while gather is the data access behind them (only a
        page evicted between fetch and gather costs a genuine re-read).
        """
        lay = layout if layout is not None else self.layout
        slots = np.asarray(slots, dtype=np.int64)
        out = np.empty((len(slots), lay.d), np.float64)
        if len(slots) == 0:
            return out
        with self._lock:
            file = file if file is not None else self.manifest.pages_file
            pages, offs = lay.slot_locations(slots)
            missing = [int(p) for p in np.unique(pages)
                       if self.cache.peek((file, int(p))) is None]
            if missing:
                self.fetch_pages(np.asarray(missing, np.int64), file=file)
            order = np.argsort(pages, kind="stable")
            sp, so = pages[order], offs[order]
            bounds = np.concatenate(
                [[0], np.nonzero(np.diff(sp))[0] + 1, [len(sp)]])
            for a, b in zip(bounds[:-1], bounds[1:]):
                block = self.cache.peek((file, int(sp[a])))
                if block is None:           # evicted under tiny capacity
                    self.fetch_pages(sp[a:a + 1], file=file)
                    block = self.cache.peek((file, int(sp[a])))
                out[order[a:b]] = block[so[a:b]]
            self.stats.rows_gathered += len(slots)
        _obs.count("storage.rows_gathered", len(slots))
        return out

    def view(self, layout: PageLayout | None = None,
             file: str | None = None) -> "StoreView":
        """Freeze a generation's (layout, pages file) into a view (what
        a snapshot binds to — see ``StoreView``); default the current."""
        return StoreView(self, layout, file)

    def record_queries(self, pages_per_query, cand_per_query) -> None:
        """Record per-query serving metrics under the store lock (the
        executor is shared across lock-free query threads; unsynchronized
        read-modify-writes would lose counts)."""
        with self._lock:
            self.stats.record_queries(pages_per_query, cand_per_query)
        _obs.count("storage.queries", len(pages_per_query))
        _obs.count("storage.pages_touched", int(np.sum(pages_per_query)))
        _obs.count("storage.candidates", int(np.sum(cand_per_query)))

    def read_cluster(self, k: int) -> np.ndarray:
        """(n_max, d) f64 bulk read of one cluster extent (no cache —
        used by the resident loader, not the query path)."""
        a, b = self.layout.cluster_file_rows(k)
        with self._lock:
            return np.array(self._mmap_for(self.manifest.pages_file)[a:b],
                            dtype=np.float64)

    # ------------------------------------------------------------ lifecycle
    def compact(self, unlink_old: bool = True) -> Manifest:
        """Rewrite the live extents into a fresh pages file and publish
        it with an atomic manifest swap.

        Repeated retrain writebacks append new extents and orphan the
        old ones; compaction reclaims that garbage: every cluster's
        current extent is copied, in cluster order, into
        ``pages-<gen>.bin`` (dense extents, ``K · pages_per_cluster``
        total pages), the manifest flips to it atomically, and the old
        file is unlinked (``unlink_old``).  In-flight readers are
        untouched: their ``StoreView``s pin the old (layout, file) pair
        and the already-open mmap keeps the unlinked bytes readable
        until the views die.  Metadata (``meta-*.npz``) is untouched —
        compaction moves rows, not models.

        The copy reads through a fresh mmap sized to the *latest
        published* manifest — never this reader's possibly older one —
        so extents appended since the last ``refresh()`` are copied in
        full.  Published extents are immutable, so the rewrite runs
        outside the store lock (queries never block on it); only the
        adoption of the new manifest serializes with fetch/gather.
        Concurrent *writers* must be serialized by the caller, as for
        ``spill_rows`` (``ServingEngine.compact`` holds its update
        lock).
        """
        man = Manifest.load(self.root)     # latest published
        lay = man.layout()
        rpp = man.rows_per_page
        ppc = lay.pages_per_cluster
        stride = ppc * rpp
        src = np.memmap(os.path.join(self.root, man.pages_file),
                        dtype="<f8", mode="r",
                        shape=(max(man.total_pages * rpp, 1), man.d))
        new_name = f"pages-{man.generation + 1}.bin"
        path = os.path.join(self.root, new_name)
        with open(path, "wb") as f:
            for k in range(man.K):
                a = int(man.extents[k]) * rpp
                f.write(np.ascontiguousarray(
                    src[a:a + stride], dtype="<f8").tobytes())
            f.flush()
            os.fsync(f.fileno())
        new_man = Manifest(
            version=FORMAT_VERSION, generation=man.generation + 1,
            page_bytes=man.page_bytes, rows_per_page=rpp, d=man.d,
            n_max=man.n_max, K=man.K, total_pages=man.K * ppc,
            extents=[k * ppc for k in range(man.K)],
            cluster_sha1=list(man.cluster_sha1),
            pages_file=new_name, meta_file=man.meta_file)
        new_man.save(self.root)
        if unlink_old:
            for name in os.listdir(self.root):
                if name != new_name and (
                        name == PAGES_NAME or
                        (name.startswith("pages-") and
                         name.endswith(".bin"))):
                    os.unlink(os.path.join(self.root, name))
        with self._lock:
            self.manifest = new_man
            self._map()
            self._prune_maps()
        return new_man

    def drop_os_cache(self) -> bool:
        """Best-effort eviction of every pages file from the OS page
        cache, so the next cold pass reads from the device (the
        ``--real-io`` benchmark mode).  True when the platform supports
        the advice.

        ``POSIX_FADV_DONTNEED`` cannot evict pages a live mapping pins,
        so files still on disk are *remapped*: the old mmap is dropped
        (its cached page blocks are copies, nothing dangles), the
        advice runs against an unmapped file, and a fresh mmap comes
        back cold.  Unlinked files (pre-compaction generations pinned
        by in-flight views) are left mapped — they have no disk
        presence to evict anyway."""
        if not hasattr(os, "posix_fadvise"):
            return False
        with self._lock:
            names = [n for n in set(self._maps) | {self.manifest.pages_file}
                     if os.path.exists(os.path.join(self.root, n))]
            for name in names:
                self._maps.pop(name, None)      # munmap: release the pin
            for name in names:
                path = os.path.join(self.root, name)
                fd = os.open(path, os.O_RDONLY)
                try:
                    os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
                finally:
                    os.close(fd)
            self._map()                         # remap current, cold
        return True

    def nbytes_file(self) -> int:
        return os.path.getsize(os.path.join(self.root,
                                            self.manifest.pages_file))


class StoreView:
    """One snapshot's binding to a ``PagedStore``: the generation's
    (layout, pages file) frozen at bind time.

    The reader is shared and mutable (``refresh()`` adopts newer
    manifests so a serving engine reuses one warm cache across
    generations), but a snapshot's slot ids are only meaningful under
    the extents of *its* generation — so each snapshot gathers through
    a view that captured them.  Within a pages file page ids are
    append-only, which keeps an old view's extents byte-valid (and its
    cached pages correct) after any number of later writebacks; across
    a compaction the view additionally pins the *file*, whose open mmap
    outlives the unlink — which is exactly what lets an in-flight batch
    on a pre-swap executor finish correctly.
    """

    def __init__(self, store: PagedStore, layout: PageLayout | None = None,
                 file: str | None = None):
        self.base = store
        # an explicit layout/file pins a specific generation (the
        # snapshot loader passes the pair matching the metadata it just
        # read, so a concurrent writeback between the two reads can't
        # mismatch them)
        self.layout = layout if layout is not None else store.layout
        self.file = file if file is not None else store.manifest.pages_file
        store._register_view(self)

    def gather(self, slots: np.ndarray) -> np.ndarray:
        return self.base.gather(slots, layout=self.layout, file=self.file)

    def fetch(self, plan: IOPlan) -> None:
        self.base.fetch_pages(plan.pages, file=self.file)

    def fetch_pages(self, pages: np.ndarray, record: bool = True) -> None:
        self.base.fetch_pages(pages, file=self.file, record=record)

    def pin_pages(self, pages: np.ndarray) -> None:
        self.base.pin_pages(pages, file=self.file)

    def unpin_pages(self, pages: np.ndarray) -> None:
        self.base.unpin_pages(pages, file=self.file)

    def cluster_heat(self) -> np.ndarray:
        return self.base.cluster_heat(layout=self.layout, file=self.file)

    def __getattr__(self, name):
        # everything generation-agnostic (stats, cache, manifest,
        # generation, record_queries, nbytes_file, ...) delegates
        return getattr(self.base, name)


__all__ = ["PagedStore", "StoreView", "spill_rows", "load_meta"]
