"""Store manifest: the single source of truth for one store generation.

A spilled snapshot directory holds

  pages.bin        append-only packed pages (f64 rows, little-endian)
  meta-<gen>.npz   every non-row snapshot array (index metadata)
  manifest.json    THIS file: geometry + per-cluster extents + hashes

``manifest.json`` is the atomicity point.  Writers prepare everything
else first (append new page extents, write the new meta file, fsync),
then publish with a single ``os.replace`` of the manifest — a reader
either sees the previous complete generation or the new complete
generation, never a torn state.  Because a pages file is append-only,
page ids are immutable once written: a page cache keyed on
(file, page id) never needs invalidation across generations, and a
crashed writer leaves at worst unreferenced garbage pages.  Compaction
(``PagedStore.compact``) reclaims that garbage by switching
``pages_file`` to a freshly rewritten ``pages-<gen>.bin`` in the same
atomic swap; generation-bound views keep the retired file's name (and
mmap) so their page ids stay meaningful.

``cluster_sha1`` lets an incremental writer skip clusters whose row
bytes are unchanged (their extents carry over; only dirty clusters cost
IO on a refresh/retrain writeback).
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field

from .layout import PageLayout

MANIFEST_NAME = "manifest.json"
PAGES_NAME = "pages.bin"
FORMAT_VERSION = 1


def write_atomic(path: str, data: bytes) -> None:
    """temp file in the same directory + fsync + rename: the standard
    crash-safe publish (an interrupted writer can't truncate ``path``)."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


@dataclass
class Manifest:
    version: int
    generation: int
    page_bytes: int
    rows_per_page: int
    d: int
    n_max: int
    K: int
    total_pages: int
    extents: list = field(default_factory=list)        # (K,) start pages
    cluster_sha1: list = field(default_factory=list)   # (K,) row-byte hashes
    pages_file: str = PAGES_NAME
    meta_file: str = ""

    def layout(self) -> PageLayout:
        return PageLayout(page_bytes=self.page_bytes,
                          rows_per_page=self.rows_per_page,
                          d=self.d, n_max=self.n_max,
                          extents=tuple(self.extents))

    # ------------------------------------------------------------------- io
    @staticmethod
    def path_in(root: str) -> str:
        return os.path.join(root, MANIFEST_NAME)

    @classmethod
    def exists(cls, root: str) -> bool:
        return os.path.exists(cls.path_in(root))

    @classmethod
    def load(cls, root: str) -> "Manifest":
        with open(cls.path_in(root), "rb") as f:
            raw = json.loads(f.read().decode())
        if raw.get("version") != FORMAT_VERSION:
            raise ValueError(
                f"unsupported store format version {raw.get('version')!r}")
        return cls(**raw)

    def save(self, root: str) -> None:
        """Publish this generation: one atomic rename (see module doc)."""
        data = json.dumps(asdict(self), indent=1, sort_keys=True).encode()
        write_atomic(self.path_in(root), data)


__all__ = ["Manifest", "write_atomic", "MANIFEST_NAME", "PAGES_NAME",
           "FORMAT_VERSION"]
