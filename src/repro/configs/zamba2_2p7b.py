"""zamba2-2.7b [hybrid] — Mamba2 trunk + shared attention block every 6
layers with concat down-projection [arXiv:2411.15242]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80, shared_every=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4, chunk=128),
)
