"""llava-next-34b [vlm] — anyres tiling; backbone only, the vision tower
is a stub providing (B, 2304, d_model) patch embeddings
[hf:llava-hf/llava-v1.6]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128, rope_theta=5_000_000.0,
    n_prefix_embeds=2304,
    # 56 q-heads don't shard 16-way; pad to 64 with zero wq/wo rows
    # (outputs unchanged, attention shards instead of replicating 16x)
    pad_heads_to=64,
)
