"""Architecture registry: ``--arch <id>`` resolution."""
from .base import SHAPES, ModelConfig, ShapeCell, long_context_ok

from .chatglm3_6b import CONFIG as chatglm3_6b
from .deepseek_7b import CONFIG as deepseek_7b
from .internlm2_20b import CONFIG as internlm2_20b
from .kimi_k2_1t import CONFIG as kimi_k2_1t
from .llama3_8b import CONFIG as llama3_8b
from .llava_next_34b import CONFIG as llava_next_34b
from .mamba2_780m import CONFIG as mamba2_780m
from .mixtral_8x7b import CONFIG as mixtral_8x7b
from .seamless_m4t_large import CONFIG as seamless_m4t_large
from .zamba2_2p7b import CONFIG as zamba2_2p7b

ARCHS: dict[str, ModelConfig] = {c.name: c for c in [
    deepseek_7b, chatglm3_6b, internlm2_20b, llama3_8b, zamba2_2p7b,
    kimi_k2_1t, mixtral_8x7b, mamba2_780m, llava_next_34b,
    seamless_m4t_large,
]}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) cells; skips long_500k for pure full attention."""
    out = []
    for name, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            skip = sname == "long_500k" and not long_context_ok(cfg)
            if skip and not include_skipped:
                continue
            out.append((name, sname, skip))
    return out
