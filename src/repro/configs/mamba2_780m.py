"""mamba2-780m [ssm] — attention-free SSD (state-space duality)
[arXiv:2405.21060]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280, head_dim=64, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=256),
)
