"""chatglm3-6b [dense] — GQA kv=2, 2d-RoPE (partial rotary 0.5)
[arXiv:2406.12793]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, head_dim=128, rope_theta=10_000.0,
    partial_rotary=0.5,
)
