"""seamless-m4t-large-v2 [audio] — enc-dec backbone (24+24 layers); the
modality frontend is a stub providing (B, S_frames, d_model) embeddings
[arXiv:2308.11596]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_dec_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64, cross_attention=True,
)
