"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention 4096
[arXiv:2401.04088]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128, rope_theta=1_000_000.0,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336,
                  capacity_factor=1.25),
)
