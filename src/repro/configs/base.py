"""Model / run configuration schema.

One ``ModelConfig`` per assigned architecture (exact paper numbers) plus a
``reduced()`` shrink used by CPU smoke tests. Shape cells (train_4k /
prefill_32k / decode_32k / long_500k) live in ``ShapeCell``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                   # FFN hidden size per expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    n_shared_experts: int = 0       # dense experts always active (deepseek-style)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128
    n_groups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    rope_theta: float = 10_000.0
    partial_rotary: float = 1.0     # chatglm3: 0.5 ("RoPE 2d")
    sliding_window: Optional[int] = None   # mixtral: 4096
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    # MoE dispatch locality groups (set ≥ data-parallel degree so routing
    # stays shard-local and only expert buffers cross the mesh)
    moe_dispatch_groups: int = 1
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block every `shared_every`
    # mamba layers, with per-invocation concat down-projections
    shared_every: int = 6
    # encdec (seamless): layers counted per stack
    n_dec_layers: Optional[int] = None
    cross_attention: bool = False
    # vlm (llava): stub patch-embedding prefix length
    n_prefix_embeds: int = 0
    # computational head padding: extra q-heads with zero wq/wo rows so
    # the head dim shards on the production mesh (outputs are unchanged —
    # zero wo rows drop the dummy heads). llava: 56 → 64.
    pad_heads_to: Optional[int] = None
    # --- numerics / execution ---
    dtype: str = "bfloat16"
    attn_impl: str = "chunked"      # dense | chunked
    attn_chunk: int = 2048
    remat: str = "selective"        # none | full | selective
    scan_layers: bool = True
    # --- notes for the roofline table ---
    approx_params: Optional[float] = None   # filled by param counter

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def n_q_heads(self) -> int:
        """Compute-time q-head count (≥ n_heads when padded for sharding)."""
        return self.pad_heads_to or self.n_heads

    def reduced(self) -> "ModelConfig":
        """Smoke-test shrink of the same family: tiny widths/layers/experts,
        same code paths."""
        kw = dict(
            n_layers=min(self.n_layers, 4) if self.family != "hybrid" else 8,
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2))
            if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab=512,
            head_dim=32,
            n_prefix_embeds=8 if self.family == "vlm" else 0,
            pad_heads_to=None,
            attn_impl="dense",
            attn_chunk=64,
            remat="none",
        )
        if self.moe is not None:
            # capacity_factor 4.0: smoke tests verify routing/dispatch
            # mechanics drop-free; the drop path has its own unit test.
            kw["moe"] = MoEConfig(n_experts=4, top_k=min(self.moe.top_k, 2),
                                  d_expert=64, capacity_factor=4.0,
                                  n_shared_experts=min(
                                      self.moe.n_shared_experts, 1))
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, head_dim=16, chunk=16,
                                  d_conv=self.ssm.d_conv)
        if self.n_dec_layers is not None:
            kw["n_dec_layers"] = min(self.n_dec_layers, 2)
            kw["n_layers"] = min(self.n_layers, 2)
        if self.sliding_window is not None:
            kw["sliding_window"] = 32
        if self.family == "hybrid":
            kw["shared_every"] = 4
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode

    def reduced(self) -> "ShapeCell":
        return ShapeCell(self.name, min(self.seq_len, 64),
                         min(self.global_batch, 2), self.kind)


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# long_500k requires sub-quadratic attention: SSM / hybrid / sliding-window.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def long_context_ok(cfg: ModelConfig) -> bool:
    return cfg.family in SUBQUADRATIC_FAMILIES or cfg.sliding_window is not None


@dataclass(frozen=True)
class RunConfig:
    """Execution-level knobs (sharding / optimizer / fault tolerance)."""
    fsdp: bool = False              # shard weights over the data axis too
    zero1: bool = False             # shard ONLY optimizer state + grad
                                    # accumulators over data (no per-µb
                                    # weight re-gather, unlike fsdp)
    seq_shard_activations: bool = False   # SP for long prefill
    microbatches: int = 1           # gradient accumulation (activation mem ÷ n)
    optimizer: str = "adamw"        # adamw | adafactor | adamw8bit
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_compression: Optional[str] = None  # None | int8
    remat_override: Optional[str] = None
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_ckpts: int = 3
