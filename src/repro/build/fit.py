"""Batched rank-model fitting: one launch for every model in the index.

The host build fits K·m distance→rank models plus K LIMS-value→position
models one ``chebfit`` at a time.  Here all G = K·m + K groups solve in
a single jitted launch: a Chebyshev-Vandermonde basis over the padded
(G, n_max) column matrix, normal equations per group, and one batched
``linalg.solve`` on the (G, C, C) stack.

Numerical notes (f32 on device):

* the basis is Chebyshev on x normalized to [-1, 1] — the same model
  class as the host's ``PolyRankModel.fit`` (degree-g polynomials),
  same normalization, so device coefficients drop straight into
  ``PolyRankModel`` records;
* normal equations square the basis condition number, so each group
  gets a scale-aware Tikhonov jitter, the per-group degree is capped
  exactly like the hardened host fit (``min(degree, max(1, n//8),
  n_distinct - 1)``), and any group whose solve still goes non-finite
  falls back to the exact linear ramp rank ≈ (n-1)(t+1)/2;
* model quality never affects exactness (DESIGN.md §3/§6) — a worse
  fit only widens the certified error bound E.

The same pass certifies a device-side rank-error estimate per group
(max deviation at the data points + the Chebyshev derivative bound for
the gaps, §3's recipe).  Snapshots built from the materialized index
re-certify E against the exact f64 columns through the deployed
``rankeval`` kernel; the device estimate is for diagnostics and for
callers staying entirely on device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_E_SLACK = 2.0      # rint half-steps + f32 eval slop (mirrors snapshot)


def cheb_basis(t: jax.Array, degree: int) -> jax.Array:
    """(..., n) → (..., n, degree+1) Chebyshev-Vandermonde basis via the
    T_k recurrence (numerically benign on [-1, 1])."""
    cols = [jnp.ones_like(t), t]
    for _ in range(2, degree + 1):
        cols.append(2.0 * t * cols[-1] - cols[-2])
    return jnp.stack(cols[:degree + 1], axis=-1)


@functools.partial(jax.jit, static_argnames=("max_degree",))
def _fit_kernel(cols: jax.Array, counts: jax.Array, deg_req: jax.Array,
                max_degree: int):
    """The one-launch fit. ``cols`` (G, n_max) ascending per group with
    arbitrary padding past ``counts[g]``; ``deg_req`` (G,) per-group
    requested degree (rank vs position models differ)."""
    G, n_max = cols.shape
    C = max_degree + 1
    idx = jnp.arange(n_max)
    n = counts.astype(jnp.float32)                            # (G,)
    w = (idx[None, :] < counts[:, None]).astype(jnp.float32)  # (G, n_max)

    lo = cols[:, 0]
    last = jnp.clip(counts - 1, 0, n_max - 1)
    hi = jnp.take_along_axis(cols, last[:, None], axis=1)[:, 0]
    span = hi - lo
    degenerate = (span <= 0) | (counts <= 1)
    span_safe = jnp.where(span > 0, span, 1.0)
    t = jnp.clip((cols - lo[:, None]) / span_safe[:, None] * 2.0 - 1.0,
                 -1.0, 1.0)

    # ties-low ranks within each sorted column: the last index that
    # started a new value, propagated by a running max
    prev = jnp.concatenate(
        [jnp.full((G, 1), -jnp.inf, cols.dtype), cols[:, :-1]], axis=1)
    newv = cols != prev
    ranks = jax.lax.cummax(
        jnp.where(newv, idx[None, :], 0), axis=1).astype(jnp.float32)
    n_distinct = jnp.sum(newv.astype(jnp.int32) * (w > 0), axis=1)

    # hardened per-group degree: over-determined and tie-aware
    dg = jnp.minimum(jnp.minimum(deg_req, jnp.maximum(1, counts // 8)),
                     jnp.maximum(1, n_distinct - 1))
    c_idx = jnp.arange(C)
    cmask = (c_idx[None, :] <= dg[:, None]).astype(jnp.float32)   # (G, C)

    T = cheb_basis(t, max_degree)                                 # (G,n,C)
    Tw = T * w[:, :, None] * cmask[:, None, :]
    A = jnp.einsum("gnc,gnd->gcd", Tw, Tw)
    b = jnp.einsum("gnc,gn->gc", Tw, ranks)
    # identity rows pin masked coefficients to 0; live rows get a
    # scale-aware jitter (diag(A) ≈ n/2 per Chebyshev coefficient)
    jitter = 1e-6 * jnp.maximum(n, 1.0)
    diag = jnp.where(cmask > 0, jitter[:, None], 1.0)
    A = A + jnp.eye(C)[None] * diag[:, None, :]
    coef = jnp.linalg.solve(A, b[..., None])[..., 0] * cmask

    # exact linear-ramp fallback for any solve that went non-finite
    r_last = jnp.take_along_axis(ranks, last[:, None], axis=1)[:, 0]
    ramp = jnp.zeros((G, C), coef.dtype)
    ramp = ramp.at[:, 0].set(r_last / 2.0)
    if C > 1:
        ramp = ramp.at[:, 1].set(r_last / 2.0)
    bad = ~jnp.all(jnp.isfinite(coef), axis=1)
    coef = jnp.where(bad[:, None], ramp, coef)
    coef = jnp.where(degenerate[:, None], 0.0, coef)
    hi_out = jnp.where(span > 0, hi, lo + 1.0)
    lo_out = jnp.where(counts > 0, lo, 0.0)
    hi_out = jnp.where(counts > 0, hi_out, 1.0)

    # device-side certified error estimate (§3): deployed-polynomial
    # deviation at the data points + derivative bound × largest t-gap
    pred = jnp.clip(jnp.rint(jnp.einsum("gnc,gc->gn", T, coef)),
                    0.0, jnp.maximum(n - 1.0, 0.0)[:, None])
    err_pt = jnp.max(jnp.abs(pred - ranks) * w, axis=1)
    deriv = jnp.sum((c_idx.astype(jnp.float32) ** 2)[None, :]
                    * jnp.abs(coef), axis=1)
    t_next = jnp.concatenate([t[:, 1:], t[:, -1:]], axis=1)
    pair_ok = (idx[None, :] + 1 < counts[:, None]).astype(jnp.float32)
    gap = jnp.max((t_next - t) * pair_ok, axis=1)
    err = jnp.minimum(err_pt + deriv * gap + _E_SLACK, n)
    err = jnp.where(counts > 0, err, 0.0)
    return coef, lo_out, hi_out, n, dg, err


def batched_chebfit(cols, counts, deg_req, max_degree: int):
    """Fit every group's rank model in one launch.

    ``cols`` (G, n_max) ascending (any padding), ``counts`` (G,) valid
    lengths, ``deg_req`` (G,) requested degree per group.  Returns
    ``(coef (G, max_degree+1), lo, hi, n, dg, err)`` — ``dg`` the
    per-group effective degree actually fit, ``err`` the device-side
    certified rank-error estimate.
    """
    return _fit_kernel(jnp.asarray(cols, jnp.float32),
                       jnp.asarray(counts, jnp.int32),
                       jnp.asarray(deg_req, jnp.int32), int(max_degree))


__all__ = ["batched_chebfit", "cheb_basis"]
