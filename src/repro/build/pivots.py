"""Device FFT pivot selection + pivot-distance columns for the builder.

``fft_sweeps`` runs the per-cluster farthest-first traversal for ALL
clusters at once over the padded cluster-major layout: each of the m-1
rounds is one masked argmax per cluster plus one batched
point-to-pivot distance pass — the device analogue of the host's
``repro.core.pivots.fft_pivots`` loop, including its degenerate-cluster
semantics (a re-picked pivot latches the cluster and the remaining
pivot slots repeat the last distinct pivot).

``pivot_columns`` computes the full (K, m, n_max) pivot-distance matrix
through the existing ``pdist`` Pallas kernel: pivots of a cluster chunk
form the query rows, the chunk's member rows the point rows, and the
block-diagonal of the resulting (cc·m, cc·n_max) launch is gathered per
cluster.  These f32 columns feed the rank-model fits only — the exact
f64 columns exactness depends on are recomputed on the host
(DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops


def _rows_to_pivot(rows: jax.Array, prow: jax.Array, metric: str) -> jax.Array:
    """(K, n_max) distances from every (padded) member row to its own
    cluster's pivot row — direct formulation, vectorized over clusters."""
    if metric == "l2":
        diff = rows - prow[:, None, :]
        return jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    if metric == "l1":
        return jnp.sum(jnp.abs(rows - prow[:, None, :]), axis=-1)
    if metric == "linf":
        return jnp.max(jnp.abs(rows - prow[:, None, :]), axis=-1)
    if metric == "cosine":
        xn = rows / jnp.maximum(
            jnp.linalg.norm(rows, axis=-1, keepdims=True), 1e-12)
        rn = prow / jnp.maximum(
            jnp.linalg.norm(prow, axis=-1, keepdims=True), 1e-12)
        return 1.0 - jnp.einsum("knd,kd->kn", xn, rn)
    raise ValueError(f"device pivots: unsupported metric {metric!r}")


@functools.partial(jax.jit, static_argnames=("m", "metric"))
def fft_sweeps(rows: jax.Array, mask: jax.Array, gids: jax.Array,
               d1: jax.Array, cent_rows: jax.Array, cent_gids: jax.Array,
               m: int, metric: str) -> jax.Array:
    """(K, m) global pivot ids for every cluster, pivot #1 = centroid.

    Mirrors the host loop: ``d_near`` starts at the centroid distances
    (the exact host values — parity of the first argmax is free), each
    round argmaxes within the cluster and min-updates, and a round that
    re-picks an existing pivot (all surviving ``d_near`` zero: duplicate
    points) latches the cluster into repeating its last pivot, exactly
    the host's ``break``-then-pad semantics.
    """
    K, n_max, _ = rows.shape
    neg = jnp.asarray(-jnp.inf, d1.dtype)
    d_near = jnp.where(mask, d1, neg)
    piv_gids = cent_gids[:, None].astype(gids.dtype)         # (K, 1..m)
    piv_row = cent_rows
    latched = ~mask.any(axis=1)                              # empty clusters
    for _ in range(1, m):
        best = jnp.argmax(d_near, axis=1)
        nxt_gid = jnp.take_along_axis(gids, best[:, None], axis=1)[:, 0]
        dup = jnp.any(nxt_gid[:, None] == piv_gids, axis=1)
        latched = latched | dup
        cand = jnp.take_along_axis(rows, best[:, None, None], axis=1)[:, 0]
        piv_row = jnp.where(latched[:, None], piv_row, cand)
        new_gid = jnp.where(latched, piv_gids[:, -1], nxt_gid)
        piv_gids = jnp.concatenate([piv_gids, new_gid[:, None]], axis=1)
        dj = _rows_to_pivot(rows, piv_row, metric)
        d_near = jnp.minimum(d_near, jnp.where(mask, dj, neg))
    return piv_gids


def pivot_columns(rows: jax.Array, pivot_rows: jax.Array, metric: str,
                  chunk: int = 16) -> jax.Array:
    """(K, m, n_max) f32 member→pivot distances through the ``pdist``
    Pallas kernel, chunked over clusters.

    One launch covers a chunk of ``cc`` clusters: queries are the
    chunk's cc·m pivots, points its cc·n_max member slots; the needed
    per-cluster block diagonal of the (cc·m, cc·n_max) result is then
    gathered, so the kernel waste factor is ``cc``, not K.  Cosine has
    no Pallas kernel — it falls back to the jitted ``cdist`` math.
    """
    K, n_max, d = rows.shape
    m = pivot_rows.shape[1]
    outs = []
    for c0 in range(0, K, chunk):
        c1 = min(c0 + chunk, K)
        cc = c1 - c0
        q = pivot_rows[c0:c1].reshape(cc * m, d)
        p = rows[c0:c1].reshape(cc * n_max, d)
        if metric == "l2":
            dist = jnp.sqrt(jnp.maximum(ops.pdist(q, p, metric="sql2"), 0.0))
        elif metric in ("l1", "linf"):
            dist = ops.pdist(q, p, metric=metric)
        else:                                   # cosine: no pallas kernel
            from ..core.metrics import cdist
            dist = cdist(q, p, metric)
        blocks = dist.reshape(cc, m, cc, n_max)
        outs.append(blocks[jnp.arange(cc), :, jnp.arange(cc), :])
    return jnp.concatenate(outs, axis=0)


__all__ = ["fft_sweeps", "pivot_columns"]
