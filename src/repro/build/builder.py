"""Builder orchestration + the exact host materialization.

``device_build`` runs the full §4 build pipeline with the heavy stages
on device — batched clustering sweeps, FFT pivot argmax sweeps,
pivot-distance columns through the ``pdist`` Pallas kernel, and every
rank/position model fit in one batched least-squares launch — and
returns a ``DeviceBuildResult``: the structural choices (clustering,
pivot ids), the device-fit models, and per-stage timings.

``LIMSIndex(backend="device")`` consumes the result and materializes
its host structures from it, recomputing exactly (f64, host
``dist_one_to_many``) everything exactness depends on: pivot-distance
columns, ring boundaries, TriPrune extents.  Device-fit models ride
along as-is — they are accelerators the host corrects with exponential
search, and snapshots re-certify their error bound E against the exact
columns (DESIGN.md §6).

``retrain_device`` is the single-cluster variant ``ServingEngine``
routes online retrains through.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from contextlib import nullcontext

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..core.clustering import Clustering
from ..core.metrics import MetricSpace
from ..core.rankmodel import PolyRankModel
from .cluster import cluster_major, device_kcenter, device_kmeans
from .fit import batched_chebfit
from .pivots import fft_sweeps, pivot_columns

_PAD_LIMS = np.float32(2 ** 30)     # sorts after every real LIMS value


@dataclass
class DeviceBuildResult:
    """Everything the host materialization needs from the device pass."""
    clustering: Clustering
    pivot_gids: np.ndarray                  # (K, m) global pivot object ids
    rank_models: list                       # K lists of m PolyRankModels
    pos_models: list                        # K PolyRankModels
    device_rank_err: np.ndarray             # (K, m) device-certified E est.
    timings: dict                           # per-stage seconds

    @property
    def K(self) -> int:
        return self.clustering.k


# ------------------------------------------------------------------ fitting
def _ranks_to_lims(cols_raw, mask, counts, n_rings: int):
    """Device ring assignment from the (K, m, n_max) raw column matrix:
    ties-low ranks per (cluster, pivot), equal-count ring ids, LIMS
    values, and the per-cluster sorted LIMS column for position fits."""
    K, m, n_max = cols_raw.shape
    inf = jnp.asarray(jnp.inf, cols_raw.dtype)
    masked = jnp.where(mask[:, None, :], cols_raw, inf)
    order = jnp.argsort(masked, axis=-1)                     # stable
    cols_sorted = jnp.take_along_axis(masked, order, axis=-1)
    idx = jnp.arange(n_max)
    prev = jnp.concatenate(
        [jnp.full((K, m, 1), -jnp.inf, cols_sorted.dtype),
         cols_sorted[:, :, :-1]], axis=-1)
    r_sorted = jax.lax.cummax(
        jnp.where(cols_sorted != prev, idx[None, None, :], 0), axis=2)
    inv = jnp.argsort(order, axis=-1)
    rank_member = jnp.take_along_axis(r_sorted, inv, axis=-1)  # (K, m, n_max)
    width = jnp.maximum(1, -(-jnp.asarray(counts) // n_rings))[:, None, None]
    rid = jnp.clip(rank_member // width, 0, n_rings - 1)
    weights = jnp.asarray(
        [n_rings ** (m - 1 - j) for j in range(m)], jnp.int32)
    lims = jnp.sum(rid.astype(jnp.int32)
                   * weights[None, :, None], axis=1)           # (K, n_max)
    lims_col = jnp.sort(jnp.where(mask, lims.astype(jnp.float32),
                                  _PAD_LIMS), axis=-1)
    return cols_sorted, lims_col


def _fit_all_models(cols_raw, mask, counts, n_rings: int, deg_rank: int,
                    pos_degree: int):
    """ONE batched least-squares launch for the K·m rank models and the
    K position models; returns host ``PolyRankModel`` records plus the
    device-side certified error estimate per rank group."""
    K, m, n_max = cols_raw.shape
    cols_sorted, lims_col = _ranks_to_lims(cols_raw, mask, counts, n_rings)
    counts_j = jnp.asarray(counts, jnp.int32)
    cols_all = jnp.concatenate(
        [cols_sorted.reshape(K * m, n_max), lims_col], axis=0)
    counts_all = jnp.concatenate(
        [jnp.repeat(counts_j, m), counts_j], axis=0)
    deg_req = jnp.concatenate(
        [jnp.full((K * m,), deg_rank, jnp.int32),
         jnp.full((K,), pos_degree, jnp.int32)], axis=0)
    coef, lo, hi, n, dg, err = batched_chebfit(
        cols_all, counts_all, deg_req, max(deg_rank, pos_degree))
    coef = np.asarray(coef, np.float64)
    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)
    dg = np.asarray(dg, np.int64)
    counts_all = np.asarray(counts_all, np.int64)

    def wrap(g: int) -> PolyRankModel:
        n_g = int(counts_all[g])
        if n_g == 0:
            return PolyRankModel(np.zeros(1), 0.0, 1.0, 0)
        c = coef[g, :int(dg[g]) + 1].copy()
        if not c.any():                      # constant / degenerate column
            c = np.zeros(1)
        return PolyRankModel(c, float(lo[g]), float(hi[g]), n_g)

    rank_models = [[wrap(k * m + j) for j in range(m)] for k in range(K)]
    pos_models = [wrap(K * m + k) for k in range(K)]
    dev_err = np.asarray(err, np.float64)[:K * m].reshape(K, m)
    return rank_models, pos_models, dev_err


# ------------------------------------------------------------- full build
def device_build(space: MetricSpace, n_clusters: int, m: int = 3,
                 n_rings: int = 20, degree: int = 8, pos_degree: int = 8,
                 seed: int = 0, clusterer: str = "kcenter",
                 learned: bool = True,
                 exact_sweeps: bool = True) -> DeviceBuildResult:
    """Run the device build pipeline and return its structural output.

    ``exact_sweeps`` runs the clustering / pivot argmax sweeps in f64
    (scoped ``enable_x64``) for structural bit-parity with the host
    build; f32 sweeps are available for accelerators without fast f64
    and only risk picking different (equally valid) centers/pivots.
    """
    if space._custom is not None or not space.is_vector:
        raise ValueError(
            "device build backend requires a built-in vector metric "
            f"(got {space.metric!r})")
    timings: dict = {}
    t0 = time.perf_counter()
    if clusterer == "kcenter":
        clustering = device_kcenter(space, n_clusters, seed=seed,
                                    exact_sweeps=exact_sweeps)
    elif clusterer == "kmeans":
        clustering = device_kmeans(space, n_clusters, seed=seed)
    else:
        raise ValueError(clusterer)
    timings["cluster_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    member_idx, mask, counts, _ = cluster_major(clustering.members)
    X = space.data
    dtype = np.float64 if exact_sweeps else np.float32
    ctx = enable_x64() if exact_sweeps else nullcontext()
    with ctx:
        rows_sw = jnp.asarray(X[member_idx].astype(dtype))
        mask_dev = jnp.asarray(mask)
        gids_dev = jnp.asarray(np.where(mask, member_idx, -1))
        d1_dev = jnp.asarray(
            (clustering.dist_to_center[member_idx] * mask).astype(dtype))
        cent_rows = jnp.asarray(X[clustering.center_idx].astype(dtype))
        cent_gids = jnp.asarray(clustering.center_idx)
        piv_gids = np.asarray(fft_sweeps(
            rows_sw, mask_dev, gids_dev, d1_dev, cent_rows, cent_gids,
            m, space.metric), dtype=np.int64)
    space.dist_count += int(counts.sum()) * (m - 1)
    # (empty clusters need no patching: fft_sweeps latches them onto the
    # centroid gid from round one — the host's centroid-only semantics)
    timings["pivot_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    rows_f32 = jnp.asarray(X[member_idx].astype(np.float32))
    pivot_rows = jnp.asarray(X[piv_gids].astype(np.float32))   # (K, m, d)
    cols_raw = pivot_columns(rows_f32, pivot_rows, space.metric)
    deg_rank = degree if learned else 1
    rank_models, pos_models, dev_err = _fit_all_models(
        cols_raw, jnp.asarray(mask), counts, n_rings, deg_rank, pos_degree)
    timings["fit_s"] = time.perf_counter() - t0
    timings["device_s"] = sum(timings.values())
    return DeviceBuildResult(
        clustering=clustering, pivot_gids=piv_gids,
        rank_models=rank_models, pos_models=pos_models,
        device_rank_err=dev_err, timings=timings)


# ------------------------------------------------------ index / snapshot API
def build_index(space: MetricSpace, n_clusters: int | None = None, **kw):
    """Build a host ``LIMSIndex`` through the device builder
    (``LIMSIndex(backend="device")`` convenience wrapper)."""
    from ..core.index import LIMSIndex
    return LIMSIndex(space, n_clusters=n_clusters, backend="device", **kw)


def build_snapshot(space: MetricSpace, n_clusters: int | None = None, *,
                   spill_path: str | None = None,
                   page_bytes: int | None = None,
                   store: bool = False, **kw):
    """Device-build an index and emit its serving ``LIMSSnapshot``.

    Returns ``(snapshot, index)`` — the snapshot serves through
    ``QueryExecutor``/``ShardedExecutor``; the index remains the §5.3
    update target, exactly as with a host build.

    ``spill_path`` additionally emits the paged disk layout as part of
    the build (DESIGN.md §7): rows land in learned-position page extents
    the moment they exist, so a freshly built corpus is cold-start
    servable without a second pass.  ``store=True`` returns the
    store-backed snapshot view instead of the resident one.
    """
    from ..core.snapshot import LIMSSnapshot
    index = build_index(space, n_clusters=n_clusters, **kw)
    snap = LIMSSnapshot.build(index)
    if spill_path is not None:
        from ..storage import DEFAULT_PAGE_BYTES, PagedStore
        snap.spill(spill_path,
                   page_bytes=page_bytes or DEFAULT_PAGE_BYTES)
        if store:
            snap = snap.with_store(PagedStore(spill_path))
    elif store:
        raise ValueError("store=True requires spill_path")
    return snap, index


# ------------------------------------------------------------------ retrain
def retrain_device(sub: MetricSpace, cent_row: np.ndarray, m: int,
                   n_rings: int, degree: int, pos_degree: int,
                   exact_sweeps: bool = True):
    """Single-cluster device rebuild for ``retrain_cluster`` (§5.3).

    Pivot selection + every model fit run on device (one cluster is one
    row of the padded layout); the pivot-distance matrix handed back is
    recomputed exactly on the host, so the caller's mapping/extents are
    bit-exact.  Returns ``(piv_rows (m, d) f64, pivot_d (n, m) f64,
    rank_models, pos_model)``.
    """
    if sub._custom is not None or not sub.is_vector:
        raise ValueError(
            "device retrain backend requires a built-in vector metric "
            f"(got {sub.metric!r})")
    n = sub.n
    mem = np.arange(n)
    d1 = sub.dist(cent_row, mem)                     # exact f64
    # bucket the padded length so retrains over drifting cluster sizes
    # reuse compiled kernels (same policy as cluster_major)
    n_pad = -(-n // 128) * 128
    dim = sub.data.shape[1]
    rows_np = np.zeros((1, n_pad, dim), np.float64)
    rows_np[0, :n] = sub.data
    mask_np = np.zeros((1, n_pad), bool)
    mask_np[0, :n] = True
    gids_np = np.where(mask_np, np.arange(n_pad)[None], -1)
    d1_np = np.zeros((1, n_pad), np.float64)
    d1_np[0, :n] = d1
    dtype = np.float64 if exact_sweeps else np.float32
    ctx = enable_x64() if exact_sweeps else nullcontext()
    with ctx:
        piv_gids = np.asarray(fft_sweeps(
            jnp.asarray(rows_np.astype(dtype)), jnp.asarray(mask_np),
            jnp.asarray(gids_np), jnp.asarray(d1_np.astype(dtype)),
            jnp.asarray(cent_row[None].astype(dtype)),
            jnp.asarray(np.asarray([-1])),           # centroid ∉ members
            m, sub.metric), dtype=np.int64)[0]
    sub.dist_count += n * (m - 1)

    piv_rows = np.empty((m, sub.data.shape[1]), np.float64)
    pivot_d = np.empty((n, m), np.float64)
    piv_rows[0] = cent_row
    pivot_d[:, 0] = d1
    for j in range(1, m):
        g = int(piv_gids[j])
        if g < 0:                                    # latched onto centroid
            piv_rows[j] = cent_row
            pivot_d[:, j] = d1
        else:
            piv_rows[j] = sub.data[g]
            pivot_d[:, j] = sub.dist(sub.data[g], mem)

    rows_f32 = jnp.asarray(rows_np.astype(np.float32))
    prow_f32 = jnp.asarray(piv_rows[None].astype(np.float32))
    cols_raw = pivot_columns(rows_f32, prow_f32, sub.metric)
    rank_models, pos_models, _ = _fit_all_models(
        cols_raw, jnp.asarray(mask_np), np.asarray([n]), n_rings,
        degree, pos_degree)
    return piv_rows, pivot_d, rank_models[0], pos_models[0]


__all__ = ["DeviceBuildResult", "device_build", "build_index",
           "build_snapshot", "retrain_device"]
