"""Device-side LIMS index builder (DESIGN.md §6).

The paper's build pipeline (§4: k-center clustering → FFT pivots →
per-(cluster, pivot) sorted distance columns → polynomial rank models →
rings/LIMS values → position models) expressed as batched JAX over a
padded cluster-major layout:

  ``cluster``   batched k-center / k-means sweeps over device distances
  ``pivots``    FFT pivot selection as device argmax sweeps + distance
                columns through the ``pdist`` Pallas kernel
  ``fit``       all K·m rank-model fits plus the K position-model fits
                as ONE batched Chebyshev-Vandermonde normal-equations
                solve, with a device-side certified rank-error estimate
  ``builder``   orchestration, the exact host materialization that
                ``LIMSIndex(backend="device")`` consumes, and the
                single-cluster retrain path ``ServingEngine`` routes
                through

Exactness contract: the device does the heavy lifting (clustering,
pivot selection, model fitting); every quantity exactness depends on —
pivot-distance columns, TriPrune extents, ring boundaries, certified
error bounds — is recomputed exactly on the host from the device's
structural choices (DESIGN.md §6).  Device-fit models are only ever
*accelerators*: the host path corrects them with exponential search,
the snapshot path re-certifies E against the exact columns.
"""
from .builder import (DeviceBuildResult, build_index, build_snapshot,
                      device_build, retrain_device)
from .cluster import cluster_major, device_kcenter, device_kmeans
from .fit import batched_chebfit

__all__ = [
    "DeviceBuildResult", "device_build", "build_index", "build_snapshot",
    "retrain_device", "device_kcenter", "device_kmeans", "cluster_major",
    "batched_chebfit",
]
