"""Batched device clustering for the LIMS builder.

``device_kcenter`` mirrors the host Gonzalez farthest-first traversal
(``repro.core.clustering.kcenter``) as a single ``lax.scan`` of K-1
argmax sweeps on device; ``device_kmeans`` runs Lloyd iterations with
``core.metrics.cdist`` + segment means.  Both return the same host
``Clustering`` record the numpy path produces.

Structural parity with the host build: the sweeps use the *direct*
(diff) distance formulation — the same math as the host's
``dist_one_to_many`` — and by default run in f64 via the scoped
``jax.experimental.enable_x64`` context, so every argmax sees values
within ~1 ulp of the host's and picks the same centers except on exact
ties.  ``exact_sweeps=False`` drops to f32 for accelerators without
fast f64; the resulting index is still exact (any partition is — the
materialization recomputes all bounds exactly, DESIGN.md §6), only
structural bit-parity with the host build is given up.

``dist_to_center`` is always recomputed on the host in f64 after the
sweeps: it becomes pivot column #1 of every cluster, and exactness
requires columns consistent with query-time host distances.
"""
from __future__ import annotations

import functools
from contextlib import nullcontext

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..core.clustering import Clustering
from ..core.metrics import MetricSpace, cdist


def one_to_all(X: jax.Array, row: jax.Array, metric: str) -> jax.Array:
    """(n,) distances row→X in the direct (diff) formulation — the same
    math as the host ``dist_one_to_many``, so f64 sweeps agree with the
    host to ~1 ulp (no Gram-trick cancellation)."""
    if metric == "l2":
        diff = X - row
        return jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    if metric == "l1":
        return jnp.sum(jnp.abs(X - row), axis=-1)
    if metric == "linf":
        return jnp.max(jnp.abs(X - row), axis=-1)
    if metric == "cosine":
        xn = X / jnp.maximum(jnp.linalg.norm(X, axis=-1, keepdims=True),
                             1e-12)
        rn = row / jnp.maximum(jnp.linalg.norm(row), 1e-12)
        return 1.0 - xn @ rn
    raise ValueError(f"device clustering: unsupported metric {metric!r}")


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _kcenter_sweeps(X: jax.Array, first: jax.Array, k: int, metric: str):
    """K-1 farthest-first sweeps as one ``lax.scan``; each step is one
    argmax + one one-vs-all distance pass (O(nd)), all on device."""
    n = X.shape[0]
    d0 = one_to_all(X, X[first], metric)
    centers0 = jnp.zeros((k,), jnp.int32).at[0].set(first.astype(jnp.int32))

    def step(carry, c):
        d_near, assign, centers = carry
        nxt = jnp.argmax(d_near).astype(jnp.int32)
        d_new = one_to_all(X, X[nxt], metric)
        closer = d_new < d_near
        assign = jnp.where(closer, c, assign)
        d_near = jnp.where(closer, d_new, d_near)
        centers = centers.at[c].set(nxt)
        return (d_near, assign, centers), None

    init = (d0, jnp.zeros(n, jnp.int32), centers0)
    (d_near, assign, centers), _ = jax.lax.scan(
        step, init, jnp.arange(1, k, dtype=jnp.int32))
    return centers, assign, d_near


def _exact_dist_to_center(space: MetricSpace, center_idx: np.ndarray,
                          members: list) -> np.ndarray:
    """Host-exact f64 distance to the own centroid, per object.  This is
    pivot column #1 downstream — it must be bit-consistent with the
    query-time ``dist_one_to_many`` (DESIGN.md §6)."""
    d_own = np.zeros(space.n, dtype=np.float64)
    for c, mem in enumerate(members):
        if len(mem):
            d_own[mem] = space.dist(space.data[int(center_idx[c])], mem)
    return d_own


def device_kcenter(space: MetricSpace, k: int, seed: int = 0,
                   exact_sweeps: bool = True) -> Clustering:
    """Device mirror of ``clustering.kcenter`` (same seed → same first
    center; f64 sweeps → same argmax picks up to ~1-ulp ties)."""
    n = space.n
    k = min(k, n)
    rng = np.random.default_rng(seed)
    first = int(rng.integers(n))
    dtype = np.float64 if exact_sweeps else np.float32
    ctx = enable_x64() if exact_sweeps else nullcontext()
    with ctx:
        X = jnp.asarray(space.data.astype(dtype))
        centers, assign, _ = _kcenter_sweeps(
            X, jnp.asarray(first), k, space.metric)
        centers = np.asarray(centers, dtype=np.int64)
        assign = np.asarray(assign, dtype=np.int64)
    space.dist_count += n * k        # the sweeps' distance passes
    members = [np.where(assign == c)[0] for c in range(k)]
    d_own = _exact_dist_to_center(space, centers, members)
    return Clustering(centers, assign, d_own, members)


@functools.partial(jax.jit, static_argnames=("k", "iters", "metric"))
def _kmeans_sweeps(X: jax.Array, cent0: jax.Array, k: int, iters: int,
                   metric: str):
    m = "l2" if metric == "cosine" else metric       # host `_cd` parity

    def body(_, cent):
        d = cdist(X, cent, m)
        assign = jnp.argmin(d, axis=1)
        sums = jnp.zeros_like(cent).at[assign].add(X.astype(cent.dtype))
        cnt = jnp.zeros((k,), cent.dtype).at[assign].add(1.0)
        return jnp.where(cnt[:, None] > 0, sums / jnp.maximum(cnt, 1.0)[:, None],
                         cent)

    cent = jax.lax.fori_loop(0, iters, body, cent0)
    d = cdist(X, cent, m)
    assign = jnp.argmin(d, axis=1)
    # snap centers to the nearest member (empty cluster → global argmin)
    d_member = jnp.where(assign[:, None] == jnp.arange(k)[None], d, jnp.inf)
    has = jnp.any(assign[:, None] == jnp.arange(k)[None], axis=0)
    center_idx = jnp.where(has, jnp.argmin(d_member, axis=0),
                           jnp.argmin(d, axis=0))
    return center_idx, assign


def device_kmeans(space: MetricSpace, k: int, iters: int = 15,
                  seed: int = 0) -> Clustering:
    """Lloyd's kMeans on device (vector metrics): ``cdist`` assignment +
    segment-sum means, centers snapped to real objects at the end.  The
    final assignment is recomputed against the snapped centers so it is
    consistent with the returned ``center_idx``."""
    if not space.is_vector:
        raise ValueError("kmeans requires a vector metric")
    n = space.n
    k = min(k, n)
    rng = np.random.default_rng(seed)
    cent0 = space.data[rng.choice(n, size=k, replace=False)]
    X = jnp.asarray(space.data, jnp.float32)
    center_idx, _ = _kmeans_sweeps(
        X, jnp.asarray(cent0, jnp.float32), k, iters, space.metric)
    center_idx = np.asarray(center_idx, dtype=np.int64)
    space.dist_count += n * k * (iters + 1)
    # final assignment against the *snapped* centers, on the host in f64
    # (cluster membership must agree with the exact dist_to_center below)
    d = np.stack([space.dist(space.data[int(c)]) for c in center_idx], axis=1)
    assign = np.argmin(d, axis=1).astype(np.int64)
    members = [np.where(assign == c)[0] for c in range(k)]
    d_own = _exact_dist_to_center(space, center_idx, members)
    return Clustering(center_idx, assign, d_own, members)


def cluster_major(members: list, pad_mult: int = 128):
    """Pack per-cluster member index lists into the padded cluster-major
    layout every builder stage runs over.

    Returns ``(member_idx (K, n_max) int64, mask (K, n_max) bool,
    counts (K,) int64, n_max)``; padding slots hold index 0 and a False
    mask.  Member order inside a cluster is the host order (ascending
    global id, from ``np.where``) so device argmaxes tie-break exactly
    like the host's.  ``n_max`` rounds up to a multiple of ``pad_mult``
    so repeated builds/retrains over drifting cluster sizes bucket onto
    the same shapes and reuse their compiled kernels.
    """
    K = len(members)
    counts = np.asarray([len(mm) for mm in members], dtype=np.int64)
    n_max = max(int(counts.max()) if K else 1, 1)
    n_max = -(-n_max // max(pad_mult, 1)) * max(pad_mult, 1)
    member_idx = np.zeros((K, n_max), dtype=np.int64)
    mask = np.zeros((K, n_max), dtype=bool)
    for c, mm in enumerate(members):
        member_idx[c, :len(mm)] = mm
        mask[c, :len(mm)] = True
    return member_idx, mask, counts, n_max


__all__ = ["device_kcenter", "device_kmeans", "cluster_major", "one_to_all"]
