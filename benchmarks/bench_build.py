"""Build/retrain throughput: host numpy loop vs the device builder.

Builds the same LIMS index twice per corpus size — once through the
sequential host path (``LIMSIndex.__init__``/``_build_cluster``) and
once through ``repro.build`` (``backend="device"``: batched clustering
sweeps, device FFT pivots, ``pdist``-kernel distance columns, one
batched least-squares launch for every rank/position model) — then
times §5.3 partial reconstruction (``retrain_cluster``) through both
backends on a dirtied cluster.

Emits ``name,us_per_call,derived`` rows (us per build/retrain) and, on
full runs, records everything in ``BENCH_build.json`` at
n ∈ {4k, 32k} so build/retrain throughput is tracked across PRs.  On
CPU the kernels run in interpret mode, so the absolute device numbers
only validate plumbing — the ``interpret`` flag rides along in the
record so compiled-backend runs are distinguishable.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import LIMSIndex, MetricSpace
from repro.core.index import RETRAIN_AUTO_ROWS
from repro.core.metrics import dist_one_to_many
from repro.kernels.dispatch import default_interpret

from .common import QUICK, emit, write_json

SIZES = (2_000, 6_000) if QUICK else (4_000, 32_000)
N_RETRAIN_INSERTS = 64
D = 8
DEGREE = 20          # the paper's rank-model degree — stresses the fits


def _params(n: int) -> dict:
    return dict(n_clusters=32 if n <= 8_000 else 64, m=3, n_rings=20,
                degree=DEGREE)


def _dirty_and_retrain(ix: LIMSIndex, X, backend: str, rng) -> float:
    rows = X[rng.choice(len(X), N_RETRAIN_INSERTS)] \
        + rng.normal(0, 0.01, (N_RETRAIN_INSERTS, X.shape[1]))
    for r in rows:
        ix.insert(r)
    c = int(np.argmax([len(ci.buf_ids) for ci in ix.clusters]))
    t0 = time.perf_counter()
    ix.retrain_cluster(c, backend=backend)
    return time.perf_counter() - t0


def bench_one(n: int) -> dict:
    from repro.data.datasets import gauss_mix

    X = gauss_mix(n, D, seed=0)
    p = _params(n)

    t0 = time.perf_counter()
    ih = LIMSIndex(MetricSpace(X, "l2"), **p)
    t_host = time.perf_counter() - t0

    # cold device build pays jit tracing/compilation; the warm rebuild
    # (same shapes → cached executables) is what a serving refresh loop
    # sees — report both
    t0 = time.perf_counter()
    iv = LIMSIndex(MetricSpace(X, "l2"), backend="device", **p)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    iv = LIMSIndex(MetricSpace(X, "l2"), backend="device", **p)
    t_dev = time.perf_counter() - t0
    stages = {k: round(v, 4) for k, v in iv.device_build_timings.items()}

    # sanity: both builds answer a probe query identically (exactness)
    rng = np.random.default_rng(1)
    q = X[rng.integers(n)] + rng.normal(0, 0.003, D)
    r = float(np.quantile(dist_one_to_many(q, X, "l2"), 1e-3))
    ids_h, _, _ = ih.range_query(q, r)
    ids_d, _, _ = iv.range_query(q, r)
    assert np.array_equal(ids_h, ids_d), "host/device builds disagree"

    # retrain a dirtied cluster through both backends (device retrain
    # runs on the host-built index too — backends are per-call); the
    # first device retrain is the compile-paying cold call
    t_rh = _dirty_and_retrain(ih, X, "host", rng)
    t_rd_cold = _dirty_and_retrain(ih, X, "device", rng)
    t_rd = _dirty_and_retrain(ih, X, "device", rng)
    # the "auto" router (core.index.RETRAIN_AUTO_ROWS crossover) — record
    # where it sent this cluster size so the routing decision is tracked
    # against the measured host/device times above
    t_ra = _dirty_and_retrain(ih, X, "auto", rng)
    auto_backend = ih.last_retrain_backend

    emit(f"build/host_n{n}", t_host * 1e6, f"s={t_host:.2f}")
    emit(f"build/device_n{n}", t_dev * 1e6,
         f"s={t_dev:.2f} (cold={t_cold:.2f}) "
         f"speedup={t_host / t_dev:.2f}x stages={stages}")
    emit(f"retrain/host_n{n}", t_rh * 1e6, f"ms={t_rh*1e3:.1f}")
    emit(f"retrain/device_n{n}", t_rd * 1e6,
         f"ms={t_rd*1e3:.1f} (cold={t_rd_cold*1e3:.0f}) "
         f"speedup={t_rh / t_rd:.2f}x")
    emit(f"retrain/auto_n{n}", t_ra * 1e6,
         f"ms={t_ra*1e3:.1f} routed={auto_backend}")
    return {
        "n": n, "d": D, **p, "interpret": default_interpret(),
        "build_host_s": round(t_host, 3),
        "build_device_s": round(t_dev, 3),
        "build_device_cold_s": round(t_cold, 3),
        "build_device_stages_s": stages,
        "build_speedup": round(t_host / t_dev, 3),
        "retrain_host_ms": round(t_rh * 1e3, 2),
        "retrain_device_ms": round(t_rd * 1e3, 2),
        "retrain_device_cold_ms": round(t_rd_cold * 1e3, 2),
        "retrain_speedup": round(t_rh / t_rd, 3),
        "retrain_auto_ms": round(t_ra * 1e3, 2),
        "retrain_auto_backend": auto_backend,
        "retrain_auto_rows": RETRAIN_AUTO_ROWS,
    }


def main() -> None:
    results = {str(n): bench_one(n) for n in SIZES}
    # only full runs rewrite the committed trajectory (quick numbers are
    # 1-shot noise, same policy as BENCH_serving.json)
    if not QUICK:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        write_json(os.path.join(root, "BENCH_build.json"),
                   {"bench": "LIMS build + retrain wall time, host numpy "
                             "loop vs device builder (repro.build)",
                    "sizes": results})


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
