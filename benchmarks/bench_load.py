"""Latency under load: open-loop Poisson arrivals against the frontend.

The closed-loop submitter threads in ``bench_batch`` measure *capacity*
(how fast the frontend can go when every submitter waits for its last
answer before sending the next).  A service's latency story needs the
opposite discipline: an **open-loop** arrival process, where requests
arrive on a schedule that does not care how the server is doing.  This
bench draws inter-arrival gaps from an exponential distribution (a
Poisson process), submits each request at its scheduled instant on its
own thread, and charges every request the full ``completion − scheduled
arrival`` interval — including any time the submitter itself started
late because the host was busy.  That accounting (no coordinated
omission) is what makes the p99-vs-load curve honest: a closed-loop
loop silently stops offering load exactly when the server stalls, hiding
the latencies that matter.

Sweep: offered load at fixed fractions of a measured closed-loop
capacity estimate.  Per level, latency percentiles come from the obs
metrics registry's bounded-reservoir :class:`Histogram` (the same
machinery the serving stack itself reports through), plus the shed
count from admission control.  The **knee** is the first level where
the system visibly stops keeping up: admission control sheds, or p99
blows past ``KNEE_P99_FACTOR ×`` the lightest level's p99.  The record
lands in ``BENCH_serving.json`` under ``latency_under_load`` when run
through ``bench_batch`` (config "1" sets ``BENCH_LOAD=1``), and prints
standalone via ``python -m benchmarks.bench_load``.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.obs.registry import Histogram
from repro.serving import FrontendOverload

from .common import QUICK, emit

# offered load as fractions of the measured closed-loop capacity: well
# under, approaching, at, and well past saturation — the knee lives in
# here.  The top fractions deliberately overdrive the frontend: the
# closed-loop capacity estimate is a max-coalescing number, and the
# latency story needs the level where even max batches can't keep up
# and admission control starts shedding.
LOAD_FRACTIONS = (0.25, 0.5, 1.0, 2.0, 4.0)
KNEE_P99_FACTOR = 5.0

# per-level SLO accounting (DESIGN.md §12): a request attains the SLO
# when its open-loop latency lands under this target; a shed request is
# a miss (admission control refusing work does not excuse the service
# objective).  burn_rate = miss fraction over the error budget
# (1 - objective): 1.0 spends the budget exactly, the monitor's
# SloBurnDetector alerts at 2x.
SLO_TARGET_MS = 50.0
SLO_OBJECTIVE = 0.99


def _measure_capacity(fe, Q, k: int, n: int, n_threads: int = 8) -> float:
    """Closed-loop q/s through the frontend: the denominator the load
    fractions are offered against."""
    fe.knn_query(Q[0], k)               # warm replicas + kernels
    per = max(n // n_threads, 1)

    def submitter(i: int) -> None:
        for j in range(per):
            fe.knn_query(Q[(i * per + j) % len(Q)], k)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return n_threads * per / (time.perf_counter() - t0)


def _run_level(fe, Q, k: int, offered_qps: float, n: int,
               seed: int) -> dict:
    """One open-loop level: ``n`` Poisson arrivals at ``offered_qps``.

    Every request gets its own thread, released at its scheduled
    arrival; latency is completion − *scheduled* arrival (open-loop
    time, so a late release is charged to the system, not forgiven)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / offered_qps, n)
    arrivals = np.cumsum(gaps)          # offsets from t0
    lat = Histogram(f"load.latency_s.{offered_qps:.0f}")
    lock = threading.Lock()
    counts = {"shed": 0, "slo_ok": 0}
    target_s = SLO_TARGET_MS / 1e3

    def fire(i: int, at: float, t0: float) -> None:
        delay = t0 + at - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        try:
            fe.knn_query(Q[i % len(Q)], k)
        except FrontendOverload:
            with lock:
                counts["shed"] += 1
            return
        took = time.perf_counter() - (t0 + at)
        lat.observe(took)
        if took <= target_s:
            with lock:
                counts["slo_ok"] += 1

    t0 = time.perf_counter()
    threads = [threading.Thread(target=fire, args=(i, arrivals[i], t0))
               for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    done = lat.count
    shed_n = counts["shed"]
    # SLO accounting: shed requests are misses, so attainment is
    # ok / offered (done + shed), not ok / completed
    attained = counts["slo_ok"] / max(done + shed_n, 1)
    return {
        "offered_qps": round(offered_qps, 1),
        "n": n,
        "completed": done,
        "shed": shed_n,
        "achieved_qps": round(done / elapsed, 1),
        "slo_attained": round(attained, 4),
        "burn_rate": round((1.0 - attained) / (1.0 - SLO_OBJECTIVE), 2),
        "latency_ms_p50": round(lat.percentile(50) * 1e3, 3),
        "latency_ms_p95": round(lat.percentile(95) * 1e3, 3),
        "latency_ms_p99": round(lat.percentile(99) * 1e3, 3),
        "latency_ms_mean": round(lat.mean * 1e3, 3) if done else 0.0,
    }


def bench_latency_under_load(se, Q, k: int = 10, *,
                             fractions=LOAD_FRACTIONS,
                             quick: bool = QUICK) -> dict:
    """Sweep offered load against a fresh frontend on ``se`` and return
    the latency-vs-load record (levels + knee)."""
    n_cap = 64 if quick else 160
    n_per_level = 48 if quick else 120
    # the queue must be smaller than a level's request count, or the
    # overdrive levels can never shed and the knee has nothing to find
    fe = se.frontend(max_batch=16, slo_ms=5.0,
                     max_queue=max(16, n_per_level // 2))
    try:
        cap_closed = _measure_capacity(fe, Q, k, n_cap)
        # calibration: the closed-loop number is a max-coalescing
        # ceiling; open-loop traffic at low rates dispatches mostly
        # singleton batches, whose service rate is far lower.  One
        # discarded overdrive level (offered = the closed-loop ceiling)
        # saturates the frontend, and its *achieved* q/s is the
        # open-loop sustainable rate — the capacity the sweep fractions
        # are actually offered against.  It doubles as warmup for the
        # batch shapes the capacity probe never dispatched.
        calib = _run_level(fe, Q, k, cap_closed, n_per_level, seed=99)
        cap = min(cap_closed, calib["achieved_qps"]) or cap_closed
        levels = []
        for j, frac in enumerate(fractions):
            lv = _run_level(fe, Q, k, frac * cap, n_per_level, seed=j)
            lv["offered_frac"] = frac
            levels.append(lv)
    finally:
        fe.close()
    # knee: the first level that sheds, or whose p99 blows out relative
    # to the best p99 seen at any lower offered load (min-so-far
    # baseline — robust to a noisy individual level)
    knee, best_p99 = None, float("inf")
    for lv in levels:
        p99 = lv["latency_ms_p99"]
        if lv["shed"] > 0 or \
                (best_p99 < float("inf")
                 and p99 > KNEE_P99_FACTOR * best_p99):
            knee = lv
            break
        best_p99 = min(best_p99, p99 or best_p99)
    base_p99 = best_p99 if best_p99 < float("inf") else 1e-3
    return {
        "discipline": "open-loop poisson arrivals, latency from "
                      "scheduled arrival (no coordinated omission)",
        "capacity_closed_loop_qps": round(cap_closed, 1),
        "capacity_qps": round(cap, 1),
        "slo_target_ms": SLO_TARGET_MS,
        "slo_objective": SLO_OBJECTIVE,
        "k": k,
        "n_per_level": n_per_level,
        "levels": levels,
        "knee": None if knee is None else {
            "offered_frac": knee["offered_frac"],
            "offered_qps": knee["offered_qps"],
            "latency_ms_p99": knee["latency_ms_p99"],
            "shed": knee["shed"],
            "p99_blowout_factor": round(
                knee["latency_ms_p99"] / base_p99, 1),
        },
    }


def main() -> None:
    from repro.core import LIMSIndex, MetricSpace
    from repro.core.serving import ServingEngine
    from repro.data.datasets import gauss_mix

    n = 4_000 if QUICK else 12_000
    d = 8
    X = gauss_mix(n, d, seed=0)
    ix = LIMSIndex(MetricSpace(X, "l2"), n_clusters=16, m=3, n_rings=20)
    se = ServingEngine(ix)
    rng = np.random.default_rng(1)
    Q = X[rng.choice(n, 64)] + rng.normal(0, 0.003, (64, d))
    rec = bench_latency_under_load(se, Q)
    for lv in rec["levels"]:
        emit(f"load/poisson_{lv['offered_frac']:.2f}x",
             lv["latency_ms_p99"] * 1e3,
             f"offered_qps={lv['offered_qps']} "
             f"achieved_qps={lv['achieved_qps']} "
             f"p50_ms={lv['latency_ms_p50']} "
             f"p99_ms={lv['latency_ms_p99']} shed={lv['shed']} "
             f"slo={lv['slo_attained']:.2%} burn={lv['burn_rate']}x")
    knee = rec["knee"]
    print(f"# capacity_qps={rec['capacity_qps']} knee="
          f"{knee['offered_frac'] if knee else 'none'}"
          f"{'x capacity' if knee else ' (no blowout in sweep)'}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
