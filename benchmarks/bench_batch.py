"""Batch-engine throughput: the serving story (queries/sec).

Compares, on the same snapshot and workload:
  * ``BatchedLIMS.range_query_batch`` / ``knn_query_batch`` — one kernel
    launch sequence for the whole batch;
  * the per-query ``BatchedLIMS`` loop (same kernels, batch size 1) —
    what the device path did before the batch engine;
  * the host ``LIMSIndex`` per-query path;
  * a brute-force linear scan.

Emits ``name,us_per_call,derived`` rows where us_per_call is per *query*
and derived records queries/sec plus the batch-vs-per-query speedup.
The acceptance bar for the batch engine is ≥5× the per-query device loop
at batch size 64 on CPU-interpret.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import LIMSIndex, MetricSpace
from repro.core.batched import BatchedLIMS
from repro.core.metrics import dist_one_to_many

from .common import QUICK, emit

BATCH = 64


def _bench(fn, reps: int) -> float:
    fn()                                    # warm-up (jit compile/trace)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def main() -> None:
    from repro.data.datasets import gauss_mix

    n = 6_000 if QUICK else 16_000
    d = 8
    X = gauss_mix(n, d, seed=0)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=16, m=3, n_rings=20)
    bx = BatchedLIMS(ix)

    rng = np.random.default_rng(1)
    Q = X[rng.choice(n, BATCH)] + rng.normal(0, 0.003, (BATCH, d))
    rs = np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), 1e-3))
                   for q in Q])
    reps = 1 if QUICK else 3

    # --- range ------------------------------------------------------------
    t_batch = _bench(lambda: bx.range_query_batch(Q, rs), reps)
    t_loop = _bench(
        lambda: [bx.range_query(q, r) for q, r in zip(Q, rs)], reps)
    t_host = _bench(
        lambda: [ix.range_query(q, r) for q, r in zip(Q, rs)], reps)
    t_scan = _bench(
        lambda: [np.where(dist_one_to_many(q, X, "l2") <= r)[0]
                 for q, r in zip(Q, rs)], reps)
    speedup = t_loop / t_batch
    emit("batch_range/batch64", t_batch / BATCH * 1e6,
         f"qps={BATCH / t_batch:.0f} speedup_vs_per_query={speedup:.1f}x")
    from repro.kernels.dispatch import default_interpret
    emit("batch_range/per_query_device", t_loop / BATCH * 1e6,
         f"qps={BATCH / t_loop:.0f}")
    emit("batch_range/host_index", t_host / BATCH * 1e6,
         f"qps={BATCH / t_host:.0f}")
    emit("batch_range/linear_scan", t_scan / BATCH * 1e6,
         f"qps={BATCH / t_scan:.0f}")
    # the 5x bar is defined for CPU-interpret at full reps; a single
    # quick-mode iteration (or a compiled backend where both paths are
    # fast) is too noisy to gate on
    if speedup < 5.0:
        print(f"# WARNING: batch speedup {speedup:.1f}x below the 5x bar")
        if default_interpret() and not QUICK:
            raise AssertionError(
                f"batch engine only {speedup:.1f}x over the per-query "
                f"loop (acceptance bar: 5x at batch {BATCH})")

    # --- kNN --------------------------------------------------------------
    k = 10
    t_batch = _bench(lambda: bx.knn_query_batch(Q, k), reps)
    t_loop = _bench(lambda: [bx.knn_query(q, k) for q in Q], reps)
    t_host = _bench(lambda: [ix.knn_query(q, k) for q in Q], reps)
    t_scan = _bench(
        lambda: [np.argsort(dist_one_to_many(q, X, "l2"))[:k] for q in Q],
        reps)
    emit("batch_knn/batch64", t_batch / BATCH * 1e6,
         f"qps={BATCH / t_batch:.0f} "
         f"speedup_vs_per_query={t_loop / t_batch:.1f}x")
    emit("batch_knn/per_query_device", t_loop / BATCH * 1e6,
         f"qps={BATCH / t_loop:.0f}")
    emit("batch_knn/host_index", t_host / BATCH * 1e6,
         f"qps={BATCH / t_host:.0f}")
    emit("batch_knn/linear_scan", t_scan / BATCH * 1e6,
         f"qps={BATCH / t_scan:.0f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
