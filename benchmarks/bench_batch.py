"""Batch-engine throughput: the serving story (queries/sec).

Compares, on the same snapshot and workload:
  * ``BatchedLIMS.range_query_batch`` / ``knn_query_batch`` — one kernel
    launch sequence for the whole batch;
  * the per-query ``BatchedLIMS`` loop (same kernels, batch size 1) —
    what the device path did before the batch engine;
  * the host ``LIMSIndex`` per-query path;
  * a brute-force linear scan.

Emits ``name,us_per_call,derived`` rows where us_per_call is per *query*
and derived records queries/sec plus the batch-vs-per-query speedup.
The acceptance bar for the batch engine is ≥5× the per-query device loop
at batch size 64 on CPU-interpret.

``ServingEngine`` scaling: the second phase measures queries/sec through
the full serving frontend at 1 vs N simulated host devices.  The device
count is baked into the process at jax init, so each configuration runs
in a subprocess with ``--xla_force_host_platform_device_count`` set
(``--serving`` puts this module in worker mode: run the serving bench
in-process, print one JSON record).  Results land in
``BENCH_serving.json``: q/s, the paper's pages/candidates per query,
kNN rounds + host syncs per batch (the plan/execute acceptance
metrics), and — for the ``paged-prefetch`` config — the async
prefetcher's overlap stats.

``--real-io`` drops the OS page cache (``posix_fadvise(DONTNEED)`` on
the pages files) before each cold store pass, so the cold numbers
measure device IO instead of kernel-cached reads.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import LIMSIndex, MetricSpace
from repro.core.batched import BatchedLIMS
from repro.core.metrics import dist_one_to_many

from .common import QUICK, emit, write_json

BATCH = 64
SERVING_DEVICES = (1, 4)     # simulated-host-device counts to compare
# (label, device count, extra env) serving configurations: in-memory
# scaling, the paged storage tier (page-granular IO, the paper's
# headline cost metric, recorded alongside q/s), and the paged tier
# with async prefetch (kNN rounds' page IO overlapped with refinement)
SERVING_CONFIGS = tuple(
    [(str(nd), nd, {}) for nd in SERVING_DEVICES]
    + [("paged", 1, {"REPRO_STORAGE": "paged"}),
       ("paged-prefetch", 1, {"REPRO_STORAGE": "paged",
                              "REPRO_PREFETCH": "async"})])


def _bench(fn, reps: int) -> float:
    fn()                                    # warm-up (jit compile/trace)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _bench_once(fn) -> float:
    """Single unwarmed call — for cold-cache IO measurements, where the
    first run IS the measurement."""
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main() -> None:
    from repro.data.datasets import gauss_mix

    n = 6_000 if QUICK else 16_000
    d = 8
    X = gauss_mix(n, d, seed=0)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=16, m=3, n_rings=20)
    bx = BatchedLIMS(ix)

    rng = np.random.default_rng(1)
    Q = X[rng.choice(n, BATCH)] + rng.normal(0, 0.003, (BATCH, d))
    rs = np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), 1e-3))
                   for q in Q])
    reps = 1 if QUICK else 3

    # --- range ------------------------------------------------------------
    t_batch = _bench(lambda: bx.range_query_batch(Q, rs), reps)
    t_loop = _bench(
        lambda: [bx.range_query(q, r) for q, r in zip(Q, rs)], reps)
    t_host = _bench(
        lambda: [ix.range_query(q, r) for q, r in zip(Q, rs)], reps)
    t_scan = _bench(
        lambda: [np.where(dist_one_to_many(q, X, "l2") <= r)[0]
                 for q, r in zip(Q, rs)], reps)
    speedup = t_loop / t_batch
    emit("batch_range/batch64", t_batch / BATCH * 1e6,
         f"qps={BATCH / t_batch:.0f} speedup_vs_per_query={speedup:.1f}x")
    from repro.kernels.dispatch import default_interpret
    emit("batch_range/per_query_device", t_loop / BATCH * 1e6,
         f"qps={BATCH / t_loop:.0f}")
    emit("batch_range/host_index", t_host / BATCH * 1e6,
         f"qps={BATCH / t_host:.0f}")
    emit("batch_range/linear_scan", t_scan / BATCH * 1e6,
         f"qps={BATCH / t_scan:.0f}")
    # the 5x bar is defined for CPU-interpret at full reps; a single
    # quick-mode iteration (or a compiled backend where both paths are
    # fast) is too noisy to gate on
    if speedup < 5.0:
        print(f"# WARNING: batch speedup {speedup:.1f}x below the 5x bar")
        if default_interpret() and not QUICK:
            raise AssertionError(
                f"batch engine only {speedup:.1f}x over the per-query "
                f"loop (acceptance bar: 5x at batch {BATCH})")

    # --- kNN --------------------------------------------------------------
    k = 10
    t_batch = _bench(lambda: bx.knn_query_batch(Q, k), reps)
    t_loop = _bench(lambda: [bx.knn_query(q, k) for q in Q], reps)
    t_host = _bench(lambda: [ix.knn_query(q, k) for q in Q], reps)
    t_scan = _bench(
        lambda: [np.argsort(dist_one_to_many(q, X, "l2"))[:k] for q in Q],
        reps)
    emit("batch_knn/batch64", t_batch / BATCH * 1e6,
         f"qps={BATCH / t_batch:.0f} "
         f"speedup_vs_per_query={t_loop / t_batch:.1f}x")
    emit("batch_knn/per_query_device", t_loop / BATCH * 1e6,
         f"qps={BATCH / t_loop:.0f}")
    emit("batch_knn/host_index", t_host / BATCH * 1e6,
         f"qps={BATCH / t_host:.0f}")
    emit("batch_knn/linear_scan", t_scan / BATCH * 1e6,
         f"qps={BATCH / t_scan:.0f}")


# ---------------------------------------------------------- serving scaling
def serving_worker() -> dict:
    """Measure ServingEngine throughput with this process's device count
    (set by the parent via XLA_FLAGS). Returns one JSON-able record."""
    import jax
    from repro.data.datasets import gauss_mix
    from repro.core.serving import ServingEngine

    n = 4_000 if QUICK else 12_000
    d = 8
    X = gauss_mix(n, d, seed=0)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=16, m=3, n_rings=20)
    se = ServingEngine(ix)       # auto-shards over the visible devices
    rng = np.random.default_rng(1)
    Q = X[rng.choice(n, BATCH)] + rng.normal(0, 0.003, (BATCH, d))
    rs = np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), 1e-3))
                   for q in Q])
    reps = 1 if QUICK else 3
    t_range = _bench(lambda: se.range_query_batch(Q, rs), reps)
    t_knn = _bench(lambda: se.knn_query_batch(Q, 10), reps)
    ex = se.executor
    rec = {
        "devices": jax.device_count(),
        "n_shards": getattr(ex, "n_shards", 1),
        "executor": type(ex).__name__,
        "n": n, "d": d, "batch": BATCH, "quick": QUICK,
        "range_qps": round(BATCH / t_range, 1),
        "knn_qps": round(BATCH / t_knn, 1),
        # the plan/execute acceptance metrics: growing-radius rounds per
        # batch and device→host syncs per batch (O(1) in the compiled
        # resident loop; per-round in the host-driven paged backend)
        "knn": dict(ex.last_knn),
    }
    if se.store is not None:
        # the paper's IO metric: page accesses (and candidates) per
        # query, from the store's cache stats over one clean batch each.
        # The cache is cleared first so misses are genuine disk reads
        # (the timing loops above fully warmed it); the kNN hit rate
        # then measures within-batch page reuse across growing-radius
        # rounds — Alg. 2's never-re-read-a-page contract — not the
        # tautological warm-cache 100%.  With --real-io the OS page
        # cache is additionally dropped (posix_fadvise DONTNEED) before
        # each cold pass, so page misses hit the device, not the
        # kernel's cache.
        real_io = bool(os.environ.get("REPRO_REAL_IO"))
        st = se.store

        def _cold():
            if ex.prefetcher is not None:
                # settle in-flight speculative fetches from the warm
                # loops — they would silently repopulate the cleared
                # cache and inflate the cold numbers
                ex.prefetcher.drain()
            st.cache.clear()
            st.stats.reset()
            if real_io:
                st.drop_os_cache()

        def _pf_fetched() -> int:
            # speculative reads bypass the buffer-pool counters
            # (record=False), so the cold passes account them
            # separately: genuine device reads = misses + this delta
            if ex.prefetcher is None:
                return 0
            ex.prefetcher.drain()
            return ex.prefetcher.pages_fetched

        _cold()
        pf0 = _pf_fetched()
        t_cold_range = _bench_once(lambda: se.range_query_batch(Q, rs))
        io_range = st.stats.snapshot()
        range_pf_reads = _pf_fetched() - pf0
        _cold()
        pf0 = _pf_fetched()
        t_cold_knn = _bench_once(lambda: se.knn_query_batch(Q, 10))
        io_knn = st.stats.snapshot()
        knn_pf_reads = _pf_fetched() - pf0
        rec["storage"] = {
            "mode": "paged",
            "real_io": real_io,
            "page_bytes": st.manifest.page_bytes,
            "total_pages": st.manifest.total_pages,
            "range_pages_per_query": io_range["pages_per_query"],
            "range_candidates_per_query": io_range["candidates_per_query"],
            "range_cold_page_reads": io_range["misses"],
            "range_cold_prefetch_reads": range_pf_reads,
            "cold_range_qps": round(BATCH / t_cold_range, 1),
            "knn_pages_per_query": io_knn["pages_per_query"],
            "knn_candidates_per_query": io_knn["candidates_per_query"],
            "knn_cold_page_reads": io_knn["misses"],
            "knn_cold_prefetch_reads": knn_pf_reads,
            "knn_within_batch_hit_rate": io_knn["hit_rate"],
            "cold_knn_qps": round(BATCH / t_cold_knn, 1),
        }
        rec["knn"] = dict(ex.last_knn)      # cold paged rounds/syncs
        if ex.prefetcher is not None:
            # prefetch overlap is measured on a point-lookup drilldown
            # workload (queries at pivot rows → near-zero seed radii):
            # its growing-radius rounds add pages incrementally, the
            # regime prefetch exists for.  Random-query batches over a
            # bench-sized corpus saturate the batch-deduped page union
            # in round 0, leaving later rounds no IO to overlap.
            piv = np.asarray(se.snapshot.pivots, np.float64).reshape(-1, d)
            _cold()
            ex.prefetcher.drain()
            ex.prefetcher.reset()
            se.knn_query_batch(piv[:16], 200)
            ex.prefetcher.drain()
            pf = ex.prefetcher.snapshot()
            pf["workload"] = "pivot-drilldown-16q-k200"
            pf["knn_rounds"] = ex.last_knn["rounds"]
            rec["storage"]["prefetch"] = pf
    return rec


def bench_serving_scaling(configs=SERVING_CONFIGS,
                          real_io: bool = False) -> None:
    """Run the serving worker once per configuration (device counts +
    the paged storage tier, with and without async prefetch) and record
    queries/sec — plus page accesses and candidates per query, kNN
    rounds and host syncs per batch, and prefetch overlap stats for
    store-backed runs — in BENCH_serving.json (committed alongside the
    code).  ``real_io`` (the --real-io flag) drops the OS page cache
    before each cold store pass so pages/query reflects device IO."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    results = {}
    for label, nd, extra_env in configs:
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if "host_platform_device_count" not in f]
        flags.append(f"--xla_force_host_platform_device_count={nd}")
        env["XLA_FLAGS"] = " ".join(flags)
        env["REPRO_STORAGE"] = ""
        env["REPRO_PREFETCH"] = ""
        env.update(extra_env)
        if real_io:
            env["REPRO_REAL_IO"] = "1"
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_batch", "--serving"],
            cwd=root, env=env, capture_output=True, text=True, check=True)
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        results[label] = rec
        io = rec.get("storage")
        extra = (f" pages/q={io['range_pages_per_query']:.0f}r"
                 f"/{io['knn_pages_per_query']:.0f}k"
                 f" of {io['total_pages']}") if io else ""
        if io and "prefetch" in io:
            extra += (f" prefetch_overlap="
                      f"{io['prefetch']['overlapped_rounds']}rounds")
        emit(f"serving/range_{label}", 1e6 / rec["range_qps"],
             f"qps={rec['range_qps']:.0f} shards={rec['n_shards']} "
             f"({rec['executor']}){extra}")
        emit(f"serving/knn_{label}", 1e6 / rec["knn_qps"],
             f"qps={rec['knn_qps']:.0f} rounds={rec['knn']['rounds']} "
             f"syncs={rec['knn']['host_syncs']}")
    write_json(os.path.join(root, "BENCH_serving.json"),
               {"bench": "ServingEngine queries/sec, 1 vs N simulated "
                         "host devices (CPU-interpret kernels) + the "
                         "paged storage tier (page accesses per query, "
                         "kNN rounds / host syncs per batch, async "
                         "prefetch overlap)",
                "batch": BATCH, "devices": results})


if __name__ == "__main__":
    if "--serving" in sys.argv:
        print(json.dumps(serving_worker()))
    else:
        print("name,us_per_call,derived")
        main()
        # only the full phase rewrites the committed BENCH_serving.json —
        # a BENCH_QUICK sanity run must not clobber it with 1-rep numbers
        if not QUICK:
            bench_serving_scaling(real_io="--real-io" in sys.argv)
