"""Batch-engine throughput: the serving story (queries/sec).

Compares, on the same snapshot and workload:
  * ``BatchedLIMS.range_query_batch`` / ``knn_query_batch`` — one kernel
    launch sequence for the whole batch;
  * the per-query ``BatchedLIMS`` loop (same kernels, batch size 1) —
    what the device path did before the batch engine;
  * the host ``LIMSIndex`` per-query path;
  * a brute-force linear scan.

Emits ``name,us_per_call,derived`` rows where us_per_call is per *query*
and derived records queries/sec plus the batch-vs-per-query speedup.
The acceptance bar for the batch engine is ≥5× the per-query device loop
at batch size 64 on CPU-interpret.

``ServingEngine`` scaling: the second phase measures queries/sec through
the full serving frontend at 1 vs N simulated host devices.  The device
count is baked into the process at jax init, so each configuration runs
in a subprocess with ``--xla_force_host_platform_device_count`` set
(``--serving`` puts this module in worker mode: run the serving bench
in-process, print one JSON record).  Results land in
``BENCH_serving.json``: q/s, the paper's pages/candidates per query,
kNN rounds + host syncs per batch (the plan/execute acceptance
metrics), and — for the ``paged-prefetch`` config — the async
prefetcher's overlap stats.  Each config also records: the frozen PR-4
golden drivers' q/s on the same workload (asserted: no config regresses
below them — the bar the interpret-mode rounds driver restores), the
``ServingFrontend`` metrics under concurrent single-query submitters
(achieved batch sizes, queue wait p50/p99, per-replica load, shed rate
from a deliberate overload burst), and — paged configs — the cache hit
rate of schedule-pinned eviction vs blind LRU under a squeezed
capacity (asserted: pinning wins).

``--real-io`` drops the OS page cache (``posix_fadvise(DONTNEED)`` on
the pages files) before each cold store pass, so the cold numbers
measure device IO instead of kernel-cached reads.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import LIMSIndex, MetricSpace
from repro.core.batched import BatchedLIMS
from repro.core.metrics import dist_one_to_many

from .common import QUICK, emit, write_json

BATCH = 64
SERVING_DEVICES = (1, 4)     # simulated-host-device counts to compare
# (label, device count, extra env) serving configurations: in-memory
# scaling, the paged storage tier (page-granular IO, the paper's
# headline cost metric, recorded alongside q/s), and the paged tier
# with async prefetch (kNN rounds' page IO overlapped with refinement)
SERVING_CONFIGS = tuple(
    # the single-device config additionally runs the open-loop Poisson
    # latency-under-load sweep (bench_load; BENCH_LOAD is a bench-driver
    # flag, not a REPRO_* knob)
    [(str(nd), nd, ({"BENCH_LOAD": "1"} if nd == 1 else {}))
     for nd in SERVING_DEVICES]
    + [("paged", 1, {"REPRO_STORAGE": "paged"}),
       ("paged-prefetch", 1, {"REPRO_STORAGE": "paged",
                              "REPRO_PREFETCH": "async"}),
       # the compiled XLA-CPU lane (interpret=off): jitted-XLA kernels +
       # autotuned tiles — the "fast as the hardware allows" lane on a
       # CPU-only host, held to the same golden no-regression bar (the
       # goldens run in the same lane inside the worker, so the bar
       # compares plan/execute vs the PR-4 drivers at compiled speed)
       ("xla-compiled", 1, {"REPRO_INTERPRET": "off"}),
       # full observability (metrics + spans + Chrome trace ring) under
       # the same golden no-regression bar as every other config — the
       # obs-overhead acceptance gate.  The other configs run at the
       # REPRO_OBS default ("on"), so the bar also covers metrics-on.
       ("obs-trace", 1, {"REPRO_OBS": "trace"}),
       # continuous health monitoring: the background sampler thread +
       # detectors live (DESIGN.md §12), held to the same golden bar —
       # the monitor must not tax the query path it watches.
       ("monitor", 1, {"REPRO_MONITOR": "on"})])


def _bench(fn, reps: int) -> float:
    fn()                                    # warm-up (jit compile/trace)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _bench_once(fn) -> float:
    """Single unwarmed call — for cold-cache IO measurements, where the
    first run IS the measurement."""
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _bench_paired(fn_a, fn_b, reps: int) -> tuple:
    """Best-of-``reps`` for two alternatives, interleaved a,b,a,b…

    Shared-CPU containers drift by tens of percent across seconds; a
    sequential mean charges that drift to whichever path ran in the slow
    window.  Interleaving exposes both paths to the same drift and
    best-of discards it — the standard timeit discipline — which is what
    the golden no-regression assertion needs to not be a coin flip."""
    fn_a(), fn_b()                          # warm-up (jit compile/trace)
    best_a = best_b = float("inf")
    for _ in range(max(reps, 3)):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def main() -> None:
    from repro.data.datasets import gauss_mix

    n = 6_000 if QUICK else 16_000
    d = 8
    X = gauss_mix(n, d, seed=0)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=16, m=3, n_rings=20)
    bx = BatchedLIMS(ix)

    rng = np.random.default_rng(1)
    Q = X[rng.choice(n, BATCH)] + rng.normal(0, 0.003, (BATCH, d))
    rs = np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), 1e-3))
                   for q in Q])
    reps = 1 if QUICK else 3

    # --- range ------------------------------------------------------------
    t_batch = _bench(lambda: bx.range_query_batch(Q, rs), reps)
    t_loop = _bench(
        lambda: [bx.range_query(q, r) for q, r in zip(Q, rs)], reps)
    t_host = _bench(
        lambda: [ix.range_query(q, r) for q, r in zip(Q, rs)], reps)
    t_scan = _bench(
        lambda: [np.where(dist_one_to_many(q, X, "l2") <= r)[0]
                 for q, r in zip(Q, rs)], reps)
    speedup = t_loop / t_batch
    emit("batch_range/batch64", t_batch / BATCH * 1e6,
         f"qps={BATCH / t_batch:.0f} speedup_vs_per_query={speedup:.1f}x")
    from repro.kernels.dispatch import default_interpret
    emit("batch_range/per_query_device", t_loop / BATCH * 1e6,
         f"qps={BATCH / t_loop:.0f}")
    emit("batch_range/host_index", t_host / BATCH * 1e6,
         f"qps={BATCH / t_host:.0f}")
    emit("batch_range/linear_scan", t_scan / BATCH * 1e6,
         f"qps={BATCH / t_scan:.0f}")
    # the 5x bar is defined for CPU-interpret at full reps; a single
    # quick-mode iteration (or a compiled backend where both paths are
    # fast) is too noisy to gate on
    if speedup < 5.0:
        print(f"# WARNING: batch speedup {speedup:.1f}x below the 5x bar")
        if default_interpret() and not QUICK:
            raise AssertionError(
                f"batch engine only {speedup:.1f}x over the per-query "
                f"loop (acceptance bar: 5x at batch {BATCH})")

    # --- kNN --------------------------------------------------------------
    k = 10
    t_batch = _bench(lambda: bx.knn_query_batch(Q, k), reps)
    t_loop = _bench(lambda: [bx.knn_query(q, k) for q in Q], reps)
    t_host = _bench(lambda: [ix.knn_query(q, k) for q in Q], reps)
    t_scan = _bench(
        lambda: [np.argsort(dist_one_to_many(q, X, "l2"))[:k] for q in Q],
        reps)
    emit("batch_knn/batch64", t_batch / BATCH * 1e6,
         f"qps={BATCH / t_batch:.0f} "
         f"speedup_vs_per_query={t_loop / t_batch:.1f}x")
    emit("batch_knn/per_query_device", t_loop / BATCH * 1e6,
         f"qps={BATCH / t_loop:.0f}")
    emit("batch_knn/host_index", t_host / BATCH * 1e6,
         f"qps={BATCH / t_host:.0f}")
    emit("batch_knn/linear_scan", t_scan / BATCH * 1e6,
         f"qps={BATCH / t_scan:.0f}")


# --------------------------------------------------------- frontend metrics
def _bench_frontend(se, Q, k: int = 10, n_threads: int = 8) -> dict:
    """Drive the ServingFrontend with concurrent single-query submitter
    threads (the workload it exists for) and return its metrics record:
    achieved batch sizes, queue wait p50/p99, frontend q/s, per-replica
    load — plus the shed rate from a paused-queue overload burst."""
    import threading

    fe = se.frontend(max_batch=16, slo_ms=5.0, max_queue=256)
    try:
        per = max(len(Q) // n_threads, 1)

        def submitter(i: int) -> None:
            for q in Q[i * per:(i + 1) * per]:
                fe.knn_query(q, k)

        fe.knn_query(Q[0], k)           # warm the replica set / kernels
        t0 = time.perf_counter()
        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        out = fe.metrics()
        out["frontend_qps"] = round(n_threads * per / elapsed, 1)

        # overload burst: hold the batcher, fill the bounded queue, and
        # count how many extra submits admission control sheds
        from repro.serving import FrontendOverload
        ov = se.frontend(max_batch=8, slo_ms=5.0, max_queue=8)
        try:
            ov.pause()
            burst, outcome = 16, {"admitted": 0, "shed": 0}
            holders = []

            def hold(q) -> None:
                try:
                    ov.knn_query(q, k)
                    outcome["admitted"] += 1
                except FrontendOverload:
                    outcome["shed"] += 1

            for j in range(burst):
                th = threading.Thread(target=hold, args=(Q[j % len(Q)],))
                th.start()
                holders.append(th)
                time.sleep(0.002)       # let the queue actually fill
            ov.resume()
            for th in holders:
                th.join()
            m = ov.metrics()
            out["overload"] = {"burst": burst, **outcome,
                               "shed_rate": m["shed_rate"]}
        finally:
            ov.close()
    finally:
        fe.close()
    return out


# ---------------------------------------------------------- serving scaling
def serving_worker() -> dict:
    """Measure ServingEngine throughput with this process's device count
    (set by the parent via XLA_FLAGS). Returns one JSON-able record."""
    import jax
    from repro.data.datasets import gauss_mix
    from repro.core.serving import ServingEngine
    from repro.kernels.dispatch import kernel_mode
    from repro.obs.monitor import maybe_monitor

    # the "monitor" config's overhead gate: with REPRO_MONITOR=on the
    # sampler thread ticks (probes + series + detectors) for the whole
    # worker run, and the q/s below must still clear the golden bar
    mon = maybe_monitor()

    n = 4_000 if QUICK else 12_000
    d = 8
    X = gauss_mix(n, d, seed=0)
    sp = MetricSpace(X, "l2")
    ix = LIMSIndex(sp, n_clusters=16, m=3, n_rings=20)
    se = ServingEngine(ix)       # auto-shards over the visible devices
    rng = np.random.default_rng(1)
    Q = X[rng.choice(n, BATCH)] + rng.normal(0, 0.003, (BATCH, d))
    rs = np.array([float(np.quantile(dist_one_to_many(q, X, "l2"), 1e-3))
                   for q in Q])
    reps = 1 if QUICK else 3
    ex = se.executor

    # paired best-of timing against the frozen PR-4 drivers
    # (tests/_golden_drivers) — the no-regression bar every config must
    # clear (the PR-5 interpret-mode loop fell below it; the
    # vectorized-round driver is the fix)
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "tests"))
    import _golden_drivers as golden
    g_range, g_knn = ((golden.range_store, golden.knn_store)
                      if se.store is not None
                      else (golden.range_resident, golden.knn_resident))
    t_range, t_g_range = _bench_paired(
        lambda: se.range_query_batch(Q, rs),
        lambda: g_range(ex, Q, rs), reps)
    t_knn, t_g_knn = _bench_paired(
        lambda: se.knn_query_batch(Q, 10),
        lambda: g_knn(ex, Q, 10), reps)
    rec = {
        "devices": jax.device_count(),
        "n_shards": getattr(ex, "n_shards", 1),
        "executor": type(ex).__name__,
        "n": n, "d": d, "batch": BATCH, "quick": QUICK,
        # which kernel lane answered (interpret / xla / pallas) — the
        # compiled XLA-CPU config reports "xla" here
        "kernel_mode": kernel_mode(),
        "range_qps": round(BATCH / t_range, 1),
        "knn_qps": round(BATCH / t_knn, 1),
        # the plan/execute acceptance metrics: growing-radius rounds per
        # batch and device→host syncs per batch (O(1) in the compiled
        # resident loop; per-round in the host-driven paged backend),
        # plus which kNN driver answered (loop / rounds / paged)
        "knn": dict(ex.last_knn),
    }

    rec["golden"] = {"range_qps": round(BATCH / t_g_range, 1),
                     "knn_qps": round(BATCH / t_g_knn, 1)}

    # frontend phase: concurrent single-query submitters through the
    # dynamic batcher → router → replica set (one replica per device);
    # records achieved batch sizes, queue waits, per-replica balance,
    # and a deliberate overload burst for the shed rate
    rec["frontend"] = _bench_frontend(se, Q)
    if os.environ.get("BENCH_LOAD") == "1":
        # open-loop Poisson latency-under-load sweep (ROADMAP item 2):
        # latency percentiles vs offered load, knee where the frontend
        # stops keeping up (p99 blowout or admission-control shed)
        from .bench_load import bench_latency_under_load
        rec["latency_under_load"] = bench_latency_under_load(se, Q)
    if se.store is not None:
        # the paper's IO metric: page accesses (and candidates) per
        # query, from the store's cache stats over one clean batch each.
        # The cache is cleared first so misses are genuine disk reads
        # (the timing loops above fully warmed it); the kNN hit rate
        # then measures within-batch page reuse across growing-radius
        # rounds — Alg. 2's never-re-read-a-page contract — not the
        # tautological warm-cache 100%.  With --real-io the OS page
        # cache is additionally dropped (posix_fadvise DONTNEED) before
        # each cold pass, so page misses hit the device, not the
        # kernel's cache.
        from repro import env as repro_env
        real_io = repro_env.get("REPRO_REAL_IO") == "1"
        st = se.store

        def _cold():
            if ex.prefetcher is not None:
                # settle in-flight speculative fetches from the warm
                # loops — they would silently repopulate the cleared
                # cache and inflate the cold numbers
                ex.prefetcher.drain()
            st.cache.clear()
            st.stats.reset()
            if real_io:
                st.drop_os_cache()

        def _pf_fetched() -> int:
            # speculative reads bypass the buffer-pool counters
            # (record=False), so the cold passes account them
            # separately: genuine device reads = misses + this delta
            if ex.prefetcher is None:
                return 0
            ex.prefetcher.drain()
            return ex.prefetcher.pages_fetched

        _cold()
        pf0 = _pf_fetched()
        t_cold_range = _bench_once(lambda: se.range_query_batch(Q, rs))
        io_range = st.stats.snapshot()
        range_pf_reads = _pf_fetched() - pf0
        _cold()
        pf0 = _pf_fetched()
        t_cold_knn = _bench_once(lambda: se.knn_query_batch(Q, 10))
        io_knn = st.stats.snapshot()
        knn_pf_reads = _pf_fetched() - pf0
        rec["storage"] = {
            "mode": "paged",
            "real_io": real_io,
            "page_bytes": st.manifest.page_bytes,
            "total_pages": st.manifest.total_pages,
            "range_pages_per_query": io_range["pages_per_query"],
            "range_candidates_per_query": io_range["candidates_per_query"],
            "range_cold_page_reads": io_range["misses"],
            "range_cold_prefetch_reads": range_pf_reads,
            "cold_range_qps": round(BATCH / t_cold_range, 1),
            "knn_pages_per_query": io_knn["pages_per_query"],
            "knn_candidates_per_query": io_knn["candidates_per_query"],
            "knn_cold_page_reads": io_knn["misses"],
            "knn_cold_prefetch_reads": knn_pf_reads,
            "knn_within_batch_hit_rate": io_knn["hit_rate"],
            "cold_knn_qps": round(BATCH / t_cold_knn, 1),
        }
        rec["knn"] = dict(ex.last_knn)      # cold paged rounds/syncs
        if ex.prefetcher is not None:
            # prefetch overlap is measured on a point-lookup drilldown
            # workload (queries at pivot rows → near-zero seed radii):
            # its growing-radius rounds add pages incrementally, the
            # regime prefetch exists for.  Random-query batches over a
            # bench-sized corpus saturate the batch-deduped page union
            # in round 0, leaving later rounds no IO to overlap.
            piv = np.asarray(se.snapshot.pivots, np.float64).reshape(-1, d)
            _cold()
            ex.prefetcher.drain()
            ex.prefetcher.reset()
            se.knn_query_batch(piv[:16], 200)
            ex.prefetcher.drain()
            pf = ex.prefetcher.snapshot()
            pf["workload"] = "pivot-drilldown-16q-k200"
            pf["knn_rounds"] = ex.last_knn["rounds"]
            rec["storage"]["prefetch"] = pf

        # schedule pinning vs blind LRU: the same cold kNN batch through
        # a capacity-squeezed cache with plan pinning on vs off.  The
        # squeeze (a quarter of the batch's unique pages) forces
        # evictions mid-batch; blind LRU then drops pages the plan's
        # later rounds are guaranteed to re-demand, pinning holds them —
        # the acceptance signal is a strictly higher hit rate pinned.
        squeeze = max(4, io_knn["misses"] // 4)

        def _hit_rate(pin: bool) -> float:
            os.environ["REPRO_CACHE_PIN"] = "on" if pin else "off"
            cap0 = st.cache.capacity_pages
            st.cache.capacity_pages = squeeze
            try:
                _cold()
                se.knn_query_batch(Q, 10)
                return st.stats.snapshot()["hit_rate"]
            finally:
                st.cache.capacity_pages = cap0
                os.environ.pop("REPRO_CACHE_PIN", None)

        rec["storage"]["cache_pinning"] = {
            "squeezed_capacity_pages": int(squeeze),
            "hit_rate_pinned": _hit_rate(True),
            "hit_rate_blind_lru": _hit_rate(False),
        }

    # what the obs layer saw over the whole worker run: scalar metrics
    # (counters + gauges; histograms stay out of the committed JSON),
    # the profile ring depth, and the trace ring depth under trace mode
    from repro import obs
    scalars = {k: v for k, v in obs.REGISTRY.snapshot().items()
               if not isinstance(v, dict)}
    rec["obs"] = {"mode": obs.obs_mode(),
                  "metrics": len(obs.REGISTRY),
                  "profiles": len(obs.profiles()),
                  "trace_events": obs.trace_len(),
                  "counters": scalars}
    if mon is not None:
        rec["obs"]["monitor"] = {"ticks": mon.store.ticks,
                                 "series": len(mon.store.names()),
                                 "findings": len(mon.findings())}
        mon.stop()
    return rec


def bench_serving_scaling(configs=SERVING_CONFIGS,
                          real_io: bool = False) -> None:
    """Run the serving worker once per configuration (device counts +
    the paged storage tier, with and without async prefetch) and record
    queries/sec — plus page accesses and candidates per query, kNN
    rounds and host syncs per batch, and prefetch overlap stats for
    store-backed runs — in BENCH_serving.json (committed alongside the
    code).  ``real_io`` (the --real-io flag) drops the OS page cache
    before each cold store pass so pages/query reflects device IO."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    results = {}
    for label, nd, extra_env in configs:
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if "host_platform_device_count" not in f]
        flags.append(f"--xla_force_host_platform_device_count={nd}")
        env["XLA_FLAGS"] = " ".join(flags)
        env["REPRO_STORAGE"] = ""
        env["REPRO_PREFETCH"] = ""
        env["REPRO_INTERPRET"] = ""
        env["REPRO_OBS"] = ""           # blank -> the default ("on")
        env["REPRO_MONITOR"] = ""       # blank -> the default ("off")
        env.pop("BENCH_LOAD", None)
        env.update(extra_env)
        if real_io:
            env["REPRO_REAL_IO"] = "1"
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_batch", "--serving"],
            cwd=root, env=env, capture_output=True, text=True, check=True)
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        results[label] = rec
        # no-regression bar (satellite of the rounds-driver fix): every
        # config must keep up with the PR-4 golden drivers it replaced
        # (10% measurement slack; the regression this guards against was
        # a 2.4x q/s drop).  Async-prefetch configs get a wider band:
        # speculation eagerly evaluates the next round's mask on the
        # foreground thread, a real per-round kernel cost the
        # never-prefetching golden doesn't pay — on interpret-CPU fake
        # IO that overhead buys nothing back (the overlap it exists for
        # is measured on the drilldown workload below), so the bar here
        # only guards against driver regressions, not the documented
        # speculation cost.
        slack = 0.75 if extra_env.get("REPRO_PREFETCH") == "async" else 0.9
        for kind in ("range", "knn"):
            new, old = rec[f"{kind}_qps"], rec["golden"][f"{kind}_qps"]
            assert new >= slack * old, (
                f"serving config '{label}': {kind} at {new} q/s is "
                f"slower than the PR-4 golden driver ({old} q/s)")
        cp = (rec.get("storage") or {}).get("cache_pinning")
        if cp:
            assert cp["hit_rate_pinned"] > cp["hit_rate_blind_lru"], (
                f"serving config '{label}': schedule pinning "
                f"({cp['hit_rate_pinned']}) did not beat blind LRU "
                f"({cp['hit_rate_blind_lru']}) under a squeezed cache")
        io = rec.get("storage")
        extra = (f" pages/q={io['range_pages_per_query']:.0f}r"
                 f"/{io['knn_pages_per_query']:.0f}k"
                 f" of {io['total_pages']}") if io else ""
        if io and "prefetch" in io:
            extra += (f" prefetch_overlap="
                      f"{io['prefetch']['overlapped_rounds']}rounds")
        emit(f"serving/range_{label}", 1e6 / rec["range_qps"],
             f"qps={rec['range_qps']:.0f} shards={rec['n_shards']} "
             f"({rec['executor']}){extra}")
        emit(f"serving/knn_{label}", 1e6 / rec["knn_qps"],
             f"qps={rec['knn_qps']:.0f} rounds={rec['knn']['rounds']} "
             f"syncs={rec['knn']['host_syncs']} "
             f"driver={rec['knn'].get('driver')} "
             f"golden_qps={rec['golden']['knn_qps']:.0f}")
        fr = rec.get("frontend")
        if fr:
            emit(f"serving/frontend_{label}", 1e6 / fr["frontend_qps"],
                 f"qps={fr['frontend_qps']:.0f} "
                 f"batch_mean={fr['batch_size_mean']} "
                 f"wait_p99_ms={fr['queue_wait_ms_p99']} "
                 f"overload_shed_rate={fr['overload']['shed_rate']}")
    write_json(os.path.join(root, "BENCH_serving.json"),
               {"bench": "ServingEngine queries/sec, 1 vs N simulated "
                         "host devices (CPU-interpret kernels) + the "
                         "paged storage tier (page accesses per query, "
                         "kNN rounds / host syncs per batch, async "
                         "prefetch overlap) + the serving frontend "
                         "(dynamic batching, queue waits, shed rate, "
                         "per-replica load) with PR-4 golden-driver "
                         "baselines and pinned-vs-LRU cache hit rates",
                "batch": BATCH, "devices": results})


if __name__ == "__main__":
    if "--serving" in sys.argv:
        print(json.dumps(serving_worker()))
    else:
        print("name,us_per_call,derived")
        main()
        # only the full phase rewrites the committed BENCH_serving.json —
        # a BENCH_QUICK sanity run must not clobber it with 1-rep numbers
        if not QUICK:
            bench_serving_scaling(real_io="--real-io" in sys.argv)
