"""Shared benchmark helpers: datasets, query workloads, measurement."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import LIMSIndex, MetricSpace
from repro.core.metrics import dist_one_to_many
from repro.data.datasets import dataset_by_name

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
N_DEFAULT = 20_000 if QUICK else 60_000
N_QUERIES = 8 if QUICK else 15

_cache: dict = {}


def space(name: str, n: int = None, d: int = 8, seed: int = 0) -> MetricSpace:
    n = n or N_DEFAULT
    key = (name, n, d, seed)
    if key not in _cache:
        data, metric = dataset_by_name(name, n, d, seed)
        _cache[key] = MetricSpace(data, metric)
    return _cache[key]


def queries(sp: MetricSpace, n_q: int = None, seed: int = 1):
    """Query objects: dataset points + small perturbation (vector) or raw
    dataset points (generic metrics), as the paper samples queries."""
    n_q = n_q or N_QUERIES
    rng = np.random.default_rng(seed)
    idx = rng.choice(sp.n, n_q, replace=False)
    if sp.is_vector:
        return sp.data[idx] + rng.normal(0, 0.003, (n_q, sp.data.shape[1]))
    return sp.data[idx]


def radius_for_selectivity(sp: MetricSpace, q, sel: float) -> float:
    d = dist_one_to_many(q, sp.data, sp.metric)
    return float(np.quantile(d, sel))


def run_range(index, qs, rs):
    """Aggregate (avg_pages, avg_time_ms, avg_probes, avg_dist) + exactness
    oracle count."""
    pages = t = probes = dist = 0.0
    n_res = 0
    for q, r in zip(qs, rs):
        ids, ds, st = index.range_query(q, r)
        pages += st.pages
        t += st.time_s
        probes += st.probes
        dist += st.dist_comps
        n_res += len(ids)
    n = len(qs)
    return {"pages": pages / n, "ms": t / n * 1e3, "probes": probes / n,
            "dist": dist / n, "results": n_res / n}


def run_knn(index, qs, k: int):
    pages = t = 0.0
    for q in qs:
        ids, ds, st = index.knn_query(q, k)
        pages += st.pages
        t += st.time_s
    n = len(qs)
    return {"pages": pages / n, "ms": t / n * 1e3}


# every emit() row also lands here so the driver (run.py) can persist a
# section's results to BENCH_<section>.json — the perf trajectory is
# tracked across PRs instead of only printed
RESULTS: list = []


def reset_results() -> None:
    RESULTS.clear()


def snapshot_results() -> list:
    return list(RESULTS)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    RESULTS.append({"name": name, "us_per_call": round(float(us_per_call), 1),
                    "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


def write_json(path: str, payload: dict) -> None:
    """Persist a BENCH_<name>.json atomically (temp file + rename): an
    interrupted run can never truncate a committed trajectory file.
    Same publish primitive as the storage tier's manifest swap."""
    from repro.storage import write_atomic
    write_atomic(path, (json.dumps(payload, indent=2) + "\n").encode())
