"""Benchmark driver: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV; BENCH_QUICK=1 shrinks scales."""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (bench_batch, bench_kernels, bench_knn, bench_misc,
                   bench_range)
    sections = [
        ("kernels", bench_kernels.main),
        ("batch engine (serving)", bench_batch.main),
        ("range (Fig 6/7)", bench_range.main),
        ("knn (Fig 9/10)", bench_knn.main),
        ("params/signature/build/updates/ablation (Fig 5/8/11-14)",
         bench_misc.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections:
        t0 = time.time()
        print(f"# --- {name}", file=sys.stderr)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
        print(f"# --- {name} done in {time.time()-t0:.0f}s",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark sections failed")


if __name__ == "__main__":
    main()
